//! End-to-end driver: full-stack federated training of the token LM with
//! CoGC + GC⁺ over an unreliable network.
//!
//! This is the capstone run proving the layers compose:
//!   coded combine kernels (Pallas artifact or native rust) →
//!   model train/eval steps (AOT HLO or native fwd/bwd) →
//!   rust coordinator (gradient coding over Bernoulli erasures, GC⁺).
//!
//!     cargo run --release --example e2e_transformer [ROUNDS] [AGG]
//!
//! Runs offline out of the box: the auto backend picks the AOT PJRT
//! transformer when `make artifacts` has been run and the native
//! embedding+linear LM otherwise. Defaults: 150 rounds, gcplus-until.
//! The loss curve is written to results/e2e_transformer.csv and summarized
//! on stdout; the headline comparison lands in EXPERIMENTS.md.

use cogc::coordinator::{Aggregator, TrainConfig, Trainer};
use cogc::network::Network;
use cogc::runtime::Backend;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let agg_name = std::env::args().nth(2).unwrap_or_else(|| "gcplus-until".into());
    let agg = match agg_name.as_str() {
        "ideal" => Aggregator::Ideal,
        "intermittent" => Aggregator::Intermittent,
        "gcplus" => Aggregator::GcPlus { tr: 2, until_decode: false, max_blocks: 1 },
        _ => Aggregator::GcPlus { tr: 2, until_decode: true, max_blocks: 25 },
    };

    let backend = Backend::auto();
    let man = backend.manifest();
    let spec = man.model("transformer")?;
    println!(
        "e2e transformer [{} backend]: D = {} params, batch {} x seq {}, M = {} clients",
        backend.name(),
        spec.d,
        spec.batch,
        spec.x_shape[1],
        man.m
    );

    // moderately hostile network: poor uplinks, moderate c2c
    let net = match agg {
        Aggregator::Ideal => Network::perfect(man.m),
        _ => Network::homogeneous(man.m, 0.5, 0.3),
    };

    let mut cfg = TrainConfig::new("transformer", agg);
    cfg.rounds = rounds;
    cfg.local_iters = 2; // keep wallclock sane on CPU
    cfg.per_client = 20_000; // tokens per client
    cfg.eval_batches = 4;
    cfg.eval_every = 5;
    cfg.seed = 1;
    if backend.name() == "native" {
        // the native bigram LM is far smaller than the AOT transformer and
        // needs a proportionally larger step (validated: loss 4.3 -> ~2.7
        // over 150 rounds at 0.5; flat at the transformer's 0.05)
        cfg.lr = 0.5;
    }

    println!("config: {rounds} rounds x I={} local steps, agg = {agg_name}", cfg.local_iters);
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(&backend, cfg, net)?;
    let log = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/e2e_transformer.csv", log.to_csv())?;

    // loss-curve summary
    println!("\nround  train_loss  eval_loss  token_acc  outcome");
    for rec in log.rounds.iter().filter(|r| r.test_acc.is_finite()) {
        println!(
            "{:>5}  {:>9.4}  {:>9.4}  {:>8.4}  {}",
            rec.round, rec.train_loss, rec.test_loss, rec.test_acc, rec.outcome
        );
    }
    let first = log.rounds.first().unwrap().train_loss;
    let last = log.rounds.last().unwrap().train_loss;
    println!(
        "\ntrain loss {first:.4} -> {last:.4} over {rounds} rounds ({} updates, {:.1}s wall, {:.2}s/round)",
        log.updates(),
        wall,
        wall / rounds as f64
    );
    println!("final token accuracy: {:.4}", log.final_acc());
    println!("loss curve written to results/e2e_transformer.csv");
    anyhow::ensure!(last < 0.8 * first, "loss did not decrease meaningfully");
    Ok(())
}
