//! GC⁺ rescue demo (paper §VI): on a network where the standard binary GC
//! decoder is effectively dead (P_O ≈ 1), the complementary decoder turns
//! the *same* received rows into recovered local models — and client-to-
//! client outages *help*, by raising the rank of the received coefficients
//! (Lemma 2).
//!
//!     cargo run --release --example gcplus_rescue
//!
//! Pure coding layer with synthetic payloads; exact decode errors printed.

use cogc::gc::GcCode;
use cogc::linalg::rank;
use cogc::network::{Network, Realization};
use cogc::outage::mc::{gcplus_recovery, RecoveryMode};
use cogc::outage::overall_outage;
use cogc::parallel::{derive_seed, MonteCarlo};
use cogc::scenario::Iid;
use cogc::sim::{simulate_round, Decoder, Outcome};
use cogc::util::rng::Rng;

fn main() {
    let (m, s, tr) = (10, 7, 2);
    let net = Network::conn_tier("poor", m); // p_c2s = 0.75, p_c2c = 0.8
    let mut rng = Rng::new(2025);

    println!("network: p(client->PS outage) = 0.75, p(client->client outage) = 0.8\n");

    // 1. standard GC is dead
    let code = GcCode::generate(m, s, &mut rng);
    let po = overall_outage(&net, &code);
    println!("standard GC decoder: P_O = {po:.6}  ->  E[rounds/success] = {:.0}", 1.0 / (1.0 - po));

    // 2. the rank story: perturbation raises rank above M - s = 3
    println!("\nrank of received coefficients (Lemma 2): unperturbed rank(B) = {}", m - s);
    for trial in 0..5 {
        let code = GcCode::generate(m, s, &mut rng);
        let real = Realization::sample(&net, &mut rng);
        let perturbed = cogc::gc::gcplus::perturb(&code, &real);
        println!(
            "  trial {trial}: rank(B perturbed) = {} (erasures broke the cyclic structure)",
            rank(&perturbed)
        );
    }

    // 3. GC+ decodes payloads exactly
    println!("\nGC+ on synthetic payloads (t_r = {tr}, exact decode errors):");
    let mut decoded_rounds = 0;
    for round in 0..10 {
        let r = simulate_round(&net, &mut Iid, m, s, 64, Decoder::GcPlus { tr }, &mut rng);
        match &r.outcome {
            Outcome::Standard { .. } => println!("  round {round}: standard GC decoded (lucky round)"),
            Outcome::Full => {
                decoded_rounds += 1;
                println!("  round {round}: FULL recovery, max decode err {:.2e}", r.decode_err);
            }
            Outcome::Partial { k4 } => {
                decoded_rounds += 1;
                println!(
                    "  round {round}: partial recovery of {:?}, max decode err {:.2e}",
                    k4, r.decode_err
                );
            }
            Outcome::None => println!("  round {round}: nothing decodable this round"),
        }
    }
    println!("  -> {decoded_rounds}/10 rounds recovered information the standard decoder discards");

    // 4. aggregate statistics, both repetition modes — fanned out over all
    //    cores by the deterministic parallel Monte-Carlo engine
    println!("\nrecovery statistics over 2000 rounds:");
    for (stream, (mode, name)) in [
        (RecoveryMode::FixedTr(tr), "fixed t_r = 2        "),
        (RecoveryMode::UntilDecode { tr, max_blocks: 50 }, "until-decode (Alg. 1)"),
    ]
    .into_iter()
    .enumerate()
    {
        // derive_seed keeps the two modes' per-trial RNG streams disjoint
        // (adjacent raw seeds would overlap under `seed ^ trial` seeding)
        let st = gcplus_recovery(
            &net,
            &Iid,
            m,
            s,
            mode,
            2000,
            &MonteCarlo::new(derive_seed(2025, stream as u64)),
        );
        println!(
            "  {name}: full {:.3}  partial {:.3}  none {:.3}  (mean attempts {:.1})",
            st.p_full(),
            st.p_partial(),
            st.p_none(),
            st.mean_attempts()
        );
    }
}
