//! Outage analysis walk-through (paper §IV–§V): closed-form P_O vs
//! Monte-Carlo, the P₁/P₂/P₃ subcase decomposition, cost-efficient code
//! design, and the Theorem-1 convergence-bound numerics.
//!
//!     cargo run --release --example outage_analysis
//!
//! Needs no artifacts — pure coding-theory layer.

use cogc::gc::GcCode;
use cogc::network::Network;
use cogc::outage::theory::{expected_rounds_between_success, theorem1_bound, Theorem1Params};
use cogc::outage::{self, design};
use cogc::parallel::{derive_seed, MonteCarlo};
use cogc::scenario::Iid;
use cogc::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let m = 10;

    println!("== closed form vs Monte-Carlo (M={m}) ==");
    println!("{:>3} {:>6} {:>6} {:>10} {:>10} {:>26}", "s", "p_m", "p_mk", "P_O exact", "P_O mc", "P1 + P2 + P3");
    for (case, &(s, pm, pmk)) in [(7usize, 0.4, 0.25), (7, 0.75, 0.5), (3, 0.2, 0.2), (5, 0.1, 0.1)]
        .iter()
        .enumerate()
    {
        let net = Network::homogeneous(m, pm, pmk);
        let code = GcCode::generate(m, s, &mut rng);
        let exact = outage::overall_outage(&net, &code);
        // parallel Monte-Carlo engine: all cores, bit-identical at any count
        let engine = MonteCarlo::new(derive_seed(42, case as u64));
        let mc = outage::estimate_outage(&net, &code, &Iid, 40_000, &engine);
        let (p1, p2, p3) = outage::subcase_probs(&net, &code);
        println!(
            "{s:>3} {pm:>6.2} {pmk:>6.2} {exact:>10.5} {mc:>10.5} {:>8.5}+{:>8.5}+{:>8.5}",
            p1, p2, p3
        );
        assert!((p1 + p2 + p3 - exact).abs() < 1e-9);
    }

    println!("\n== Remark 4: expected rounds between successful recoveries ==");
    for &po in &[0.1, 0.5, 0.9, 0.99] {
        println!("  P_O = {po:<5}  E[R] = {:.1}", expected_rounds_between_success(po));
    }

    println!("\n== cost-efficient design (eq. 21): p = 0.1, target P_O* = 0.5 ==");
    let net = Network::homogeneous(m, 0.1, 0.1);
    println!("{:>3} {:>10} {:>12} {:>14}", "s", "P_O", "tx/round", "tx/success");
    for d in design::sweep(&net, 1) {
        println!(
            "{:>3} {:>10.6} {:>12.2} {:>14.2}",
            d.s, d.p_o, d.tx_per_round, d.tx_per_success
        );
    }
    let pick = design::cost_efficient_s(&net, 0.5, 1).unwrap();
    println!("=> s* = {} (P_O = {:.4}), vs default s = 7", pick.s, pick.p_o);

    println!("\n== Theorem 1: epsilon(P_O) at T = 1e7, M = 10, I = 5 ==");
    for &po in &[0.1, 0.3, 0.6, 0.9] {
        let b = theorem1_bound(&Theorem1Params {
            m,
            t: 10_000_000,
            i: 5,
            p_o: po,
            p_c2s: vec![0.3; m],
            sigma2: 1.0,
            d2: vec![1.0; m],
            f_gap: 10.0,
        });
        println!(
            "  P_O = {po:<4}  eps = {:>10.5}  (valid: {})",
            b.epsilon, b.valid
        );
    }
}
