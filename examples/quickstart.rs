//! Quickstart: train the paper's MNIST CNN with CoGC over an unreliable
//! network and watch the PS recover exact global updates through the
//! gradient code.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What happens each round (paper §III):
//!  1. the PS broadcasts the global model;
//!  2. every client runs I local SGD steps (AOT-compiled JAX CNN via PJRT);
//!  3. clients exchange coded gradients with their s cyclic neighbors over
//!     Bernoulli-erasure links and form partial sums (Pallas coded_matmul);
//!  4. complete partial sums race up erasure-prone uplinks;
//!  5. if ≥ M−s arrive, the PS solves the combinator and recovers the
//!     *exact* mean update — otherwise the round is a binary failure.

use cogc::coordinator::{Aggregator, Design, TrainConfig, Trainer};
use cogc::network::Network;
use cogc::runtime::{default_artifacts_dir, Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let engine = Engine::cpu()?;
    let man = Manifest::load(&default_artifacts_dir())?;
    println!("platform: {} | artifacts for M={} clients", engine.platform(), man.m);

    // a mildly unreliable homogeneous network: 10% outage on every link
    let net = Network::homogeneous(man.m, 0.1, 0.1);

    let mut cfg = TrainConfig::new(
        "mnist_cnn",
        Aggregator::CoGc { design: Design::SkipRound, attempts: 1 },
    );
    cfg.rounds = 25;
    cfg.seed = 7;

    println!(
        "training {} for {} rounds: M={}, s={}, I={}, lr={}",
        cfg.model, cfg.rounds, man.m, cfg.s, cfg.local_iters, cfg.lr
    );
    let mut trainer = Trainer::new(&engine, &man, cfg, net)?;
    let log = trainer.run()?;

    println!("\nround  outcome    acc     train_loss  tx");
    for rec in &log.rounds {
        println!(
            "{:>5}  {:<9} {:.3}   {:>9.4}  {:>4}",
            rec.round, rec.outcome, rec.test_acc, rec.train_loss, rec.transmissions
        );
    }
    println!(
        "\nfinal accuracy {:.3} | {} exact recoveries / {} rounds | {} transmissions total",
        log.final_acc(),
        log.updates(),
        log.rounds.len(),
        log.total_transmissions()
    );
    Ok(())
}
