//! Quickstart: train the MNIST model with CoGC over an unreliable network
//! and watch the PS recover exact global updates through the gradient code.
//!
//!     cargo run --release --example quickstart
//!
//! Runs offline out of the box: the auto backend picks the AOT PJRT
//! artifacts when `make artifacts` has been run and falls back to the
//! native pure-rust models otherwise — same protocol, same figures.
//!
//! What happens each round (paper §III):
//!  1. the PS broadcasts the global model;
//!  2. every client runs I local SGD steps;
//!  3. clients exchange coded gradients with their s cyclic neighbors over
//!     Bernoulli-erasure links and form partial sums (eq. (8));
//!  4. complete partial sums race up erasure-prone uplinks;
//!  5. if ≥ M−s arrive, the PS solves the combinator and recovers the
//!     *exact* mean update — otherwise the round is a binary failure.

use cogc::coordinator::{Aggregator, Design, TrainConfig, Trainer};
use cogc::network::Network;
use cogc::runtime::Backend;

fn main() -> anyhow::Result<()> {
    let backend = Backend::auto();
    let m = backend.manifest().m;
    println!("backend: {} ({}) | M={} clients", backend.name(), backend.platform(), m);

    // a mildly unreliable homogeneous network: 10% outage on every link
    let net = Network::homogeneous(m, 0.1, 0.1);

    let mut cfg = TrainConfig::new(
        "mnist_cnn",
        Aggregator::CoGc { design: Design::SkipRound, attempts: 1 },
    );
    cfg.rounds = 25;
    cfg.seed = 7;

    println!(
        "training {} for {} rounds: M={}, s={}, I={}, lr={}",
        cfg.model, cfg.rounds, m, cfg.s, cfg.local_iters, cfg.lr
    );
    let mut trainer = Trainer::new(&backend, cfg, net)?;
    let log = trainer.run()?;

    println!("\nround  outcome    acc     train_loss  tx");
    for rec in &log.rounds {
        println!(
            "{:>5}  {:<9} {:.3}   {:>9.4}  {:>4}",
            rec.round, rec.outcome, rec.test_acc, rec.train_loss, rec.transmissions
        );
    }
    println!(
        "\nfinal accuracy {:.3} | {} exact recoveries / {} rounds | {} transmissions total",
        log.final_acc(),
        log.updates(),
        log.rounds.len(),
        log.total_transmissions()
    );
    Ok(())
}
