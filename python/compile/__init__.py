"""Build-time compile path: L1 Pallas kernels + L2 JAX models + AOT lowering.

Nothing in this package runs on the request path; ``make artifacts`` invokes
``compile.aot`` once and the rust coordinator consumes ``artifacts/`` from
then on.
"""
