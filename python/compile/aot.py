"""AOT pipeline: lower every L2 step to HLO *text* + write the manifest.

HLO text (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
and unwrapped with ``to_tuple1()``/``decompose()`` on the rust side.

Usage:  cd python && python -m compile.aot --out ../artifacts [--m 10 --tr 2]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .models import common as cm

F32, I32, U32 = jnp.float32, jnp.int32, jnp.uint32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_arity(text: str) -> int:
    """Count ENTRY-computation parameters (jax strips unused arguments when
    lowering, so the artifact arity can be smaller than the python
    signature; the rust runtime adapts via the manifest)."""
    entry = text[text.index("ENTRY") :]
    entry = entry[: entry.index("\n}")]
    return entry.count(" parameter(")


def lower_to_file(fn, args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


# Per-model data plumbing: (input specs, manifest metadata).
def model_io(name: str, batch: int):
    if name == "transformer":
        cfg = model_lib.transformer.CONFIG
        x = spec((batch, cfg.seq_len), I32)
        y = spec((batch, cfg.seq_len), I32)
        meta = {
            "kind": "lm",
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "d_model": cfg.d_model,
            "n_head": cfg.n_head,
            "n_layer": cfg.n_layer,
        }
    else:
        model = model_lib.MODELS[name]
        x = spec((batch,) + model.IMAGE_SHAPE, F32)
        y = spec((batch,), I32)
        meta = {
            "kind": "classifier",
            "image_shape": list(model.IMAGE_SHAPE),
            "num_classes": model.NUM_CLASSES,
        }
    return x, y, meta


DEFAULT_BATCH = {"mnist_cnn": 32, "cifar_cnn": 32, "transformer": 8}


def build(out_dir: str, m: int, tr: int, names, batches=None, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    batches = dict(DEFAULT_BATCH, **(batches or {}))
    mt = m * tr
    manifest = {"m": m, "tr": tr, "mt": mt, "models": {}}

    for name in names:
        model = model_lib.MODELS[name]
        d = model.D
        batch = batches[name]
        x, y, meta = model_io(name, batch)
        if name == "transformer":
            train_fn, eval_fn = model_lib.make_transformer_steps()
        else:
            train_fn, eval_fn = model_lib.make_classifier_steps(model)
        encode_fn, decode_fn = model_lib.make_coded_ops(m, mt, d)
        apply_fn = model_lib.make_sgd_apply()

        files = {}
        arities = {}

        def emit(tag, fn, args, files=files, arities=arities, name=name):
            path = f"{name}.{tag}.hlo.txt"
            full = os.path.join(out_dir, path)
            n = lower_to_file(fn, args, full)
            files[tag] = path
            arities[tag] = entry_arity(open(full).read())
            if verbose:
                print(f"  {path}: {n} chars, {arities[tag]} params")

        if verbose:
            print(f"[aot] {name}: D={d} batch={batch}")
        emit("train", train_fn, (spec((d,)), x, y, spec((), U32), spec((), F32))),
        emit("eval", eval_fn, (spec((d,)), x, y))
        emit("encode", encode_fn, (spec((m, m)), spec((m, d))))
        emit("decode", decode_fn, (spec((m, mt)), spec((mt, d))))
        emit("sgd", apply_fn, (spec((d,)), spec((d,)), spec((), F32)))

        manifest["models"][name] = {
            "d": d,
            "batch": batch,
            "x_shape": list(x.shape),
            "x_dtype": str(x.dtype),
            "y_shape": list(y.shape),
            "y_dtype": str(y.dtype),
            "meta": meta,
            "artifacts": files,
            "arities": arities,
            "params": [
                {
                    "name": t.name,
                    "shape": list(t.shape),
                    "init": t.init,
                    "fan_in": t.fan_in,
                }
                for t in model.SPECS
            ],
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] wrote {out_dir}/manifest.json")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--m", type=int, default=10, help="number of clients M")
    ap.add_argument("--tr", type=int, default=2, help="max GC+ repeats t_r")
    ap.add_argument(
        "--models", nargs="*", default=list(model_lib.MODELS), help="models to build"
    )
    args = ap.parse_args()
    build(args.out, args.m, args.tr, args.models)


if __name__ == "__main__":
    main()
