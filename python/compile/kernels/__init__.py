"""L1 Pallas kernels (build-time only; lowered into the L2 HLO modules)."""

from .coded_matmul import coded_matmul
from .sgd import sgd_apply

__all__ = ["coded_matmul", "sgd_apply"]
