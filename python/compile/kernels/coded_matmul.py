"""L1 Pallas kernel: coded gradient combine  O[R, D] = W[R, K] @ S[K, D].

This is the numeric hot-spot of gradient coding: every encode (partial sums
``s_m = sum_k b_mk * dg_k``), every standard-GC combinator application
(``a_f @ S``) and every GC+ decode transform is an instance of a short-K
matmul of a small coefficient panel against a stack of flat gradient
vectors.

TPU mapping (see DESIGN.md `Hardware-Adaptation`): the coefficient panel
W (R x K, at most ~20x20 floats) stays resident in VMEM for the whole
kernel; the gradient stack S is streamed HBM->VMEM one D-tile at a time
via the BlockSpec grid, and each output tile is written exactly once.
The kernel is bandwidth-bound (arithmetic intensity ~ 2K/(4*(1+R/K))
flop/byte), so the streaming schedule is the roofline-optimal shape.

Lowered with ``interpret=True``: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is what
the rust runtime loads.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default D-axis tile. VMEM budget at K<=24, R<=10 (f32):
#   W panel 24x10 (~1 KB, resident) + S tile 24x32768 (3.1 MB)
#   + O tile 10x32768 (1.3 MB)  =>  ~4.4 MB double-buffered < 16 MB VMEM.
# Large tiles matter twice over: on TPU they amortize the HBM->VMEM DMA per
# grid step; under interpret=True (the CPU artifact path) every grid step
# lowers to a serial HLO loop iteration with dynamic-slice overhead, so the
# step count directly sets the wallclock (measured 36ms -> ~1ms on the
# D=51480 encode when moving 512 -> 32768; see EXPERIMENTS.md §Perf).
DEFAULT_TILE_D = 65536


def _kernel(w_ref, s_ref, o_ref, *, acc_dtype):
    """One grid step: multiply the resident panel against one S tile."""
    w = w_ref[...]
    s = s_ref[...]
    acc = jnp.dot(
        w.astype(acc_dtype), s.astype(acc_dtype), preferred_element_type=acc_dtype
    )
    o_ref[...] = acc.astype(o_ref.dtype)


def coded_matmul(w, s, *, tile_d: int = DEFAULT_TILE_D, interpret: bool = True):
    """Compute ``w @ s`` with the Pallas coded-combine kernel.

    Args:
      w: ``[R, K]`` coefficient panel (perturbed GC coefficients ``b_mk`` /
         combinator rows ``a_f`` / GC+ decode transform rows).
      s: ``[K, D]`` stacked flat gradient vectors.
      tile_d: block length along the D axis; D is zero-padded up to a
         multiple of the tile so every grid step sees a full block.
      interpret: lower to plain HLO (required for CPU PJRT execution).

    Returns:
      ``[R, D]`` combined gradients, in ``s.dtype``.
    """
    if w.ndim != 2 or s.ndim != 2:
        raise ValueError(f"coded_matmul expects 2-D operands, got {w.shape}, {s.shape}")
    r, k = w.shape
    k2, d = s.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: W is {w.shape}, S is {s.shape}")

    td = min(tile_d, max(d, 1))
    d_pad = pl.cdiv(d, td) * td
    if d_pad != d:
        s = jnp.pad(s, ((0, 0), (0, d_pad - d)))
    grid = (d_pad // td,)

    out = pl.pallas_call(
        partial(_kernel, acc_dtype=jnp.float32),
        grid=grid,
        in_specs=[
            # Coefficient panel: resident, same block every grid step.
            pl.BlockSpec((r, k), lambda i: (0, 0)),
            # Gradient stack: stream one D tile per step.
            pl.BlockSpec((k, td), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((r, td), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, d_pad), s.dtype),
        interpret=interpret,
    )(w, s)
    return out[:, :d]
