"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: pytest (python/tests/test_kernels.py)
sweeps shapes and dtypes with hypothesis and asserts the Pallas kernels match
these references to tight tolerances.
"""

import jax.numpy as jnp


def coded_matmul_ref(w, s):
    """``[R,K] @ [K,D]`` with f32 accumulation, result in ``s.dtype``."""
    acc = jnp.dot(
        w.astype(jnp.float32), s.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return acc.astype(s.dtype)


def sgd_apply_ref(params, grad, lr):
    """``params - lr * grad``."""
    return params - jnp.asarray(lr, params.dtype) * grad
