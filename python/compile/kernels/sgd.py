"""L1 Pallas kernel: fused SGD apply  p' = p - lr * g over a flat D-vector.

Used inside every L2 train step (the local SGD iteration of eq. (2) in the
paper) and as a standalone artifact for the PS-side global update
``g_r <- g_{r-1} + dg_r`` (lr = -1).

The flat parameter vector is viewed as ``[1, D]`` and streamed through VMEM
one tile at a time; the learning rate rides along as a (1,1) block that maps
to the same element for every grid step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM at f32: 3 vectors x 262144 x 4 B = 3 MB per grid step — comfortably
# inside a TPU core's VMEM; and few serial loop iterations on the
# interpret=True CPU path (see coded_matmul.py for why step count matters).
DEFAULT_TILE_D = 262144


def _kernel(lr_ref, p_ref, g_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0, 0] * g_ref[...]


def sgd_apply(params, grad, lr, *, tile_d: int = DEFAULT_TILE_D, interpret: bool = True):
    """Return ``params - lr * grad`` (all ``f32[D]``, ``lr`` scalar)."""
    if params.shape != grad.shape or params.ndim != 1:
        raise ValueError(f"shape mismatch: {params.shape} vs {grad.shape}")
    (d,) = params.shape
    td = min(tile_d, max(d, 1))
    d_pad = pl.cdiv(d, td) * td
    p = params.reshape(1, d)
    g = grad.reshape(1, d)
    if d_pad != d:
        p = jnp.pad(p, ((0, 0), (0, d_pad - d)))
        g = jnp.pad(g, ((0, 0), (0, d_pad - d)))
    lr2 = jnp.asarray(lr, params.dtype).reshape(1, 1)

    out = pl.pallas_call(
        _kernel,
        grid=(d_pad // td,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, td), lambda i: (0, i)),
            pl.BlockSpec((1, td), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, td), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_pad), params.dtype),
        interpret=interpret,
    )(lr2, p, g)
    return out[0, :d]
