"""L2 step factories: the jittable functions that become AOT artifacts.

Each model contributes five artifacts (all flat-parameter, fixed shapes):

  train_step(flat[D], x, y, seed u32[], lr f32[]) -> (flat'[D], loss[])
      one local SGD iteration of paper eq. (2); the parameter update is the
      L1 fused ``sgd_apply`` Pallas kernel.
  eval_step(flat[D], x, y) -> (loss[], correct[])
  coded_encode(W[M,M],  S[M,D])  -> [M,D]    gradient-sharing partial sums,
      paper eq. (8): rows of W are the erasure-masked b_m; S stacks dg_k.
  coded_decode(W[M,MT], S[MT,D]) -> [M,D]    standard-GC combinator rows /
      GC+ decode transform (MT = M * t_r stacked rows, zero-padded).
  sgd_apply(p[D], g[D], lr[]) -> [D]         PS-side global update.

Both coded ops are the L1 ``coded_matmul`` Pallas kernel, so the entire
runtime compute surface is covered by kernel + model HLO modules.
"""

import jax
import jax.numpy as jnp

from .kernels import coded_matmul, sgd_apply
from .models import cifar_cnn, mnist_cnn, transformer
from .models import common as cm


def make_classifier_steps(model):
    """(train_step, eval_step) for an image-classification model module."""

    def loss_fn(flat, x, y, key):
        logits = model.apply(flat, x, key=key, train=True)
        return cm.nll_loss(logits, y)

    def train_step(flat, x, y, seed, lr):
        key = jax.random.PRNGKey(seed)
        loss, grad = jax.value_and_grad(loss_fn)(flat, x, y, key)
        new_flat = sgd_apply(flat, grad, lr)
        return new_flat, loss

    def eval_step(flat, x, y):
        logits = model.apply(flat, x, train=False)
        loss = cm.nll_loss(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, correct

    return train_step, eval_step


def make_transformer_steps(cfg=transformer.CONFIG):
    """(train_step, eval_step) for the decoder-only LM."""

    def loss_fn(flat, tokens, targets):
        return transformer.next_token_loss(flat, tokens, targets, cfg)

    def train_step(flat, tokens, targets, seed, lr):
        del seed  # no dropout in the LM; kept for a uniform artifact signature
        loss, grad = jax.value_and_grad(loss_fn)(flat, tokens, targets)
        new_flat = sgd_apply(flat, grad, lr)
        return new_flat, loss

    def eval_step(flat, tokens, targets):
        logits = transformer.apply(flat, tokens, train=False, cfg=cfg)
        logp = jax.nn.log_softmax(logits)
        picked = jnp.take_along_axis(logp, targets[:, :, None], axis=2)[:, :, 0]
        loss = -jnp.mean(picked)
        correct = jnp.sum((jnp.argmax(logits, axis=2) == targets).astype(jnp.float32))
        return loss, correct

    return train_step, eval_step


def make_coded_ops(m: int, mt: int, d: int):
    """(encode, decode) coded-combine graph functions for a model of size d."""

    def coded_encode(w, s):
        return coded_matmul(w, s)

    def coded_decode(w, s):
        return coded_matmul(w, s)

    return coded_encode, coded_decode


def make_sgd_apply():
    def apply_fn(p, g, lr):
        return sgd_apply(p, g, lr)

    return apply_fn


MODELS = {
    "mnist_cnn": mnist_cnn,
    "cifar_cnn": cifar_cnn,
    "transformer": transformer,
}
