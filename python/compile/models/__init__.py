"""L2 model zoo: the paper's Table-II CNNs plus the e2e transformer."""

from . import cifar_cnn, mnist_cnn, transformer

__all__ = ["mnist_cnn", "cifar_cnn", "transformer"]
