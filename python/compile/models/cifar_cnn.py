"""Paper Table II CIFAR-10 model:
C(3,32) - R - M - C(32,32) - R - M - L(256) - R - L(64) - R - L(10).

3x3 convs, stride 1, padding 1; 2x2 max-pool (32 -> 16 -> 8); NLL loss.
"""

import jax

from . import common as cm

NAME = "cifar_cnn"
IMAGE_SHAPE = (3, 32, 32)
NUM_CLASSES = 10

SPECS = (
    cm.conv_spec("conv1", 3, 32)
    + cm.conv_spec("conv2", 32, 32)
    + cm.linear_spec("fc1", 32 * 8 * 8, 256)
    + cm.linear_spec("fc2", 256, 64)
    + cm.linear_spec("fc3", 64, NUM_CLASSES)
)

D = cm.total_size(SPECS)


def apply(flat, x, *, key=None, train: bool):
    """Forward pass. ``x``: f32[B,3,32,32] -> logits f32[B,10]."""
    p = cm.unpack(flat, SPECS)
    h = jax.nn.relu(cm.conv2d(x, p["conv1.w"], p["conv1.b"]))
    h = cm.maxpool2(h)
    h = jax.nn.relu(cm.conv2d(h, p["conv2.w"], p["conv2.b"]))
    h = cm.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1.w"] + p["fc1.b"])
    h = jax.nn.relu(h @ p["fc2.w"] + p["fc2.b"])
    return h @ p["fc3.w"] + p["fc3.b"]
