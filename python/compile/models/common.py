"""Flat-parameter model plumbing shared by all L2 models.

Every model is described by a list of :class:`TensorSpec`; its parameters
live in a single ``f32[D]`` vector (the paper's model-as-a-vector
abstraction, g in R^D). ``pack``/``unpack`` convert between the flat vector
and the per-tensor pytree; ``init_flat`` draws a fresh initialization.

The same spec (name, shape, init scheme, fan_in) is exported into
``artifacts/manifest.json`` so the rust coordinator can initialize parameter
vectors without any python on the runtime path.
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: Tuple[int, ...]
    # "uniform_fanin": U(-1/sqrt(fan_in), 1/sqrt(fan_in))  (torch Linear/Conv default)
    # "zeros", "ones", "normal:<std>"
    init: str
    fan_in: int = 0

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def total_size(specs: List[TensorSpec]) -> int:
    return sum(t.size for t in specs)


def unpack(flat, specs: List[TensorSpec]):
    """Split ``f32[D]`` into the per-tensor dict (zero-copy reshapes)."""
    out = {}
    off = 0
    for t in specs:
        out[t.name] = flat[off : off + t.size].reshape(t.shape)
        off += t.size
    return out


def pack(tree: dict, specs: List[TensorSpec]):
    """Concatenate per-tensor values back into the flat ``f32[D]`` vector."""
    return jnp.concatenate([tree[t.name].reshape(-1) for t in specs])


def init_flat(key, specs: List[TensorSpec]):
    """Draw a fresh flat parameter vector (python-side, used in tests)."""
    chunks = []
    for t in specs:
        key, sub = jax.random.split(key)
        if t.init == "zeros":
            chunks.append(jnp.zeros((t.size,), jnp.float32))
        elif t.init == "ones":
            chunks.append(jnp.ones((t.size,), jnp.float32))
        elif t.init == "uniform_fanin":
            bound = 1.0 / np.sqrt(max(t.fan_in, 1))
            chunks.append(
                jax.random.uniform(sub, (t.size,), jnp.float32, -bound, bound)
            )
        elif t.init.startswith("normal:"):
            std = float(t.init.split(":", 1)[1])
            chunks.append(std * jax.random.normal(sub, (t.size,), jnp.float32))
        else:
            raise ValueError(f"unknown init scheme {t.init!r} for {t.name}")
    return jnp.concatenate(chunks)


def conv_spec(name: str, cin: int, cout: int, k: int = 3):
    """Conv2d weight+bias specs with torch-default fan-in init."""
    fan = cin * k * k
    return [
        TensorSpec(f"{name}.w", (cout, cin, k, k), "uniform_fanin", fan),
        TensorSpec(f"{name}.b", (cout,), "uniform_fanin", fan),
    ]


def linear_spec(name: str, nin: int, nout: int):
    """Linear weight+bias specs with torch-default fan-in init."""
    return [
        TensorSpec(f"{name}.w", (nin, nout), "uniform_fanin", nin),
        TensorSpec(f"{name}.b", (nout,), "uniform_fanin", nin),
    ]


# -- layer helpers (NCHW, OIHW) ------------------------------------------------

def conv2d(x, w, b, *, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    return y + b[None, :, None, None]


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def dropout(x, key, rate: float):
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


def nll_loss(logits, labels):
    """Negative log-likelihood (paper Table II) over int labels."""
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return -jnp.mean(picked)
