"""Paper Table II MNIST model: C(1,10) - C(10,20) - D - L(50) - L(10).

Conv layers are 3x3, stride 1, padding 1, each followed by ReLU and 2x2
max-pool (28 -> 14 -> 7); dropout p=0.2 before the classifier head; NLL loss.
"""

import jax

from . import common as cm

NAME = "mnist_cnn"
IMAGE_SHAPE = (1, 28, 28)
NUM_CLASSES = 10
DROPOUT = 0.2

SPECS = (
    cm.conv_spec("conv1", 1, 10)
    + cm.conv_spec("conv2", 10, 20)
    + cm.linear_spec("fc1", 20 * 7 * 7, 50)
    + cm.linear_spec("fc2", 50, NUM_CLASSES)
)

D = cm.total_size(SPECS)


def apply(flat, x, *, key=None, train: bool):
    """Forward pass. ``x``: f32[B,1,28,28] -> logits f32[B,10]."""
    p = cm.unpack(flat, SPECS)
    h = jax.nn.relu(cm.conv2d(x, p["conv1.w"], p["conv1.b"]))
    h = cm.maxpool2(h)
    h = jax.nn.relu(cm.conv2d(h, p["conv2.w"], p["conv2.b"]))
    h = cm.maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    if train:
        h = cm.dropout(h, key, DROPOUT)
    h = jax.nn.relu(h @ p["fc1.w"] + p["fc1.b"])
    return h @ p["fc2.w"] + p["fc2.b"]
