"""Decoder-only transformer LM for the end-to-end driver (examples/e2e_transformer).

Pre-norm GPT-style blocks: LN -> causal MHA -> residual, LN -> MLP(4x, GELU)
-> residual; learned positional embeddings; untied LM head; next-token
cross-entropy loss.

The size is set by CONFIG; the default ("base") is a ~0.9M-parameter model
sized so the full CoGC stack (M clients x I local steps x hundreds of
rounds) runs in CPU-PJRT minutes. Scale knobs are d_model/n_layer/vocab —
the architecture is the standard one and scales to 100M+ unchanged.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import common as cm
from .common import TensorSpec

NAME = "transformer"


@dataclass(frozen=True)
class Config:
    vocab: int = 256
    seq_len: int = 32
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 4

    @property
    def d_head(self):
        return self.d_model // self.n_head


CONFIG = Config()


def build_specs(cfg: Config = CONFIG):
    d = cfg.d_model
    specs = [
        TensorSpec("tok_emb", (cfg.vocab, d), "normal:0.02"),
        TensorSpec("pos_emb", (cfg.seq_len, d), "normal:0.02"),
    ]
    for i in range(cfg.n_layer):
        pre = f"layer{i}."
        specs += [
            TensorSpec(pre + "ln1.g", (d,), "ones"),
            TensorSpec(pre + "ln1.b", (d,), "zeros"),
            TensorSpec(pre + "attn.wqkv", (d, 3 * d), "uniform_fanin", d),
            TensorSpec(pre + "attn.bqkv", (3 * d,), "zeros"),
            TensorSpec(pre + "attn.wo", (d, d), "uniform_fanin", d),
            TensorSpec(pre + "attn.bo", (d,), "zeros"),
            TensorSpec(pre + "ln2.g", (d,), "ones"),
            TensorSpec(pre + "ln2.b", (d,), "zeros"),
            TensorSpec(pre + "mlp.w1", (d, 4 * d), "uniform_fanin", d),
            TensorSpec(pre + "mlp.b1", (4 * d,), "zeros"),
            TensorSpec(pre + "mlp.w2", (4 * d, d), "uniform_fanin", 4 * d),
            TensorSpec(pre + "mlp.b2", (d,), "zeros"),
        ]
    specs += [
        TensorSpec("lnf.g", (d,), "ones"),
        TensorSpec("lnf.b", (d,), "zeros"),
        TensorSpec("head.w", (d, cfg.vocab), "uniform_fanin", d),
        TensorSpec("head.b", (cfg.vocab,), "zeros"),
    ]
    return specs


SPECS = build_specs()
D = cm.total_size(SPECS)


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, p, pre, cfg: Config):
    bsz, t, d = x.shape
    qkv = x @ p[pre + "attn.wqkv"] + p[pre + "attn.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(bsz, t, cfg.n_head, cfg.d_head).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(cfg.d_head)
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(bsz, t, d)
    return y @ p[pre + "attn.wo"] + p[pre + "attn.bo"]


def apply(flat, tokens, *, key=None, train: bool = True, cfg: Config = CONFIG):
    """``tokens``: i32[B, T] -> logits f32[B, T, vocab]."""
    p = cm.unpack(flat, build_specs(cfg))
    t = tokens.shape[1]
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t]
    for i in range(cfg.n_layer):
        pre = f"layer{i}."
        x = x + _attention(_layernorm(x, p[pre + "ln1.g"], p[pre + "ln1.b"]), p, pre, cfg)
        h = _layernorm(x, p[pre + "ln2.g"], p[pre + "ln2.b"])
        h = jax.nn.gelu(h @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x + h @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
    x = _layernorm(x, p["lnf.g"], p["lnf.b"])
    return x @ p["head.w"] + p["head.b"]


def next_token_loss(flat, tokens, targets, cfg: Config = CONFIG):
    """Mean cross-entropy of predicting ``targets`` from ``tokens``."""
    logits = apply(flat, tokens, train=True, cfg=cfg)
    logp = jax.nn.log_softmax(logits)
    picked = jnp.take_along_axis(logp, targets[:, :, None], axis=2)[:, :, 0]
    return -jnp.mean(picked)
