"""AOT pipeline: HLO text artifacts + manifest are well-formed."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    # Lower only the (cheap) mnist model at a tiny batch; the full build is
    # exercised by `make artifacts`.
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(
        out, m=4, tr=2, names=["mnist_cnn"], batches={"mnist_cnn": 2}, verbose=False
    )
    return out, manifest


def test_manifest_contents(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == json.loads(json.dumps(manifest))
    assert on_disk["m"] == 4 and on_disk["tr"] == 2 and on_disk["mt"] == 8
    mm = on_disk["models"]["mnist_cnn"]
    assert mm["d"] == 51480
    assert mm["x_shape"] == [2, 1, 28, 28]
    assert sorted(mm["artifacts"]) == ["decode", "encode", "eval", "sgd", "train"]
    assert sum(
        int(__import__("numpy").prod(p["shape"])) for p in mm["params"]
    ) == mm["d"]


def test_hlo_text_artifacts(built):
    out, manifest = built
    for tag, path in manifest["models"]["mnist_cnn"]["artifacts"].items():
        full = os.path.join(out, path)
        assert os.path.exists(full), full
        text = open(full).read()
        assert text.startswith("HloModule"), f"{path} is not HLO text"
        assert "ENTRY" in text


def test_train_artifact_has_expected_arity(built):
    """flat, x, y, seed, lr = 5 parameters (unused args must not be stripped)."""
    out, manifest = built
    text = open(os.path.join(out, manifest["models"]["mnist_cnn"]["artifacts"]["train"])).read()
    entry = text[text.index("ENTRY") :]
    entry = entry[: entry.index("\n}")]
    n_params = entry.count(" parameter(")
    assert n_params == 5, f"expected 5 entry parameters, found {n_params}"


def test_encode_decode_shapes(built):
    out, manifest = built
    enc = open(os.path.join(out, manifest["models"]["mnist_cnn"]["artifacts"]["encode"])).read()
    dec = open(os.path.join(out, manifest["models"]["mnist_cnn"]["artifacts"]["decode"])).read()
    assert "f32[4,51480]" in enc  # [M, D]
    assert "f32[8,51480]" in dec  # [MT, D]
