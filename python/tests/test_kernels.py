"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis shape sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import coded_matmul, sgd_apply
from compile.kernels.ref import coded_matmul_ref, sgd_apply_ref

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@settings(max_examples=40, deadline=None)
@given(
    r=st.integers(1, 12),
    k=st.integers(1, 24),
    d=st.integers(1, 700),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_coded_matmul_matches_ref(r, k, d, dtype, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = rand(k1, (r, k), dtype)
    s = rand(k2, (k, d), dtype)
    got = coded_matmul(w, s)
    want = coded_matmul_ref(w, s)
    assert got.shape == (r, d)
    assert got.dtype == s.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(1, 5000),
    lr=st.floats(-2.0, 2.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_apply_matches_ref(d, lr, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    p = jax.random.normal(k1, (d,), jnp.float32)
    g = jax.random.normal(k2, (d,), jnp.float32)
    got = sgd_apply(p, g, lr)
    want = sgd_apply_ref(p, g, lr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


# -- deterministic edge cases -------------------------------------------------

def test_coded_matmul_tile_boundaries():
    """D exactly at/around the tile boundary must not corrupt the tail."""
    for d in (511, 512, 513, 1024, 1025):
        w = jnp.ones((3, 4), jnp.float32)
        s = jnp.arange(4 * d, dtype=jnp.float32).reshape(4, d)
        got = coded_matmul(w, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(coded_matmul_ref(w, s)))


def test_coded_matmul_zero_coefficients():
    """Erasure-masked rows (all-zero W rows) must produce exactly zero."""
    w = jnp.zeros((5, 8), jnp.float32).at[2, 3].set(2.5)
    s = jax.random.normal(jax.random.PRNGKey(0), (8, 300), jnp.float32)
    got = np.asarray(coded_matmul(w, s))
    assert np.all(got[[0, 1, 3, 4]] == 0.0)
    np.testing.assert_allclose(got[2], 2.5 * np.asarray(s)[3], rtol=1e-6)


def test_coded_matmul_identity_roundtrip():
    """W = I recovers the stacked gradients bit-exactly (f32 path)."""
    s = jax.random.normal(jax.random.PRNGKey(1), (10, 1000), jnp.float32)
    got = coded_matmul(jnp.eye(10, dtype=jnp.float32), s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(s))


def test_coded_matmul_custom_tile():
    w = jnp.ones((2, 2), jnp.float32)
    s = jnp.ones((2, 77), jnp.float32)
    got = coded_matmul(w, s, tile_d=16)
    np.testing.assert_allclose(np.asarray(got), 2.0 * np.ones((2, 77)))


def test_coded_matmul_shape_errors():
    with pytest.raises(ValueError):
        coded_matmul(jnp.ones((2, 3)), jnp.ones((4, 5)))
    with pytest.raises(ValueError):
        coded_matmul(jnp.ones((2,)), jnp.ones((2, 5)))


def test_sgd_apply_zero_lr_is_identity():
    p = jax.random.normal(jax.random.PRNGKey(2), (777,), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(3), (777,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(sgd_apply(p, g, 0.0)), np.asarray(p))


def test_sgd_apply_negative_lr_adds():
    """lr = -1 is the PS-side global *additive* update g <- g + dg."""
    p = jnp.ones((100,), jnp.float32)
    g = 2.0 * jnp.ones((100,), jnp.float32)
    np.testing.assert_allclose(np.asarray(sgd_apply(p, g, -1.0)), 3.0 * np.ones(100))
