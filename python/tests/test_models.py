"""L2 model correctness: pack/unpack, shapes, and learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.models import cifar_cnn, mnist_cnn, transformer
from compile.models import common as cm


@pytest.mark.parametrize("model", [mnist_cnn, cifar_cnn, transformer])
def test_pack_unpack_roundtrip(model):
    flat = cm.init_flat(jax.random.PRNGKey(0), model.SPECS)
    assert flat.shape == (model.D,)
    tree = cm.unpack(flat, model.SPECS)
    again = cm.pack(tree, model.SPECS)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))


def test_param_counts():
    # Hand-computed from the Table-II architectures.
    assert mnist_cnn.D == (10 * 9 + 10) + (20 * 10 * 9 + 20) + (980 * 50 + 50) + (50 * 10 + 10)
    assert cifar_cnn.D == (32 * 27 + 32) + (32 * 32 * 9 + 32) + (2048 * 256 + 256) + (
        256 * 64 + 64
    ) + (64 * 10 + 10)
    assert transformer.D == cm.total_size(transformer.build_specs())


@pytest.mark.parametrize("model", [mnist_cnn, cifar_cnn])
def test_classifier_shapes(model):
    flat = cm.init_flat(jax.random.PRNGKey(0), model.SPECS)
    x = jax.random.normal(jax.random.PRNGKey(1), (4,) + model.IMAGE_SHAPE)
    logits = model.apply(flat, x, train=False)
    assert logits.shape == (4, model.NUM_CLASSES)
    logits_t = model.apply(flat, x, key=jax.random.PRNGKey(2), train=True)
    assert logits_t.shape == (4, model.NUM_CLASSES)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_transformer_shapes():
    cfg = transformer.CONFIG
    flat = cm.init_flat(jax.random.PRNGKey(0), transformer.SPECS)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq_len), 0, cfg.vocab)
    logits = transformer.apply(flat, toks)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_transformer_causality():
    """Changing a future token must not change past logits."""
    cfg = transformer.CONFIG
    flat = cm.init_flat(jax.random.PRNGKey(0), transformer.SPECS)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.seq_len), 0, cfg.vocab)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab)
    l1 = np.asarray(transformer.apply(flat, toks))
    l2 = np.asarray(transformer.apply(flat, toks2))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


@pytest.mark.parametrize("name", ["mnist_cnn", "cifar_cnn"])
def test_classifier_train_step_learns(name):
    model = model_lib.MODELS[name]
    train_step, eval_step = model_lib.make_classifier_steps(model)
    train_step = jax.jit(train_step)
    flat = cm.init_flat(jax.random.PRNGKey(0), model.SPECS)
    # Easy separable batch: class = sign pattern of channel means.
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (16,) + model.IMAGE_SHAPE)
    y = jnp.arange(16, dtype=jnp.int32) % model.NUM_CLASSES
    x = x + 3.0 * y[:, None, None, None].astype(jnp.float32) / model.NUM_CLASSES
    first = None
    for i in range(40):
        flat, loss = train_step(flat, x, y, jnp.uint32(i), jnp.float32(0.05))
        if first is None:
            first = float(loss)
    assert float(loss) < 0.7 * first, f"loss {first} -> {float(loss)}"
    ev_loss, correct = jax.jit(eval_step)(flat, x, y)
    assert 0 <= float(correct) <= 16
    assert np.isfinite(float(ev_loss))


def test_transformer_train_step_learns():
    train_step, eval_step = model_lib.make_transformer_steps()
    train_step = jax.jit(train_step)
    cfg = transformer.CONFIG
    flat = cm.init_flat(jax.random.PRNGKey(0), transformer.SPECS)
    toks = jnp.tile(jnp.arange(cfg.seq_len, dtype=jnp.int32) % 17, (4, 1))
    targets = (toks + 1) % 17
    first = None
    for i in range(30):
        flat, loss = train_step(flat, toks, targets, jnp.uint32(i), jnp.float32(0.05))
        if first is None:
            first = float(loss)
    assert float(loss) < 0.5 * first, f"loss {first} -> {float(loss)}"
    ev_loss, correct = jax.jit(eval_step)(flat, toks, targets)
    assert 0 <= float(correct) <= 4 * cfg.seq_len


def test_train_step_uses_pallas_sgd():
    """The train step's update must equal p - lr*grad exactly (fused kernel)."""
    model = mnist_cnn
    train_step, _ = model_lib.make_classifier_steps(model)
    flat = cm.init_flat(jax.random.PRNGKey(0), model.SPECS)
    x = jax.random.normal(jax.random.PRNGKey(1), (4,) + model.IMAGE_SHAPE)
    y = jnp.array([0, 1, 2, 3], jnp.int32)

    def loss_fn(f):
        logits = model.apply(f, x, key=jax.random.PRNGKey(7), train=True)
        return cm.nll_loss(logits, y)

    new_flat, _ = train_step(flat, x, y, jnp.uint32(0), jnp.float32(0.1))
    # independent grad at the same dropout key (seed 0 -> PRNGKey(0))
    grad = jax.grad(
        lambda f: cm.nll_loss(
            model.apply(f, x, key=jax.random.PRNGKey(0), train=True), y
        )
    )(flat)
    np.testing.assert_allclose(
        np.asarray(new_flat), np.asarray(flat - 0.1 * grad), rtol=1e-5, atol=1e-6
    )


def test_dropout_seed_changes_loss():
    model = mnist_cnn
    train_step, _ = model_lib.make_classifier_steps(model)
    flat = cm.init_flat(jax.random.PRNGKey(0), model.SPECS)
    x = jax.random.normal(jax.random.PRNGKey(1), (8,) + model.IMAGE_SHAPE)
    y = jnp.zeros((8,), jnp.int32)
    _, l0 = train_step(flat, x, y, jnp.uint32(0), jnp.float32(0.0))
    _, l1 = train_step(flat, x, y, jnp.uint32(12345), jnp.float32(0.0))
    assert float(l0) != float(l1)


def test_init_schemes():
    specs = [
        cm.TensorSpec("z", (3, 3), "zeros"),
        cm.TensorSpec("o", (2,), "ones"),
        cm.TensorSpec("n", (4000,), "normal:0.02"),
        cm.TensorSpec("u", (4000,), "uniform_fanin", 100),
    ]
    flat = cm.init_flat(jax.random.PRNGKey(0), specs)
    t = cm.unpack(flat, specs)
    assert np.all(np.asarray(t["z"]) == 0)
    assert np.all(np.asarray(t["o"]) == 1)
    assert abs(float(jnp.std(t["n"])) - 0.02) < 0.002
    assert float(jnp.max(jnp.abs(t["u"]))) <= 0.1 + 1e-6
