//! Ablation benches for the design choices called out in DESIGN.md:
//!
//!  A1  exact vs approximate (paper Algorithm 2) GC⁺ detection — recovery
//!      rates and cost, fanned over the parallel Monte-Carlo engine;
//!  A2  t_r sweep — how stacking depth buys reliability (Lemma 3 in action);
//!  A3  s sweep on a fixed network — the non-monotone P_O(s) the §V design
//!      problem optimizes over;
//!  A4  Pallas vs native combine, end-to-end training round (pallas rows
//!      need `make artifacts` + real PJRT; native always runs);
//!  A5  Design 1 vs Design 2 — update guarantee vs attempt cost.

use cogc::bench::Suite;
use cogc::coordinator::{Aggregator, Design, TrainConfig, Trainer};
use cogc::gc::{self, GcCode};
use cogc::metrics::Table;
use cogc::network::{Network, Realization};
use cogc::outage::mc::{gcplus_recovery, RecoveryMode};
use cogc::outage::{self};
use cogc::parallel::{derive_seed, MonteCarlo};
use cogc::runtime::{Backend, CombineImpl};
use cogc::scenario::Iid;
use cogc::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(17);

    // ── A1: exact vs approximate detection ──────────────────────────────
    // Each setting sweeps through the deterministic parallel engine with a
    // derived per-setting seed: bit-identical rates at any worker count.
    let mut t = Table::new(
        "A1: GC+ exact vs Algorithm-2 approximate detection (M=10 s=7 t_r=2, 600 rounds/setting, \
         parallel MC engine)",
        &["setting", "exact_decode_rate", "approx_decode_rate", "exact_mean_k4", "approx_mean_k4"],
    );
    for setting in 1..=4usize {
        let net = Network::fig6_setting(setting, 10);
        let rounds = 600;
        // ((exact decodes, exact Σ|K4|), (approx decodes, approx Σ|K4|))
        type A1Acc = ((usize, usize), (usize, usize));
        let mc = MonteCarlo::new(derive_seed(17, 100 + setting as u64));
        let acc: A1Acc = mc.run(rounds, |_t, rng, acc: &mut A1Acc| {
            let attempts: Vec<gc::Attempt> = (0..2)
                .map(|_| {
                    let code = GcCode::generate(10, 7, rng);
                    gc::Attempt::observe(&code, &Realization::sample(&net, rng))
                })
                .collect();
            let stacked = gc::stack_attempts(&attempts);
            if stacked.rows == 0 {
                return;
            }
            let ex = gc::decode(&stacked);
            let ap = gc::decode_approx(&stacked);
            if !ex.k4.is_empty() {
                (acc.0).0 += 1;
                (acc.0).1 += ex.k4.len();
            }
            if !ap.k4.is_empty() {
                (acc.1).0 += 1;
                (acc.1).1 += ap.k4.len();
            }
        });
        let ((ex_dec, ex_k4), (ap_dec, ap_k4)) = acc;
        t.row(&[
            setting.to_string(),
            format!("{:.4}", ex_dec as f64 / rounds as f64),
            format!("{:.4}", ap_dec as f64 / rounds as f64),
            format!("{:.2}", ex_k4 as f64 / ex_dec.max(1) as f64),
            format!("{:.2}", ap_k4 as f64 / ap_dec.max(1) as f64),
        ]);
    }
    t.print();

    // ── A2: t_r sweep ────────────────────────────────────────────────────
    let mut t = Table::new(
        "A2: stacking depth t_r vs GC+ outcomes (setting 2: p_m=0.4, p_mk=0.5)",
        &["t_r", "p_full", "p_partial", "p_none"],
    );
    let net = Network::fig6_setting(2, 10);
    for tr in 1..=4usize {
        let mc = MonteCarlo::new(derive_seed(17, tr as u64));
        let st = gcplus_recovery(&net, &Iid, 10, 7, RecoveryMode::FixedTr(tr), 500, &mc);
        t.rowf(&[tr as f64, st.p_full(), st.p_partial(), st.p_none()]);
    }
    t.print();

    // ── A3: s sweep (non-monotone P_O) ──────────────────────────────────
    let mut t = Table::new(
        "A3: P_O(s) non-monotonicity across networks (closed form)",
        &["s", "po_p0.1", "po_p0.3", "po_p0.5"],
    );
    for s in 1..10usize {
        let code = GcCode::generate(10, s, &mut rng);
        let row: Vec<f64> = std::iter::once(s as f64)
            .chain([0.1, 0.3, 0.5].iter().map(|&p| {
                outage::overall_outage(&Network::homogeneous(10, p, p), &code)
            }))
            .collect();
        t.rowf(&row);
    }
    t.print();

    // ── A4 + A5: end-to-end round ablations ─────────────────────────────
    // The auto backend keeps these running on a clean checkout (native
    // models); with `make artifacts` + real PJRT the A4 comparison gains
    // its pallas row.
    let backend = Backend::auto();
    let net = Network::homogeneous(backend.manifest().m, 0.3, 0.3);
    let mut suite = Suite::new("ablations: end-to-end round");
    let combines: &[(&str, CombineImpl)] = if backend.name() == "pjrt" {
        &[("pallas", CombineImpl::Pallas), ("native", CombineImpl::Native)]
    } else {
        // the Pallas kernels are PJRT artifacts; only the native combine exists
        &[("native", CombineImpl::Native)]
    };
    for &(label, imp) in combines {
        let mut cfg = TrainConfig::new(
            "mnist_cnn",
            Aggregator::GcPlus { tr: 2, until_decode: false, max_blocks: 1 },
        );
        cfg.rounds = 2;
        cfg.per_client = 40;
        cfg.eval_batches = 1;
        cfg.combine = imp;
        let t0 = std::time::Instant::now();
        let mut trainer = Trainer::new(&backend, cfg, net.clone()).unwrap();
        let log = trainer.run().unwrap();
        println!(
            "A4 combine={label} [{} backend]: 2 rounds in {:.2}s (outcomes: {:?})",
            backend.name(),
            t0.elapsed().as_secs_f64(),
            log.rounds.iter().map(|r| r.outcome.clone()).collect::<Vec<_>>()
        );
    }
    let designs =
        [("design1_retry", Design::RetryUntilSuccess), ("design2_skip", Design::SkipRound)];
    for (label, design) in designs {
        let attempts = if design == Design::RetryUntilSuccess { 50 } else { 1 };
        let mut cfg = TrainConfig::new("mnist_cnn", Aggregator::CoGc { design, attempts });
        cfg.rounds = 4;
        cfg.per_client = 40;
        cfg.eval_batches = 1;
        let net_harsh = Network::homogeneous(backend.manifest().m, 0.5, 0.1);
        let mut trainer = Trainer::new(&backend, cfg, net_harsh).unwrap();
        let log = trainer.run().unwrap();
        println!(
            "A5 {label}: {} updates / 4 rounds, {} attempts, {} transmissions",
            log.updates(),
            log.rounds.iter().map(|r| r.attempts).sum::<usize>(),
            log.total_transmissions()
        );
    }
    suite.finish();
}
