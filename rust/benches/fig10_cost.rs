//! Bench + regeneration harness for Fig. 10 (communication cost of the
//! cost-efficient GC design vs regular GC). Reduced target/rounds by
//! default; full run: `cogc fig10 --rounds 100 --target 0.85`.

use cogc::figures;

fn main() {
    let rounds: usize = std::env::var("COGC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);
    let target: f64 = std::env::var("COGC_BENCH_TARGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.35);
    let t0 = std::time::Instant::now();
    let table = figures::fig10(rounds, target, 42).expect("fig10");
    table.print();
    println!(
        "\n== bench fig10_cost: target acc {target}, cap {rounds} rounds, {:.1}s ==",
        t0.elapsed().as_secs_f64()
    );
}
