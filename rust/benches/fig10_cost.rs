//! Bench + regeneration harness for Fig. 10 (communication cost of the
//! cost-efficient GC design vs regular GC). Reduced target/rounds by
//! default; full run: `cogc fig10 --rounds 100 --target 0.85`. Runs on
//! whichever backend is available (native on a clean checkout).

use cogc::figures;
use cogc::runtime::Backend;

fn main() {
    let rounds: usize = std::env::var("COGC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);
    let target: f64 = std::env::var("COGC_BENCH_TARGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.35);
    let backend = Backend::auto();
    let t0 = std::time::Instant::now();
    let table = figures::fig10(&backend, rounds, target, 42, 0).expect("fig10");
    table.print();
    println!(
        "\n== bench fig10_cost [{} backend]: target acc {target}, cap {rounds} rounds, {:.1}s ==",
        backend.name(),
        t0.elapsed().as_secs_f64()
    );
}
