//! Bench + regeneration harness for Fig. 11 (MNIST: ideal / GC / GC⁺ /
//! intermittent under poor uplinks, per client-to-client tier). Reduced
//! rounds by default; full run: `cogc fig11 --conn poor --rounds 100`.
//! Runs on whichever backend is available (native on a clean checkout).

use cogc::figures;
use cogc::runtime::Backend;

fn main() {
    let rounds: usize = std::env::var("COGC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let backend = Backend::auto();
    let t0 = std::time::Instant::now();
    let table = figures::fig11_12(&backend, "mnist_cnn", "poor", rounds, 42, 0).expect("fig11");
    table.print();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n== bench fig11_gcplus [{} backend]: {rounds} rounds x 4 methods in {wall:.1}s ==",
        backend.name(),
    );
}
