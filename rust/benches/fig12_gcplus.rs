//! Bench + regeneration harness for Fig. 12 (CIFAR version of Fig. 11).
//! Reduced rounds by default; full: `cogc fig12 --conn moderate --rounds 100`.
//! Runs on whichever backend is available (native on a clean checkout).

use cogc::figures;
use cogc::runtime::Backend;

fn main() {
    let rounds: usize = std::env::var("COGC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let backend = Backend::auto();
    let t0 = std::time::Instant::now();
    let table = figures::fig11_12(&backend, "cifar_cnn", "moderate", rounds, 42, 0).expect("fig12");
    table.print();
    println!(
        "\n== bench fig12_gcplus [{} backend]: {rounds} rounds x 4 methods in {:.1}s ==",
        backend.name(),
        t0.elapsed().as_secs_f64()
    );
}
