//! Bench + regeneration harness for Fig. 4 (P_O vs s).
//!
//!     cargo bench --bench fig4_outage
//!
//! Prints the paper's data series (reduced MC trials; `cogc fig4` runs the
//! full version) and times the closed-form evaluation hot path plus the
//! Monte-Carlo sweep, serial vs parallel.

use cogc::bench::Suite;
use cogc::figures;
use cogc::gc::GcCode;
use cogc::network::Network;
use cogc::outage;
use cogc::parallel::{available_threads, MonteCarlo};
use cogc::scenario::Iid;
use cogc::util::rng::Rng;

fn main() {
    // ── the figure itself (reduced trials, all cores) ───────────────────
    figures::fig4(2_000, 42, 0).print();

    // ── timing ──────────────────────────────────────────────────────────
    let mut rng = Rng::new(1);
    let net = Network::homogeneous(10, 0.4, 0.25);
    let code = GcCode::generate(10, 7, &mut rng);
    let net_het = Network::heterogeneous(10, (0.0, 0.9), (0.0, 0.9), &mut rng);

    let mut suite = Suite::new("fig4: outage analysis");
    suite.bench("overall_outage closed-form (M=10)", || {
        cogc::bench::black_box(outage::overall_outage(&net, &code));
    });
    suite.bench("subcase_probs P1/P2/P3 joint DP (M=10)", || {
        cogc::bench::black_box(outage::subcase_probs(&net_het, &code));
    });
    suite.bench("full s-sweep x 5 cases (fig4 inner loop)", || {
        for s in 1..10 {
            let c = GcCode::generate(10, s, &mut rng);
            cogc::bench::black_box(outage::overall_outage(&net, &c));
        }
    });
    let serial = MonteCarlo::serial(7);
    suite.bench_throughput("monte-carlo outage rounds (1 thread)", 1000.0, "rounds", || {
        cogc::bench::black_box(outage::estimate_outage(&net, &code, &Iid, 1000, &serial));
    });
    let threaded = MonteCarlo::new(7);
    suite.bench_throughput(
        &format!("monte-carlo outage rounds ({} threads)", available_threads()),
        1000.0,
        "rounds",
        || {
            cogc::bench::black_box(outage::estimate_outage(&net, &code, &Iid, 1000, &threaded));
        },
    );
    suite.finish();
}
