//! Bench + regeneration harness for Fig. 6 (GC⁺ recovery statistics).
//!
//!     cargo bench --bench fig6_recovery

use cogc::bench::Suite;
use cogc::figures;
use cogc::network::Network;
use cogc::outage::mc::{gcplus_recovery, RecoveryMode};
use cogc::parallel::{available_threads, MonteCarlo};
use cogc::scenario::Iid;

fn main() {
    // the figure's series (reduced trials, all cores; `cogc fig6` for full)
    figures::fig6(400, 42, 0).print();

    let mut suite = Suite::new("fig6: GC+ recovery simulation");
    let serial = MonteCarlo::serial(2);
    let threaded = MonteCarlo::new(2);
    for setting in [2usize, 4] {
        let net = Network::fig6_setting(setting, 10);
        suite.bench_throughput(
            &format!("gcplus_recovery fixed t_r=2, setting {setting} (1 thread)"),
            50.0,
            "rounds",
            || {
                cogc::bench::black_box(gcplus_recovery(
                    &net,
                    &Iid,
                    10,
                    7,
                    RecoveryMode::FixedTr(2),
                    50,
                    &serial,
                ));
            },
        );
        suite.bench_throughput(
            &format!(
                "gcplus_recovery fixed t_r=2, setting {setting} ({} threads)",
                available_threads()
            ),
            50.0,
            "rounds",
            || {
                cogc::bench::black_box(gcplus_recovery(
                    &net,
                    &Iid,
                    10,
                    7,
                    RecoveryMode::FixedTr(2),
                    50,
                    &threaded,
                ));
            },
        );
    }
    let net = Network::fig6_setting(3, 10);
    suite.bench_throughput("gcplus_recovery until-decode, setting 3", 20.0, "rounds", || {
        cogc::bench::black_box(gcplus_recovery(
            &net,
            &Iid,
            10,
            7,
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 },
            20,
            &threaded,
        ));
    });
    suite.finish();
}
