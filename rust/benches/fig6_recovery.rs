//! Bench + regeneration harness for Fig. 6 (GC⁺ recovery statistics).
//!
//!     cargo bench --bench fig6_recovery

use cogc::bench::Suite;
use cogc::figures;
use cogc::network::Network;
use cogc::outage::mc::{gcplus_recovery, RecoveryMode};
use cogc::util::rng::Rng;

fn main() {
    // the figure's series (reduced trials; `cogc fig6` for full)
    figures::fig6(400, 42).print();

    let mut suite = Suite::new("fig6: GC+ recovery simulation");
    let mut rng = Rng::new(2);
    for setting in [2usize, 4] {
        let net = Network::fig6_setting(setting, 10);
        suite.bench_throughput(
            &format!("gcplus_recovery fixed t_r=2, setting {setting}"),
            50.0,
            "rounds",
            || {
                cogc::bench::black_box(gcplus_recovery(
                    &net,
                    10,
                    7,
                    RecoveryMode::FixedTr(2),
                    50,
                    &mut rng,
                ));
            },
        );
    }
    let net = Network::fig6_setting(3, 10);
    suite.bench_throughput("gcplus_recovery until-decode, setting 3", 20.0, "rounds", || {
        cogc::bench::black_box(gcplus_recovery(
            &net,
            10,
            7,
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 },
            20,
            &mut rng,
        ));
    });
    suite.finish();
}
