//! Bench + regeneration harness for Fig. 7 (MNIST: ideal / CoGC /
//! intermittent on paper Network 1). Reduced rounds by default; set
//! `COGC_BENCH_ROUNDS` (and see `cogc fig7 --network N --rounds 100`, the
//! full paper-scale run recorded in EXPERIMENTS.md). Runs on whichever
//! backend is available — the native pure-rust models on a clean checkout.

use cogc::figures;
use cogc::runtime::Backend;

fn main() {
    let rounds: usize = std::env::var("COGC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let backend = Backend::auto();
    let t0 = std::time::Instant::now();
    let table = figures::fig7_8(&backend, "mnist_cnn", 1, rounds, 42, 0).expect("fig7");
    table.print();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n== bench fig7_mnist [{} backend]: {rounds} rounds x 3 methods in {wall:.1}s \
         ({:.2}s/round/method) ==",
        backend.name(),
        wall / (3 * rounds) as f64
    );
}
