//! Bench + regeneration harness for Fig. 8 (CIFAR: ideal / CoGC /
//! intermittent on paper Network 2). Reduced rounds by default
//! (`COGC_BENCH_ROUNDS`); full run: `cogc fig8 --network N --rounds 100`.
//! Runs on whichever backend is available (native on a clean checkout).

use cogc::figures;
use cogc::runtime::Backend;

fn main() {
    let rounds: usize = std::env::var("COGC_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let backend = Backend::auto();
    let t0 = std::time::Instant::now();
    let table = figures::fig7_8(&backend, "cifar_cnn", 2, rounds, 42, 0).expect("fig8");
    table.print();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n== bench fig8_cifar [{} backend]: {rounds} rounds x 3 methods in {wall:.1}s \
         ({:.2}s/round/method) ==",
        backend.name(),
        wall / (3 * rounds) as f64
    );
}
