//! Hot-path microbenchmarks: the per-round compute surface of the
//! coordinator — coded combines (Pallas artifact vs native rust), RREF
//! decode (batch re-factor vs the incremental engine — peeling-fronted
//! and bare — at until-decode stack depths 6/20/40), the binary family's
//! exact integer engine vs the float peeling decoder (paper shape and
//! M = 10⁴), code generation, combinator solve, native dense
//! kernels (blocked/unrolled vs scalar reference), Monte-Carlo trial
//! sweeps (serial vs parallel engine), Byzantine audit overhead
//! (adversarial estimators vs their clean counterparts at the same
//! shapes), scenario-engine sweeps per channel model, and single train
//! steps.
//!
//!     cargo bench --bench hotpath
//!
//! The numbers here feed EXPERIMENTS.md §Perf. The coding-layer,
//! Monte-Carlo, scenario, and native model-step sections always run; the
//! PJRT model-runtime section needs `make artifacts` + real PJRT bindings
//! and is skipped (with a message) when either is missing.

use cogc::bench::Suite;
use cogc::gc::{self, BinaryCode, FrCode, GcCode, IntRref};
use cogc::linalg::{rref_with_transform, IncrementalRref, Matrix, PeelingDecoder};
use cogc::network::{Network, Realization, SparseRealization};
use cogc::outage::exact::poisson_binomial_pmf;
use cogc::outage::mc::{
    estimate_outage, estimate_outage_adv, estimate_outage_tri, fr_recovery, fr_recovery_adv,
    gcplus_recovery, gcplus_recovery_adv, gcplus_recovery_approx, RecoveryMode,
};
use cogc::parallel::{available_threads, MonteCarlo};
use cogc::runtime::native::kernels;
use cogc::runtime::{coded::native_combine, Backend, CodedKernels, CombineImpl, ModelRuntime};
use cogc::scenario::{self, run_scenario, AdversarySpec, Attack, Iid};
use cogc::testing::fake_batch;
use cogc::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let mut suite = Suite::new("hotpath");

    // ── coding-layer primitives ─────────────────────────────────────────
    let net = Network::fig6_setting(2, 10);
    suite.bench("GcCode::generate M=10 s=7", || {
        cogc::bench::black_box(GcCode::generate(10, 7, &mut rng));
    });
    let code = GcCode::generate(10, 7, &mut rng);
    suite.bench("find_combinator (3 received rows)", || {
        cogc::bench::black_box(gc::find_combinator(&code, &[1, 4, 8]));
    });
    let stacked = {
        let a1 = gc::Attempt::observe(&code, &Realization::sample(&net, &mut rng));
        let code2 = GcCode::generate(10, 7, &mut rng);
        let a2 = gc::Attempt::observe(&code2, &Realization::sample(&net, &mut rng));
        gc::stack_attempts(&[a1, a2])
    };
    if stacked.rows > 0 {
        suite.bench(&format!("gcplus decode rref ({}x10 stack)", stacked.rows), || {
            cogc::bench::black_box(gc::decode(&stacked));
        });
        suite.bench("rref_with_transform (stack)", || {
            cogc::bench::black_box(rref_with_transform(&stacked));
        });
    }
    let ps = vec![0.42; 10];
    suite.bench("poisson_binomial_pmf M=10", || {
        cogc::bench::black_box(poisson_binomial_pmf(&ps));
    });

    // ── decode engine: batch re-RREF vs incremental (until-decode) ──────
    // Algorithm 1's until-decode loop polls "anything decodable yet?" after
    // every tr=2-attempt block. The pre-incremental protocol re-stacked and
    // re-factored everything received so far on every poll (O(blocks²·M²)
    // per round); the incremental decoder eliminates each newly delivered
    // row once (O(rows·rank·M)). Both rows execute the *same* decode
    // schedule over the same fixed attempt set — only the engine differs.
    {
        let net3 = Network::fig6_setting(3, 10); // poor uplinks: sparse rows
        for target_rows in [6usize, 20, 40] {
            let mut arng = Rng::new(1000 + target_rows as u64);
            let mut attempts = Vec::new();
            let mut rows = 0usize;
            while rows < target_rows {
                let code = GcCode::generate(10, 7, &mut arng);
                let att = gc::Attempt::observe(&code, &Realization::sample(&net3, &mut arng));
                rows += att.delivered.len();
                attempts.push(att);
            }
            let n_blocks = attempts.len().div_ceil(2);
            suite.bench(
                &format!("until-decode batch re-rref  ({rows} rows, {n_blocks} blocks)"),
                || {
                    for b in 1..=n_blocks {
                        let upto = (2 * b).min(attempts.len());
                        let stacked = gc::stack_attempts(&attempts[..upto]);
                        cogc::bench::black_box(gc::decode(&stacked).k4.len());
                    }
                },
            );
            suite.bench(
                &format!("until-decode incremental    ({rows} rows, {n_blocks} blocks)"),
                || {
                    let mut dec = gc::GcPlusDecoder::new(10);
                    for chunk in attempts.chunks(2) {
                        for att in chunk {
                            dec.push_attempt(att);
                        }
                        cogc::bench::black_box(dec.decodable_count());
                    }
                },
            );
            // the incremental row above runs peeling-fronted (the decoder's
            // default); this one is the bare elimination engine on the same
            // schedule — the delta is what the degree-≤1 fast path buys
            suite.bench(
                &format!("until-decode pure rref      ({rows} rows, {n_blocks} blocks)"),
                || {
                    let mut eng = IncrementalRref::new(10);
                    for chunk in attempts.chunks(2) {
                        for att in chunk {
                            for &r in &att.delivered {
                                eng.push_row(att.perturbed.row(r));
                            }
                        }
                        cogc::bench::black_box(eng.decodable_count());
                    }
                },
            );
        }
    }

    // ── binary family: exact integer engine vs float peeling decoder ────
    // The ±1 family decodes in exact i128 rational arithmetic; these rows
    // price that exactness against the float peeling decoder on the same
    // row stream, at the paper shape and a federation-scale M. Rows are
    // built sparsely from the deterministic support — no dense M×M bridge
    // is materialized at the large-M shape.
    {
        for &(m, s, n_rows) in &[(10usize, 4usize, 12usize), (10_000, 4, 64)] {
            let bcode = BinaryCode::new(m, s).unwrap();
            let mut brng = Rng::new(4_000 + m as u64);
            let mut irows: Vec<Vec<i64>> = Vec::new();
            let mut frows: Vec<Vec<f64>> = Vec::new();
            let mut buf: Vec<i64> = Vec::new();
            for _ in 0..n_rows {
                bcode.int_row_into(brng.below(m), &mut buf);
                // erode ~40% of each row's support, as erased uplinks would
                for v in buf.iter_mut() {
                    if *v != 0 && brng.bernoulli(0.4) {
                        *v = 0;
                    }
                }
                irows.push(buf.clone());
                frows.push(buf.iter().map(|&x| x as f64).collect());
            }
            suite.bench(&format!("binary int-rref push  M={m} ({n_rows} rows)"), || {
                let mut eng = IntRref::new(m);
                for row in &irows {
                    eng.push_row(row);
                }
                cogc::bench::black_box(eng.decodable_count());
            });
            suite.bench(&format!("float peeling push    M={m} ({n_rows} rows)"), || {
                let mut dec = PeelingDecoder::new(m);
                for row in &frows {
                    dec.push_row(row);
                }
                cogc::bench::black_box(dec.decodable_count());
            });
        }
        // the exact rational combinator solve at the paper shape
        let bcode = BinaryCode::new(10, 4).unwrap();
        let complete: Vec<usize> = (0..6).collect();
        suite.bench("binary combinator_weights M=10 (6 rows)", || {
            cogc::bench::black_box(bcode.combinator_weights(&complete));
        });
    }

    // ── structured family: sparse vs dense sampling, group scan vs RREF ─
    // The scaling evidence for the CodeFamily refactor (EXPERIMENTS.md
    // §Perf): realization sampling is O(M·(s+1)) draws on the sparse path
    // vs O(M²) dense, and the FR per-group coverage scan replaces the
    // incremental-RREF decodability test entirely. The dense/RREF rows
    // stop at M = 1024 — one dense realization beyond that is hundreds of
    // MB and the row would measure the allocator, not the engine; the cap
    // is printed, never silent.
    {
        let fr_s = 3usize; // every M below is divisible by s+1 = 4
        for &m in &[64usize, 1024, 10_000, 100_000] {
            let fr_net = Network::homogeneous(m, 0.3, 0.2);
            let fr_code = FrCode::new(m, fr_s).unwrap();
            let sup = fr_code.sparse_support();
            let mut srng = Rng::new(500 + m as u64);
            let mut sparse = SparseRealization::perfect(&sup);
            suite.bench_throughput(
                &format!("sparse sample_into      M={m} s={fr_s}"),
                (m * (fr_s + 1)) as f64,
                "links",
                || {
                    SparseRealization::sample_with_into(
                        &sup,
                        &mut srng,
                        |row, _idx, j| fr_net.p_c2c(row, j),
                        |i| fr_net.p_c2s[i],
                        &mut sparse,
                    );
                    cogc::bench::black_box(sparse.tau[0]);
                },
            );
            let mut covered: Vec<bool> = Vec::new();
            suite.bench_throughput(
                &format!("fr group scan (serial)  M={m} s={fr_s}"),
                fr_code.groups() as f64,
                "groups",
                || {
                    fr_code.covered_into(&sparse, &mut covered);
                    cogc::bench::black_box(covered.len());
                },
            );
            if m > 1024 {
                eprintln!(
                    "note: skipping dense-sampling and incremental-rref rows at M={m} — the \
                     dense path allocates O(M²) (≈{} MB per realization); the comparison rows \
                     run at M ≤ 1024",
                    m * m / 1_000_000
                );
                continue;
            }
            let mut drng = Rng::new(900 + m as u64);
            let mut dense = Realization::perfect(m);
            suite.bench_throughput(
                &format!("dense sample_into       M={m}"),
                (m * m) as f64,
                "links",
                || {
                    Realization::sample_with_into(
                        m,
                        &mut drng,
                        |i, j| fr_net.p_c2c(i, j),
                        |i| fr_net.p_c2s[i],
                        &mut dense,
                    );
                    cogc::bench::black_box(dense.tau[0]);
                },
            );
            // decodability test over one attempt's delivered rows: the FR
            // scan above vs eliminating the cyclic rows incrementally
            let cyc = GcCode::generate(m, fr_s, &mut Rng::new(3 + m as u64));
            let att = gc::Attempt::observe(&cyc, &dense);
            suite.bench(
                &format!("incremental rref attempt M={m} ({} rows)", att.delivered.len()),
                || {
                    let mut dec = gc::GcPlusDecoder::new(m);
                    dec.push_attempt(&att);
                    cogc::bench::black_box(dec.decodable_count());
                },
            );
        }
    }

    // ── native kernels: blocked/unrolled vs scalar reference ────────────
    // The fwd/bwd compute surface of every native train_step, at the
    // mnist_cnn layer shapes (B=32: 196→64 hidden, 64→10 head).
    {
        let mut krng = Rng::new(77);
        for (rows, n_in, n_out) in [(32usize, 196usize, 64usize), (32, 64, 10)] {
            let x: Vec<f32> = (0..rows * n_in)
                .map(|_| if krng.bernoulli(0.5) { 0.0 } else { krng.normal() as f32 })
                .collect();
            let w: Vec<f32> = (0..n_in * n_out).map(|_| krng.normal() as f32).collect();
            let b: Vec<f32> = (0..n_out).map(|_| krng.normal() as f32).collect();
            let dy: Vec<f32> = (0..rows * n_out).map(|_| krng.normal() as f32).collect();
            let shape = format!("{rows}x{n_in}->{n_out}");
            let flops = (2 * rows * n_in * n_out) as f64;
            suite.bench_throughput(&format!("affine naive    {shape}"), flops, "flop", || {
                cogc::bench::black_box(kernels::affine_ref(&x, rows, n_in, &w, &b, n_out));
            });
            suite.bench_throughput(&format!("affine blocked  {shape}"), flops, "flop", || {
                cogc::bench::black_box(kernels::affine(&x, rows, n_in, &w, &b, n_out));
            });
            suite.bench_throughput(&format!("matmul_bt naive   {shape}"), flops, "flop", || {
                cogc::bench::black_box(kernels::matmul_bt_ref(&dy, rows, n_out, &w, n_in));
            });
            suite.bench_throughput(&format!("matmul_bt blocked {shape}"), flops, "flop", || {
                cogc::bench::black_box(kernels::matmul_bt(&dy, rows, n_out, &w, n_in));
            });
            suite.bench_throughput(&format!("matgrad naive   {shape}"), flops, "flop", || {
                let mut gw = vec![0.0f32; n_in * n_out];
                let mut gb = vec![0.0f32; n_out];
                kernels::accum_matgrad_ref(&x, rows, n_in, &dy, n_out, &mut gw, &mut gb);
                cogc::bench::black_box((gw, gb));
            });
            suite.bench_throughput(&format!("matgrad blocked {shape}"), flops, "flop", || {
                let mut gw = vec![0.0f32; n_in * n_out];
                let mut gb = vec![0.0f32; n_out];
                kernels::accum_matgrad(&x, rows, n_in, &dy, n_out, &mut gw, &mut gb);
                cogc::bench::black_box((gw, gb));
            });
        }
    }

    // ── Monte-Carlo trial sweeps: serial vs parallel engine ─────────────
    // The Fig. 4 / Fig. 6 workload shapes; same seeds at both thread
    // counts, so both runs produce bit-identical tallies — only the
    // wall-clock differs. This is the tentpole speedup evidence.
    let cores = available_threads();
    let mut thread_counts = vec![1usize];
    if cores > 1 {
        thread_counts.push(cores);
    }
    let outage_trials = 20_000;
    for &threads in &thread_counts {
        let mc = MonteCarlo::new(11).with_threads(threads);
        suite.bench_throughput(
            &format!("mc outage sweep fig4-shape, {outage_trials} trials ({threads} thr)"),
            outage_trials as f64,
            "rounds",
            || {
                cogc::bench::black_box(estimate_outage(&net, &code, &Iid, outage_trials, &mc));
            },
        );
    }
    let recovery_trials = 2_000;
    for &threads in &thread_counts {
        let mc = MonteCarlo::new(13).with_threads(threads);
        suite.bench_throughput(
            &format!("mc gc+ recovery fig6-shape, {recovery_trials} trials ({threads} thr)"),
            recovery_trials as f64,
            "rounds",
            || {
                cogc::bench::black_box(gcplus_recovery(
                    &net,
                    &Iid,
                    10,
                    7,
                    RecoveryMode::FixedTr(2),
                    recovery_trials,
                    &mc,
                ));
            },
        );
    }

    // ── degraded-mode decode: the lstsq fallback at the paper shapes ────
    // The rescue prices one Gram/Cholesky least-squares solve over the
    // delivered rows. The solve-only row isolates it; the MC rows run the
    // approx-aware estimators on the same seeds as the exact fig4/fig6
    // rows above, so the delta over those rows is the full price of the
    // fallback (it only fires on would-be-outage trials).
    {
        let net3 = Network::fig6_setting(3, 10);
        let mut arng = Rng::new(4242);
        let mut dec = gc::GcPlusDecoder::new(10);
        while dec.rows() < 8 {
            let c = GcCode::generate(10, 7, &mut arng);
            let att = gc::Attempt::observe(&c, &Realization::sample(&net3, &mut arng));
            dec.push_attempt(&att);
        }
        suite.bench(&format!("lstsq approx_sum M=10 ({} rows)", dec.rows()), || {
            let sol = gc::approx_sum(&dec);
            cogc::bench::black_box(sol.map(|s| gc::relative_residual(&s, 10)));
        });
        for &threads in &thread_counts {
            let mc = MonteCarlo::new(13).with_threads(threads);
            suite.bench_throughput(
                &format!(
                    "mc gc+ recovery approx fig6-shape, {recovery_trials} trials ({threads} thr)"
                ),
                recovery_trials as f64,
                "rounds",
                || {
                    cogc::bench::black_box(gcplus_recovery_approx(
                        &net,
                        &Iid,
                        10,
                        7,
                        RecoveryMode::FixedTr(2),
                        f64::INFINITY,
                        recovery_trials,
                        &mc,
                    ));
                },
            );
            let mc4 = MonteCarlo::new(11).with_threads(threads);
            suite.bench_throughput(
                &format!("mc outage tri fig4-shape, {outage_trials} trials ({threads} thr)"),
                outage_trials as f64,
                "rounds",
                || {
                    cogc::bench::black_box(estimate_outage_tri(
                        &net,
                        &code,
                        &Iid,
                        f64::INFINITY,
                        outage_trials,
                        &mc4,
                    ));
                },
            );
        }
    }

    // ── telemetry overhead: armed vs disabled, same shapes ──────────────
    // The disabled rows above already price the zero-cost default (the
    // shard plumbing compiles to integer bumps into pooled scratch); these
    // re-run the same workloads with the registry armed, so the delta is
    // the full price of counting + phase clocks + shard merges. Feeds the
    // EXPERIMENTS.md telemetry-overhead table.
    {
        use cogc::telemetry;
        let mc = MonteCarlo::new(11).with_threads(cores.max(1));
        telemetry::reset();
        telemetry::arm();
        suite.bench_throughput(
            &format!("mc outage sweep fig4-shape ARMED, {outage_trials} trials ({cores} thr)"),
            outage_trials as f64,
            "rounds",
            || {
                cogc::bench::black_box(estimate_outage(&net, &code, &Iid, outage_trials, &mc));
            },
        );
        let mc13 = MonteCarlo::new(13).with_threads(cores.max(1));
        suite.bench_throughput(
            &format!("mc gc+ recovery fig6-shape ARMED, {recovery_trials} trials ({cores} thr)"),
            recovery_trials as f64,
            "rounds",
            || {
                cogc::bench::black_box(gcplus_recovery(
                    &net,
                    &Iid,
                    10,
                    7,
                    RecoveryMode::FixedTr(2),
                    recovery_trials,
                    &mc13,
                ));
            },
        );
        let m_fr = 10_000usize;
        let fr_code_tel = FrCode::new(m_fr, 3).unwrap();
        let fr_net_tel = Network::homogeneous(m_fr, 0.3, 0.2);
        let fr_trials = 200usize;
        let mc17 = MonteCarlo::new(17).with_threads(cores.max(1));
        suite.bench_throughput(
            &format!("fr recovery clean M={m_fr} ARMED, {fr_trials} trials ({cores} thr)"),
            fr_trials as f64,
            "rounds",
            || {
                cogc::bench::black_box(fr_recovery(
                    &fr_net_tel,
                    &Iid,
                    &fr_code_tel,
                    RecoveryMode::FixedTr(2),
                    fr_trials,
                    &mc17,
                ));
            },
        );
        telemetry::disarm();
        telemetry::reset();
    }

    // ── Byzantine audit overhead: adversarial estimators vs clean ───────
    // Same shapes as the clean rows above, under a 20% sign-flip uplink
    // adversary; the delta over the clean rows is the price of adversary
    // sampling + corruption bookkeeping + (gc+/audit) the cross-attempt
    // parity audit with identify-and-excise re-decode. `nodetect` isolates
    // the bookkeeping from the audit itself.
    {
        let spec = AdversarySpec::fraction(Attack::SignFlip, 0.2);
        let mut nodetect = spec.clone();
        nodetect.detect = false;
        for &threads in &thread_counts {
            let mc = MonteCarlo::new(11).with_threads(threads);
            suite.bench_throughput(
                &format!("mc outage adv fig4-shape, {outage_trials} trials ({threads} thr)"),
                outage_trials as f64,
                "rounds",
                || {
                    cogc::bench::black_box(estimate_outage_adv(
                        &net,
                        &code,
                        &Iid,
                        &spec,
                        outage_trials,
                        &mc,
                    ));
                },
            );
        }
        for &threads in &thread_counts {
            let mc = MonteCarlo::new(13).with_threads(threads);
            for (label, sp) in [("audit   ", &spec), ("nodetect", &nodetect)] {
                suite.bench_throughput(
                    &format!(
                        "mc gc+ recovery adv/{label} fig6-shape, {recovery_trials} trials \
                         ({threads} thr)"
                    ),
                    recovery_trials as f64,
                    "rounds",
                    || {
                        cogc::bench::black_box(gcplus_recovery_adv(
                            &net,
                            &Iid,
                            sp,
                            10,
                            7,
                            RecoveryMode::FixedTr(2),
                            recovery_trials,
                            &mc,
                        ));
                    },
                );
            }
        }
        // large-M FR shape: the sparse group scan vs the plurality-vote
        // audit over group copies
        let m_fr = 10_000usize;
        let fr_code = FrCode::new(m_fr, 3).unwrap();
        let fr_net = Network::homogeneous(m_fr, 0.3, 0.2);
        let fr_trials = 200usize;
        for &threads in &thread_counts {
            let mc = MonteCarlo::new(17).with_threads(threads);
            suite.bench_throughput(
                &format!("fr recovery clean M={m_fr}, {fr_trials} trials ({threads} thr)"),
                fr_trials as f64,
                "rounds",
                || {
                    cogc::bench::black_box(fr_recovery(
                        &fr_net,
                        &Iid,
                        &fr_code,
                        RecoveryMode::FixedTr(2),
                        fr_trials,
                        &mc,
                    ));
                },
            );
            suite.bench_throughput(
                &format!("fr recovery adv   M={m_fr}, {fr_trials} trials ({threads} thr)"),
                fr_trials as f64,
                "rounds",
                || {
                    cogc::bench::black_box(fr_recovery_adv(
                        &fr_net,
                        &Iid,
                        &fr_code,
                        &spec,
                        RecoveryMode::FixedTr(2),
                        fr_trials,
                        &mc,
                    ));
                },
            );
        }
    }

    // ── scenario engine: stateful channel sweeps, serial vs parallel ────
    // One row per channel model kind; each sweep runs `trials` episodes of
    // the scenario's full round schedule, so the throughput unit is
    // simulated rounds. Same seed at both thread counts → identical
    // RoundSeries, only wall-clock differs.
    {
        let scenario_trials = 200usize;
        for name in ["iid-moderate", "bursty-c2c", "correlated-fade", "straggler-harsh"] {
            let sc = scenario::find(name).unwrap();
            let rounds = (scenario_trials * sc.rounds) as f64;
            for &threads in &thread_counts {
                let mc = MonteCarlo::new(29).with_threads(threads);
                suite.bench_throughput(
                    &format!(
                        "scenario {name} [{}], {scenario_trials} episodes ({threads} thr)",
                        sc.channel.name()
                    ),
                    rounds,
                    "rounds",
                    || {
                        cogc::bench::black_box(run_scenario(&sc, scenario_trials, &mc));
                    },
                );
            }
        }
    }

    // ── native model steps (always run — no artifacts needed) ───────────
    {
        let backend = Backend::native();
        for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
            let model = backend.load_model(name).unwrap();
            let params = model.init_params(&mut rng);
            let batch = fake_batch(&model.spec, &mut rng);
            let d = model.spec.d;
            suite.bench(&format!("native train_step {name} (D={d})"), || {
                cogc::bench::black_box(model.train_step(&params, &batch, 0, 0.01).unwrap());
            });
            suite.bench(&format!("native eval_step  {name} (D={d})"), || {
                cogc::bench::black_box(model.eval_step(&params, &batch).unwrap());
            });
            let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            suite.bench(&format!("native sgd_apply  {name} (D={d})"), || {
                cogc::bench::black_box(model.sgd_apply(&params, &g, 0.01).unwrap());
            });
        }
    }

    // ── model runtime (needs artifacts + PJRT) ──────────────────────────
    let runtime = match Backend::pjrt_parts() {
        Ok(pair) => Some(pair),
        Err(e) => {
            eprintln!("skipping PJRT model-runtime benches: {e:#}");
            None
        }
    };

    if let Some((engine, man)) = runtime {
        // ── coded combine: Pallas vs native, per model size ─────────────
        for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
            let spec = man.model(name).unwrap().clone();
            let d = spec.d;
            let pallas = CodedKernels::load(&engine, &man, &spec, CombineImpl::Pallas).unwrap();
            let w = Matrix::from_fn(man.m, man.m, |i, j| {
                if i == j || rng.bernoulli(0.7) { rng.normal() } else { 0.0 }
            });
            let grads: Vec<f32> = (0..man.m * d).map(|_| rng.normal() as f32).collect();
            let flops = (2 * man.m * man.m * d) as f64;
            suite.bench_throughput(&format!("encode pallas   {name} (D={d})"), flops, "flop", || {
                cogc::bench::black_box(pallas.encode(&w, &grads).unwrap());
            });
            suite.bench_throughput(&format!("encode native   {name} (D={d})"), flops, "flop", || {
                cogc::bench::black_box(native_combine(&w, &grads, d));
            });
            let wd = Matrix::from_fn(man.m, man.mt, |_, _| {
                if rng.bernoulli(0.3) { rng.normal() } else { 0.0 }
            });
            let stacked: Vec<f32> = (0..man.mt * d).map(|_| rng.normal() as f32).collect();
            let dflops = (2 * man.m * man.mt * d) as f64;
            suite.bench_throughput(&format!("decode pallas   {name} (D={d})"), dflops, "flop", || {
                cogc::bench::black_box(pallas.decode(&wd, &stacked).unwrap());
            });
            suite.bench_throughput(&format!("decode native   {name} (D={d})"), dflops, "flop", || {
                cogc::bench::black_box(native_combine(&wd, &stacked, d));
            });
        }

        // ── model runtime: single train/eval steps ──────────────────────
        for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
            let model = ModelRuntime::load(&engine, &man, name).unwrap();
            let params = model.init_params(&mut rng);
            let spec = &model.spec;
            let batch = fake_batch(spec, &mut rng);
            suite.bench(&format!("train_step {name}"), || {
                cogc::bench::black_box(model.train_step(&params, &batch, 0, 0.01).unwrap());
            });
            suite.bench(&format!("eval_step  {name}"), || {
                cogc::bench::black_box(model.eval_step(&params, &batch).unwrap());
            });
            let g: Vec<f32> = (0..spec.d).map(|_| rng.normal() as f32).collect();
            suite.bench(&format!("sgd_apply  {name} (D={})", spec.d), || {
                cogc::bench::black_box(model.sgd_apply(&params, &g, 0.01).unwrap());
            });
        }
    }

    suite.finish();
}
