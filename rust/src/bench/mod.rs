//! Micro-benchmark harness (substrate — criterion is not available offline).
//!
//! `cargo bench` targets use this via `harness = false`: each bench binary
//! builds a `Suite`, registers closures, and `run()` prints a stable table
//! (name, iters, mean, p50, p95, min) plus optional throughput. Benchmarks
//! auto-calibrate the iteration count to a target measurement window.
//!
//! Figure benches additionally print the paper's data series (CSV) so that
//! `cargo bench` regenerates every table/figure shape end-to-end.
//!
//! Usage: [`Suite::bench`] for latency rows, [`Suite::bench_throughput`]
//! when a work count (flops, trials) gives the row a rate column, and
//! [`black_box`] around every measured expression so the optimizer cannot
//! delete it. Numbers land in EXPERIMENTS.md — regenerate them with
//! `cargo bench --bench hotpath` before editing that file.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_s(&self) -> f64 {
        self.mean_ns / 1e9
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1}ns")
    } else if ns < 1e6 {
        format!("{:8.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2}ms", ns / 1e6)
    } else {
        format!("{:8.3}s ", ns / 1e9)
    }
}

/// Measure `f` by sampling: warm up, then collect `samples` timed batches.
pub fn measure<F: FnMut()>(mut f: F, target: Duration, samples: usize) -> Stats {
    // Calibrate batch size so one batch is ~ target/samples.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let per_sample = target.as_secs_f64() / samples as f64;
    let batch = (per_sample / once.as_secs_f64()).clamp(1.0, 1e7) as u64;

    // Warmup (~10% of target).
    let warm_end = Instant::now() + target / 10;
    while Instant::now() < warm_end {
        f();
    }

    let mut times: Vec<f64> = Vec::with_capacity(samples);
    let mut total_iters = 0u64;
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t.elapsed().as_nanos() as f64 / batch as f64;
        times.push(dt);
        total_iters += batch;
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let pct = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    Stats {
        iters: total_iters,
        mean_ns: mean,
        p50_ns: pct(0.5),
        p95_ns: pct(0.95),
        min_ns: times[0],
        max_ns: *times.last().unwrap(),
    }
}

pub struct Suite {
    name: String,
    target: Duration,
    samples: usize,
    results: Vec<(String, Stats, Option<String>)>,
}

impl Suite {
    pub fn new(name: &str) -> Self {
        // COGC_BENCH_FAST=1 shrinks the window for CI-style smoke runs.
        let fast = std::env::var("COGC_BENCH_FAST").is_ok();
        Suite {
            name: name.to_string(),
            target: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            samples: if fast { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    pub fn with_target(mut self, d: Duration) -> Self {
        self.target = d;
        self
    }

    /// Register + run one benchmark.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &mut Self {
        let stats = measure(f, self.target, self.samples);
        self.results.push((name.to_string(), stats, None));
        self
    }

    /// Benchmark with a throughput annotation (`units` per iteration).
    pub fn bench_throughput<F: FnMut()>(&mut self, name: &str, units: f64, unit_name: &str, f: F) {
        let stats = measure(f, self.target, self.samples);
        let rate = units / stats.mean_s();
        let ann = if rate > 1e9 {
            format!("{:7.2} G{unit_name}/s", rate / 1e9)
        } else if rate > 1e6 {
            format!("{:7.2} M{unit_name}/s", rate / 1e6)
        } else if rate > 1e3 {
            format!("{:7.2} k{unit_name}/s", rate / 1e3)
        } else {
            format!("{rate:7.2} {unit_name}/s")
        };
        self.results.push((name.to_string(), stats, Some(ann)));
    }

    /// Print the results table.
    pub fn finish(&self) {
        println!("\n== bench suite: {} ==", self.name);
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}  {}",
            "benchmark", "mean", "p50", "p95", "min", "throughput"
        );
        for (name, s, ann) in &self.results {
            println!(
                "{:<44} {} {} {} {}  {}",
                name,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
                fmt_ns(s.min_ns),
                ann.as_deref().unwrap_or("")
            );
        }
    }

    pub fn results(&self) -> &[(String, Stats, Option<String>)] {
        &self.results
    }
}

/// Keep a value alive / opaque to the optimizer.
pub fn keep<T>(x: T) -> T {
    bb(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_is_sane() {
        let mut acc = 0u64;
        let s = measure(
            || {
                acc = acc.wrapping_add(black_box(1));
            },
            Duration::from_millis(20),
            5,
        );
        assert!(s.iters > 0);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns * 1.5);
        assert!(s.p50_ns <= s.p95_ns);
    }

    #[test]
    fn suite_collects_results() {
        std::env::set_var("COGC_BENCH_FAST", "1");
        let mut suite = Suite::new("test").with_target(Duration::from_millis(10));
        suite.bench("noop", || {
            black_box(0);
        });
        suite.bench_throughput("bytes", 1024.0, "B", || {
            black_box([0u8; 16]);
        });
        assert_eq!(suite.results().len(), 2);
    }
}
