//! Per-client state: current parameters and the local data shard.

use crate::data::{ImageShard, TokenShard};
use crate::runtime::Batch;

/// A client's data source.
#[derive(Clone)]
pub enum Shard {
    Image(ImageShard),
    Tokens(TokenShard),
}

impl Shard {
    pub fn next_batch(&mut self) -> Batch {
        match self {
            Shard::Image(s) => s.next_batch(),
            Shard::Tokens(s) => s.next_batch(),
        }
    }
}

/// One federated client.
pub struct ClientState {
    pub id: usize,
    /// The latest local model `g_{m,r}` (kept across rounds for Design 2's
    /// broadcast fallback, eq. (7)).
    pub params: Vec<f32>,
    pub shard: Shard,
    /// Cumulative local training steps (diagnostics).
    pub steps: usize,
}

impl ClientState {
    pub fn new(id: usize, params: Vec<f32>, shard: Shard) -> ClientState {
        ClientState { id, params, shard, steps: 0 }
    }
}
