//! Training-run configuration: which aggregation protocol, which model,
//! which network, which data partition.

use crate::data::Partition;
use crate::gc::CodeFamily;
use crate::runtime::CombineImpl;
use crate::scenario::{AdversarySpec, ChannelSpec};

/// PS-side aggregation protocol (the paper's §VII comparison set).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Aggregator {
    /// FL with perfect connectivity (the paper's ideal benchmark (iii)).
    Ideal,
    /// FL over intermittent uplinks: average whichever updates arrive
    /// (benchmark (iv), update rule of eq. (23)).
    Intermittent,
    /// CoGC with the standard (binary) GC decoder (§III).
    CoGc { design: Design, attempts: usize },
    /// CoGC with the GC⁺ complementary decoder (§VI, Algorithm 1).
    GcPlus { tr: usize, until_decode: bool, max_blocks: usize },
    /// GC⁺ with the degraded-mode rescue: when a round ends with nothing
    /// exactly decodable, the PS applies the least-squares approximate
    /// aggregate over the delivered coded rows (relative residual logged
    /// per round) instead of skipping the update. Dense families only.
    Approx { tr: usize, until_decode: bool, max_blocks: usize },
    /// Tandon-style dataset-replication GC: partial sums are computed from
    /// replicated data (no client-to-client erasure exposure, (s+1)× the
    /// local compute), uplinks still fail. The paper's Fig. 1 baseline.
    TandonReplicated { attempts: usize },
}

/// The paper's two update-rule designs for standard CoGC (§III).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Design {
    /// Design 1: repeat communication until the PS recovers the model
    /// (bounded here by `attempts`; a real system would retry forever).
    RetryUntilSuccess,
    /// Design 2: on failure, skip the update and continue local training.
    SkipRound,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model name in the manifest (mnist_cnn / cifar_cnn / transformer).
    pub model: String,
    /// Gradient-code family used by the CoGC aggregators (cyclic, or
    /// fractional repetition — which additionally needs M % (s+1) == 0).
    pub code: CodeFamily,
    /// Straggler tolerance s of the code.
    pub s: usize,
    /// Total training rounds T.
    pub rounds: usize,
    /// Local SGD iterations per round I.
    pub local_iters: usize,
    pub lr: f32,
    pub seed: u64,
    pub aggregator: Aggregator,
    pub partition: Partition,
    /// Training examples per client (images) / tokens per client (LM).
    pub per_client: usize,
    /// Held-out eval batches per evaluation.
    pub eval_batches: usize,
    /// Evaluate every this many rounds (1 = every round).
    pub eval_every: usize,
    /// Coded-combine implementation (Pallas artifacts vs native rust).
    pub combine: CombineImpl,
    /// Synthetic dataset separability (class-mean signal strength).
    pub signal: f64,
    /// Link dynamics: i.i.d. erasures (the paper's model) or a stateful
    /// channel from `scenario` (bursts persist across rounds/attempts).
    pub channel: ChannelSpec,
    /// Byzantine clients: `None` trains exactly as before; `Some` fixes a
    /// malicious set for the whole run (sampled once from the run seed)
    /// that corrupts its emissions every round.
    pub adversary: Option<AdversarySpec>,
}

impl TrainConfig {
    pub fn new(model: &str, aggregator: Aggregator) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            code: CodeFamily::Cyclic,
            s: 7,
            rounds: 100,
            local_iters: 5,
            lr: match model {
                "cifar_cnn" => 0.02,
                "transformer" => 0.05,
                _ => 0.005,
            },
            seed: 0,
            aggregator,
            partition: match model {
                "cifar_cnn" => Partition::Dirichlet(0.35),
                "transformer" => Partition::Iid, // token shards are contiguous
                _ => Partition::OneClassPerClient,
            },
            per_client: 200,
            eval_batches: 8,
            eval_every: 1,
            combine: CombineImpl::Pallas,
            signal: 2.0,
            channel: ChannelSpec::Iid,
            adversary: None,
        }
    }

    /// Tag used in logs/CSV column names.
    pub fn tag(&self) -> String {
        match self.aggregator {
            Aggregator::Ideal => "ideal".into(),
            Aggregator::Intermittent => "intermittent".into(),
            Aggregator::CoGc { design: Design::RetryUntilSuccess, .. } => "cogc_d1".into(),
            Aggregator::CoGc { design: Design::SkipRound, .. } => "cogc".into(),
            Aggregator::GcPlus { .. } => "gcplus".into(),
            Aggregator::Approx { .. } => "approx".into(),
            Aggregator::TandonReplicated { .. } => "tandon".into(),
        }
    }
}
