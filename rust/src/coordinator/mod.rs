//! L3 coordinator: the CoGC training system — clients, PS aggregation
//! protocols (ideal / intermittent / CoGC / GC⁺ / replicated-GC), and the
//! round engine gluing the gradient-coding layer to the PJRT runtime.

pub mod client;
pub mod config;
pub mod trainer;

pub use client::{ClientState, Shard};
pub use config::{Aggregator, Design, TrainConfig};
pub use trainer::{TrainAdvLog, Trainer};
