//! The CoGC training loop (paper §III Fig. 3, §VI Algorithm 1) plus the
//! §VII baselines — the end-to-end coordinator tying the gradient-coding
//! layer to the model runtime (either backend: PJRT artifacts or the
//! native pure-rust models).
//!
//! Per round: broadcast (eq. (7)) → I-step local SGD (eq. (2), the AOT
//! train artifact) → gradient-sharing encode (eq. (8), the Pallas
//! `coded_matmul` artifact) → uplink over the erasure network → decode
//! (standard combinator eq. (9) or GC⁺ Algorithm 2) → global update
//! (eq. (10)/(23), the Pallas `sgd_apply` artifact).

use super::client::{ClientState, Shard};
use super::config::{Aggregator, Design, TrainConfig};
use crate::data::{class_means, partition, ImageDataset, ImageShard, TokenDataset, TokenShard};
use crate::gc::{self, BinaryCode, CodeFamily, FrCode, GcCode, IntRref};
use crate::linalg::Matrix;
use crate::metrics::{RoundRecord, RunLog};
use crate::network::{Network, SparseRealization};
use crate::runtime::{Backend, CodedKernels, InputKind, ModelRuntime};
use crate::scenario::{AdversaryModel, ChannelModel, GroupVerdict, Surface, ADVERSARY_STREAM};
use crate::telemetry;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Outcome of the aggregation step of one round.
struct AggResult {
    /// Mean update to apply to the global model (None = no update).
    delta: Option<Vec<f32>>,
    outcome: &'static str,
    k4: usize,
    attempts: usize,
    transmissions: usize,
    /// Relative residual of the applied aggregate (0 for exact decodes,
    /// positive when the least-squares fallback supplied the update).
    residual: f64,
}

/// Relative f32 tolerance for cross-combinator decode comparison: two
/// distinct combinator row sets must reproduce the same full sum on honest
/// payloads up to encode/accumulate rounding.
const CROSS_CHECK_TOL: f32 = 1e-3;

/// Run-level adversary tallies. The trainer sees only what a real PS sees
/// (values, no ground truth), so it reports what its defenses *did* —
/// alarms raised and rows/copies excised — not oracle poisoned counts.
#[derive(Clone, Debug, Default)]
pub struct TrainAdvLog {
    /// Malicious clients fixed for this run (0 = clean run).
    pub malicious: usize,
    /// Alarms raised by the decode-path audits across the run.
    pub detected: usize,
    /// Stacked rows / FR member copies excised across the run.
    pub excised: usize,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub net: Network,
    model: ModelRuntime,
    coded: CodedKernels,
    m: usize,
    mt: usize,
    d: usize,
    clients: Vec<ClientState>,
    global: Vec<f32>,
    /// Whether the previous round updated the global model (eq. (7)).
    updated_last: bool,
    /// Link dynamics (state persists across rounds and repeat attempts);
    /// built from `cfg.channel`, reset from the run seed in `new`.
    channel: Box<dyn ChannelModel>,
    /// Byzantine clients (None = clean run). The malicious set is fixed at
    /// construction from the run seed — a compromised client stays
    /// compromised for the whole run.
    adversary: Option<AdversaryModel>,
    /// What the decode-path defenses did this run (see [`TrainAdvLog`]).
    pub adv_log: TrainAdvLog,
    eval_shard: Shard,
    /// Denominator for accuracy per eval batch.
    eval_denom: f64,
    rng: Rng,
}

impl Trainer {
    pub fn new(backend: &Backend, cfg: TrainConfig, net: Network) -> anyhow::Result<Trainer> {
        let man = backend.manifest();
        anyhow::ensure!(net.m == man.m, "network M={} but backend built for M={}", net.m, man.m);
        cfg.code.validate(man.m, cfg.s)?;
        anyhow::ensure!(
            !(matches!(cfg.aggregator, Aggregator::Approx { .. })
                && cfg.code == CodeFamily::FractionalRepetition),
            "--agg approx needs a dense code family (cyclic/binary): the FR decoder \
             delivers group indicators, not stackable coded rows to least-square over"
        );
        let model = backend.load_model(&cfg.model)?;
        let coded = backend.coded(&model.spec, cfg.combine)?;
        let mut rng = Rng::new(cfg.seed ^ 0xC0_6C);
        let m = man.m;
        let d = model.spec.d;

        // data
        let (clients, eval_shard, eval_denom) = match model.spec.kind {
            InputKind::Image => {
                let elems = model.spec.x_elems() / model.spec.batch;
                let classes = model.spec.num_classes;
                let means = class_means(elems, classes, &mut rng);
                let train = Arc::new(ImageDataset::synth_with_means(
                    cfg.per_client * m,
                    &means,
                    cfg.signal,
                    &mut rng,
                ));
                let test = Arc::new(ImageDataset::synth_with_means(
                    (cfg.eval_batches * model.spec.batch).max(model.spec.batch),
                    &means,
                    cfg.signal,
                    &mut rng,
                ));
                let shards = partition(&train, m, cfg.partition, &mut rng);
                let clients: Vec<ClientState> = shards
                    .into_iter()
                    .enumerate()
                    .map(|(id, idx)| {
                        let shard = Shard::Image(ImageShard::new(
                            train.clone(),
                            idx,
                            model.spec.batch,
                            rng.split(id as u64 + 1000),
                        ));
                        ClientState::new(id, Vec::new(), shard)
                    })
                    .collect();
                let eval = Shard::Image(ImageShard::new(
                    test.clone(),
                    (0..test.n).collect(),
                    model.spec.batch,
                    rng.split(999),
                ));
                (clients, eval, model.spec.batch as f64)
            }
            InputKind::Tokens => {
                let seq = model.spec.x_shape[1];
                let batch = model.spec.batch;
                let train = Arc::new(TokenDataset::synth(
                    cfg.per_client * m,
                    model.spec.num_classes,
                    0.05,
                    &mut rng,
                ));
                let test = Arc::new(TokenDataset::synth(
                    (batch * seq * (cfg.eval_batches + 2)).max(4 * seq),
                    model.spec.num_classes,
                    0.05,
                    &mut rng,
                ));
                let mut shards = TokenShard::split(train, m, batch, seq, &mut rng);
                let clients: Vec<ClientState> = shards
                    .drain(..)
                    .enumerate()
                    .map(|(id, s)| ClientState::new(id, Vec::new(), Shard::Tokens(s)))
                    .collect();
                let hi = test.tokens.len();
                let eval = Shard::Tokens(TokenShard::new(test, 0, hi, batch, seq, rng.split(999)));
                (clients, eval, (batch * seq) as f64)
            }
        };

        let global = model.init_params(&mut rng.split(7));
        let mut clients = clients;
        for c in &mut clients {
            c.params = global.clone();
        }
        // the channel's private state stream derives from the run seed, so
        // training runs stay bit-reproducible from `--seed` alone
        let mut channel = cfg.channel.build();
        channel.reset(&net, crate::parallel::derive_seed(cfg.seed, 0xC4A2));
        // the malicious set likewise: fixed for the run, drawn on the
        // adversary substream so a clean config draws nothing
        let adversary = match &cfg.adversary {
            Some(spec) => {
                spec.validate()?;
                let mut adv = AdversaryModel::new(spec.clone());
                adv.reset(m, crate::parallel::derive_seed(cfg.seed, ADVERSARY_STREAM));
                Some(adv)
            }
            None => None,
        };
        let adv_log = TrainAdvLog {
            malicious: adversary.as_ref().map_or(0, |a| a.malicious_count()),
            ..TrainAdvLog::default()
        };
        Ok(Trainer {
            cfg,
            net,
            model,
            coded,
            m,
            mt: man.mt,
            d,
            clients,
            global,
            updated_last: true,
            channel,
            adversary,
            adv_log,
            eval_shard,
            eval_denom,
            rng,
        })
    }

    /// Run the full training loop, returning the per-round log.
    pub fn run(&mut self) -> anyhow::Result<RunLog> {
        let mut log = RunLog::new(&format!("{}/{}", self.cfg.model, self.cfg.tag()));
        for round in 0..self.cfg.rounds {
            let rec = self.round(round)?;
            if crate::util::logging::enabled(crate::util::logging::Level::Debug) {
                crate::debug!(
                    "round {round}: outcome={} acc={:.3} loss={:.3}",
                    rec.outcome,
                    rec.test_acc,
                    rec.train_loss
                );
            }
            log.push(rec);
        }
        Ok(log)
    }

    /// Run until test accuracy first reaches `target` (Fig. 10 protocol);
    /// returns the log truncated at the hit (or the full `rounds` budget).
    pub fn run_until_acc(&mut self, target: f64) -> anyhow::Result<RunLog> {
        let mut log = RunLog::new(&format!("{}/{}@{}", self.cfg.model, self.cfg.tag(), target));
        for round in 0..self.cfg.rounds {
            let rec = self.round(round)?;
            let hit = rec.test_acc.is_finite() && rec.test_acc >= target;
            log.push(rec);
            if hit {
                break;
            }
        }
        Ok(log)
    }

    pub fn global_params(&self) -> &[f32] {
        &self.global
    }

    fn round(&mut self, round: usize) -> anyhow::Result<RoundRecord> {
        // Phase scopes record wall-clock into the telemetry registry's
        // non-deterministic section; disarmed they read no clock at all.
        // ── 1. broadcast (eq. (7)) ────────────────────────────────────────
        {
            let _t = telemetry::phase("train/broadcast");
            let broadcast_always = !matches!(self.cfg.aggregator, Aggregator::CoGc { .. });
            if self.updated_last || broadcast_always {
                for c in &mut self.clients {
                    c.params.copy_from_slice(&self.global);
                }
            } // else: clients continue from their latest local models
        }

        // ── 2. local training (eq. (2)) ───────────────────────────────────
        let _local = telemetry::phase("train/local");
        let mut deltas = vec![0.0f32; self.m * self.d];
        let mut train_loss = 0.0f64;
        for ci in 0..self.m {
            let start: Vec<f32> = self.clients[ci].params.clone();
            let mut params = start.clone();
            let mut last_loss = 0.0f32;
            for it in 0..self.cfg.local_iters {
                let batch = self.clients[ci].shard.next_batch();
                let seed = (round * 1_000_003 + ci * 1009 + it) as u32;
                let _k = telemetry::phase("train/kernel");
                let (new_params, loss) =
                    self.model.train_step(&params, &batch, seed, self.cfg.lr)?;
                drop(_k);
                params = new_params;
                last_loss = loss;
                self.clients[ci].steps += 1;
            }
            train_loss += last_loss as f64;
            for j in 0..self.d {
                deltas[ci * self.d + j] = params[j] - start[j];
            }
            self.clients[ci].params = params;
        }
        train_loss /= self.m as f64;
        drop(_local);

        // ── 3. communication + decode ─────────────────────────────────────
        let agg = {
            let _t = telemetry::phase("train/aggregate");
            self.aggregate(&deltas)?
        };

        // ── 4. global update ──────────────────────────────────────────────
        let updated = agg.delta.is_some();
        if let Some(delta) = &agg.delta {
            let _t = telemetry::phase("train/apply");
            // g_r <- g_{r-1} + delta  via the fused Pallas sgd kernel (lr=-1)
            self.global = self.model.sgd_apply(&self.global, delta, -1.0)?;
        }
        self.updated_last = updated;

        // ── 5. evaluation ─────────────────────────────────────────────────
        let (test_loss, test_acc) = if round % self.cfg.eval_every == 0
            || round + 1 == self.cfg.rounds
        {
            let _t = telemetry::phase("train/eval");
            self.evaluate()?
        } else {
            (f64::NAN, f64::NAN)
        };

        Ok(RoundRecord {
            round,
            updated,
            outcome: agg.outcome.to_string(),
            k4: agg.k4,
            attempts: agg.attempts,
            transmissions: agg.transmissions,
            train_loss,
            test_loss,
            test_acc,
            residual: agg.residual,
        })
    }

    fn evaluate(&mut self) -> anyhow::Result<(f64, f64)> {
        let mut loss = 0.0f64;
        let mut correct = 0.0f64;
        for _ in 0..self.cfg.eval_batches {
            let batch = self.eval_shard.next_batch();
            let (l, c) = self.model.eval_step(&self.global, &batch)?;
            loss += l as f64;
            correct += c as f64;
        }
        let nb = self.cfg.eval_batches as f64;
        Ok((loss / nb, correct / (nb * self.eval_denom)))
    }

    // ── aggregation protocols ────────────────────────────────────────────

    fn aggregate(&mut self, deltas: &[f32]) -> anyhow::Result<AggResult> {
        // A c2c (data-poisoning) adversary substitutes its local update
        // consistently in everything it emits, so the corruption lands once
        // on the delta stack before any protocol runs. Consistent
        // substitution satisfies every coding relation — by construction no
        // decode-path audit can flag it (the documented blind spot).
        // Uplink tampering instead lands on the coded sums inside each
        // protocol, where redundancy checks can catch it.
        let d = self.d;
        if let Some(adv) = self.adversary.as_mut() {
            if adv.any() && matches!(adv.spec.surface, Surface::C2c) {
                let mut poisoned = deltas.to_vec();
                for ci in 0..self.m {
                    if adv.is_malicious(ci) {
                        adv.corrupt_row_f32(&mut poisoned[ci * d..(ci + 1) * d]);
                    }
                }
                return self.aggregate_inner(&poisoned);
            }
        }
        self.aggregate_inner(deltas)
    }

    fn aggregate_inner(&mut self, deltas: &[f32]) -> anyhow::Result<AggResult> {
        match self.cfg.aggregator {
            Aggregator::Ideal => {
                let all: Vec<usize> = (0..self.m).collect();
                Ok(self.agg_subset_mean(deltas, &all, "ideal", 0))
            }
            Aggregator::Intermittent => {
                let real = self.channel.sample(&self.net, &mut self.rng);
                let received: Vec<usize> =
                    (0..self.m).filter(|&i| real.tau[i]).collect();
                let tx = self.m; // every client attempts its uplink
                if received.is_empty() {
                    Ok(AggResult {
                        delta: None,
                        outcome: "none",
                        k4: 0,
                        attempts: 1,
                        transmissions: tx,
                        residual: 0.0,
                    })
                } else if self.uplink_adversary_active() {
                    // uncoded uplinks: a malicious client's update arrives
                    // corrupted and there is no redundancy to check it with
                    let mut tampered = deltas.to_vec();
                    let d = self.d;
                    let adv = self.adversary.as_mut().expect("checked active");
                    for &ci in &received {
                        if adv.is_malicious(ci) {
                            adv.corrupt_row_f32(&mut tampered[ci * d..(ci + 1) * d]);
                        }
                    }
                    Ok(self.agg_subset_mean(&tampered, &received, "subset", tx))
                } else {
                    Ok(self.agg_subset_mean(deltas, &received, "subset", tx))
                }
            }
            // the binary family shares the cyclic aggregation pipeline;
            // inside, the code is the deterministic ±1 bridge and the
            // combinator / extraction solves run in exact arithmetic
            Aggregator::CoGc { design, attempts } => match self.cfg.code {
                CodeFamily::Cyclic | CodeFamily::Binary => {
                    self.agg_cogc(deltas, design, attempts, false)
                }
                CodeFamily::FractionalRepetition => {
                    self.agg_cogc_fr(deltas, design, attempts, false)
                }
            },
            Aggregator::TandonReplicated { attempts } => match self.cfg.code {
                CodeFamily::Cyclic | CodeFamily::Binary => {
                    self.agg_cogc(deltas, Design::SkipRound, attempts, true)
                }
                CodeFamily::FractionalRepetition => {
                    self.agg_cogc_fr(deltas, Design::SkipRound, attempts, true)
                }
            },
            Aggregator::GcPlus { tr, until_decode, max_blocks } => match self.cfg.code {
                CodeFamily::Cyclic | CodeFamily::Binary => {
                    self.agg_gcplus(deltas, tr, until_decode, max_blocks, false)
                }
                CodeFamily::FractionalRepetition => {
                    self.agg_gcplus_fr(deltas, tr, until_decode, max_blocks)
                }
            },
            Aggregator::Approx { tr, until_decode, max_blocks } => match self.cfg.code {
                CodeFamily::Cyclic | CodeFamily::Binary => {
                    self.agg_gcplus(deltas, tr, until_decode, max_blocks, true)
                }
                CodeFamily::FractionalRepetition => {
                    anyhow::bail!("approx aggregator with FR is rejected in Trainer::new")
                }
            },
        }
    }

    /// Whether uplink-surface tampering is live this run.
    fn uplink_adversary_active(&self) -> bool {
        self.adversary
            .as_ref()
            .map_or(false, |a| a.any() && matches!(a.spec.surface, Surface::Uplink))
    }

    /// Tamper the uplinked coded sums of every malicious client in place.
    fn corrupt_sums(&mut self, sums: &mut [f32]) {
        let d = self.d;
        let adv = self.adversary.as_mut().expect("caller checked active");
        for ci in 0..self.m {
            if adv.is_malicious(ci) {
                adv.corrupt_row_f32(&mut sums[ci * d..(ci + 1) * d]);
            }
        }
    }

    /// Cross-combinator integrity check (the GC-redundancy detector): when
    /// more than M−s complete rows arrived, two distinct combinator row
    /// sets must decode to the same full sum; disagreement betrays a
    /// tampered row. Returns `true` when the decode is consistent (or when
    /// there is no spare row to check with — a lone minimal set is
    /// unfalsifiable).
    fn cross_check(&self, code: &GcCode, complete: &[usize], sums: &[f32]) -> bool {
        let need = self.m - self.cfg.s;
        if complete.len() <= need {
            return true;
        }
        let lo = gc::find_combinator(code, &complete[..need]);
        let hi = gc::find_combinator(code, &complete[complete.len() - need..]);
        let (Some(a), Some(b)) = (lo, hi) else {
            return true; // degenerate subsets: fall back to the plain path
        };
        let am = Matrix::from_rows(&[a]);
        let bm = Matrix::from_rows(&[b]);
        let oa = crate::runtime::coded::native_combine(&am, sums, self.d);
        let ob = crate::runtime::coded::native_combine(&bm, sums, self.d);
        let mut err = 0.0f32;
        let mut scale = 1.0f32;
        for (x, y) in oa[..self.d].iter().zip(&ob[..self.d]) {
            err = err.max((x - y).abs());
            scale = scale.max(x.abs()).max(y.abs());
        }
        err <= CROSS_CHECK_TOL * scale
    }

    /// Mean over an explicit subset (ideal / intermittent baselines) — the
    /// unbiased-given-uniform-subsets rule of eq. (23).
    fn agg_subset_mean(
        &self,
        deltas: &[f32],
        subset: &[usize],
        outcome: &'static str,
        transmissions: usize,
    ) -> AggResult {
        let mut delta = vec![0.0f32; self.d];
        for &ci in subset {
            let row = &deltas[ci * self.d..(ci + 1) * self.d];
            for (o, v) in delta.iter_mut().zip(row) {
                *o += v;
            }
        }
        let inv = 1.0 / subset.len() as f32;
        for o in &mut delta {
            *o *= inv;
        }
        AggResult {
            delta: Some(delta),
            outcome,
            k4: subset.len(),
            attempts: 1,
            transmissions,
            residual: 0.0,
        }
    }

    /// Standard CoGC (§III) — optionally with Tandon-style replication
    /// (perfect sharing phase, uplink erasure only).
    fn agg_cogc(
        &mut self,
        deltas: &[f32],
        design: Design,
        attempts: usize,
        replicated: bool,
    ) -> anyhow::Result<AggResult> {
        let max_attempts = match design {
            Design::RetryUntilSuccess => attempts.max(50),
            Design::SkipRound => attempts.max(1),
        };
        let mut tx = 0usize;
        // binary runs: one deterministic ±1 code for the whole round,
        // bridged to the dense form for observation/encode; combinator
        // solves go through the exact rational engine instead of floats
        let binary = match self.cfg.code {
            CodeFamily::Binary => Some(
                BinaryCode::new(self.m, self.cfg.s).expect("code validated in Trainer::new"),
            ),
            _ => None,
        };
        let bridged = binary.map(|bc| bc.to_gc_code());
        // the gradient stack is identical across attempts: build its device
        // literal once (saves an M·D host copy per retry — §Perf)
        let prepared = self.coded.prepare_grads(deltas)?;
        for attempt in 0..max_attempts {
            let generated;
            let code = match &bridged {
                Some(c) => c,
                None => {
                    generated = GcCode::generate(self.m, self.cfg.s, &mut self.rng);
                    &generated
                }
            };
            let mut real = self.channel.sample(&self.net, &mut self.rng);
            if replicated {
                // dataset replication: partial sums never see c2c erasure
                real.t = vec![vec![true; self.m]; self.m];
            }
            let att = gc::Attempt::observe(code, &real);
            // sharing phase: s transmissions per client (none when replicated)
            tx += if replicated { 0 } else { self.cfg.s * self.m };
            // uplinks: only complete partial sums are transmitted
            tx += att.complete.len();
            if att.complete.len() < self.m - self.cfg.s {
                continue; // all-or-nothing failure — try again or give up
            }
            let combinator = match binary {
                // exact rational solve, scattered back to client indexing
                Some(bc) => bc.combinator_weights(&att.complete).map(|w| {
                    let mut full = vec![0.0f64; self.m];
                    for (k, &r) in att.complete.iter().enumerate() {
                        full[r] = w[k];
                    }
                    full
                }),
                None => gc::find_combinator(code, &att.complete),
            };
            let Some(a) = combinator else {
                continue;
            };
            // partial sums S = B̂ · Δ  (the Pallas encode artifact)
            let mut sums = self.coded.encode_prepared(&att.perturbed, &prepared, deltas)?;
            if self.uplink_adversary_active() {
                self.corrupt_sums(&mut sums);
                let detect = self.adversary.as_ref().map_or(false, |adv| adv.spec.detect);
                if detect && !self.cross_check(code, &att.complete, &sums) {
                    // redundant complete rows disagree: a tampered uplink
                    // sits in the minimal set — drop the attempt rather
                    // than apply a poisoned update
                    self.adv_log.detected += 1;
                    continue;
                }
            }
            // PS-side combinator application (eq. (9)): a single row dot —
            // native combine (the M×MT Pallas decode shape would compute
            // M·D outputs for 1 needed row; see §Perf)
            let sums_m = Matrix::from_rows(&[a]);
            let out = crate::runtime::coded::native_combine(&sums_m, &sums, self.d);
            // exact sum / M  (eq. (9))
            let inv = 1.0 / self.m as f32;
            let delta: Vec<f32> = out[..self.d].iter().map(|x| x * inv).collect();
            return Ok(AggResult {
                delta: Some(delta),
                outcome: "standard",
                k4: self.m,
                attempts: attempt + 1,
                transmissions: tx,
                residual: 0.0,
            });
        }
        Ok(AggResult {
            delta: None,
            outcome: "none",
            k4: 0,
            attempts: max_attempts,
            transmissions: tx,
            residual: 0.0,
        })
    }

    /// GC⁺ (§VI, Algorithm 1): stack complete *and* incomplete partial sums
    /// across attempts; decode every recoverable local update. With
    /// `approx`, a round that would end "none" instead applies the
    /// least-squares aggregate over the delivered rows (the degraded-mode
    /// rescue — outcome "approx", residual logged per round).
    fn agg_gcplus(
        &mut self,
        deltas: &[f32],
        tr: usize,
        until_decode: bool,
        max_blocks: usize,
        approx: bool,
    ) -> anyhow::Result<AggResult> {
        let blocks = if until_decode { max_blocks.max(1) } else { 1 };
        let mut tx = 0usize;
        let mut attempts_used = 0usize;
        // incremental decoder over the delivered coefficient rows: each new
        // row is eliminated against the reduced form in O(rank·M) — the
        // per-block "anything decodable yet?" test needs no re-stack and no
        // re-RREF of everything received so far (§Perf)
        let mut decoder = gc::GcPlusDecoder::new(self.m);
        // binary runs: fixed ±1 code bridged for observation/encode, plus
        // an exact integer engine fed in lockstep with the float decoder —
        // gates and extraction weights come from the exact engine
        let binary = match self.cfg.code {
            CodeFamily::Binary => Some(
                BinaryCode::new(self.m, self.cfg.s).expect("code validated in Trainer::new"),
            ),
            _ => None,
        };
        let bridged = binary.map(|bc| bc.to_gc_code());
        let mut ieng = binary.map(|_| IntRref::new(self.m));
        let mut ibuf: Vec<i64> = Vec::new();
        // payload rows delivered to the PS, in stack order
        let mut payload_rows: Vec<Vec<f32>> = Vec::new();
        // one gradient literal for the whole round (§Perf)
        let prepared = self.coded.prepare_grads(deltas)?;
        // live uplink tampering + detection: mirror the delivered
        // coefficient rows so the decode-point audit can excise suspects
        let audit_live = self.uplink_adversary_active()
            && self.adversary.as_ref().map_or(false, |adv| adv.spec.detect);
        let mut coeff_stack = Matrix::zeros(0, self.m);
        // armed-only decode introspection: fold the engine state into the
        // global registry at each return point (one merge per round — no
        // shard pooling needed outside the MC trial loops)
        let harvest = |decoder: &gc::GcPlusDecoder, ieng: &Option<IntRref>| {
            if telemetry::armed() {
                let mut sh = telemetry::Shard::new();
                match ieng {
                    Some(eng) => sh.absorb_int_engine(eng.rows() as u64, eng.rank() as u64),
                    None => decoder.harvest(&mut sh),
                }
                telemetry::merge_shard(&sh);
            }
        };

        for _ in 0..blocks {
            for _ in 0..tr {
                attempts_used += 1;
                let generated;
                let code = match &bridged {
                    Some(c) => c,
                    None => {
                        generated = GcCode::generate(self.m, self.cfg.s, &mut self.rng);
                        &generated
                    }
                };
                let real = self.channel.sample(&self.net, &mut self.rng);
                let att = gc::Attempt::observe(code, &real);
                tx += self.cfg.s * self.m + self.m; // all partial sums are uplinked
                let mut sums = self.coded.encode_prepared(&att.perturbed, &prepared, deltas)?;
                if self.uplink_adversary_active() {
                    self.corrupt_sums(&mut sums);
                }
                // standard-GC shortcut (Algorithm 1's first branch); under a
                // live audit the shortcut's row set must also survive the
                // cross-combinator check before it is trusted
                if att.complete.len() >= self.m - self.cfg.s {
                    let shortcut = if audit_live && !self.cross_check(code, &att.complete, &sums)
                    {
                        // tampered uplink in the minimal set: refuse the
                        // shortcut, keep stacking — the parity audit below
                        // gets a vote once redundancy accumulates
                        self.adv_log.detected += 1;
                        None
                    } else {
                        match binary {
                            Some(bc) => bc.combinator_weights(&att.complete).map(|w| {
                                let mut full = vec![0.0f64; self.m];
                                for (k, &r) in att.complete.iter().enumerate() {
                                    full[r] = w[k];
                                }
                                full
                            }),
                            None => gc::find_combinator(code, &att.complete),
                        }
                    };
                    if let Some(a) = shortcut {
                        let a_m = Matrix::from_rows(&[a]);
                        let out =
                            crate::runtime::coded::native_combine(&a_m, &sums, self.d);
                        let inv = 1.0 / self.m as f32;
                        let delta: Vec<f32> = out[..self.d].iter().map(|x| x * inv).collect();
                        harvest(&decoder, &ieng);
                        return Ok(AggResult {
                            delta: Some(delta),
                            outcome: "standard",
                            k4: self.m,
                            attempts: attempts_used,
                            transmissions: tx,
                            residual: 0.0,
                        });
                    }
                }
                for &r in &att.delivered {
                    payload_rows.push(sums[r * self.d..(r + 1) * self.d].to_vec());
                    if audit_live {
                        coeff_stack.push_row(att.perturbed.row(r));
                    }
                    if let Some(eng) = &mut ieng {
                        // delivered ±1 rows are integer-exact by construction
                        ibuf.clear();
                        ibuf.extend(att.perturbed.row(r).iter().map(|&v| v as i64));
                        eng.push_row(&ibuf);
                    }
                }
                decoder.push_attempt(&att);
            }
            // complementary decode over everything received so far — the
            // engine already holds the reduced form of every pushed row
            // (binary runs gate on the exact engine, not the float one)
            let decodable_now = match &ieng {
                Some(eng) => eng.decodable_count(),
                None => decoder.decodable_count(),
            };
            if decoder.rows() == 0 || decodable_now == 0 {
                continue;
            }
            if audit_live {
                // payload-parity audit over the whole stack: every
                // linearly dependent row yields a check that must vanish
                // on honest data (tolerance matched to f32 encode
                // rounding, cf. the f64 RESIDUAL_TOL of the MC oracle)
                let d = self.d;
                let audit = gc::audit_rows(&coeff_stack, |combo, kept| {
                    let mut mag = 0.0f64;
                    for (i, &orig) in kept.iter().enumerate().take(combo.len()) {
                        if combo[i] != 0.0 {
                            let rinf = payload_rows[orig]
                                .iter()
                                .fold(0.0f64, |mx, &x| mx.max((x as f64).abs()));
                            mag += combo[i].abs() * rinf;
                        }
                    }
                    let mut worst = 0.0f64;
                    for j in 0..d {
                        let mut acc = 0.0f64;
                        for (i, &orig) in kept.iter().enumerate().take(combo.len()) {
                            if combo[i] != 0.0 {
                                acc += combo[i] * payload_rows[orig][j] as f64;
                            }
                        }
                        worst = worst.max(acc.abs());
                    }
                    worst > CROSS_CHECK_TOL as f64 * mag
                });
                if telemetry::armed() {
                    let mut sh = telemetry::Shard::new();
                    sh.inc(telemetry::metric::AUDIT_CHECKS);
                    sh.add(telemetry::metric::AUDIT_EXCISIONS, audit.excised.len() as u64);
                    telemetry::merge_shard(&sh);
                }
                if audit.alarm {
                    self.adv_log.detected += 1;
                    self.adv_log.excised += audit.excised.len();
                    // realign all three structures on the survivors and
                    // rebuild the incremental engine
                    coeff_stack = coeff_stack.select_rows(&audit.kept);
                    payload_rows = audit
                        .kept
                        .iter()
                        .map(|&i| std::mem::take(&mut payload_rows[i]))
                        .collect();
                    decoder.reset(self.m);
                    for i in 0..coeff_stack.rows {
                        decoder.push_row(coeff_stack.row(i));
                    }
                    if let Some(eng) = &mut ieng {
                        eng.reset(self.m);
                        for i in 0..coeff_stack.rows {
                            ibuf.clear();
                            ibuf.extend(coeff_stack.row(i).iter().map(|&v| v as i64));
                            eng.push_row(&ibuf);
                        }
                    }
                    let decodable_now = match &ieng {
                        Some(eng) => eng.decodable_count(),
                        None => decoder.decodable_count(),
                    };
                    if decodable_now == 0 {
                        continue; // excision emptied K₄ — stack more blocks
                    }
                }
            }
            let dec = match &ieng {
                // exact extraction: K₄ and weights from the integer engine
                Some(eng) => {
                    let mut k4 = Vec::new();
                    let mut weights = Matrix::zeros(0, decoder.rows());
                    let mut wrow = Vec::new();
                    for (client, row) in eng.decodable() {
                        k4.push(client);
                        eng.t_row_f64(row, &mut wrow);
                        weights.push_row(&wrow);
                    }
                    gc::Decoded { k4, weights, rank: eng.rank() }
                }
                None => decoder.decode(),
            };
            let rows = decoder.rows();
            let delta = if rows <= self.mt {
                // Pallas path: pad weights to [M, MT] and payload to [MT, D]
                let w = gc::gcplus::pad_weights(&dec, self.m, self.mt);
                let mut stacked = vec![0.0f32; self.mt * self.d];
                for (i, row) in payload_rows.iter().enumerate() {
                    stacked[i * self.d..(i + 1) * self.d].copy_from_slice(row);
                }
                let out = self.coded.decode(&w, &stacked)?;
                // mean over K4 (eq. (23))
                let mut delta = vec![0.0f32; self.d];
                for &client in &dec.k4 {
                    let row = &out[client * self.d..(client + 1) * self.d];
                    for (o, v) in delta.iter_mut().zip(row) {
                        *o += v;
                    }
                }
                let inv = 1.0 / dec.k4.len() as f32;
                for o in &mut delta {
                    *o *= inv;
                }
                delta
            } else {
                // native fallback for stacks beyond the AOT shape
                let mut flat = vec![0.0f32; rows * self.d];
                for (i, row) in payload_rows.iter().enumerate() {
                    flat[i * self.d..(i + 1) * self.d].copy_from_slice(row);
                }
                let out = crate::runtime::coded::native_combine(&dec.weights, &flat, self.d);
                let mut delta = vec![0.0f32; self.d];
                for i in 0..dec.k4.len() {
                    let row = &out[i * self.d..(i + 1) * self.d];
                    for (o, v) in delta.iter_mut().zip(row) {
                        *o += v;
                    }
                }
                let inv = 1.0 / dec.k4.len() as f32;
                for o in &mut delta {
                    *o *= inv;
                }
                delta
            };
            let outcome = if dec.k4.len() == self.m { "full" } else { "partial" };
            harvest(&decoder, &ieng);
            return Ok(AggResult {
                delta: Some(delta),
                outcome,
                k4: dec.k4.len(),
                attempts: attempts_used,
                transmissions: tx,
                residual: 0.0,
            });
        }
        harvest(&decoder, &ieng);
        // degraded-mode rescue: nothing decoded exactly across the whole
        // budget — least-square 𝟙 over the delivered coefficient rows and
        // apply the approximate mean rather than skipping the update. The
        // decoder's row stack and `payload_rows` are in lockstep (both fed
        // per delivered row, both rebuilt together on audit excision), so
        // `sol.weights[i]` weighs `payload_rows[i]`.
        if approx && decoder.rank() > 0 {
            if let Some(sol) = gc::approx_sum(&decoder) {
                let rel = gc::relative_residual(&sol, self.m);
                let mut delta = vec![0.0f32; self.d];
                for (i, row) in payload_rows.iter().enumerate() {
                    let w = sol.weights[i] as f32;
                    if w != 0.0 {
                        for (o, v) in delta.iter_mut().zip(row) {
                            *o += w * v;
                        }
                    }
                }
                let inv = 1.0 / self.m as f32;
                for o in &mut delta {
                    *o *= inv;
                }
                if telemetry::armed() {
                    let mut sh = telemetry::Shard::new();
                    sh.inc(telemetry::metric::APPROX_FALLBACKS);
                    telemetry::merge_shard(&sh);
                }
                return Ok(AggResult {
                    delta: Some(delta),
                    outcome: "approx",
                    k4: 0,
                    attempts: attempts_used,
                    transmissions: tx,
                    residual: rel,
                });
            }
        }
        Ok(AggResult {
            delta: None,
            outcome: "none",
            k4: 0,
            attempts: attempts_used,
            transmissions: tx,
            residual: 0.0,
        })
    }

    // ── fractional-repetition aggregation ────────────────────────────────

    /// Per-group delta sums under the FR code — the only payloads FR can
    /// deliver: every row of a group carries the identical all-ones
    /// combination of its members (the distinct rows of
    /// [`FrCode::dense_b`]), so one G×M indicator combine per round covers
    /// every attempt.
    fn fr_group_sums(&self, code: &FrCode, deltas: &[f32]) -> Vec<f32> {
        let w = Matrix::from_fn(code.groups(), self.m, |g, j| {
            if code.group_of(j) == g {
                1.0
            } else {
                0.0
            }
        });
        crate::runtime::coded::native_combine(&w, deltas, self.d)
    }

    /// Standard CoGC under the FR family: decode succeeds iff every group
    /// delivers at least one complete sum, and the update is the exact
    /// mean (one all-ones row per group sums to the total). Coverage is
    /// the O(M) group scan — no combinator search, no RREF.
    fn agg_cogc_fr(
        &mut self,
        deltas: &[f32],
        design: Design,
        attempts: usize,
        replicated: bool,
    ) -> anyhow::Result<AggResult> {
        let code = FrCode::new(self.m, self.cfg.s).expect("code validated in Trainer::new");
        let sup = code.sparse_support();
        let max_attempts = match design {
            Design::RetryUntilSuccess => attempts.max(50),
            Design::SkipRound => attempts.max(1),
        };
        let mut tx = 0usize;
        let mut covered: Vec<bool> = Vec::new();
        let mut verdicts: Vec<GroupVerdict> = Vec::new();
        let vote = self.uplink_adversary_active();
        for attempt in 0..max_attempts {
            let mut real = self.channel.sample(&self.net, &mut self.rng);
            if replicated {
                // dataset replication: partial sums never see c2c erasure
                real.t = vec![vec![true; self.m]; self.m];
            }
            let sreal = SparseRealization::project_from_dense(&sup, &real);
            code.covered_into(&sreal, &mut covered);
            // sharing phase: s transmissions per client (none when replicated)
            tx += if replicated { 0 } else { self.cfg.s * self.m };
            // uplinks: only complete partial sums are transmitted
            tx += (0..self.m).filter(|&i| sreal.row_delivered_complete(i)).count();
            // Byzantine uplinks: the PS accepts a group only through the
            // member-value plurality vote — a tied vote excises the whole
            // group (→ uncovered), a unanimous malicious group decodes a
            // poisoned value below
            let ok = if vote {
                let adv = self.adversary.as_ref().expect("vote implies adversary");
                let audit = adv.fr_attempt_verdicts(&code, &sreal, &mut verdicts);
                self.adv_log.detected += audit.alarms;
                self.adv_log.excised += audit.excised;
                verdicts.iter().all(|v| v.covered())
            } else {
                FrCode::all_covered(&covered)
            };
            if !ok {
                continue; // some group delivered nothing — retry or give up
            }
            let mut sums = self.fr_group_sums(&code, deltas);
            if vote {
                let d = self.d;
                let adv = self.adversary.as_mut().expect("vote implies adversary");
                for (g, v) in verdicts.iter().enumerate() {
                    if *v == GroupVerdict::Poisoned {
                        adv.corrupt_row_f32(&mut sums[g * d..(g + 1) * d]);
                    }
                }
            }
            let inv = 1.0 / self.m as f32;
            let mut delta = vec![0.0f32; self.d];
            for g in 0..code.groups() {
                for (o, v) in delta.iter_mut().zip(&sums[g * self.d..(g + 1) * self.d]) {
                    *o += v;
                }
            }
            for o in &mut delta {
                *o *= inv;
            }
            return Ok(AggResult {
                delta: Some(delta),
                outcome: "standard",
                k4: self.m,
                attempts: attempt + 1,
                transmissions: tx,
                residual: 0.0,
            });
        }
        Ok(AggResult {
            delta: None,
            outcome: "none",
            k4: 0,
            attempts: max_attempts,
            transmissions: tx,
            residual: 0.0,
        })
    }

    /// GC⁺ under the FR family: covered groups accumulate across attempts;
    /// any covered group's members are immediately decodable (its all-ones
    /// sum is the group's exact delta total), so partial recovery is the
    /// union scan — no stacked-row elimination.
    fn agg_gcplus_fr(
        &mut self,
        deltas: &[f32],
        tr: usize,
        until_decode: bool,
        max_blocks: usize,
    ) -> anyhow::Result<AggResult> {
        let code = FrCode::new(self.m, self.cfg.s).expect("code validated in Trainer::new");
        let sup = code.sparse_support();
        let blocks = if until_decode { max_blocks.max(1) } else { 1 };
        let mut tx = 0usize;
        let mut attempts_used = 0usize;
        let mut acc = vec![false; code.groups()];
        let mut covered: Vec<bool> = Vec::new();
        let vote = self.uplink_adversary_active();
        let detect = self.adversary.as_ref().map_or(false, |adv| adv.spec.detect);
        // best verdict per group across repeats (vote runs only)
        let mut verdicts: Vec<GroupVerdict> = Vec::new();
        let mut best = vec![GroupVerdict::Uncovered; if vote { code.groups() } else { 0 }];
        for _ in 0..blocks {
            for _ in 0..tr {
                attempts_used += 1;
                let real = self.channel.sample(&self.net, &mut self.rng);
                let sreal = SparseRealization::project_from_dense(&sup, &real);
                code.covered_into(&sreal, &mut covered);
                tx += self.cfg.s * self.m + self.m; // all partial sums are uplinked
                if vote {
                    let adv = self.adversary.as_ref().expect("vote implies adversary");
                    let audit = adv.fr_attempt_verdicts(&code, &sreal, &mut verdicts);
                    self.adv_log.detected += audit.alarms;
                    self.adv_log.excised += audit.excised;
                    // under detection the best verdict per group wins across
                    // repeats; without it the first delivered copy sticks
                    for (b, &v) in best.iter_mut().zip(verdicts.iter()) {
                        if detect {
                            *b = (*b).max(v);
                        } else if !b.covered() && v != GroupVerdict::Uncovered {
                            *b = v;
                        }
                    }
                }
                // standard-decode shortcut on any single attempt
                let standard = if vote {
                    verdicts.iter().all(|v| v.covered())
                } else {
                    FrCode::all_covered(&covered)
                };
                if standard {
                    let mut sums = self.fr_group_sums(&code, deltas);
                    if vote {
                        let d = self.d;
                        let adv = self.adversary.as_mut().expect("vote implies adversary");
                        for (g, v) in verdicts.iter().enumerate() {
                            if *v == GroupVerdict::Poisoned {
                                adv.corrupt_row_f32(&mut sums[g * d..(g + 1) * d]);
                            }
                        }
                    }
                    let inv = 1.0 / self.m as f32;
                    let mut delta = vec![0.0f32; self.d];
                    for g in 0..code.groups() {
                        for (o, v) in delta.iter_mut().zip(&sums[g * self.d..(g + 1) * self.d]) {
                            *o += v;
                        }
                    }
                    for o in &mut delta {
                        *o *= inv;
                    }
                    return Ok(AggResult {
                        delta: Some(delta),
                        outcome: "standard",
                        k4: self.m,
                        attempts: attempts_used,
                        transmissions: tx,
                        residual: 0.0,
                    });
                }
                FrCode::union_covered(&mut acc, &covered);
            }
            let group_ok: Vec<bool> = if vote {
                best.iter().map(|v| v.covered()).collect()
            } else {
                acc.clone()
            };
            let k4 = code.k4_count(&group_ok);
            if k4 == 0 {
                continue;
            }
            // mean over the covered groups' members (eq. (23) restricted to K₄)
            let mut sums = self.fr_group_sums(&code, deltas);
            if vote {
                let d = self.d;
                let adv = self.adversary.as_mut().expect("vote implies adversary");
                for (g, v) in best.iter().enumerate() {
                    if *v == GroupVerdict::Poisoned {
                        adv.corrupt_row_f32(&mut sums[g * d..(g + 1) * d]);
                    }
                }
            }
            let mut delta = vec![0.0f32; self.d];
            for (g, &c) in group_ok.iter().enumerate() {
                if c {
                    for (o, v) in delta.iter_mut().zip(&sums[g * self.d..(g + 1) * self.d]) {
                        *o += v;
                    }
                }
            }
            let inv = 1.0 / k4 as f32;
            for o in &mut delta {
                *o *= inv;
            }
            let outcome = if k4 == self.m { "full" } else { "partial" };
            return Ok(AggResult {
                delta: Some(delta),
                outcome,
                k4,
                attempts: attempts_used,
                transmissions: tx,
                residual: 0.0,
            });
        }
        Ok(AggResult {
            delta: None,
            outcome: "none",
            k4: 0,
            attempts: attempts_used,
            transmissions: tx,
            residual: 0.0,
        })
    }
}
