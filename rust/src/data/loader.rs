//! Batch loaders: assemble fixed-shape `runtime::Batch`es from shards.

use super::synth::{ImageDataset, TokenDataset};
use crate::runtime::Batch;
use crate::util::rng::Rng;
use std::sync::Arc;

/// A client's shard of an image dataset with epoch-shuffled batching.
#[derive(Clone)]
pub struct ImageShard {
    ds: Arc<ImageDataset>,
    indices: Vec<usize>,
    batch: usize,
    cursor: usize,
    order: Vec<usize>,
    rng: Rng,
}

impl ImageShard {
    pub fn new(ds: Arc<ImageDataset>, indices: Vec<usize>, batch: usize, rng: Rng) -> Self {
        assert!(!indices.is_empty());
        let order: Vec<usize> = (0..indices.len()).collect();
        let mut s = ImageShard { ds, indices, batch, cursor: 0, order, rng };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next batch (wraps with reshuffle; repeats examples when the shard is
    /// smaller than the batch — fixed artifact shapes require full batches).
    pub fn next_batch(&mut self) -> Batch {
        let elems = self.ds.elems;
        let mut x = Vec::with_capacity(self.batch * elems);
        let mut y = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            let idx = self.indices[self.order[self.cursor]];
            self.cursor += 1;
            let (img, label) = self.ds.example(idx);
            x.extend_from_slice(img);
            y.push(label);
        }
        Batch::Image { x, y }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// A client's contiguous slice of the token stream.
#[derive(Clone)]
pub struct TokenShard {
    ds: Arc<TokenDataset>,
    lo: usize,
    hi: usize,
    batch: usize,
    seq: usize,
    rng: Rng,
}

impl TokenShard {
    pub fn new(ds: Arc<TokenDataset>, lo: usize, hi: usize, batch: usize, seq: usize, rng: Rng) -> Self {
        assert!(hi > lo + seq + 1, "token shard too small");
        TokenShard { ds, lo, hi, batch, seq, rng }
    }

    /// Split the stream into `m` contiguous shards.
    pub fn split(
        ds: Arc<TokenDataset>,
        m: usize,
        batch: usize,
        seq: usize,
        rng: &mut Rng,
    ) -> Vec<TokenShard> {
        let per = ds.tokens.len() / m;
        (0..m)
            .map(|i| {
                TokenShard::new(ds.clone(), i * per, (i + 1) * per, batch, seq, rng.split(i as u64))
            })
            .collect()
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut x = Vec::with_capacity(self.batch * self.seq);
        let mut y = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let off = self.rng.range(self.lo, self.hi - self.seq - 1);
            let (cx, cy) = self.ds.window(off, self.seq);
            x.extend_from_slice(cx);
            y.extend_from_slice(cy);
        }
        Batch::Tokens { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batches_have_fixed_shape_and_cycle() {
        let ds = Arc::new(ImageDataset::synth(50, 4, 10, 1.0, &mut Rng::new(1)));
        let mut shard = ImageShard::new(ds.clone(), (0..10).collect(), 8, Rng::new(2));
        for _ in 0..5 {
            match shard.next_batch() {
                Batch::Image { x, y } => {
                    assert_eq!(x.len(), 32);
                    assert_eq!(y.len(), 8);
                    // labels come only from the shard (indices 0..10)
                    for label in y {
                        assert!((0..10).contains(&label));
                    }
                }
                _ => panic!("wrong batch kind"),
            }
        }
    }

    #[test]
    fn small_shard_repeats_examples() {
        let ds = Arc::new(ImageDataset::synth(50, 4, 10, 1.0, &mut Rng::new(1)));
        let mut shard = ImageShard::new(ds, vec![3], 4, Rng::new(2));
        match shard.next_batch() {
            // only example #3 exists; labels are i % 10 -> all 3s
            Batch::Image { y, .. } => assert_eq!(y, vec![3, 3, 3, 3]),
            _ => unreachable!(),
        }
    }

    #[test]
    fn token_shards_are_disjoint_ranges() {
        let ds = Arc::new(TokenDataset::synth(4000, 32, 0.05, &mut Rng::new(3)));
        let shards = TokenShard::split(ds, 4, 2, 16, &mut Rng::new(4));
        assert_eq!(shards.len(), 4);
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.lo, i * 1000);
            assert_eq!(s.hi, (i + 1) * 1000);
        }
        let mut s0 = shards[0].clone();
        match s0.next_batch() {
            Batch::Tokens { x, y } => {
                assert_eq!(x.len(), 32);
                assert_eq!(y.len(), 32);
            }
            _ => panic!(),
        }
    }
}
