//! Synthetic data + non-IID partitioning + batch loading.

pub mod loader;
pub mod partition;
pub mod synth;

pub use loader::{ImageShard, TokenShard};
pub use partition::{label_entropy, partition, Partition};
pub use synth::{class_means, ImageDataset, TokenDataset};
