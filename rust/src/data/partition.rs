//! Non-IID data partitioning across clients (paper §VII):
//! - MNIST-style: each client holds a single class (extreme non-IID);
//! - CIFAR-style: Dirichlet(γ)-sampled class proportions per client
//!   (γ = 0.35 in the paper — moderately non-IID);
//! - IID: uniform shuffle split (baseline / ablations).
//!
//! All partitions are equal-size (the paper assigns equal sample counts).

use super::synth::ImageDataset;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    /// One class per client (requires M == num_classes).
    OneClassPerClient,
    /// Dirichlet(γ) class mixture per client.
    Dirichlet(f64),
    /// Uniform IID split.
    Iid,
}

/// Split `ds` into `m` equal shards of example indices.
pub fn partition(ds: &ImageDataset, m: usize, kind: Partition, rng: &mut Rng) -> Vec<Vec<usize>> {
    let per_client = ds.n / m;
    assert!(per_client > 0, "dataset too small for {m} clients");
    match kind {
        Partition::OneClassPerClient => {
            assert_eq!(
                m, ds.num_classes,
                "one-class-per-client needs M == num_classes"
            );
            (0..m)
                .map(|c| {
                    let mut idx = ds.by_class(c as i32);
                    rng.shuffle(&mut idx);
                    idx.truncate(per_client);
                    assert!(
                        idx.len() == per_client,
                        "class {c} has too few samples for an equal shard"
                    );
                    idx
                })
                .collect()
        }
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..ds.n).collect();
            rng.shuffle(&mut idx);
            (0..m).map(|i| idx[i * per_client..(i + 1) * per_client].to_vec()).collect()
        }
        Partition::Dirichlet(gamma) => {
            // per-class pools
            let mut pools: Vec<Vec<usize>> = (0..ds.num_classes)
                .map(|c| {
                    let mut v = ds.by_class(c as i32);
                    rng.shuffle(&mut v);
                    v
                })
                .collect();
            let mut shards = Vec::with_capacity(m);
            for _ in 0..m {
                let props = rng.dirichlet(gamma, ds.num_classes);
                let mut quota: Vec<usize> =
                    props.iter().map(|p| (p * per_client as f64).floor() as usize).collect();
                // distribute the rounding remainder to the largest proportions
                let mut assigned: usize = quota.iter().sum();
                let mut order: Vec<usize> = (0..ds.num_classes).collect();
                order.sort_by(|&a, &b| props[b].partial_cmp(&props[a]).unwrap());
                let mut oi = 0;
                while assigned < per_client {
                    quota[order[oi % ds.num_classes]] += 1;
                    assigned += 1;
                    oi += 1;
                }
                let mut shard = Vec::with_capacity(per_client);
                for (c, q) in quota.iter().enumerate() {
                    let take = (*q).min(pools[c].len());
                    shard.extend(pools[c].drain(..take));
                }
                // pool exhaustion: fill from whatever classes remain
                while shard.len() < per_client {
                    if let Some(pool) = pools.iter_mut().find(|p| !p.is_empty()) {
                        shard.push(pool.pop().unwrap());
                    } else {
                        break;
                    }
                }
                assert_eq!(shard.len(), per_client, "dataset exhausted during partition");
                shards.push(shard);
            }
            shards
        }
    }
}

/// Shannon entropy (nats) of a shard's label distribution — a non-IID-ness
/// diagnostic used in tests and the data report.
pub fn label_entropy(ds: &ImageDataset, shard: &[usize]) -> f64 {
    let mut counts = vec![0usize; ds.num_classes];
    for &i in shard {
        counts[ds.labels[i] as usize] += 1;
    }
    let n = shard.len() as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize) -> ImageDataset {
        ImageDataset::synth(n, 8, 10, 1.0, &mut Rng::new(1))
    }

    #[test]
    fn one_class_per_client_is_pure() {
        let d = ds(1000);
        let shards = partition(&d, 10, Partition::OneClassPerClient, &mut Rng::new(2));
        assert_eq!(shards.len(), 10);
        for (c, shard) in shards.iter().enumerate() {
            assert_eq!(shard.len(), 100);
            assert!(shard.iter().all(|&i| d.labels[i] == c as i32));
            assert!(label_entropy(&d, shard) < 1e-12);
        }
    }

    #[test]
    fn iid_shards_are_mixed_and_disjoint() {
        let d = ds(1000);
        let shards = partition(&d, 10, Partition::Iid, &mut Rng::new(3));
        let mut seen = std::collections::BTreeSet::new();
        for shard in &shards {
            assert_eq!(shard.len(), 100);
            for &i in shard {
                assert!(seen.insert(i), "index {i} duplicated");
            }
            // IID shard entropy close to ln(10)
            assert!(label_entropy(&d, shard) > 2.0);
        }
    }

    #[test]
    fn dirichlet_is_between_extremes() {
        let d = ds(2000);
        let shards = partition(&d, 10, Partition::Dirichlet(0.35), &mut Rng::new(4));
        let mut seen = std::collections::BTreeSet::new();
        let mut total_entropy = 0.0;
        for shard in &shards {
            assert_eq!(shard.len(), 200);
            for &i in shard {
                assert!(seen.insert(i));
            }
            total_entropy += label_entropy(&d, shard);
        }
        let mean = total_entropy / 10.0;
        // gamma = 0.35: meaningfully skewed but not single-class
        assert!(mean > 0.2 && mean < 2.1, "mean shard entropy {mean}");
    }

    #[test]
    fn dirichlet_entropy_monotone_in_gamma() {
        let d = ds(2000);
        let e_small: f64 = partition(&d, 10, Partition::Dirichlet(0.05), &mut Rng::new(5))
            .iter()
            .map(|s| label_entropy(&d, s))
            .sum();
        let e_large: f64 = partition(&d, 10, Partition::Dirichlet(10.0), &mut Rng::new(5))
            .iter()
            .map(|s| label_entropy(&d, s))
            .sum();
        assert!(e_small < e_large, "{e_small} !< {e_large}");
    }
}
