//! Synthetic datasets (substitution for MNIST / CIFAR-10 downloads — see
//! DESIGN.md §2).
//!
//! The paper's experiments probe *aggregation under unreliable links*, not
//! vision SOTA: what matters is a classification signal whose quality
//! degrades when aggregation is biased or missing, plus non-IID label
//! structure across clients. Class-conditional Gaussian images provide
//! exactly that: class c has a fixed random mean pattern `μ_c`; samples are
//! `x = α·μ_c + ε`. Separability is controlled by `signal`.
//!
//! The LM corpus for the e2e transformer is a noisy cyclic-pattern stream:
//! predictable enough to show a clean loss curve, noisy enough not to be
//! trivially memorized in one step.

use crate::util::rng::Rng;

/// An in-memory labelled image dataset, flattened row-major.
#[derive(Clone, Debug)]
pub struct ImageDataset {
    pub n: usize,
    /// C*H*W per example.
    pub elems: usize,
    pub num_classes: usize,
    /// `n * elems` f32.
    pub images: Vec<f32>,
    /// `n` labels.
    pub labels: Vec<i32>,
}

/// Per-class mean patterns shared by a train/test pair.
pub fn class_means(elems: usize, num_classes: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
    (0..num_classes)
        .map(|_| (0..elems).map(|_| rng.normal()).collect())
        .collect()
}

impl ImageDataset {
    /// Class-conditional Gaussian synthesis with balanced labels.
    pub fn synth(
        n: usize,
        elems: usize,
        num_classes: usize,
        signal: f64,
        rng: &mut Rng,
    ) -> ImageDataset {
        let means = class_means(elems, num_classes, rng);
        Self::synth_with_means(n, &means, signal, rng)
    }

    /// Synthesize from fixed class means (train/test consistency).
    pub fn synth_with_means(
        n: usize,
        means: &[Vec<f64>],
        signal: f64,
        rng: &mut Rng,
    ) -> ImageDataset {
        let num_classes = means.len();
        let elems = means[0].len();
        let mut images = Vec::with_capacity(n * elems);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % num_classes; // balanced
            labels.push(c as i32);
            let mu = &means[c];
            images.extend((0..elems).map(|j| (signal * mu[j] + rng.normal()) as f32));
        }
        ImageDataset { n, elems, num_classes, images, labels }
    }

    pub fn example(&self, i: usize) -> (&[f32], i32) {
        (&self.images[i * self.elems..(i + 1) * self.elems], self.labels[i])
    }

    /// Indices of examples with the given label.
    pub fn by_class(&self, c: i32) -> Vec<usize> {
        (0..self.n).filter(|&i| self.labels[i] == c).collect()
    }
}

/// A token stream for the LM: noisy repetition of per-segment cyclic
/// patterns over the vocabulary.
#[derive(Clone, Debug)]
pub struct TokenDataset {
    pub tokens: Vec<i32>,
    pub vocab: usize,
}

impl TokenDataset {
    pub fn synth(len: usize, vocab: usize, noise: f64, rng: &mut Rng) -> TokenDataset {
        assert!(vocab >= 4);
        let mut tokens = Vec::with_capacity(len);
        // segments of cyclic arithmetic progressions with random stride
        while tokens.len() < len {
            let start = rng.below(vocab);
            let stride = 1 + rng.below(7);
            let seg = 24 + rng.below(40);
            for k in 0..seg {
                if tokens.len() >= len {
                    break;
                }
                let t = if rng.bernoulli(noise) {
                    rng.below(vocab)
                } else {
                    (start + k * stride) % vocab
                };
                tokens.push(t as i32);
            }
        }
        TokenDataset { tokens, vocab }
    }

    /// Slice a (context, target) window pair of length `t` at offset `off`.
    pub fn window(&self, off: usize, t: usize) -> (&[i32], &[i32]) {
        (&self.tokens[off..off + t], &self.tokens[off + 1..off + t + 1])
    }

    pub fn max_offset(&self, t: usize) -> usize {
        self.tokens.len().saturating_sub(t + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_balanced_and_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = ImageDataset::synth(100, 16, 10, 2.0, &mut r1);
        let b = ImageDataset::synth(100, 16, 10, 2.0, &mut r2);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        for c in 0..10 {
            assert_eq!(a.by_class(c).len(), 10);
        }
        let (x, y) = a.example(17);
        assert_eq!(x.len(), 16);
        assert_eq!(y, 7);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-class-mean classifier on held-out samples should beat
        // chance by a wide margin at signal = 2.0
        let mut rng = Rng::new(9);
        let ds = ImageDataset::synth(400, 32, 10, 2.0, &mut rng);
        // estimate class means from the first 200
        let mut means = vec![vec![0.0f64; 32]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..200 {
            let (x, y) = ds.example(i);
            counts[y as usize] += 1;
            for j in 0..32 {
                means[y as usize][j] += x[j] as f64;
            }
        }
        for c in 0..10 {
            for j in 0..32 {
                means[c][j] /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for i in 200..400 {
            let (x, y) = ds.example(i);
            let pred = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = (0..32).map(|j| (x[j] as f64 - means[a][j]).powi(2)).sum();
                    let db: f64 = (0..32).map(|j| (x[j] as f64 - means[b][j]).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred as i32 == y {
                correct += 1;
            }
        }
        assert!(correct > 150, "nearest-mean accuracy {correct}/200");
    }

    #[test]
    fn token_stream_predictable() {
        let mut rng = Rng::new(3);
        let ds = TokenDataset::synth(5000, 64, 0.05, &mut rng);
        assert_eq!(ds.tokens.len(), 5000);
        assert!(ds.tokens.iter().all(|&t| (0..64).contains(&t)));
        let (x, y) = ds.window(100, 32);
        assert_eq!(x.len(), 32);
        assert_eq!(&x[1..], &y[..31]); // shifted by one
        assert!(ds.max_offset(32) == 5000 - 33);
    }
}
