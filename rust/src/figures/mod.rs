//! Figure/table harnesses: regenerate every experimental artifact of the
//! paper's evaluation (§VII) as CSV series — the same rows/curves the paper
//! plots. Shared by the `cogc` CLI and the `cargo bench` targets.
//!
//! The training figures (7/8/10/11/12) take a [`Backend`] — PJRT artifacts
//! or the native pure-rust models — plus a `threads` worker count: their
//! method/network grid fans out over [`parallel_map`], one deterministic
//! training run per cell, merged in grid order so the CSV is byte-identical
//! at every thread count.

use crate::coordinator::{Aggregator, Design, TrainConfig, Trainer};
use crate::gc::GcCode;
use crate::metrics::{RunLog, Table};
use crate::network::Network;
use crate::outage::mc::RecoveryMode;
use crate::outage::theory::{self, Theorem1Params};
use crate::outage::{self, design};
use crate::parallel::{derive_seed, parallel_map, MonteCarlo};
use crate::privacy;
use crate::runtime::Backend;
use crate::scenario::{ChannelModel, Iid, Scenario};
use crate::util::rng::Rng;

/// Fig. 4: overall outage probability `P_O` vs `s` for several network
/// cases (closed form + Monte-Carlo cross-check).
///
/// The MC columns run through the parallel engine with one derived seed per
/// (s, case) cell, so the table is bit-identical for every `threads` value
/// (0 = one worker per core).
pub fn fig4(mc_trials: usize, seed: u64, threads: usize) -> Table {
    fig4_channel(&Iid, mc_trials, seed, threads)
}

/// [`fig4`] under an arbitrary channel model: the MC columns sample `ch`
/// instead of i.i.d. erasures (the closed-form columns stay memoryless — a
/// stateful channel makes the gap between the two *visible*). A
/// degenerately-configured stateful model reproduces the [`Iid`] table
/// byte-for-byte (asserted in `tests/scenario_models.rs`).
pub fn fig4_channel(ch: &dyn ChannelModel, mc_trials: usize, seed: u64, threads: usize) -> Table {
    // (p_m, p_mk) study cases spanning the paper's regimes
    let cases: &[(f64, f64)] = &[(0.1, 0.1), (0.4, 0.25), (0.4, 0.5), (0.75, 0.5), (0.75, 0.8)];
    let mut header: Vec<String> = vec!["s".into()];
    for (pm, pmk) in cases {
        header.push(format!("po_exact_pm{pm}_pmk{pmk}"));
        header.push(format!("po_mc_pm{pm}_pmk{pmk}"));
    }
    let mut t = Table::new(
        "fig4: P_O vs s, M=10 (closed form eq. (11)-(16) + Monte-Carlo)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let m = 10;
    let mut rng = Rng::new(seed);
    for s in 1..m {
        let mut row = vec![s as f64];
        for (case, &(pm, pmk)) in cases.iter().enumerate() {
            let net = Network::homogeneous(m, pm, pmk);
            let code = GcCode::generate(m, s, &mut rng);
            row.push(outage::overall_outage(&net, &code));
            let mc = MonteCarlo::new(derive_seed(seed, (s * 16 + case) as u64))
                .with_threads(threads);
            row.push(outage::estimate_outage(&net, &code, ch, mc_trials, &mc));
        }
        t.rowf(&row);
    }
    t
}

/// Remark 5 case study: the probability that *all* clients fail to collect
/// a complete partial sum at p_mk = 0.4, M = 10, s = 7 (paper: 0.7528).
pub fn remark5() -> Table {
    let mut t = Table::new(
        "remark 5: P(all M clients incomplete) at p_mk=0.4, M=10, s=7 (paper: 0.7528)",
        &["p_mk", "prob_all_incomplete", "overall_outage_pm0.4"],
    );
    let mut rng = Rng::new(5);
    let code = GcCode::generate(10, 7, &mut rng);
    for &pmk in &[0.2, 0.3, 0.4, 0.5] {
        let net = Network::homogeneous(10, 0.4, pmk);
        let q = outage::incomplete_probs(&net, &code);
        let all: f64 = q.iter().product();
        t.rowf(&[pmk, all, outage::overall_outage(&net, &code)]);
    }
    t
}

/// Fig. 6: GC⁺ recovery statistics across the four paper settings
/// (t_r = 2, M = 10, s = 7), in both repetition modes.
///
/// Each (setting, mode) sweep runs through the parallel engine with its own
/// derived seed; the table is bit-identical for every `threads` value.
pub fn fig6(trials: usize, seed: u64, threads: usize) -> Table {
    fig6_channel(&Iid, trials, seed, threads)
}

/// [`fig6`] under an arbitrary channel model (see [`fig4_channel`]).
pub fn fig6_channel(ch: &dyn ChannelModel, trials: usize, seed: u64, threads: usize) -> Table {
    let mut t = Table::new(
        "fig6: GC+ recovery statistics, M=10 s=7 t_r=2\n\
         fixed: exactly t_r attempts (analysis mode)\n\
         until: Algorithm 1 repeat-until-decode (blocks of t_r)",
        &[
            "setting", "p_m", "p_mk", "mode", "p_full", "p_partial", "p_none", "mean_attempts",
        ],
    );
    for setting in 1..=4usize {
        let net = Network::fig6_setting(setting, 10);
        for (mode_idx, (mode, name)) in [
            (RecoveryMode::FixedTr(2), "fixed"),
            (RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 }, "until"),
        ]
        .into_iter()
        .enumerate()
        {
            let mc = MonteCarlo::new(derive_seed(seed, (setting * 8 + mode_idx) as u64))
                .with_threads(threads);
            let st = outage::gcplus_recovery(&net, ch, 10, 7, mode, trials, &mc);
            t.row(&[
                setting.to_string(),
                format!("{}", net.p_c2s[0]),
                format!("{}", net.p_c2c(0, 1)),
                name.to_string(),
                format!("{:.4}", st.p_full()),
                format!("{:.4}", st.p_partial()),
                format!("{:.4}", st.p_none()),
                format!("{:.2}", st.mean_attempts()),
            ]);
        }
    }
    t
}

/// Shared runner: train one configuration and return its log.
pub fn run_training(backend: &Backend, cfg: TrainConfig, net: Network) -> anyhow::Result<RunLog> {
    let mut tr = Trainer::new(backend, cfg, net)?;
    tr.run()
}

/// Run a grid of (config, network) training cells through the worker pool
/// and return the logs tagged by config, in grid order.
fn run_grid(
    backend: &Backend,
    jobs: &[(TrainConfig, Network)],
    threads: usize,
) -> anyhow::Result<Vec<(String, RunLog)>> {
    let results = parallel_map(jobs, threads, |_i, (cfg, net)| {
        run_training(backend, cfg.clone(), net.clone())
    });
    let mut logs = Vec::with_capacity(jobs.len());
    for ((cfg, _), result) in jobs.iter().zip(results) {
        logs.push((cfg.tag(), result?));
    }
    Ok(logs)
}

/// Accuracy-curve comparison table from several runs (columns per method).
fn curves_table(comment: &str, logs: &[(String, RunLog)]) -> Table {
    let mut header = vec!["round".to_string()];
    for (name, _) in logs {
        header.push(format!("acc_{name}"));
        header.push(format!("loss_{name}"));
    }
    let mut t = Table::new(comment, &header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let rounds = logs.iter().map(|(_, l)| l.rounds.len()).max().unwrap_or(0);
    for r in 0..rounds {
        let mut row = vec![r as f64];
        for (_, log) in logs {
            if let Some(rec) = log.rounds.get(r) {
                row.push(rec.test_acc);
                row.push(rec.train_loss);
            } else {
                row.push(f64::NAN);
                row.push(f64::NAN);
            }
        }
        t.rowf(&row);
    }
    t
}

/// Figs. 7 (MNIST) / 8 (CIFAR): ideal FL vs CoGC vs intermittent FL on
/// Networks 1–3 (Fig. 9). The three methods train in parallel.
pub fn fig7_8(
    backend: &Backend,
    model: &str,
    network_idx: usize,
    rounds: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Table> {
    let m = backend.manifest().m;
    let net = Network::paper_network(network_idx, m, seed);
    let jobs: Vec<(TrainConfig, Network)> = [
        Aggregator::Ideal,
        Aggregator::CoGc { design: Design::SkipRound, attempts: 1 },
        Aggregator::Intermittent,
    ]
    .into_iter()
    .map(|agg| {
        let mut cfg = TrainConfig::new(model, agg);
        cfg.rounds = rounds;
        cfg.seed = seed;
        let net_used = if agg == Aggregator::Ideal { Network::perfect(m) } else { net.clone() };
        (cfg, net_used)
    })
    .collect();
    let logs = run_grid(backend, &jobs, threads)?;
    for (tag, log) in &logs {
        crate::info!(
            "{model} net{network_idx} {tag}: final acc {:.3}, {} updates / {} rounds",
            log.final_acc(),
            log.updates(),
            rounds
        );
    }
    Ok(curves_table(
        &format!(
            "fig{}: {model} on paper network {network_idx} (ideal / CoGC / intermittent) \
             [{} backend]",
            if model == "mnist_cnn" { 7 } else { 8 },
            backend.name()
        ),
        &logs,
    ))
}

/// One Fig. 10 variant: train at straggler tolerance `s` until the target
/// accuracy is hit (Design 1, so every round ends in a recovery).
fn fig10_cell(
    backend: &Backend,
    s: usize,
    rounds: usize,
    target_acc: f64,
    seed: u64,
    net: &Network,
) -> anyhow::Result<RunLog> {
    let mut cfg = TrainConfig::new(
        "mnist_cnn",
        Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: 200 },
    );
    cfg.s = s;
    cfg.rounds = rounds;
    cfg.seed = seed;
    let mut trainer = Trainer::new(backend, cfg, net.clone())?;
    trainer.run_until_acc(target_acc)
}

/// Fig. 10: communication cost to reach a target accuracy — regular GC
/// (s = 7) vs the cost-efficient design s* of eq. (21). The two variants
/// train in parallel.
pub fn fig10(
    backend: &Backend,
    rounds: usize,
    target_acc: f64,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Table> {
    let m = backend.manifest().m;
    let net = Network::homogeneous(m, 0.1, 0.1); // the paper's Fig.10 network
    let pick = design::cost_efficient_s(&net, 0.5, seed).ok_or_else(|| {
        anyhow::anyhow!("fig10: no straggler tolerance s meets P_O <= 0.5 on the p=0.1 network")
    })?;
    let mut t = Table::new(
        &format!(
            "fig10: transmissions to reach acc {target_acc} (p=0.1, P_O*=0.5 -> s*={}) \
             [{} backend]",
            pick.s,
            backend.name()
        ),
        &["variant", "s", "rounds_used", "total_transmissions", "final_acc", "reached"],
    );
    // Design 1 (retry-until-success) is the protocol that isolates the
    // communication cost: every round ends in a successful recovery, so
    // both variants see the same optimization trajectory and differ only
    // in transmissions spent per success (paper §V / Fig. 10).
    let variants = [("regular_s7", 7usize), ("cost_efficient", pick.s)];
    let results = parallel_map(&variants, threads, |_i, &(_, s)| {
        fig10_cell(backend, s, rounds, target_acc, seed, &net)
    });
    for (&(variant, s), result) in variants.iter().zip(results) {
        let log = result?;
        let reached = log.rounds_to_acc(target_acc).is_some();
        t.row(&[
            variant.to_string(),
            s.to_string(),
            log.rounds.len().to_string(),
            log.total_transmissions().to_string(),
            format!("{:.4}", log.final_acc()),
            (reached as u8).to_string(),
        ]);
        crate::info!(
            "fig10 {variant}: s={s} tx={} rounds={} reached={reached}",
            log.total_transmissions(),
            log.rounds.len()
        );
    }
    Ok(t)
}

/// Figs. 11 (MNIST) / 12 (CIFAR): ideal / standard GC / GC⁺ / intermittent
/// under poor client→PS links and good/moderate/poor client-to-client
/// links. The four methods train in parallel.
pub fn fig11_12(
    backend: &Backend,
    model: &str,
    conn: &str,
    rounds: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Table> {
    let m = backend.manifest().m;
    let net = Network::conn_tier(conn, m);
    let jobs: Vec<(TrainConfig, Network)> = [
        Aggregator::Ideal,
        Aggregator::CoGc { design: Design::SkipRound, attempts: 2 },
        // Algorithm 1's repeat-until-decode loop (§VI): with poor uplinks a
        // fixed t_r=2 stack sees too few rows to decode anything most
        // rounds; the paper's GC+ curves rely on the `while K4=∅` repeats.
        Aggregator::GcPlus { tr: 2, until_decode: true, max_blocks: 25 },
        Aggregator::Intermittent,
    ]
    .into_iter()
    .map(|agg| {
        let mut cfg = TrainConfig::new(model, agg);
        cfg.rounds = rounds;
        cfg.seed = seed;
        let net_used = if agg == Aggregator::Ideal { Network::perfect(m) } else { net.clone() };
        (cfg, net_used)
    })
    .collect();
    let logs = run_grid(backend, &jobs, threads)?;
    for (tag, log) in &logs {
        crate::info!(
            "{model} conn={conn} {tag}: final acc {:.3}, {} updates",
            log.final_acc(),
            log.updates()
        );
    }
    Ok(curves_table(
        &format!(
            "fig{}: {model}, poor client-to-PS (p=0.75), {conn} client-to-client \
             [{} backend]",
            if model == "mnist_cnn" { 11 } else { 12 },
            backend.name()
        ),
        &logs,
    ))
}

/// Theorem 1 / Lemma 5 numerics: ε(P_O) and K* sweeps.
pub fn theory_table() -> Table {
    let mut t = Table::new(
        "theory: Theorem-1 bound eps(P_O) (T=1e7, M=10, I=5) and Lemma-5 K* (t_r sweep, p=0.3)",
        &["p_o", "epsilon", "mu_j1", "mu_j2", "expected_rounds", "k_star_tr4", "k_star_tr8"],
    );
    for &po in &[0.05, 0.1, 0.2, 0.4, 0.6, 0.8, 0.9] {
        let p = Theorem1Params {
            m: 10,
            t: 10_000_000,
            i: 5,
            p_o: po,
            p_c2s: vec![0.3; 10],
            sigma2: 1.0,
            d2: vec![1.0; 10],
            f_gap: 10.0,
        };
        let b = theory::theorem1_bound(&p);
        t.rowf(&[
            po,
            if b.valid { b.epsilon } else { f64::NAN },
            b.mu_j1,
            b.mu_j2,
            theory::expected_rounds_between_success(po),
            theory::k_star(10, 7, 4, 0.3, po),
            theory::k_star(10, 7, 8, 0.3, po),
        ]);
    }
    t
}

/// Lemma 1 privacy: worst-case LMIP leakage of a complete partial sum vs s,
/// with and without the Gaussian mechanism.
pub fn privacy_table(d: usize) -> anyhow::Result<Table> {
    let mut t = Table::new(
        &format!("privacy: worst-case CD-LMIP bits of a complete partial sum (d={d})"),
        &["s", "mu_bits", "mu_bits_per_dim", "mu_bits_gauss_sigma1"],
    );
    let mut rng = Rng::new(11);
    for s in 1..10usize {
        let code = GcCode::generate(10, s, &mut rng);
        let vars = vec![1.0; 10];
        let mu = (0..10)
            .map(|r| privacy::row_worst_leakage(&code, r, &vars, d))
            .fold(0.0, f64::max);
        // Gaussian mechanism at sigma_dp^2 = 1
        let coeffs: Vec<f64> = (0..10).map(|k| code.b[(0, k)]).collect();
        let target = (0..10).find(|&k| coeffs[k] != 0.0).ok_or_else(|| {
            anyhow::anyhow!("privacy: generated code row 0 is all-zero at s={s}")
        })?;
        let mu_g = privacy::lmip_with_gaussian_mechanism(&coeffs, &vars, target, d, 1.0);
        t.rowf(&[s as f64, mu, mu / d as f64, mu_g]);
    }
    Ok(t)
}

/// Cost-efficient design sweep (§V): P_O(s), expected transmissions, s*,
/// plus a Monte-Carlo cross-check column (`p_o_mc`) computed through the
/// parallel engine (`mc_trials` rounds per sweep point).
pub fn design_table(p: f64, target_po: f64, seed: u64, mc_trials: usize, threads: usize) -> Table {
    let net = Network::homogeneous(10, p, p);
    let mut t = Table::new(
        &format!(
            "design: cost-efficient GC on homogeneous p={p} (target P_O* = {target_po}, \
             mc cross-check over {mc_trials} rounds/point)"
        ),
        &["s", "p_o", "p_o_mc", "tx_per_round", "expected_rounds", "tx_per_success", "is_s_star"],
    );
    let pick = design::cost_efficient_s(&net, target_po, seed);
    let mc = design::sweep_mc(&net, seed, mc_trials, threads);
    for (d, po_mc) in design::sweep(&net, seed).into_iter().zip(mc) {
        t.rowf(&[
            d.s as f64,
            d.p_o,
            po_mc,
            d.tx_per_round,
            d.expected_rounds,
            d.tx_per_success,
            pick.as_ref().map_or(0.0, |p| (p.s == d.s) as u8 as f64),
        ]);
    }
    t
}

/// Scenario sweep (`cogc scenario run <name>`): the per-round time series
/// of a [`Scenario`] over `trials` independent episodes — outage rate and
/// the GC⁺ standard/full/partial/none split, mean transmissions per round,
/// the fraction of link-attempts in the degraded channel condition (burst
/// statistics), and the deadline hit-rate. `wall_clock` is the nominal
/// elapsed time assuming every communication attempt consumes one channel
/// round-duration window (the deadline for straggler models, 1 otherwise),
/// making wall-clock-to-decode a first-class series. Bit-identical for
/// every `threads` value.
pub fn scenario_sweep(sc: &Scenario, trials: usize, seed: u64, threads: usize) -> Table {
    let mc = MonteCarlo::new(derive_seed(seed, 0x5CE9_A810)).with_threads(threads);
    let series = crate::scenario::run_scenario(sc, trials, &mc);
    let attempts_per_round = match sc.decoder {
        crate::sim::Decoder::Standard { attempts } => attempts.max(1),
        crate::sim::Decoder::GcPlus { tr } | crate::sim::Decoder::Approx { tr } => tr.max(1),
    };
    let window = sc.channel.build().round_duration() * attempts_per_round as f64;
    // non-default code families are flagged in the comment; cyclic output
    // stays byte-identical to before the family abstraction existed
    let code_tag = match sc.code {
        crate::gc::CodeFamily::Cyclic => String::new(),
        family => format!(" code={}", family.name()),
    };
    // adversarial scenarios grow five integrity columns and a comment tag;
    // clean scenarios stay byte-identical to before the adversary
    // dimension existed
    let adv_tag = match &sc.adversary {
        None => String::new(),
        Some(spec) => format!(" adversary={}", spec.summary()),
    };
    // degraded-mode scenarios (approximate decoder, or a recovery policy
    // with the exact→approx fallback armed) grow the approx-acceptance
    // column plus the relative-residual histogram; active policies grow the
    // retransmission/fault accounting. Plain scenarios keep the exact
    // pre-existing column set, byte-identical.
    let degraded = matches!(sc.decoder, crate::sim::Decoder::Approx { .. })
        || sc.policy.as_ref().is_some_and(|p| p.fallback);
    let policied = sc.policy.as_ref().is_some_and(|p| !p.is_passive());
    let policy_tag = match &sc.policy {
        Some(p) if !p.is_passive() => format!(" {}", p.summary()),
        _ => String::new(),
    };
    let mut header = vec![
        "round",
        "wall_clock",
        "p_update",
        "p_standard",
        "p_full",
        "p_partial",
        "p_none",
        "mean_tx",
        "degraded_frac",
        "deadline_hit_rate",
    ];
    if sc.adversary.is_some() {
        header.extend([
            "p_corrupted",
            "p_detected",
            "p_poisoned",
            "mean_excised",
            "mean_false_excised",
        ]);
    }
    if degraded {
        header.push("p_approx");
        header.extend([
            "resid_b0", "resid_b1", "resid_b2", "resid_b3", "resid_b4", "resid_b5", "resid_b6",
            "resid_b7",
        ]);
    }
    if policied {
        header.extend(["mean_retries", "mean_recovered", "mean_budget_exhausted", "mean_killed"]);
    }
    // armed telemetry appends the GC⁺ peel/forward split per round; clean
    // (disarmed) CSVs stay byte-identical — the determinism contract of
    // `tests/telemetry.rs`
    let armed = crate::telemetry::armed();
    if armed {
        header.extend(["mean_peeled", "mean_forwarded"]);
    }
    let mut t = Table::new(
        &format!(
            "scenario {}: {}\nchannel={} net={} decoder={:?} s={}{code_tag}{adv_tag}{policy_tag} trials={trials}",
            sc.name,
            sc.description,
            sc.channel.name(),
            sc.net.summary(),
            sc.decoder,
            sc.s
        ),
        &header,
    );
    for (r, tally) in series.rounds.iter().enumerate() {
        let n = tally.trials.max(1) as f64;
        let mut row = vec![
            r as f64,
            (r + 1) as f64 * window,
            tally.p_update(),
            tally.standard as f64 / n,
            tally.full as f64 / n,
            tally.partial as f64 / n,
            tally.none as f64 / n,
            tally.transmissions as f64 / n,
            tally.channel.degraded_frac(),
            tally.channel.deadline_hit_rate(),
        ];
        if sc.adversary.is_some() {
            row.extend([
                tally.corrupted as f64 / n,
                tally.p_detected(),
                tally.p_poisoned(),
                tally.excised as f64 / n,
                tally.false_excised as f64 / n,
            ]);
        }
        if degraded {
            row.push(tally.approx as f64 / n);
            row.extend(tally.residual_hist.iter().map(|&c| c as f64 / n));
        }
        if policied {
            row.extend([
                tally.retries as f64 / n,
                tally.recovered as f64 / n,
                tally.budget_exhausted as f64 / n,
                tally.killed as f64 / n,
            ]);
        }
        if armed {
            row.extend([tally.peeled as f64 / n, tally.forwarded as f64 / n]);
        }
        t.rowf(&row);
    }
    t
}

/// Error-vs-communication-budget sweep across the scenario registry: every
/// clean (non-adversarial) built-in scenario is re-run under three decode
/// regimes — exact GC⁺, the least-squares approximate decoder, and exact
/// GC⁺ under a bounded-retransmission policy with the exact→approx
/// fallback armed — and each regime's update-miss rate is tabled against
/// the communication it spent (transmissions per round, retransmissions
/// included). Each (scenario, regime) cell runs on its own derived seed,
/// so the table is bit-identical at every `threads` value.
pub fn error_vs_budget(trials: usize, seed: u64, threads: usize) -> Table {
    use crate::scenario::RecoveryPolicy;
    use crate::sim::Decoder;
    let mut t = Table::new(
        "error_vs_budget: update-miss rate vs communication spend per decode regime\n\
         exact: GC+ only | approx: least-squares fallback accepted at any residual |\n\
         retry_approx: 2 bounded retransmits (backoff 2.0) then approx at rel-residual <= 0.5",
        &[
            "scenario",
            "regime",
            "p_update",
            "p_exact",
            "p_approx",
            "p_miss",
            "tx_per_round",
            "retries_per_round",
        ],
    );
    let retry_policy = RecoveryPolicy {
        retries: 2,
        backoff: 2.0,
        deadline: 6.0,
        fallback: true,
        fallback_residual: 0.5,
        ..RecoveryPolicy::default()
    };
    for (si, base) in crate::scenario::builtin().into_iter().enumerate() {
        // the degraded pipeline needs a dense clean realization: skip
        // adversarial scenarios and the sparse fr family
        if base.adversary.is_some()
            || base.code == crate::gc::CodeFamily::FractionalRepetition
        {
            continue;
        }
        let tr = match base.decoder {
            Decoder::Standard { attempts } => attempts.max(1),
            Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr.max(1),
        };
        let regimes: [(&str, Decoder, Option<RecoveryPolicy>); 3] = [
            ("exact", Decoder::GcPlus { tr }, None),
            ("approx", Decoder::Approx { tr }, None),
            ("retry_approx", Decoder::GcPlus { tr }, Some(retry_policy.clone())),
        ];
        for (ri, (regime, decoder, policy)) in regimes.into_iter().enumerate() {
            let mut sc = base.clone();
            sc.decoder = decoder;
            sc.policy = policy;
            let mc = MonteCarlo::new(derive_seed(seed, (si * 8 + ri) as u64))
                .with_threads(threads);
            let series = crate::scenario::run_scenario(&sc, trials, &mc);
            let (mut n, mut exact, mut approx, mut none, mut tx, mut retries) =
                (0usize, 0usize, 0usize, 0usize, 0usize, 0usize);
            for tally in &series.rounds {
                n += tally.trials;
                exact += tally.standard + tally.full + tally.partial;
                approx += tally.approx;
                none += tally.none;
                tx += tally.transmissions;
                retries += tally.retries;
            }
            let n = n.max(1) as f64;
            let rounds = series.rounds.len().max(1) as f64;
            let per_round = trials.max(1) as f64;
            t.row(&[
                base.name.clone(),
                regime.to_string(),
                format!("{:.4}", (exact + approx) as f64 / n),
                format!("{:.4}", exact as f64 / n),
                format!("{:.4}", approx as f64 / n),
                format!("{:.4}", none as f64 / n),
                format!("{:.2}", tx as f64 / (rounds * per_round)),
                format!("{:.3}", retries as f64 / (rounds * per_round)),
            ]);
        }
    }
    t
}

/// The 2×2 recovery × integrity split of an adversarial scenario: one
/// coded attempt per trial, classified clean-decode / poisoned-decode /
/// outage. `cogc scenario run` prints this to stderr next to the
/// per-round CSV when the scenario carries an adversary.
pub fn outage_split_summary(
    sc: &Scenario,
    trials: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<String> {
    let spec = sc
        .adversary
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("scenario {:?} has no adversary", sc.name))?;
    let net = sc.net.build();
    let ch = sc.channel.build();
    let mc = MonteCarlo::new(derive_seed(seed, 0x0B5_A11D)).with_threads(threads);
    let split = match sc.code {
        crate::gc::CodeFamily::Cyclic => {
            let code = GcCode::generate(net.m, sc.s, &mut Rng::new(seed));
            outage::estimate_outage_adv(&net, &code, ch.as_ref(), spec, trials, &mc)
        }
        crate::gc::CodeFamily::FractionalRepetition => {
            let code = crate::gc::FrCode::new(net.m, sc.s)?;
            outage::estimate_outage_fr_adv(&net, &code, ch.as_ref(), spec, trials, &mc)
        }
        crate::gc::CodeFamily::Binary => {
            let code = crate::gc::BinaryCode::new(net.m, sc.s)?;
            outage::estimate_outage_binary_adv(&net, code, ch.as_ref(), spec, trials, &mc)
        }
    };
    let n = split.trials.max(1) as f64;
    Ok(format!(
        "recovery x integrity split ({} single-attempt trials): \
         clean-decode {:.4} | poisoned-decode {:.4} | outage {:.4}",
        split.trials,
        split.decoded_clean as f64 / n,
        split.decoded_poisoned as f64 / n,
        split.p_outage(),
    ))
}

/// Detection operating characteristic: audit detection / poisoning /
/// false-excision rates as the attack strategy and malicious fraction
/// sweep, through the GC⁺ adversarial recovery estimator at the Fig. 6
/// geometry (M=10, s=7, setting-2 network, repeat-until-decode t_r=2).
/// Each (attack, fraction) cell runs on its own derived seed, so the table
/// is bit-identical at every `threads` value.
pub fn detection_roc(trials: usize, seed: u64, threads: usize) -> Table {
    use crate::scenario::{AdversarySpec, Attack};
    let m = 10;
    let s = 7;
    let net = Network::fig6_setting(2, m);
    let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 25 };
    let attacks: &[(&str, Attack)] = &[
        ("sign_flip", Attack::SignFlip),
        ("noise", Attack::Noise { sigma: 1.0 }),
        ("replace", Attack::Replace { scale: 5.0 }),
        ("collude", Attack::Collude { scale: 1.0 }),
    ];
    let mut t = Table::new(
        "detection_roc: GC+ decode-path audit vs attack strategy and malicious fraction\n\
         M=10 s=7 fig6-setting-2 network, repeat-until-decode t_r=2",
        &[
            "attack",
            "fraction",
            "p_corrupted",
            "p_detected",
            "p_poisoned",
            "p_full",
            "excised_per_trial",
            "false_excised_per_trial",
        ],
    );
    for (ai, &(name, attack)) in attacks.iter().enumerate() {
        for (fi, &frac) in [0.1, 0.2, 0.3, 0.4].iter().enumerate() {
            let spec = AdversarySpec::fraction(attack, frac);
            let mc =
                MonteCarlo::new(derive_seed(seed, (ai * 16 + fi) as u64)).with_threads(threads);
            let st = outage::gcplus_recovery_adv(&net, &Iid, &spec, m, s, mode, trials, &mc);
            let n = trials.max(1) as f64;
            t.row(&[
                name.to_string(),
                format!("{frac}"),
                format!("{:.4}", st.corrupted as f64 / n),
                format!("{:.4}", st.p_detected()),
                format!("{:.4}", st.p_poisoned()),
                format!("{:.4}", st.p_full()),
                format!("{:.4}", st.excised as f64 / n),
                format!("{:.4}", st.false_excised as f64 / n),
            ]);
        }
    }
    t
}

/// Convergence under attack: the same GC⁺ training configuration run
/// clean, attacked with the audit disabled, and attacked with the
/// decode-path audit on. All three cells share `cfg.tag()`, so the column
/// labels are explicit. The three runs train in parallel.
pub fn convergence_under_attack(
    backend: &Backend,
    model: &str,
    conn: &str,
    attack_fraction: f64,
    rounds: usize,
    seed: u64,
    threads: usize,
) -> anyhow::Result<Table> {
    use crate::scenario::{AdversarySpec, Attack};
    let m = backend.manifest().m;
    let net = Network::conn_tier(conn, m);
    let agg = Aggregator::GcPlus { tr: 2, until_decode: true, max_blocks: 25 };
    let mut attacked = AdversarySpec::fraction(Attack::SignFlip, attack_fraction);
    attacked.detect = false;
    let defended = AdversarySpec::fraction(Attack::SignFlip, attack_fraction);
    let cells: Vec<(&str, Option<AdversarySpec>)> =
        vec![("clean", None), ("attacked", Some(attacked)), ("defended", Some(defended))];
    let jobs: Vec<(TrainConfig, Network)> = cells
        .iter()
        .map(|(_, adv)| {
            let mut cfg = TrainConfig::new(model, agg);
            cfg.rounds = rounds;
            cfg.seed = seed;
            cfg.adversary = adv.clone();
            (cfg, net.clone())
        })
        .collect();
    let results = parallel_map(&jobs, threads, |_i, (cfg, net)| {
        run_training(backend, cfg.clone(), net.clone())
    });
    let mut logs = Vec::with_capacity(jobs.len());
    for ((label, _), result) in cells.iter().zip(results) {
        logs.push((label.to_string(), result?));
    }
    for (label, log) in &logs {
        crate::info!(
            "{model} conn={conn} {label}: final acc {:.3}, {} updates",
            log.final_acc(),
            log.updates()
        );
    }
    Ok(curves_table(
        &format!(
            "convergence_under_attack: {model}, GC+ t_r=2, {conn} client-to-client links, \
             sign-flip fraction {attack_fraction} (clean / attacked no-detect / attacked+audit) \
             [{} backend]",
            backend.name()
        ),
        &logs,
    ))
}

/// The `cogc scenario list` catalog table.
pub fn scenario_catalog() -> Table {
    let mut t = Table::new(
        "scenario catalog (run with `cogc scenario run <name>`)",
        &["name", "channel", "network", "decoder", "s", "rounds", "description"],
    );
    for sc in crate::scenario::builtin() {
        t.row(&[
            sc.name.clone(),
            sc.channel.name().to_string(),
            sc.net.summary(),
            format!("{:?}", sc.decoder),
            sc.s.to_string(),
            sc.rounds.to_string(),
            sc.description.clone(),
        ]);
    }
    t
}

/// Train a single configuration from the CLI (`cogc train ...`).
#[allow(clippy::too_many_arguments)]
pub fn train_once(
    backend: &Backend,
    model: &str,
    agg: Aggregator,
    net: Network,
    rounds: usize,
    seed: u64,
    combine: crate::runtime::CombineImpl,
    channel: crate::scenario::ChannelSpec,
    code: crate::gc::CodeFamily,
    s: usize,
    adversary: Option<crate::scenario::AdversarySpec>,
) -> anyhow::Result<(RunLog, crate::coordinator::TrainAdvLog)> {
    let mut cfg = TrainConfig::new(model, agg);
    cfg.rounds = rounds;
    cfg.seed = seed;
    cfg.combine = combine;
    cfg.channel = channel;
    cfg.code = code;
    cfg.s = s;
    cfg.adversary = adversary;
    let mut tr = Trainer::new(backend, cfg, net)?;
    let log = tr.run()?;
    let adv_log = tr.adv_log.clone();
    Ok((log, adv_log))
}
