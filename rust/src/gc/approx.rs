//! Degraded-mode approximate aggregation: when the complementary decoder
//! reports the gradient-sum row unreachable (`K₄ = ∅` and no standard
//! decode), the delivered coded rows still pin the *closest* reachable
//! combination. This module bridges the GC⁺ decoder state to the
//! least-squares solver in [`crate::linalg::lstsq`] and standardizes the
//! diagnostics (relative residual, residual buckets) the sweep/outage/
//! trainer layers report upstream.
//!
//! The naming is deliberate: [`crate::gc::gcplus::decode_approx`] is the
//! paper's Algorithm 2 (an *exact* decode over a full-rank block); the
//! functions here are the lossy fallback and always carry a residual.

use crate::gc::GcPlusDecoder;
use crate::linalg::{lstsq_ones, Lstsq};

/// Number of relative-residual buckets reported by sweeps and figures.
pub const RESIDUAL_BUCKETS: usize = 8;

/// Optimal least-squares weights for the gradient-*sum* target (`𝟙ᵀ·G`)
/// over everything pushed into the decoder so far. `None` when the Gram
/// solve is numerically degenerate — callers treat that as a true outage.
pub fn approx_sum(dec: &GcPlusDecoder) -> Option<Lstsq> {
    lstsq_ones(dec.engine())
}

/// Relative residual `‖𝟙 − w·A‖ / ‖𝟙‖ = residual / √M` — 0 means the
/// exact decoder would also have succeeded, 1 means nothing was recovered.
pub fn relative_residual(sol: &Lstsq, m: usize) -> f64 {
    if m == 0 {
        return 0.0;
    }
    sol.residual / (m as f64).sqrt()
}

/// Fixed bucketing of the relative residual for associative histograms:
/// bucket 0 is "exact to rounding", the top bucket is "recovered almost
/// nothing". Thresholds are constants so tallies merge bit-identically at
/// any thread count.
pub fn residual_bucket(rel: f64) -> usize {
    const EDGES: [f64; RESIDUAL_BUCKETS - 1] =
        [1e-6, 0.01, 0.05, 0.1, 0.25, 0.5, 0.75];
    EDGES.iter().position(|&e| rel < e).unwrap_or(RESIDUAL_BUCKETS - 1)
}

/// Combine stacked payload rows with least-squares weights into the
/// approximate gradient *mean*: `(Σ wᵢ · rowᵢ) / M`. Rows are in stack
/// (push) order, matching `sol.weights`.
pub fn combine_mean(weights: &[f64], rows: &[Vec<f64>], m: usize, out: &mut Vec<f64>) {
    assert_eq!(weights.len(), rows.len(), "approx combine arity mismatch");
    let dim = rows.first().map_or(0, |r| r.len());
    out.clear();
    out.resize(dim, 0.0);
    for (w, row) in weights.iter().zip(rows) {
        if *w == 0.0 {
            continue;
        }
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += w * v;
        }
    }
    let inv = 1.0 / m as f64;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::{Attempt, GcCode};
    use crate::linalg::Matrix;
    use crate::network::Realization;
    use crate::util::rng::Rng;

    #[test]
    fn full_rank_stack_matches_exact_gcplus_decode() {
        // ISSUE acceptance: on a full-rank delivery the approx weights
        // reproduce the exact decode against the dense oracle at M ≤ 12.
        let mut rng = Rng::new(5);
        for m in [3usize, 6, 9, 12] {
            let s = (m / 2).max(1);
            let mut dec = GcPlusDecoder::new(m);
            let mut stack = Matrix::zeros(0, m);
            while dec.rank() < m {
                let code = GcCode::generate(m, s, &mut rng);
                let att = Attempt::observe(&code, &Realization::perfect(m));
                for &r in &att.delivered {
                    dec.push_row(att.perturbed.row(r));
                    stack.push_row(att.perturbed.row(r));
                }
            }
            let sol = approx_sum(&dec).expect("full-rank gram must solve");
            assert!(sol.residual < 1e-8, "m={m} residual {}", sol.residual);
            assert_eq!(sol.covered, m);
            assert_eq!(residual_bucket(relative_residual(&sol, m)), 0);
            // w·A must be the all-ones row the exact decoder reaches
            for j in 0..m {
                let got: f64 = sol
                    .weights
                    .iter()
                    .enumerate()
                    .map(|(i, &w)| w * stack.row(i)[j])
                    .sum();
                assert!((got - 1.0).abs() < 1e-8, "m={m} col {j}: {got}");
            }
        }
    }

    #[test]
    fn empty_decoder_reports_total_loss() {
        let dec = GcPlusDecoder::new(6);
        let sol = approx_sum(&dec).unwrap();
        assert_eq!(sol.covered, 0);
        let rel = relative_residual(&sol, 6);
        assert!((rel - 1.0).abs() < 1e-12, "rel {rel}");
        assert_eq!(residual_bucket(rel), RESIDUAL_BUCKETS - 1);
    }

    #[test]
    fn residual_buckets_are_monotone_and_in_range() {
        let mut prev = 0;
        for i in 0..=100 {
            let rel = i as f64 / 100.0;
            let b = residual_bucket(rel);
            assert!(b < RESIDUAL_BUCKETS);
            assert!(b >= prev, "bucket not monotone at rel={rel}");
            prev = b;
        }
        assert_eq!(residual_bucket(0.0), 0);
        assert_eq!(residual_bucket(2.0), RESIDUAL_BUCKETS - 1);
    }

    #[test]
    fn combine_mean_weights_payload_rows() {
        let rows = vec![vec![2.0, 4.0], vec![1.0, -1.0]];
        let mut out = Vec::new();
        combine_mean(&[0.5, 1.0], &rows, 2, &mut out);
        assert_eq!(out, vec![1.0, 0.5]);
    }
}
