//! The {±1}-valued binary gradient-code family and its exact integer
//! decode engine.
//!
//! *Numerically Stable Binary Gradient Coding* (PAPERS.md) observes that
//! gradient codes over {−1, +1} decode in integer arithmetic: no pivot
//! floors, no residue flushing, no rounding — a row is dependent iff it is
//! *exactly* dependent. [`BinaryCode`] realizes that idea on the cyclic
//! support of the paper's construction:
//!
//! - row `r` covers blocks `{r, r+1, …, r+s} mod M` (the same support as
//!   the dense cyclic family, so the c2c traffic pattern is identical);
//! - the coefficient at offset `t` is `(−1)^t` — `+1` on the client's own
//!   diagonal, alternating outward.
//!
//! `s` must be **even**: each row then has `s+1` (odd) alternating terms
//! summing to exactly `+1`, so the all-ones combinator decodes a fully
//! delivered round and `𝟙` lies in the row span. (Odd `s` makes every row
//! sum to `0`, putting `𝟙` outside the span — the family would never
//! decode.) Unlike the random cyclic family, a ±1 code cannot promise the
//! any-(M−s)-rows identity (e.g. M = 3, s = 2: rows sum pairwise to rank-
//! deficient stacks for some erasure patterns), so both decode paths here
//! *test* solvability exactly instead of assuming it — the same
//! family-specific-semantics precedent the FR family set.
//!
//! The decode engine is [`IntRref`]: an incremental reduced-row-echelon
//! form over exact rationals (one `i128` denominator per stored row,
//! `i128` numerators, gcd-reduced after every update). Its push/query
//! surface mirrors the float engine's, but membership decisions compare
//! integers with zero — this file contains no floating-point comparison
//! machinery at all, which `tests/binary_family.rs` pins at the source
//! level. Floats appear only at the extraction boundary, where exact
//! rational weights are rounded once into `f64` for the payload combine.
//!
//! The dense float mirror ([`BinaryCode::dense_b`] +
//! [`BinaryCode::to_gc_code`]) feeds the generic float pipeline (attempt
//! observation, peeling/RREF, the small-M oracle tests); the exact paths
//! here are the production decode for `--code binary`.

use crate::gc::codes::GcCode;
use crate::gc::family::CodeFamily;
use crate::linalg::Matrix;

/// Deterministic {±1} cyclic-support gradient code. Fully determined by
/// (M, s) — no RNG, no stored matrix; every accessor is O(1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BinaryCode {
    pub m: usize,
    pub s: usize,
}

impl BinaryCode {
    pub fn new(m: usize, s: usize) -> anyhow::Result<BinaryCode> {
        CodeFamily::Binary.validate(m, s)?;
        Ok(BinaryCode { m, s })
    }

    /// Integer coefficient `B[i][j] ∈ {−1, 0, +1}`.
    #[inline]
    pub fn coeff(&self, i: usize, j: usize) -> i64 {
        let t = (j + self.m - i) % self.m;
        if t > self.s {
            0
        } else if t % 2 == 0 {
            1
        } else {
            -1
        }
    }

    /// Support of row `r` in coverage order, `(block, coefficient)` pairs.
    pub fn support_iter(&self, r: usize) -> impl Iterator<Item = (usize, i64)> + '_ {
        let m = self.m;
        (0..=self.s).map(move |t| ((r + t) % m, if t % 2 == 0 { 1 } else { -1 }))
    }

    /// Write row `r` as integers into `buf` (length M, zero-filled first).
    pub fn int_row_into(&self, r: usize, buf: &mut Vec<i64>) {
        buf.clear();
        buf.resize(self.m, 0);
        for (j, c) in self.support_iter(r) {
            buf[j] = c;
        }
    }

    /// Dense float mirror of the allocation matrix — the small-M oracle
    /// and the bridge into the generic attempt/observation pipeline.
    pub fn dense_b(&self) -> Matrix {
        Matrix::from_fn(self.m, self.m, |i, j| self.coeff(i, j) as f64)
    }

    /// Bridge into the generic [`GcCode`] container (same `m`, `s`, and
    /// cyclic support, so `incoming_iter`/completeness logic applies
    /// unchanged). The parity block `h` is left empty — it only feeds the
    /// cyclic construction's structural diagnostic, never a decode path.
    pub fn to_gc_code(&self) -> GcCode {
        GcCode { m: self.m, s: self.s, b: self.dense_b(), h: Matrix::zeros(0, self.m) }
    }

    /// Exact standard-GC decode: combinator weights `a` with
    /// `Σ a_f · B[rows[f]] = 𝟙`, or `None` when the received complete rows
    /// cannot reproduce the all-ones vector. Solved over the rationals —
    /// a pattern either decodes or it does not, with no tolerance band.
    pub fn combinator_weights(&self, rows: &[usize]) -> Option<Vec<f64>> {
        if rows.len() < self.m - self.s {
            // the standard decoder's protocol threshold, mirroring the
            // float path's `find_combinator_rows`
            return None;
        }
        // unknowns: one weight per received row; equations: one per block,
        // augmented with the all-ones right-hand side
        let n = rows.len();
        let mut eng = IntRref::new(n + 1);
        let mut eq: Vec<i64> = Vec::with_capacity(n + 1);
        for j in 0..self.m {
            eq.clear();
            eq.extend(rows.iter().map(|&r| self.coeff(r, j)));
            eq.push(1);
            eng.push_row(&eq);
        }
        eng.solve_augmented(n)
    }
}

/// Greatest common divisor of two non-negative i128 values.
fn gcd(mut a: i128, mut b: i128) -> i128 {
    while b != 0 {
        let r = a % b;
        a = b;
        b = r;
    }
    a
}

/// Divide a rational row (numerators + denominator) by its content so the
/// entries stay small across eliminations.
fn reduce_row(nums: &mut [i128], more: &mut [i128], den: &mut i128) {
    let mut g = den.abs();
    for &x in nums.iter().chain(more.iter()) {
        if g == 1 {
            break;
        }
        g = gcd(g, x.abs());
    }
    if g > 1 {
        for x in nums.iter_mut().chain(more.iter_mut()) {
            *x /= g;
        }
        *den /= g;
    }
    if *den < 0 {
        for x in nums.iter_mut().chain(more.iter_mut()) {
            *x = -*x;
        }
        *den = -*den;
    }
}

fn mul(a: i128, b: i128) -> i128 {
    a.checked_mul(b).expect("IntRref overflow: stack exceeds exact i128 range")
}

fn fused(a: i128, da: i128, b: i128, f: i128) -> i128 {
    // a·da − b·f, checked
    mul(a, da).checked_sub(mul(b, f)).expect("IntRref overflow: stack exceeds exact i128 range")
}

/// Incremental reduced row-echelon form over exact rationals.
///
/// Stored row `i` represents the rational row `e[i][·] / den[i]`
/// (`den[i] > 0`, gcd-reduced, pivot entry equal to `den[i]` so the pivot
/// value is exactly 1); `t[i] / den[i]` is its transform over the pushed
/// rows. The push algorithm is the integer mirror of the float engine's:
/// reduce against stored pivots in creation order, pivot on the leftmost
/// **non-zero** entry (exactness makes a pivot floor meaningless), then
/// eliminate the new column from the store. Dependence and decodability
/// are integer-zero tests, so the engine's verdicts are exact for any
/// input the `i128` range can hold (the ±1 decode stacks sit far inside
/// it; overflow panics rather than mis-decoding).
pub struct IntRref {
    cols: usize,
    rows_seen: usize,
    rank: usize,
    pivots: Vec<Option<usize>>,
    row_cols: Vec<usize>,
    /// Stored numerator rows of E, width `cols`.
    e: Vec<Vec<i128>>,
    /// Stored numerator transform rows, width `rows_seen`.
    t: Vec<Vec<i128>>,
    /// Per-row positive denominator.
    den: Vec<i128>,
    /// Null-space transform of the latest dependent push (numerators).
    null_t: Vec<i128>,
    null_den: i128,
}

impl IntRref {
    pub fn new(cols: usize) -> IntRref {
        IntRref {
            cols,
            rows_seen: 0,
            rank: 0,
            pivots: vec![None; cols],
            row_cols: Vec::new(),
            e: Vec::new(),
            t: Vec::new(),
            den: Vec::new(),
            null_t: Vec::new(),
            null_den: 1,
        }
    }

    /// Clear all state for a fresh stream of `cols`-wide rows, keeping
    /// allocations.
    pub fn reset(&mut self, cols: usize) {
        self.cols = cols;
        self.rows_seen = 0;
        self.rank = 0;
        self.pivots.clear();
        self.pivots.resize(cols, None);
        self.row_cols.clear();
        self.e.clear();
        self.t.clear();
        self.den.clear();
        self.null_t.clear();
        self.null_den = 1;
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn rows(&self) -> usize {
        self.rows_seen
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn pivots(&self) -> &[Option<usize>] {
        &self.pivots
    }

    /// Push one integer row; `Some(pivot_column)` when it increased the
    /// rank, `None` when it is exactly dependent on the rows pushed so far.
    pub fn push_row(&mut self, row: &[i64]) -> Option<usize> {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.rows_seen += 1;
        for tr in &mut self.t {
            tr.push(0);
        }
        let mut ce: Vec<i128> = row.iter().map(|&v| v as i128).collect();
        let mut ct: Vec<i128> = vec![0; self.rows_seen];
        ct[self.rows_seen - 1] = 1;
        let mut cden: i128 = 1;

        // reduce against stored pivot rows (creation order)
        for i in 0..self.rank {
            let c = self.row_cols[i];
            let f = ce[c];
            if f == 0 {
                continue;
            }
            let di = self.den[i];
            for (x, &p) in ce.iter_mut().zip(&self.e[i]) {
                *x = fused(*x, di, p, f);
            }
            for (x, &p) in ct.iter_mut().zip(&self.t[i]) {
                *x = fused(*x, di, p, f);
            }
            cden = mul(cden, di);
            debug_assert_eq!(ce[c], 0);
            reduce_row(&mut ce, &mut ct, &mut cden);
        }

        // leftmost non-zero entry pivots; none ⇒ exactly dependent
        let Some(c) = ce.iter().position(|&x| x != 0) else {
            reduce_row(&mut ce, &mut ct, &mut cden);
            self.null_t = ct;
            self.null_den = cden;
            return None;
        };

        // normalize: the pivot numerator becomes the denominator (pivot
        // value exactly 1), then eliminate column `c` from the store
        let mut p = ce[c];
        if p < 0 {
            for x in ce.iter_mut().chain(ct.iter_mut()) {
                *x = -*x;
            }
            p = -p;
        }
        let mut pden = p;
        reduce_row(&mut ce, &mut ct, &mut pden);
        let p = ce[c]; // == reduced denominator
        debug_assert_eq!(p, pden);
        for i in 0..self.rank {
            let f = self.e[i][c];
            if f == 0 {
                continue;
            }
            for (x, &q) in self.e[i].iter_mut().zip(&ce) {
                *x = fused(*x, p, q, f);
            }
            for (x, &q) in self.t[i].iter_mut().zip(&ct) {
                *x = fused(*x, p, q, f);
            }
            self.den[i] = mul(self.den[i], p);
            debug_assert_eq!(self.e[i][c], 0);
            let (e_i, t_i) = (&mut self.e[i], &mut self.t[i]);
            reduce_row(e_i, t_i, &mut self.den[i]);
        }
        self.pivots[c] = Some(self.rank);
        self.row_cols.push(c);
        self.e.push(ce);
        self.t.push(ct);
        self.den.push(pden);
        self.rank += 1;
        Some(c)
    }

    /// Whether stored row `i` is a unit row — exact integer zeros at every
    /// non-pivot column (the pivot entry equals the denominator by
    /// construction).
    pub fn is_unit_row(&self, i: usize) -> bool {
        let c = self.row_cols[i];
        self.e[i].iter().enumerate().all(|(k, &v)| k == c || v == 0)
    }

    /// Number of decodable columns (unit pivot rows), exactly.
    pub fn decodable_count(&self) -> usize {
        (0..self.rank).filter(|&i| self.is_unit_row(i)).count()
    }

    /// Decodable columns ascending, as `(column, stored_row)` pairs.
    pub fn decodable(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.pivots.iter().enumerate().filter_map(move |(c, p)| match p {
            Some(i) if self.is_unit_row(*i) => Some((c, *i)),
            _ => None,
        })
    }

    /// Extraction weights of stored row `i`, rounded once into `f64`
    /// (`weights · pushed_rows = e_row`, so for a unit row the weights
    /// recover its pivot column's payload).
    pub fn t_row_f64(&self, i: usize, out: &mut Vec<f64>) {
        let d = self.den[i] as f64;
        out.clear();
        out.extend(self.t[i].iter().map(|&x| x as f64 / d));
    }

    /// Null-space transform of the latest dependent push, rounded into
    /// `f64` (`combo · pushed_rows = 0`, exactly).
    pub fn null_transform_f64(&self, out: &mut Vec<f64>) {
        let d = self.null_den as f64;
        out.clear();
        out.extend(self.null_t.iter().map(|&x| x as f64 / d));
    }

    /// Treat the engine as an augmented system `[A | b]` whose first `n`
    /// columns are unknown coefficients: return the consistent solution
    /// with free unknowns at zero, or `None` if column `n` pivots
    /// (inconsistent). Exact; rounded into `f64` once at extraction.
    pub fn solve_augmented(&self, n: usize) -> Option<Vec<f64>> {
        assert_eq!(self.cols, n + 1, "solve_augmented: engine width must be n+1");
        if self.pivots[n].is_some() {
            return None;
        }
        let mut x = vec![0.0; n];
        for (c, p) in self.pivots[..n].iter().enumerate() {
            if let Some(r) = p {
                x[c] = self.e[*r][n] as f64 / self.den[*r] as f64;
            }
        }
        Some(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn construction_is_deterministic_and_alternating() {
        let code = BinaryCode::new(8, 4).unwrap();
        assert_eq!(code.coeff(0, 0), 1);
        assert_eq!(code.coeff(0, 1), -1);
        assert_eq!(code.coeff(0, 4), 1);
        assert_eq!(code.coeff(0, 5), 0);
        assert_eq!(code.coeff(6, 1), -1); // wraparound support
        // every row sums to exactly +1 (s even)
        for r in 0..8 {
            let sum: i64 = (0..8).map(|j| code.coeff(r, j)).sum();
            assert_eq!(sum, 1, "row {r}");
        }
        // dense mirror agrees entry-for-entry
        let b = code.dense_b();
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(b[(i, j)], code.coeff(i, j) as f64);
            }
        }
    }

    #[test]
    fn odd_s_is_rejected() {
        assert!(BinaryCode::new(8, 3).is_err());
        assert!(BinaryCode::new(8, 2).is_ok());
        assert!(CodeFamily::Binary.validate(9, 4).is_ok());
        assert!(CodeFamily::Binary.validate(9, 3).is_err());
    }

    #[test]
    fn full_reception_decodes_with_all_ones() {
        for (m, s) in [(6, 2), (9, 4), (12, 6)] {
            let code = BinaryCode::new(m, s).unwrap();
            let rows: Vec<usize> = (0..m).collect();
            let a = code.combinator_weights(&rows).expect("full reception must decode");
            // Σ a_f · B[f] = 𝟙, checked exactly in integers scaled by 1
            for j in 0..m {
                let got: f64 = rows.iter().zip(&a).map(|(&r, &w)| w * code.coeff(r, j) as f64).sum();
                assert!((got - 1.0).abs() < 1e-12, "m={m} s={s} block {j}: {got}");
            }
        }
    }

    #[test]
    fn undecodable_patterns_return_none_not_garbage() {
        let code = BinaryCode::new(6, 2).unwrap();
        // fewer than M−s rows can never decode
        assert!(code.combinator_weights(&[0, 1, 2]).is_none());
        // exhaustively: every received set either solves 𝟙 exactly or is
        // refused — verify the returned weights whenever Some
        for mask in 0u32..64 {
            let rows: Vec<usize> = (0..6).filter(|&r| mask & (1 << r) != 0).collect();
            if let Some(a) = code.combinator_weights(&rows) {
                for j in 0..6 {
                    let got: f64 =
                        rows.iter().zip(&a).map(|(&r, &w)| w * code.coeff(r, j) as f64).sum();
                    assert!((got - 1.0).abs() < 1e-9, "mask {mask:#b} block {j}");
                }
            }
        }
    }

    #[test]
    fn int_rref_matches_float_engine_verdicts_on_pm1_stacks() {
        let mut rng = Rng::new(515);
        for trial in 0..40 {
            let m = 2 + rng.below(9);
            let s = 2 * (1 + rng.below(((m - 1) / 2).max(1)));
            let Ok(code) = BinaryCode::new(m, s) else { continue };
            let mut eng = IntRref::new(m);
            let mut flt = crate::linalg::IncrementalRref::new(m);
            let mut ibuf = Vec::new();
            for _ in 0..2 * m {
                let r = rng.below(m);
                code.int_row_into(r, &mut ibuf);
                // random erasures on the off-diagonal support
                for (j, v) in ibuf.iter_mut().enumerate() {
                    if j != r && rng.bernoulli(0.3) {
                        *v = 0;
                    }
                }
                let frow: Vec<f64> = ibuf.iter().map(|&v| v as f64).collect();
                let a = eng.push_row(&ibuf);
                let b = flt.push_row(&frow);
                // ±1 stacks are exactly representable: verdicts agree
                assert_eq!(a, b, "trial {trial}");
                assert_eq!(eng.rank(), flt.rank(), "trial {trial}");
                assert_eq!(eng.decodable_count(), flt.decodable_count(), "trial {trial}");
            }
        }
    }

    #[test]
    fn unit_rows_extract_exact_weights() {
        let mut eng = IntRref::new(3);
        eng.push_row(&[1, -1, 0]);
        eng.push_row(&[0, 1, -1]);
        eng.push_row(&[0, 0, 2]);
        assert_eq!(eng.rank(), 3);
        assert_eq!(eng.decodable_count(), 3);
        // decode block 0: g0 = row0 + row1 + row2/2
        let (c, i) = eng.decodable().next().unwrap();
        assert_eq!(c, 0);
        let mut w = Vec::new();
        eng.t_row_f64(i, &mut w);
        assert_eq!(w, vec![1.0, 1.0, 0.5]);
    }

    #[test]
    fn dependent_pushes_expose_exact_null_transforms() {
        let mut eng = IntRref::new(4);
        eng.push_row(&[1, -1, 1, 0]);
        eng.push_row(&[0, 1, -1, 1]);
        // sum of the two rows
        assert_eq!(eng.push_row(&[1, 0, 0, 1]), None);
        let mut combo = Vec::new();
        eng.null_transform_f64(&mut combo);
        assert_eq!(combo.len(), 3);
        // combo · pushed = 0 exactly: scaled to integers it is (1, 1, -1)
        let scale = combo[2].abs();
        assert!(scale > 0.0);
        assert_eq!(combo.iter().map(|x| x / scale).collect::<Vec<_>>(), vec![-1.0, -1.0, 1.0]);
    }

    #[test]
    fn reset_reuses_engine() {
        let mut eng = IntRref::new(3);
        eng.push_row(&[1, 1, 0]);
        eng.reset(2);
        assert_eq!(eng.rank(), 0);
        assert_eq!(eng.rows(), 0);
        eng.push_row(&[0, 5]);
        assert_eq!(eng.rank(), 1);
        assert_eq!(eng.decodable_count(), 1);
    }
}
