//! Redundancy-based Byzantine detection in the GC⁺ decode path.
//!
//! A stack of delivered coded rows is over-determined whenever more rows
//! arrive than the code's rank: every vector in the left null space of the
//! coefficient stack is a **parity check** — an exact linear relation the
//! corresponding payload rows must satisfy. An uplink-tampered row breaks
//! every check whose support touches it; rows covered by no check (no
//! spare redundancy) are undetectable.
//!
//! [`audit_rows`] harvests the checks for free from the decode engine
//! (each dependent `push_row` exposes one via `null_transform()`),
//! evaluates them with a caller-supplied closure (payload residual in
//! `sim`/trainer, symbolic corruption flags in `outage::mc`, so the two
//! modes are oracle-comparable in tests), and on failure excises suspects
//! and repeats on the surviving rows until all remaining checks pass.
//! Suspicion is conservative: a row implicated by a failing check is
//! excised unless some *passing* check vouches for it — trading a little
//! recovery (honest rows excised alongside the liar) for integrity, which
//! is the right trade for CoGC's exact decode.
//!
//! # Peeling and the audit
//!
//! The peeling front-end ([`PeelingDecoder`]) does **not** exempt any row
//! from the parity audit. Peel-resolved rows enter the engine at their
//! arrival index exactly like eliminated rows, and a dependent row
//! produces the bit-identical `null_transform()` whether its reduction
//! took the fast path or the dense one — so every check the pure engine
//! would harvest is harvested, with the same coefficients, in the same
//! order. [`audit_rows`] therefore runs its passes *on* the peeling
//! decoder (dependent redundant rows — the very rows that carry checks —
//! are the fast path's best case), and [`audit_rows_pure`] keeps the
//! plain-engine reference; detection rates are pinned equal by the
//! differential tests here and in `tests/decode_equivalence.rs`.

use crate::gc::binary::IntRref;
use crate::linalg::{IncrementalRref, PeelingDecoder};
use crate::linalg::Matrix;

/// Relative magnitude below which a check coefficient is considered
/// structurally zero (outside the check's support).
const SUPPORT_TOL: f64 = 1e-9;

/// Relative residual above which a payload parity check fails. Honest
/// stacks sit near machine epsilon (≲1e-12 after RREF combination, with
/// pivot amplification bounded by the engine's 1e6 acceptance floor);
/// tampered rows contribute O(1) relative residual.
const RESIDUAL_TOL: f64 = 1e-6;

/// Result of auditing one stack of coded rows.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Audit {
    /// Surviving row indices into the original stack, ascending.
    pub kept: Vec<usize>,
    /// Excised row indices, ascending.
    pub excised: Vec<usize>,
    /// Whether any parity check failed (the detection alarm).
    pub alarm: bool,
    /// Parity checks evaluated across all passes.
    pub checks: usize,
    /// Checks that failed across all passes.
    pub failing: usize,
}

/// Indices (into `combo`) carrying structurally non-zero weight.
pub fn combo_support(combo: &[f64]) -> Vec<usize> {
    let max = combo.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    let tol = SUPPORT_TOL * max.max(1.0);
    combo
        .iter()
        .enumerate()
        .filter(|(_, &x)| x.abs() > tol)
        .map(|(i, _)| i)
        .collect()
}

/// The engine surface the audit passes need. Implemented by the pure
/// incremental engine and by the peeling front-end; the two are
/// bit-identical state machines, so either harvests the same checks.
trait CheckEngine {
    fn reset(&mut self, cols: usize);
    fn push_row(&mut self, row: &[f64]) -> Option<usize>;
    fn null_transform(&self) -> &[f64];
    /// Structural support of a harvested check. The float engines apply
    /// the relative tolerance; the exact integer engine overrides this
    /// with the exact non-zero test (its combos carry no rounding noise).
    fn check_support(&self, combo: &[f64]) -> Vec<usize> {
        combo_support(combo)
    }
}

impl CheckEngine for IncrementalRref {
    fn reset(&mut self, cols: usize) {
        IncrementalRref::reset(self, cols)
    }
    fn push_row(&mut self, row: &[f64]) -> Option<usize> {
        IncrementalRref::push_row(self, row)
    }
    fn null_transform(&self) -> &[f64] {
        IncrementalRref::null_transform(self)
    }
}

impl CheckEngine for PeelingDecoder {
    fn reset(&mut self, cols: usize) {
        PeelingDecoder::reset(self, cols)
    }
    fn push_row(&mut self, row: &[f64]) -> Option<usize> {
        PeelingDecoder::push_row(self, row)
    }
    fn null_transform(&self) -> &[f64] {
        PeelingDecoder::null_transform(self)
    }
}

/// Audit a stack of coefficient rows against a check evaluator.
///
/// `coeffs` holds one coded coefficient row per stacked observation (the
/// raw `b̃` rows, in stack order). `check_fails(combo, kept)` receives a
/// left-null-space combination `combo` aligned with the prefix
/// `kept[..combo.len()]` of currently kept original indices, and returns
/// whether the corresponding payload relation is violated.
///
/// Each pass rebuilds the RREF engine over the kept rows, harvesting one
/// check per dependent row; failing-check supports minus rows vouched by a
/// passing check are excised and the pass repeats, until every check
/// passes (or nothing more can be excised). Terminates in ≤ rows passes
/// since each continuing pass removes at least one row.
///
/// Runs on the peeling front-end (see the module docs);
/// [`audit_rows_pure`] is the plain-engine reference with pinned-equal
/// output.
pub fn audit_rows<F>(coeffs: &Matrix, check_fails: F) -> Audit
where
    F: FnMut(&[f64], &[usize]) -> bool,
{
    let mut eng = PeelingDecoder::with_capacity(coeffs.cols, coeffs.rows);
    audit_rows_with(&mut eng, coeffs, check_fails)
}

/// [`audit_rows`] on the pure incremental engine — the reference
/// implementation the differential tests compare the peeling audit
/// against.
pub fn audit_rows_pure<F>(coeffs: &Matrix, check_fails: F) -> Audit
where
    F: FnMut(&[f64], &[usize]) -> bool,
{
    let mut eng = IncrementalRref::with_capacity(coeffs.cols, coeffs.rows);
    audit_rows_with(&mut eng, coeffs, check_fails)
}

/// [`CheckEngine`] over the exact integer eliminator: rows arrive as
/// integer-valued `f64`s (the binary family's ±1 coefficients), the
/// elimination runs in i128 rationals, and check supports are the exact
/// non-zero sets — no tolerance anywhere, so the audit can neither drop a
/// small-but-real check coefficient nor hallucinate one from rounding.
struct IntCheckEngine {
    eng: IntRref,
    ibuf: Vec<i64>,
    combo: Vec<f64>,
}

impl CheckEngine for IntCheckEngine {
    fn reset(&mut self, cols: usize) {
        self.eng.reset(cols);
    }
    fn push_row(&mut self, row: &[f64]) -> Option<usize> {
        self.ibuf.clear();
        self.ibuf.extend(row.iter().map(|&v| {
            debug_assert_eq!(v, v.trunc(), "integer audit fed a non-integer coefficient");
            v as i64
        }));
        let pivot = self.eng.push_row(&self.ibuf);
        if pivot.is_none() {
            self.eng.null_transform_f64(&mut self.combo);
        }
        pivot
    }
    fn null_transform(&self) -> &[f64] {
        &self.combo
    }
    fn check_support(&self, combo: &[f64]) -> Vec<usize> {
        // exact rationals: an entry is zero iff its i128 numerator is zero
        combo
            .iter()
            .enumerate()
            .filter(|(_, &x)| x != 0.0)
            .map(|(i, _)| i)
            .collect()
    }
}

/// [`audit_rows`] in exact i128 arithmetic for integer-valued code
/// families (the ±1 `binary` family): elimination, harvested checks, and
/// their supports are all exact, so the audit verdict has no numerical
/// failure mode. Rows must hold exactly representable integers.
pub fn audit_rows_int<F>(coeffs: &Matrix, check_fails: F) -> Audit
where
    F: FnMut(&[f64], &[usize]) -> bool,
{
    let mut eng = IntCheckEngine {
        eng: IntRref::new(coeffs.cols),
        ibuf: Vec::with_capacity(coeffs.cols),
        combo: Vec::new(),
    };
    audit_rows_with(&mut eng, coeffs, check_fails)
}

fn audit_rows_with<E, F>(eng: &mut E, coeffs: &Matrix, mut check_fails: F) -> Audit
where
    E: CheckEngine,
    F: FnMut(&[f64], &[usize]) -> bool,
{
    let mut audit = Audit { kept: (0..coeffs.rows).collect(), ..Audit::default() };
    if coeffs.rows == 0 {
        return audit;
    }
    // (fails, support as local kept-indices) per check of the current pass
    let mut pass_checks: Vec<(bool, Vec<usize>)> = Vec::new();
    loop {
        eng.reset(coeffs.cols);
        pass_checks.clear();
        for (local, &orig) in audit.kept.iter().enumerate() {
            if eng.push_row(coeffs.row(orig)).is_none() {
                let combo = eng.null_transform();
                debug_assert_eq!(combo.len(), local + 1);
                let fails = check_fails(combo, &audit.kept[..=local]);
                let support = eng.check_support(combo);
                pass_checks.push((fails, support));
            }
        }
        audit.checks += pass_checks.len();
        let n_fail = pass_checks.iter().filter(|(f, _)| *f).count();
        if n_fail == 0 {
            return audit;
        }
        audit.failing += n_fail;
        audit.alarm = true;
        let n = audit.kept.len();
        let mut implicated = vec![false; n];
        let mut vouched = vec![false; n];
        for (fails, sup) in &pass_checks {
            for &i in sup {
                if *fails {
                    implicated[i] = true;
                } else {
                    vouched[i] = true;
                }
            }
        }
        let mut suspect: Vec<bool> =
            (0..n).map(|i| implicated[i] && !vouched[i]).collect();
        if !suspect.iter().any(|&s| s) {
            // every implicated row is also vouched (a corrupted row can
            // slip into a passing check's support through cancellation):
            // fall back to excising everything the failing checks touch
            for i in 0..n {
                suspect[i] = implicated[i];
            }
        }
        if !suspect.iter().any(|&s| s) {
            // failing checks with empty support — numerically degenerate;
            // nothing actionable to excise
            return audit;
        }
        let mut kept_next = Vec::with_capacity(n);
        for (i, &orig) in audit.kept.iter().enumerate() {
            if suspect[i] {
                audit.excised.push(orig);
            } else {
                kept_next.push(orig);
            }
        }
        audit.kept = kept_next;
        if audit.kept.is_empty() {
            return audit;
        }
    }
}

/// Payload parity-check evaluator: the check fails iff the combined
/// partial-sum residual `Σᵢ comboᵢ · sums[kept[i]]` is non-zero relative
/// to the magnitudes involved. `sums` rows are aligned with the original
/// stack indices.
pub fn payload_check_fails(combo: &[f64], kept: &[usize], sums: &Matrix) -> bool {
    let d = sums.cols;
    let mut scale = 0.0f64;
    let mut worst = 0.0f64;
    for j in 0..d {
        let mut acc = 0.0f64;
        for (i, &orig) in kept.iter().enumerate().take(combo.len()) {
            acc += combo[i] * sums.row(orig)[j];
        }
        worst = worst.max(acc.abs());
    }
    for (i, &orig) in kept.iter().enumerate().take(combo.len()) {
        let row_max = sums.row(orig).iter().fold(0.0f64, |a, &x| a.max(x.abs()));
        scale += combo[i].abs() * row_max;
    }
    worst > RESIDUAL_TOL * scale.max(1.0)
}

/// Symbolic evaluator for payload-free Monte-Carlo: the check fails iff
/// its support touches a row flagged as corrupted. This matches the
/// payload evaluator for generic (non-cancelling) corruptions — the
/// identity the dense-oracle tests pin down.
pub fn symbolic_check_fails(combo: &[f64], kept: &[usize], corrupted: &[bool]) -> bool {
    combo_support(combo).iter().any(|&i| corrupted[kept[i]])
}

/// [`symbolic_check_fails`] with exact support: any non-zero combo entry
/// counts. Pair with [`audit_rows_int`], whose combos are exact rationals
/// (zero iff the i128 numerator is zero).
pub fn symbolic_check_fails_exact(combo: &[f64], kept: &[usize], corrupted: &[bool]) -> bool {
    combo.iter().zip(kept).any(|(&x, &k)| x != 0.0 && corrupted[k])
}

/// Whether a decode weight row (aligned with `kept` stack indices) places
/// structural weight on any corrupted kept row — i.e. the decoded value is
/// poisoned.
pub fn weights_touch_corrupted(weights: &[f64], kept: &[usize], corrupted: &[bool]) -> bool {
    combo_support(weights).iter().any(|&i| corrupted[kept[i]])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::GcCode;
    use crate::util::rng::Rng;

    /// Stack the full cyclic code twice: M extra rows ⇒ M parity checks.
    fn double_stack(m: usize, s: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let code_a = GcCode::generate(m, s, &mut rng);
        let code_b = GcCode::generate(m, s, &mut rng);
        let d = 4;
        let payload = Matrix::from_fn(m, d, |_, _| rng.normal());
        let mut coeffs = Matrix::zeros(0, m);
        for r in 0..m {
            coeffs.push_row(code_a.b.row(r));
        }
        for r in 0..m {
            coeffs.push_row(code_b.b.row(r));
        }
        let sums = coeffs.matmul(&payload);
        (coeffs, sums, payload)
    }

    #[test]
    fn clean_stack_raises_no_alarm() {
        for seed in 0..5 {
            let (coeffs, sums, _) = double_stack(8, 3, seed);
            let audit = audit_rows(&coeffs, |c, k| payload_check_fails(c, k, &sums));
            assert!(!audit.alarm, "false alarm at seed {seed}");
            assert_eq!(audit.kept.len(), coeffs.rows);
            assert!(audit.checks >= 8, "expected ≥ M checks, got {}", audit.checks);
        }
    }

    #[test]
    fn single_sign_flip_is_excised_and_redecode_is_clean() {
        for &bad in &[0usize, 5, 11] {
            let (coeffs, mut sums, _) = double_stack(8, 3, 42);
            for x in sums.row_mut(bad) {
                *x = -*x;
            }
            let audit = audit_rows(&coeffs, |c, k| payload_check_fails(c, k, &sums));
            assert!(audit.alarm);
            assert!(audit.excised.contains(&bad), "row {bad} not excised: {:?}", audit.excised);
            // surviving rows satisfy all their checks
            let kept_c = coeffs.select_rows(&audit.kept);
            let re = audit_rows(&kept_c, |c, k| {
                let orig: Vec<usize> = k.iter().map(|&i| audit.kept[i]).collect();
                payload_check_fails(c, &orig, &sums)
            });
            assert!(!re.alarm);
        }
    }

    #[test]
    fn symbolic_and_payload_audits_agree_on_generic_corruptions() {
        let mut rng = Rng::new(7);
        for trial in 0..20 {
            let (coeffs, mut sums, _) = double_stack(6, 2, 100 + trial);
            let mut corrupted = vec![false; coeffs.rows];
            for r in 0..coeffs.rows {
                if rng.bernoulli(0.15) {
                    corrupted[r] = true;
                    for x in sums.row_mut(r) {
                        // generic replacement — no accidental cancellation
                        *x = 3.0 + rng.normal();
                    }
                }
            }
            let pay = audit_rows(&coeffs, |c, k| payload_check_fails(c, k, &sums));
            let sym = audit_rows(&coeffs, |c, k| symbolic_check_fails(c, k, &corrupted));
            assert_eq!(pay.kept, sym.kept, "trial {trial}");
            assert_eq!(pay.excised, sym.excised, "trial {trial}");
            assert_eq!(pay.alarm, sym.alarm, "trial {trial}");
        }
    }

    #[test]
    fn corruption_without_redundancy_is_missed() {
        // exactly rank-many independent rows → zero checks → no detection
        let mut rng = Rng::new(3);
        let code = GcCode::generate(8, 3, &mut rng);
        let payload = Matrix::from_fn(8, 4, |_, _| rng.normal());
        let mut sums = code.b.matmul(&payload);
        for x in sums.row_mut(2) {
            *x = -*x;
        }
        let audit = audit_rows(&code.b, |c, k| payload_check_fails(c, k, &sums));
        // the cyclic B is full-rank: every row is a pivot, no null combos
        assert_eq!(audit.checks, 0);
        assert!(!audit.alarm);
        assert_eq!(audit.kept.len(), 8);
    }

    #[test]
    fn consistent_payload_substitution_is_invisible() {
        // c2c-surface model: the adversary swaps client k's gradient for a
        // fake one *before* encoding — the stack stays self-consistent, so
        // no parity check can fail (the documented blind spot)
        let mut rng = Rng::new(9);
        let code_a = GcCode::generate(8, 3, &mut rng);
        let code_b = GcCode::generate(8, 3, &mut rng);
        let mut payload = Matrix::from_fn(8, 4, |_, _| rng.normal());
        for x in payload.row_mut(3) {
            *x = 100.0 + rng.normal(); // wildly wrong, but consistent
        }
        let mut coeffs = Matrix::zeros(0, 8);
        for r in 0..8 {
            coeffs.push_row(code_a.b.row(r));
        }
        for r in 0..8 {
            coeffs.push_row(code_b.b.row(r));
        }
        let sums = coeffs.matmul(&payload);
        let audit = audit_rows(&coeffs, |c, k| payload_check_fails(c, k, &sums));
        assert!(!audit.alarm);
        assert!(audit.checks >= 8);
    }

    #[test]
    fn peeling_audit_matches_pure_audit_on_adversarial_grid() {
        // satellite regression: detection behavior with the peeling
        // front-end in the audit loop is identical to the pure engine —
        // alarms, checks, excisions, survivors, bit for bit
        let mut rng = Rng::new(88);
        for (m, s) in [(6usize, 2usize), (8, 3), (10, 4)] {
            for trial in 0u64..15 {
                let (coeffs, mut sums, _) = double_stack(m, s, 1000 + trial);
                let mut corrupted = vec![false; coeffs.rows];
                for r in 0..coeffs.rows {
                    if rng.bernoulli(0.2) {
                        corrupted[r] = true;
                        for x in sums.row_mut(r) {
                            *x = 5.0 + rng.normal();
                        }
                    }
                }
                let peel = audit_rows(&coeffs, |c, k| payload_check_fails(c, k, &sums));
                let pure = audit_rows_pure(&coeffs, |c, k| payload_check_fails(c, k, &sums));
                assert_eq!(peel, pure, "payload audit m={m} s={s} trial {trial}");
                let peel = audit_rows(&coeffs, |c, k| symbolic_check_fails(c, k, &corrupted));
                let pure =
                    audit_rows_pure(&coeffs, |c, k| symbolic_check_fails(c, k, &corrupted));
                assert_eq!(peel, pure, "symbolic audit m={m} s={s} trial {trial}");
            }
        }
    }

    #[test]
    fn int_audit_matches_float_audit_on_binary_double_stacks() {
        // satellite differential: the exact i128 audit and the float audit
        // must agree — alarms, checks, excisions, survivors, bit for bit —
        // on ±1 binary stacks, where every float combo is exactly the
        // rational one (pinned by int_rref_matches_float_engine_verdicts).
        use crate::gc::BinaryCode;
        let mut rng = Rng::new(17);
        for (m, s) in [(6usize, 2usize), (10, 4), (14, 6)] {
            let code = BinaryCode::new(m, s).unwrap();
            let b = code.dense_b();
            let mut coeffs = Matrix::zeros(0, m);
            for r in 0..m {
                coeffs.push_row(b.row(r));
            }
            for r in 0..m {
                coeffs.push_row(b.row(r));
            }
            for trial in 0..15 {
                let mut corrupted = vec![false; coeffs.rows];
                for c in corrupted.iter_mut() {
                    *c = rng.bernoulli(0.2);
                }
                let float =
                    audit_rows(&coeffs, |c, k| symbolic_check_fails(c, k, &corrupted));
                let exact = audit_rows_int(&coeffs, |c, k| {
                    symbolic_check_fails_exact(c, k, &corrupted)
                });
                assert_eq!(float, exact, "m={m} s={s} trial={trial}");
            }
        }
    }

    #[test]
    fn int_audit_excises_flipped_binary_payload_row() {
        // payload-evaluator end of the int audit: duplicate the ±1 stack,
        // flip one payload row's sign, and the exact audit must excise it
        use crate::gc::BinaryCode;
        let mut rng = Rng::new(29);
        let code = BinaryCode::new(8, 2).unwrap();
        let b = code.dense_b();
        let payload = Matrix::from_fn(8, 4, |_, _| rng.normal());
        let mut coeffs = Matrix::zeros(0, 8);
        for r in 0..8 {
            coeffs.push_row(b.row(r));
        }
        for r in 0..8 {
            coeffs.push_row(b.row(r));
        }
        let mut sums = coeffs.matmul(&payload);
        for x in sums.row_mut(5) {
            *x = -*x;
        }
        let audit = audit_rows_int(&coeffs, |c, k| payload_check_fails(c, k, &sums));
        assert!(audit.alarm);
        assert!(audit.excised.contains(&5), "excised: {:?}", audit.excised);
    }

    #[test]
    fn weights_touch_corrupted_flags_structural_support_only() {
        let kept = vec![0, 2, 5];
        let corrupted = vec![false, true, true, false, false, false];
        assert!(weights_touch_corrupted(&[0.0, 1.0, 0.0], &kept, &corrupted));
        assert!(!weights_touch_corrupted(&[1.0, 0.0, 0.0], &kept, &corrupted));
        assert!(weights_touch_corrupted(&[0.5, 0.0, -0.5], &kept, &corrupted));
        // sub-tolerance residue does not count as support
        assert!(!weights_touch_corrupted(&[1.0, 1e-14, 0.0], &kept, &corrupted));
    }
}
