//! Cyclic gradient-code construction (paper §II-C, Tandon et al. Alg. 2).
//!
//! A code is a pair `(B, A)` with `A·B = 𝟙`. `B` is `M×M`, cyclic, with
//! `s+1` nonzeros per row (row m is supported on columns
//! `{m, m+1, …, m+s} mod M`). Rows of `B` are drawn from the null space of a
//! random `s×M` matrix `H` whose columns sum to zero — this puts the all-one
//! vector in the row space of any `M−s` rows, which is exactly what makes
//! the code robust to any `s` stragglers.
//!
//! `A` is never materialized (it has `C(M,s)` rows): combinator rows are
//! solved on demand from the observed straggler pattern
//! (`gc::combinator::find_combinator`).

use crate::linalg::{rank, solve_consistent, Matrix};
use crate::util::rng::Rng;

/// Reject codes whose coefficients exceed this magnitude (conditioning
/// guard: the row solves can blow up when the random `H_supp` block is
/// nearly singular, which poisons downstream decode numerics).
pub const MAX_COEFF: f64 = 50.0;

/// Generation is rejection sampling; degenerate draws have small
/// probability so this bound is never approached in practice.
const MAX_GENERATE_ATTEMPTS: usize = 1000;

#[derive(Clone, Debug)]
pub struct GcCode {
    pub m: usize,
    pub s: usize,
    /// `M×M` cyclic allocation matrix.
    pub b: Matrix,
    /// The `s×M` parity matrix used in the construction (`H·bᵀ = 0` row-wise).
    pub h: Matrix,
}

impl GcCode {
    /// Cyclic support of row `m`: `{m, m+1, …, m+s} mod M`.
    pub fn support(m: usize, s: usize, row: usize) -> Vec<usize> {
        Self::support_iter(m, s, row).collect()
    }

    /// Allocation-free form of [`GcCode::support`] — the per-row hot loops
    /// (completeness checks run once per delivered row per attempt)
    /// iterate the cyclic support without materializing a `Vec`.
    pub fn support_iter(m: usize, s: usize, row: usize) -> impl Iterator<Item = usize> {
        (0..=s).map(move |o| (row + o) % m)
    }

    /// Incoming-neighbor set `K₂(row)` (paper §III): the clients this client
    /// must hear from — its row support minus itself.
    pub fn incoming(&self, row: usize) -> Vec<usize> {
        self.incoming_iter(row).collect()
    }

    /// Allocation-free form of [`GcCode::incoming`].
    pub fn incoming_iter(&self, row: usize) -> impl Iterator<Item = usize> {
        Self::support_iter(self.m, self.s, row).filter(move |&k| k != row)
    }

    /// Outgoing-neighbor set `K₁(col)`: the clients this client's gradient is
    /// sent to — the rows whose support contains `col`, minus itself.
    pub fn outgoing(&self, col: usize) -> Vec<usize> {
        (0..self.m)
            .filter(|&r| r != col && self.b[(r, col)] != 0.0)
            .collect()
    }

    /// Generate a fresh random cyclic code (Tandon Algorithm 2 analogue).
    ///
    /// Requires `1 <= s <= M-1`. Each row's coefficients solve
    /// `H_supp · x = 0` over the row's `s+1` support columns; the null space
    /// is 1-dimensional w.p. 1, scaled so the diagonal entry is 1 (the
    /// diagonal is the client's own gradient and must never vanish — the
    /// rank analysis of Lemma 2 relies on it).
    ///
    /// Draws whose row solves are ill-conditioned (coefficients above
    /// [`MAX_COEFF`]) or that fail the structural checks are rejected and
    /// redrawn — this keeps every accepted code numerically well-behaved
    /// for the decode paths (probability of rejection is small).
    pub fn generate(m: usize, s: usize, rng: &mut Rng) -> GcCode {
        assert!(m >= 2, "need at least 2 clients");
        assert!(s >= 1 && s < m, "straggler tolerance s must be in [1, M-1]");
        for _attempt in 0..MAX_GENERATE_ATTEMPTS {
            // H: s x M, first M-1 columns ~ N(0,1), last column = -row sums
            // so that H * 1 = 0 (the all-one vector lies in null(H)).
            let mut h = Matrix::from_fn(s, m, |_, j| if j + 1 < m { rng.normal() } else { 0.0 });
            for i in 0..s {
                let sum: f64 = (0..m - 1).map(|j| h[(i, j)]).sum();
                h[(i, m - 1)] = -sum;
            }

            let mut b = Matrix::zeros(m, m);
            let mut ok = true;
            'rows: for r in 0..m {
                let supp = Self::support(m, s, r);
                // Solve H_supp x = 0 with x[diag position] = 1:
                // move the diagonal column to the RHS.
                // H_rest (s x s) * x_rest = -H[:, r]
                let rest: Vec<usize> = supp.iter().copied().filter(|&c| c != r).collect();
                let h_rest = Matrix::from_fn(s, s, |i, j| h[(i, rest[j])]);
                let rhs: Vec<f64> = (0..s).map(|i| -h[(i, r)]).collect();
                match solve_consistent(&h_rest, &rhs) {
                    Some(x) => {
                        b[(r, r)] = 1.0;
                        for (j, &c) in rest.iter().enumerate() {
                            b[(r, c)] = x[j];
                        }
                    }
                    None => {
                        ok = false;
                        break 'rows;
                    }
                }
            }
            if !ok || b.max_abs() > MAX_COEFF {
                continue; // degenerate or ill-conditioned draw; redraw
            }
            let code = GcCode { m, s, b, h };
            if code.structural_check().is_ok() {
                return code;
            }
        }
        panic!("GcCode::generate failed to draw a well-conditioned code for M={m}, s={s}");
    }

    /// Cheap invariants used as the accept test inside `generate`:
    /// cyclic support + unit diagonal, rows in `null(H)`, `rank(B) = M−s`.
    /// (`verify` additionally checks decodability on straggler patterns.)
    pub fn structural_check(&self) -> anyhow::Result<()> {
        let (m, s) = (self.m, self.s);
        for r in 0..m {
            let supp = Self::support(m, s, r);
            anyhow::ensure!((self.b[(r, r)] - 1.0).abs() < 1e-9, "diagonal not 1 at row {r}");
            for c in 0..m {
                anyhow::ensure!(
                    supp.contains(&c) || self.b[(r, c)] == 0.0,
                    "row {r} has nonzero outside cyclic support at col {c}"
                );
            }
        }
        let hb = self.h.matmul(&self.b.transpose());
        anyhow::ensure!(hb.max_abs() < 1e-6, "rows of B are not in null(H)");
        let rk = rank(&self.b);
        anyhow::ensure!(rk == m - s, "rank(B) = {rk}, expected M-s = {}", m - s);
        Ok(())
    }

    /// Full verification: the structural invariants plus `AB = 𝟙` on
    /// straggler patterns (every pattern when `C(M,s)` is small, random
    /// patterns otherwise).
    pub fn verify(&self, rng: &mut Rng) -> anyhow::Result<()> {
        let (m, s) = (self.m, self.s);
        self.structural_check()?;
        // AB = 1 on straggler patterns
        let patterns = sample_straggler_patterns(m, s, rng, 32);
        for pat in patterns {
            let received: Vec<usize> = (0..m).filter(|i| !pat.contains(i)).collect();
            anyhow::ensure!(
                super::combinator::find_combinator(self, &received).is_some(),
                "no combinator for straggler pattern {pat:?}"
            );
        }
        Ok(())
    }
}

/// Sample up to `limit` straggler patterns of exactly `s` stragglers
/// (exhaustive when `C(M,s)` is small).
pub fn sample_straggler_patterns(
    m: usize,
    s: usize,
    rng: &mut Rng,
    limit: usize,
) -> Vec<Vec<usize>> {
    // binomial() is None when C(M,s) overflows u128 — then it is certainly
    // larger than any practical `limit`, so fall through to random sampling
    // (the pre-guard code silently wrapped and could "enumerate" garbage).
    let total = binomial(m, s);
    if total.is_some_and(|t| t <= limit as u128) {
        // exhaustive enumeration
        let mut out = Vec::new();
        let mut comb: Vec<usize> = (0..s).collect();
        loop {
            out.push(comb.clone());
            // next combination
            let mut i = s;
            loop {
                if i == 0 {
                    return out;
                }
                i -= 1;
                if comb[i] != i + m - s {
                    break;
                }
            }
            if comb[s - 1] == m - 1 && comb[0] == m - s {
                return out;
            }
            comb[i] += 1;
            for j in i + 1..s {
                comb[j] = comb[j - 1] + 1;
            }
        }
    }
    (0..limit)
        .map(|_| {
            let mut idx = rng.sample_indices(m, s);
            idx.sort();
            idx
        })
        .collect()
}

/// Binomial coefficient, or `None` when the (intermediate) product
/// overflows u128 — large-M callers must treat that as "astronomically
/// many", never as a small wrapped value.
pub fn binomial(n: usize, k: usize) -> Option<u128> {
    if k > n {
        return Some(0);
    }
    let k = k.min(n - k);
    let mut num: u128 = 1;
    for i in 0..k {
        num = num.checked_mul((n - i) as u128)? / (i + 1) as u128;
    }
    Some(num)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Prop;

    #[test]
    fn support_is_cyclic() {
        assert_eq!(GcCode::support(5, 2, 3), vec![3, 4, 0]);
        assert_eq!(GcCode::support(5, 2, 0), vec![0, 1, 2]);
    }

    #[test]
    fn binomial_known() {
        assert_eq!(binomial(10, 7), Some(120));
        assert_eq!(binomial(10, 0), Some(1));
        assert_eq!(binomial(5, 6), Some(0));
        assert_eq!(binomial(52, 5), Some(2_598_960));
    }

    #[test]
    fn binomial_overflow_is_none_not_garbage() {
        // C(100000, 50000) overflows u128 by a huge margin
        assert_eq!(binomial(100_000, 50_000), None);
        // symmetric k still short-circuits cheaply
        assert_eq!(binomial(100_000, 1), Some(100_000));
        // largest exact row that fits: C(n, n/2) for n ≤ 131 fits u128
        assert!(binomial(130, 65).is_some());
    }

    #[test]
    fn pattern_sampling_survives_overflowing_binomial() {
        // would previously compare a wrapped C(M,s) against `limit`; now the
        // overflow falls through to random sampling of the right shape
        let mut rng = Rng::new(4);
        let pats = sample_straggler_patterns(100_000, 50_000, &mut rng, 4);
        assert_eq!(pats.len(), 4);
        for p in &pats {
            assert_eq!(p.len(), 50_000);
            assert!(p.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn enumeration_is_exhaustive() {
        let mut rng = Rng::new(0);
        let pats = sample_straggler_patterns(5, 2, &mut rng, 100);
        assert_eq!(pats.len(), 10);
        let set: std::collections::BTreeSet<_> = pats.iter().cloned().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn paper_code_m10_s7_verifies() {
        let mut rng = Rng::new(7);
        let code = GcCode::generate(10, 7, &mut rng);
        code.verify(&mut rng).unwrap();
        assert_eq!(rank(&code.b), 3);
    }

    #[test]
    fn prop_codes_verify_across_m_s() {
        Prop::new(24).forall("code verifies", |rng, _| {
            let m = rng.range(3, 13);
            let s = rng.range(1, m);
            let code = GcCode::generate(m, s, rng);
            code.verify(rng).unwrap();
        });
    }

    #[test]
    fn neighbor_sets_are_consistent() {
        let mut rng = Rng::new(3);
        let code = GcCode::generate(8, 3, &mut rng);
        for me in 0..8 {
            let inc = code.incoming(me);
            assert_eq!(inc.len(), 3);
            // k is incoming to m  <=>  m is outgoing from k
            for &k in &inc {
                assert!(code.outgoing(k).contains(&me));
            }
        }
    }

    #[test]
    fn all_one_in_row_space_of_any_m_minus_s_rows() {
        // the essence of straggler tolerance: any M-s rows span 1
        let mut rng = Rng::new(11);
        let code = GcCode::generate(7, 3, &mut rng);
        let pats = sample_straggler_patterns(7, 3, &mut rng, 1000);
        for pat in pats {
            let rows: Vec<usize> = (0..7).filter(|i| !pat.contains(i)).collect();
            let bsub = code.b.select_rows(&rows).transpose(); // M x (M-s)
            let ones = vec![1.0; 7];
            assert!(
                solve_consistent(&bsub, &ones).is_some(),
                "pattern {pat:?} cannot reconstruct the sum"
            );
        }
    }
}
