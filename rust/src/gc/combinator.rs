//! Standard GC decoding (paper §II-C2, §III): find the combination row
//! `a_f` for an observed straggler pattern.
//!
//! Given the set of clients whose *complete* partial sums reached the PS,
//! the combinator is a row vector supported on that set with
//! `a_f · B = 1ᵀ`; applying it to the stacked partial sums recovers the
//! exact gradient sum (eq. (9)). By the code construction this is solvable
//! whenever at least `M − s` complete partial sums arrive, and never
//! solvable otherwise — the binary all-or-nothing behaviour the paper
//! analyzes.

use super::codes::GcCode;
use crate::linalg::{solve_consistent, Matrix};

/// Solve for the combinator over the `received` complete partial sums.
///
/// Returns the full-length (`M`) coefficient vector with zeros at
/// non-received positions, or `None` when the pattern is undecodable
/// (fewer than `M − s` rows received — the "overall outage").
pub fn find_combinator(code: &GcCode, received: &[usize]) -> Option<Vec<f64>> {
    find_combinator_rows(&code.b, code.s, received)
}

/// [`find_combinator`] over a raw allocation matrix (e.g. the complete
/// rows of a perturbed `B̃`, which equal the original code rows) — saves
/// callers from materializing a `GcCode` wrapper around a matrix they
/// already hold.
pub fn find_combinator_rows(b: &Matrix, s: usize, received: &[usize]) -> Option<Vec<f64>> {
    let m = b.rows;
    debug_assert_eq!(b.cols, m);
    debug_assert!(received.iter().all(|&r| r < m));
    if received.len() < m - s {
        return None; // information-theoretically impossible
    }
    // Solve  B_F^T · a_F = 1  (M equations, |F| unknowns).
    let bf_t = b.select_rows(received).transpose();
    let ones = vec![1.0; m];
    let af = solve_consistent(&bf_t, &ones)?;
    let mut full = vec![0.0; m];
    for (i, &r) in received.iter().enumerate() {
        full[r] = af[i];
    }
    Some(full)
}

/// Apply a combinator to stacked partial sums (`M×D`, zero rows for
/// non-received clients): the exact-sum recovery of eq. (9). This is the
/// *native* path; the AOT Pallas path routes through `runtime::coded`.
pub fn apply_combinator(a: &[f64], partial_sums: &Matrix) -> Vec<f64> {
    assert_eq!(a.len(), partial_sums.rows);
    let d = partial_sums.cols;
    let mut out = vec![0.0; d];
    for (coef, row) in a.iter().zip(0..partial_sums.rows) {
        if *coef == 0.0 {
            continue;
        }
        let r = partial_sums.row(row);
        for j in 0..d {
            out[j] += coef * r[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::codes::sample_straggler_patterns;
    use crate::testing::{assert_allclose, Prop};
    use crate::util::rng::Rng;

    /// End-to-end check on one pattern: encode gradients into partial sums,
    /// decode with the combinator, compare against the true sum.
    fn check_pattern(code: &GcCode, received: &[usize], rng: &mut Rng) {
        let (m, d) = (code.m, 17);
        let grads = Matrix::from_fn(m, d, |_, _| rng.normal());
        let sums = code.b.matmul(&grads); // complete partial sums
        let a = find_combinator(code, received).expect("pattern should decode");
        // zero out non-received rows, then combine
        let mut masked = Matrix::zeros(m, d);
        for &r in received {
            masked.row_mut(r).copy_from_slice(sums.row(r));
        }
        let got = apply_combinator(&a, &masked);
        let want: Vec<f64> = (0..d).map(|j| (0..m).map(|i| grads[(i, j)]).sum()).collect();
        assert_allclose(&got, &want, 1e-6);
    }

    #[test]
    fn exact_sum_under_max_stragglers() {
        let mut rng = Rng::new(5);
        let code = GcCode::generate(10, 7, &mut rng);
        // all patterns with exactly s stragglers (sampled), plus none
        for pat in sample_straggler_patterns(10, 7, &mut rng, 40) {
            let received: Vec<usize> = (0..10).filter(|i| !pat.contains(i)).collect();
            check_pattern(&code, &received, &mut rng);
        }
        check_pattern(&code, &(0..10).collect::<Vec<_>>(), &mut rng);
    }

    #[test]
    fn too_few_rows_is_binary_failure() {
        let mut rng = Rng::new(6);
        let code = GcCode::generate(8, 3, &mut rng);
        // only M - s - 1 = 4 received: must fail
        assert!(find_combinator(&code, &[0, 2, 4, 6]).is_none());
        assert!(find_combinator(&code, &[]).is_none());
    }

    #[test]
    fn prop_any_m_minus_s_subset_decodes() {
        Prop::new(20).forall("combinator exists", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m - 1);
            let code = GcCode::generate(m, s, rng);
            // random subset of exactly M - s received rows
            let mut received = rng.sample_indices(m, m - s);
            received.sort();
            check_pattern(&code, &received, rng);
        });
    }

    #[test]
    fn combinator_supported_on_received_only() {
        let mut rng = Rng::new(9);
        let code = GcCode::generate(9, 4, &mut rng);
        let received = vec![1, 3, 4, 6, 8];
        let a = find_combinator(&code, &received).unwrap();
        for (i, &coef) in a.iter().enumerate() {
            if !received.contains(&i) {
                assert_eq!(coef, 0.0, "coefficient leaked to straggler {i}");
            }
        }
    }
}
