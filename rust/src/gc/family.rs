//! Structured code families: the dense cyclic construction plus the
//! fractional-repetition (FR) family that scales to M = 10⁵–10⁶ clients
//! and the exact-arithmetic binary family.
//!
//! [`CodeFamily`] names the constructions the stack can run:
//!
//! - **Cyclic** — the paper's dense construction ([`super::GcCode`],
//!   Tandon Alg. 2): random coefficients, RREF/combinator decoding,
//!   O(M²) state. Unchanged semantics; the small-M oracle.
//! - **FractionalRepetition** — [`FrCode`]: M divisible by s+1, allocation
//!   matrix B block-diagonal with all-ones (s+1)×(s+1) groups. B is never
//!   materialized on the hot path; decoding is a per-group membership scan
//!   (one complete delivered row per group pins that group's gradient sum —
//!   the `GC_FR` construction of *Generalized Fractional Repetition Codes
//!   for Binary Coded Computations*), GC⁺ partial recovery is the count of
//!   covered groups, and everything is O(M·(s+1)) in time and memory.
//! - **Binary** — [`super::BinaryCode`] (`gc::binary`): deterministic
//!   {±1} coefficients on the cyclic support, `s` even. Standard decode
//!   and GC⁺ block solves run in exact integer/rational arithmetic
//!   ([`super::binary::IntRref`]) — no pivot-tolerance machinery — with
//!   the dense float mirror retained as the small-M oracle.
//!
//! The FR code satisfies the same decodability identity as the cyclic
//! family — any M−s rows of B span the all-one vector — because erasing at
//! most s rows cannot wipe out all s+1 identical rows of any group. The
//! binary family does *not* carry that identity (±1 rows admit erasure
//! patterns whose span misses 𝟙), so its decode paths test solvability
//! exactly instead of assuming it.

use crate::network::{SparseRealization, SparseSupport};
use crate::parallel::parallel_map;

/// Which code construction a sweep / training run uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CodeFamily {
    /// Dense cyclic gradient code (the paper's construction; small-M oracle).
    #[default]
    Cyclic,
    /// Block-diagonal fractional-repetition code (structured large-M path).
    FractionalRepetition,
    /// Deterministic {±1} cyclic-support code with exact integer decoding.
    Binary,
}

impl CodeFamily {
    /// Stable CLI/JSON identifier (`cyclic` | `fr` | `binary`).
    pub fn name(&self) -> &'static str {
        match self {
            CodeFamily::Cyclic => "cyclic",
            CodeFamily::FractionalRepetition => "fr",
            CodeFamily::Binary => "binary",
        }
    }

    /// Parse the CLI/JSON identifier.
    pub fn parse(s: &str) -> Option<CodeFamily> {
        match s {
            "cyclic" => Some(CodeFamily::Cyclic),
            "fr" | "fractional_repetition" => Some(CodeFamily::FractionalRepetition),
            "binary" => Some(CodeFamily::Binary),
            _ => None,
        }
    }

    /// Family-specific (M, s) constraint check.
    pub fn validate(&self, m: usize, s: usize) -> anyhow::Result<()> {
        anyhow::ensure!(m >= 2, "need at least 2 clients");
        anyhow::ensure!(s >= 1 && s < m, "straggler tolerance s must be in [1, M-1]");
        match self {
            CodeFamily::Cyclic => {}
            CodeFamily::FractionalRepetition => {
                anyhow::ensure!(
                    m % (s + 1) == 0,
                    "fractional repetition needs M divisible by s+1 (M={m}, s={s})"
                );
            }
            CodeFamily::Binary => {
                anyhow::ensure!(
                    s % 2 == 0,
                    "binary needs even s so each ±1 row sums to 1 (M={m}, s={s})"
                );
            }
        }
        Ok(())
    }
}

/// Group-chunk size of the parallel coverage scan: coarse enough that each
/// [`parallel_map`] job amortizes dispatch, fine enough that M = 10⁵–10⁶
/// still splits across every worker.
const DECODE_CHUNK: usize = 4096;

/// A fractional-repetition gradient code: clients are partitioned into
/// M/(s+1) groups of s+1; every member of a group computes the plain sum of
/// its group's gradients (all-ones coefficients). The code is fully
/// determined by (M, s), so this struct stores no matrix — `B` exists only
/// implicitly (or via [`FrCode::dense_b`] for small-M oracle checks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrCode {
    pub m: usize,
    pub s: usize,
}

impl FrCode {
    pub fn new(m: usize, s: usize) -> anyhow::Result<FrCode> {
        CodeFamily::FractionalRepetition.validate(m, s)?;
        Ok(FrCode { m, s })
    }

    /// Number of groups, M/(s+1).
    #[inline]
    pub fn groups(&self) -> usize {
        self.m / (self.s + 1)
    }

    /// Group index of a client row.
    #[inline]
    pub fn group_of(&self, row: usize) -> usize {
        row / (self.s + 1)
    }

    /// Member rows of group `g` (a contiguous range).
    #[inline]
    pub fn members(&self, g: usize) -> std::ops::Range<usize> {
        g * (self.s + 1)..(g + 1) * (self.s + 1)
    }

    /// The implicit incoming-link support (each row listens to the other s
    /// members of its group).
    pub fn sparse_support(&self) -> SparseSupport {
        SparseSupport::group(self.m, self.s)
    }

    /// Serial per-group coverage scan: `covered[g]` ⟺ some member of group
    /// `g` heard its whole group *and* reached the PS. Resizes `covered` to
    /// the group count.
    pub fn covered_into(&self, real: &SparseRealization, covered: &mut Vec<bool>) {
        debug_assert_eq!(real.m(), self.m);
        covered.clear();
        covered.extend(
            (0..self.groups())
                .map(|g| self.members(g).any(|row| real.row_delivered_complete(row))),
        );
    }

    /// Parallel coverage scan with an explicit group-chunk size: the
    /// per-group decode dispatched through [`parallel_map`] (order-
    /// preserving, so the result is identical to [`FrCode::covered_into`]
    /// at any thread count).
    pub fn covered_chunked(
        &self,
        real: &SparseRealization,
        threads: usize,
        chunk: usize,
    ) -> Vec<bool> {
        debug_assert_eq!(real.m(), self.m);
        let g = self.groups();
        let chunk = chunk.max(1);
        let chunks: Vec<(usize, usize)> =
            (0..g).step_by(chunk).map(|a| (a, (a + chunk).min(g))).collect();
        let parts = parallel_map(&chunks, threads, |_, &(a, b)| {
            (a..b)
                .map(|grp| self.members(grp).any(|row| real.row_delivered_complete(row)))
                .collect::<Vec<bool>>()
        });
        parts.concat()
    }

    /// [`FrCode::covered_chunked`] at the default chunk size.
    pub fn covered(&self, real: &SparseRealization, threads: usize) -> Vec<bool> {
        self.covered_chunked(real, threads, DECODE_CHUNK)
    }

    /// Union another attempt's coverage into an accumulator (GC⁺ repeats:
    /// a group decoded on any attempt stays decoded).
    pub fn union_covered(acc: &mut [bool], attempt: &[bool]) {
        debug_assert_eq!(acc.len(), attempt.len());
        for (a, &b) in acc.iter_mut().zip(attempt) {
            *a |= b;
        }
    }

    /// Standard (binary) GC decode succeeds ⟺ every group is covered.
    pub fn all_covered(covered: &[bool]) -> bool {
        covered.iter().all(|&c| c)
    }

    /// Number of covered groups.
    pub fn covered_groups(covered: &[bool]) -> usize {
        covered.iter().filter(|&&c| c).count()
    }

    /// GC⁺ partial-recovery set size |K₄|: every member of a covered group
    /// is recovered (its group's sum is pinned by the delivered row).
    pub fn k4_count(&self, covered: &[bool]) -> usize {
        Self::covered_groups(covered) * (self.s + 1)
    }

    /// Materialize the block-diagonal allocation matrix — O(M²); for the
    /// small-M oracle tests and the trainer's dense aggregation only.
    pub fn dense_b(&self) -> crate::linalg::Matrix {
        crate::linalg::Matrix::from_fn(self.m, self.m, |i, j| {
            if self.group_of(i) == self.group_of(j) {
                1.0
            } else {
                0.0
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve_consistent;

    #[test]
    fn family_names_roundtrip() {
        for fam in
            [CodeFamily::Cyclic, CodeFamily::FractionalRepetition, CodeFamily::Binary]
        {
            assert_eq!(CodeFamily::parse(fam.name()), Some(fam));
        }
        assert_eq!(CodeFamily::parse("fractional_repetition"),
            Some(CodeFamily::FractionalRepetition));
        assert_eq!(CodeFamily::parse("dense"), None);
        assert_eq!(CodeFamily::default(), CodeFamily::Cyclic);
    }

    #[test]
    fn validation_enforces_divisibility() {
        assert!(CodeFamily::Cyclic.validate(10, 7).is_ok());
        assert!(CodeFamily::FractionalRepetition.validate(12, 3).is_ok());
        assert!(CodeFamily::FractionalRepetition.validate(10, 3).is_err());
        assert!(CodeFamily::FractionalRepetition.validate(12, 12).is_err());
        assert!(FrCode::new(10, 3).is_err());
        assert!(CodeFamily::Binary.validate(10, 4).is_ok());
        assert!(CodeFamily::Binary.validate(10, 3).is_err());
    }

    #[test]
    fn groups_and_members() {
        let code = FrCode::new(12, 2).unwrap();
        assert_eq!(code.groups(), 4);
        assert_eq!(code.group_of(0), 0);
        assert_eq!(code.group_of(5), 1);
        assert_eq!(code.members(2).collect::<Vec<_>>(), vec![6, 7, 8]);
    }

    #[test]
    fn coverage_scan_matches_hand_built_realization() {
        let code = FrCode::new(6, 1).unwrap(); // 3 groups of 2
        let sup = code.sparse_support();
        let mut real = SparseRealization::perfect(&sup);
        // group 0: row 0 delivered+complete → covered
        // group 1: row 2 uplink down, row 3 missing its incoming → uncovered
        real.tau[2] = false;
        real.t[3] = false; // row 3, idx 0
        // group 2: row 4 complete but uplink down; row 5 fine → covered
        real.tau[4] = false;
        let mut covered = Vec::new();
        code.covered_into(&real, &mut covered);
        assert_eq!(covered, vec![true, false, true]);
        assert!(!FrCode::all_covered(&covered));
        assert_eq!(FrCode::covered_groups(&covered), 2);
        assert_eq!(code.k4_count(&covered), 4);
    }

    #[test]
    fn parallel_scan_matches_serial_across_chunkings() {
        let code = FrCode::new(60, 2).unwrap();
        let sup = code.sparse_support();
        let mut rng = crate::util::rng::Rng::new(5);
        let net = crate::network::Network::homogeneous(60, 0.4, 0.3);
        for _ in 0..20 {
            let real = SparseRealization::sample(&sup, &net, &mut rng);
            let mut serial = Vec::new();
            code.covered_into(&real, &mut serial);
            for chunk in [1, 3, 7, 4096] {
                for threads in [1, 4] {
                    assert_eq!(code.covered_chunked(&real, threads, chunk), serial);
                }
            }
        }
    }

    #[test]
    fn union_accumulates_gc_plus_repeats() {
        let mut acc = vec![false, true, false];
        FrCode::union_covered(&mut acc, &[true, false, false]);
        assert_eq!(acc, vec![true, true, false]);
    }

    #[test]
    fn dense_b_is_block_diagonal_and_decodable() {
        let code = FrCode::new(8, 1).unwrap();
        let b = code.dense_b();
        for i in 0..8 {
            for j in 0..8 {
                let want = if i / 2 == j / 2 { 1.0 } else { 0.0 };
                assert_eq!(b[(i, j)], want);
            }
        }
        // any M - s rows span the all-one vector (decodability identity):
        // drop one row per trial and solve  B_Fᵀ · a = 𝟙
        for drop in 0..8 {
            let rows: Vec<usize> = (0..8).filter(|&r| r != drop).collect();
            let bsub = b.select_rows(&rows).transpose();
            assert!(solve_consistent(&bsub, &vec![1.0; 8]).is_some(), "dropping row {drop}");
        }
    }
}
