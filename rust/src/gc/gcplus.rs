//! GC⁺: the complementary decoding mechanism (paper §VI, Algorithms 1–2).
//!
//! When the standard GC decoder fails (fewer than `M−s` complete partial
//! sums), the PS does not discard the incomplete partial sums: it stacks the
//! received coefficient rows `B̂(r) = [B̂_1; …; B̂_{t_r}]` across repeated
//! attempts and row-reduces them. Every RREF row that is a unit vector `e_j`
//! pins the individual local model `g_j`; the same row of the tracked
//! transform, applied to the stacked partial-sum payloads, extracts it
//! (`linalg::rref`). The global model is then the average over the decoded
//! subset `K₄` (paper eq. (23)).
//!
//! Two detectors are provided:
//! - [`decode`] — exact: finds *every* decodable subset (unit RREF rows);
//! - [`decode_approx`] — the paper's Algorithm 2, a cheaper full-rank-block
//!   test (footnote 1 calls it an approximation). It succeeds only when all
//!   nonzero columns are simultaneously decodable; `decode` subsumes it.
//!
//! Both run on the incremental engine ([`crate::linalg::IncrementalRref`]);
//! the until-decode hot loops use the persistent [`GcPlusDecoder`], which
//! eliminates each newly delivered row against the existing reduced form
//! instead of re-factoring the whole growing stack every block — same
//! results, bit for bit, at `O(rows · rank · M)` per trial instead of
//! `O(blocks² · M²)`.

use crate::gc::codes::GcCode;
use crate::linalg::{IncrementalRref, Matrix, PeelingDecoder};
use crate::network::Realization;

/// Erasure-perturbed coefficients `B̃ = B ∘ T(r)` (paper eq. (22), before
/// the uplink mask): entry `(m,k)` is erased iff the k→m link was down.
/// The diagonal is never erased (no transmission to self).
pub fn perturb(code: &GcCode, real: &Realization) -> Matrix {
    let m = code.m;
    Matrix::from_fn(m, m, |i, j| {
        if i == j || real.t[i][j] {
            code.b[(i, j)]
        } else {
            0.0
        }
    })
}

/// Row indices whose partial sums reached the PS (`tau` mask).
pub fn delivered_rows(tau: &[bool]) -> Vec<usize> {
    tau.iter()
        .enumerate()
        .filter_map(|(i, &up)| up.then_some(i))
        .collect()
}

/// Whether a perturbed row is *complete* (heard all incoming neighbors).
pub fn is_complete_row(code: &GcCode, bt: &Matrix, row: usize) -> bool {
    code.incoming_iter(row).all(|k| bt[(row, k)] != 0.0)
}

/// One communication attempt as observed by the PS.
#[derive(Clone, Debug)]
pub struct Attempt {
    /// Perturbed coefficients `B̃ = B ∘ T` of this attempt, all `M` rows.
    pub perturbed: Matrix,
    /// Which rows were delivered to the PS (uplink up).
    pub delivered: Vec<usize>,
    /// Which *delivered* rows are complete partial sums.
    pub complete: Vec<usize>,
}

impl Attempt {
    pub fn observe(code: &GcCode, real: &Realization) -> Attempt {
        let mut att = Attempt::empty();
        Attempt::observe_into(code, real, &mut att);
        att
    }

    /// An empty buffer suitable for [`Attempt::observe_into`] reuse.
    pub fn empty() -> Attempt {
        Attempt {
            perturbed: Matrix::zeros(0, 0),
            delivered: Vec::new(),
            complete: Vec::new(),
        }
    }

    /// [`Attempt::observe`] into a reused buffer: resizes `out` on first
    /// use, allocates nothing on steady-state reuse (the Monte-Carlo
    /// hot-loop contract — one `Attempt` per worker serves every trial).
    pub fn observe_into(code: &GcCode, real: &Realization, out: &mut Attempt) {
        let m = code.m;
        debug_assert_eq!(real.m(), m);
        if out.perturbed.rows != m || out.perturbed.cols != m {
            out.perturbed = Matrix::zeros(m, m);
        }
        for i in 0..m {
            let brow = &code.b.data[i * m..(i + 1) * m];
            let trow = &real.t[i];
            let prow = out.perturbed.row_mut(i);
            for j in 0..m {
                prow[j] = if i == j || trow[j] { brow[j] } else { 0.0 };
            }
        }
        out.delivered.clear();
        out.complete.clear();
        for (i, &up) in real.tau.iter().enumerate() {
            if up {
                out.delivered.push(i);
                if is_complete_row(code, &out.perturbed, i) {
                    out.complete.push(i);
                }
            }
        }
    }

    /// The coefficient rows the PS actually holds from this attempt
    /// (delivered rows of the perturbed matrix), in `delivered` order.
    pub fn received_coeffs(&self) -> Matrix {
        self.perturbed.select_rows(&self.delivered)
    }
}

/// Result of a GC⁺ decode over the stacked received rows.
#[derive(Clone, Debug)]
pub struct Decoded {
    /// Decodable clients `K₄(r)`, ascending.
    pub k4: Vec<usize>,
    /// Extraction weights: row i of `weights` (length = stacked rows)
    /// applied to the stacked payload matrix recovers `g_{k4[i]}`.
    pub weights: Matrix,
    /// Numerical rank of the stacked coefficient matrix (for diagnostics
    /// and the Lemma 2/3 rank analyses).
    pub rank: usize,
}

/// Extract the [`Decoded`] of the engine's current state: every unit pivot
/// row pins its column's local model; the transform rows are the
/// extraction weights. Shared by [`decode`], [`decode_approx`], and
/// [`GcPlusDecoder::decode`], so every path produces bit-identical output
/// for the same pushed row stream.
fn extract_decoded(inc: &IncrementalRref) -> Decoded {
    let n = inc.rows();
    let mut k4 = Vec::new();
    let mut rows = Vec::new();
    for (c, i) in inc.decodable() {
        k4.push(c);
        rows.push(i);
    }
    let mut weights = Matrix::zeros(k4.len(), n);
    for (w, &i) in rows.iter().enumerate() {
        weights.row_mut(w).copy_from_slice(inc.t_row(i));
    }
    Decoded { k4, weights, rank: inc.rank() }
}

/// Exact GC⁺ detection over the stacked coefficient matrix (rows × M).
///
/// Returns the set of *all* individually decodable local models and the
/// transform rows that extract them. Empty `k4` means the complementary
/// decoder failed too (the PS decodes nothing this round).
///
/// This is the batch convenience form: it runs the rows through a fresh
/// [`IncrementalRref`]; a persistent [`GcPlusDecoder`] fed the same rows
/// decodes bit-identically without re-factoring the stack per block.
pub fn decode(stacked: &Matrix) -> Decoded {
    if stacked.rows == 0 {
        return Decoded { k4: Vec::new(), weights: Matrix::zeros(0, 0), rank: 0 };
    }
    let mut inc = IncrementalRref::with_capacity(stacked.cols, stacked.rows);
    inc.push_matrix(stacked);
    extract_decoded(&inc)
}

/// The paper's Algorithm 2 (approximate detection): decode only when the
/// nonzero columns of the RREF form a full-column-rank block, i.e. when
/// `|K₄| = |K₅|` — every nonzero column is a pivot. (The paper states the
/// condition as `|K₄| < |K₅|`, which is unsatisfiable since
/// `|K₅| = rank ≤ |K₄|`; the intended test is equality — "determined or
/// overdetermined submatrix".)
pub fn decode_approx(stacked: &Matrix) -> Decoded {
    if stacked.rows == 0 {
        return Decoded { k4: Vec::new(), weights: Matrix::zeros(0, 0), rank: 0 };
    }
    let mut inc = IncrementalRref::with_capacity(stacked.cols, stacked.rows);
    inc.push_matrix(stacked);
    // K4: nonzero columns of E;  K5: nonzero rows of E (= rank).
    if inc.nonzero_col_count() != inc.rank() {
        return Decoded { k4: Vec::new(), weights: Matrix::zeros(0, 0), rank: inc.rank() };
    }
    // Full column rank on the nonzero block: every nonzero column is a
    // pivot with a unit RREF row — identical to the exact extraction.
    let dec = extract_decoded(&inc);
    debug_assert_eq!(dec.k4.len(), dec.rank);
    dec
}

/// Persistent per-trial GC⁺ decoder: the degree-one peeling front-end over
/// the incremental engine, plus the attempt-feeding conventions of
/// Algorithm 1's until-decode loop.
///
/// Feed each communication attempt's delivered coefficient rows with
/// [`push_attempt`](GcPlusDecoder::push_attempt) (rows stream straight out
/// of the attempt's perturbed matrix — no intermediate stack is ever
/// materialized), poll [`decodable_count`](GcPlusDecoder::decodable_count)
/// after each block (allocation-free), and call
/// [`decode`](GcPlusDecoder::decode) once something is decodable. Rows
/// whose support is already resolved down to degree ≤ 1 take the
/// [`PeelingDecoder`] fast path past the dense elimination; the engine
/// state — and therefore the result — stays bit-for-bit the [`decode`] of
/// the equivalent [`stack_attempts`] matrix (`tests/decode_equivalence.rs`).
/// [`reset`](GcPlusDecoder::reset) recycles all buffers for the next trial.
pub struct GcPlusDecoder {
    peel: PeelingDecoder,
}

impl GcPlusDecoder {
    pub fn new(m: usize) -> GcPlusDecoder {
        GcPlusDecoder { peel: PeelingDecoder::with_capacity(m, 4 * m.max(1)) }
    }

    /// Clear for a fresh trial over `m` clients, keeping all allocations.
    pub fn reset(&mut self, m: usize) {
        self.peel.reset(m);
    }

    /// Push the delivered coefficient rows of one attempt, in `delivered`
    /// order (the same order [`stack_attempts`] emits).
    pub fn push_attempt(&mut self, att: &Attempt) {
        for &r in &att.delivered {
            self.peel.push_row(att.perturbed.row(r));
        }
    }

    /// Push one received coefficient row.
    pub fn push_row(&mut self, coeffs: &[f64]) {
        self.peel.push_row(coeffs);
    }

    /// Coefficient rows received so far (the stacked-matrix height).
    pub fn rows(&self) -> usize {
        self.peel.rows()
    }

    /// Numerical rank of the received stack (Lemma 2/3 diagnostics).
    pub fn rank(&self) -> usize {
        self.peel.rank()
    }

    /// `|K₄|` of the current stack without allocating — the per-block
    /// success test of the until-decode loop.
    pub fn decodable_count(&self) -> usize {
        self.peel.decodable_count()
    }

    /// Rows resolved by the peeling fast path / forwarded to the dense
    /// elimination (telemetry + per-round sweep CSV columns).
    pub fn peel_split(&self) -> (usize, usize) {
        (self.peel.peeled(), self.peel.forwarded())
    }

    /// Record one decode episode's work into a telemetry shard: rows
    /// pushed, peeling fast-path vs forwarded split, and final rank
    /// (counter totals, log₂ histograms, and max-gauges). Integer bumps
    /// only — safe in the Monte-Carlo hot loops armed or disarmed.
    pub fn harvest(&self, sh: &mut crate::telemetry::Shard) {
        use crate::telemetry::metric;
        let rows = self.rows() as u64;
        let rank = self.rank() as u64;
        let (peeled, forwarded) = self.peel_split();
        sh.inc(metric::DEC_EPISODES);
        sh.add(metric::DEC_ROWS_PUSHED, rows);
        sh.add(metric::DEC_ROWS_PEELED, peeled as u64);
        sh.add(metric::DEC_ROWS_FORWARDED, forwarded as u64);
        sh.observe(metric::H_DEC_ROWS, rows);
        sh.observe(metric::H_DEC_RANK, rank);
        sh.observe(metric::H_DEC_PEELED, peeled as u64);
        sh.gauge_max(metric::DEC_MAX_RANK, rank);
        sh.gauge_max(metric::DEC_MAX_ROWS, rows);
    }

    /// Full decode of the current stack (identical to batch [`decode`] of
    /// the stacked rows).
    pub fn decode(&self) -> Decoded {
        if self.peel.rows() == 0 {
            return Decoded { k4: Vec::new(), weights: Matrix::zeros(0, 0), rank: 0 };
        }
        extract_decoded(self.peel.engine())
    }

    /// The underlying engine (rank/pivot introspection, audit checks) —
    /// bit-identical to a pure [`IncrementalRref`] fed the same rows.
    pub fn engine(&self) -> &IncrementalRref {
        self.peel.engine()
    }
}

/// Stack the received coefficient rows of several attempts
/// (`B̂(r) = [B̂_1; …; B̂_{t_r}]`, delivered rows only). Rows stream
/// directly from each attempt's perturbed matrix into one output
/// allocation — no intermediate per-attempt matrices.
pub fn stack_attempts(attempts: &[Attempt]) -> Matrix {
    let cols = attempts.first().map(|a| a.perturbed.cols).unwrap_or(0);
    let rows: usize = attempts.iter().map(|a| a.delivered.len()).sum();
    let mut out = Matrix::zeros(rows, cols);
    let mut i = 0;
    for att in attempts {
        debug_assert_eq!(att.perturbed.cols, cols, "mixed attempt widths");
        for &r in &att.delivered {
            out.row_mut(i).copy_from_slice(att.perturbed.row(r));
            i += 1;
        }
    }
    out
}

/// Pad decode weights into the fixed `[M, MT]` shape consumed by the AOT
/// `coded_decode` Pallas artifact: row `m` holds the extraction weights for
/// client `m` if `m ∈ K₄`, zeros otherwise; columns beyond the actually
/// received row count are zero.
pub fn pad_weights(dec: &Decoded, m: usize, mt: usize) -> Matrix {
    assert!(dec.weights.cols <= mt, "stacked rows {} exceed MT {mt}", dec.weights.cols);
    let mut w = Matrix::zeros(m, mt);
    for (i, &client) in dec.k4.iter().enumerate() {
        w.row_mut(client)[..dec.weights.cols].copy_from_slice(dec.weights.row(i));
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Network;
    use crate::testing::{assert_allclose, Prop};
    use crate::util::rng::Rng;

    /// Build payloads S = stacked_coeffs * G and verify extraction.
    fn check_extraction(stacked: &Matrix, dec: &Decoded, rng: &mut Rng) {
        let m = stacked.cols;
        let d = 13;
        let g = Matrix::from_fn(m, d, |_, _| rng.normal_ms(0.0, 3.0));
        let s = stacked.matmul(&g);
        let got = dec.weights.matmul(&s);
        for (i, &client) in dec.k4.iter().enumerate() {
            assert_allclose(got.row(i), g.row(client), 1e-6);
        }
    }

    #[test]
    fn perturb_masks_links() {
        let mut rng = Rng::new(1);
        let code = GcCode::generate(6, 2, &mut rng);
        let mut real = Realization::perfect(6);
        real.t[0][1] = false; // link 1 -> 0 down
        let bt = perturb(&code, &real);
        assert_eq!(bt[(0, 1)], 0.0);
        assert_eq!(bt[(0, 0)], code.b[(0, 0)]);
        assert!(!is_complete_row(&code, &bt, 0));
        assert!(is_complete_row(&code, &bt, 1));
    }

    #[test]
    fn perfect_round_decodes_everyone() {
        let mut rng = Rng::new(2);
        let code = GcCode::generate(10, 7, &mut rng);
        // t_r = 2 perfect attempts with independent codes
        let code2 = GcCode::generate(10, 7, &mut rng);
        let a1 = Attempt::observe(&code, &Realization::perfect(10));
        let a2 = Attempt::observe(&code2, &Realization::perfect(10));
        // unperturbed stack: rank (M-s-1)*tr + 1 = 5 < 10 -> cannot decode all,
        // but the standard path applies since all rows are complete
        assert_eq!(a1.complete.len(), 10);
        let stacked = stack_attempts(&[a1, a2]);
        let dec = decode(&stacked);
        assert_eq!(dec.rank, (10 - 7 - 1) * 2 + 1); // Lemma 3
        check_extraction(&stacked, &dec, &mut rng);
    }

    #[test]
    fn c2c_outages_increase_rank_and_unlock_decoding() {
        // Setting with heavy client-to-client erasures: perturbation raises
        // the rank (Lemma 2) and GC+ decodes a non-empty subset even though
        // standard GC fails.
        let net = Network::fig6_setting(4, 10); // p_m=0.75, p_mk=0.8
        let mut rng = Rng::new(3);
        let mut decoded_any = 0;
        let mut rank_above_base = 0;
        let trials = 200;
        for _ in 0..trials {
            let code1 = GcCode::generate(10, 7, &mut rng);
            let code2 = GcCode::generate(10, 7, &mut rng);
            let r1 = Realization::sample(&net, &mut rng);
            let r2 = Realization::sample(&net, &mut rng);
            let a1 = Attempt::observe(&code1, &r1);
            let a2 = Attempt::observe(&code2, &r2);
            let stacked = stack_attempts(&[a1, a2]);
            if stacked.rows == 0 {
                continue;
            }
            let dec = decode(&stacked);
            if dec.rank > 3 {
                rank_above_base += 1;
            }
            if !dec.k4.is_empty() {
                decoded_any += 1;
                check_extraction(&stacked, &dec, &mut rng);
            }
        }
        assert!(rank_above_base > trials / 2, "rank enhancement not observed");
        assert!(decoded_any > trials / 4, "GC+ decoded nothing in most trials");
    }

    #[test]
    fn approx_is_subset_of_exact() {
        let net = Network::fig6_setting(3, 8);
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            let code = GcCode::generate(8, 5, &mut rng);
            let real = Realization::sample(&net, &mut rng);
            let a = Attempt::observe(&code, &real);
            let stacked = stack_attempts(&[a]);
            if stacked.rows == 0 {
                continue;
            }
            let ex = decode(&stacked);
            let ap = decode_approx(&stacked);
            for c in &ap.k4 {
                assert!(ex.k4.contains(c), "approx decoded {c} that exact missed");
            }
            if !ap.k4.is_empty() {
                check_extraction(&stacked, &ap, &mut rng);
            }
        }
    }

    #[test]
    fn prop_extraction_correct_under_random_erasures() {
        Prop::new(40).forall("gcplus extraction", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m);
            let tr = rng.range(1, 4);
            let p = rng.uniform(0.1, 0.9);
            let net = Network::homogeneous(m, p, p);
            let attempts: Vec<Attempt> = (0..tr)
                .map(|_| {
                    let code = GcCode::generate(m, s, rng);
                    Attempt::observe(&code, &Realization::sample(&net, rng))
                })
                .collect();
            let stacked = stack_attempts(&attempts);
            if stacked.rows == 0 {
                return;
            }
            let dec = decode(&stacked);
            check_extraction(&stacked, &dec, rng);
            // padded weights route: same numbers through the [M, MT] shape
            let mt = m * 3;
            let w = pad_weights(&dec, m, mt);
            let d = 7;
            let g = Matrix::from_fn(m, d, |_, _| rng.normal());
            let s_pay = stacked.matmul(&g);
            let mut s_pad = Matrix::zeros(mt, d);
            for r in 0..s_pay.rows {
                s_pad.row_mut(r).copy_from_slice(s_pay.row(r));
            }
            let out = w.matmul(&s_pad);
            for &client in &dec.k4 {
                assert_allclose(out.row(client), g.row(client), 1e-6);
            }
        });
    }

    #[test]
    fn empty_stack_decodes_nothing() {
        let dec = decode(&Matrix::zeros(0, 10));
        assert!(dec.k4.is_empty());
        let ap = decode_approx(&Matrix::zeros(0, 10));
        assert!(ap.k4.is_empty());
    }
}
