//! Gradient-coding core: cyclic code construction, the structured
//! fractional-repetition and exact ±1 binary families, the standard
//! (combinator) GC decoder, the complementary GC⁺ decoder with its
//! peeling front-end, and the rank analyses that underpin the paper's
//! reliability results.

pub mod approx;
pub mod binary;
pub mod byzantine;
pub mod codes;
pub mod combinator;
pub mod family;
pub mod gcplus;
pub mod rank;

pub use approx::{approx_sum, combine_mean, relative_residual, residual_bucket, RESIDUAL_BUCKETS};
pub use binary::{BinaryCode, IntRref};
pub use byzantine::{
    audit_rows, audit_rows_int, audit_rows_pure, payload_check_fails, symbolic_check_fails,
    symbolic_check_fails_exact, Audit,
};
pub use codes::GcCode;
pub use combinator::{apply_combinator, find_combinator};
pub use family::{CodeFamily, FrCode};
pub use gcplus::{decode, decode_approx, stack_attempts, Attempt, Decoded, GcPlusDecoder};
