//! Gradient-coding core: cyclic code construction, the structured
//! fractional-repetition family, the standard (binary) GC decoder, the
//! complementary GC⁺ decoder, and the rank analyses that underpin the
//! paper's reliability results.

pub mod byzantine;
pub mod codes;
pub mod combinator;
pub mod family;
pub mod gcplus;
pub mod rank;

pub use byzantine::{audit_rows, payload_check_fails, symbolic_check_fails, Audit};
pub use codes::GcCode;
pub use combinator::{apply_combinator, find_combinator};
pub use family::{CodeFamily, FrCode};
pub use gcplus::{decode, decode_approx, stack_attempts, Attempt, Decoded, GcPlusDecoder};
