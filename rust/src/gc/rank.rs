//! Rank analysis of perturbed / stacked GC matrices (paper §VI-B,
//! Lemmas 2–3, Appendix C).
//!
//! - Lemma 2: client-to-client outages can only *increase* the rank of the
//!   coefficient matrix: `rank(B̃) ≥ M − s` always, and when at least `M−s`
//!   rows are unperturbed, `rank(B̃) = min{M, M−s+n}` where `n` is the
//!   maximum number of erased entries no two of which share a row or column
//!   (a maximum bipartite matching over the erasure pattern of perturbed
//!   rows).
//! - Lemma 3: vertically stacking `t_r` independently drawn codes gives
//!   `rank(B(r)) = min{(M−s−1)·t_r + 1, M}` — each code contributes `M−s`
//!   fresh dimensions but all share the all-one vector.

use crate::gc::codes::GcCode;
use crate::linalg::Matrix;
use crate::network::Realization;

/// Erased coefficient positions of `B̃` relative to `B` (off-diagonal
/// support entries whose link was down).
pub fn erased_positions(code: &GcCode, real: &Realization) -> Vec<(usize, usize)> {
    let m = code.m;
    let mut out = Vec::new();
    for i in 0..m {
        for &k in &code.incoming(i) {
            if !real.t[i][k] {
                out.push((i, k));
            }
        }
    }
    out
}

/// Rows with at least one erased incoming coefficient.
pub fn perturbed_rows(code: &GcCode, real: &Realization) -> Vec<usize> {
    let m = code.m;
    (0..m)
        .filter(|&i| code.incoming(i).iter().any(|&k| !real.t[i][k]))
        .collect()
}

/// Maximum bipartite matching over a set of (row, col) positions:
/// the largest subset with all rows distinct and all cols distinct.
/// Classic augmenting-path algorithm — the instance is at most M×M.
pub fn max_matching(positions: &[(usize, usize)], rows: usize, cols: usize) -> usize {
    // adjacency: row -> cols
    let mut adj = vec![Vec::new(); rows];
    for &(r, c) in positions {
        adj[r].push(c);
    }
    let mut match_col: Vec<Option<usize>> = vec![None; cols];

    fn try_augment(
        r: usize,
        adj: &[Vec<usize>],
        match_col: &mut [Option<usize>],
        visited: &mut [bool],
    ) -> bool {
        for &c in &adj[r] {
            if visited[c] {
                continue;
            }
            visited[c] = true;
            if match_col[c].is_none()
                || try_augment(match_col[c].unwrap(), adj, match_col, visited)
            {
                match_col[c] = Some(r);
                return true;
            }
        }
        false
    }

    let mut size = 0;
    for r in 0..rows {
        if adj[r].is_empty() {
            continue;
        }
        let mut visited = vec![false; cols];
        if try_augment(r, &adj, &mut match_col, &mut visited) {
            size += 1;
        }
    }
    size
}

/// Lemma 2's closed-form rank of the perturbed matrix (eq. (24)), stated
/// for the regime with at least `M−s` unperturbed rows:
/// `min{M, M−s+n}` with `n` the max matching of erased positions.
///
/// Appendix C derives this by transforming each perturbed row into a
/// vector supported on its erased positions; `n` is then the *generic*
/// (structural) rank of that erasure-pattern block. The formula is an
/// **upper bound** on the true rank: it neglects the (measure-nonzero,
/// because the transformed values are tied to `B`'s structure) overlap
/// between the erasure block's span and the unperturbed rows' span. Our
/// property tests confirm it upper-bounds the measured rank everywhere and
/// is tight in the large majority of draws (see
/// `lemma2_formula_upper_bounds_and_usually_tight`).
///
/// Returns `None` when the precondition does not hold.
pub fn lemma2_rank(code: &GcCode, real: &Realization) -> Option<usize> {
    let m = code.m;
    let pert = perturbed_rows(code, real);
    if m - pert.len() < m - code.s {
        // fewer than M-s unperturbed rows: outside the lemma's stated regime
        return None;
    }
    let erased = erased_positions(code, real);
    let n = max_matching(&erased, m, m);
    Some((m - code.s + n).min(m))
}

/// Lemma 3's closed-form rank of the vertical stack of `t_r` independent
/// unperturbed codes.
pub fn lemma3_rank(m: usize, s: usize, tr: usize) -> usize {
    ((m - s - 1) * tr + 1).min(m)
}

/// Stack `t_r` fresh codes' B matrices (for Lemma 3 validation).
pub fn stack_codes(codes: &[GcCode]) -> Matrix {
    let mats: Vec<&Matrix> = codes.iter().map(|c| &c.b).collect();
    Matrix::vstack(&mats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::gcplus::perturb;
    use crate::linalg::rank;
    use crate::network::Network;
    use crate::testing::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn matching_known_cases() {
        // diagonal positions: perfect matching
        let pos: Vec<(usize, usize)> = (0..4).map(|i| (i, i)).collect();
        assert_eq!(max_matching(&pos, 4, 4), 4);
        // all in one column: matching 1
        let pos: Vec<(usize, usize)> = (0..4).map(|i| (i, 2)).collect();
        assert_eq!(max_matching(&pos, 4, 4), 1);
        // all in one row: matching 1
        let pos: Vec<(usize, usize)> = (0..4).map(|j| (1, j)).collect();
        assert_eq!(max_matching(&pos, 4, 4), 1);
        // empty
        assert_eq!(max_matching(&[], 4, 4), 0);
        // classic 3x3 cross pattern
        let pos = [(0, 0), (0, 1), (1, 0), (2, 2)];
        assert_eq!(max_matching(&pos, 3, 3), 3);
    }

    #[test]
    fn lemma2_lower_bound_always_holds() {
        // rank(B~) >= M - s w.p. 1 for ANY erasure pattern (strict claim)
        Prop::new(60).forall("rank lower bound", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m);
            let p = rng.uniform(0.0, 1.0);
            let code = GcCode::generate(m, s, rng);
            let net = Network::homogeneous(m, 0.0, p);
            let real = Realization::sample(&net, rng);
            let bt = perturb(&code, &real);
            let rk = rank(&bt);
            assert!(rk >= m - s, "rank {rk} < M-s = {} (m={m}, s={s})", m - s);
        });
    }

    #[test]
    fn lemma2_formula_upper_bounds_and_usually_tight() {
        let mut rng = Rng::new(0xBEEF);
        let mut applicable = 0usize;
        let mut tight = 0usize;
        for _ in 0..600 {
            let m = rng.range(5, 11);
            let s = rng.range(1, m);
            let p = rng.uniform(0.0, 0.5);
            let code = GcCode::generate(m, s, &mut rng);
            let net = Network::homogeneous(m, 0.0, p);
            let real = Realization::sample(&net, &mut rng);
            if let Some(predicted) = lemma2_rank(&code, &real) {
                applicable += 1;
                let measured = rank(&perturb(&code, &real));
                assert!(
                    measured <= predicted,
                    "formula must upper-bound rank: m={m} s={s} measured {measured} > {predicted}"
                );
                assert!(measured >= m - s, "Lemma 2 lower bound violated");
                if measured == predicted {
                    tight += 1;
                }
            }
        }
        assert!(applicable > 100, "too few applicable draws: {applicable}");
        // eq. (24) is generically exact; overlap corrections are rare
        assert!(
            tight as f64 > 0.85 * applicable as f64,
            "formula tight in only {tight}/{applicable} draws"
        );
    }

    #[test]
    fn lemma3_formula_matches_measured_rank() {
        Prop::new(30).forall("lemma3 formula", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m);
            let tr = rng.range(1, 5);
            let codes: Vec<GcCode> = (0..tr).map(|_| GcCode::generate(m, s, rng)).collect();
            let stacked = stack_codes(&codes);
            assert_eq!(rank(&stacked), lemma3_rank(m, s, tr), "m={m} s={s} tr={tr}");
        });
    }

    #[test]
    fn lemma3_never_decreases_with_tr() {
        for tr in 1..6 {
            assert!(lemma3_rank(10, 7, tr + 1) >= lemma3_rank(10, 7, tr));
        }
        assert_eq!(lemma3_rank(10, 7, 1), 3);
        assert_eq!(lemma3_rank(10, 7, 2), 5);
        assert_eq!(lemma3_rank(10, 7, 5), 10); // saturates at M
    }

    #[test]
    fn paper_m10_s7_tr2_rank5() {
        // the Fig. 6 configuration: stacked unperturbed rank is 5 < 10,
        // which is why perturbation ("benefiting from disrupted links") is
        // essential for full recovery.
        assert_eq!(lemma3_rank(10, 7, 2), 5);
    }
}
