//! # CoGC — Cooperative Gradient Coding
//!
//! Production-grade reproduction of *Cooperative Gradient Coding* (Weng,
//! Ren, Xiao, Skoglund; CS.DC 2025): a gradient-sharing gradient-coding
//! framework for federated learning over unreliable links, with the
//! standard binary GC decoder and the complementary GC⁺ decoder.
//!
//! Three layers:
//! - **L3 (this crate)**: the coordinator — cyclic GC codes, erasure network
//!   simulation, CoGC round engine, GC/GC⁺ decoding, outage + convergence +
//!   privacy theory, figure harnesses.
//! - **L2/L1 (python/, build-time only)**: JAX models + Pallas kernels,
//!   AOT-lowered to HLO text and executed through the PJRT CPU client
//!   (`runtime`), never touching python at run time.
//!
//! Model execution is backend-selectable (`runtime::Backend`): the PJRT
//! artifacts above, or a **native pure-rust backend** (`runtime::native`)
//! with hand-rolled forward/backward that runs every training figure on a
//! clean offline checkout — no artifacts, no bindings, bit-deterministic.
//!
//! Beyond the paper's memoryless links, the [`scenario`] subsystem supplies
//! stateful channel dynamics — Gilbert–Elliott bursts, correlated fading,
//! deadline stragglers — behind a declarative JSON scenario registry
//! (`cogc scenario list|run`), threaded through the sim layer, the outage
//! estimators, and the trainer with the same bit-deterministic parallel
//! sweep guarantees.
//!
//! Quickstart: see `examples/quickstart.rs`; figures: `cogc fig4` …
//! `cogc fig12`; theory: `cogc theory`, `cogc privacy`, `cogc design`;
//! channel scenarios: `cogc scenario run <name>`.

// Index-heavy linear-algebra substrate and many-parameter figure harnesses
// trip these clippy *style* lints without being wrong; correctness lints
// stay enabled (CI runs `cargo clippy -- -D warnings`).
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::manual_memcpy)]

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod figures;
pub mod gc;
pub mod linalg;
pub mod metrics;
pub mod network;
pub mod outage;
pub mod parallel;
pub mod privacy;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod util;
