//! Least-squares solves over delivered coded stacks — the degraded-mode
//! complement to the exact RREF decode.
//!
//! When the stacked coefficient rows cannot reach a target combination
//! exactly (GC outage, GC⁺ empty `K₄`), the delivered rows still pin the
//! *closest* reachable combination: the orthogonal projection of the
//! target onto the row space. [`lstsq_rows`] computes the optimal weights
//! `w` minimizing `‖wᵀA − target‖₂` straight from the incremental
//! engine's reduced state — no re-factorization of the stack:
//!
//! - the engine's stored rows `e_i` are a basis of `rowspace(A)` with
//!   known transforms `t_i` (`t_i · A = e_i`), so the projection solve
//!   collapses to the `rank × rank` Gram system `G α = E·target`,
//!   `G[i][j] = e_i · e_j`, solved by Cholesky;
//! - the stack-row weights are then `w = Σ αᵢ t_i`, and the residual norm
//!   `‖target − proj‖₂` comes from the same inner products
//!   (`‖t‖² − bᵀα`), so the whole solve is `O(rank²·M + rank³)`.
//!
//! On a full-rank delivery the row space is all of `ℝᴹ`, the projection
//! is the target itself, and the weights reproduce the exact decode to
//! machine precision (pinned against the dense oracle in tests). The
//! residual norm and the effective-coverage count (how many clients the
//! row space touches at all) are the two diagnostics the degraded-mode
//! pipeline reports upstream.

use crate::linalg::rref::IncrementalRref;

/// One least-squares solve over a delivered stack.
#[derive(Clone, Debug, PartialEq)]
pub struct Lstsq {
    /// Optimal stack-row weights, one per pushed row (stack order):
    /// `weights · A` is the closest reachable combination to the target.
    pub weights: Vec<f64>,
    /// `‖target − weights·A‖₂` — 0 (to rounding) iff the target lies in
    /// the row space, i.e. the exact decoder would also have succeeded.
    pub residual: f64,
    /// Effective coverage: columns (clients) the row space touches at
    /// all. Columns outside it contribute their full target weight to the
    /// residual no matter what.
    pub covered: usize,
}

/// Solve the `n × n` SPD system `G x = b` in place by Cholesky
/// (`g` row-major, overwritten with the factor; `b` overwritten with the
/// solution). Returns `false` when a pivot collapses (G not numerically
/// positive definite).
fn cholesky_solve(g: &mut [f64], n: usize, b: &mut [f64]) -> bool {
    for j in 0..n {
        let mut d = g[j * n + j];
        for k in 0..j {
            d -= g[j * n + k] * g[j * n + k];
        }
        if !(d > 0.0) || !d.is_finite() {
            return false;
        }
        let l = d.sqrt();
        g[j * n + j] = l;
        for i in j + 1..n {
            let mut v = g[i * n + j];
            for k in 0..j {
                v -= g[i * n + k] * g[j * n + k];
            }
            g[i * n + j] = v / l;
        }
    }
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= g[i * n + k] * b[k];
        }
        b[i] = v / g[i * n + i];
    }
    for i in (0..n).rev() {
        let mut v = b[i];
        for k in i + 1..n {
            v -= g[k * n + i] * b[k];
        }
        b[i] = v / g[i * n + i];
    }
    true
}

/// Optimal least-squares combination of the rows pushed into `eng` so
/// far: weights `w` (one per pushed row) minimizing `‖w·A − target‖₂`,
/// where `A` is the pushed stack. `None` when the Gram system is
/// numerically degenerate (callers treat this as an outage); a rank-0
/// engine returns the all-zero weights with `residual = ‖target‖`.
pub fn lstsq_rows(eng: &IncrementalRref, target: &[f64]) -> Option<Lstsq> {
    assert_eq!(target.len(), eng.cols(), "lstsq target width mismatch");
    let r = eng.rank();
    let n = eng.rows();
    let t_norm2: f64 = target.iter().map(|&x| x * x).sum();
    let covered = eng.nonzero_col_count();
    if r == 0 {
        return Some(Lstsq {
            weights: vec![0.0; n],
            residual: t_norm2.sqrt(),
            covered,
        });
    }
    // Gram matrix of the stored basis rows and the target inner products.
    let mut g = vec![0.0f64; r * r];
    let mut alpha = vec![0.0f64; r];
    for i in 0..r {
        let ei = eng.e_row(i);
        for j in i..r {
            let ej = eng.e_row(j);
            let dot: f64 = ei.iter().zip(ej).map(|(&a, &b)| a * b).sum();
            g[i * r + j] = dot;
            g[j * r + i] = dot;
        }
        alpha[i] = ei.iter().zip(target).map(|(&a, &b)| a * b).sum();
    }
    let b = alpha.clone();
    if !cholesky_solve(&mut g, r, &mut alpha) {
        return None;
    }
    // residual² = ‖target‖² − bᵀα  (projection shrinks the norm; clamp
    // the rounding tail so a full-rank solve reports exactly 0-ish).
    let proj2: f64 = b.iter().zip(&alpha).map(|(&x, &y)| x * y).sum();
    let residual = (t_norm2 - proj2).max(0.0).sqrt();
    // map the basis combination back to stack-row weights through the
    // stored transforms: w = Σ αᵢ tᵢ
    let mut weights = vec![0.0f64; n];
    for (i, &a) in alpha.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        for (w, &t) in weights.iter_mut().zip(eng.t_row(i)) {
            *w += a * t;
        }
    }
    Some(Lstsq { weights, residual, covered })
}

/// [`lstsq_rows`] against the all-ones target — the gradient-*sum*
/// combination the GC decode chases (`𝟙ᵀ · G`). This is the degraded-mode
/// fallback's workhorse form.
pub fn lstsq_ones(eng: &IncrementalRref) -> Option<Lstsq> {
    let ones = vec![1.0f64; eng.cols()];
    lstsq_rows(eng, &ones)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    fn engine_of(a: &Matrix) -> IncrementalRref {
        let mut eng = IncrementalRref::with_capacity(a.cols, a.rows);
        eng.push_matrix(a);
        eng
    }

    /// First-order optimality: the residual vector `w·A − target` must be
    /// orthogonal to every row of `A` (else some perturbation of `w`
    /// strictly improves the fit).
    fn assert_optimal(a: &Matrix, target: &[f64], sol: &Lstsq) {
        let m = a.cols;
        let mut res = vec![0.0f64; m];
        for j in 0..m {
            let mut acc = -target[j];
            for (i, &w) in sol.weights.iter().enumerate() {
                acc += w * a.row(i)[j];
            }
            res[j] = acc;
        }
        let norm: f64 = res.iter().map(|&x| x * x).sum::<f64>().sqrt();
        assert!(
            (norm - sol.residual).abs() < 1e-7 * (1.0 + norm),
            "reported residual {} vs recomputed {norm}",
            sol.residual
        );
        let scale = 1.0
            + a.data.iter().fold(0.0f64, |mx, &x| mx.max(x.abs()))
            + norm;
        for i in 0..a.rows {
            let dot: f64 = res.iter().zip(a.row(i)).map(|(&x, &y)| x * y).sum();
            assert!(dot.abs() < 1e-7 * scale, "row {i} not orthogonal: {dot}");
        }
    }

    #[test]
    fn full_rank_delivery_reaches_the_target_exactly() {
        let mut rng = Rng::new(11);
        for m in [3usize, 6, 12] {
            let a = Matrix::from_fn(m + 2, m, |_, _| rng.normal());
            let eng = engine_of(&a);
            assert_eq!(eng.rank(), m);
            let target: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let sol = lstsq_rows(&eng, &target).unwrap();
            assert!(sol.residual < 1e-9, "residual {}", sol.residual);
            assert_eq!(sol.covered, m);
            for j in 0..m {
                let got: f64 =
                    sol.weights.iter().enumerate().map(|(i, &w)| w * a.row(i)[j]).sum();
                assert!((got - target[j]).abs() < 1e-9, "col {j}");
            }
        }
    }

    #[test]
    fn rank_deficient_stacks_project_optimally() {
        let mut rng = Rng::new(23);
        for trial in 0..30 {
            let m = 4 + rng.below(9);
            let r = 1 + rng.below(m - 1);
            // random rank-r stack with duplicated/combined rows
            let basis = Matrix::from_fn(r, m, |_, _| rng.normal());
            let n = r + 1 + rng.below(4);
            let a = Matrix::from_fn(n, m, |i, j| {
                if i < r {
                    basis[(i, j)]
                } else {
                    basis[(i % r, j)] + 0.5 * basis[((i + 1) % r, j)]
                }
            });
            let eng = engine_of(&a);
            let ones = vec![1.0f64; m];
            let sol = lstsq_rows(&eng, &ones).unwrap_or_else(|| panic!("trial {trial}"));
            assert_optimal(&a, &ones, &sol);
        }
    }

    #[test]
    fn rank_zero_engine_returns_zero_weights() {
        let eng = IncrementalRref::new(5);
        let sol = lstsq_ones(&eng).unwrap();
        assert!(sol.weights.is_empty());
        assert!((sol.residual - 5.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(sol.covered, 0);
    }

    #[test]
    fn coverage_counts_touched_columns() {
        // two rows touching columns {0,1} only: column 2 is uncovered and
        // its target weight survives in the residual
        let a = Matrix::from_rows(&[vec![1.0, 1.0, 0.0], vec![1.0, -1.0, 0.0]]);
        let eng = engine_of(&a);
        let sol = lstsq_ones(&eng).unwrap();
        assert_eq!(sol.covered, 2);
        assert!((sol.residual - 1.0).abs() < 1e-9, "residual {}", sol.residual);
        assert_optimal(&a, &[1.0, 1.0, 1.0], &sol);
    }
}
