//! Dense row-major f64 matrix (substrate — no external linear algebra).
//!
//! Sized for coding-theory work: coefficient matrices are at most
//! `M·t_r × M` (tens of rows); the heavy `coefficients × gradients` products
//! run through the AOT Pallas kernel, not this type.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols), "ragged rows");
        Matrix {
            rows: rows.len(),
            cols,
            data: rows.iter().flat_map(|r| r.iter().copied()).collect(),
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        if self.rows == 0 {
            return Vec::new();
        }
        debug_assert!(j < self.cols);
        self.data[j..].iter().step_by(self.cols).copied().collect()
    }

    /// Blocked transpose: walks `B×B` tiles so both the source rows and the
    /// destination rows stay cache-resident, instead of striding the full
    /// destination once per source element.
    pub fn transpose(&self) -> Matrix {
        const B: usize = 32;
        let (n, m) = (self.rows, self.cols);
        let mut out = Matrix::zeros(m, n);
        for ib in (0..n).step_by(B) {
            let i1 = (ib + B).min(n);
            for jb in (0..m).step_by(B) {
                let j1 = (jb + B).min(m);
                for i in ib..i1 {
                    let row = self.row(i);
                    for j in jb..j1 {
                        out.data[j * n + i] = row[j];
                    }
                }
            }
        }
        out
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let crow = out.row_mut(i);
                for j in 0..other.cols {
                    crow[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `out[i] = row(i) · v`, with 4-wide accumulators over `chunks_exact`
    /// so the dot products autovectorize instead of forming one serial
    /// dependency chain per row.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = Vec::with_capacity(self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            let rc = row.chunks_exact(4);
            let vc = v.chunks_exact(4);
            let (rrem, vrem) = (rc.remainder(), vc.remainder());
            let mut acc = [0.0f64; 4];
            for (r4, v4) in rc.zip(vc) {
                acc[0] += r4[0] * v4[0];
                acc[1] += r4[1] * v4[1];
                acc[2] += r4[2] * v4[2];
                acc[3] += r4[3] * v4[3];
            }
            let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
            for (a, b) in rrem.iter().zip(vrem) {
                s += a * b;
            }
            out.push(s);
        }
        out
    }

    /// Append a row (the growable stacked-payload buffer of the sim hot
    /// loop). Amortized allocation-free once capacity is warm.
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Drop all rows, keeping the column width and the allocation.
    pub fn clear_rows(&mut self) {
        self.rows = 0;
        self.data.clear();
    }

    /// Vertical concatenation (the GC+ `B(r) = [B_1; ...; B_tr]` stack).
    pub fn vstack(mats: &[&Matrix]) -> Matrix {
        assert!(!mats.is_empty());
        let cols = mats[0].cols;
        assert!(mats.iter().all(|m| m.cols == cols));
        let rows = mats.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in mats {
            data.extend_from_slice(&m.data);
        }
        Matrix { rows, cols, data }
    }

    /// Select a subset of rows.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Horizontal augmentation [self | other].
    pub fn hstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |acc, x| acc.max(x.abs()))
    }

    pub fn approx_eq(&self, other: &Matrix, tol: f64) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())))
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(12) {
            let cells: Vec<String> = self.row(i).iter().take(12).map(|x| format!("{x:9.4}")).collect();
            writeln!(f, "  [{}]", cells.join(", "))?;
        }
        if self.rows > 12 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = Matrix::from_rows(&[vec![7.0, 8.0], vec![9.0, 10.0], vec![11.0, 12.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[vec![58.0, 64.0], vec![139.0, 154.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn vstack_and_select() {
        let a = Matrix::ones(2, 3);
        let b = Matrix::zeros(1, 3);
        let s = Matrix::vstack(&[&a, &b]);
        assert_eq!(s.rows, 3);
        assert_eq!(s.row(2), &[0.0, 0.0, 0.0]);
        let sel = s.select_rows(&[2, 0]);
        assert_eq!(sel.row(0), &[0.0, 0.0, 0.0]);
        assert_eq!(sel.row(1), &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn hstack_augments() {
        let a = Matrix::identity(2);
        let b = Matrix::ones(2, 1);
        let h = a.hstack(&b);
        assert_eq!(h.cols, 3);
        assert_eq!(h.row(0), &[1.0, 0.0, 1.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(4, 3, |i, j| (i + j) as f64);
        let v = vec![1.0, -2.0, 0.5];
        let got = a.matvec(&v);
        let want = a.matmul(&Matrix::from_rows(&[vec![1.0], vec![-2.0], vec![0.5]]));
        for i in 0..4 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }
}
