//! Dense linear algebra substrate: matrices, RREF with transform tracking,
//! rank, and consistent-system solves. These power the GC code construction
//! and the GC⁺ complementary decoder.

pub mod matrix;
pub mod rref;

pub use matrix::Matrix;
pub use rref::{decodable_columns, rank, rref_with_transform, solve_consistent, Rref};
