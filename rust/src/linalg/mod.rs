//! Dense linear algebra substrate: matrices, RREF with transform tracking
//! (batch and incremental), rank, and consistent-system solves. These power
//! the GC code construction and the GC⁺ complementary decoder; the
//! incremental engine ([`IncrementalRref`]) behind the degree-one peeling
//! front-end ([`PeelingDecoder`]) is the until-decode hot path.

pub mod lstsq;
pub mod matrix;
pub mod peeling;
pub mod rref;

pub use lstsq::{lstsq_ones, lstsq_rows, Lstsq};
pub use matrix::Matrix;
pub use peeling::PeelingDecoder;
pub use rref::{
    decodable_columns, rank, rref_with_transform, solve_consistent, IncrementalRref, Rref,
};
