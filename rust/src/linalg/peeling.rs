//! Degree-one peeling front-end for the incremental RREF engine.
//!
//! GC coefficient rows are sparse — `s+1` non-zeros on a cyclic support —
//! so most delivered rows arrive with every support block but (at most)
//! one already resolved. Classic online-fountain peeling (decode stacks,
//! block→row adjacency, O(1) propagation per resolved block) exploits
//! exactly this; [`PeelingDecoder`] is that idea adapted to the streaming,
//! bit-for-bit-reproducible setting of the GC⁺ decode path:
//!
//! - A **resolution map** tracks, per stored pivot row, whether it is a
//!   *bit-exact unit* (pivot entry exactly `1.0`, all else `== 0.0`) —
//!   i.e. whether its pivot block is fully resolved.
//! - Each pushed row is classified in one sparse pass over its support:
//!   if every support column but at most one (`j`) pivots in an exact-unit
//!   row, the row is **degree ≤ 1** and takes the peel fast path
//!   ([`IncrementalRref::peel_push`]): O(rank + rows) transform
//!   back-substitution instead of the O(rank · M) dense elimination.
//!   Otherwise it forwards to the ordinary [`IncrementalRref::push_row`].
//! - A **ripple stack**: committing block `j` zeroes column `j` in stored
//!   rows, which may promote them to exact units; promoted rows resolve
//!   their blocks, which can promote further rows on later pushes.
//!
//! Unlike a deferred fountain decoder, rows are never buffered for later
//! peeling: every row enters the engine at its arrival index, because the
//! decode paths (and the Byzantine audit, which consumes the
//! [`null_transform`](IncrementalRref::null_transform) of each dependent
//! push *in arrival order*) are pinned bit-for-bit to the pure-RREF
//! operation sequence. Deferring a row would reorder the transform
//! accumulation and change every downstream weight at the last ulp. The
//! fast path instead performs the *identical* state transition to
//! `push_row` whenever sparsity makes that transition cheap — so after
//! every push the wrapped engine is bit-identical to a pure
//! `IncrementalRref` fed the same stream, and `decodable_count`, decode
//! weights, outcome classification, and audit alarms are unchanged by
//! construction (`tests/decode_equivalence.rs` pins this per prefix).
//!
//! The biggest single win in the until-decode loop is the *dependent* fast
//! path: once a block set is resolved, every further row over those blocks
//! is recognized as redundant from its support alone — O(s) — where the
//! pure engine would spend a full O(rank · M) reduction to discover the
//! same thing.

use super::rref::IncrementalRref;

/// Peeling + RREF hybrid decoder: a drop-in for [`IncrementalRref`] on the
/// GC⁺ decode path (same push/query surface, bit-identical state), with
/// degree-≤1 rows short-circuited past the dense elimination.
pub struct PeelingDecoder {
    inc: IncrementalRref,
    /// `unit[i]` — stored row `i` is a bit-exact unit (block resolved).
    /// Monotone: exact-unit rows are never modified again (elimination
    /// factors read exactly `0.0` and are skipped).
    unit: Vec<bool>,
    /// Scratch: `in_support[c]` for the row being pushed (all-false
    /// between pushes).
    in_support: Vec<bool>,
    /// Scratch: support columns of the row being pushed.
    support: Vec<usize>,
    /// Ripple stack: stored rows whose column-`j` entry a peel just
    /// zeroed, pending an exact-unit re-check.
    ripple: Vec<usize>,
    peeled: usize,
    forwarded: usize,
}

impl PeelingDecoder {
    pub fn new(cols: usize) -> PeelingDecoder {
        PeelingDecoder::with_capacity(cols, 0)
    }

    /// Decoder with engine buffers pre-sized for `rows_hint` pushed rows.
    pub fn with_capacity(cols: usize, rows_hint: usize) -> PeelingDecoder {
        PeelingDecoder {
            inc: IncrementalRref::with_capacity(cols, rows_hint),
            unit: Vec::new(),
            in_support: vec![false; cols],
            support: Vec::new(),
            ripple: Vec::new(),
            peeled: 0,
            forwarded: 0,
        }
    }

    /// Clear all state for a fresh stream of `cols`-wide rows, retaining
    /// every allocation (pooled per-trial reuse).
    pub fn reset(&mut self, cols: usize) {
        self.inc.reset(cols);
        self.unit.clear();
        self.in_support.clear();
        self.in_support.resize(cols, false);
        self.support.clear();
        self.ripple.clear();
        self.peeled = 0;
        self.forwarded = 0;
    }

    /// The wrapped engine (read-only): pivot rows, transforms, null
    /// transforms — bit-identical to a pure [`IncrementalRref`] fed the
    /// same rows.
    pub fn engine(&self) -> &IncrementalRref {
        &self.inc
    }

    /// Rows taken by the degree-≤1 fast path so far.
    pub fn peeled(&self) -> usize {
        self.peeled
    }

    /// Rows forwarded to the dense elimination so far.
    pub fn forwarded(&self) -> usize {
        self.forwarded
    }

    /// Push one row; returns exactly what [`IncrementalRref::push_row`]
    /// would, leaving the engine in the identical state.
    pub fn push_row(&mut self, row: &[f64]) -> Option<usize> {
        assert_eq!(row.len(), self.inc.cols(), "push_row width mismatch");
        // classify: sparse support scan + resolution check
        self.support.clear();
        for (c, &v) in row.iter().enumerate() {
            if v != 0.0 {
                self.support.push(c);
                self.in_support[c] = true;
            }
        }
        let mut j = None;
        let mut degree_le1 = true;
        for &c in &self.support {
            match self.inc.pivots()[c] {
                Some(i) if self.unit[i] => {}
                Some(_) => {
                    degree_le1 = false;
                    break;
                }
                None if j.is_none() => j = Some(c),
                None => {
                    degree_le1 = false;
                    break;
                }
            }
        }

        let res = if degree_le1 {
            self.peeled += 1;
            let res = self.inc.peel_push(row, &self.in_support, j, &mut self.ripple);
            if res.is_some() {
                self.unit.push(true);
                // ripple: rows whose last off-pivot entry was just zeroed
                // resolve their own blocks
                while let Some(i) = self.ripple.pop() {
                    if !self.unit[i] && self.exact_unit(i) {
                        self.unit[i] = true;
                    }
                }
            }
            res
        } else {
            self.forwarded += 1;
            let res = self.inc.push_row(row);
            if res.is_some() {
                // the commit may have eliminated its pivot column from any
                // stored row; re-check the non-units (the push itself was
                // already O(rank · M), so this does not change the order)
                self.unit.push(self.exact_unit(self.inc.rank() - 1));
                for i in 0..self.inc.rank() - 1 {
                    if !self.unit[i] && self.exact_unit(i) {
                        self.unit[i] = true;
                    }
                }
            }
            res
        };
        for &c in &self.support {
            self.in_support[c] = false;
        }
        res
    }

    /// Push every `cols`-wide row of a flat slice, in order.
    pub fn push_rows(&mut self, rows: &[f64]) {
        let cols = self.inc.cols();
        assert!(cols > 0 && rows.len() % cols == 0, "push_rows: flat slice must be a multiple of cols");
        for row in rows.chunks_exact(cols) {
            self.push_row(row);
        }
    }

    /// Whether stored row `i` is a bit-exact unit: pivot entry exactly
    /// `1.0`, every other entry `== 0.0`. Strictly stronger than the
    /// engine's tolerance-based [`is_unit_row`](IncrementalRref::is_unit_row)
    /// — only bit-exact units make reduction a provable no-op.
    fn exact_unit(&self, i: usize) -> bool {
        let c = self.inc.row_cols()[i];
        self.inc
            .e_row(i)
            .iter()
            .enumerate()
            .all(|(k, &v)| if k == c { v == 1.0 } else { v == 0.0 })
    }

    // ── delegated queries (identical answers to the pure engine) ───────

    pub fn cols(&self) -> usize {
        self.inc.cols()
    }

    /// Total rows pushed so far (the width of the transform rows).
    pub fn rows(&self) -> usize {
        self.inc.rows()
    }

    pub fn rank(&self) -> usize {
        self.inc.rank()
    }

    /// See [`IncrementalRref::null_transform`].
    pub fn null_transform(&self) -> &[f64] {
        self.inc.null_transform()
    }

    /// See [`IncrementalRref::decodable_count`].
    pub fn decodable_count(&self) -> usize {
        self.inc.decodable_count()
    }

    /// See [`IncrementalRref::decodable`].
    pub fn decodable(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.inc.decodable()
    }

    /// See [`IncrementalRref::nonzero_col_count`].
    pub fn nonzero_col_count(&self) -> usize {
        self.inc.nonzero_col_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Rng;

    /// Engine-state equality, bit-for-bit, after every push.
    fn assert_state_eq(peel: &PeelingDecoder, pure: &IncrementalRref, ctx: &str) {
        let (a, b) = (peel.engine(), pure);
        assert_eq!(a.rank(), b.rank(), "{ctx}: rank");
        assert_eq!(a.rows(), b.rows(), "{ctx}: rows");
        assert_eq!(a.pivots(), b.pivots(), "{ctx}: pivots");
        assert_eq!(a.row_cols(), b.row_cols(), "{ctx}: row_cols");
        for i in 0..a.rank() {
            for (x, y) in a.e_row(i).iter().zip(b.e_row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: e row {i}");
            }
            for (x, y) in a.t_row(i).iter().zip(b.t_row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: t row {i}");
            }
        }
    }

    #[test]
    fn degree_one_stream_peels_and_matches() {
        // identity rows arrive one by one: everything after the forwarded
        // classification is degree ≤ 1
        let mut peel = PeelingDecoder::new(4);
        let mut pure = IncrementalRref::new(4);
        for c in 0..4 {
            let mut row = [0.0; 4];
            row[c] = 2.0 + c as f64;
            assert_eq!(peel.push_row(&row), pure.push_row(&row));
            assert_state_eq(&peel, &pure, &format!("unit row {c}"));
        }
        assert_eq!(peel.peeled(), 4, "single-support rows are degree one");
        assert_eq!(peel.decodable_count(), 4);
        // a now-redundant sparse row takes the dependent fast path
        let row = [1.0, -1.0, 0.0, 0.5];
        assert_eq!(peel.push_row(&row), pure.push_row(&row));
        assert_eq!(peel.peeled(), 5);
        assert_state_eq(&peel, &pure, "redundant row");
        for (x, y) in peel.null_transform().iter().zip(pure.null_transform()) {
            assert_eq!(x.to_bits(), y.to_bits(), "null transform");
        }
    }

    #[test]
    fn ripple_promotes_stored_rows() {
        let mut peel = PeelingDecoder::new(3);
        // dense row: forwarded (two unpivoted support columns)
        peel.push_row(&[1.0, 1.0, 0.0]);
        assert_eq!(peel.forwarded(), 1);
        // resolves block 1 AND promotes the stored row to a unit (its
        // column-1 entry is eliminated)
        peel.push_row(&[0.0, 3.0, 0.0]);
        assert_eq!(peel.peeled(), 1);
        assert_eq!(peel.decodable_count(), 2);
        // both blocks resolved ⇒ this row is degree ≤ 1 (residual block 2)
        peel.push_row(&[1.0, 1.0, 1.0]);
        assert_eq!(peel.peeled(), 2);
        assert_eq!(peel.decodable_count(), 3);
    }

    #[test]
    fn random_sparse_streams_match_pure_engine_bitwise() {
        let mut rng = Rng::new(4021);
        for trial in 0..60 {
            let m = 2 + rng.below(10);
            let s = 1 + rng.below(3.min(m - 1));
            let n_rows = 1 + rng.below(3 * m);
            let mut peel = PeelingDecoder::new(m);
            let mut pure = IncrementalRref::new(m);
            for r in 0..n_rows {
                // cyclic-support row with occasional extra zeros and
                // occasional all-zero rows
                let start = rng.below(m);
                let mut row = vec![0.0; m];
                if !rng.bernoulli(0.05) {
                    for o in 0..=s {
                        if !rng.bernoulli(0.2) {
                            row[(start + o) % m] = rng.normal_ms(0.0, 2.0);
                        }
                    }
                }
                assert_eq!(peel.push_row(&row), pure.push_row(&row), "trial {trial} row {r}");
                assert_state_eq(&peel, &pure, &format!("trial {trial} row {r}"));
                assert_eq!(
                    peel.decodable_count(),
                    pure.decodable_count(),
                    "trial {trial} row {r}"
                );
            }
            assert_eq!(peel.peeled() + peel.forwarded(), n_rows, "trial {trial}");
        }
    }

    #[test]
    fn reset_clears_resolution_state() {
        let mut peel = PeelingDecoder::with_capacity(3, 8);
        peel.push_row(&[0.0, 5.0, 0.0]);
        assert_eq!(peel.peeled(), 1);
        peel.reset(2);
        assert_eq!(peel.rank(), 0);
        assert_eq!(peel.rows(), 0);
        assert_eq!(peel.peeled(), 0);
        assert_eq!(peel.forwarded(), 0);
        peel.push_row(&[0.0, 1.5]);
        assert_eq!(peel.rank(), 1);
        assert_eq!(peel.decodable_count(), 1);
    }

    #[test]
    fn batch_matrix_agrees_with_batch_rref() {
        let mut rng = Rng::new(909);
        let a = Matrix::from_fn(12, 6, |_, _| {
            if rng.bernoulli(0.55) { 0.0 } else { rng.normal() }
        });
        let rr = crate::linalg::rref_with_transform(&a);
        let mut peel = PeelingDecoder::new(6);
        for i in 0..a.rows {
            peel.push_row(a.row(i));
        }
        assert_eq!(peel.rank(), rr.rank);
        assert_eq!(peel.engine().pivots(), &rr.pivots[..]);
        for i in 0..peel.rank() {
            for (x, y) in peel.engine().t_row(i).iter().zip(rr.t.row(i)) {
                assert_eq!(x.to_bits(), y.to_bits(), "t row {i}");
            }
        }
    }
}
