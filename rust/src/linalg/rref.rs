//! Reduced row-echelon form with transform tracking — the decode engine
//! behind GC⁺ (paper Algorithm 2).
//!
//! Two entry points share one elimination core:
//!
//! - [`IncrementalRref`] — the **incremental engine**: maintains
//!   `(E, T, pivots, rank)` under a stream of [`push_row`] /
//!   [`push_rows`] calls. Each newly delivered coefficient row is
//!   eliminated against the existing reduced form in `O(rank · M)` — the
//!   until-decode loop of GC⁺ therefore costs `O(rows · rank · M)` per
//!   trial instead of the `O(blocks² · M²)` of re-factoring the whole
//!   growing stack at every block.
//! - [`rref_with_transform`] — the batch form: pushes every row of the
//!   input through a fresh engine and materializes the classic
//!   `(E, T, pivots)` with `T · A = E`. Because the batch path **is** the
//!   incremental engine run to completion, decoding incrementally is
//!   bit-for-bit identical to batch-decoding the same stacked matrix —
//!   the equivalence the property tests in `tests/incremental_rref.rs`
//!   pin down.
//!
//! Because the received partial sums satisfy `S = B̂ · G`, the tracked
//! transform gives `T · S = E · G`; any row of `E` that is a unit vector
//! `e_j` decodes the local model `g_j` as `T_row · S`.
//!
//! # Algorithm (one `push_row`)
//!
//! 1. Reduce the incoming row against every stored pivot row: for pivot
//!    column `c` with stored row `r`, subtract `row[c] · E_r` (and the same
//!    multiple of `T_r` from the incoming transform row). Stored pivot rows
//!    are zero at every *other* pivot column, so a single pass suffices.
//! 2. Scan left-to-right for the first entry above the pivot floor
//!    (`PIVOT_EPS · scale`, see below); entries at or below the floor are
//!    flushed to exact zero on the way. No such entry ⇒ the row is
//!    dependent: rank unchanged, nothing stored (the reduced transform
//!    row remains readable via [`null_transform`] for callers that track
//!    the null space, e.g. the batch wrapper).
//! 3. Otherwise normalize the row by the pivot entry, flush sub-tolerance
//!    residue, and eliminate the new pivot column from every stored row
//!    (updating their transform rows identically). The new row joins the
//!    store; `pivots[c]` records it.
//!
//! Sorted by pivot column, the stored rows are exactly the nonzero rows of
//! the RREF of everything pushed so far: each stored row is zero strictly
//! left of its pivot (entries there are either other pivots' columns —
//! eliminated exactly — or sub-floor residue flushed in step 2, and
//! later eliminations only touch columns at or right of the *newer* pivot,
//! which is always right of any existing pivot the row is nonzero at), so
//! in exact arithmetic the engine reproduces the unique RREF regardless of
//! arrival order.
//!
//! # Tolerance policy
//!
//! Two relative thresholds, both scaled by the largest absolute input
//! entry pushed **so far** (`scale`):
//!
//! - `tol = EPS · max(1, scale)` — the zero threshold: elimination skips,
//!   residue flushing, and the unit-row test all treat `|v| ≤ tol` as
//!   exact zero, as the historical batch path did.
//! - `pivot floor = PIVOT_EPS · max(1, scale)` — the pivot-acceptance
//!   threshold. The engine pivots on the *leftmost* surviving entry (the
//!   left-to-right scan is what keeps [`solve_consistent`]'s augmented-
//!   column trick sound), so, unlike the magnitude-based partial pivoting
//!   it replaces, nothing would otherwise stop it normalizing by an entry
//!   barely above `tol` — amplifying rounding residue by up to `1/EPS`
//!   into the stored rows and the extraction weights. Requiring
//!   `|pivot| > PIVOT_EPS · scale` bounds that amplification at
//!   `1/PIVOT_EPS` (≈1e6, keeping elimination error ~1e-10·scale, far
//!   inside every decode tolerance); a candidate row with no entry above
//!   the floor is classified dependent — always *conservative* for
//!   decoding (a dropped row can only shrink the decodable set, never
//!   corrupt it). Exact dependencies reduce to ~1e-13·scale residue,
//!   orders below the floor, so generic rank decisions are unaffected.
//!
//! Note the scale is a **running prefix maximum**: a row is judged with
//! the scale known at its push. This is where the engine deliberately
//! departs from the pre-incremental batch implementation (which computed
//! one whole-matrix scale up front): a prefix scale is the only definition
//! under which pushing rows in chunks and pushing them in one batch
//! perform the identical operation sequence — the bit-for-bit equivalence
//! the decode paths are built on. For same-magnitude data (the decode
//! stacks: O(1) coefficients bounded by the code conditioning guard) the
//! two definitions coincide.
//!
//! [`push_row`]: IncrementalRref::push_row
//! [`push_rows`]: IncrementalRref::push_rows
//! [`null_transform`]: IncrementalRref::null_transform

use super::matrix::Matrix;

/// Relative pivot tolerance: coefficients are O(1) random reals, so values
/// below `EPS * max_abs` are treated as exact zeros created by elimination.
pub const EPS: f64 = 1e-9;

/// Relative pivot-acceptance floor: a candidate row's leftmost surviving
/// entry must exceed `PIVOT_EPS * max_abs` to become a pivot, bounding the
/// normalization amplification at `1/PIVOT_EPS` (see the module docs'
/// tolerance-policy section). Rows with no entry above the floor are
/// classified dependent — conservative for every decode consumer.
pub const PIVOT_EPS: f64 = 1e-6;

pub struct Rref {
    /// The nonzero rows of the reduced form first (in pivot-*creation*
    /// order, which is arrival order — not sorted by pivot column; permute
    /// rows by ascending pivot column to obtain the textbook RREF), then
    /// one zero row per dependent input row. Index rows through `pivots`.
    pub e: Matrix,
    /// Row transform with `t · input = e`.
    pub t: Matrix,
    /// `pivots[c] = Some(r)` if column `c` has its pivot in row `r` of `e`.
    pub pivots: Vec<Option<usize>>,
    /// Numerical rank (= number of pivots).
    pub rank: usize,
}

/// Incremental RREF-with-transform over a stream of rows (see module docs).
///
/// Only the `rank` pivot rows are stored; rows that reduce to zero carry no
/// decode information (their transform rows never enter any extraction) and
/// are dropped after the push reports them. All buffers survive
/// [`reset`](IncrementalRref::reset), so a pooled engine performs no steady
/// -state allocation across trials — the Monte-Carlo hot-loop contract.
pub struct IncrementalRref {
    cols: usize,
    /// Total rows pushed (dependent rows included) — the width of `T`.
    rows_seen: usize,
    rank: usize,
    /// Largest |input entry| seen so far (the tolerance scale).
    max_abs: f64,
    /// `pivots[c] = Some(i)` — column `c` pivots in stored row `i`.
    pivots: Vec<Option<usize>>,
    /// Stored row `i` pivots in column `row_cols[i]` (inverse of `pivots`).
    row_cols: Vec<usize>,
    /// Stored pivot rows of `E`, flat, stride `cols`; one extra trailing
    /// slot holds the row currently being reduced.
    e: Vec<f64>,
    /// Transform rows of the stored pivot rows; each has len `rows_seen`.
    t: Vec<Vec<f64>>,
    /// Transform row of the row currently being reduced; after a dependent
    /// push this is the null-space combination (`t_cand · input = 0`).
    t_cand: Vec<f64>,
    /// Recycled transform-row buffers (filled by `reset`).
    t_spare: Vec<Vec<f64>>,
}

impl IncrementalRref {
    pub fn new(cols: usize) -> IncrementalRref {
        IncrementalRref::with_capacity(cols, 0)
    }

    /// Engine with buffers pre-sized for `rows_hint` pushed rows.
    pub fn with_capacity(cols: usize, rows_hint: usize) -> IncrementalRref {
        IncrementalRref {
            cols,
            rows_seen: 0,
            rank: 0,
            max_abs: 0.0,
            pivots: vec![None; cols],
            row_cols: Vec::with_capacity(cols.min(rows_hint.max(8))),
            e: Vec::with_capacity(cols * (cols + 1)),
            t: Vec::new(),
            t_cand: Vec::with_capacity(rows_hint),
            t_spare: Vec::new(),
        }
    }

    /// Clear all state for a fresh stream of `cols`-wide rows, retaining
    /// every allocation (pooled per-trial reuse).
    pub fn reset(&mut self, cols: usize) {
        self.cols = cols;
        self.rows_seen = 0;
        self.rank = 0;
        self.max_abs = 0.0;
        self.pivots.clear();
        self.pivots.resize(cols, None);
        self.row_cols.clear();
        self.e.clear();
        self.t_spare.append(&mut self.t);
        self.t_cand.clear();
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total rows pushed so far (the width of the transform rows).
    pub fn rows(&self) -> usize {
        self.rows_seen
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Current absolute tolerance, `EPS · max(1, largest input entry)`.
    pub fn tol(&self) -> f64 {
        EPS * self.max_abs.max(1.0)
    }

    /// `pivots[c] = Some(i)` — column `c` pivots in stored row `i`.
    pub fn pivots(&self) -> &[Option<usize>] {
        &self.pivots
    }

    /// Stored row `i` pivots in column `row_cols()[i]` (inverse of
    /// [`pivots`](IncrementalRref::pivots), in pivot-creation order).
    pub fn row_cols(&self) -> &[usize] {
        &self.row_cols
    }

    /// Stored pivot row `i` of `E` (reduced coefficients, width `cols`).
    pub fn e_row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rank);
        &self.e[i * self.cols..(i + 1) * self.cols]
    }

    /// Transform row of stored pivot row `i` (`t_row · pushed = e_row`),
    /// width [`rows`](IncrementalRref::rows).
    pub fn t_row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rank);
        &self.t[i]
    }

    /// After a [`push_row`](IncrementalRref::push_row) that returned
    /// `None`: the reduced transform row of the dependent push — a
    /// null-space combination of everything pushed (width `rows`).
    pub fn null_transform(&self) -> &[f64] {
        &self.t_cand
    }

    /// Push one row; returns `Some(pivot_column)` when it increased the
    /// rank, `None` when it was dependent on the rows already pushed.
    pub fn push_row(&mut self, row: &[f64]) -> Option<usize> {
        let cols = self.cols;
        assert_eq!(row.len(), cols, "push_row width mismatch");
        self.rows_seen += 1;
        // transform rows track every pushed row: grow them by one column
        for tr in &mut self.t {
            tr.push(0.0);
        }
        for &v in row {
            self.max_abs = self.max_abs.max(v.abs());
        }
        let tol = self.tol();

        // stage the incoming row in the trailing scratch slot of `e`
        if self.e.len() < (self.rank + 1) * cols {
            self.e.resize((self.rank + 1) * cols, 0.0);
        }
        let (stored, cand) = self.e.split_at_mut(self.rank * cols);
        let cand = &mut cand[..cols];
        cand.copy_from_slice(row);
        self.t_cand.clear();
        self.t_cand.resize(self.rows_seen, 0.0);
        self.t_cand[self.rows_seen - 1] = 1.0;

        // 1) reduce against every stored pivot row (single pass: stored
        // rows are zero at each other's pivot columns)
        for i in 0..self.rank {
            let c = self.row_cols[i];
            let f = cand[c];
            if f == 0.0 {
                continue;
            }
            if f.abs() <= tol {
                cand[c] = 0.0;
                continue;
            }
            let erow = &stored[i * cols..(i + 1) * cols];
            for (x, p) in cand.iter_mut().zip(erow) {
                *x -= f * p;
            }
            cand[c] = 0.0; // exact
            for (x, p) in self.t_cand.iter_mut().zip(&self.t[i]) {
                *x -= f * p;
            }
        }

        // 2) leftmost entry above the pivot floor is the pivot; smaller
        // entries are flushed on the way (dividing by a near-tolerance
        // pivot would amplify rounding residue by up to 1/EPS into the
        // stored rows — the floor caps amplification at 1/PIVOT_EPS; see
        // the module docs)
        let pivot_floor = PIVOT_EPS * self.max_abs.max(1.0);
        let mut pivot = None;
        for (c, x) in cand.iter_mut().enumerate() {
            if x.abs() <= pivot_floor {
                *x = 0.0;
            } else {
                pivot = Some(c);
                break;
            }
        }
        // dependent row ⇒ None: rank unchanged, t_cand = null combination
        let c = pivot?;

        // 3) normalize, flush, and eliminate the new column everywhere
        let inv = 1.0 / cand[c];
        for x in cand.iter_mut() {
            *x *= inv;
        }
        cand[c] = 1.0; // exact
        for x in cand.iter_mut() {
            if x.abs() <= tol {
                *x = 0.0;
            }
        }
        for x in self.t_cand.iter_mut() {
            *x *= inv;
        }
        for i in 0..self.rank {
            let erow = &mut stored[i * cols..(i + 1) * cols];
            let f = erow[c];
            if f == 0.0 {
                continue;
            }
            if f.abs() <= tol {
                erow[c] = 0.0;
                continue;
            }
            for (x, p) in erow.iter_mut().zip(cand.iter()) {
                *x -= f * p;
            }
            erow[c] = 0.0; // exact
            for (x, p) in self.t[i].iter_mut().zip(self.t_cand.iter()) {
                *x -= f * p;
            }
        }

        // commit: the scratch slot becomes stored row `rank`
        self.pivots[c] = Some(self.rank);
        self.row_cols.push(c);
        let mut committed = self.t_spare.pop().unwrap_or_default();
        committed.clear();
        committed.extend_from_slice(&self.t_cand);
        self.t.push(committed);
        self.rank += 1;
        Some(c)
    }

    /// Degree-≤1 fast-path push — the peeling back-substitution used by
    /// [`PeelingDecoder`](super::peeling::PeelingDecoder).
    ///
    /// **Precondition** (checked by the caller, debug-asserted here): every
    /// support column of `row` (`in_support[c] == (row[c] != 0.0)`) except
    /// the at-most-one unpivoted column `j` pivots in a stored row that is a
    /// *bit-exact unit* (pivot entry exactly `1.0`, every other entry
    /// `== 0.0`). Under that precondition [`push_row`] would perform the
    /// identical state transition: reducing by an exact-unit row is a
    /// bit-level no-op on every candidate column except the pivot column
    /// itself (which `push_row` then overwrites with exact `0.0`), so the
    /// candidate's residual value at `j` is the raw `row[j]`, the committed
    /// row is exactly the unit vector `e_j` (normalization flushes every
    /// off-pivot entry of a degree-one row), and eliminating column `j` from
    /// a stored row by an exact-unit candidate only touches that row's
    /// column-`j` entry. This method performs exactly those updates — O(rank
    /// + rows) transform work instead of `push_row`'s O(rank · cols)
    /// elimination — leaving the engine state **bit-for-bit identical** to
    /// what [`push_row`] would have produced (pinned per-prefix by
    /// `tests/decode_equivalence.rs`).
    ///
    /// `j = None` means every support column is already resolved: the row is
    /// necessarily dependent and only the null transform is produced.
    /// Stored rows whose column-`j` entry was zeroed by the elimination are
    /// appended to `touched` — each may have just become a unit row (the
    /// caller's ripple re-check). Returns what `push_row` would return.
    pub(crate) fn peel_push(
        &mut self,
        row: &[f64],
        in_support: &[bool],
        j: Option<usize>,
        touched: &mut Vec<usize>,
    ) -> Option<usize> {
        let cols = self.cols;
        assert_eq!(row.len(), cols, "peel_push width mismatch");
        debug_assert!(row.iter().zip(in_support).all(|(&v, &s)| s == (v != 0.0)));
        debug_assert!(j.map_or(true, |jc| self.pivots[jc].is_none()));
        // prologue: identical to push_row
        self.rows_seen += 1;
        for tr in &mut self.t {
            tr.push(0.0);
        }
        for &v in row {
            self.max_abs = self.max_abs.max(v.abs());
        }
        let tol = self.tol();
        self.t_cand.clear();
        self.t_cand.resize(self.rows_seen, 0.0);
        self.t_cand[self.rows_seen - 1] = 1.0;

        // step 1 mirror: stored rows in creation order; in-support pivot
        // rows are exact units, so only the transform accumulates (the
        // factor is the raw entry — no earlier reduction can have changed
        // it) and sub-tolerance factors flush without a transform update,
        // exactly as in push_row
        for i in 0..self.rank {
            let c = self.row_cols[i];
            if !in_support[c] {
                continue; // push_row: f == 0.0 ⇒ skip
            }
            let f = row[c];
            if f.abs() <= tol {
                continue; // push_row: flush only, no transform update
            }
            for (x, p) in self.t_cand.iter_mut().zip(&self.t[i]) {
                *x -= f * p;
            }
        }

        // step 2 mirror: the only surviving entry is the residual at `j`
        let pivot_floor = PIVOT_EPS * self.max_abs.max(1.0);
        let jc = match j {
            Some(jc) if row[jc].abs() > pivot_floor => jc,
            // dependent: rank unchanged, t_cand is the null combination
            _ => return None,
        };

        // step 3 mirror: normalize the transform, eliminate column `jc`
        // from every stored row (an exact-unit candidate touches nothing
        // else), commit the unit row e_jc
        let inv = 1.0 / row[jc];
        for x in self.t_cand.iter_mut() {
            *x *= inv;
        }
        for i in 0..self.rank {
            let f = self.e[i * cols + jc];
            if f == 0.0 {
                continue;
            }
            self.e[i * cols + jc] = 0.0; // exact, in both branches below
            touched.push(i);
            if f.abs() <= tol {
                continue; // push_row: flush only, no transform update
            }
            for (x, p) in self.t[i].iter_mut().zip(self.t_cand.iter()) {
                *x -= f * p;
            }
        }
        if self.e.len() < (self.rank + 1) * cols {
            self.e.resize((self.rank + 1) * cols, 0.0);
        }
        let slot = &mut self.e[self.rank * cols..(self.rank + 1) * cols];
        for x in slot.iter_mut() {
            *x = 0.0;
        }
        slot[jc] = 1.0;
        self.pivots[jc] = Some(self.rank);
        self.row_cols.push(jc);
        let mut committed = self.t_spare.pop().unwrap_or_default();
        committed.clear();
        committed.extend_from_slice(&self.t_cand);
        self.t.push(committed);
        self.rank += 1;
        Some(jc)
    }

    /// Push a flat block of rows (`rows.len()` must divide into `cols`-wide
    /// rows); equivalent to pushing each row in order.
    pub fn push_rows(&mut self, rows: &[f64]) {
        assert!(
            self.cols > 0 && rows.len() % self.cols == 0,
            "push_rows: flat slice must be a multiple of cols"
        );
        for row in rows.chunks_exact(self.cols) {
            self.push_row(row);
        }
    }

    /// Push every row of a matrix, in order.
    pub fn push_matrix(&mut self, a: &Matrix) {
        assert_eq!(a.cols, self.cols, "push_matrix width mismatch");
        for i in 0..a.rows {
            self.push_row(a.row(i));
        }
    }

    /// Whether stored pivot row `i` is a unit vector up to tolerance —
    /// i.e. its pivot column's value is pinned by the pushed row space.
    /// (The batch path reaches the same verdict by flushing sub-tolerance
    /// residue and testing for exact zeros.)
    pub fn is_unit_row(&self, i: usize) -> bool {
        let c = self.row_cols[i];
        let tol = self.tol();
        self.e_row(i)
            .iter()
            .enumerate()
            .all(|(j, &v)| j == c || v.abs() <= tol)
    }

    /// Number of decodable columns (unit pivot rows) — the `|K₄|` of a
    /// GC⁺ decode, computed without allocating.
    pub fn decodable_count(&self) -> usize {
        (0..self.rank).filter(|&i| self.is_unit_row(i)).count()
    }

    /// Decodable columns in ascending column order, as
    /// `(column, stored_row)` pairs; `t_row(stored_row)` extracts the
    /// column's value from the stacked payloads.
    pub fn decodable(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.pivots
            .iter()
            .enumerate()
            .filter_map(move |(c, p)| match p {
                Some(i) if self.is_unit_row(*i) => Some((c, *i)),
                _ => None,
            })
    }

    /// Number of columns with any entry above tolerance (the `|K₅|`-vs-
    /// `|K₄|` test of the paper's Algorithm 2 approximation).
    pub fn nonzero_col_count(&self) -> usize {
        let tol = self.tol();
        (0..self.cols)
            .filter(|&c| (0..self.rank).any(|i| self.e_row(i)[c].abs() > tol))
            .count()
    }
}

/// Compute RREF with transform tracking: `t · a = e`, `e` in RREF.
///
/// This is the incremental engine run over all rows of `a` in order (see
/// the module docs for pivot selection and the tolerance policy). Rows of
/// `e`: the `rank` pivot rows first in pivot-creation order, then the zero
/// rows of the dependent pushes in arrival order; `pivots[c]` indexes into
/// that layout. Sub-tolerance residue is flushed to exact zero so
/// downstream structure checks ([`decodable_columns`]) can compare
/// against literal `0.0`.
pub fn rref_with_transform(a: &Matrix) -> Rref {
    let (n, m) = (a.rows, a.cols);
    let mut inc = IncrementalRref::with_capacity(m, n);
    let mut null_t: Vec<Vec<f64>> = Vec::new();
    for i in 0..n {
        if inc.push_row(a.row(i)).is_none() {
            null_t.push(inc.null_transform().to_vec());
        }
    }
    let tol = inc.tol();
    let mut e = Matrix::zeros(n, m);
    let mut t = Matrix::zeros(n, n);
    for i in 0..inc.rank() {
        for (x, &v) in e.row_mut(i).iter_mut().zip(inc.e_row(i)) {
            *x = if v.abs() <= tol { 0.0 } else { v };
        }
        t.row_mut(i).copy_from_slice(inc.t_row(i));
    }
    for (k, tr) in null_t.iter().enumerate() {
        let i = inc.rank() + k;
        t.row_mut(i)[..tr.len()].copy_from_slice(tr);
    }
    let rank = inc.rank();
    Rref { e, t, pivots: inc.pivots().to_vec(), rank }
}

/// Numerical rank.
pub fn rank(a: &Matrix) -> usize {
    let mut inc = IncrementalRref::with_capacity(a.cols, a.rows);
    inc.push_matrix(a);
    inc.rank()
}

/// Solve `A x = b` if consistent (free variables set to 0); `None` otherwise.
pub fn solve_consistent(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, b.len());
    let aug = a.hstack(&Matrix::from_rows(&[b.to_vec()]).transpose());
    let rr = rref_with_transform(&aug);
    // inconsistent iff the augmented column holds a pivot
    if rr.pivots[a.cols].is_some() {
        return None;
    }
    let mut x = vec![0.0; a.cols];
    for (c, p) in rr.pivots[..a.cols].iter().enumerate() {
        if let Some(r) = p {
            x[c] = rr.e[(*r, a.cols)];
        }
    }
    // verify (guards borderline numerics)
    let resid: f64 = a
        .matvec(&x)
        .iter()
        .zip(b)
        .map(|(y, t)| (y - t) * (y - t))
        .sum::<f64>()
        .sqrt();
    let scale = 1.0 + b.iter().map(|v| v * v).sum::<f64>().sqrt();
    (resid <= 1e-6 * scale).then_some(x)
}

/// Decodable columns: indices `j` whose value is pinned by `A`'s row space —
/// i.e. some row of RREF is exactly the unit vector `e_j` — together with the
/// transform row that extracts each (`g_j = transform_row · S`).
pub fn decodable_columns(rr: &Rref) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (c, p) in rr.pivots.iter().enumerate() {
        let Some(r) = *p else { continue };
        let row = rr.e.row(r);
        let clean = row
            .iter()
            .enumerate()
            .all(|(j, &v)| j == c || v == 0.0);
        if clean {
            out.push((c, r));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rref_known_small() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![2.0, 4.0, 6.0], vec![1.0, 0.0, 1.0]]);
        let rr = rref_with_transform(&a);
        assert_eq!(rr.rank, 2);
        // T * A == E
        assert!(rr.t.matmul(&a).approx_eq(&rr.e, 1e-9));
    }

    #[test]
    fn rref_identity_full_rank() {
        let rr = rref_with_transform(&Matrix::identity(5));
        assert_eq!(rr.rank, 5);
        assert!(rr.e.approx_eq(&Matrix::identity(5), 0.0));
    }

    #[test]
    fn transform_invariant_random() {
        let mut rng = Rng::new(2024);
        for trial in 0..50 {
            let n = 2 + rng.below(8);
            let m = 2 + rng.below(8);
            let a = Matrix::from_fn(n, m, |_, _| rng.normal_ms(0.0, 2.0));
            let rr = rref_with_transform(&a);
            assert!(
                rr.t.matmul(&a).approx_eq(&rr.e, 1e-7),
                "trial {trial}: T*A != E"
            );
            assert!(rr.rank <= n.min(m));
        }
    }

    #[test]
    fn random_square_full_rank() {
        let mut rng = Rng::new(7);
        let a = Matrix::from_fn(10, 10, |_, _| rng.normal());
        assert_eq!(rank(&a), 10); // w.p. 1
    }

    #[test]
    fn rank_deficient_by_construction() {
        let mut rng = Rng::new(8);
        // 6x4 matrix whose rows live in a 2-dim subspace
        let b1: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let b2: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let rows: Vec<Vec<f64>> = (0..6)
            .map(|_| {
                let (c1, c2) = (rng.normal(), rng.normal());
                (0..4).map(|j| c1 * b1[j] + c2 * b2[j]).collect()
            })
            .collect();
        assert_eq!(rank(&Matrix::from_rows(&rows)), 2);
    }

    #[test]
    fn solve_consistent_works() {
        let a = Matrix::from_rows(&[vec![2.0, 0.0], vec![0.0, 4.0], vec![2.0, 4.0]]);
        let x = solve_consistent(&a, &[2.0, 8.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9 && (x[1] - 2.0).abs() < 1e-9);
        assert!(solve_consistent(&a, &[2.0, 8.0, 11.0]).is_none());
    }

    #[test]
    fn decodable_columns_identity_block() {
        // rows pin g0 and g1+g2 but only g0 is a unit row
        let a = Matrix::from_rows(&[vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 1.0]]);
        let rr = rref_with_transform(&a);
        let dec = decodable_columns(&rr);
        assert_eq!(dec.len(), 1);
        assert_eq!(dec[0].0, 0);
    }

    #[test]
    fn decodable_columns_extract_correct_values() {
        // Random 3-unknown system with enough equations: all decodable, and
        // the transform rows recover each unknown from the RHS.
        let mut rng = Rng::new(99);
        let g = [3.5, -1.25, 0.75];
        let a = Matrix::from_fn(5, 3, |_, _| rng.normal());
        let s: Vec<f64> = (0..5).map(|i| (0..3).map(|j| a[(i, j)] * g[j]).sum()).collect();
        let rr = rref_with_transform(&a);
        let dec = decodable_columns(&rr);
        assert_eq!(dec.len(), 3);
        for (c, r) in dec {
            let got: f64 = rr.t.row(r).iter().zip(&s).map(|(w, v)| w * v).sum();
            assert!((got - g[c]).abs() < 1e-8, "g[{c}]: {got} vs {}", g[c]);
        }
    }

    // ── incremental engine ──────────────────────────────────────────────

    #[test]
    fn incremental_tracks_transform_and_rank() {
        let mut rng = Rng::new(31);
        let a = Matrix::from_fn(9, 6, |_, _| rng.normal());
        let mut inc = IncrementalRref::new(6);
        for i in 0..a.rows {
            inc.push_row(a.row(i));
            // invariant after every push: t_row · pushed-prefix == e_row
            for r in 0..inc.rank() {
                let trow = inc.t_row(r);
                assert_eq!(trow.len(), i + 1);
                for c in 0..6 {
                    let want: f64 = trow.iter().zip(0..=i).map(|(w, k)| w * a[(k, c)]).sum();
                    let got = inc.e_row(r)[c];
                    assert!((want - got).abs() < 1e-7, "push {i} row {r} col {c}");
                }
            }
        }
        assert_eq!(inc.rank(), 6);
        assert_eq!(inc.rows(), 9);
        assert_eq!(inc.decodable_count(), 6); // full rank => all unit
    }

    #[test]
    fn incremental_matches_batch_wrapper_bitwise() {
        let mut rng = Rng::new(77);
        for trial in 0..30 {
            let n = 1 + rng.below(12);
            let m = 1 + rng.below(8);
            let a = Matrix::from_fn(n, m, |_, _| {
                if rng.bernoulli(0.25) { 0.0 } else { rng.normal_ms(0.0, 3.0) }
            });
            let rr = rref_with_transform(&a);
            let mut inc = IncrementalRref::new(m);
            inc.push_matrix(&a);
            assert_eq!(inc.rank(), rr.rank, "trial {trial}");
            assert_eq!(inc.pivots(), &rr.pivots[..], "trial {trial}");
            for i in 0..inc.rank() {
                let (tb, ti) = (rr.t.row(i), inc.t_row(i));
                assert_eq!(tb.len(), ti.len());
                for (x, y) in tb.iter().zip(ti) {
                    assert_eq!(x.to_bits(), y.to_bits(), "trial {trial} t row {i}");
                }
            }
        }
    }

    #[test]
    fn dependent_push_exposes_null_transform() {
        let mut inc = IncrementalRref::new(3);
        assert_eq!(inc.push_row(&[1.0, 2.0, 0.0]), Some(0));
        // duplicate row: dependent, null transform = [-1, 1]
        assert_eq!(inc.push_row(&[1.0, 2.0, 0.0]), None);
        let nt = inc.null_transform();
        assert_eq!(nt.len(), 2);
        assert!((nt[0] + 1.0).abs() < 1e-12 && (nt[1] - 1.0).abs() < 1e-12);
        assert_eq!(inc.rank(), 1);
        assert_eq!(inc.rows(), 2);
    }

    #[test]
    fn reset_reuses_buffers_and_clears_state() {
        let mut inc = IncrementalRref::with_capacity(4, 16);
        inc.push_row(&[1.0, 0.0, 2.0, 0.0]);
        inc.push_row(&[0.0, 1.0, 0.0, 3.0]);
        assert_eq!(inc.rank(), 2);
        inc.reset(4);
        assert_eq!(inc.rank(), 0);
        assert_eq!(inc.rows(), 0);
        assert!(inc.pivots().iter().all(|p| p.is_none()));
        inc.push_row(&[0.0, 0.0, 0.0, 5.0]);
        assert_eq!(inc.rank(), 1);
        assert_eq!(inc.decodable_count(), 1);
        // reset to a different width
        inc.reset(2);
        inc.push_row(&[3.0, 0.0]);
        assert_eq!(inc.pivots(), &[Some(0), None]);
    }

    #[test]
    fn zero_and_empty_rows_are_dependent() {
        let mut inc = IncrementalRref::new(5);
        assert_eq!(inc.push_row(&[0.0; 5]), None);
        assert_eq!(inc.rank(), 0);
        assert_eq!(inc.rows(), 1);
        assert_eq!(inc.decodable_count(), 0);
        assert_eq!(inc.nonzero_col_count(), 0);
    }

    #[test]
    fn push_rows_flat_equals_row_by_row() {
        let mut rng = Rng::new(5);
        let a = Matrix::from_fn(6, 4, |_, _| rng.normal());
        let mut one = IncrementalRref::new(4);
        one.push_rows(&a.data);
        let mut two = IncrementalRref::new(4);
        for i in 0..6 {
            two.push_row(a.row(i));
        }
        assert_eq!(one.rank(), two.rank());
        for i in 0..one.rank() {
            assert_eq!(one.e_row(i), two.e_row(i));
            assert_eq!(one.t_row(i), two.t_row(i));
        }
    }
}
