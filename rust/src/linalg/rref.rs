//! Reduced row-echelon form with transform tracking — the decode engine
//! behind GC⁺ (paper Algorithm 2).
//!
//! `rref_with_transform(A)` returns `(E, T, pivots)` with `T · A = E`,
//! `E` in RREF, and `pivots[j] = Some(row)` for pivot columns. Because the
//! received partial sums satisfy `S = B̂ · G`, the same transform gives
//! `T · S = E · G`; any row of `E` that is a unit vector `e_j` decodes the
//! local model `g_j` as `(T · S)_row = T_row · S`.

use super::matrix::Matrix;

/// Relative pivot tolerance: coefficients are O(1) random reals, so values
/// below `EPS * max_abs` are treated as exact zeros created by elimination.
pub const EPS: f64 = 1e-9;

pub struct Rref {
    /// RREF of the input.
    pub e: Matrix,
    /// Row transform with `t · input = e`.
    pub t: Matrix,
    /// `pivots[c] = Some(r)` if column `c` has its pivot in row `r`.
    pub pivots: Vec<Option<usize>>,
    /// Numerical rank (= number of pivots).
    pub rank: usize,
}

/// Compute RREF with partial pivoting, tracking the row transform.
pub fn rref_with_transform(a: &Matrix) -> Rref {
    let (n, m) = (a.rows, a.cols);
    let mut e = a.clone();
    let mut t = Matrix::identity(n);
    let scale = a.max_abs().max(1.0);
    let tol = EPS * scale;

    let mut pivots: Vec<Option<usize>> = vec![None; m];
    let mut r = 0; // next pivot row
    for c in 0..m {
        if r >= n {
            break;
        }
        // partial pivot: largest |entry| in column c at/below row r
        let (mut best, mut best_abs) = (r, e[(r, c)].abs());
        for i in (r + 1)..n {
            let v = e[(i, c)].abs();
            if v > best_abs {
                best = i;
                best_abs = v;
            }
        }
        if best_abs <= tol {
            continue; // no pivot in this column
        }
        if best != r {
            e.data.swap_chunks(best, r, m);
            t.data.swap_chunks(best, r, n);
        }
        // normalize pivot row
        let inv = 1.0 / e[(r, c)];
        for x in e.row_mut(r) {
            *x *= inv;
        }
        for x in t.row_mut(r) {
            *x *= inv;
        }
        e[(r, c)] = 1.0; // exact
        // eliminate column c from every other row
        for i in 0..n {
            if i == r {
                continue;
            }
            let f = e[(i, c)];
            if f.abs() <= tol {
                e[(i, c)] = 0.0;
                continue;
            }
            // e[i] -= f * e[r];  t[i] -= f * t[r]
            let (erow, eref) = row_pair(&mut e, i, r);
            for (x, p) in erow.iter_mut().zip(eref.iter()) {
                *x -= f * p;
            }
            let (trow, tref) = row_pair(&mut t, i, r);
            for (x, p) in trow.iter_mut().zip(tref.iter()) {
                *x -= f * p;
            }
            e[(i, c)] = 0.0; // exact
        }
        pivots[c] = Some(r);
        r += 1;
    }

    // flush sub-tolerance residue so downstream structure checks are exact
    for x in &mut e.data {
        if x.abs() <= tol {
            *x = 0.0;
        }
    }
    Rref { e, t, pivots, rank: r }
}

/// Numerical rank.
pub fn rank(a: &Matrix) -> usize {
    rref_with_transform(a).rank
}

/// Solve `A x = b` if consistent (free variables set to 0); `None` otherwise.
pub fn solve_consistent(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, b.len());
    let aug = a.hstack(&Matrix::from_rows(&[b.to_vec()]).transpose());
    let rr = rref_with_transform(&aug);
    // inconsistent iff the augmented column holds a pivot
    if rr.pivots[a.cols].is_some() {
        return None;
    }
    let mut x = vec![0.0; a.cols];
    for (c, p) in rr.pivots[..a.cols].iter().enumerate() {
        if let Some(r) = p {
            x[c] = rr.e[(*r, a.cols)];
        }
    }
    // verify (guards borderline numerics)
    let resid: f64 = a
        .matvec(&x)
        .iter()
        .zip(b)
        .map(|(y, t)| (y - t) * (y - t))
        .sum::<f64>()
        .sqrt();
    let scale = 1.0 + b.iter().map(|v| v * v).sum::<f64>().sqrt();
    (resid <= 1e-6 * scale).then_some(x)
}

/// Decodable columns: indices `j` whose value is pinned by `A`'s row space —
/// i.e. some row of RREF is exactly the unit vector `e_j` — together with the
/// transform row that extracts each (`g_j = transform_row · S`).
pub fn decodable_columns(rr: &Rref) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (c, p) in rr.pivots.iter().enumerate() {
        let Some(r) = *p else { continue };
        let row = rr.e.row(r);
        let clean = row
            .iter()
            .enumerate()
            .all(|(j, &v)| j == c || v == 0.0);
        if clean {
            out.push((c, r));
        }
    }
    out
}

// -- helpers -------------------------------------------------------------------

trait SwapChunks {
    fn swap_chunks(&mut self, i: usize, j: usize, w: usize);
}

impl SwapChunks for Vec<f64> {
    fn swap_chunks(&mut self, i: usize, j: usize, w: usize) {
        if i == j {
            return;
        }
        let (lo, hi) = (i.min(j), i.max(j));
        let (a, b) = self.split_at_mut(hi * w);
        a[lo * w..lo * w + w].swap_with_slice(&mut b[..w]);
    }
}

/// Mutable access to two distinct rows.
fn row_pair(m: &mut Matrix, i: usize, r: usize) -> (&mut [f64], &[f64]) {
    assert_ne!(i, r);
    let w = m.cols;
    if i < r {
        let (a, b) = m.data.split_at_mut(r * w);
        (&mut a[i * w..i * w + w], &b[..w])
    } else {
        let (a, b) = m.data.split_at_mut(i * w);
        (&mut b[..w], &a[r * w..r * w + w])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rref_known_small() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![2.0, 4.0, 6.0], vec![1.0, 0.0, 1.0]]);
        let rr = rref_with_transform(&a);
        assert_eq!(rr.rank, 2);
        // T * A == E
        assert!(rr.t.matmul(&a).approx_eq(&rr.e, 1e-9));
    }

    #[test]
    fn rref_identity_full_rank() {
        let rr = rref_with_transform(&Matrix::identity(5));
        assert_eq!(rr.rank, 5);
        assert!(rr.e.approx_eq(&Matrix::identity(5), 0.0));
    }

    #[test]
    fn transform_invariant_random() {
        let mut rng = Rng::new(2024);
        for trial in 0..50 {
            let n = 2 + rng.below(8);
            let m = 2 + rng.below(8);
            let a = Matrix::from_fn(n, m, |_, _| rng.normal_ms(0.0, 2.0));
            let rr = rref_with_transform(&a);
            assert!(
                rr.t.matmul(&a).approx_eq(&rr.e, 1e-7),
                "trial {trial}: T*A != E"
            );
            assert!(rr.rank <= n.min(m));
        }
    }

    #[test]
    fn random_square_full_rank() {
        let mut rng = Rng::new(7);
        let a = Matrix::from_fn(10, 10, |_, _| rng.normal());
        assert_eq!(rank(&a), 10); // w.p. 1
    }

    #[test]
    fn rank_deficient_by_construction() {
        let mut rng = Rng::new(8);
        // 6x4 matrix whose rows live in a 2-dim subspace
        let b1: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let b2: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let rows: Vec<Vec<f64>> = (0..6)
            .map(|_| {
                let (c1, c2) = (rng.normal(), rng.normal());
                (0..4).map(|j| c1 * b1[j] + c2 * b2[j]).collect()
            })
            .collect();
        assert_eq!(rank(&Matrix::from_rows(&rows)), 2);
    }

    #[test]
    fn solve_consistent_works() {
        let a = Matrix::from_rows(&[vec![2.0, 0.0], vec![0.0, 4.0], vec![2.0, 4.0]]);
        let x = solve_consistent(&a, &[2.0, 8.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9 && (x[1] - 2.0).abs() < 1e-9);
        assert!(solve_consistent(&a, &[2.0, 8.0, 11.0]).is_none());
    }

    #[test]
    fn decodable_columns_identity_block() {
        // rows pin g0 and g1+g2 but only g0 is a unit row
        let a = Matrix::from_rows(&[vec![1.0, 0.0, 0.0], vec![0.0, 1.0, 1.0]]);
        let rr = rref_with_transform(&a);
        let dec = decodable_columns(&rr);
        assert_eq!(dec.len(), 1);
        assert_eq!(dec[0].0, 0);
    }

    #[test]
    fn decodable_columns_extract_correct_values() {
        // Random 3-unknown system with enough equations: all decodable, and
        // the transform rows recover each unknown from the RHS.
        let mut rng = Rng::new(99);
        let g = [3.5, -1.25, 0.75];
        let a = Matrix::from_fn(5, 3, |_, _| rng.normal());
        let s: Vec<f64> = (0..5).map(|i| (0..3).map(|j| a[(i, j)] * g[j]).sum()).collect();
        let rr = rref_with_transform(&a);
        let dec = decodable_columns(&rr);
        assert_eq!(dec.len(), 3);
        for (c, r) in dec {
            let got: f64 = rr.t.row(r).iter().zip(&s).map(|(w, v)| w * v).sum();
            assert!((got - g[c]).abs() < 1e-8, "g[{c}]: {got} vs {}", g[c]);
        }
    }
}
