//! `cogc` — the CoGC launcher.
//!
//! Subcommands regenerate every paper figure as CSV on stdout, run custom
//! training configurations, and expose the analysis tooling:
//!
//! ```text
//! cogc fig4 [--trials 20000]                 outage P_O vs s (Fig. 4)
//! cogc fig6 [--trials 2000]                  GC+ recovery stats (Fig. 6)
//! cogc fig7  --network 1|2|3 [--rounds 100]  MNIST curves (Fig. 7)
//! cogc fig8  --network 1|2|3                 CIFAR curves (Fig. 8)
//! cogc fig10 [--target 0.85]                 cost-efficient GC (Fig. 10)
//! cogc fig11 --conn good|moderate|poor       GC+ vs GC, MNIST (Fig. 11)
//! cogc fig12 --conn good|moderate|poor       GC+ vs GC, CIFAR (Fig. 12)
//! cogc remark5                               Remark-5 case study
//! cogc theory                                Theorem-1 / Lemma-5 numerics
//! cogc privacy [--dim 100]                   Lemma-1 LMIP table
//! cogc design [--p 0.1] [--target-po 0.5]    eq. (21) design sweep + MC check
//! cogc detection-roc [--trials 2000]         Byzantine audit detection sweep
//! cogc attack [--fraction 0.3]               convergence under attack curves
//! cogc scenario list                         built-in channel-scenario catalog
//! cogc scenario run <name> [--trials 2000]   per-round time-series CSV
//! cogc error-budget [--trials 2000]          error vs communication budget
//! cogc train --model M --agg A [...]         single training run (CSV log)
//! cogc telemetry check <file.json>           validate a --telemetry export
//! cogc info                                  backend / model inventory
//! ```
//!
//! Any subcommand accepts `--telemetry <out.json>`: it arms the global
//! telemetry registry (deterministic counters + a segregated wall-clock
//! section) and writes the JSON export after the run.
//!
//! Training subcommands take `--backend auto|native|pjrt` (default `auto`:
//! PJRT when `artifacts/manifest.json` and the real bindings exist, the
//! native pure-rust models otherwise — so every figure regenerates on a
//! clean offline checkout).
//!
//! All parallel subcommands accept `--threads N` (default 0 = one worker
//! per core). Monte-Carlo sweeps (`fig4`, `fig6`, `design`) fan trials over
//! the deterministic parallel engine; the training figures (`fig7`-`fig12`)
//! fan their method grid over the same pool. Either way the emitted CSV is
//! bit-identical for every `--threads` value.

use cogc::coordinator::{Aggregator, Design};
use cogc::figures;
use cogc::gc::CodeFamily;
use cogc::network::Network;
use cogc::runtime::{Backend, CombineImpl};
use cogc::scenario::{self, ChannelSpec, NetworkSpec, Scenario};
use cogc::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_code(a: &Args) -> anyhow::Result<CodeFamily> {
    let name = a.str_opt("code", "cyclic");
    CodeFamily::parse(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown --code {name:?} (cyclic|fr|binary)"))
}

fn parse_agg(a: &Args) -> anyhow::Result<Aggregator> {
    let tr = a.usize_opt("tr", 2)?;
    let attempts = a.usize_opt("attempts", 1)?;
    Ok(match a.str_opt("agg", "cogc").as_str() {
        "ideal" => Aggregator::Ideal,
        "intermittent" => Aggregator::Intermittent,
        "cogc" => Aggregator::CoGc { design: Design::SkipRound, attempts },
        "cogc-d1" => {
            Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: attempts.max(50) }
        }
        "gcplus" => Aggregator::GcPlus { tr, until_decode: false, max_blocks: 1 },
        "gcplus-until" => Aggregator::GcPlus { tr, until_decode: true, max_blocks: 25 },
        "approx" => Aggregator::Approx { tr, until_decode: false, max_blocks: 1 },
        "approx-until" => Aggregator::Approx { tr, until_decode: true, max_blocks: 25 },
        "tandon" => Aggregator::TandonReplicated { attempts },
        other => anyhow::bail!("unknown --agg {other:?}"),
    })
}

fn parse_network(a: &Args, m: usize, seed: u64) -> anyhow::Result<Network> {
    Ok(match a.str_opt("net", "homogeneous").as_str() {
        "perfect" => Network::perfect(m),
        "homogeneous" => {
            Network::homogeneous(m, a.f64_opt("p-ps", 0.1)?, a.f64_opt("p-cc", 0.1)?)
        }
        "paper1" => Network::paper_network(1, m, seed),
        "paper2" => Network::paper_network(2, m, seed),
        "paper3" => Network::paper_network(3, m, seed),
        tier @ ("good" | "moderate" | "poor") => Network::conn_tier(tier, m),
        other => anyhow::bail!("unknown --net {other:?}"),
    })
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(argv, &["verbose", "native"], true)?;
    if args.flag("verbose") {
        cogc::util::logging::set_level(cogc::util::logging::Level::Debug);
    }
    let seed = args.u64_opt("seed", 42)?;
    let threads = args.usize_opt("threads", 0)?;
    let backend = || Backend::from_flag(&args.str_opt("backend", "auto"));
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    // --telemetry <out.json> arms the registry for any subcommand; the
    // deterministic counters land in the JSON export, the human summary on
    // stderr (stdout stays pure CSV). Disarmed (the default) the hot paths
    // skip every clock read and registry lock.
    let telemetry_out = args.get("telemetry").map(String::from);
    if telemetry_out.is_some() {
        cogc::telemetry::reset();
        cogc::telemetry::arm();
    }
    match sub.as_str() {
        "fig4" => figures::fig4(args.usize_opt("trials", 20_000)?, seed, threads).print(),
        "fig6" => figures::fig6(args.usize_opt("trials", 2_000)?, seed, threads).print(),
        "fig7" | "fig8" => {
            let model = if sub == "fig7" { "mnist_cnn" } else { "cifar_cnn" };
            let network = args.usize_opt("network", 1)?;
            let rounds = args.usize_opt("rounds", 100)?;
            figures::fig7_8(&backend()?, model, network, rounds, seed, threads)?.print();
        }
        "fig10" => figures::fig10(
            &backend()?,
            args.usize_opt("rounds", 100)?,
            args.f64_opt("target", 0.85)?,
            seed,
            threads,
        )?
        .print(),
        "fig11" | "fig12" => {
            let model = if sub == "fig11" { "mnist_cnn" } else { "cifar_cnn" };
            let conn = args.str_opt("conn", "good");
            let rounds = args.usize_opt("rounds", 100)?;
            figures::fig11_12(&backend()?, model, &conn, rounds, seed, threads)?.print();
        }
        "remark5" => figures::remark5().print(),
        "theory" => figures::theory_table().print(),
        "privacy" => figures::privacy_table(args.usize_opt("dim", 100)?)?.print(),
        "detection-roc" => {
            figures::detection_roc(args.usize_opt("trials", 2_000)?, seed, threads).print()
        }
        "attack" => {
            let model = args.str_opt("model", "mnist_cnn");
            let conn = args.str_opt("conn", "moderate");
            let fraction = args.f64_opt("fraction", 0.3)?;
            let rounds = args.usize_opt("rounds", 100)?;
            figures::convergence_under_attack(
                &backend()?,
                &model,
                &conn,
                fraction,
                rounds,
                seed,
                threads,
            )?
            .print();
        }
        "scenario" => {
            let action = args.positionals.first().map(|s| s.as_str()).unwrap_or("list");
            match action {
                "list" => {
                    anyhow::ensure!(
                        args.get("file").is_none(),
                        "--file only applies to `scenario run` (try `cogc scenario run --file …`)"
                    );
                    figures::scenario_catalog().print();
                }
                "run" => {
                    anyhow::ensure!(
                        args.positionals.len() <= 2,
                        "scenario run takes one name, got extra arguments {:?}",
                        &args.positionals[2..]
                    );
                    // --code/--m/--s retarget a scenario without editing
                    // JSON; with no name given they default to "smoke"
                    let has_overrides = args.get("code").is_some()
                        || args.get("m").is_some()
                        || args.get("s").is_some();
                    let mut sc: Scenario = match (args.get("file"), args.positionals.get(1)) {
                        (Some(_), Some(name)) => anyhow::bail!(
                            "pass either a scenario name or --file, not both (got {name:?} \
                             and --file)"
                        ),
                        (Some(path), None) => Scenario::load(std::path::Path::new(path))?,
                        (None, Some(name)) => scenario::find(name)?,
                        (None, None) if has_overrides => scenario::find("smoke")?,
                        (None, None) => anyhow::bail!(
                            "usage: cogc scenario run <name> (or --file spec.json); \
                             see `cogc scenario list`"
                        ),
                    };
                    let mut revalidate = false;
                    if let Some(r) = args.get("rounds") {
                        sc.rounds = r.parse().map_err(|_| {
                            anyhow::anyhow!("--rounds expects an integer, got {r:?}")
                        })?;
                        revalidate = true;
                    }
                    if args.get("code").is_some() {
                        sc.code = parse_code(&args)?;
                        revalidate = true;
                    }
                    if args.get("m").is_some() {
                        let m = args.usize_opt("m", 0)?;
                        match &mut sc.net {
                            NetworkSpec::Homogeneous { m: mm, .. } => *mm = m,
                            NetworkSpec::Perfect { m: mm } => *mm = m,
                        }
                        revalidate = true;
                    }
                    if args.get("s").is_some() {
                        sc.s = args.usize_opt("s", sc.s)?;
                        revalidate = true;
                    }
                    // --adversary sign_flip:0.2 (or none) overrides the
                    // scenario's Byzantine spec in place
                    if let Some(spec) = args.get("adversary") {
                        sc.adversary = if spec == "none" {
                            None
                        } else {
                            Some(scenario::AdversarySpec::parse_cli(spec)?)
                        };
                        revalidate = true;
                    }
                    // --agg standard|gcplus|approx swaps the scenario's
                    // decoder in place, keeping its per-round attempt budget
                    if let Some(agg) = args.get("agg") {
                        use cogc::sim::Decoder;
                        let budget = match sc.decoder {
                            Decoder::Standard { attempts } => attempts,
                            Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
                        };
                        sc.decoder = match agg {
                            "standard" => Decoder::Standard { attempts: budget.max(1) },
                            "gcplus" => Decoder::GcPlus { tr: budget.max(1) },
                            "approx" => Decoder::Approx { tr: budget.max(1) },
                            other => anyhow::bail!(
                                "unknown scenario --agg {other:?} (standard|gcplus|approx)"
                            ),
                        };
                        revalidate = true;
                    }
                    // --policy retry:<n>[:...] (or none) overrides the
                    // scenario's recovery policy in place
                    if let Some(spec) = args.get("policy") {
                        sc.policy = if spec == "none" {
                            None
                        } else {
                            Some(scenario::RecoveryPolicy::parse_cli(spec)?)
                        };
                        revalidate = true;
                    }
                    if revalidate {
                        sc.validate()?;
                    }
                    // dense cyclic — and the binary family's dense bridge —
                    // materialize M×M matrices per attempt; refuse federation
                    // scales that only the sparse family can carry instead of
                    // thrashing for hours
                    anyhow::ensure!(
                        sc.code == CodeFamily::FractionalRepetition || sc.net.m() <= 4096,
                        "M = {} with the {} family would allocate O(M²) state; \
                         pass --code fr (fractional repetition, needs M % (s+1) == 0)",
                        sc.net.m(),
                        sc.code.name()
                    );
                    let trials = args.usize_opt("trials", 2_000)?;
                    figures::scenario_sweep(&sc, trials, seed, threads).print();
                    if sc.adversary.is_some() {
                        eprintln!(
                            "{}",
                            figures::outage_split_summary(&sc, trials, seed, threads)?
                        );
                    }
                }
                other => anyhow::bail!("unknown scenario action {other:?} (list|run)"),
            }
        }
        "error-budget" => {
            figures::error_vs_budget(args.usize_opt("trials", 2_000)?, seed, threads).print()
        }
        "design" => figures::design_table(
            args.f64_opt("p", 0.1)?,
            args.f64_opt("target-po", 0.5)?,
            seed,
            args.usize_opt("trials", 20_000)?,
            threads,
        )
        .print(),
        "train" => {
            let backend = backend()?;
            let model = args.str_opt("model", "mnist_cnn");
            let agg = parse_agg(&args)?;
            let net = parse_network(&args, backend.manifest().m, seed)?;
            let rounds = args.usize_opt("rounds", 50)?;
            // coded-combine impl: --combine pallas|native (the boolean
            // --native flag is kept as an alias; it selects the combine
            // kernels, NOT the model backend — that is --backend native)
            let default_combine = if args.flag("native") { "native" } else { "pallas" };
            let combine = match args.str_opt("combine", default_combine).as_str() {
                "pallas" => CombineImpl::Pallas,
                "native" => CombineImpl::Native,
                other => anyhow::bail!("unknown --combine {other:?} (pallas|native)"),
            };
            // an *explicit* pallas request cannot be honored natively — fail
            // loudly instead of silently substituting the native combine
            anyhow::ensure!(
                !(backend.name() == "native" && args.get("combine") == Some("pallas")),
                "--combine pallas requires the PJRT backend (the Pallas kernels are AOT artifacts)"
            );
            // link dynamics: iid (default) or the channel model of a named
            // scenario from the registry (`cogc scenario list`)
            let channel = match args.str_opt("channel", "iid").as_str() {
                "iid" => ChannelSpec::Iid,
                name => scenario::find(name)?.channel,
            };
            // code family + straggler tolerance (fr needs M % (s+1) == 0;
            // at the backends' M=10 that means e.g. --code fr --s 4)
            let code = parse_code(&args)?;
            let s = args.usize_opt("s", 7)?;
            // Byzantine clients: --adversary <attack>:<fraction>[:...]
            // (compact spec, see `cogc scenario run --adversary`)
            let adversary = match args.get("adversary") {
                None => None,
                Some("none") => None,
                Some(spec) => Some(scenario::AdversarySpec::parse_cli(spec)?),
            };
            let (log, adv_log) = figures::train_once(
                &backend, &model, agg, net, rounds, seed, combine, channel, code, s, adversary,
            )?;
            print!("{}", log.to_csv());
            if adv_log.malicious > 0 {
                eprintln!(
                    "adversary: {} malicious clients, {} audit alarms, {} rows/copies excised",
                    adv_log.malicious, adv_log.detected, adv_log.excised
                );
            }
            if log.approx_updates() > 0 {
                eprintln!(
                    "degraded-mode fallback supplied {} of {} updates",
                    log.approx_updates(),
                    log.updates()
                );
            }
            eprintln!(
                "final acc {:.4}, best {:.4}, {} updates, {} transmissions",
                log.final_acc(),
                log.best_acc(),
                log.updates(),
                log.total_transmissions()
            );
        }
        "telemetry" => {
            let action = args.positionals.first().map(|s| s.as_str()).unwrap_or("");
            match action {
                "check" => {
                    let path = args.positionals.get(1).ok_or_else(|| {
                        anyhow::anyhow!("usage: cogc telemetry check <file.json>")
                    })?;
                    let text = std::fs::read_to_string(path)?;
                    match cogc::telemetry::check_json(&text) {
                        Ok(msg) => println!("{msg}"),
                        Err(e) => anyhow::bail!("telemetry check failed for {path}: {e}"),
                    }
                }
                other => anyhow::bail!("unknown telemetry action {other:?} (check)"),
            }
        }
        "info" => {
            let backend = backend()?;
            println!("backend: {} | platform: {}", backend.name(), backend.platform());
            let man = backend.manifest();
            if backend.name() == "pjrt" {
                println!("artifacts: {}", man.dir.display());
            }
            println!("M={} t_r={} MT={}", man.m, man.tr, man.mt);
            for (name, spec) in &man.models {
                println!(
                    "  {name}: D={} batch={} x={:?} params={}",
                    spec.d,
                    spec.batch,
                    spec.x_shape,
                    spec.params.len()
                );
            }
        }
        _ => {
            println!("{}", HELP.trim());
        }
    }
    if let Some(path) = telemetry_out {
        cogc::telemetry::write_json(std::path::Path::new(&path))
            .map_err(|e| anyhow::anyhow!("writing telemetry to {path}: {e}"))?;
        eprint!("{}", cogc::telemetry::summary_table().to_csv());
        eprintln!("telemetry written to {path}");
        cogc::telemetry::disarm();
    }
    Ok(())
}

const HELP: &str = r#"
cogc — Cooperative Gradient Coding (CoGC + GC+) launcher

figures (CSV on stdout):
  fig4 fig6 fig7 fig8 fig10 fig11 fig12 remark5 theory privacy design

byzantine (adversarial clients; see the README threat-model section):
  detection-roc [--trials N]      audit detection / poisoning / false-excision
                                  rates vs attack strategy x malicious fraction
  attack [--model M]              GC+ training curves: clean vs attacked
        [--conn good|moderate|poor] (no detection) vs attacked + decode audit
        [--fraction F] [--rounds N]
  --adversary <spec>              attack spec for `scenario run` / `train`:
                                  <attack>:<fraction>[:<param>][:c2c][:nodetect]
                                  attacks: sign_flip | noise | replace | collude
                                  e.g. sign_flip:0.2, noise:0.1:5.0,
                                  collude:0.3:1.0:c2c:nodetect, or `none`
                                  (c2c = consistent-substitution surface — it
                                  satisfies every coding relation, undetectable
                                  by parity audits; uplink is the default)

scenarios (stateful channels: bursty / correlated / straggler links):
  scenario list                   built-in catalog (name, channel, regime)
  scenario run <name>             per-round time-series CSV (outage rate,
        [--trials N] [--rounds R] GC+ full/partial/none split, burst
                                  fraction, deadline hit-rate, wall-clock;
                                  adversarial scenarios — the byz-* builtins
                                  or --adversary — add corruption/detection/
                                  poisoning columns and print the 2x2
                                  recovery x integrity split)
        [--code cyclic|fr|binary] code family: dense cyclic (default),
        [--m N] [--s S]           fractional repetition — the sparse
                                  O(M·(s+1)) path that scales to M = 10^5-10^6
                                  (needs M % (s+1) == 0) — or the exact ±1
                                  binary family (needs even s); --m/--s
                                  retarget the scenario's federation size in
                                  place (default scenario: smoke)
        [--agg standard|gcplus|approx]  swap the scenario's decoder in place
                                  (approx = GC+ with the least-squares
                                  degraded-mode fallback when nothing
                                  decodes exactly; adds p_approx + residual
                                  histogram columns)
        [--policy <spec>]         recovery policy override (or `none`):
                                  retry:<n>[:backoff=<b>][:deadline=<d>]
                                  [:approx[=<thr>]][:kill_up=<i,...>]
                                  [:kill_c2c=<i-j,...>][:crash=<c>@<r>+<n>]
                                  e.g. retry:2:deadline=6:approx=0.5, or
                                  retry:0:kill_up=0,3:crash=1@5+10 for
                                  link-fault injection
  scenario run --file spec.json   run a custom JSON scenario spec

degraded-mode decoding (see the README section of the same name):
  error-budget [--trials N]       error vs communication budget across the
                                  non-adversarial dense builtins: exact GC+,
                                  pure approx, and retry+fallback policy
                                  regimes side by side (p_exact / p_approx /
                                  p_miss / tx and retries per round)

training:
  train --model mnist_cnn|cifar_cnn|transformer
        --agg ideal|intermittent|cogc|cogc-d1|gcplus|gcplus-until|tandon
              |approx|approx-until  (approx = gcplus + the least-squares
                     fallback update on rounds that decode nothing exactly;
                     per-round relative residual lands in the CSV log)
        --net perfect|homogeneous|paper1|paper2|paper3|good|moderate|poor
        [--rounds N] [--seed S] [--p-ps P] [--p-cc P] [--tr T] [--attempts A]
        [--channel iid|<scenario>]  link dynamics: iid or the channel model
                     of a named scenario (e.g. --channel bursty-c2c)
        [--code cyclic|fr|binary] [--s S]  gradient-code family + straggler
                     tolerance (fr needs M % (s+1) == 0, e.g. --s 4 at M=10;
                     binary decodes exactly and needs even s)
        [--combine pallas|native]   coded-combine kernels (NOT the model
                     backend — see --backend); pallas needs PJRT artifacts
        [--adversary <spec>]        Byzantine clients (fixed set for the run);
                     the decode-path audit excises corrupted rows unless
                     :nodetect — alarms/excisions reported after the run

observability:
  --telemetry FILE  arm the telemetry registry for any subcommand and write
                  a JSON export after the run: counters/gauges/histograms
                  are deterministic (bit-identical at any --threads); phase
                  wall-clock and worker throughput live in a separate
                  non_deterministic section. Armed `scenario run` CSVs
                  append mean_peeled/mean_forwarded columns; a stderr
                  summary table prints after the run (stdout stays CSV)
  telemetry check <file.json>   validate a --telemetry export (schema
                  version, counter/histogram integrity) — the CI smoke gate

misc:
  info            show backend + model inventory
  --backend B     auto|native|pjrt for training subcommands (default auto:
                  PJRT artifacts when available, else the offline native
                  pure-rust models — no `make artifacts` needed)
  --threads N     worker threads (0 = one per core, the default) for the
                  Monte-Carlo sweeps (fig4/fig6/design) and the training
                  figure grids (fig7/fig8/fig10/fig11/fig12); results are
                  bit-identical for every N
  --verbose       debug logging
"#;
