//! `cogc` — the CoGC launcher.
//!
//! Subcommands regenerate every paper figure as CSV on stdout, run custom
//! training configurations, and expose the analysis tooling:
//!
//! ```text
//! cogc fig4 [--trials 20000]                 outage P_O vs s (Fig. 4)
//! cogc fig6 [--trials 2000]                  GC+ recovery stats (Fig. 6)
//! cogc fig7  --network 1|2|3 [--rounds 100]  MNIST curves (Fig. 7)
//! cogc fig8  --network 1|2|3                 CIFAR curves (Fig. 8)
//! cogc fig10 [--target 0.85]                 cost-efficient GC (Fig. 10)
//! cogc fig11 --conn good|moderate|poor       GC+ vs GC, MNIST (Fig. 11)
//! cogc fig12 --conn good|moderate|poor       GC+ vs GC, CIFAR (Fig. 12)
//! cogc remark5                               Remark-5 case study
//! cogc theory                                Theorem-1 / Lemma-5 numerics
//! cogc privacy [--dim 100]                   Lemma-1 LMIP table
//! cogc design [--p 0.1] [--target-po 0.5]    eq. (21) design sweep + MC check
//! cogc train --model M --agg A [...]         single training run (CSV log)
//! cogc info                                  runtime / artifact info
//! ```
//!
//! The Monte-Carlo-backed subcommands (`fig4`, `fig6`, `design`) accept
//! `--threads N` (default 0 = one worker per core). Trial sweeps run
//! through the deterministic parallel engine (`cogc::parallel`), so the
//! emitted statistics are bit-identical for every `--threads` value and
//! match a serial run.

use cogc::coordinator::{Aggregator, Design};
use cogc::figures;
use cogc::network::Network;
use cogc::runtime::{default_artifacts_dir, CombineImpl, Engine, Manifest};
use cogc::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_agg(a: &Args) -> anyhow::Result<Aggregator> {
    let tr = a.usize_opt("tr", 2)?;
    let attempts = a.usize_opt("attempts", 1)?;
    Ok(match a.str_opt("agg", "cogc").as_str() {
        "ideal" => Aggregator::Ideal,
        "intermittent" => Aggregator::Intermittent,
        "cogc" => Aggregator::CoGc { design: Design::SkipRound, attempts },
        "cogc-d1" => Aggregator::CoGc { design: Design::RetryUntilSuccess, attempts: attempts.max(50) },
        "gcplus" => Aggregator::GcPlus { tr, until_decode: false, max_blocks: 1 },
        "gcplus-until" => Aggregator::GcPlus { tr, until_decode: true, max_blocks: 25 },
        "tandon" => Aggregator::TandonReplicated { attempts },
        other => anyhow::bail!("unknown --agg {other:?}"),
    })
}

fn parse_network(a: &Args, m: usize, seed: u64) -> anyhow::Result<Network> {
    Ok(match a.str_opt("net", "homogeneous").as_str() {
        "perfect" => Network::perfect(m),
        "homogeneous" => {
            Network::homogeneous(m, a.f64_opt("p-ps", 0.1)?, a.f64_opt("p-cc", 0.1)?)
        }
        "paper1" => Network::paper_network(1, m, seed),
        "paper2" => Network::paper_network(2, m, seed),
        "paper3" => Network::paper_network(3, m, seed),
        tier @ ("good" | "moderate" | "poor") => Network::conn_tier(tier, m),
        other => anyhow::bail!("unknown --net {other:?}"),
    })
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(argv, &["verbose", "native"], true)?;
    if args.flag("verbose") {
        cogc::util::logging::set_level(cogc::util::logging::Level::Debug);
    }
    let seed = args.u64_opt("seed", 42)?;
    let threads = args.usize_opt("threads", 0)?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "fig4" => figures::fig4(args.usize_opt("trials", 20_000)?, seed, threads).print(),
        "fig6" => figures::fig6(args.usize_opt("trials", 2_000)?, seed, threads).print(),
        "fig7" | "fig8" => {
            let model = if sub == "fig7" { "mnist_cnn" } else { "cifar_cnn" };
            let network = args.usize_opt("network", 1)?;
            let rounds = args.usize_opt("rounds", 100)?;
            figures::fig7_8(model, network, rounds, seed)?.print();
        }
        "fig10" => figures::fig10(
            args.usize_opt("rounds", 100)?,
            args.f64_opt("target", 0.85)?,
            seed,
        )?
        .print(),
        "fig11" | "fig12" => {
            let model = if sub == "fig11" { "mnist_cnn" } else { "cifar_cnn" };
            let conn = args.str_opt("conn", "good");
            let rounds = args.usize_opt("rounds", 100)?;
            figures::fig11_12(model, &conn, rounds, seed)?.print();
        }
        "remark5" => figures::remark5().print(),
        "theory" => figures::theory_table().print(),
        "privacy" => figures::privacy_table(args.usize_opt("dim", 100)?).print(),
        "design" => figures::design_table(
            args.f64_opt("p", 0.1)?,
            args.f64_opt("target-po", 0.5)?,
            seed,
            args.usize_opt("trials", 20_000)?,
            threads,
        )
        .print(),
        "train" => {
            let model = args.str_opt("model", "mnist_cnn");
            let agg = parse_agg(&args)?;
            let net = parse_network(&args, 10, seed)?;
            let rounds = args.usize_opt("rounds", 50)?;
            let combine = if args.flag("native") { CombineImpl::Native } else { CombineImpl::Pallas };
            let log = figures::train_once(&model, agg, net, rounds, seed, combine)?;
            print!("{}", log.to_csv());
            eprintln!(
                "final acc {:.4}, best {:.4}, {} updates, {} transmissions",
                log.final_acc(),
                log.best_acc(),
                log.updates(),
                log.total_transmissions()
            );
        }
        "info" => {
            let engine = Engine::cpu()?;
            println!("platform: {}", engine.platform());
            let dir = default_artifacts_dir();
            println!("artifacts: {}", dir.display());
            let man = Manifest::load(&dir)?;
            println!("M={} t_r={} MT={}", man.m, man.tr, man.mt);
            for (name, spec) in &man.models {
                println!(
                    "  {name}: D={} batch={} x={:?} artifacts={:?}",
                    spec.d,
                    spec.batch,
                    spec.x_shape,
                    spec.artifacts.keys().collect::<Vec<_>>()
                );
            }
        }
        _ => {
            println!("{}", HELP.trim());
        }
    }
    Ok(())
}

const HELP: &str = r#"
cogc — Cooperative Gradient Coding (CoGC + GC+) launcher

figures (CSV on stdout):
  fig4 fig6 fig7 fig8 fig10 fig11 fig12 remark5 theory privacy design

training:
  train --model mnist_cnn|cifar_cnn|transformer
        --agg ideal|intermittent|cogc|cogc-d1|gcplus|gcplus-until|tandon
        --net perfect|homogeneous|paper1|paper2|paper3|good|moderate|poor
        [--rounds N] [--seed S] [--p-ps P] [--p-cc P] [--tr T] [--attempts A]
        [--native]   (native rust combine instead of the Pallas artifacts)

misc:
  info         show platform + artifact inventory
  --threads N  Monte-Carlo worker threads for fig4/fig6/design (0 = one per
               core, the default); results are bit-identical for every N —
               trial sweeps use counter-seeded RNG streams and order-fixed
               chunk merges
  --verbose    debug logging
"#;
