//! Metrics: training curves, round events, CSV emission.
//!
//! Two building blocks shared by the trainer, the figure harnesses, and
//! the bench binaries:
//!
//! - [`RunLog`] — the per-round record stream of one training run
//!   ([`RoundRecord`]: decode outcome, |K₄|, attempts, transmissions,
//!   losses, accuracy) plus the summary queries the figures need
//!   (`final_acc`, `best_acc`, `rounds_to_acc`, `total_transmissions`).
//! - [`Table`] — a generic CSV table with a `#`-prefixed comment header,
//!   used for every figure series the CLI prints.
//!
//! Everything renders through `to_csv()` with fixed float formatting, so
//! two identical runs produce byte-identical output — the property the
//! determinism tests (`--threads` invariance, seed reproducibility)
//! assert on.

use std::fmt::Write as _;

/// One training-round record.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Whether the PS updated the global model this round.
    pub updated: bool,
    /// Decode outcome: "standard", "full", "partial", "none", or baseline tag.
    pub outcome: String,
    /// Number of local models the update aggregated (0 when no update).
    pub k4: usize,
    /// Communication attempts consumed this round.
    pub attempts: usize,
    /// Transmissions consumed this round (sharing + uplinks).
    pub transmissions: usize,
    /// Mean training loss over clients' local steps this round.
    pub train_loss: f64,
    /// Test accuracy of the PS global model (NaN when not evaluated).
    pub test_acc: f64,
    /// Test loss of the PS global model (NaN when not evaluated).
    pub test_loss: f64,
    /// Relative residual `‖𝟙 − w·A‖/√M` of the round's aggregate: 0 for an
    /// exact decode (or no update), positive when the degraded-mode
    /// least-squares fallback supplied the update — the per-round
    /// gradient-error series of the `approx` aggregator.
    pub residual: f64,
}

/// Accumulates per-round records and renders CSV.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunLog {
    pub fn new(name: &str) -> Self {
        RunLog { name: name.to_string(), rounds: Vec::new() }
    }

    pub fn push(&mut self, rec: RoundRecord) {
        self.rounds.push(rec);
    }

    /// Total transmissions across all rounds.
    pub fn total_transmissions(&self) -> usize {
        self.rounds.iter().map(|r| r.transmissions).sum()
    }

    /// Number of rounds with a successful global update.
    pub fn updates(&self) -> usize {
        self.rounds.iter().filter(|r| r.updated).count()
    }

    /// Rounds whose update came from the degraded-mode least-squares
    /// fallback rather than an exact decode.
    pub fn approx_updates(&self) -> usize {
        self.rounds.iter().filter(|r| r.outcome == "approx").count()
    }

    /// Final test accuracy (last evaluated round).
    pub fn final_acc(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| r.test_acc.is_finite())
            .map(|r| r.test_acc)
            .unwrap_or(f64::NAN)
    }

    /// Best test accuracy seen.
    pub fn best_acc(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| r.test_acc.is_finite())
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }

    /// First round index whose test accuracy reaches `target`, if any.
    pub fn rounds_to_acc(&self, target: f64) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.test_acc.is_finite() && r.test_acc >= target)
            .map(|r| r.round)
    }

    /// CSV with a `# name` header comment.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# run: {}", self.name);
        let _ = writeln!(
            out,
            "round,updated,outcome,k4,attempts,transmissions,train_loss,test_loss,test_acc,residual"
        );
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{:.6},{:.6},{:.4},{:.6}",
                r.round,
                r.updated as u8,
                r.outcome,
                r.k4,
                r.attempts,
                r.transmissions,
                r.train_loss,
                r.test_loss,
                r.test_acc,
                r.residual
            );
        }
        out
    }
}

/// Generic CSV table builder for figure series.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub comment: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(comment: &str, header: &[&str]) -> Self {
        Table {
            comment: comment.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|x| format!("{x:.6}")).collect::<Vec<_>>());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for line in self.comment.lines() {
            let _ = writeln!(out, "# {line}");
        }
        let _ = writeln!(out, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, updated: bool, tx: usize) -> RoundRecord {
        RoundRecord {
            round,
            updated,
            outcome: "standard".into(),
            k4: 10,
            attempts: 1,
            transmissions: tx,
            train_loss: 1.0,
            test_loss: 0.5,
            test_acc: acc,
            residual: 0.0,
        }
    }

    #[test]
    fn runlog_aggregates() {
        let mut log = RunLog::new("test");
        log.push(rec(0, 0.2, true, 80));
        log.push(rec(1, f64::NAN, false, 75));
        log.push(rec(2, 0.5, true, 80));
        assert_eq!(log.updates(), 2);
        assert_eq!(log.total_transmissions(), 235);
        assert_eq!(log.final_acc(), 0.5);
        assert_eq!(log.best_acc(), 0.5);
        assert_eq!(log.rounds_to_acc(0.4), Some(2));
        assert_eq!(log.rounds_to_acc(0.9), None);
        let csv = log.to_csv();
        assert!(csv.starts_with("# run: test"));
        assert_eq!(csv.lines().count(), 5);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("fig4: P_O vs s", &["s", "p_o"]);
        t.rowf(&[1.0, 0.25]);
        t.rowf(&[2.0, 0.125]);
        let csv = t.to_csv();
        assert!(csv.contains("# fig4"));
        assert!(csv.contains("s,p_o"));
        assert_eq!(csv.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.rowf(&[1.0]);
    }
}
