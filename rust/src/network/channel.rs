//! Channel realizations: draw `T(r)` and `tau(r)` for one communication
//! attempt (paper §II-B). All links are independent Bernoulli erasures.

use super::topology::Network;
use crate::util::rng::Rng;

/// One realization of the client-to-client link matrix `T(r)` and the
/// client-to-PS link vector `tau(r)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Realization {
    /// `t[(m,k)] = true` iff the link from client k to client m is up.
    /// Diagonal is always true (no transmission to self).
    pub t: Vec<Vec<bool>>,
    /// `tau[m] = true` iff the uplink from client m to the PS is up.
    pub tau: Vec<bool>,
}

impl Realization {
    /// Draw a realization with per-link outage probabilities supplied by
    /// closures — the emission-draw contract every stateful channel model in
    /// [`crate::scenario`] is built on: exactly one Bernoulli draw from `rng`
    /// per off-diagonal c2c link in row-major `(m, k)` order, then one per
    /// uplink in client order; the diagonal consumes **no** draw. Any two
    /// models whose closures return the same probabilities therefore consume
    /// byte-identical RNG streams (the degenerate-equivalence guarantee).
    pub fn sample_with(
        m: usize,
        rng: &mut Rng,
        p_c2c: impl FnMut(usize, usize) -> f64,
        p_c2s: impl FnMut(usize) -> f64,
    ) -> Realization {
        let mut out = Realization { t: Vec::new(), tau: Vec::new() };
        Realization::sample_with_into(m, rng, p_c2c, p_c2s, &mut out);
        out
    }

    /// [`Realization::sample_with`] into a reused buffer: identical draws
    /// in the identical order (the short-circuited diagonal consumes no
    /// draw), but steady-state reuse allocates nothing — the Monte-Carlo
    /// hot loops keep one `Realization` per worker and refill it per
    /// attempt.
    pub fn sample_with_into(
        m: usize,
        rng: &mut Rng,
        mut p_c2c: impl FnMut(usize, usize) -> f64,
        mut p_c2s: impl FnMut(usize) -> f64,
        out: &mut Realization,
    ) {
        if out.tau.len() != m || out.t.len() != m {
            out.t = vec![vec![true; m]; m];
            out.tau = vec![true; m];
        }
        for (i, row) in out.t.iter_mut().enumerate() {
            debug_assert_eq!(row.len(), m);
            for (j, up) in row.iter_mut().enumerate() {
                *up = i == j || !rng.bernoulli(p_c2c(i, j));
            }
        }
        for (i, up) in out.tau.iter_mut().enumerate() {
            *up = !rng.bernoulli(p_c2s(i));
        }
    }

    /// Draw a fresh memoryless realization from the network's per-link
    /// Bernoulli probabilities.
    pub fn sample(net: &Network, rng: &mut Rng) -> Realization {
        Realization::sample_with(net.m, rng, |i, j| net.p_c2c(i, j), |i| net.p_c2s[i])
    }

    /// All links up (ideal-FL baseline / perfect round).
    pub fn perfect(m: usize) -> Realization {
        Realization { t: vec![vec![true; m]; m], tau: vec![true; m] }
    }

    pub fn m(&self) -> usize {
        self.tau.len()
    }

    /// True iff client `m` heard every incoming link in `incoming`.
    pub fn heard_all(&self, m: usize, incoming: &[usize]) -> bool {
        incoming.iter().all(|&k| self.t[m][k])
    }

    /// Number of up uplinks.
    pub fn uplinks_up(&self) -> usize {
        self.tau.iter().filter(|&&b| b).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_network_never_fails() {
        let net = Network::perfect(8);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let r = Realization::sample(&net, &mut rng);
            assert!(r.tau.iter().all(|&b| b));
            assert!(r.t.iter().all(|row| row.iter().all(|&b| b)));
        }
    }

    #[test]
    fn always_down_network() {
        let net = Network::homogeneous(6, 1.0, 1.0);
        let mut rng = Rng::new(2);
        let r = Realization::sample(&net, &mut rng);
        assert!(r.tau.iter().all(|&b| !b));
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(r.t[i][j], i == j, "diagonal stays up");
            }
        }
    }

    #[test]
    fn outage_rates_match_probabilities() {
        let net = Network::homogeneous(10, 0.4, 0.25);
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut up_tau = 0usize;
        let mut up_t = 0usize;
        for _ in 0..n {
            let r = Realization::sample(&net, &mut rng);
            up_tau += r.tau[3] as usize;
            up_t += r.t[2][7] as usize;
        }
        let f_tau = up_tau as f64 / n as f64;
        let f_t = up_t as f64 / n as f64;
        assert!((f_tau - 0.6).abs() < 0.02, "tau up-rate {f_tau}");
        assert!((f_t - 0.75).abs() < 0.02, "t up-rate {f_t}");
    }

    #[test]
    fn sample_with_matches_sample_draw_for_draw() {
        let net = Network::homogeneous(7, 0.3, 0.4);
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..20 {
            let r1 = Realization::sample(&net, &mut a);
            let r2 =
                Realization::sample_with(7, &mut b, |i, j| net.p_c2c(i, j), |i| net.p_c2s[i]);
            assert_eq!(r1, r2);
        }
        // the two streams advanced identically
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn heard_all_semantics() {
        let mut r = Realization::perfect(5);
        r.t[2][4] = false;
        assert!(r.heard_all(2, &[1, 3]));
        assert!(!r.heard_all(2, &[3, 4]));
        assert_eq!(r.uplinks_up(), 5);
    }
}
