//! Unreliable-communication model (paper §II-B): Bernoulli-erasure links
//! between clients and from clients to the parameter server.

pub mod channel;
pub mod sparse;
pub mod topology;

pub use channel::Realization;
pub use sparse::{SparseRealization, SparseSupport};
pub use topology::Network;
