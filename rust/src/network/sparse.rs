//! Sparse channel realizations for structured code families.
//!
//! The dense [`Realization`](super::Realization) samples every off-diagonal
//! client-to-client link — O(M²) draws and O(M²) bytes — even though a
//! structured code only ever *reads* the s incoming links on each row's
//! support. [`SparseRealization`] samples exactly those M·s supported links
//! (plus the M uplinks), so the structured path stays O(M·(s+1)) in both
//! time and memory and scales to M = 10⁵–10⁶ clients.
//!
//! The support itself is implicit: [`SparseSupport`] maps `(row, idx)` to
//! the idx-th incoming neighbour arithmetically (cyclic offset or
//! fractional-repetition group member), so no adjacency lists are stored.
//!
//! Draw schedule (the sparse analogue of the dense emission contract):
//! exactly one Bernoulli per supported incoming link in row-major
//! `(row, idx)` order, then one per uplink in client order. Any two channel
//! models that feed identical probabilities therefore consume byte-identical
//! RNG streams, which is what the degenerate-equivalence tests pin down.

use super::topology::Network;
use crate::util::rng::Rng;

/// Implicit incoming-link support of a structured code: which s neighbours
/// each row listens to, computed arithmetically instead of stored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseSupport {
    /// Cyclic code support: row r listens to rows r+1 … r+s (mod M).
    Cyclic { m: usize, s: usize },
    /// Fractional-repetition support: row r listens to the other s members
    /// of its (s+1)-sized group. Requires M divisible by s+1.
    Group { m: usize, s: usize },
}

impl SparseSupport {
    pub fn cyclic(m: usize, s: usize) -> SparseSupport {
        assert!(s < m, "cyclic support needs s < M");
        SparseSupport::Cyclic { m, s }
    }

    pub fn group(m: usize, s: usize) -> SparseSupport {
        assert!(s < m && m % (s + 1) == 0, "group support needs s < M and M % (s+1) == 0");
        SparseSupport::Group { m, s }
    }

    #[inline]
    pub fn m(&self) -> usize {
        match *self {
            SparseSupport::Cyclic { m, .. } | SparseSupport::Group { m, .. } => m,
        }
    }

    /// Incoming links per row (= s for both families).
    #[inline]
    pub fn k(&self) -> usize {
        match *self {
            SparseSupport::Cyclic { s, .. } | SparseSupport::Group { s, .. } => s,
        }
    }

    /// The idx-th incoming neighbour of `row` (idx < k).
    #[inline]
    pub fn neighbor(&self, row: usize, idx: usize) -> usize {
        match *self {
            SparseSupport::Cyclic { m, s } => {
                debug_assert!(idx < s);
                (row + 1 + idx) % m
            }
            SparseSupport::Group { s, .. } => {
                debug_assert!(idx < s);
                let base = row - row % (s + 1);
                let off = row - base;
                // skip self: group members base..base+s, excluding `row`
                base + idx + (idx >= off) as usize
            }
        }
    }

    /// Iterator over the incoming neighbours of `row`.
    pub fn incoming_iter(&self, row: usize) -> impl Iterator<Item = usize> + '_ {
        let k = self.k();
        (0..k).map(move |idx| self.neighbor(row, idx))
    }

    /// Total supported incoming links (M·s).
    pub fn links(&self) -> usize {
        self.m() * self.k()
    }
}

/// One channel realization restricted to a sparse support: M·s incoming
/// link states plus M uplink states. The structured-path replacement for
/// the dense M×M [`Realization`](super::Realization).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseRealization {
    /// Incoming links per row (mirrors the support's `k`).
    pub k: usize,
    /// `t[row * k + idx] = true` iff the link from `support.neighbor(row,
    /// idx)` to `row` is up. Length M·k.
    pub t: Vec<bool>,
    /// `tau[m] = true` iff the uplink from client m to the PS is up.
    pub tau: Vec<bool>,
}

impl SparseRealization {
    /// Draw a realization on `sup`'s links with per-link probabilities
    /// supplied by closures, into a reused buffer. Exactly one Bernoulli
    /// per supported link in row-major `(row, idx)` order, then one per
    /// uplink; steady-state reuse allocates nothing. The c2c closure
    /// receives `(row, idx, neighbor)` so stateful models can index their
    /// per-link state by flat `(row, idx)` position without recomputing
    /// support arithmetic.
    pub fn sample_with_into(
        sup: &SparseSupport,
        rng: &mut Rng,
        mut p_c2c: impl FnMut(usize, usize, usize) -> f64,
        mut p_c2s: impl FnMut(usize) -> f64,
        out: &mut SparseRealization,
    ) {
        let (m, k) = (sup.m(), sup.k());
        if out.k != k || out.tau.len() != m || out.t.len() != m * k {
            out.k = k;
            out.t = vec![true; m * k];
            out.tau = vec![true; m];
        }
        for row in 0..m {
            for idx in 0..k {
                let j = sup.neighbor(row, idx);
                out.t[row * k + idx] = !rng.bernoulli(p_c2c(row, idx, j));
            }
        }
        for (i, up) in out.tau.iter_mut().enumerate() {
            *up = !rng.bernoulli(p_c2s(i));
        }
    }

    /// Draw a fresh memoryless realization from the network's per-link
    /// Bernoulli probabilities, restricted to `sup`.
    pub fn sample(sup: &SparseSupport, net: &Network, rng: &mut Rng) -> SparseRealization {
        let mut out = SparseRealization::default();
        SparseRealization::sample_with_into(
            sup,
            rng,
            |row, _idx, j| net.p_c2c(row, j),
            |i| net.p_c2s[i],
            &mut out,
        );
        out
    }

    /// All links up (perfect round).
    pub fn perfect(sup: &SparseSupport) -> SparseRealization {
        SparseRealization {
            k: sup.k(),
            t: vec![true; sup.links()],
            tau: vec![true; sup.m()],
        }
    }

    /// Project a dense realization onto `sup` — same link states, sparse
    /// layout. The equivalence tests use this to run the dense oracle and
    /// the sparse scan on *identical* channel draws.
    pub fn project_from_dense(sup: &SparseSupport, dense: &super::Realization) -> SparseRealization {
        let (m, k) = (sup.m(), sup.k());
        assert_eq!(dense.m(), m);
        let mut t = vec![true; m * k];
        for row in 0..m {
            for idx in 0..k {
                t[row * k + idx] = dense.t[row][sup.neighbor(row, idx)];
            }
        }
        SparseRealization { k, t, tau: dense.tau.clone() }
    }

    pub fn m(&self) -> usize {
        self.tau.len()
    }

    /// State of the idx-th incoming link of `row`.
    #[inline]
    pub fn link_up(&self, row: usize, idx: usize) -> bool {
        self.t[row * self.k + idx]
    }

    /// True iff `row` heard every one of its incoming links.
    #[inline]
    pub fn heard_all(&self, row: usize) -> bool {
        self.t[row * self.k..(row + 1) * self.k].iter().all(|&b| b)
    }

    /// True iff `row`'s coded combination reaches the PS: all incoming
    /// links up *and* the uplink up.
    #[inline]
    pub fn row_delivered_complete(&self, row: usize) -> bool {
        self.tau[row] && self.heard_all(row)
    }

    /// Number of up uplinks.
    pub fn uplinks_up(&self) -> usize {
        self.tau.iter().filter(|&&b| b).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Realization;

    #[test]
    fn cyclic_neighbors_match_offsets() {
        let sup = SparseSupport::cyclic(10, 3);
        assert_eq!(sup.incoming_iter(0).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(sup.incoming_iter(8).collect::<Vec<_>>(), vec![9, 0, 1]);
        assert_eq!(sup.k(), 3);
        assert_eq!(sup.links(), 30);
    }

    #[test]
    fn group_neighbors_skip_self() {
        let sup = SparseSupport::group(12, 3);
        // group 0 = rows 0..4
        assert_eq!(sup.incoming_iter(0).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(sup.incoming_iter(2).collect::<Vec<_>>(), vec![0, 1, 3]);
        assert_eq!(sup.incoming_iter(3).collect::<Vec<_>>(), vec![0, 1, 2]);
        // group 2 = rows 8..12
        assert_eq!(sup.incoming_iter(9).collect::<Vec<_>>(), vec![8, 10, 11]);
    }

    #[test]
    #[should_panic]
    fn group_requires_divisibility() {
        SparseSupport::group(10, 3);
    }

    #[test]
    fn perfect_and_heard_all() {
        let sup = SparseSupport::group(8, 1);
        let mut r = SparseRealization::perfect(&sup);
        assert!(r.heard_all(5));
        assert!(r.row_delivered_complete(5));
        r.t[5] = false; // row 5, idx 0
        assert!(!r.heard_all(5));
        r.tau[2] = false;
        assert!(!r.row_delivered_complete(2));
        assert_eq!(r.uplinks_up(), 7);
    }

    #[test]
    fn sample_rates_match_probabilities() {
        let net = Network::homogeneous(12, 0.4, 0.25);
        let sup = SparseSupport::cyclic(12, 3);
        let mut rng = Rng::new(3);
        let n = 20_000;
        let (mut up_tau, mut up_t) = (0usize, 0usize);
        for _ in 0..n {
            let r = SparseRealization::sample(&sup, &net, &mut rng);
            up_tau += r.tau[3] as usize;
            up_t += r.link_up(2, 1) as usize;
        }
        let f_tau = up_tau as f64 / n as f64;
        let f_t = up_t as f64 / n as f64;
        assert!((f_tau - 0.6).abs() < 0.02, "tau up-rate {f_tau}");
        assert!((f_t - 0.75).abs() < 0.02, "t up-rate {f_t}");
    }

    #[test]
    fn projection_agrees_with_dense_states() {
        let net = Network::homogeneous(12, 0.3, 0.5);
        let sup = SparseSupport::group(12, 2);
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let dense = Realization::sample(&net, &mut rng);
            let sparse = SparseRealization::project_from_dense(&sup, &dense);
            assert_eq!(sparse.tau, dense.tau);
            for row in 0..12 {
                for idx in 0..sup.k() {
                    assert_eq!(sparse.link_up(row, idx), dense.t[row][sup.neighbor(row, idx)]);
                }
                let inc: Vec<usize> = sup.incoming_iter(row).collect();
                assert_eq!(sparse.heard_all(row), dense.heard_all(row, &inc));
            }
        }
    }
}
