//! Network topologies: per-link Bernoulli outage probabilities (paper §II-B).
//!
//! Links are independent binary erasures: client-k → client-m fails with
//! probability `p_c2c(m, k)`; client-m → PS fails with probability
//! `p_c2s[m]`. Downlink broadcast is error-free (paper assumption).
//!
//! Client-to-client probabilities are stored behind an implicit/dense enum:
//! every homogeneous constructor keeps a single shared value (O(1) storage,
//! which is what lets the structured large-M path run at M = 10⁵–10⁶
//! without an M×M matrix), while the heterogeneous constructors fall back
//! to a dense per-link matrix. The [`Network::p_c2c`] accessor returns the
//! same values either way, so the dense small-M paths are unchanged.
//!
//! The named constructors reproduce the paper's experimental networks:
//! Fig. 9's Networks 1–3 (homogeneous / heterogeneous client→PS), Fig. 6's
//! settings 1–4, and Fig. 11/12's good/moderate/poor client-to-client tiers.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Client-to-client outage storage: one shared off-diagonal value (the only
/// form the large-M structured path ever builds) or a dense per-link matrix
/// (the heterogeneous small-M networks).
#[derive(Clone, Debug)]
enum C2c {
    Uniform(f64),
    Dense(Matrix),
}

#[derive(Clone, Debug)]
pub struct Network {
    pub m: usize,
    /// `p_c2s[m]`: outage probability of the uplink from client m to the PS.
    pub p_c2s: Vec<f64>,
    c2c: C2c,
}

impl Network {
    /// Homogeneous network: every uplink fails w.p. `p_ps`, every
    /// client-to-client link w.p. `p_cc`. Stores no per-link state, so this
    /// is O(M) memory at any M.
    pub fn homogeneous(m: usize, p_ps: f64, p_cc: f64) -> Network {
        assert!((0.0..=1.0).contains(&p_ps) && (0.0..=1.0).contains(&p_cc));
        Network { m, p_c2s: vec![p_ps; m], c2c: C2c::Uniform(p_cc) }
    }

    /// Heterogeneous uplinks drawn from U(lo, hi); homogeneous c2c links.
    pub fn heterogeneous_uplink(m: usize, lo: f64, hi: f64, p_cc: f64, rng: &mut Rng) -> Network {
        let mut net = Network::homogeneous(m, 0.0, p_cc);
        for p in &mut net.p_c2s {
            *p = rng.uniform(lo, hi);
        }
        net
    }

    /// Fully heterogeneous: uplinks U(lo_s,hi_s), c2c links U(lo_c,hi_c).
    /// Draw order (uplinks, then row-major off-diagonal c2c) is part of the
    /// reproducibility contract for the paper networks.
    pub fn heterogeneous(
        m: usize,
        (lo_s, hi_s): (f64, f64),
        (lo_c, hi_c): (f64, f64),
        rng: &mut Rng,
    ) -> Network {
        let mut net = Network::homogeneous(m, 0.0, 0.0);
        for p in &mut net.p_c2s {
            *p = rng.uniform(lo_s, hi_s);
        }
        let mut p_c2c = Matrix::from_fn(m, m, |_, _| 0.0);
        for i in 0..m {
            for j in 0..m {
                if i != j {
                    p_c2c[(i, j)] = rng.uniform(lo_c, hi_c);
                }
            }
        }
        net.c2c = C2c::Dense(p_c2c);
        net
    }

    /// Outage probability of the link from client `j` to client `i`
    /// (0 on the diagonal — no transmission to self).
    #[inline]
    pub fn p_c2c(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return 0.0;
        }
        match &self.c2c {
            C2c::Uniform(p) => *p,
            C2c::Dense(mat) => mat[(i, j)],
        }
    }

    /// True iff client-to-client probabilities are stored implicitly (one
    /// shared value) rather than as a dense M×M matrix. The large-M
    /// structured path asserts this to guarantee O(M) resident state.
    pub fn c2c_is_uniform(&self) -> bool {
        matches!(self.c2c, C2c::Uniform(_))
    }

    // -- paper networks --------------------------------------------------------

    /// Fig. 9 Networks 1–3 (Figs. 7/8). Network 1 is homogeneous and mild;
    /// Networks 2 and 3 have increasingly asymmetric client→PS statistics
    /// (the regime where plain intermittent FL converges to a biased point);
    /// client-to-client links stay good (p=0.1), the regime where CoGC's
    /// binary decoder is effective (paper §VII-A).
    pub fn paper_network(idx: usize, m: usize, seed: u64) -> Network {
        let mut rng = Rng::new(seed ^ 0x9e37_79b9);
        match idx {
            1 => Network::homogeneous(m, 0.1, 0.1),
            2 => Network::heterogeneous_uplink(m, 0.0, 0.5, 0.1, &mut rng),
            3 => Network::heterogeneous_uplink(m, 0.1, 0.9, 0.1, &mut rng),
            _ => panic!("paper networks are 1..=3, got {idx}"),
        }
    }

    /// Fig. 6 settings 1–4 (GC+ recovery statistics).
    pub fn fig6_setting(idx: usize, m: usize) -> Network {
        match idx {
            1 => Network::homogeneous(m, 0.4, 0.25),
            2 => Network::homogeneous(m, 0.4, 0.5),
            3 => Network::homogeneous(m, 0.75, 0.5),
            4 => Network::homogeneous(m, 0.75, 0.8),
            _ => panic!("fig6 settings are 1..=4, got {idx}"),
        }
    }

    /// Fig. 11/12 connectivity tiers: poor client→PS (p=0.75) throughout;
    /// client-to-client good / moderate / poor.
    pub fn conn_tier(tier: &str, m: usize) -> Network {
        let p_cc = match tier {
            "good" => 0.1,
            "moderate" => 0.5,
            "poor" => 0.8,
            _ => panic!("conn tier must be good|moderate|poor, got {tier:?}"),
        };
        Network::homogeneous(m, 0.75, p_cc)
    }

    /// Perfect connectivity (the ideal-FL baseline).
    pub fn perfect(m: usize) -> Network {
        Network::homogeneous(m, 0.0, 0.0)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.p_c2s.len() == self.m, "p_c2s length != M");
        for p in &self.p_c2s {
            anyhow::ensure!((0.0..=1.0).contains(p), "p_c2s out of range");
        }
        match &self.c2c {
            C2c::Uniform(p) => {
                anyhow::ensure!((0.0..=1.0).contains(p), "p_c2c out of range");
            }
            C2c::Dense(mat) => {
                anyhow::ensure!(mat.rows == self.m && mat.cols == self.m, "p_c2c shape != MxM");
                for i in 0..self.m {
                    anyhow::ensure!(mat[(i, i)] == 0.0, "p_c2c diagonal must be 0");
                    for j in 0..self.m {
                        anyhow::ensure!(
                            (0.0..=1.0).contains(&mat[(i, j)]),
                            "p_c2c out of range"
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_is_valid() {
        let net = Network::homogeneous(10, 0.4, 0.25);
        net.validate().unwrap();
        assert_eq!(net.p_c2s, vec![0.4; 10]);
        assert_eq!(net.p_c2c(0, 1), 0.25);
        assert_eq!(net.p_c2c(3, 3), 0.0);
        assert!(net.c2c_is_uniform());
    }

    #[test]
    fn heterogeneous_is_dense_with_zero_diagonal() {
        let mut rng = Rng::new(9);
        let net = Network::heterogeneous(6, (0.1, 0.3), (0.2, 0.6), &mut rng);
        net.validate().unwrap();
        assert!(!net.c2c_is_uniform());
        for i in 0..6 {
            assert_eq!(net.p_c2c(i, i), 0.0);
            for j in 0..6 {
                if i != j {
                    let p = net.p_c2c(i, j);
                    assert!((0.2..=0.6).contains(&p), "p_c2c({i},{j}) = {p}");
                }
            }
        }
    }

    #[test]
    fn paper_networks_reproducible() {
        let a = Network::paper_network(2, 10, 42);
        let b = Network::paper_network(2, 10, 42);
        assert_eq!(a.p_c2s, b.p_c2s);
        a.validate().unwrap();
        // heterogeneous: uplinks actually differ
        let distinct = a
            .p_c2s
            .iter()
            .map(|p| format!("{p:.12}"))
            .collect::<std::collections::BTreeSet<_>>()
            .len();
        assert!(distinct > 5);
    }

    #[test]
    fn fig6_settings_match_paper() {
        let s3 = Network::fig6_setting(3, 10);
        assert_eq!(s3.p_c2s[0], 0.75);
        assert_eq!(s3.p_c2c(0, 1), 0.5);
    }

    #[test]
    fn conn_tiers() {
        assert_eq!(Network::conn_tier("poor", 10).p_c2c(1, 0), 0.8);
        assert_eq!(Network::conn_tier("good", 10).p_c2s[0], 0.75);
    }

    #[test]
    #[should_panic]
    fn bad_tier_panics() {
        Network::conn_tier("great", 10);
    }
}
