//! Cost-efficient cyclic GC design (paper §V, eq. (21)).
//!
//! The `s+1` nonzeros per row of `B` set the per-round communication cost
//! of the gradient-sharing framework (`s·M` sharing transmissions plus up
//! to `M` uplinks). Given target reliability `P_O*` and the network
//! statistics, pick the smallest `s` whose closed-form outage probability
//! meets the target. `P_O(s)` is not monotone in `s` (the paper's
//! observation: more neighbors = more straggler margin at the PS but more
//! chances for an incomplete partial sum), so all feasible `s` are scanned.

use crate::gc::GcCode;
use crate::network::Network;
use crate::outage::exact::{expected_transmissions, overall_outage};
use crate::outage::mc::estimate_outage;
use crate::parallel::{derive_seed, MonteCarlo};
use crate::scenario::Iid;
use crate::util::rng::Rng;

/// The code evaluated at sweep point `s` (coefficients are irrelevant to
/// the outage probabilities — only the cyclic support matters — but the
/// closed-form sweep and the MC cross-check must agree on the draw).
fn design_code(m: usize, s: usize, seed: u64) -> GcCode {
    GcCode::generate(m, s, &mut Rng::new(seed ^ ((s as u64) << 32)))
}

#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub s: usize,
    pub p_o: f64,
    /// Expected transmissions per round at this `s`.
    pub tx_per_round: f64,
    /// Expected rounds between successful global updates, `1/(1−P_O)`.
    pub expected_rounds: f64,
    /// Expected transmissions per successful global update.
    pub tx_per_success: f64,
}

/// Evaluate every `s ∈ [1, M−1]` on the given network.
pub fn sweep(net: &Network, seed: u64) -> Vec<DesignPoint> {
    (1..net.m)
        .map(|s| {
            let code = design_code(net.m, s, seed);
            let p_o = overall_outage(net, &code);
            let tx = expected_transmissions(net, &code);
            let er = if p_o < 1.0 { 1.0 / (1.0 - p_o) } else { f64::INFINITY };
            DesignPoint {
                s,
                p_o,
                tx_per_round: tx,
                expected_rounds: er,
                tx_per_success: tx * er,
            }
        })
        .collect()
}

/// Monte-Carlo cross-check of the closed-form sweep, one estimate per
/// `s ∈ [1, M−1]`, run through the parallel engine. The returned vector
/// aligns with [`sweep`]'s points (same codes, same order) and is
/// bit-identical for any `threads` setting.
pub fn sweep_mc(net: &Network, seed: u64, trials: usize, threads: usize) -> Vec<f64> {
    (1..net.m)
        .map(|s| {
            let code = design_code(net.m, s, seed);
            let mc = MonteCarlo::new(derive_seed(seed, s as u64)).with_threads(threads);
            // the closed forms assume memoryless links, so the cross-check
            // is always i.i.d. — stateful channels live in `scenario`
            estimate_outage(net, &code, &Iid, trials, &mc)
        })
        .collect()
}

/// Eq. (21): the most cost-efficient `s*` meeting `P_O(s) ≤ target`.
/// Returns `None` when no `s` is feasible on this network.
pub fn cost_efficient_s(net: &Network, target_po: f64, seed: u64) -> Option<DesignPoint> {
    sweep(net, seed)
        .into_iter()
        .filter(|d| d.p_o <= target_po)
        .min_by(|a, b| a.s.cmp(&b.s))
}

/// The alternative objective: `s` minimizing expected transmissions per
/// successful update (used by the ablation bench).
pub fn min_tx_per_success(net: &Network, seed: u64) -> Option<DesignPoint> {
    sweep(net, seed)
        .into_iter()
        .filter(|d| d.tx_per_success.is_finite())
        .min_by(|a, b| a.tx_per_success.partial_cmp(&b.tx_per_success).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig10_network_selects_small_s() {
        // Fig. 10 network: p_m = p_mk = 0.1, target P_O* = 0.5.
        let net = Network::homogeneous(10, 0.1, 0.1);
        let d = cost_efficient_s(&net, 0.5, 1).expect("feasible");
        // With such good links even small s meets 0.5; s* must be well below
        // the default s = 7 the paper compares against.
        assert!(d.s < 7, "s* = {}", d.s);
        assert!(d.p_o <= 0.5);
        // and the saving vs s = 7 is large
        let pts = sweep(&net, 1);
        let at7 = pts.iter().find(|p| p.s == 7).unwrap();
        assert!(d.tx_per_round < 0.8 * at7.tx_per_round);
    }

    #[test]
    fn mc_crosscheck_tracks_closed_form() {
        let net = Network::homogeneous(8, 0.2, 0.2);
        let pts = sweep(&net, 3);
        let est = sweep_mc(&net, 3, 8_000, 0);
        assert_eq!(est.len(), pts.len());
        for (p, e) in pts.iter().zip(&est) {
            let sigma = (p.p_o * (1.0 - p.p_o) / 8_000.0).sqrt();
            assert!(
                (p.p_o - e).abs() < 5.0 * sigma + 5e-3,
                "s={}: closed {} vs mc {e}",
                p.s,
                p.p_o
            );
        }
        // thread-count invariance of the cross-check itself
        let serial = sweep_mc(&net, 3, 2_000, 1);
        let threaded = sweep_mc(&net, 3, 2_000, 4);
        assert_eq!(serial, threaded);
    }

    #[test]
    fn infeasible_target_returns_none() {
        let net = Network::homogeneous(10, 0.9, 0.9);
        assert!(cost_efficient_s(&net, 1e-6, 2).is_none());
    }

    #[test]
    fn sweep_covers_all_s() {
        let net = Network::homogeneous(8, 0.2, 0.2);
        let pts = sweep(&net, 3);
        assert_eq!(pts.len(), 7);
        assert_eq!(pts[0].s, 1);
        assert_eq!(pts.last().unwrap().s, 7);
        for p in &pts {
            assert!((0.0..=1.0).contains(&p.p_o));
            assert!(p.tx_per_round > 0.0);
        }
    }

    #[test]
    fn tx_per_success_blows_up_with_po() {
        let net = Network::homogeneous(10, 0.5, 0.5);
        let pts = sweep(&net, 4);
        // high-s points on this poor network have P_O ~ 1 and huge cost
        let worst = pts.iter().map(|p| p.tx_per_success).fold(0.0f64, f64::max);
        let best = min_tx_per_success(&net, 4).unwrap();
        assert!(worst > 5.0 * best.tx_per_success);
    }
}
