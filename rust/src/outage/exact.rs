//! Closed-form outage analysis of CoGC (paper §IV-A, eqs. (11)–(16)).
//!
//! Per round, client `m` produces a *complete* partial sum iff every
//! incoming link of its cyclic neighborhood is up — probability
//! `1 − q_m` with `q_m = 1 − ∏_{k∈K₂(m)}(1−p_mk)` — and it reaches the PS
//! iff its uplink is up (prob `1 − p_m`). Because all links are independent
//! and neighborhoods use disjoint links, the per-client delivery indicators
//! are independent Bernoullis, so the exact heterogeneous-network law of
//! the delivered count is a Poisson-binomial; we evaluate it with an O(M²)
//! convolution DP instead of the paper's exponential subset sums, and also
//! expose the paper's P₁/P₂/P₃ subcase decomposition (computed with a joint
//! DP) so the identity `P_O = P₁+P₂+P₃` is testable.

use crate::gc::GcCode;
use crate::network::Network;

/// Per-client probability that the partial sum is *incomplete*
/// (`q_m = P₁₁` of eq. (11)): at least one incoming link erased.
pub fn incomplete_probs(net: &Network, code: &GcCode) -> Vec<f64> {
    (0..net.m)
        .map(|m| {
            let all_up: f64 = code
                .incoming(m)
                .iter()
                .map(|&k| 1.0 - net.p_c2c(m, k))
                .product();
            1.0 - all_up
        })
        .collect()
}

/// Poisson-binomial PMF: `out[k] = P(exactly k successes)` for independent
/// Bernoulli successes with probabilities `ps`.
pub fn poisson_binomial_pmf(ps: &[f64]) -> Vec<f64> {
    let n = ps.len();
    let mut pmf = vec![0.0; n + 1];
    pmf[0] = 1.0;
    for (i, &p) in ps.iter().enumerate() {
        for k in (0..=i + 1).rev() {
            let stay = if k <= i { pmf[k] * (1.0 - p) } else { 0.0 };
            let step = if k > 0 { pmf[k - 1] * p } else { 0.0 };
            pmf[k] = stay + step;
        }
    }
    pmf
}

/// The overall outage probability `P_O` (eq. (16)): probability that fewer
/// than `M − s` complete partial sums are delivered to the PS.
pub fn overall_outage(net: &Network, code: &GcCode) -> f64 {
    let q = incomplete_probs(net, code);
    let deliver: Vec<f64> = (0..net.m)
        .map(|m| (1.0 - q[m]) * (1.0 - net.p_c2s[m]))
        .collect();
    let pmf = poisson_binomial_pmf(&deliver);
    let need = net.m - code.s;
    pmf[..need].iter().sum()
}

/// The paper's subcase decomposition (P₁, P₂, P₃) of `P_O`.
///
/// Joint DP over clients tracking (#incomplete partial sums, #complete
/// partial sums whose uplink failed). Each client lands in exactly one of:
/// incomplete (w.p. `q_m`), complete-but-undelivered (w.p. `(1−q_m)·p_m`),
/// or delivered (the rest).
///
/// - `P₁ = P(incomplete > s)` — outage regardless of uplinks (Subcase 1);
/// - `P₂ = P(incomplete = 0, uplink failures > s)` (Subcase 2);
/// - `P₃ = P(1 ≤ incomplete = v ≤ s, uplink failures > s − v)` (Subcase 3).
pub fn subcase_probs(net: &Network, code: &GcCode) -> (f64, f64, f64) {
    let m = net.m;
    let s = code.s;
    let q = incomplete_probs(net, code);

    // dp[v][f] = P(v incomplete, f complete-with-failed-uplink) so far
    let mut dp = vec![vec![0.0; m + 1]; m + 1];
    dp[0][0] = 1.0;
    for client in 0..m {
        let p_inc = q[client];
        let p_fail = (1.0 - q[client]) * net.p_c2s[client];
        let p_del = (1.0 - q[client]) * (1.0 - net.p_c2s[client]);
        let mut next = vec![vec![0.0; m + 1]; m + 1];
        for v in 0..=client {
            for f in 0..=(client - v) {
                let cur = dp[v][f];
                if cur == 0.0 {
                    continue;
                }
                next[v + 1][f] += cur * p_inc;
                next[v][f + 1] += cur * p_fail;
                next[v][f] += cur * p_del;
            }
        }
        dp = next;
    }

    let (mut p1, mut p2, mut p3) = (0.0, 0.0, 0.0);
    for v in 0..=m {
        for f in 0..=(m - v) {
            let pr = dp[v][f];
            if pr == 0.0 {
                continue;
            }
            if v > s {
                p1 += pr; // Subcase 1: too many incomplete, outage for sure
            } else if v == 0 && f > s {
                p2 += pr; // Subcase 2
            } else if v >= 1 && v + f > s {
                p3 += pr; // Subcase 3
            }
        }
    }
    (p1, p2, p3)
}

/// Expected transmissions in one CoGC round (paper §V-1): `s·M` in the
/// gradient-sharing phase plus one uplink transmission per *complete*
/// partial sum (only those are sent under the standard decoder).
pub fn expected_transmissions(net: &Network, code: &GcCode) -> f64 {
    let q = incomplete_probs(net, code);
    let expected_complete: f64 = q.iter().map(|qm| 1.0 - qm).sum();
    (code.s * net.m) as f64 + expected_complete
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, Prop};
    use crate::util::rng::Rng;

    fn code(m: usize, s: usize, seed: u64) -> GcCode {
        GcCode::generate(m, s, &mut Rng::new(seed))
    }

    #[test]
    fn pmf_sums_to_one_and_matches_binomial() {
        let pmf = poisson_binomial_pmf(&[0.3; 10]);
        assert_close(pmf.iter().sum::<f64>(), 1.0, 1e-12);
        // binomial check: P(X = 3) for Bin(10, 0.3)
        let want = 120.0 * 0.3f64.powi(3) * 0.7f64.powi(7);
        assert_close(pmf[3], want, 1e-12);
    }

    #[test]
    fn pmf_heterogeneous_small_case() {
        // two clients: p = [0.2, 0.5]
        let pmf = poisson_binomial_pmf(&[0.2, 0.5]);
        assert_close(pmf[0], 0.8 * 0.5, 1e-15);
        assert_close(pmf[1], 0.2 * 0.5 + 0.8 * 0.5, 1e-15);
        assert_close(pmf[2], 0.2 * 0.5, 1e-15);
    }

    #[test]
    fn subcases_sum_to_overall() {
        Prop::new(30).forall("P1+P2+P3 = PO", |rng, _| {
            let m = rng.range(4, 12);
            let s = rng.range(1, m);
            let c = GcCode::generate(m, s, rng);
            let net = crate::network::Network::heterogeneous(
                m,
                (0.0, 0.9),
                (0.0, 0.9),
                rng,
            );
            let po = overall_outage(&net, &c);
            let (p1, p2, p3) = subcase_probs(&net, &c);
            assert_close(p1 + p2 + p3, po, 1e-10);
        });
    }

    #[test]
    fn perfect_network_never_outages() {
        let net = Network::perfect(10);
        let c = code(10, 7, 1);
        assert_close(overall_outage(&net, &c), 0.0, 1e-12);
    }

    #[test]
    fn dead_network_always_outages() {
        let net = Network::homogeneous(10, 1.0, 0.0);
        let c = code(10, 7, 2);
        assert_close(overall_outage(&net, &c), 1.0, 1e-12);
    }

    #[test]
    fn remark5_case_study() {
        // p_mk = 0.4, M = 10, s = 7: P(all 10 clients have incomplete sums)
        // = (1 - 0.6^7)^10 = 0.7528 (paper Remark 5).
        let net = Network::homogeneous(10, 0.0, 0.4);
        let c = code(10, 7, 3);
        let q = incomplete_probs(&net, &c);
        let all_incomplete: f64 = q.iter().product();
        assert_close(all_incomplete, 0.7528, 2e-4);
        // and the overall outage is consequently enormous
        let net2 = Network::homogeneous(10, 0.4, 0.4);
        assert!(overall_outage(&net2, &c) > 0.95);
    }

    #[test]
    fn outage_decreases_with_better_links() {
        let c = code(10, 5, 4);
        let po_bad = overall_outage(&Network::homogeneous(10, 0.4, 0.4), &c);
        let po_mid = overall_outage(&Network::homogeneous(10, 0.2, 0.2), &c);
        let po_good = overall_outage(&Network::homogeneous(10, 0.05, 0.05), &c);
        assert!(po_bad > po_mid && po_mid > po_good);
    }

    #[test]
    fn p2_monotone_decreasing_in_s() {
        // the paper notes P2 decreases with s (more straggler margin)
        let mut prev = f64::INFINITY;
        for s in 1..10 {
            let c = code(10, s, 100 + s as u64);
            let net = Network::homogeneous(10, 0.3, 0.0); // isolate uplink effect
            let (_, p2, _) = subcase_probs(&net, &c);
            assert!(p2 <= prev + 1e-12, "P2 increased at s={s}");
            prev = p2;
        }
    }

    #[test]
    fn expected_transmissions_bounds() {
        let c = code(10, 7, 5);
        let net = Network::homogeneous(10, 0.4, 0.25);
        let tx = expected_transmissions(&net, &c);
        assert!(tx > 70.0 && tx < 80.0, "tx = {tx}"); // sM=70 plus E[complete] in [0,10]
        // perfect network: exactly (s+1) M
        let tx_perfect = expected_transmissions(&Network::perfect(10), &c);
        assert_close(tx_perfect, 80.0, 1e-12);
    }
}
