//! Monte-Carlo estimation of outage / recovery statistics: cross-checks the
//! closed forms in `outage::exact` and produces the GC⁺ recovery statistics
//! of Fig. 6 (which have no closed form — only the bound of eq. (29)).
//!
//! All trial sweeps run through the deterministic [`crate::parallel`]
//! engine: pass a [`MonteCarlo`] instead of an `Rng` and the sweep fans out
//! over the worker pool with bit-identical tallies at any thread count
//! (serial reference = the same engine at `threads = 1`; see
//! `tests/parallel_determinism.rs` for the hand-rolled cross-check).
//!
//! Erasures are drawn through a [`ChannelModel`] prototype — the engine
//! clones it **once per worker** and resets the per-trial state from the
//! channel substream, so bursty/correlated/straggler dynamics
//! ([`crate::scenario`]) slot into every estimator unchanged. Pass
//! [`Iid`](crate::scenario::Iid) for the paper's memoryless statistics.
//!
//! The trial bodies are allocation-free at steady state: each worker pools
//! one channel box, one [`Realization`], one [`gc::Attempt`], and one
//! persistent [`gc::GcPlusDecoder`] ([`MonteCarlo::run_scratch`]); the
//! until-decode loop feeds newly delivered rows into the incremental
//! decoder instead of re-running a full RREF over the growing stack every
//! block.

use crate::gc::{self, BinaryCode, FrCode, GcCode, IntRref};
use crate::network::{Network, Realization, SparseRealization};
use crate::parallel::{Accumulate, MonteCarlo};
use crate::scenario::{ChannelModel, CHANNEL_STREAM};
use crate::telemetry;
use crate::util::rng::Rng;

/// Pooled per-worker buffers of the Monte-Carlo trial bodies.
struct TrialScratch {
    ch: Box<dyn ChannelModel>,
    real: Realization,
    att: gc::Attempt,
    dec: gc::GcPlusDecoder,
    /// Pooled telemetry shard — flat integer arrays, no heap, merged into
    /// the global registry in worker-index order by the engine.
    tel: telemetry::Shard,
}

impl TrialScratch {
    fn new(proto: &dyn ChannelModel, m: usize) -> TrialScratch {
        TrialScratch {
            ch: proto.clone_box(),
            real: Realization::perfect(m),
            att: gc::Attempt::empty(),
            dec: gc::GcPlusDecoder::new(m),
            tel: telemetry::Shard::new(),
        }
    }
}

// Named shard projections (plain `fn` items for `run_scratch_tel`).
fn trial_shard(s: &mut TrialScratch) -> Option<&mut telemetry::Shard> {
    Some(&mut s.tel)
}

fn bin_trial_shard(s: &mut BinTrialScratch) -> Option<&mut telemetry::Shard> {
    Some(&mut s.tel)
}

fn fr_trial_shard(s: &mut FrTrialScratch) -> Option<&mut telemetry::Shard> {
    Some(&mut s.tel)
}

fn adv_trial_shard(s: &mut TrialScratchAdv) -> Option<&mut telemetry::Shard> {
    Some(&mut s.base.tel)
}

/// Monte-Carlo estimate of the overall outage probability `P_O` under the
/// standard GC decoder, parallelized over the engine's worker pool.
pub fn estimate_outage(
    net: &Network,
    code: &GcCode,
    ch: &dyn ChannelModel,
    trials: usize,
    mc: &MonteCarlo,
) -> f64 {
    let outages: usize = mc.run_scratch_tel(
        trials,
        || TrialScratch::new(ch, net.m),
        trial_shard,
        |t, rng, acc: &mut usize, s| {
            s.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            s.ch.sample_into(net, rng, &mut s.real);
            gc::Attempt::observe_into(code, &s.real, &mut s.att);
            if s.att.complete.len() < net.m - code.s {
                *acc += 1;
            }
        },
    );
    outages as f64 / trials as f64
}

/// GC⁺ repetition policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RecoveryMode {
    /// Exactly `t_r` attempts are stacked (the paper's analysis setting:
    /// "a fixed number of repeated communications, t_r, is assumed").
    FixedTr(usize),
    /// Algorithm 1's protocol: blocks of `t_r` attempts accumulate into
    /// `B̂(r)` until `K₄(r) ≠ ∅` (capped at `max_blocks` for safety).
    /// In this mode partial decodes are rare: with generic perturbed rows,
    /// no unit vector enters the row space until the rank reaches M, at
    /// which point *all* models decode — this is why full recovery
    /// dominates (paper Lemma 4 / Fig. 6).
    UntilDecode { tr: usize, max_blocks: usize },
}

/// Outcome statistics of GC⁺ over `trials` rounds.
///
/// Every field is an associative tally (counts, sums, histogram buckets),
/// so per-worker instances combine exactly via [`Accumulate::merge`] — the
/// property the parallel engine relies on for thread-count invariance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    pub trials: usize,
    /// Standard GC succeeded in some attempt (≥ M−s complete sums).
    pub standard: usize,
    /// Complementary decoder recovered all M local models.
    pub full: usize,
    /// Complementary decoder recovered a proper non-empty subset.
    pub partial: usize,
    /// Nothing decodable.
    pub none: usize,
    /// Histogram of |K₄| over complementary decodes (index = |K₄|).
    pub k4_hist: Vec<usize>,
    /// Total communication attempts consumed (for mean attempts/round).
    pub attempts: usize,
    /// Trials where corrupted rows reached the PS (adversarial runs only;
    /// 0 otherwise, as are the four tallies below).
    pub corrupted: usize,
    /// Trials where the decode-point audit raised an alarm.
    pub detected: usize,
    /// Trials whose decode used corrupted data — decoded-but-poisoned,
    /// the second axis of the 2×2 recovery × integrity split.
    pub poisoned: usize,
    /// Stacked rows (or FR member copies) excised by the audit.
    pub excised: usize,
    /// Honest rows among the excised (false-alarm cost).
    pub false_excised: usize,
    /// Trials rescued by the least-squares approximate aggregator after
    /// GC⁺ reported nothing decodable (approx-aware estimators only;
    /// 0 otherwise, as is the histogram below). Approx trials are *not*
    /// counted in `k4_hist` — they recover no individual model.
    pub approx: usize,
    /// Relative-residual histogram of the accepted approximate trials
    /// (bucket edges in [`gc::residual_bucket`]).
    pub residual_hist: [usize; gc::RESIDUAL_BUCKETS],
}

impl RecoveryStats {
    /// P(update uses *all* local models) = standard + complementary-full.
    pub fn p_full(&self) -> f64 {
        (self.standard + self.full) as f64 / self.trials as f64
    }

    pub fn p_partial(&self) -> f64 {
        self.partial as f64 / self.trials as f64
    }

    pub fn p_none(&self) -> f64 {
        self.none as f64 / self.trials as f64
    }

    pub fn mean_attempts(&self) -> f64 {
        self.attempts as f64 / self.trials as f64
    }

    /// Detection rate among trials where corruption reached the PS.
    pub fn p_detected(&self) -> f64 {
        self.detected as f64 / self.corrupted.max(1) as f64
    }

    /// Miss rate: corrupted trials that decoded poisoned.
    pub fn p_poisoned(&self) -> f64 {
        self.poisoned as f64 / self.trials.max(1) as f64
    }

    /// Fraction of trials rescued by the approximate aggregator.
    pub fn p_approx(&self) -> f64 {
        self.approx as f64 / self.trials.max(1) as f64
    }
}

impl Accumulate for RecoveryStats {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.standard += other.standard;
        self.full += other.full;
        self.partial += other.partial;
        self.none += other.none;
        self.attempts += other.attempts;
        self.k4_hist.merge(other.k4_hist);
        self.corrupted += other.corrupted;
        self.detected += other.detected;
        self.poisoned += other.poisoned;
        self.excised += other.excised;
        self.false_excised += other.false_excised;
        self.approx += other.approx;
        for (a, b) in self.residual_hist.iter_mut().zip(other.residual_hist) {
            *a += b;
        }
    }
}

/// Degraded-mode rescue at the would-be-outage point: least-squares over
/// everything the decoder stacked, accepted iff the relative residual
/// clears `max_rel`. Consumes no randomness, so an approx-aware trial is
/// draw-for-draw identical to the plain one.
fn try_approx(
    dec: &gc::GcPlusDecoder,
    m: usize,
    max_rel: f64,
    stats: &mut RecoveryStats,
) -> bool {
    if let Some(sol) = gc::approx_sum(dec) {
        let rel = gc::relative_residual(&sol, m);
        if rel <= max_rel {
            stats.approx += 1;
            stats.residual_hist[gc::residual_bucket(rel)] += 1;
            return true;
        }
    }
    false
}

/// One GC⁺ round: run the decoding pipeline (coefficients only, no
/// payloads), classify the outcome, and fold it into `stats`.
///
/// The until-decode loop is incremental: each attempt's delivered rows go
/// straight into the pooled [`gc::GcPlusDecoder`] and the per-block success
/// test is the allocation-free `decodable_count()` — bit-identical to
/// batch-decoding the stacked rows (see `tests/incremental_rref.rs`), but
/// `O(rank · M)` per new row instead of a full re-factor per block.
///
/// `approx_rel = Some(max_rel)` arms the degraded-mode tri-state: a trial
/// that would classify `none` first offers its stacked rows to the
/// least-squares aggregator ([`try_approx`]). `None` reproduces the plain
/// estimator bit-for-bit.
fn recovery_trial(
    net: &Network,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    approx_rel: Option<f64>,
    rng: &mut Rng,
    stats: &mut RecoveryStats,
    scratch: &mut TrialScratch,
) {
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    let need = m - s;
    let (tr, max_blocks) = match mode {
        RecoveryMode::FixedTr(tr) => (tr, 1),
        RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
    };
    stats.trials += 1;
    scratch.dec.reset(m);
    let mut outcome: Option<usize> = None; // |K4| of the decode
    'blocks: for _ in 0..max_blocks {
        for _ in 0..tr {
            let code = GcCode::generate(m, s, rng);
            scratch.ch.sample_into(net, rng, &mut scratch.real);
            gc::Attempt::observe_into(&code, &scratch.real, &mut scratch.att);
            stats.attempts += 1;
            // standard GC shortcut on any single attempt
            if scratch.att.complete.len() >= need {
                stats.standard += 1;
                stats.k4_hist[m] += 1;
                outcome = Some(usize::MAX); // marker: standard
                break 'blocks;
            }
            scratch.dec.push_attempt(&scratch.att);
        }
        let k4 = scratch.dec.decodable_count();
        if k4 > 0 {
            outcome = Some(k4);
            break 'blocks;
        }
        if matches!(mode, RecoveryMode::FixedTr(_)) {
            outcome = Some(0);
            break 'blocks;
        }
    }
    match outcome {
        Some(usize::MAX) => {} // standard, already recorded
        Some(0) | None => {
            if approx_rel.is_some_and(|max_rel| try_approx(&scratch.dec, m, max_rel, stats)) {
                scratch.tel.inc(telemetry::metric::APPROX_FALLBACKS);
            } else {
                stats.none += 1;
                stats.k4_hist[0] += 1;
            }
        }
        Some(k) if k == m => {
            stats.full += 1;
            stats.k4_hist[m] += 1;
        }
        Some(k) => {
            stats.partial += 1;
            stats.k4_hist[k] += 1;
        }
    }
}

/// Run the GC⁺ decoding pipeline over `trials` rounds through the parallel
/// engine and classify each round's outcome. The channel prototype `ch` is
/// cloned once per worker and reset per trial; its state evolves across the
/// round's repeated attempts (a burst can kill a whole block of repeats).
pub fn gcplus_recovery(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    gcplus_recovery_inner(net, ch, m, s, mode, None, trials, mc)
}

/// Approx-aware [`gcplus_recovery`]: trials that end with nothing
/// decodable run the least-squares fallback and count as `approx` when
/// their relative residual is at most `max_rel` (tri-state
/// exact / approx-with-error / outage). Pass `f64::INFINITY` to accept
/// every solvable fallback. Identical draws to the plain estimator, so
/// the exact tallies (`standard`/`full`/`partial`) match it bit-for-bit.
pub fn gcplus_recovery_approx(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    max_rel: f64,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    gcplus_recovery_inner(net, ch, m, s, mode, Some(max_rel), trials, mc)
}

#[allow(clippy::too_many_arguments)]
fn gcplus_recovery_inner(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    approx_rel: Option<f64>,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let mut stats: RecoveryStats = mc.run_scratch_tel(
        trials,
        || TrialScratch::new(ch, m),
        trial_shard,
        |t, rng, acc: &mut RecoveryStats, scratch| {
            scratch.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            recovery_trial(net, m, s, mode, approx_rel, rng, acc, scratch);
            scratch.dec.harvest(&mut scratch.tel);
        },
    );
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0); // trials == 0 edge case
    }
    stats
}

/// Pooled per-worker buffers of the binary-family trial bodies: the float
/// decoder is replaced by the exact [`IntRref`] and the deterministic code
/// is bridged to its dense form once per worker.
struct BinTrialScratch {
    ch: Box<dyn ChannelModel>,
    real: Realization,
    att: gc::Attempt,
    bridge: GcCode,
    ieng: IntRref,
    ibuf: Vec<i64>,
    /// Float shadow of the integer stack, fed only by the approx-aware
    /// estimator: the least-squares fallback needs the float engine.
    fdec: gc::GcPlusDecoder,
    tel: telemetry::Shard,
}

impl BinTrialScratch {
    fn new(proto: &dyn ChannelModel, code: BinaryCode) -> BinTrialScratch {
        BinTrialScratch {
            ch: proto.clone_box(),
            real: Realization::perfect(code.m),
            att: gc::Attempt::empty(),
            bridge: code.to_gc_code(),
            ieng: IntRref::new(code.m),
            ibuf: Vec::with_capacity(code.m),
            fdec: gc::GcPlusDecoder::new(code.m),
            tel: telemetry::Shard::new(),
        }
    }
}

/// [`recovery_trial`] for the binary {±1} family, decoded exactly.
///
/// Two deliberate departures from the cyclic trial: the code is fixed (no
/// per-attempt draw — the family is deterministic), and the standard-GC
/// shortcut *tests* the received pattern with the exact rational
/// combinator solve instead of assuming it — the binary family carries no
/// any-(M−s)-rows decodability guarantee, so `complete.len() >= M − s` is
/// necessary but not sufficient.
fn binary_recovery_trial(
    net: &Network,
    code: BinaryCode,
    mode: RecoveryMode,
    approx_rel: Option<f64>,
    rng: &mut Rng,
    stats: &mut RecoveryStats,
    scratch: &mut BinTrialScratch,
) {
    let (m, s) = (code.m, code.s);
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    let need = m - s;
    let (tr, max_blocks) = match mode {
        RecoveryMode::FixedTr(tr) => (tr, 1),
        RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
    };
    stats.trials += 1;
    scratch.ieng.reset(m);
    if approx_rel.is_some() {
        scratch.fdec.reset(m);
    }
    let mut outcome: Option<usize> = None; // |K4| of the decode
    'blocks: for _ in 0..max_blocks {
        for _ in 0..tr {
            scratch.ch.sample_into(net, rng, &mut scratch.real);
            gc::Attempt::observe_into(&scratch.bridge, &scratch.real, &mut scratch.att);
            stats.attempts += 1;
            // standard GC shortcut, solvability *tested* exactly
            if scratch.att.complete.len() >= need
                && code.combinator_weights(&scratch.att.complete).is_some()
            {
                stats.standard += 1;
                stats.k4_hist[m] += 1;
                outcome = Some(usize::MAX); // marker: standard
                break 'blocks;
            }
            for &r in &scratch.att.delivered {
                scratch.ibuf.clear();
                scratch
                    .ibuf
                    .extend(scratch.att.perturbed.row(r).iter().map(|&v| v as i64));
                scratch.ieng.push_row(&scratch.ibuf);
                if approx_rel.is_some() {
                    scratch.fdec.push_row(scratch.att.perturbed.row(r));
                }
            }
        }
        let k4 = scratch.ieng.decodable_count();
        if k4 > 0 {
            outcome = Some(k4);
            break 'blocks;
        }
        if matches!(mode, RecoveryMode::FixedTr(_)) {
            outcome = Some(0);
            break 'blocks;
        }
    }
    match outcome {
        Some(usize::MAX) => {} // standard, already recorded
        Some(0) | None => {
            if approx_rel.is_some_and(|max_rel| try_approx(&scratch.fdec, m, max_rel, stats)) {
                scratch.tel.inc(telemetry::metric::APPROX_FALLBACKS);
            } else {
                stats.none += 1;
                stats.k4_hist[0] += 1;
            }
        }
        Some(k) if k == m => {
            stats.full += 1;
            stats.k4_hist[m] += 1;
        }
        Some(k) => {
            stats.partial += 1;
            stats.k4_hist[k] += 1;
        }
    }
}

/// Binary-family analogue of [`gcplus_recovery`]: classify GC⁺ outcomes
/// over the deterministic ±1 code with the exact integer decoder.
pub fn binary_recovery(
    net: &Network,
    ch: &dyn ChannelModel,
    code: BinaryCode,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    binary_recovery_inner(net, ch, code, mode, None, trials, mc)
}

/// Approx-aware [`binary_recovery`] (see [`gcplus_recovery_approx`]): the
/// integer engine still rules on exact decodability; only a would-be
/// outage consults the float least-squares fallback.
pub fn binary_recovery_approx(
    net: &Network,
    ch: &dyn ChannelModel,
    code: BinaryCode,
    mode: RecoveryMode,
    max_rel: f64,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    binary_recovery_inner(net, ch, code, mode, Some(max_rel), trials, mc)
}

fn binary_recovery_inner(
    net: &Network,
    ch: &dyn ChannelModel,
    code: BinaryCode,
    mode: RecoveryMode,
    approx_rel: Option<f64>,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let m = code.m;
    let mut stats: RecoveryStats = mc.run_scratch_tel(
        trials,
        || BinTrialScratch::new(ch, code),
        bin_trial_shard,
        |t, rng, acc: &mut RecoveryStats, scratch| {
            scratch.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            binary_recovery_trial(net, code, mode, approx_rel, rng, acc, scratch);
            scratch.tel.absorb_int_engine(scratch.ieng.rows() as u64, scratch.ieng.rank() as u64);
        },
    );
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0); // trials == 0 edge case
    }
    stats
}

/// Pooled per-worker buffers of the fractional-repetition trial bodies:
/// everything is O(M·(s+1)) — no dense matrix, no RREF decoder.
struct FrTrialScratch {
    ch: Box<dyn ChannelModel>,
    real: SparseRealization,
    covered: Vec<bool>,
    acc: Vec<bool>,
    /// The FR scan has no row engine; its shard carries only the engine's
    /// trial/chunk throughput counters.
    tel: telemetry::Shard,
}

impl FrTrialScratch {
    fn new(proto: &dyn ChannelModel, code: &FrCode) -> FrTrialScratch {
        FrTrialScratch {
            ch: proto.clone_box(),
            real: SparseRealization::perfect(&code.sparse_support()),
            covered: Vec::with_capacity(code.groups()),
            acc: vec![false; code.groups()],
            tel: telemetry::Shard::new(),
        }
    }
}

/// Monte-Carlo outage estimate for the fractional-repetition family:
/// outage iff some group has no member delivering a complete sum. The
/// trial body is the O(M) group scan over a sparse realization — the
/// structured-path replacement for [`estimate_outage`]'s rank test.
pub fn estimate_outage_fr(
    net: &Network,
    code: &FrCode,
    ch: &dyn ChannelModel,
    trials: usize,
    mc: &MonteCarlo,
) -> f64 {
    let sup = code.sparse_support();
    let outages: usize = mc.run_scratch_tel(
        trials,
        || FrTrialScratch::new(ch, code),
        fr_trial_shard,
        |t, rng, acc: &mut usize, s| {
            s.ch.reset_sparse(&sup, net, mc.substream_seed(CHANNEL_STREAM, t));
            s.ch.sample_sparse_into(&sup, net, rng, &mut s.real);
            code.covered_into(&s.real, &mut s.covered);
            if !FrCode::all_covered(&s.covered) {
                *acc += 1;
            }
        },
    );
    outages as f64 / trials as f64
}

/// One FR GC⁺ round: accumulate covered groups across repeated attempts
/// and classify like [`recovery_trial`], except "decodable" is the group
/// coverage scan (each covered group contributes its s+1 models to K₄)
/// instead of the incremental RREF.
fn fr_recovery_trial(
    net: &Network,
    code: &FrCode,
    mode: RecoveryMode,
    rng: &mut Rng,
    stats: &mut RecoveryStats,
    scratch: &mut FrTrialScratch,
) {
    let m = code.m;
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    let (tr, max_blocks) = match mode {
        RecoveryMode::FixedTr(tr) => (tr, 1),
        RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
    };
    stats.trials += 1;
    let sup = code.sparse_support();
    scratch.acc.clear();
    scratch.acc.resize(code.groups(), false);
    let mut standard = false;
    'blocks: for _ in 0..max_blocks {
        for _ in 0..tr {
            scratch.ch.sample_sparse_into(&sup, net, rng, &mut scratch.real);
            code.covered_into(&scratch.real, &mut scratch.covered);
            stats.attempts += 1;
            // standard FR decode on any single attempt: every group covered
            if FrCode::all_covered(&scratch.covered) {
                standard = true;
                break 'blocks;
            }
            FrCode::union_covered(&mut scratch.acc, &scratch.covered);
        }
        // any covered group decodes immediately (K₄ ≠ ∅), mirroring the
        // dense engine's per-block decodable_count() > 0 test
        if FrCode::covered_groups(&scratch.acc) > 0 {
            break 'blocks;
        }
        if matches!(mode, RecoveryMode::FixedTr(_)) {
            break 'blocks;
        }
    }
    if standard {
        stats.standard += 1;
        stats.k4_hist[m] += 1;
        return;
    }
    let k4 = code.k4_count(&scratch.acc);
    if k4 == m {
        stats.full += 1;
    } else if k4 > 0 {
        stats.partial += 1;
    } else {
        stats.none += 1;
    }
    stats.k4_hist[k4] += 1;
}

/// FR-family analogue of [`gcplus_recovery`]: classify GC⁺ outcomes over
/// `trials` rounds through the parallel engine using the O(M) group scan.
pub fn fr_recovery(
    net: &Network,
    ch: &dyn ChannelModel,
    code: &FrCode,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let sup = code.sparse_support();
    let mut stats: RecoveryStats = mc.run_scratch_tel(
        trials,
        || FrTrialScratch::new(ch, code),
        fr_trial_shard,
        |t, rng, acc: &mut RecoveryStats, scratch| {
            scratch.ch.reset_sparse(&sup, net, mc.substream_seed(CHANNEL_STREAM, t));
            fr_recovery_trial(net, code, mode, rng, acc, scratch);
        },
    );
    if stats.k4_hist.len() < code.m + 1 {
        stats.k4_hist.resize(code.m + 1, 0); // trials == 0 edge case
    }
    stats
}

// ── Degraded-mode (tri-state) estimators ────────────────────────────────

/// Tri-state refinement of the binary outage verdict: a trial is `exact`
/// (standard GC decodes), `approx` (the least-squares fallback clears the
/// residual threshold), or a true `outage`. The classic outage probability
/// is `(approx + outage) / trials`; the degraded-mode miss rate is
/// `outage / trials`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TriSplit {
    pub trials: usize,
    /// Standard GC decoded the exact gradient sum.
    pub exact: usize,
    /// Rescued by the least-squares aggregator within the residual budget.
    pub approx: usize,
    /// Nothing acceptable — a degraded-mode outage.
    pub outage: usize,
    /// Relative-residual histogram of the accepted approximate trials.
    pub residual_hist: [usize; gc::RESIDUAL_BUCKETS],
}

impl TriSplit {
    pub fn p_exact(&self) -> f64 {
        self.exact as f64 / self.trials.max(1) as f64
    }

    pub fn p_approx(&self) -> f64 {
        self.approx as f64 / self.trials.max(1) as f64
    }

    pub fn p_outage(&self) -> f64 {
        self.outage as f64 / self.trials.max(1) as f64
    }
}

impl Accumulate for TriSplit {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.exact += other.exact;
        self.approx += other.approx;
        self.outage += other.outage;
        for (a, b) in self.residual_hist.iter_mut().zip(other.residual_hist) {
            *a += b;
        }
    }
}

/// Tri-state [`estimate_outage`]: the same single-attempt draws, but a
/// trial that misses the standard `M − s` complete-sums bar offers its
/// delivered rows to the least-squares aggregator before being declared
/// an outage. `max_rel < 0` disables the rescue, reproducing the plain
/// outage count exactly (asserted in the tests below).
pub fn estimate_outage_tri(
    net: &Network,
    code: &GcCode,
    ch: &dyn ChannelModel,
    max_rel: f64,
    trials: usize,
    mc: &MonteCarlo,
) -> TriSplit {
    mc.run_scratch_tel(
        trials,
        || TrialScratch::new(ch, net.m),
        trial_shard,
        |t, rng, acc: &mut TriSplit, s| {
            s.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            s.ch.sample_into(net, rng, &mut s.real);
            gc::Attempt::observe_into(code, &s.real, &mut s.att);
            acc.trials += 1;
            if s.att.complete.len() >= net.m - code.s {
                acc.exact += 1;
                return;
            }
            s.dec.reset(net.m);
            s.dec.push_attempt(&s.att);
            if let Some(sol) = gc::approx_sum(&s.dec) {
                let rel = gc::relative_residual(&sol, net.m);
                if rel <= max_rel {
                    acc.approx += 1;
                    acc.residual_hist[gc::residual_bucket(rel)] += 1;
                    s.tel.inc(telemetry::metric::APPROX_FALLBACKS);
                    return;
                }
            }
            acc.outage += 1;
        },
    )
}

// ── Byzantine-adversarial estimators (symbolic / payload-free) ──────────
//
// These mirror the plain estimators but track which stacked rows carry
// corrupted data, run the redundancy audit at the decode point with the
// *symbolic* check evaluator (a parity check fails iff its support touches
// a corrupted row — the generic-position behavior of the payload
// evaluator, pinned against the dense payload oracle in
// `tests/adversary.rs`), and classify each trial on the 2×2 of
// recovery × integrity. Trials whose sampled malicious set is empty run
// the plain trial body verbatim, so a fraction-0 spec is byte-identical.

use crate::scenario::{AdversaryModel, AdversarySpec, GroupVerdict, Surface, ADVERSARY_STREAM};

/// 2×2 recovery × integrity split of a single-attempt outage estimate.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OutageSplit {
    pub trials: usize,
    /// Decoded and the accepted value is the honest sum.
    pub decoded_clean: usize,
    /// Decoded, but the accepted value embeds corrupted data — the state
    /// classic outage analysis cannot see.
    pub decoded_poisoned: usize,
    /// Standard outage (nothing decodable).
    pub outage: usize,
}

impl OutageSplit {
    pub fn p_outage(&self) -> f64 {
        self.outage as f64 / self.trials.max(1) as f64
    }

    pub fn p_poisoned(&self) -> f64 {
        self.decoded_poisoned as f64 / self.trials.max(1) as f64
    }
}

impl Accumulate for OutageSplit {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.decoded_clean += other.decoded_clean;
        self.decoded_poisoned += other.decoded_poisoned;
        self.outage += other.outage;
    }
}

/// Whether a coded row's sum embeds a malicious contribution. On the
/// uplink surface the row owner tampers with what it uplinks; on the c2c
/// surface any malicious client inside the row's support poisons it.
fn row_corrupted(adv: &AdversaryModel, coeffs: &[f64], owner: usize) -> bool {
    match adv.spec.surface {
        Surface::Uplink => adv.is_malicious(owner),
        Surface::C2c => coeffs
            .iter()
            .enumerate()
            .any(|(k, &c)| c != 0.0 && adv.is_malicious(k)),
    }
}

/// Adversarial [`estimate_outage`]: the single-attempt standard decode
/// becomes the 2×2 split. A lone attempt of the full-rank cyclic code
/// carries **zero** parity redundancy, so there is nothing to audit here —
/// this estimator quantifies what silent poisoning costs when no repeats
/// are available (detection needs the stacked redundancy of
/// [`gcplus_recovery_adv`]).
pub fn estimate_outage_adv(
    net: &Network,
    code: &GcCode,
    ch: &dyn ChannelModel,
    spec: &AdversarySpec,
    trials: usize,
    mc: &MonteCarlo,
) -> OutageSplit {
    mc.run_scratch(
        trials,
        || (TrialScratch::new(ch, net.m), AdversaryModel::new(spec.clone())),
        |t, rng, acc: &mut OutageSplit, (s, adv)| {
            s.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(net.m, mc.substream_seed(ADVERSARY_STREAM, t));
            s.ch.sample_into(net, rng, &mut s.real);
            gc::Attempt::observe_into(code, &s.real, &mut s.att);
            acc.trials += 1;
            if s.att.complete.len() < net.m - code.s {
                acc.outage += 1;
            } else if s
                .att
                .complete
                .iter()
                .any(|&r| adv.any() && row_corrupted(adv, s.att.perturbed.row(r), r))
            {
                acc.decoded_poisoned += 1;
            } else {
                acc.decoded_clean += 1;
            }
        },
    )
}

/// Adversarial single-attempt split for the binary {±1} family: the
/// standard decode is *tested* with the exact rational combinator solve
/// (the family carries no any-(M−s)-rows guarantee), and a decode is
/// poisoned iff some complete row with **nonzero** combinator weight
/// embeds corrupted data — the exact-arithmetic analogue of
/// [`estimate_outage_adv`]'s generic-position rule, sharpened: a
/// corrupted row the combinator provably ignores cannot poison the sum.
pub fn estimate_outage_binary_adv(
    net: &Network,
    code: BinaryCode,
    ch: &dyn ChannelModel,
    spec: &AdversarySpec,
    trials: usize,
    mc: &MonteCarlo,
) -> OutageSplit {
    mc.run_scratch(
        trials,
        || (BinTrialScratch::new(ch, code), AdversaryModel::new(spec.clone())),
        |t, rng, acc: &mut OutageSplit, (s, adv)| {
            s.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(code.m, mc.substream_seed(ADVERSARY_STREAM, t));
            s.ch.sample_into(net, rng, &mut s.real);
            gc::Attempt::observe_into(&s.bridge, &s.real, &mut s.att);
            acc.trials += 1;
            let weights = if s.att.complete.len() >= code.m - code.s {
                code.combinator_weights(&s.att.complete)
            } else {
                None
            };
            match weights {
                None => acc.outage += 1,
                Some(w) => {
                    let poisoned = adv.any()
                        && s.att.complete.iter().zip(&w).any(|(&r, &wr)| {
                            wr != 0.0 && row_corrupted(adv, s.att.perturbed.row(r), r)
                        });
                    if poisoned {
                        acc.decoded_poisoned += 1;
                    } else {
                        acc.decoded_clean += 1;
                    }
                }
            }
        },
    )
}

/// Pooled buffers of [`gcplus_recovery_adv`]: the plain scratch plus the
/// raw coefficient stack and per-row corruption flags the audit consumes.
struct TrialScratchAdv {
    base: TrialScratch,
    adv: AdversaryModel,
    coeffs: crate::linalg::Matrix,
    corrupted: Vec<bool>,
}

/// One adversarial GC⁺ round. Identical attempt/draw structure to
/// [`recovery_trial`]; additionally stacks every uplinked coefficient row
/// with its corruption flag and, at the first decode event (standard
/// shortcut or `decodable_count() > 0`), runs the symbolic audit, excises
/// suspects, and classifies the post-excision outcome. Conservative by
/// design: if excision empties the decodable set the trial is classified
/// `none` (the loop is not resumed) — detection trades a little recovery
/// for integrity.
fn recovery_trial_adv(
    net: &Network,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    detect: bool,
    rng: &mut Rng,
    stats: &mut RecoveryStats,
    sc: &mut TrialScratchAdv,
) {
    if !sc.adv.any() {
        recovery_trial(net, m, s, mode, None, rng, stats, &mut sc.base);
        return;
    }
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    let need = m - s;
    let (tr, max_blocks) = match mode {
        RecoveryMode::FixedTr(tr) => (tr, 1),
        RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
    };
    stats.trials += 1;
    sc.base.dec.reset(m);
    if sc.coeffs.cols != m {
        sc.coeffs = crate::linalg::Matrix::zeros(0, m);
    } else {
        sc.coeffs.clear_rows();
    }
    sc.corrupted.clear();

    // run the attempt loop; `event` records how the trial ended
    enum DecodeEvent {
        /// Some attempt had ≥ M−s complete rows; payload = their stack indices.
        StandardShortcut(Vec<usize>),
        Decodable,
        Nothing,
    }
    let mut event = DecodeEvent::Nothing;
    'blocks: for _ in 0..max_blocks {
        for _ in 0..tr {
            let code = GcCode::generate(m, s, rng);
            sc.base.ch.sample_into(net, rng, &mut sc.base.real);
            gc::Attempt::observe_into(&code, &sc.base.real, &mut sc.base.att);
            stats.attempts += 1;
            let att = &sc.base.att;
            let base_row = sc.coeffs.rows;
            for &r in &att.delivered {
                sc.coeffs.push_row(att.perturbed.row(r));
                sc.corrupted.push(row_corrupted(&sc.adv, att.perturbed.row(r), r));
            }
            if att.complete.len() >= need {
                // stack indices of this attempt's complete rows
                let mut complete_stack = Vec::with_capacity(att.complete.len());
                let mut ci = 0usize;
                for (off, &r) in att.delivered.iter().enumerate() {
                    if ci < att.complete.len() && att.complete[ci] == r {
                        complete_stack.push(base_row + off);
                        ci += 1;
                    }
                }
                event = DecodeEvent::StandardShortcut(complete_stack);
                break 'blocks;
            }
            sc.base.dec.push_attempt(att);
        }
        if sc.base.dec.decodable_count() > 0 {
            event = DecodeEvent::Decodable;
            break 'blocks;
        }
        if matches!(mode, RecoveryMode::FixedTr(_)) {
            break 'blocks;
        }
    }
    stats.corrupted += sc.corrupted.iter().any(|&c| c) as usize;

    // audit everything the PS received (GC⁺ uplinks every delivered row)
    let mut kept_mask = vec![true; sc.coeffs.rows];
    if detect && !matches!(event, DecodeEvent::Nothing) {
        let audit = gc::audit_rows(&sc.coeffs, |combo, kept| {
            gc::symbolic_check_fails(combo, kept, &sc.corrupted)
        });
        sc.base.tel.inc(telemetry::metric::AUDIT_CHECKS);
        sc.base.tel.add(telemetry::metric::AUDIT_EXCISIONS, audit.excised.len() as u64);
        stats.detected += audit.alarm as usize;
        stats.excised += audit.excised.len();
        for &r in &audit.excised {
            kept_mask[r] = false;
            if !sc.corrupted[r] {
                stats.false_excised += 1;
            }
        }
    }

    match event {
        DecodeEvent::StandardShortcut(complete_stack) => {
            let kept_complete = complete_stack.iter().filter(|&&st| kept_mask[st]).count();
            if kept_complete >= need {
                stats.standard += 1;
                stats.k4_hist[m] += 1;
                // conservative: the combinator may select any surviving
                // complete row, so a corrupted survivor poisons the decode
                let poisoned =
                    complete_stack.iter().any(|&st| kept_mask[st] && sc.corrupted[st]);
                stats.poisoned += poisoned as usize;
                return;
            }
            // excision broke the shortcut: fall back to GC⁺ over the
            // surviving stack
            rebuild_and_classify(&kept_mask, stats, sc, m);
        }
        DecodeEvent::Decodable => {
            if detect && kept_mask.iter().any(|&k| !k) {
                rebuild_and_classify(&kept_mask, stats, sc, m);
            } else {
                classify_decoder(&sc.base.dec, &sc.corrupted, None, stats, m);
            }
        }
        DecodeEvent::Nothing => {
            stats.none += 1;
            stats.k4_hist[0] += 1;
        }
    }
}

/// Rebuild the incremental engine on the kept rows and classify.
fn rebuild_and_classify(
    kept_mask: &[bool],
    stats: &mut RecoveryStats,
    sc: &mut TrialScratchAdv,
    m: usize,
) {
    let kept: Vec<usize> = (0..sc.coeffs.rows).filter(|&r| kept_mask[r]).collect();
    sc.base.dec.reset(m);
    for &r in &kept {
        sc.base.dec.push_row(sc.coeffs.row(r));
    }
    classify_decoder(&sc.base.dec, &sc.corrupted, Some(&kept), stats, m);
}

/// Classify a decoder state on the recovery × integrity grid: the decode
/// is poisoned iff some decodable client's weight vector places structural
/// weight on a corrupted stacked row. `kept` maps the decoder's pushed-row
/// order back to stack indices (`None` = identity).
fn classify_decoder(
    dec: &gc::GcPlusDecoder,
    corrupted: &[bool],
    kept: Option<&[usize]>,
    stats: &mut RecoveryStats,
    m: usize,
) {
    let eng = dec.engine();
    let k4 = dec.decodable_count();
    if k4 == 0 {
        stats.none += 1;
        stats.k4_hist[0] += 1;
        return;
    }
    let identity: Vec<usize>;
    let kept = match kept {
        Some(k) => k,
        None => {
            identity = (0..eng.rows()).collect();
            &identity
        }
    };
    let mut poisoned = false;
    for (_, row_i) in eng.decodable() {
        if crate::gc::byzantine::weights_touch_corrupted(eng.t_row(row_i), kept, corrupted) {
            poisoned = true;
            break;
        }
    }
    stats.poisoned += poisoned as usize;
    if k4 == m {
        stats.full += 1;
    } else {
        stats.partial += 1;
    }
    stats.k4_hist[k4] += 1;
}

/// Adversarial [`gcplus_recovery`]: symbolic corruption tracking, audit at
/// the decode point, and the extended [`RecoveryStats`] integrity tallies.
/// The malicious set is sampled per trial from the [`ADVERSARY_STREAM`]
/// substream; trials with no malicious client run the plain trial body, so
/// a fraction-0 spec produces byte-identical recovery tallies.
#[allow(clippy::too_many_arguments)]
pub fn gcplus_recovery_adv(
    net: &Network,
    ch: &dyn ChannelModel,
    spec: &AdversarySpec,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let mut stats: RecoveryStats = mc.run_scratch_tel(
        trials,
        || TrialScratchAdv {
            base: TrialScratch::new(ch, m),
            adv: AdversaryModel::new(spec.clone()),
            coeffs: crate::linalg::Matrix::zeros(0, m),
            corrupted: Vec::new(),
        },
        adv_trial_shard,
        |t, rng, acc: &mut RecoveryStats, scratch| {
            scratch.base.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            scratch.adv.reset(m, mc.substream_seed(ADVERSARY_STREAM, t));
            recovery_trial_adv(net, m, s, mode, spec.detect, rng, acc, scratch);
            scratch.base.dec.harvest(&mut scratch.base.tel);
        },
    );
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    stats
}

/// Adversarial [`fr_recovery`]: the audit is the per-group plurality vote
/// ([`AdversaryModel::fr_attempt_verdicts`]), and the union across repeats
/// keeps the best verdict per group under detection (first covered copy
/// without). Still O(M·(s+1)) per attempt.
pub fn fr_recovery_adv(
    net: &Network,
    ch: &dyn ChannelModel,
    code: &FrCode,
    spec: &AdversarySpec,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let sup = code.sparse_support();
    let m = code.m;
    let detect = spec.detect;
    let mut stats: RecoveryStats = mc.run_scratch(
        trials,
        || {
            (
                FrTrialScratch::new(ch, code),
                AdversaryModel::new(spec.clone()),
                Vec::<GroupVerdict>::new(),
                Vec::<GroupVerdict>::new(),
            )
        },
        |t, rng, acc: &mut RecoveryStats, (scratch, adv, verdicts, accv)| {
            scratch.ch.reset_sparse(&sup, net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(m, mc.substream_seed(ADVERSARY_STREAM, t));
            if !adv.any() {
                fr_recovery_trial(net, code, mode, rng, acc, scratch);
                return;
            }
            if acc.k4_hist.len() < m + 1 {
                acc.k4_hist.resize(m + 1, 0);
            }
            let (tr, max_blocks) = match mode {
                RecoveryMode::FixedTr(tr) => (tr, 1),
                RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
            };
            acc.trials += 1;
            accv.clear();
            accv.resize(code.groups(), GroupVerdict::Uncovered);
            let mut active = false;
            let mut alarmed = false;
            let mut standard = false;
            'blocks: for _ in 0..max_blocks {
                for _ in 0..tr {
                    scratch.ch.sample_sparse_into(&sup, net, rng, &mut scratch.real);
                    acc.attempts += 1;
                    let audit = adv.fr_attempt_verdicts(code, &scratch.real, verdicts);
                    active |= audit.active;
                    alarmed |= audit.alarms > 0;
                    acc.excised += audit.excised;
                    acc.false_excised += audit.false_excised;
                    if verdicts.iter().all(|v| v.covered()) {
                        standard = true;
                        accv.copy_from_slice(verdicts);
                        break 'blocks;
                    }
                    for (a, &v) in accv.iter_mut().zip(verdicts.iter()) {
                        if detect {
                            *a = (*a).max(v);
                        } else if !a.covered() && v != GroupVerdict::Uncovered {
                            *a = v;
                        }
                    }
                }
                if accv.iter().any(|v| v.covered()) {
                    break 'blocks;
                }
                if matches!(mode, RecoveryMode::FixedTr(_)) {
                    break 'blocks;
                }
            }
            acc.corrupted += active as usize;
            acc.detected += alarmed as usize;
            acc.poisoned += accv.iter().any(|&v| v == GroupVerdict::Poisoned) as usize;
            if standard {
                acc.standard += 1;
                acc.k4_hist[m] += 1;
                return;
            }
            let k4 = accv.iter().filter(|v| v.covered()).count() * (code.s + 1);
            if k4 == m {
                acc.full += 1;
            } else if k4 > 0 {
                acc.partial += 1;
            } else {
                acc.none += 1;
            }
            acc.k4_hist[k4] += 1;
        },
    );
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    stats
}

/// Adversarial [`estimate_outage_fr`]: single-attempt FR decode classified
/// on the 2×2 split by the per-group plurality vote. Outage iff some group
/// ends uncovered (including groups the vote excised entirely); poisoned
/// iff any accepted group value embeds corrupted data.
pub fn estimate_outage_fr_adv(
    net: &Network,
    code: &FrCode,
    ch: &dyn ChannelModel,
    spec: &AdversarySpec,
    trials: usize,
    mc: &MonteCarlo,
) -> OutageSplit {
    let sup = code.sparse_support();
    let m = code.m;
    mc.run_scratch(
        trials,
        || {
            (
                FrTrialScratch::new(ch, code),
                AdversaryModel::new(spec.clone()),
                Vec::<GroupVerdict>::new(),
            )
        },
        |t, rng, acc: &mut OutageSplit, (s, adv, verdicts)| {
            s.ch.reset_sparse(&sup, net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(m, mc.substream_seed(ADVERSARY_STREAM, t));
            s.ch.sample_sparse_into(&sup, net, rng, &mut s.real);
            acc.trials += 1;
            if !adv.any() {
                code.covered_into(&s.real, &mut s.covered);
                if FrCode::all_covered(&s.covered) {
                    acc.decoded_clean += 1;
                } else {
                    acc.outage += 1;
                }
                return;
            }
            adv.fr_attempt_verdicts(code, &s.real, verdicts);
            if verdicts.iter().any(|v| !v.covered()) {
                acc.outage += 1;
            } else if verdicts.iter().any(|&v| v == GroupVerdict::Poisoned) {
                acc.decoded_poisoned += 1;
            } else {
                acc.decoded_clean += 1;
            }
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outage::exact::overall_outage;
    use crate::parallel::trial_rng;
    use crate::scenario::Iid;
    use crate::testing::Prop;

    /// Allocating reference trial — the hand-rolled serial baseline the
    /// pooled engine path is asserted against.
    fn outage_trial(
        net: &Network,
        code: &GcCode,
        ch: &mut dyn ChannelModel,
        rng: &mut Rng,
    ) -> bool {
        let real = ch.sample(net, rng);
        let att = gc::Attempt::observe(code, &real);
        att.complete.len() < net.m - code.s
    }

    #[test]
    fn mc_matches_closed_form() {
        Prop::new(8).forall("mc vs exact", |rng, _| {
            let m = rng.range(5, 11);
            let s = rng.range(1, m);
            let code = GcCode::generate(m, s, rng);
            let net = Network::homogeneous(m, rng.uniform(0.05, 0.7), rng.uniform(0.05, 0.7));
            let exact = overall_outage(&net, &code);
            let trials = 20_000;
            let mc = MonteCarlo::new(rng.next_u64());
            let est = estimate_outage(&net, &code, &Iid, trials, &mc);
            // 4-sigma binomial tolerance
            let sigma = (exact * (1.0 - exact) / trials as f64).sqrt();
            assert!(
                (est - exact).abs() < 4.0 * sigma + 5e-3,
                "exact {exact} vs mc {est} (m={m}, s={s})"
            );
        });
    }

    #[test]
    fn parallel_equals_serial_reference() {
        let net = Network::fig6_setting(2, 10);
        let code = GcCode::generate(10, 7, &mut Rng::new(3));
        let trials = 4_000;
        let seed = 0xFEED;
        // hand-rolled reference with the engine's per-trial seeding scheme
        let mut outages = 0usize;
        for t in 0..trials {
            let mut rng = trial_rng(seed, t as u64);
            if outage_trial(&net, &code, &mut Iid, &mut rng) {
                outages += 1;
            }
        }
        let want = outages as f64 / trials as f64;
        for threads in [1usize, 2, 8] {
            let mc = MonteCarlo::new(seed).with_threads(threads);
            let got = estimate_outage(&net, &code, &Iid, trials, &mc);
            assert_eq!(got.to_bits(), want.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn recovery_stats_partition() {
        let net = Network::fig6_setting(2, 10);
        for (i, mode) in [
            RecoveryMode::FixedTr(2),
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 },
        ]
        .into_iter()
        .enumerate()
        {
            let mc = MonteCarlo::new(42 + i as u64);
            let st = gcplus_recovery(&net, &Iid, 10, 7, mode, 300, &mc);
            assert_eq!(st.trials, 300);
            assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
            assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
            let total = st.p_full() + st.p_partial() + st.p_none();
            assert!((total - 1.0).abs() < 1e-12);
            assert!(st.mean_attempts() >= 1.0);
        }
    }

    #[test]
    fn binary_recovery_stats_partition_and_thread_invariance() {
        let net = Network::fig6_setting(2, 10);
        let code = BinaryCode::new(10, 4).unwrap();
        for (i, mode) in [
            RecoveryMode::FixedTr(2),
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 },
        ]
        .into_iter()
        .enumerate()
        {
            let mc = MonteCarlo::new(42 + i as u64);
            let st = binary_recovery(&net, &Iid, code, mode, 300, &mc);
            assert_eq!(st.trials, 300);
            assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
            assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
            let total = st.p_full() + st.p_partial() + st.p_none();
            assert!((total - 1.0).abs() < 1e-12);
            assert!(st.mean_attempts() >= 1.0);
        }
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 };
        let want = binary_recovery(&net, &Iid, code, mode, 300, &MonteCarlo::new(9));
        for threads in [2usize, 8] {
            let mc = MonteCarlo::new(9).with_threads(threads);
            let got = binary_recovery(&net, &Iid, code, mode, 300, &mc);
            assert_eq!(got.trials, want.trials, "threads={threads}");
            assert_eq!(got.standard, want.standard, "threads={threads}");
            assert_eq!(got.full, want.full, "threads={threads}");
            assert_eq!(got.partial, want.partial, "threads={threads}");
            assert_eq!(got.none, want.none, "threads={threads}");
            assert_eq!(got.k4_hist, want.k4_hist, "threads={threads}");
        }
    }

    #[test]
    fn paper_claim_full_recovery_dominates() {
        // Lemma 4 / Fig. 6: under Algorithm 1's repeat-until-decode protocol
        // (blocks of t_r = 2), full recovery dominates in every paper
        // setting — generically no unit vector enters the row space before
        // the rank saturates at M, so the first decodable event is usually
        // "everything decodes".
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 };
        for setting in 1..=3 {
            let net = Network::fig6_setting(setting, 10);
            let mc = MonteCarlo::new(7 + setting as u64);
            let st = gcplus_recovery(&net, &Iid, 10, 7, mode, 300, &mc);
            assert!(
                st.p_full() > st.p_partial() && st.p_full() > st.p_none(),
                "setting {setting}: full {:.3} partial {:.3} none {:.3}",
                st.p_full(),
                st.p_partial(),
                st.p_none()
            );
        }
        // Setting 4 (p_mk = 0.8) is the extreme-erasure regime: ~0.8^7 = 21%
        // of delivered rows are already unit vectors, so a *partial* decode
        // almost always fires before the stack reaches full rank. GC+ still
        // always recovers something (the paper's operational claim).
        let net = Network::fig6_setting(4, 10);
        let st = gcplus_recovery(&net, &Iid, 10, 7, mode, 300, &MonteCarlo::new(11));
        assert!(st.p_none() < 0.05, "setting 4 none = {:.3}", st.p_none());
        assert!(st.p_full() + st.p_partial() > 0.95);
    }

    #[test]
    fn fixed_tr_with_poor_uplinks_rarely_full() {
        // Sanity check of the analysis mode: with p_m = 0.75 and t_r = 2 the
        // PS sees ~5 of 20 rows, so full recovery needs a >= M-row delivery
        // burst (P ~ 1.4%); its rate must be small. This is exactly why
        // Algorithm 1 loops until decode.
        let net = Network::fig6_setting(3, 10);
        let st =
            gcplus_recovery(&net, &Iid, 10, 7, RecoveryMode::FixedTr(2), 800, &MonteCarlo::new(11));
        assert!(st.p_full() < 0.1, "p_full = {}", st.p_full());
    }

    #[test]
    fn gcplus_beats_standard_gc_under_poor_c2c() {
        // the headline GC+ claim: when client-to-client links are poor,
        // standard GC almost never updates but GC+ (Algorithm 1) always
        // decodes within a bounded number of blocks.
        let net = Network::conn_tier("poor", 10);
        let mut rng = Rng::new(3);
        let code = GcCode::generate(10, 7, &mut rng);
        let po = overall_outage(&net, &code);
        assert!(po > 0.99, "standard GC should be nearly dead, P_O = {po}");
        let st = gcplus_recovery(
            &net,
            &Iid,
            10,
            7,
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 },
            200,
            &MonteCarlo::new(3),
        );
        assert!(
            st.p_none() < 0.05,
            "GC+ should decode something, failed {:.3}",
            st.p_none()
        );
        // and the fixed-t_r mode still decodes a nontrivial fraction
        let st2 =
            gcplus_recovery(&net, &Iid, 10, 7, RecoveryMode::FixedTr(2), 400, &MonteCarlo::new(4));
        assert!(st2.p_none() < 0.7, "fixed-tr decode rate too low: {:.3}", st2.p_none());
    }

    /// Closed-form FR outage on a homogeneous iid network: a member
    /// delivers w.p. (1−p_mk)^s (1−p_m); a group is covered unless all
    /// s+1 members fail; success needs every group covered.
    fn fr_outage_closed_form(m: usize, s: usize, p_m: f64, p_mk: f64) -> f64 {
        let p_del = (1.0 - p_mk).powi(s as i32) * (1.0 - p_m);
        let p_group = 1.0 - (1.0 - p_del).powi((s + 1) as i32);
        1.0 - p_group.powi((m / (s + 1)) as i32)
    }

    #[test]
    fn fr_mc_matches_closed_form() {
        Prop::new(6).forall("fr mc vs product form", |rng, _| {
            let s = rng.range(1, 4);
            let groups = rng.range(2, 5);
            let m = groups * (s + 1);
            let (p_m, p_mk) = (rng.uniform(0.05, 0.5), rng.uniform(0.05, 0.5));
            let net = Network::homogeneous(m, p_m, p_mk);
            let code = FrCode::new(m, s).unwrap();
            let exact = fr_outage_closed_form(m, s, p_m, p_mk);
            let trials = 20_000;
            let mc = MonteCarlo::new(rng.next_u64());
            let est = estimate_outage_fr(&net, &code, &Iid, trials, &mc);
            let sigma = (exact * (1.0 - exact) / trials as f64).sqrt();
            assert!(
                (est - exact).abs() < 4.0 * sigma + 5e-3,
                "exact {exact} vs mc {est} (m={m}, s={s})"
            );
        });
    }

    #[test]
    fn fr_outage_thread_invariant() {
        let net = Network::homogeneous(12, 0.3, 0.3);
        let code = FrCode::new(12, 2).unwrap();
        let mc1 = MonteCarlo::new(0xF00D).with_threads(1);
        let want = estimate_outage_fr(&net, &code, &Iid, 3_000, &mc1);
        for threads in [2usize, 8] {
            let mc = MonteCarlo::new(0xF00D).with_threads(threads);
            let got = estimate_outage_fr(&net, &code, &Iid, 3_000, &mc);
            assert_eq!(got.to_bits(), want.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn fr_recovery_stats_partition() {
        let net = Network::homogeneous(12, 0.4, 0.35);
        let code = FrCode::new(12, 2).unwrap();
        for (i, mode) in [
            RecoveryMode::FixedTr(2),
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 },
        ]
        .into_iter()
        .enumerate()
        {
            let mc = MonteCarlo::new(91 + i as u64);
            let st = fr_recovery(&net, &Iid, &code, mode, 300, &mc);
            assert_eq!(st.trials, 300);
            assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
            assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
            let total = st.p_full() + st.p_partial() + st.p_none();
            assert!((total - 1.0).abs() < 1e-12);
            // FR partial decodes come in whole groups of s+1 models
            for (k, &n) in st.k4_hist.iter().enumerate() {
                if n > 0 {
                    assert_eq!(k % (code.s + 1), 0, "k4 = {k} not group-aligned");
                }
            }
        }
    }

    #[test]
    fn fr_until_decode_rarely_none() {
        // GC⁺'s operational claim carries over: looping until some group
        // is covered almost always recovers something.
        let net = Network::homogeneous(12, 0.5, 0.4);
        let code = FrCode::new(12, 2).unwrap();
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 };
        let st = fr_recovery(&net, &Iid, &code, mode, 300, &MonteCarlo::new(5));
        assert!(st.p_none() < 0.05, "none = {:.3}", st.p_none());
    }

    // ── adversarial estimators ──────────────────────────────────────────

    use crate::scenario::Attack;

    #[test]
    fn adv_fraction_zero_matches_plain_estimators_exactly() {
        let spec = AdversarySpec::fraction(Attack::SignFlip, 0.0);
        let net = Network::fig6_setting(2, 10);
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 };

        let plain = gcplus_recovery(&net, &Iid, 10, 7, mode, 400, &MonteCarlo::new(21));
        let adv = gcplus_recovery_adv(&net, &Iid, &spec, 10, 7, mode, 400, &MonteCarlo::new(21));
        assert_eq!(plain, adv);
        assert_eq!(adv.corrupted + adv.detected + adv.poisoned + adv.excised, 0);

        let code = GcCode::generate(10, 7, &mut Rng::new(3));
        let po = estimate_outage(&net, &code, &Iid, 3_000, &MonteCarlo::new(9));
        let split = estimate_outage_adv(&net, &code, &Iid, &spec, 3_000, &MonteCarlo::new(9));
        assert_eq!(split.trials, 3_000);
        assert_eq!(split.decoded_poisoned, 0);
        assert_eq!(po.to_bits(), split.p_outage().to_bits());

        let fnet = Network::homogeneous(12, 0.4, 0.35);
        let fcode = FrCode::new(12, 2).unwrap();
        let fplain = fr_recovery(&fnet, &Iid, &fcode, mode, 400, &MonteCarlo::new(31));
        let fadv = fr_recovery_adv(&fnet, &Iid, &fcode, &spec, mode, 400, &MonteCarlo::new(31));
        assert_eq!(fplain, fadv);
        let fr_po = estimate_outage_fr(&fnet, &fcode, &Iid, 3_000, &MonteCarlo::new(17));
        let fr_split =
            estimate_outage_fr_adv(&fnet, &fcode, &Iid, &spec, 3_000, &MonteCarlo::new(17));
        assert_eq!(fr_po.to_bits(), fr_split.p_outage().to_bits());
    }

    #[test]
    fn adv_recovery_partition_detection_and_excision_invariants() {
        let spec = AdversarySpec::fraction(Attack::SignFlip, 0.3);
        let net = Network::fig6_setting(2, 10);
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 };
        let st = gcplus_recovery_adv(&net, &Iid, &spec, 10, 7, mode, 400, &MonteCarlo::new(55));
        assert_eq!(st.trials, 400);
        assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
        assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
        // with 30% flippers corruption reaches the PS often and the
        // repeat-redundancy audit must catch a healthy share of it
        assert!(st.corrupted > 100, "corrupted = {}", st.corrupted);
        assert!(st.detected > 0, "audit never fired");
        assert!(st.detected <= st.corrupted, "alarms on honest trials");
        assert!(st.excised >= st.detected, "each alarm excises >= 1 row");
        assert!(st.poisoned <= st.corrupted);
        assert!(st.p_detected() > 0.5, "detection rate {:.3}", st.p_detected());
    }

    #[test]
    fn adv_detection_beats_nodetect_on_poisoning() {
        let net = Network::fig6_setting(2, 10);
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 };
        let on = AdversarySpec::fraction(Attack::SignFlip, 0.3);
        let off = AdversarySpec { detect: false, ..on.clone() };
        let with = gcplus_recovery_adv(&net, &Iid, &on, 10, 7, mode, 400, &MonteCarlo::new(77));
        let without = gcplus_recovery_adv(&net, &Iid, &off, 10, 7, mode, 400, &MonteCarlo::new(77));
        // same seeds, same draws: the corruption exposure is identical
        assert_eq!(with.corrupted, without.corrupted);
        assert_eq!(without.detected, 0);
        assert_eq!(without.excised, 0);
        assert!(without.poisoned > 0, "undetected flips must poison decodes");
        assert!(
            with.poisoned < without.poisoned,
            "detection should cut poisoning: {} vs {}",
            with.poisoned,
            without.poisoned
        );
    }

    #[test]
    fn adv_fr_plurality_vote_detects_and_stays_group_aligned() {
        let spec = AdversarySpec::fraction(Attack::SignFlip, 0.3);
        let net = Network::homogeneous(12, 0.3, 0.25);
        let code = FrCode::new(12, 2).unwrap();
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 };
        let st = fr_recovery_adv(&net, &Iid, &code, &spec, mode, 400, &MonteCarlo::new(13));
        assert_eq!(st.trials, 400);
        assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
        assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
        assert!(st.corrupted > 100, "corrupted = {}", st.corrupted);
        assert!(st.detected > 0, "plurality vote never fired");
        assert!(st.detected <= st.corrupted);
        for (k, &n) in st.k4_hist.iter().enumerate() {
            if n > 0 && k != code.m {
                assert_eq!(k % (code.s + 1), 0, "k4 = {k} not group-aligned");
            }
        }
    }

    #[test]
    fn adv_outage_split_partitions_and_poisons() {
        // near-perfect links: decodes always happen, so a 30% flipper
        // fraction must convert a visible share into decoded-but-poisoned
        let net = Network::homogeneous(10, 0.02, 0.02);
        let code = GcCode::generate(10, 3, &mut Rng::new(7));
        let spec = AdversarySpec::fraction(Attack::SignFlip, 0.3);
        let split = estimate_outage_adv(&net, &code, &Iid, &spec, 2_000, &MonteCarlo::new(23));
        assert_eq!(
            split.decoded_clean + split.decoded_poisoned + split.outage,
            split.trials
        );
        assert!(split.decoded_poisoned > 200, "poisoned = {}", split.decoded_poisoned);
        assert!(split.p_poisoned() > split.p_outage());

        let fcode = FrCode::new(12, 2).unwrap();
        let fnet = Network::homogeneous(12, 0.02, 0.02);
        let fsplit =
            estimate_outage_fr_adv(&fnet, &fcode, &Iid, &spec, 2_000, &MonteCarlo::new(29));
        assert_eq!(
            fsplit.decoded_clean + fsplit.decoded_poisoned + fsplit.outage,
            fsplit.trials
        );
        // the single-attempt FR vote both excises (→ outage) and, when a
        // group is unanimously malicious, decodes poisoned
        assert!(fsplit.decoded_poisoned + fsplit.outage > 0);
    }

    #[test]
    fn adv_recovery_thread_invariant() {
        let spec = AdversarySpec::fraction(Attack::Replace { scale: 5.0 }, 0.25);
        let net = Network::fig6_setting(2, 10);
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 };
        let mc1 = MonteCarlo::new(0xBEEF).with_threads(1);
        let want = gcplus_recovery_adv(&net, &Iid, &spec, 10, 7, mode, 600, &mc1);
        for threads in [2usize, 8] {
            let mc = MonteCarlo::new(0xBEEF).with_threads(threads);
            let got = gcplus_recovery_adv(&net, &Iid, &spec, 10, 7, mode, 600, &mc);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    // ── degraded-mode (approx / tri-state) estimators ───────────────────

    #[test]
    fn approx_recovery_reclassifies_only_the_none_arm() {
        // poor links + fixed t_r so plain GC⁺ leaves plenty of outages
        let net = Network::fig6_setting(3, 10);
        let mode = RecoveryMode::FixedTr(2);
        let plain = gcplus_recovery(&net, &Iid, 10, 7, mode, 400, &MonteCarlo::new(33));
        let ap =
            gcplus_recovery_approx(&net, &Iid, 10, 7, mode, f64::INFINITY, 400, &MonteCarlo::new(33));
        // identical draws: the exact tallies must match bit-for-bit and
        // the rescue can only drain the none bucket
        assert_eq!(plain.standard, ap.standard);
        assert_eq!(plain.full, ap.full);
        assert_eq!(plain.partial, ap.partial);
        assert_eq!(plain.attempts, ap.attempts);
        assert_eq!(plain.none, ap.none + ap.approx);
        assert!(ap.approx > 0, "no trial was rescued on a p=0.75 network");
        assert_eq!(ap.residual_hist.iter().sum::<usize>(), ap.approx);
        assert_eq!(ap.standard + ap.full + ap.partial + ap.none + ap.approx, ap.trials);
        assert_eq!(ap.k4_hist.iter().sum::<usize>() + ap.approx, ap.trials);
        // the plain estimator never touches the new fields
        assert_eq!(plain.approx, 0);
        assert_eq!(plain.residual_hist, [0; gc::RESIDUAL_BUCKETS]);
    }

    #[test]
    fn approx_recovery_residual_threshold_is_monotone() {
        let net = Network::fig6_setting(3, 10);
        let mode = RecoveryMode::FixedTr(2);
        let mut prev = 0usize;
        for max_rel in [0.0, 0.1, 0.5, f64::INFINITY] {
            let st = gcplus_recovery_approx(&net, &Iid, 10, 7, mode, max_rel, 400,
                &MonteCarlo::new(33));
            assert!(st.approx >= prev, "tightening the budget gained trials");
            prev = st.approx;
        }
    }

    #[test]
    fn binary_approx_recovery_partition_and_thread_invariance() {
        let net = Network::fig6_setting(3, 10);
        let code = BinaryCode::new(10, 4).unwrap();
        let mode = RecoveryMode::FixedTr(2);
        let plain = binary_recovery(&net, &Iid, code, mode, 400, &MonteCarlo::new(21));
        let want =
            binary_recovery_approx(&net, &Iid, code, mode, f64::INFINITY, 400, &MonteCarlo::new(21));
        assert_eq!(plain.standard, want.standard);
        assert_eq!(plain.none, want.none + want.approx);
        assert!(want.approx > 0);
        assert_eq!(want.residual_hist.iter().sum::<usize>(), want.approx);
        assert_eq!(
            want.standard + want.full + want.partial + want.none + want.approx,
            want.trials
        );
        for threads in [2usize, 8] {
            let mc = MonteCarlo::new(21).with_threads(threads);
            let got = binary_recovery_approx(&net, &Iid, code, mode, f64::INFINITY, 400, &mc);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn tri_split_disabled_rescue_matches_plain_outage_exactly() {
        let net = Network::fig6_setting(2, 10);
        let code = GcCode::generate(10, 7, &mut Rng::new(3));
        let po = estimate_outage(&net, &code, &Iid, 3_000, &MonteCarlo::new(9));
        // max_rel < 0 never accepts: same draws, so the outage count is
        // the plain estimator's, bit-for-bit
        let tri = estimate_outage_tri(&net, &code, &Iid, -1.0, 3_000, &MonteCarlo::new(9));
        assert_eq!(tri.trials, 3_000);
        assert_eq!(tri.approx, 0);
        assert_eq!(tri.exact + tri.outage, tri.trials);
        assert_eq!(po.to_bits(), tri.p_outage().to_bits());
    }

    #[test]
    fn tri_split_rescues_and_stays_thread_invariant() {
        let net = Network::fig6_setting(3, 10);
        let code = GcCode::generate(10, 7, &mut Rng::new(3));
        let mc1 = MonteCarlo::new(0xABAD).with_threads(1);
        let want = estimate_outage_tri(&net, &code, &Iid, f64::INFINITY, 2_000, &mc1);
        assert_eq!(want.exact + want.approx + want.outage, want.trials);
        assert!(want.approx > 0, "no single-attempt rescue on a p=0.75 network");
        assert_eq!(want.residual_hist.iter().sum::<usize>(), want.approx);
        for threads in [2usize, 8] {
            let mc = MonteCarlo::new(0xABAD).with_threads(threads);
            let got = estimate_outage_tri(&net, &code, &Iid, f64::INFINITY, 2_000, &mc);
            assert_eq!(want, got, "threads={threads}");
        }
    }

    #[test]
    fn binary_adv_outage_split_partitions_and_poisons() {
        let code = BinaryCode::new(8, 2).unwrap();
        // near-perfect links: decodes always happen, so flippers must
        // surface as decoded-but-poisoned
        let net = Network::homogeneous(8, 0.02, 0.02);
        let spec = AdversarySpec::fraction(Attack::SignFlip, 0.3);
        let split =
            estimate_outage_binary_adv(&net, code, &Iid, &spec, 2_000, &MonteCarlo::new(41));
        assert_eq!(split.decoded_clean + split.decoded_poisoned + split.outage, split.trials);
        assert!(split.decoded_poisoned > 200, "poisoned = {}", split.decoded_poisoned);

        // fraction 0 never poisons
        let clean_spec = AdversarySpec::fraction(Attack::SignFlip, 0.0);
        let clean =
            estimate_outage_binary_adv(&net, code, &Iid, &clean_spec, 2_000, &MonteCarlo::new(41));
        assert_eq!(clean.decoded_poisoned, 0);
        assert_eq!(clean.decoded_clean + clean.outage, clean.trials);
    }
}
