//! Monte-Carlo estimation of outage / recovery statistics: cross-checks the
//! closed forms in `outage::exact` and produces the GC⁺ recovery statistics
//! of Fig. 6 (which have no closed form — only the bound of eq. (29)).
//!
//! All trial sweeps run through the deterministic [`crate::parallel`]
//! engine: pass a [`MonteCarlo`] instead of an `Rng` and the sweep fans out
//! over the worker pool with bit-identical tallies at any thread count
//! (serial reference = the same engine at `threads = 1`; see
//! `tests/parallel_determinism.rs` for the hand-rolled cross-check).
//!
//! Erasures are drawn through a [`ChannelModel`] prototype — the engine
//! clones it **once per worker** and resets the per-trial state from the
//! channel substream, so bursty/correlated/straggler dynamics
//! ([`crate::scenario`]) slot into every estimator unchanged. Pass
//! [`Iid`](crate::scenario::Iid) for the paper's memoryless statistics.
//!
//! The trial bodies are allocation-free at steady state: each worker pools
//! one channel box, one [`Realization`], one [`gc::Attempt`], and one
//! persistent [`gc::GcPlusDecoder`] ([`MonteCarlo::run_scratch`]); the
//! until-decode loop feeds newly delivered rows into the incremental
//! decoder instead of re-running a full RREF over the growing stack every
//! block.

use crate::gc::{self, FrCode, GcCode};
use crate::network::{Network, Realization, SparseRealization};
use crate::parallel::{Accumulate, MonteCarlo};
use crate::scenario::{ChannelModel, CHANNEL_STREAM};
use crate::util::rng::Rng;

/// Pooled per-worker buffers of the Monte-Carlo trial bodies.
struct TrialScratch {
    ch: Box<dyn ChannelModel>,
    real: Realization,
    att: gc::Attempt,
    dec: gc::GcPlusDecoder,
}

impl TrialScratch {
    fn new(proto: &dyn ChannelModel, m: usize) -> TrialScratch {
        TrialScratch {
            ch: proto.clone_box(),
            real: Realization::perfect(m),
            att: gc::Attempt::empty(),
            dec: gc::GcPlusDecoder::new(m),
        }
    }
}

/// Monte-Carlo estimate of the overall outage probability `P_O` under the
/// standard GC decoder, parallelized over the engine's worker pool.
pub fn estimate_outage(
    net: &Network,
    code: &GcCode,
    ch: &dyn ChannelModel,
    trials: usize,
    mc: &MonteCarlo,
) -> f64 {
    let outages: usize = mc.run_scratch(
        trials,
        || TrialScratch::new(ch, net.m),
        |t, rng, acc: &mut usize, s| {
            s.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            s.ch.sample_into(net, rng, &mut s.real);
            gc::Attempt::observe_into(code, &s.real, &mut s.att);
            if s.att.complete.len() < net.m - code.s {
                *acc += 1;
            }
        },
    );
    outages as f64 / trials as f64
}

/// GC⁺ repetition policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RecoveryMode {
    /// Exactly `t_r` attempts are stacked (the paper's analysis setting:
    /// "a fixed number of repeated communications, t_r, is assumed").
    FixedTr(usize),
    /// Algorithm 1's protocol: blocks of `t_r` attempts accumulate into
    /// `B̂(r)` until `K₄(r) ≠ ∅` (capped at `max_blocks` for safety).
    /// In this mode partial decodes are rare: with generic perturbed rows,
    /// no unit vector enters the row space until the rank reaches M, at
    /// which point *all* models decode — this is why full recovery
    /// dominates (paper Lemma 4 / Fig. 6).
    UntilDecode { tr: usize, max_blocks: usize },
}

/// Outcome statistics of GC⁺ over `trials` rounds.
///
/// Every field is an associative tally (counts, sums, histogram buckets),
/// so per-worker instances combine exactly via [`Accumulate::merge`] — the
/// property the parallel engine relies on for thread-count invariance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    pub trials: usize,
    /// Standard GC succeeded in some attempt (≥ M−s complete sums).
    pub standard: usize,
    /// Complementary decoder recovered all M local models.
    pub full: usize,
    /// Complementary decoder recovered a proper non-empty subset.
    pub partial: usize,
    /// Nothing decodable.
    pub none: usize,
    /// Histogram of |K₄| over complementary decodes (index = |K₄|).
    pub k4_hist: Vec<usize>,
    /// Total communication attempts consumed (for mean attempts/round).
    pub attempts: usize,
}

impl RecoveryStats {
    /// P(update uses *all* local models) = standard + complementary-full.
    pub fn p_full(&self) -> f64 {
        (self.standard + self.full) as f64 / self.trials as f64
    }

    pub fn p_partial(&self) -> f64 {
        self.partial as f64 / self.trials as f64
    }

    pub fn p_none(&self) -> f64 {
        self.none as f64 / self.trials as f64
    }

    pub fn mean_attempts(&self) -> f64 {
        self.attempts as f64 / self.trials as f64
    }
}

impl Accumulate for RecoveryStats {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.standard += other.standard;
        self.full += other.full;
        self.partial += other.partial;
        self.none += other.none;
        self.attempts += other.attempts;
        self.k4_hist.merge(other.k4_hist);
    }
}

/// One GC⁺ round: run the decoding pipeline (coefficients only, no
/// payloads), classify the outcome, and fold it into `stats`.
///
/// The until-decode loop is incremental: each attempt's delivered rows go
/// straight into the pooled [`gc::GcPlusDecoder`] and the per-block success
/// test is the allocation-free `decodable_count()` — bit-identical to
/// batch-decoding the stacked rows (see `tests/incremental_rref.rs`), but
/// `O(rank · M)` per new row instead of a full re-factor per block.
fn recovery_trial(
    net: &Network,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    rng: &mut Rng,
    stats: &mut RecoveryStats,
    scratch: &mut TrialScratch,
) {
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    let need = m - s;
    let (tr, max_blocks) = match mode {
        RecoveryMode::FixedTr(tr) => (tr, 1),
        RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
    };
    stats.trials += 1;
    scratch.dec.reset(m);
    let mut outcome: Option<usize> = None; // |K4| of the decode
    'blocks: for _ in 0..max_blocks {
        for _ in 0..tr {
            let code = GcCode::generate(m, s, rng);
            scratch.ch.sample_into(net, rng, &mut scratch.real);
            gc::Attempt::observe_into(&code, &scratch.real, &mut scratch.att);
            stats.attempts += 1;
            // standard GC shortcut on any single attempt
            if scratch.att.complete.len() >= need {
                stats.standard += 1;
                stats.k4_hist[m] += 1;
                outcome = Some(usize::MAX); // marker: standard
                break 'blocks;
            }
            scratch.dec.push_attempt(&scratch.att);
        }
        let k4 = scratch.dec.decodable_count();
        if k4 > 0 {
            outcome = Some(k4);
            break 'blocks;
        }
        if matches!(mode, RecoveryMode::FixedTr(_)) {
            outcome = Some(0);
            break 'blocks;
        }
    }
    match outcome {
        Some(usize::MAX) => {} // standard, already recorded
        Some(0) | None => {
            stats.none += 1;
            stats.k4_hist[0] += 1;
        }
        Some(k) if k == m => {
            stats.full += 1;
            stats.k4_hist[m] += 1;
        }
        Some(k) => {
            stats.partial += 1;
            stats.k4_hist[k] += 1;
        }
    }
}

/// Run the GC⁺ decoding pipeline over `trials` rounds through the parallel
/// engine and classify each round's outcome. The channel prototype `ch` is
/// cloned once per worker and reset per trial; its state evolves across the
/// round's repeated attempts (a burst can kill a whole block of repeats).
pub fn gcplus_recovery(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let mut stats: RecoveryStats = mc.run_scratch(
        trials,
        || TrialScratch::new(ch, m),
        |t, rng, acc: &mut RecoveryStats, scratch| {
            scratch.ch.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            recovery_trial(net, m, s, mode, rng, acc, scratch);
        },
    );
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0); // trials == 0 edge case
    }
    stats
}

/// Pooled per-worker buffers of the fractional-repetition trial bodies:
/// everything is O(M·(s+1)) — no dense matrix, no RREF decoder.
struct FrTrialScratch {
    ch: Box<dyn ChannelModel>,
    real: SparseRealization,
    covered: Vec<bool>,
    acc: Vec<bool>,
}

impl FrTrialScratch {
    fn new(proto: &dyn ChannelModel, code: &FrCode) -> FrTrialScratch {
        FrTrialScratch {
            ch: proto.clone_box(),
            real: SparseRealization::perfect(&code.sparse_support()),
            covered: Vec::with_capacity(code.groups()),
            acc: vec![false; code.groups()],
        }
    }
}

/// Monte-Carlo outage estimate for the fractional-repetition family:
/// outage iff some group has no member delivering a complete sum. The
/// trial body is the O(M) group scan over a sparse realization — the
/// structured-path replacement for [`estimate_outage`]'s rank test.
pub fn estimate_outage_fr(
    net: &Network,
    code: &FrCode,
    ch: &dyn ChannelModel,
    trials: usize,
    mc: &MonteCarlo,
) -> f64 {
    let sup = code.sparse_support();
    let outages: usize = mc.run_scratch(
        trials,
        || FrTrialScratch::new(ch, code),
        |t, rng, acc: &mut usize, s| {
            s.ch.reset_sparse(&sup, net, mc.substream_seed(CHANNEL_STREAM, t));
            s.ch.sample_sparse_into(&sup, net, rng, &mut s.real);
            code.covered_into(&s.real, &mut s.covered);
            if !FrCode::all_covered(&s.covered) {
                *acc += 1;
            }
        },
    );
    outages as f64 / trials as f64
}

/// One FR GC⁺ round: accumulate covered groups across repeated attempts
/// and classify like [`recovery_trial`], except "decodable" is the group
/// coverage scan (each covered group contributes its s+1 models to K₄)
/// instead of the incremental RREF.
fn fr_recovery_trial(
    net: &Network,
    code: &FrCode,
    mode: RecoveryMode,
    rng: &mut Rng,
    stats: &mut RecoveryStats,
    scratch: &mut FrTrialScratch,
) {
    let m = code.m;
    if stats.k4_hist.len() < m + 1 {
        stats.k4_hist.resize(m + 1, 0);
    }
    let (tr, max_blocks) = match mode {
        RecoveryMode::FixedTr(tr) => (tr, 1),
        RecoveryMode::UntilDecode { tr, max_blocks } => (tr, max_blocks),
    };
    stats.trials += 1;
    let sup = code.sparse_support();
    scratch.acc.clear();
    scratch.acc.resize(code.groups(), false);
    let mut standard = false;
    'blocks: for _ in 0..max_blocks {
        for _ in 0..tr {
            scratch.ch.sample_sparse_into(&sup, net, rng, &mut scratch.real);
            code.covered_into(&scratch.real, &mut scratch.covered);
            stats.attempts += 1;
            // standard FR decode on any single attempt: every group covered
            if FrCode::all_covered(&scratch.covered) {
                standard = true;
                break 'blocks;
            }
            FrCode::union_covered(&mut scratch.acc, &scratch.covered);
        }
        // any covered group decodes immediately (K₄ ≠ ∅), mirroring the
        // dense engine's per-block decodable_count() > 0 test
        if FrCode::covered_groups(&scratch.acc) > 0 {
            break 'blocks;
        }
        if matches!(mode, RecoveryMode::FixedTr(_)) {
            break 'blocks;
        }
    }
    if standard {
        stats.standard += 1;
        stats.k4_hist[m] += 1;
        return;
    }
    let k4 = code.k4_count(&scratch.acc);
    if k4 == m {
        stats.full += 1;
    } else if k4 > 0 {
        stats.partial += 1;
    } else {
        stats.none += 1;
    }
    stats.k4_hist[k4] += 1;
}

/// FR-family analogue of [`gcplus_recovery`]: classify GC⁺ outcomes over
/// `trials` rounds through the parallel engine using the O(M) group scan.
pub fn fr_recovery(
    net: &Network,
    ch: &dyn ChannelModel,
    code: &FrCode,
    mode: RecoveryMode,
    trials: usize,
    mc: &MonteCarlo,
) -> RecoveryStats {
    let sup = code.sparse_support();
    let mut stats: RecoveryStats = mc.run_scratch(
        trials,
        || FrTrialScratch::new(ch, code),
        |t, rng, acc: &mut RecoveryStats, scratch| {
            scratch.ch.reset_sparse(&sup, net, mc.substream_seed(CHANNEL_STREAM, t));
            fr_recovery_trial(net, code, mode, rng, acc, scratch);
        },
    );
    if stats.k4_hist.len() < code.m + 1 {
        stats.k4_hist.resize(code.m + 1, 0); // trials == 0 edge case
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::outage::exact::overall_outage;
    use crate::parallel::trial_rng;
    use crate::scenario::Iid;
    use crate::testing::Prop;

    /// Allocating reference trial — the hand-rolled serial baseline the
    /// pooled engine path is asserted against.
    fn outage_trial(
        net: &Network,
        code: &GcCode,
        ch: &mut dyn ChannelModel,
        rng: &mut Rng,
    ) -> bool {
        let real = ch.sample(net, rng);
        let att = gc::Attempt::observe(code, &real);
        att.complete.len() < net.m - code.s
    }

    #[test]
    fn mc_matches_closed_form() {
        Prop::new(8).forall("mc vs exact", |rng, _| {
            let m = rng.range(5, 11);
            let s = rng.range(1, m);
            let code = GcCode::generate(m, s, rng);
            let net = Network::homogeneous(m, rng.uniform(0.05, 0.7), rng.uniform(0.05, 0.7));
            let exact = overall_outage(&net, &code);
            let trials = 20_000;
            let mc = MonteCarlo::new(rng.next_u64());
            let est = estimate_outage(&net, &code, &Iid, trials, &mc);
            // 4-sigma binomial tolerance
            let sigma = (exact * (1.0 - exact) / trials as f64).sqrt();
            assert!(
                (est - exact).abs() < 4.0 * sigma + 5e-3,
                "exact {exact} vs mc {est} (m={m}, s={s})"
            );
        });
    }

    #[test]
    fn parallel_equals_serial_reference() {
        let net = Network::fig6_setting(2, 10);
        let code = GcCode::generate(10, 7, &mut Rng::new(3));
        let trials = 4_000;
        let seed = 0xFEED;
        // hand-rolled reference with the engine's per-trial seeding scheme
        let mut outages = 0usize;
        for t in 0..trials {
            let mut rng = trial_rng(seed, t as u64);
            if outage_trial(&net, &code, &mut Iid, &mut rng) {
                outages += 1;
            }
        }
        let want = outages as f64 / trials as f64;
        for threads in [1usize, 2, 8] {
            let mc = MonteCarlo::new(seed).with_threads(threads);
            let got = estimate_outage(&net, &code, &Iid, trials, &mc);
            assert_eq!(got.to_bits(), want.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn recovery_stats_partition() {
        let net = Network::fig6_setting(2, 10);
        for (i, mode) in [
            RecoveryMode::FixedTr(2),
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 },
        ]
        .into_iter()
        .enumerate()
        {
            let mc = MonteCarlo::new(42 + i as u64);
            let st = gcplus_recovery(&net, &Iid, 10, 7, mode, 300, &mc);
            assert_eq!(st.trials, 300);
            assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
            assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
            let total = st.p_full() + st.p_partial() + st.p_none();
            assert!((total - 1.0).abs() < 1e-12);
            assert!(st.mean_attempts() >= 1.0);
        }
    }

    #[test]
    fn paper_claim_full_recovery_dominates() {
        // Lemma 4 / Fig. 6: under Algorithm 1's repeat-until-decode protocol
        // (blocks of t_r = 2), full recovery dominates in every paper
        // setting — generically no unit vector enters the row space before
        // the rank saturates at M, so the first decodable event is usually
        // "everything decodes".
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 };
        for setting in 1..=3 {
            let net = Network::fig6_setting(setting, 10);
            let mc = MonteCarlo::new(7 + setting as u64);
            let st = gcplus_recovery(&net, &Iid, 10, 7, mode, 300, &mc);
            assert!(
                st.p_full() > st.p_partial() && st.p_full() > st.p_none(),
                "setting {setting}: full {:.3} partial {:.3} none {:.3}",
                st.p_full(),
                st.p_partial(),
                st.p_none()
            );
        }
        // Setting 4 (p_mk = 0.8) is the extreme-erasure regime: ~0.8^7 = 21%
        // of delivered rows are already unit vectors, so a *partial* decode
        // almost always fires before the stack reaches full rank. GC+ still
        // always recovers something (the paper's operational claim).
        let net = Network::fig6_setting(4, 10);
        let st = gcplus_recovery(&net, &Iid, 10, 7, mode, 300, &MonteCarlo::new(11));
        assert!(st.p_none() < 0.05, "setting 4 none = {:.3}", st.p_none());
        assert!(st.p_full() + st.p_partial() > 0.95);
    }

    #[test]
    fn fixed_tr_with_poor_uplinks_rarely_full() {
        // Sanity check of the analysis mode: with p_m = 0.75 and t_r = 2 the
        // PS sees ~5 of 20 rows, so full recovery needs a >= M-row delivery
        // burst (P ~ 1.4%); its rate must be small. This is exactly why
        // Algorithm 1 loops until decode.
        let net = Network::fig6_setting(3, 10);
        let st =
            gcplus_recovery(&net, &Iid, 10, 7, RecoveryMode::FixedTr(2), 800, &MonteCarlo::new(11));
        assert!(st.p_full() < 0.1, "p_full = {}", st.p_full());
    }

    #[test]
    fn gcplus_beats_standard_gc_under_poor_c2c() {
        // the headline GC+ claim: when client-to-client links are poor,
        // standard GC almost never updates but GC+ (Algorithm 1) always
        // decodes within a bounded number of blocks.
        let net = Network::conn_tier("poor", 10);
        let mut rng = Rng::new(3);
        let code = GcCode::generate(10, 7, &mut rng);
        let po = overall_outage(&net, &code);
        assert!(po > 0.99, "standard GC should be nearly dead, P_O = {po}");
        let st = gcplus_recovery(
            &net,
            &Iid,
            10,
            7,
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 },
            200,
            &MonteCarlo::new(3),
        );
        assert!(
            st.p_none() < 0.05,
            "GC+ should decode something, failed {:.3}",
            st.p_none()
        );
        // and the fixed-t_r mode still decodes a nontrivial fraction
        let st2 =
            gcplus_recovery(&net, &Iid, 10, 7, RecoveryMode::FixedTr(2), 400, &MonteCarlo::new(4));
        assert!(st2.p_none() < 0.7, "fixed-tr decode rate too low: {:.3}", st2.p_none());
    }

    /// Closed-form FR outage on a homogeneous iid network: a member
    /// delivers w.p. (1−p_mk)^s (1−p_m); a group is covered unless all
    /// s+1 members fail; success needs every group covered.
    fn fr_outage_closed_form(m: usize, s: usize, p_m: f64, p_mk: f64) -> f64 {
        let p_del = (1.0 - p_mk).powi(s as i32) * (1.0 - p_m);
        let p_group = 1.0 - (1.0 - p_del).powi((s + 1) as i32);
        1.0 - p_group.powi((m / (s + 1)) as i32)
    }

    #[test]
    fn fr_mc_matches_closed_form() {
        Prop::new(6).forall("fr mc vs product form", |rng, _| {
            let s = rng.range(1, 4);
            let groups = rng.range(2, 5);
            let m = groups * (s + 1);
            let (p_m, p_mk) = (rng.uniform(0.05, 0.5), rng.uniform(0.05, 0.5));
            let net = Network::homogeneous(m, p_m, p_mk);
            let code = FrCode::new(m, s).unwrap();
            let exact = fr_outage_closed_form(m, s, p_m, p_mk);
            let trials = 20_000;
            let mc = MonteCarlo::new(rng.next_u64());
            let est = estimate_outage_fr(&net, &code, &Iid, trials, &mc);
            let sigma = (exact * (1.0 - exact) / trials as f64).sqrt();
            assert!(
                (est - exact).abs() < 4.0 * sigma + 5e-3,
                "exact {exact} vs mc {est} (m={m}, s={s})"
            );
        });
    }

    #[test]
    fn fr_outage_thread_invariant() {
        let net = Network::homogeneous(12, 0.3, 0.3);
        let code = FrCode::new(12, 2).unwrap();
        let mc1 = MonteCarlo::new(0xF00D).with_threads(1);
        let want = estimate_outage_fr(&net, &code, &Iid, 3_000, &mc1);
        for threads in [2usize, 8] {
            let mc = MonteCarlo::new(0xF00D).with_threads(threads);
            let got = estimate_outage_fr(&net, &code, &Iid, 3_000, &mc);
            assert_eq!(got.to_bits(), want.to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn fr_recovery_stats_partition() {
        let net = Network::homogeneous(12, 0.4, 0.35);
        let code = FrCode::new(12, 2).unwrap();
        for (i, mode) in [
            RecoveryMode::FixedTr(2),
            RecoveryMode::UntilDecode { tr: 2, max_blocks: 20 },
        ]
        .into_iter()
        .enumerate()
        {
            let mc = MonteCarlo::new(91 + i as u64);
            let st = fr_recovery(&net, &Iid, &code, mode, 300, &mc);
            assert_eq!(st.trials, 300);
            assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
            assert_eq!(st.k4_hist.iter().sum::<usize>(), st.trials);
            let total = st.p_full() + st.p_partial() + st.p_none();
            assert!((total - 1.0).abs() < 1e-12);
            // FR partial decodes come in whole groups of s+1 models
            for (k, &n) in st.k4_hist.iter().enumerate() {
                if n > 0 {
                    assert_eq!(k % (code.s + 1), 0, "k4 = {k} not group-aligned");
                }
            }
        }
    }

    #[test]
    fn fr_until_decode_rarely_none() {
        // GC⁺'s operational claim carries over: looping until some group
        // is covered almost always recovers something.
        let net = Network::homogeneous(12, 0.5, 0.4);
        let code = FrCode::new(12, 2).unwrap();
        let mode = RecoveryMode::UntilDecode { tr: 2, max_blocks: 50 };
        let st = fr_recovery(&net, &Iid, &code, mode, 300, &MonteCarlo::new(5));
        assert!(st.p_none() < 0.05, "none = {:.3}", st.p_none());
    }
}
