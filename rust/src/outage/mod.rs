//! Reliability theory: closed-form outage analysis, Monte-Carlo
//! cross-checks, cost-efficient code design, and the convergence-bound
//! numerics of Theorems 1–2.

pub mod design;
pub mod exact;
pub mod mc;
pub mod theory;

pub use design::{cost_efficient_s, sweep, sweep_mc, DesignPoint};
pub use exact::{incomplete_probs, overall_outage, subcase_probs};
pub use mc::{
    binary_recovery, binary_recovery_approx, estimate_outage, estimate_outage_adv,
    estimate_outage_binary_adv, estimate_outage_fr, estimate_outage_fr_adv, estimate_outage_tri,
    fr_recovery, fr_recovery_adv, gcplus_recovery, gcplus_recovery_adv, gcplus_recovery_approx,
    OutageSplit, RecoveryMode, RecoveryStats, TriSplit,
};
