//! Convergence theory numerics (paper §IV-B Theorem 1, Remark 4, §VI-C
//! Lemma 5 / eq. (29), Theorem 2, Appendix A).
//!
//! Implements the negative-order polylogarithms `Li₋ᵥ(z)` in closed form,
//! the geometric repeated-round statistics of Remark 4, the Theorem-1
//! probabilistic convergence bound `ε(P_O)` (via the Delta-method Gaussian
//! approximation and the three-sigma rule), the GC⁺ full-recovery lower
//! bound `P̌_M` of eq. (29), the `K*` bound of Lemma 5 and the Theorem-2
//! optimality gap.

/// Binomial coefficient evaluated in f64 (loses exactness beyond ~2⁵³ but
/// never overflows for any realistic `(M−s)·t_r` — unlike the exact u128
/// [`crate::gc::codes::binomial`], which returns `None` on overflow).
fn binomial_f64(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut num = 1.0f64;
    for i in 0..k {
        num = num * (n - i) as f64 / (i + 1) as f64;
    }
    num
}

/// Negative-order polylogarithm `Li₋ᵥ(z) = Σ_{k≥1} kᵛ zᵏ` for v = 0..=4 and
/// `|z| < 1`, in closed rational form.
pub fn polylog_neg(v: u32, z: f64) -> f64 {
    assert!(z.abs() < 1.0, "polylog_neg requires |z| < 1, got {z}");
    let om = 1.0 - z;
    match v {
        0 => z / om,
        1 => z / (om * om),
        2 => z * (1.0 + z) / om.powi(3),
        3 => z * (1.0 + 4.0 * z + z * z) / om.powi(4),
        4 => z * (1.0 + z) * (1.0 + 10.0 * z + z * z) / om.powi(5),
        _ => {
            // series fallback (converges for |z| < 1)
            let mut sum = 0.0;
            let mut zk = 1.0;
            for k in 1..10_000u64 {
                zk *= z;
                let term = (k as f64).powi(v as i32) * zk;
                sum += term;
                if term.abs() < 1e-16 * sum.abs().max(1.0) {
                    break;
                }
            }
            sum
        }
    }
}

/// Remark 4: rounds between consecutive successful recoveries are
/// `Geo(1 − P_O)`; the expectation is `1/(1 − P_O)`.
pub fn expected_rounds_between_success(p_o: f64) -> f64 {
    assert!((0.0..1.0).contains(&p_o));
    1.0 / (1.0 - p_o)
}

/// Inputs of the Theorem-1 bound.
#[derive(Clone, Debug)]
pub struct Theorem1Params {
    pub m: usize,
    /// Total training rounds T (large but finite).
    pub t: usize,
    /// Local iterations per round I.
    pub i: usize,
    /// Overall outage probability per round.
    pub p_o: f64,
    /// Client→PS outage probabilities `p_m` (length M).
    pub p_c2s: Vec<f64>,
    /// Data-variance bound σ² (Assumption 2).
    pub sigma2: f64,
    /// Heterogeneity bounds D_m² (Assumption 3), length M.
    pub d2: Vec<f64>,
    /// Initial optimality gap F(g⁰) − F*.
    pub f_gap: f64,
}

/// Moments of J₁ and J₂ (eqs. (37)–(40)) and the resulting ε(P_O).
#[derive(Clone, Debug)]
pub struct Theorem1Bound {
    pub mu_j1: f64,
    pub sigma_j1: f64,
    pub mu_j2: f64,
    pub sigma_j2: f64,
    /// The 99.86%-probability convergence bound ε(P_O) of eq. (18).
    pub epsilon: f64,
    /// Whether T is in the bound's validity regime: the theorem requires T
    /// "sufficiently large", concretely `μ_J1 > 0` (the effective progress
    /// coefficient `H₁ = R/2 − H₃` must stay positive on average).
    pub valid: bool,
}

/// Smallest T (power-of-2 search) for which the Theorem-1 bound is valid
/// at the given parameters — useful for picking T in sweeps.
pub fn min_valid_t(p: &Theorem1Params) -> usize {
    let mut t = 16usize;
    while t < 1usize << 62 {
        let mut q = p.clone();
        q.t = t;
        if theorem1_bound(&q).valid {
            return t;
        }
        t *= 2;
    }
    t
}

/// Evaluate the Theorem-1 bound.
///
/// Follows Appendix A: with η = (1/L)√(M/T) the normalized J-statistics are
/// Gaussian by CLT; the ratio is Delta-method Gaussian; Cauchy–Schwarz
/// bounds the covariance; the three-sigma rule gives the 99.86% guarantee.
pub fn theorem1_bound(p: &Theorem1Params) -> Theorem1Bound {
    assert!((0.0..1.0).contains(&p.p_o), "P_O must be in [0,1) for convergence");
    let (t, i, m) = (p.t as f64, p.i as f64, p.m as f64);
    let po = p.p_o.max(1e-12); // Li expressions are continuous at 0; avoid 0/0
    let g = (1.0 - po) / po;
    let sqrt_mt = (m / t).sqrt();
    let li1 = polylog_neg(1, po);
    let li2 = polylog_neg(2, po);
    let li3 = polylog_neg(3, po);
    let li4 = polylog_neg(4, po);

    // (37a), (37b), (38)
    let mu_j1 = g * (0.5 * li1 - 2.0 * i * sqrt_mt * li2);
    let e_j1_sq = g * (0.25 * li2 - 2.0 * i * sqrt_mt * li3 + 4.0 * i * i * (m / t) * li4);
    let sigma_j1 = (e_j1_sq - mu_j1 * mu_j1).max(0.0).sqrt();

    let sum_p2: f64 = p.p_c2s.iter().map(|x| x * x).sum();
    let sum_pd2: f64 = p.p_c2s.iter().zip(&p.d2).map(|(pm, d)| pm * d).sum();

    // (39a), (39b), (40a), (40b)
    let mu_j3 = g * (0.5 * p.sigma2 * sqrt_mt * sum_p2 * li1 + 2.0 * i * sqrt_mt * sum_pd2 * li2);
    let e_j3_sq = g
        * (0.25 * (m / t) * p.sigma2 * p.sigma2 * sum_p2 * sum_p2 * li2
            + 4.0 * (m / t) * i * sum_pd2 * sum_pd2 * li4
            + 2.0 * (m / t) * i * sum_p2 * sum_pd2 * li3);
    let sigma_j3 = (e_j3_sq - mu_j3 * mu_j3).max(0.0).sqrt();

    // L cancels out of mu_J2's first term once eta = (1/L) sqrt(M/T) is
    // substituted into H2/J-normalization; the paper's (40a) keeps L/(TI)
    // with sqrt(T/M) — we take L = 1 (it rescales f_gap).
    let mu_j2 = (1.0 / (t * i)) * (t / m).sqrt() * p.f_gap + mu_j3;
    let sigma_j2 = sigma_j3;

    // (46): sigma_max^2, then (18)
    let sigma_max2 = sigma_j2 * sigma_j2 / (mu_j1 * mu_j1 * t)
        + mu_j2 * mu_j2 * sigma_j1 * sigma_j1 / (mu_j1.powi(4) * t)
        + 2.0 * mu_j2 * sigma_j1 * sigma_j2 / (mu_j1.powi(3) * t);
    let epsilon = mu_j2 / mu_j1 + 3.0 * sigma_max2;

    Theorem1Bound { mu_j1, sigma_j1, mu_j2, sigma_j2, epsilon, valid: mu_j1 > 0.0 }
}

/// Eq. (29): `P̌_M`, the lower bound on GC⁺ full recovery — the probability
/// that at least `M` of the `(M−s)·t_r` extracted rows survive uplink
/// erasure (homogeneous link probability `p`).
pub fn p_check_full(m: usize, s: usize, tr: usize, p: f64) -> f64 {
    let n = (m - s) * tr;
    if n < m {
        return 0.0;
    }
    let mut sum = 0.0;
    for v in m..=n {
        sum += binomial_f64(n, v) * p.powi((n - v) as i32) * (1.0 - p).powi(v as i32);
    }
    sum.clamp(0.0, 1.0)
}

/// Lemma 5: upper bound on `1/K̄` (inverse expected decoded-set size), and
/// the derived `K*`.
pub fn k_star(m: usize, s: usize, tr: usize, p: f64, p_o: f64) -> f64 {
    let pm = p_check_full(m, s, tr, p);
    let harmonic: f64 = (1..m).map(|k| 1.0 / k as f64).sum();
    let p_empty_bound = p_o.powi(tr as i32).min(1.0 - pm);
    let inv_k = pm * harmonic / (1.0 - p_empty_bound).max(1e-12) + 1.0 / m as f64;
    1.0 / inv_k
}

/// Theorem 2 inputs (GC⁺ convergence).
#[derive(Clone, Debug)]
pub struct Theorem2Params {
    pub t: usize,
    pub i: usize,
    pub k_star: f64,
    pub l_smooth: f64,
    pub f_gap: f64,
    pub sigma2: f64,
    pub batch: f64,
    /// Mean heterogeneity bound (1/M) Σ D_m².
    pub mean_d2: f64,
    /// Mean squared local-gradient norm bound (1/M) Σ J²_{m,r} (we fold the
    /// double sum of (32) into its per-round mean).
    pub mean_j2: f64,
}

/// Eq. (32): the Theorem-2 optimality gap bound.
pub fn theorem2_bound(p: &Theorem2Params) -> f64 {
    let (t, i, ks) = (p.t as f64, p.i as f64, p.k_star);
    let tik = t * i * ks;
    let ti = t * i;
    (496.0 * p.l_smooth / (11.0 * tik.sqrt())) * p.f_gap
        + (31.0 / (88.0 * ti.powf(1.5) * ks.sqrt())) * t * p.mean_j2
        + (39.0 / (88.0 * tik.sqrt()) + 1.0 / (88.0 * tik.powf(0.75))) * p.sigma2 / p.batch
        + (4.0 / (11.0 * tik.sqrt())
            + 1.0 / (22.0 * tik.powf(0.75))
            + 31.0 / (22.0 * ti.powf(0.25) * ks.powf(1.25)))
            * p.mean_d2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn polylog_matches_series() {
        for &z in &[0.1, 0.4, 0.75, 0.9] {
            for v in 0..=4u32 {
                let closed = polylog_neg(v, z);
                let mut series = 0.0;
                let mut zk = 1.0;
                for k in 1..2000u64 {
                    zk *= z;
                    series += (k as f64).powi(v as i32) * zk;
                }
                assert_close(closed, series, 1e-9);
            }
        }
    }

    #[test]
    fn polylog_identity_geometric_mean() {
        // E[R] for R ~ Geo(1-z) equals ((1-z)/z) * Li_{-1}(z) shifted:
        // sum_{k>=1} k z^{k-1} (1-z) = (1-z)/z * Li_{-1}(z) = 1/(1-z).
        for &z in &[0.2, 0.5, 0.8] {
            let lhs = (1.0 - z) / z * polylog_neg(1, z);
            assert_close(lhs, 1.0 / (1.0 - z), 1e-12);
            assert_close(expected_rounds_between_success(z), 1.0 / (1.0 - z), 1e-12);
        }
    }

    fn base_params(p_o: f64, t: usize) -> Theorem1Params {
        Theorem1Params {
            m: 10,
            t,
            i: 5,
            p_o,
            p_c2s: vec![0.3; 10],
            sigma2: 1.0,
            d2: vec![1.0; 10],
            f_gap: 10.0,
        }
    }

    #[test]
    fn bound_is_finite_and_positive() {
        let b = theorem1_bound(&base_params(0.3, 10_000_000));
        assert!(b.valid, "T=1e7 should be in the validity regime: {b:?}");
        assert!(b.epsilon.is_finite() && b.epsilon > 0.0, "{b:?}");
    }

    #[test]
    fn small_t_is_flagged_invalid() {
        // the "T sufficiently large" requirement is real: tiny T flips mu_J1
        let b = theorem1_bound(&base_params(0.8, 100));
        assert!(!b.valid);
        let t_min = min_valid_t(&base_params(0.8, 0));
        assert!(t_min > 100, "t_min = {t_min}");
        assert!(theorem1_bound(&base_params(0.8, t_min)).valid);
    }

    #[test]
    fn bound_shrinks_with_t() {
        // O(1/sqrt(T)) rate (Remark 6)
        let e1 = theorem1_bound(&base_params(0.3, 10_000_000)).epsilon;
        let e2 = theorem1_bound(&base_params(0.3, 1_000_000_000)).epsilon;
        assert!(e2 < e1, "e(1e7) = {e1} vs e(1e9) = {e2}");
        // ~ sqrt(100) improvement expected on the dominant term
        assert!(e2 < 0.3 * e1);
    }

    #[test]
    fn bound_grows_with_outage() {
        // compare at a T valid for both outage levels
        let t = min_valid_t(&base_params(0.8, 0)) * 4;
        let e_lo = theorem1_bound(&base_params(0.1, t)).epsilon;
        let e_hi = theorem1_bound(&base_params(0.8, t)).epsilon;
        assert!(e_hi > e_lo, "epsilon must grow with P_O: {e_lo} vs {e_hi}");
    }

    #[test]
    fn p_check_matches_paper_regimes() {
        // (M-s) t_r >= M is required for any mass at all
        assert_eq!(p_check_full(10, 7, 2, 0.3), 0.0); // 6 rows < 10
        assert_eq!(p_check_full(10, 7, 3, 0.5), 0.0); // 9 rows < 10 even with perfect links
        // with t_r = 4: 12 rows >= 10
        let p = p_check_full(10, 7, 4, 0.2);
        assert!(p > 0.0 && p < 1.0);
        // perfect links: probability 1
        assert_close(p_check_full(10, 7, 4, 0.0), 1.0, 1e-12);
        // monotone in p
        assert!(p_check_full(10, 7, 4, 0.1) > p_check_full(10, 7, 4, 0.5));
    }

    #[test]
    fn p_check_approaches_one_when_rows_abound() {
        // Lemma 4: (M-s) t_r >> M makes full recovery dominant
        let p = p_check_full(10, 5, 10, 0.3); // 50 rows vs 10 needed
        assert!(p > 0.999, "p = {p}");
    }

    #[test]
    fn k_star_in_valid_range() {
        for &(tr, p, po) in &[(2usize, 0.4, 0.9), (4, 0.2, 0.5), (8, 0.5, 0.99)] {
            let ks = k_star(10, 7, tr, p, po);
            assert!(ks > 0.0 && ks <= 10.0, "K* = {ks} (tr={tr})");
        }
    }

    #[test]
    fn theorem2_bound_decreases_with_budget() {
        let mk = |t: usize| Theorem2Params {
            t,
            i: 5,
            k_star: 5.0,
            l_smooth: 1.0,
            f_gap: 10.0,
            sigma2: 1.0,
            batch: 32.0,
            mean_d2: 1.0,
            mean_j2: 1.0,
        };
        let e1 = theorem2_bound(&mk(100));
        let e2 = theorem2_bound(&mk(10_000));
        assert!(e2 < e1);
        assert!(e2 > 0.0);
    }
}
