//! Deterministic parallel Monte-Carlo engine.
//!
//! Every headline statistic of the paper — the Fig. 4 outage probabilities,
//! the Fig. 6 GC⁺ recovery distribution, the eq. (21) design cross-checks —
//! is an average over tens of thousands of independent trials. This module
//! fans those trial loops out over a `std::thread` worker pool while keeping
//! the results **bit-identical for every thread count**, so a figure
//! regenerated on a laptop matches one regenerated on a 64-core box.
//!
//! # Determinism scheme
//!
//! Two ingredients make the engine thread-count-invariant:
//!
//! 1. **Counter-derived RNG streams.** Trial `t` draws exclusively from
//!    `Rng::new(base_seed ^ t)` ([`MonteCarlo::trial_rng`]). `Rng` seeds
//!    through SplitMix64, which whitens the correlated inputs
//!    `seed ^ 0, seed ^ 1, …` into independent xoshiro256** states, so no
//!    trial ever observes another trial's draws — regardless of which worker
//!    runs it or in what order.
//! 2. **Fixed-size chunks merged in index order.** Trials are grouped into
//!    chunks of [`MonteCarlo::chunk`] trials (a constant independent of the
//!    thread count). Workers pull chunk indices from an atomic counter and
//!    accumulate each chunk into a fresh accumulator; the per-chunk results
//!    are then merged **in ascending chunk order**. A `threads = 1` run
//!    executes the exact same chunk/merge schedule sequentially, so it is
//!    the serial reference by construction.
//!
//! Accumulators implement [`Accumulate`]; for thread-count invariance a
//! `merge` must be associative over the values it folds (integer tallies and
//! sums, `f64::max`-style maxima — **not** order-sensitive `f64` sums).
//!
//! # Usage
//!
//! ```no_run
//! use cogc::parallel::MonteCarlo;
//! let mc = MonteCarlo::new(42).with_threads(0); // 0 = one per core
//! let heads: usize = mc.run(100_000, |_trial, rng, acc: &mut usize| {
//!     if rng.bernoulli(0.5) {
//!         *acc += 1;
//!     }
//! });
//! ```
//!
//! # `--threads` semantics (CLI contract)
//!
//! Every parallel subcommand of the `cogc` CLI takes `--threads N`:
//!
//! - `N = 0` (the default) resolves to one worker per core
//!   (`std::thread::available_parallelism`);
//! - any `N ≥ 1` pins the worker count.
//!
//! **`N` never changes results, only wall-clock.** Monte-Carlo sweeps
//! (`fig4`, `fig6`, `design`) are thread-count-invariant by the
//! chunk/merge scheme above. The training figures (`fig7`, `fig8`,
//! `fig10`, `fig11`, `fig12`) fan their method/network grid out through
//! [`parallel_map`]: each grid cell is an independent, fully deterministic
//! training run (own seed-derived RNG streams, sequential rounds), and
//! cells are collected in grid order — so the emitted CSV is byte-identical
//! for every `--threads` value, including `1`.
//!
//! # Worker-pool map
//!
//! [`parallel_map`] is the second entry point next to [`MonteCarlo::run`]:
//! an order-preserving map over a small work list (figure grid cells,
//! per-model sweeps) on the same scoped-thread / atomic-counter pool
//! pattern. Use `MonteCarlo` for tens of thousands of cheap trials folded
//! into an accumulator; use `parallel_map` for a handful of expensive jobs
//! whose outputs you need individually.

use crate::telemetry;
use crate::util::rng::{splitmix64, Rng};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default trials per chunk. Large enough that chunk dispatch overhead is
/// negligible against trial work (a trial is ≥ one code generation + one
/// network realization), small enough to load-balance tail chunks well.
pub const DEFAULT_CHUNK: usize = 256;

/// Mergeable per-worker tally.
///
/// `merge` folds another accumulator of the same kind into `self`. The
/// engine always merges per-chunk accumulators in ascending chunk index
/// order, so determinism across thread counts only requires `merge` to be
/// deterministic; order-*independence* additionally requires commutativity
/// and associativity, which all the built-in tallies (counts, integer sums,
/// maxima) satisfy — see the property tests in `tests/parallel_determinism`.
pub trait Accumulate: Default + Send {
    fn merge(&mut self, other: Self);
}

/// Plain counters (outage tallies and the like).
impl Accumulate for usize {
    fn merge(&mut self, other: Self) {
        *self += other;
    }
}

/// Pairs merge element-wise (e.g. (count, transmissions)).
impl<A: Accumulate, B: Accumulate> Accumulate for (A, B) {
    fn merge(&mut self, other: Self) {
        self.0.merge(other.0);
        self.1.merge(other.1);
    }
}

/// Per-bucket tallies (histograms); shorter vectors are zero-extended.
impl Accumulate for Vec<usize> {
    fn merge(&mut self, other: Self) {
        if self.len() < other.len() {
            self.resize(other.len(), 0);
        }
        for (i, v) in other.into_iter().enumerate() {
            self[i] += v;
        }
    }
}

/// Worker count of this machine (≥ 1).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing thread request: `0` means "one per core".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Order-preserving parallel map over a work list: `out[i] = f(i, &items[i])`.
///
/// Workers pull item indices from an atomic counter (work stealing, same
/// pattern as [`MonteCarlo::run`]) and results land in their item's slot,
/// so the output order is the input order for every `threads` value —
/// `threads = 0` resolves to one worker per core, `threads = 1` degrades
/// to a plain serial map. Determinism therefore only requires `f` itself
/// to be deterministic per item; the training-figure grids rely on this
/// for byte-identical CSV at any `--threads`.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    // Item totals are deterministic (input length); per-worker throughput
    // is wall-clock and goes to the non-deterministic section. Both are
    // recorded only when telemetry is armed: this path runs inside
    // per-round hot loops (FR decode fan-out), so disarmed it must not
    // touch the registry lock at all.
    let armed = telemetry::armed();
    if armed {
        telemetry::count(telemetry::metric::PM_ITEMS, n as u64);
    }
    let workers = resolve_threads(threads).min(n).max(1);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, item)| f(i, item)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let next = &next;
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || {
                    let t0 = armed.then(std::time::Instant::now);
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(i, &items[i])));
                    }
                    (done, t0.map(|t0| t0.elapsed()))
                })
            })
            .collect();
        for (w, h) in handles.into_iter().enumerate() {
            let (done, elapsed) = h.join().expect("parallel_map worker panicked");
            let items_done = done.len() as u64;
            for (i, r) in done {
                slots[i] = Some(r);
            }
            if let Some(elapsed) = elapsed {
                telemetry::record_worker("parallel_map", w, items_done, elapsed);
            }
        }
    });
    slots
        .into_iter()
        .map(|s| s.expect("every index dispatched exactly once"))
        .collect()
}

/// Derive an independent base seed for a named sub-experiment (figure cell,
/// sweep point, …) so that sweeps can issue one `MonteCarlo` per cell
/// without the cells' trial streams colliding.
pub fn derive_seed(base: u64, stream: u64) -> u64 {
    let mut s = base ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// The canonical per-trial *emission* stream of a Monte-Carlo sweep:
/// `Rng::new(seed ^ trial)`. This is THE definition — every sweep in the
/// crate and every hand-rolled serial reference in the determinism tests
/// derives trial randomness through this one helper, so the seeding scheme
/// can never drift between the engine and its cross-checks.
pub fn trial_rng(seed: u64, trial: u64) -> Rng {
    Rng::new(seed ^ trial)
}

/// Seed of a named auxiliary per-trial stream — e.g. the private
/// state-evolution stream of a stateful channel model — derived so it is
/// disjoint from the emission stream [`trial_rng`] of *every* trial and
/// from other tags. Keeping auxiliary draws off the emission stream is what
/// lets a degenerately-configured stateful model consume emission draws
/// byte-identically to the memoryless one.
pub fn trial_substream(seed: u64, tag: u64, trial: u64) -> u64 {
    derive_seed(derive_seed(seed, tag), trial)
}

/// A deterministic Monte-Carlo runner: base seed + worker pool + chunking.
#[derive(Clone, Debug)]
pub struct MonteCarlo {
    /// Base seed; trial `t` uses `Rng::new(seed ^ t)`.
    pub seed: u64,
    /// Worker threads (resolved, ≥ 1). Does not affect results.
    pub threads: usize,
    /// Trials per chunk (fixed, independent of `threads`). Affects only the
    /// internal merge schedule, and the merge is order-fixed, so results are
    /// chunk-size-invariant for the commutative/associative accumulators
    /// used throughout this crate.
    pub chunk: usize,
}

impl MonteCarlo {
    /// Engine with one worker per available core.
    pub fn new(seed: u64) -> MonteCarlo {
        MonteCarlo { seed, threads: available_threads(), chunk: DEFAULT_CHUNK }
    }

    /// Single-threaded engine (the serial reference schedule).
    pub fn serial(seed: u64) -> MonteCarlo {
        MonteCarlo::new(seed).with_threads(1)
    }

    /// Set the worker count; `0` resolves to one per core.
    pub fn with_threads(mut self, threads: usize) -> MonteCarlo {
        self.threads = resolve_threads(threads);
        self
    }

    /// Override the chunk size (mainly for tests).
    pub fn with_chunk(mut self, chunk: usize) -> MonteCarlo {
        self.chunk = chunk.max(1);
        self
    }

    /// The counter-derived emission RNG stream of trial `t`
    /// (see [`trial_rng`], the crate-wide definition).
    pub fn trial_rng(&self, trial: u64) -> Rng {
        trial_rng(self.seed, trial)
    }

    /// Seed of the auxiliary per-trial stream `tag` of this engine's sweep
    /// (see [`trial_substream`]).
    pub fn substream_seed(&self, tag: u64, trial: u64) -> u64 {
        trial_substream(self.seed, tag, trial)
    }

    /// Run `trials` independent trials and merge their tallies.
    ///
    /// `trial(t, rng, acc)` must derive all randomness from `rng` (the
    /// stream of trial `t`) and fold its outcome into `acc`. The returned
    /// accumulator is bit-identical for every `threads` setting.
    pub fn run<A, F>(&self, trials: usize, trial: F) -> A
    where
        A: Accumulate,
        F: Fn(u64, &mut Rng, &mut A) + Sync,
    {
        self.run_scratch(trials, || (), |t, rng, acc, _| trial(t, rng, acc))
    }

    /// [`run`](MonteCarlo::run) with **per-worker scratch state**: each
    /// worker thread calls `scratch()` once and threads the value through
    /// every trial it executes. This is the zero-allocation hook of the
    /// Monte-Carlo hot loops — pooled channel-model boxes, `Realization`/
    /// `Attempt` buffers, and the persistent GC⁺ decoder live in the
    /// scratch and are *reset*, never reallocated, per trial.
    ///
    /// Determinism contract: a trial's outcome must depend only on
    /// `(t, rng)` — the trial body must re-initialize whatever scratch
    /// state it reads (e.g. `ChannelModel::reset`, `GcPlusDecoder::reset`),
    /// since which trials share a scratch instance depends on the
    /// work-stealing schedule. Under that contract the result is
    /// bit-identical for every thread count, exactly as with `run`.
    pub fn run_scratch<A, S, F, G>(&self, trials: usize, scratch: G, trial: F) -> A
    where
        A: Accumulate,
        G: Fn() -> S + Sync,
        F: Fn(u64, &mut Rng, &mut A, &mut S) + Sync,
    {
        self.run_scratch_tel(trials, scratch, telemetry::no_shard::<S>, trial)
    }

    /// [`run_scratch`](MonteCarlo::run_scratch) with a **telemetry shard
    /// projection**: `tel` exposes the [`telemetry::Shard`] pooled inside
    /// the worker scratch (or `None` — [`telemetry::no_shard`] — for
    /// scratch types that carry none). The trial bodies bump the shard
    /// with plain integer ops; after the join the engine snapshots each
    /// worker's shard and merges them into the global registry **in
    /// worker-index order**, so the registry's deterministic section is
    /// bit-identical at any thread count even though the chunk→worker
    /// assignment is racy (shard merges are commutative integer ops).
    ///
    /// Per-worker wall-clock throughput is recorded into the registry's
    /// non-deterministic section only when telemetry is
    /// [`armed`](telemetry::armed) — disarmed, this path reads no clock,
    /// takes no lock per trial, and allocates nothing beyond
    /// [`run_scratch`] itself (`tests/telemetry_alloc.rs`).
    ///
    /// `tel` is a plain `fn` pointer (not a generic closure) so the
    /// projection cannot capture state and higher-ranked lifetime
    /// inference stays trivial at every call site.
    pub fn run_scratch_tel<A, S, F, G>(
        &self,
        trials: usize,
        scratch: G,
        tel: fn(&mut S) -> Option<&mut telemetry::Shard>,
        trial: F,
    ) -> A
    where
        A: Accumulate,
        G: Fn() -> S + Sync,
        F: Fn(u64, &mut Rng, &mut A, &mut S) + Sync,
    {
        let chunk = self.chunk.max(1);
        let n_chunks = if trials == 0 { 0 } else { (trials - 1) / chunk + 1 };

        let run_chunk = |c: usize, s: &mut S| -> A {
            let mut acc = A::default();
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(trials);
            for t in lo..hi {
                let mut rng = self.trial_rng(t as u64);
                trial(t as u64, &mut rng, &mut acc, s);
            }
            if let Some(sh) = tel(s) {
                sh.inc(telemetry::metric::MC_CHUNKS);
                sh.add(telemetry::metric::MC_TRIALS, (hi - lo) as u64);
            }
            acc
        };

        // Snapshot a worker's shard for the ordered registry merge; a
        // Shard is flat arrays, so the clone is a memcpy, not a heap op.
        let take_shard = |s: &mut S| -> Option<telemetry::Shard> {
            tel(s).map(|sh| {
                let snap = sh.clone();
                sh.clear();
                snap
            })
        };

        let workers = self.threads.min(n_chunks).max(1);
        if workers == 1 {
            // Same chunk/merge schedule, executed in order on this thread.
            let mut s = scratch();
            let mut total = A::default();
            for c in 0..n_chunks {
                total.merge(run_chunk(c, &mut s));
            }
            if let Some(snap) = take_shard(&mut s) {
                telemetry::merge_shard(&snap);
            }
            return total;
        }

        // Work-stealing over chunk indices; each worker returns its chunks
        // tagged with their index so the final merge is order-fixed.
        let armed = telemetry::armed();
        let next = AtomicUsize::new(0);
        let mut slots: Vec<Option<A>> = Vec::with_capacity(n_chunks);
        slots.resize_with(n_chunks, || None);
        std::thread::scope(|scope| {
            let next = &next;
            let run_chunk = &run_chunk;
            let take_shard = &take_shard;
            let scratch = &scratch;
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(move || {
                        let t0 = armed.then(std::time::Instant::now);
                        let mut s = scratch();
                        let mut done: Vec<(usize, A)> = Vec::new();
                        let mut n_trials = 0u64;
                        loop {
                            let c = next.fetch_add(1, Ordering::Relaxed);
                            if c >= n_chunks {
                                break;
                            }
                            let lo = c * chunk;
                            let hi = ((c + 1) * chunk).min(trials);
                            n_trials += (hi - lo) as u64;
                            done.push((c, run_chunk(c, &mut s)));
                        }
                        (done, take_shard(&mut s), t0.map(|t0| (n_trials, t0.elapsed())))
                    })
                })
                .collect();
            for (w, h) in handles.into_iter().enumerate() {
                let (done, shard, stat) = h.join().expect("monte-carlo worker panicked");
                for (c, acc) in done {
                    slots[c] = Some(acc);
                }
                // worker-index order: handles are joined 0..workers
                if let Some(snap) = shard {
                    telemetry::merge_shard(&snap);
                }
                if let Some((items, elapsed)) = stat {
                    telemetry::record_worker("monte_carlo", w, items, elapsed);
                }
            }
        });
        let mut total = A::default();
        for slot in slots {
            if let Some(acc) = slot {
                total.merge(acc);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_heads(mc: &MonteCarlo, trials: usize) -> usize {
        mc.run(trials, |_t, rng, acc: &mut usize| {
            if rng.bernoulli(0.37) {
                *acc += 1;
            }
        })
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let trials = 10_000;
        let want = count_heads(&MonteCarlo::serial(99), trials);
        for threads in [2usize, 3, 4, 8, 16] {
            let got = count_heads(&MonteCarlo::new(99).with_threads(threads), trials);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn chunk_size_does_not_change_count_tallies() {
        let trials = 5_000;
        let want = count_heads(&MonteCarlo::serial(7), trials);
        for chunk in [1usize, 17, 256, 10_000] {
            let got = count_heads(&MonteCarlo::new(7).with_threads(4).with_chunk(chunk), trials);
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn matches_hand_rolled_per_trial_loop() {
        let trials = 3_000;
        let seed = 0xABCDu64;
        let mut want = 0usize;
        for t in 0..trials {
            let mut rng = trial_rng(seed, t as u64);
            if rng.bernoulli(0.37) {
                want += 1;
            }
        }
        assert_eq!(count_heads(&MonteCarlo::new(seed).with_threads(8), trials), want);
    }

    #[test]
    fn trial_substream_is_disjoint_from_emission_streams() {
        let seed = 42u64;
        // the substream seed of any (tag, trial) must differ from the raw
        // emission seed `seed ^ trial` of every nearby trial, and from the
        // same trial under a different tag
        for trial in 0..64u64 {
            let sub = trial_substream(seed, 7, trial);
            for t in 0..64u64 {
                assert_ne!(sub, seed ^ t, "collides with emission stream of trial {t}");
            }
            assert_ne!(sub, trial_substream(seed, 8, trial));
            assert_eq!(sub, trial_substream(seed, 7, trial), "must be deterministic");
        }
        let mc = MonteCarlo::new(seed);
        assert_eq!(mc.substream_seed(7, 3), trial_substream(seed, 7, 3));
    }

    #[test]
    fn run_scratch_matches_run_at_any_thread_count() {
        // Pooled scratch must be invisible in the results when the trial
        // body resets it — bit-identical to the scratch-free engine.
        let trials = 5_000;
        let want = count_heads(&MonteCarlo::serial(13), trials);
        for threads in [1usize, 3, 8] {
            let mc = MonteCarlo::new(13).with_threads(threads);
            let got: usize = mc.run_scratch(
                trials,
                Vec::<u64>::new,
                |t, rng, acc, buf| {
                    buf.clear(); // per-trial reset of the pooled buffer
                    buf.push(t);
                    if rng.bernoulli(0.37) {
                        *acc += buf.len();
                    }
                },
            );
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn run_scratch_tel_registry_is_thread_invariant() {
        // The merged deterministic section must be bit-identical at any
        // thread count: shards ride in the scratch, and the engine merges
        // worker snapshots in index order after the join.
        let _lock = telemetry::TEST_LOCK.lock().unwrap();
        telemetry::disarm();
        let trials = 3_000;
        fn shard_of(s: &mut telemetry::Shard) -> Option<&mut telemetry::Shard> {
            Some(s)
        }
        let run = |threads: usize| -> telemetry::Shard {
            telemetry::reset();
            let mc = MonteCarlo::new(21).with_threads(threads).with_chunk(64);
            let _: usize = mc.run_scratch_tel(
                trials,
                telemetry::Shard::default,
                shard_of,
                |_t, rng, acc, sh| {
                    sh.inc(telemetry::metric::DEC_EPISODES);
                    sh.observe(telemetry::metric::H_DEC_RANK, rng.range(0, 9) as u64);
                    if rng.bernoulli(0.37) {
                        *acc += 1;
                    }
                },
            );
            telemetry::snapshot()
        };
        let want = run(1);
        assert_eq!(want.counter(telemetry::metric::DEC_EPISODES), trials as u64);
        assert_eq!(want.counter(telemetry::metric::MC_TRIALS), trials as u64);
        assert_eq!(want.hist_count(telemetry::metric::H_DEC_RANK), trials as u64);
        for threads in [2usize, 3, 8] {
            assert_eq!(run(threads), want, "threads={threads}");
        }
        telemetry::reset();
    }

    #[test]
    fn trial_index_is_passed_through() {
        let sum: usize = MonteCarlo::new(1).with_threads(4).run(1000, |t, _rng, acc: &mut usize| {
            *acc += t as usize;
        });
        assert_eq!(sum, 1000 * 999 / 2);
    }

    #[test]
    fn zero_trials_yields_default() {
        let mc = MonteCarlo::new(5);
        let acc: usize = mc.run(0, |_, _, a: &mut usize| *a += 1);
        assert_eq!(acc, 0);
    }

    #[test]
    fn vec_accumulator_zero_extends() {
        let mut a = vec![1usize, 2];
        Accumulate::merge(&mut a, vec![10, 10, 10]);
        assert_eq!(a, vec![11, 12, 10]);
        let mut b = vec![1usize, 2, 3];
        Accumulate::merge(&mut b, vec![5]);
        assert_eq!(b, vec![6, 2, 3]);
    }

    #[test]
    fn pair_accumulator_merges_elementwise() {
        let mut p = (1usize, vec![2usize]);
        Accumulate::merge(&mut p, (10, vec![0, 7]));
        assert_eq!(p, (11, vec![2, 7]));
    }

    #[test]
    fn derive_seed_separates_streams() {
        let a = derive_seed(42, 0);
        let b = derive_seed(42, 1);
        let c = derive_seed(43, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, derive_seed(42, 0));
    }

    #[test]
    fn parallel_map_preserves_order_at_any_thread_count() {
        let items: Vec<usize> = (0..37).collect();
        let want: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [0usize, 1, 2, 8, 64] {
            let got = parallel_map(&items, threads, |i, &x| {
                assert_eq!(i, x);
                x * x + 1
            });
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert_eq!(parallel_map(&empty, 4, |_, &x| x).len(), 0);
        assert_eq!(parallel_map(&[5u32], 4, |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn parallel_map_propagates_fallible_results() {
        let items = [1i32, -2, 3];
        let got = parallel_map(&items, 2, |_, &x| {
            if x < 0 { Err(format!("bad {x}")) } else { Ok(x * 10) }
        });
        assert_eq!(got[0], Ok(10));
        assert_eq!(got[1], Err("bad -2".to_string()));
        assert_eq!(got[2], Ok(30));
    }

    #[test]
    fn resolve_threads_semantics() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert!(available_threads() >= 1);
    }
}
