//! Secure-aggregation analysis (paper §IV-C, Lemma 1) plus the Gaussian
//! mechanism add-on the paper suggests for GC⁺ (Remark 8).
//!
//! Under the standard GC decoder the PS only sees *partial sums*
//! `Σ_k b_mk g_k`; Lemma 1 quantifies what it can still learn about an
//! individual `g_m` via context-dependent local mutual-information privacy
//! (CD-LMIP). For mutually independent Gaussian models with isotropic (or
//! diagonal) covariances the mutual information has the closed log-det
//! ratio form of eq. (20).
//!
//! Entry points: [`lmip_isotropic`] / [`lmip_diagonal`] for one
//! coefficient row, [`row_worst_leakage`] for the worst case over a code's
//! rows (the `cogc privacy` table), and [`lmip_with_gaussian_mechanism`]
//! for the Remark-8 noise add-on. All return leakage in *bits*;
//! `f64::INFINITY` marks a degenerate row that exposes its target exactly.

use crate::gc::GcCode;

/// Lemma 1 for isotropic covariances `Σ_k = σ_k² I_d`:
/// `μ = (d/2) · log2( Σ_k b_k² σ_k² / Σ_{k≠m} b_k² σ_k² )` bits.
///
/// `coeffs` are the partial-sum coefficients `b_mk` (a row of B),
/// `variances` the per-client model variances `σ_k²`, `target` the index
/// whose leakage is measured. Returns bits (`f64::INFINITY` when the
/// denominator vanishes — e.g. the coefficient row touches only the target).
pub fn lmip_isotropic(coeffs: &[f64], variances: &[f64], target: usize, d: usize) -> f64 {
    assert_eq!(coeffs.len(), variances.len());
    assert!(target < coeffs.len());
    if coeffs[target] == 0.0 {
        return 0.0; // target does not appear in the sum: zero leakage
    }
    let num: f64 = coeffs
        .iter()
        .zip(variances)
        .map(|(b, v)| b * b * v)
        .sum();
    let den: f64 = coeffs
        .iter()
        .zip(variances)
        .enumerate()
        .filter(|(k, _)| *k != target)
        .map(|(_, (b, v))| b * b * v)
        .sum();
    if den <= 0.0 {
        return f64::INFINITY;
    }
    (d as f64 / 2.0) * (num / den).log2()
}

/// Lemma 1 for diagonal covariances: per-dimension variances
/// `diag[k][j] = Σ_k[j,j]`. `μ = (1/2) Σ_j log2(num_j / den_j)` bits.
pub fn lmip_diagonal(coeffs: &[f64], diag: &[Vec<f64>], target: usize) -> f64 {
    assert_eq!(coeffs.len(), diag.len());
    let d = diag[0].len();
    let mut bits = 0.0;
    for j in 0..d {
        let num: f64 = coeffs
            .iter()
            .zip(diag)
            .map(|(b, v)| b * b * v[j])
            .sum();
        let den: f64 = coeffs
            .iter()
            .zip(diag)
            .enumerate()
            .filter(|(k, _)| *k != target)
            .map(|(_, (b, v))| b * b * v[j])
            .sum();
        if den <= 0.0 {
            return f64::INFINITY;
        }
        bits += 0.5 * (num / den).log2();
    }
    bits
}

/// Worst-case leakage of a code row: max over the clients in its support.
pub fn row_worst_leakage(code: &GcCode, row: usize, variances: &[f64], d: usize) -> f64 {
    let coeffs: Vec<f64> = (0..code.m).map(|k| code.b[(row, k)]).collect();
    (0..code.m)
        .filter(|&k| coeffs[k] != 0.0)
        .map(|k| lmip_isotropic(&coeffs, variances, k, d))
        .fold(0.0, f64::max)
}

/// GC⁺ with the Gaussian mechanism (Remark 8): adding N(0, σ_dp² I) noise
/// to each shared model bounds the per-partial-sum leakage at
/// `(d/2) log2(1 + b_m² σ_m² / (Σ_{k≠m} b_k² σ_k² + σ_dp² Σ_k b_k²))`.
pub fn lmip_with_gaussian_mechanism(
    coeffs: &[f64],
    variances: &[f64],
    target: usize,
    d: usize,
    sigma_dp2: f64,
) -> f64 {
    if coeffs[target] == 0.0 {
        return 0.0;
    }
    let coef2: f64 = coeffs.iter().map(|b| b * b).sum();
    let signal = coeffs[target] * coeffs[target] * variances[target];
    let noise: f64 = coeffs
        .iter()
        .zip(variances)
        .enumerate()
        .filter(|(k, _)| *k != target)
        .map(|(_, (b, v))| b * b * v)
        .sum::<f64>()
        + sigma_dp2 * coef2;
    if noise <= 0.0 {
        return f64::INFINITY;
    }
    (d as f64 / 2.0) * (1.0 + signal / noise).log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, Prop};
    use crate::util::rng::Rng;

    #[test]
    fn two_party_sum_leakage() {
        // s = g1 + g2, unit variances: mu = (d/2) log2(2) = d/2 bits.
        let mu = lmip_isotropic(&[1.0, 1.0], &[1.0, 1.0], 0, 10);
        assert_close(mu, 5.0, 1e-12);
    }

    #[test]
    fn more_cover_means_less_leakage() {
        // adding more independent terms to the sum reduces leakage of each
        let v = vec![1.0; 6];
        let mut prev = f64::INFINITY;
        for k in 2..=6 {
            let coeffs: Vec<f64> = (0..6).map(|i| if i < k { 1.0 } else { 0.0 }).collect();
            let mu = lmip_isotropic(&coeffs, &v, 0, 100);
            assert!(mu < prev, "k={k}: {mu} !< {prev}");
            prev = mu;
        }
    }

    #[test]
    fn solo_row_leaks_everything() {
        let mu = lmip_isotropic(&[2.0, 0.0], &[1.0, 1.0], 0, 4);
        assert!(mu.is_infinite());
        // and a client not in the sum leaks nothing
        assert_eq!(lmip_isotropic(&[0.0, 1.0], &[1.0, 1.0], 0, 4), 0.0);
    }

    #[test]
    fn diagonal_reduces_to_isotropic() {
        Prop::new(20).forall("diag == iso for equal dims", |rng, _| {
            let n = rng.range(2, 6);
            let d = rng.range(1, 8);
            let coeffs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let vars: Vec<f64> = (0..n).map(|_| rng.uniform(0.1, 3.0)).collect();
            let diag: Vec<Vec<f64>> = vars.iter().map(|&v| vec![v; d]).collect();
            let a = lmip_isotropic(&coeffs, &vars, 0, d);
            let b = lmip_diagonal(&coeffs, &diag, 0);
            if a.is_finite() {
                assert_close(a, b, 1e-9);
            } else {
                assert!(b.is_infinite());
            }
        });
    }

    #[test]
    fn gc_rows_bound_leakage_below_half_d() {
        // a GC partial sum over s+1 = 8 unit-variance models leaks at most
        // what the 2-party sum does, and decreases with s
        let mut rng = Rng::new(5);
        let code = crate::gc::GcCode::generate(10, 7, &mut rng);
        let v = vec![1.0; 10];
        for row in 0..10 {
            let mu = row_worst_leakage(&code, row, &v, 100);
            assert!(mu.is_finite() && mu > 0.0);
        }
    }

    #[test]
    fn gaussian_mechanism_monotone_in_noise() {
        let coeffs = [1.0, 0.5, -0.8, 0.0];
        let vars = [1.0, 2.0, 0.5, 1.0];
        let base = lmip_with_gaussian_mechanism(&coeffs, &vars, 0, 50, 0.0);
        let mut prev = base;
        for &s in &[0.5, 2.0, 10.0] {
            let mu = lmip_with_gaussian_mechanism(&coeffs, &vars, 0, 50, s);
            assert!(mu < prev, "noise {s}: {mu} !< {prev}");
            prev = mu;
        }
        // zero-noise version coincides with Lemma 1 (log(1+S/N) = log(num/den))
        let lemma1 = lmip_isotropic(&coeffs, &vars, 0, 50);
        assert_close(base, lemma1, 1e-9);
    }
}
