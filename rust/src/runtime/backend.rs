//! Backend selection: which execution substrate runs the models.
//!
//! - [`Backend::Pjrt`] — the production path: AOT HLO artifacts from
//!   `make artifacts`, compiled and executed on the PJRT CPU client.
//! - [`Backend::Native`] — the offline path: compact pure-rust models
//!   ([`super::native`]) with hand-rolled forward/backward. No artifacts,
//!   no bindings, bit-deterministic; every training figure runs on a clean
//!   checkout.
//!
//! [`Backend::auto`] picks PJRT when the artifacts and real bindings are
//! both available and falls back to native otherwise, so binaries work
//! unmodified in either environment. The CLI exposes the choice as
//! `--backend auto|native|pjrt`.

use super::coded::{CodedKernels, CombineImpl};
use super::engine::Engine;
use super::manifest::{default_artifacts_dir, Manifest, ModelSpec};
use super::model::ModelRuntime;
use super::native;

/// An execution backend: owns the (real or synthesized) manifest plus
/// whatever engine state model loading needs.
pub enum Backend {
    /// AOT artifacts executed through the PJRT CPU client.
    Pjrt { engine: Engine, manifest: Manifest },
    /// Native pure-rust models; the manifest is synthesized in-process.
    Native { manifest: Manifest },
}

impl Backend {
    /// The native backend — always available, nothing to load.
    pub fn native() -> Backend {
        Backend::Native { manifest: native::native_manifest() }
    }

    /// The PJRT backend; errors when `artifacts/manifest.json` is missing
    /// or the bindings are the offline stub.
    pub fn pjrt() -> anyhow::Result<Backend> {
        let (engine, manifest) = Backend::pjrt_parts()?;
        Ok(Backend::Pjrt { engine, manifest })
    }

    /// The engine + manifest pair [`Backend::pjrt`] wraps — the canonical
    /// "is PJRT usable?" probe for callers that drive the runtime layer
    /// directly (artifact benches/tests).
    pub fn pjrt_parts() -> anyhow::Result<(Engine, Manifest)> {
        let manifest = Manifest::load(&default_artifacts_dir())?;
        let engine = Engine::cpu()?;
        Ok((engine, manifest))
    }

    /// PJRT when available, native otherwise — the default for every
    /// binary so a clean offline checkout still trains. The fallback is
    /// silent on a clean checkout (no artifacts — nothing to diagnose) but
    /// logged when a built `artifacts/` exists and was still rejected, so a
    /// broken manifest or missing bindings cannot masquerade as a real
    /// artifact run.
    pub fn auto() -> Backend {
        match Backend::pjrt() {
            Ok(b) => b,
            Err(e) => {
                if default_artifacts_dir().join("manifest.json").exists() {
                    crate::warn!(
                        "PJRT backend rejected despite built artifacts ({e:#}); \
                         falling back to the native backend"
                    );
                }
                Backend::native()
            }
        }
    }

    /// Resolve a CLI `--backend` value.
    pub fn from_flag(flag: &str) -> anyhow::Result<Backend> {
        match flag {
            "auto" => Ok(Backend::auto()),
            "native" => Ok(Backend::native()),
            "pjrt" => Backend::pjrt(),
            other => anyhow::bail!("unknown --backend {other:?} (auto|native|pjrt)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Native { .. } => "native",
        }
    }

    pub fn platform(&self) -> String {
        match self {
            Backend::Pjrt { engine, .. } => engine.platform(),
            Backend::Native { .. } => "native (pure rust)".to_string(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        match self {
            Backend::Pjrt { manifest, .. } | Backend::Native { manifest } => manifest,
        }
    }

    /// Build the runtime for one model of this backend's manifest.
    pub fn load_model(&self, name: &str) -> anyhow::Result<ModelRuntime> {
        match self {
            Backend::Pjrt { engine, manifest } => ModelRuntime::load(engine, manifest, name),
            Backend::Native { .. } => ModelRuntime::native(name),
        }
    }

    /// Build the coded-combine kernels for one model. The Pallas kernels
    /// are PJRT artifacts, so the native backend always combines in pure
    /// rust regardless of the requested implementation.
    pub fn coded(&self, spec: &ModelSpec, imp: CombineImpl) -> anyhow::Result<CodedKernels> {
        match self {
            Backend::Pjrt { engine, manifest } => CodedKernels::load(engine, manifest, spec, imp),
            Backend::Native { manifest } => {
                Ok(CodedKernels::native(manifest.m, manifest.mt, spec.d))
            }
        }
    }
}

// The training-figure grids construct Trainers from one shared Backend on
// several worker threads (`parallel::parallel_map`); keep that contract
// checked at compile time. This is a deliberate tripwire for the ROADMAP
// item that swaps the vendored no-op `xla` stub for real PJRT bindings:
// real client handles are typically not auto-Send/Sync, so that swap MUST
// stop compiling here — the fix is per-worker engines (or confining grid
// parallelism to the native backend), never a force-`unsafe impl`.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Backend>();
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_serves_all_models() {
        let b = Backend::native();
        assert_eq!(b.name(), "native");
        assert!(b.platform().contains("native"));
        let man = b.manifest();
        assert_eq!(man.m, native::NATIVE_M);
        for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
            let model = b.load_model(name).unwrap();
            assert_eq!(model.backend_name(), "native");
            let kernels = b.coded(&model.spec, CombineImpl::Pallas).unwrap();
            // the Pallas impl silently degrades to native here
            assert_eq!(kernels.imp, CombineImpl::Native);
            assert_eq!(kernels.d, model.spec.d);
            assert_eq!(kernels.m, man.m);
            assert_eq!(kernels.mt, man.mt);
        }
        assert!(b.load_model("nope").is_err());
    }

    #[test]
    fn auto_backend_always_resolves() {
        // on an offline checkout this is native; with artifacts + real
        // bindings it is pjrt — either way it must produce a usable backend
        let b = Backend::auto();
        assert!(b.load_model("mnist_cnn").is_ok());
    }

    #[test]
    fn from_flag_parses() {
        assert_eq!(Backend::from_flag("native").unwrap().name(), "native");
        assert!(Backend::from_flag("auto").is_ok());
        assert!(Backend::from_flag("bogus").is_err());
    }
}
