//! Coded-combine runtime: the `W × S` products of gradient coding, either
//! through the AOT Pallas `coded_matmul` artifacts (the production path) or
//! a native rust fallback (odd shapes / ablation baseline).

use super::engine::{lit_f32, to_vec_f32, Engine, Executable};
use super::manifest::{Manifest, ModelSpec};
use crate::linalg::Matrix;

/// Which combine implementation to use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CombineImpl {
    /// AOT Pallas kernel through PJRT (requires manifest shapes).
    Pallas,
    /// Pure-rust combine (any shape; ablation baseline).
    Native,
}

/// Compiled coded-combine executables for one model size D.
pub struct CodedKernels {
    /// `[M, M] @ [M, D]` — gradient-sharing encode (partial sums).
    encode: Option<Executable>,
    /// `[M, MT] @ [MT, D]` — combinator / GC⁺ decode transform.
    decode: Option<Executable>,
    pub m: usize,
    pub mt: usize,
    pub d: usize,
    pub imp: CombineImpl,
}

impl CodedKernels {
    pub fn load(
        engine: &Engine,
        man: &Manifest,
        spec: &ModelSpec,
        imp: CombineImpl,
    ) -> anyhow::Result<CodedKernels> {
        let (encode, decode) = match imp {
            CombineImpl::Pallas => (
                Some(engine.load(&man.artifact_path(spec, "encode")?)?),
                Some(engine.load(&man.artifact_path(spec, "decode")?)?),
            ),
            CombineImpl::Native => (None, None),
        };
        Ok(CodedKernels { encode, decode, m: man.m, mt: man.mt, d: spec.d, imp })
    }

    /// Native-only kernels (no artifacts needed), any shape.
    pub fn native(m: usize, mt: usize, d: usize) -> CodedKernels {
        CodedKernels { encode: None, decode: None, m, mt, d, imp: CombineImpl::Native }
    }

    /// Encode: partial sums `S = B̂ · G` (paper eq. (8)).
    /// `w` is `M×M` (f64 coefficients), `grads` is row-major `M×D` f32.
    pub fn encode(&self, w: &Matrix, grads: &[f32]) -> anyhow::Result<Vec<f32>> {
        let prepared = self.prepare_grads(grads)?;
        self.encode_prepared(w, &prepared, grads)
    }

    /// Build the device literal for the gradient stack once; a CoGC round
    /// encodes the *same* gradients under a fresh coefficient mask per
    /// communication attempt, so callers should reuse this across attempts
    /// (saves an M·D f32 host->literal copy per attempt — see §Perf).
    pub fn prepare_grads(&self, grads: &[f32]) -> anyhow::Result<Option<xla::Literal>> {
        assert_eq!(grads.len(), self.m * self.d);
        match (&self.encode, self.imp) {
            (Some(_), CombineImpl::Pallas) => {
                Ok(Some(lit_f32(grads, &[self.m, self.d])?))
            }
            _ => Ok(None),
        }
    }

    /// Encode against a prepared gradient literal (`grads` is the same
    /// buffer, used by the native fallback).
    pub fn encode_prepared(
        &self,
        w: &Matrix,
        prepared: &Option<xla::Literal>,
        grads: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        assert_eq!(w.rows, self.m);
        assert_eq!(w.cols, self.m);
        match (&self.encode, self.imp, prepared) {
            (Some(exe), CombineImpl::Pallas, Some(lit)) => {
                let wf: Vec<f32> = w.data.iter().map(|&x| x as f32).collect();
                let wl = lit_f32(&wf, &[w.rows, w.cols])?;
                let out = exe.run_refs(&[&wl, lit])?;
                to_vec_f32(&out[0])
            }
            _ => Ok(native_combine(w, grads, self.d)),
        }
    }

    /// Decode: `O = W · S` with `W` `M×MT` (combinator rows or GC⁺ transform,
    /// zero-padded) and `S` the stacked payload rows padded to `MT×D`.
    pub fn decode(&self, w: &Matrix, stacked: &[f32]) -> anyhow::Result<Vec<f32>> {
        assert_eq!(w.rows, self.m);
        assert_eq!(w.cols, self.mt);
        assert_eq!(stacked.len(), self.mt * self.d);
        match (&self.decode, self.imp) {
            (Some(exe), CombineImpl::Pallas) => run_coded(exe, w, stacked, self.d),
            _ => Ok(native_combine(w, stacked, self.d)),
        }
    }
}

fn run_coded(exe: &Executable, w: &Matrix, s: &[f32], d: usize) -> anyhow::Result<Vec<f32>> {
    let wf: Vec<f32> = w.data.iter().map(|&x| x as f32).collect();
    let wl = lit_f32(&wf, &[w.rows, w.cols])?;
    let sl = lit_f32(s, &[w.cols, d])?;
    let out = exe.run(&[wl, sl])?;
    to_vec_f32(&out[0])
}

/// Row-major native combine: `out[r, :] = Σ_k w[r,k] * s[k, :]`.
/// Skips zero coefficients — GC weight matrices are sparse (cyclic support /
/// zero padding), which makes this surprisingly competitive; the hotpath
/// bench compares it against the Pallas path.
pub fn native_combine(w: &Matrix, s: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; w.rows * d];
    for r in 0..w.rows {
        let orow = &mut out[r * d..(r + 1) * d];
        for k in 0..w.cols {
            let coef = w[(r, k)] as f32;
            if coef == 0.0 {
                continue;
            }
            let srow = &s[k * d..(k + 1) * d];
            for (o, v) in orow.iter_mut().zip(srow) {
                *o += coef * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_combine_matches_matmul() {
        let mut rng = Rng::new(1);
        let w = Matrix::from_fn(4, 6, |_, _| if rng.bernoulli(0.5) { rng.normal() } else { 0.0 });
        let d = 33;
        let s: Vec<f32> = (0..6 * d).map(|_| rng.normal() as f32).collect();
        let got = native_combine(&w, &s, d);
        // reference through Matrix::matmul
        let sm = Matrix::from_fn(6, d, |i, j| s[i * d + j] as f64);
        let want = w.matmul(&sm);
        for r in 0..4 {
            for j in 0..d {
                assert!((got[r * d + j] as f64 - want[(r, j)]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn native_kernels_any_shape() {
        let k = CodedKernels::native(3, 6, 10);
        let w = Matrix::identity(3);
        let grads: Vec<f32> = (0..30).map(|x| x as f32).collect();
        let out = k.encode(&w, &grads).unwrap();
        assert_eq!(out, grads);
    }
}
