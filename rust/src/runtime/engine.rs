//! PJRT engine: load HLO-text artifacts, compile once, execute many.
//!
//! Follows the /opt/xla-example/load_hlo reference: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Every artifact was lowered with
//! `return_tuple=True`, so results decompose from a single tuple literal.

use std::path::Path;
use std::sync::Arc;

/// Shared PJRT CPU client. Compiled executables keep the client alive.
pub struct Engine {
    client: Arc<xla::PjRtClient>,
}

impl Engine {
    pub fn cpu() -> anyhow::Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, _client: self.client.clone(), name: path.display().to_string() })
    }
}

/// A compiled artifact. `run` takes input literals positionally and returns
/// the decomposed output tuple.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    _client: Arc<xla::PjRtClient>,
    pub name: String,
}

impl Executable {
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with borrowed inputs (avoids deep-copying cached literals —
    /// the per-round gradient stack is reused across attempts).
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// -- literal helpers -----------------------------------------------------------

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn lit_f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn lit_u32_scalar(x: u32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a literal into a Vec<f32>.
pub fn to_vec_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_f32(lit: &xla::Literal) -> anyhow::Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
