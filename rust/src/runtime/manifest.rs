//! `artifacts/manifest.json` parsing: what the AOT build produced.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "uniform_fanin" | "zeros" | "ones" | "normal:<std>"
    pub init: String,
    pub fan_in: usize,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum InputKind {
    /// f32 images `[B, C, H, W]` with i32 labels `[B]`.
    Image,
    /// i32 token ids `[B, T]` with i32 targets `[B, T]`.
    Tokens,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Flat parameter count D.
    pub d: usize,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub kind: InputKind,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    /// artifact tag -> file name (train/eval/encode/decode/sgd)
    pub artifacts: BTreeMap<String, String>,
    /// artifact tag -> ENTRY parameter count (jax strips unused args, e.g.
    /// the dropout seed of models without dropout).
    pub arities: BTreeMap<String, usize>,
}

impl ModelSpec {
    pub fn x_elems(&self) -> usize {
        self.x_shape.iter().product()
    }

    pub fn y_elems(&self) -> usize {
        self.y_shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Number of clients M the coded artifacts were built for.
    pub m: usize,
    /// Max stacked attempts t_r.
    pub tr: usize,
    /// Stacked row capacity M * t_r of the decode artifact.
    pub mt: usize,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e}; run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest: {e}"))?;
        let m = j.req("m")?.as_usize().unwrap();
        let tr = j.req("tr")?.as_usize().unwrap();
        let mt = j.req("mt")?.as_usize().unwrap();
        let mut models = BTreeMap::new();
        for (name, mj) in j.req("models")?.as_obj().unwrap() {
            let kind_str = mj.req("meta")?.req("kind")?.as_str().unwrap().to_string();
            let kind = match kind_str.as_str() {
                "classifier" => InputKind::Image,
                "lm" => InputKind::Tokens,
                other => anyhow::bail!("unknown model kind {other:?}"),
            };
            let num_classes = match kind {
                InputKind::Image => mj.req("meta")?.req("num_classes")?.as_usize().unwrap(),
                InputKind::Tokens => mj.req("meta")?.req("vocab")?.as_usize().unwrap(),
            };
            let params = mj
                .req("params")?
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| ParamSpec {
                    name: p.req("name").unwrap().as_str().unwrap().to_string(),
                    shape: p
                        .req("shape")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|x| x.as_usize().unwrap())
                        .collect(),
                    init: p.req("init").unwrap().as_str().unwrap().to_string(),
                    fan_in: p.req("fan_in").unwrap().as_usize().unwrap(),
                })
                .collect();
            let artifacts = mj
                .req("artifacts")?
                .as_obj()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.as_str().unwrap().to_string()))
                .collect();
            let arities = mj
                .req("arities")?
                .as_obj()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.as_usize().unwrap()))
                .collect();
            let spec = ModelSpec {
                name: name.clone(),
                d: mj.req("d")?.as_usize().unwrap(),
                batch: mj.req("batch")?.as_usize().unwrap(),
                x_shape: mj
                    .req("x_shape")?
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|x| x.as_usize().unwrap())
                    .collect(),
                y_shape: mj
                    .req("y_shape")?
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|x| x.as_usize().unwrap())
                    .collect(),
                kind,
                num_classes,
                params,
                artifacts,
                arities,
            };
            anyhow::ensure!(
                spec.params.iter().map(|p| p.size()).sum::<usize>() == spec.d,
                "param spec sizes do not sum to D for {name}"
            );
            models.insert(name.clone(), spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), m, tr, mt, models })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} not in manifest ({:?})", self.models.keys()))
    }

    pub fn artifact_path(&self, spec: &ModelSpec, tag: &str) -> anyhow::Result<PathBuf> {
        let file = spec
            .artifacts
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("artifact {tag:?} missing for {}", spec.name))?;
        Ok(self.dir.join(file))
    }
}

/// Locate the artifacts directory: `$COGC_ARTIFACTS` or `./artifacts`
/// (walking up from cwd so tests can run from subdirectories).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("COGC_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest() {
        // requires `make artifacts` (the Makefile test target guarantees it)
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.m, 10);
        assert_eq!(man.mt, man.m * man.tr);
        let mnist = man.model("mnist_cnn").unwrap();
        assert_eq!(mnist.d, 51480);
        assert_eq!(mnist.kind, InputKind::Image);
        assert_eq!(mnist.x_shape, vec![32, 1, 28, 28]);
        for tag in ["train", "eval", "encode", "decode", "sgd"] {
            let p = man.artifact_path(mnist, tag).unwrap();
            assert!(p.exists(), "{p:?} missing");
        }
        let tf = man.model("transformer").unwrap();
        assert_eq!(tf.kind, InputKind::Tokens);
        assert!(man.model("nope").is_err());
    }
}
