//! Model execution runtime, two backends behind one API:
//!
//! - **PJRT** ([`engine`], [`manifest`]): loads the AOT HLO-text artifacts
//!   (`make artifacts`) and executes them on the CPU PJRT client. Python
//!   never runs here.
//! - **Native** ([`native`]): compact pure-rust models with hand-rolled
//!   forward/backward — no artifacts, no bindings, runs on a clean offline
//!   checkout.
//!
//! [`Backend`] selects between them (auto-detecting by default);
//! [`ModelRuntime`] and [`CodedKernels`] are the backend-agnostic surfaces
//! the coordinator trains through.

pub mod backend;
pub mod coded;
pub mod engine;
pub mod manifest;
pub mod model;
pub mod native;

pub use backend::Backend;
pub use coded::{CodedKernels, CombineImpl};
pub use engine::Engine;
pub use manifest::{default_artifacts_dir, InputKind, Manifest, ModelSpec};
pub use model::{Batch, ModelRuntime};
pub use native::{NativeArch, NativeModel};
