//! PJRT runtime: loads the AOT HLO-text artifacts (`make artifacts`) and
//! executes them on the CPU PJRT client. Python never runs here.

pub mod coded;
pub mod engine;
pub mod manifest;
pub mod model;

pub use coded::{CodedKernels, CombineImpl};
pub use engine::Engine;
pub use manifest::{default_artifacts_dir, InputKind, Manifest, ModelSpec};
pub use model::{Batch, ModelRuntime};
