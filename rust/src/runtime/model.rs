//! Model runtime: typed wrappers over the AOT train/eval/sgd artifacts.

use super::engine::{
    lit_f32, lit_f32_scalar, lit_i32, lit_u32_scalar, to_f32, to_vec_f32, Engine, Executable,
};
use super::manifest::{InputKind, Manifest, ModelSpec};
use crate::util::rng::Rng;

/// One training/eval batch in the layout the artifacts expect.
#[derive(Clone, Debug)]
pub enum Batch {
    /// f32 images `[B*C*H*W]` + labels `[B]`.
    Image { x: Vec<f32>, y: Vec<i32> },
    /// i32 tokens `[B*T]` + targets `[B*T]`.
    Tokens { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    pub fn kind(&self) -> InputKind {
        match self {
            Batch::Image { .. } => InputKind::Image,
            Batch::Tokens { .. } => InputKind::Tokens,
        }
    }
}

/// Compiled executables + metadata for one model.
pub struct ModelRuntime {
    pub spec: ModelSpec,
    train: Executable,
    eval: Executable,
    sgd: Executable,
}

impl ModelRuntime {
    pub fn load(engine: &Engine, man: &Manifest, name: &str) -> anyhow::Result<ModelRuntime> {
        let spec = man.model(name)?.clone();
        let train = engine.load(&man.artifact_path(&spec, "train")?)?;
        let eval = engine.load(&man.artifact_path(&spec, "eval")?)?;
        let sgd = engine.load(&man.artifact_path(&spec, "sgd")?)?;
        Ok(ModelRuntime { spec, train, eval, sgd })
    }

    /// Initialize a flat parameter vector from the manifest's per-tensor
    /// init schemes (mirrors `python/compile/models/common.py::init_flat`).
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.spec.d);
        for p in &self.spec.params {
            let n = p.size();
            match p.init.as_str() {
                "zeros" => out.extend(std::iter::repeat(0.0f32).take(n)),
                "ones" => out.extend(std::iter::repeat(1.0f32).take(n)),
                "uniform_fanin" => {
                    let bound = 1.0 / (p.fan_in.max(1) as f64).sqrt();
                    out.extend((0..n).map(|_| rng.uniform(-bound, bound) as f32));
                }
                init if init.starts_with("normal:") => {
                    let std: f64 = init[7..].parse().expect("bad normal std in manifest");
                    out.extend((0..n).map(|_| rng.normal_ms(0.0, std) as f32));
                }
                other => panic!("unknown init scheme {other:?} in manifest"),
            }
        }
        debug_assert_eq!(out.len(), self.spec.d);
        out
    }

    fn xy_literals(&self, batch: &Batch) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(batch.kind() == self.spec.kind, "batch kind mismatch");
        Ok(match batch {
            Batch::Image { x, y } => (
                lit_f32(x, &self.spec.x_shape)?,
                lit_i32(y, &self.spec.y_shape)?,
            ),
            Batch::Tokens { x, y } => (
                lit_i32(x, &self.spec.x_shape)?,
                lit_i32(y, &self.spec.y_shape)?,
            ),
        })
    }

    /// One local SGD step (paper eq. (2)); returns (new params, loss).
    pub fn train_step(
        &self,
        params: &[f32],
        batch: &Batch,
        seed: u32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, f32)> {
        let (x, y) = self.xy_literals(batch)?;
        let p = lit_f32(params, &[self.spec.d])?;
        // models without dropout lower to 4 entry params (seed stripped)
        let arity = self.spec.arities.get("train").copied().unwrap_or(5);
        let out = if arity == 5 {
            self.train
                .run(&[p, x, y, lit_u32_scalar(seed), lit_f32_scalar(lr)])?
        } else {
            self.train.run(&[p, x, y, lit_f32_scalar(lr)])?
        };
        anyhow::ensure!(out.len() == 2, "train artifact returned {} outputs", out.len());
        Ok((to_vec_f32(&out[0])?, to_f32(&out[1])?))
    }

    /// Evaluate a batch; returns (mean loss, #correct).
    pub fn eval_step(&self, params: &[f32], batch: &Batch) -> anyhow::Result<(f32, f32)> {
        let (x, y) = self.xy_literals(batch)?;
        let p = lit_f32(params, &[self.spec.d])?;
        let out = self.eval.run(&[p, x, y])?;
        anyhow::ensure!(out.len() == 2, "eval artifact returned {} outputs", out.len());
        Ok((to_f32(&out[0])?, to_f32(&out[1])?))
    }

    /// PS-side fused update `p − lr·g` through the L1 Pallas kernel
    /// (`lr = −1` turns it into the additive global update of eq. (10)).
    pub fn sgd_apply(&self, params: &[f32], grad: &[f32], lr: f32) -> anyhow::Result<Vec<f32>> {
        let p = lit_f32(params, &[self.spec.d])?;
        let g = lit_f32(grad, &[self.spec.d])?;
        let out = self.sgd.run(&[p, g, lit_f32_scalar(lr)])?;
        Ok(to_vec_f32(&out[0])?)
    }

    /// Per-example predictions are not exposed; accuracy comes from
    /// `eval_step`'s correct count over the fixed eval batch shape.
    pub fn batch_size(&self) -> usize {
        self.spec.batch
    }
}
