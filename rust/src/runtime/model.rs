//! Model runtime: one typed train/eval/sgd surface over both backends —
//! the AOT PJRT artifacts and the native pure-rust models.

use super::engine::{
    lit_f32, lit_f32_scalar, lit_i32, lit_u32_scalar, to_f32, to_vec_f32, Engine, Executable,
};
use super::manifest::{InputKind, Manifest, ModelSpec};
use super::native::{self, NativeModel};
use crate::util::rng::Rng;

/// One training/eval batch in the layout the models expect.
#[derive(Clone, Debug)]
pub enum Batch {
    /// f32 images `[B*C*H*W]` + labels `[B]`.
    Image { x: Vec<f32>, y: Vec<i32> },
    /// i32 tokens `[B*T]` + targets `[B*T]`.
    Tokens { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    pub fn kind(&self) -> InputKind {
        match self {
            Batch::Image { .. } => InputKind::Image,
            Batch::Tokens { .. } => InputKind::Tokens,
        }
    }
}

/// Backend-specific execution state for one model.
enum Imp {
    /// Compiled PJRT executables (train/eval/sgd artifacts).
    Pjrt { train: Executable, eval: Executable, sgd: Executable },
    /// Hand-rolled pure-rust forward/backward.
    Native(NativeModel),
}

/// Executable model + metadata, backend-agnostic. Built through
/// [`ModelRuntime::load`] (PJRT artifacts) or [`ModelRuntime::native`]
/// (pure rust, any offline checkout).
pub struct ModelRuntime {
    pub spec: ModelSpec,
    imp: Imp,
}

impl ModelRuntime {
    /// Load the AOT artifacts of `name` and compile them on `engine`.
    pub fn load(engine: &Engine, man: &Manifest, name: &str) -> anyhow::Result<ModelRuntime> {
        let spec = man.model(name)?.clone();
        let train = engine.load(&man.artifact_path(&spec, "train")?)?;
        let eval = engine.load(&man.artifact_path(&spec, "eval")?)?;
        let sgd = engine.load(&man.artifact_path(&spec, "sgd")?)?;
        Ok(ModelRuntime { spec, imp: Imp::Pjrt { train, eval, sgd } })
    }

    /// Build the native pure-rust model registered under `name`.
    pub fn native(name: &str) -> anyhow::Result<ModelRuntime> {
        let (spec, model) = native::native_model(name).ok_or_else(|| {
            anyhow::anyhow!("model {name:?} has no native implementation")
        })?;
        Ok(ModelRuntime { spec, imp: Imp::Native(model) })
    }

    /// Which backend executes this model ("pjrt" / "native").
    pub fn backend_name(&self) -> &'static str {
        match self.imp {
            Imp::Pjrt { .. } => "pjrt",
            Imp::Native(_) => "native",
        }
    }

    /// Initialize a flat parameter vector from the spec's per-tensor
    /// init schemes (mirrors `python/compile/models/common.py::init_flat`;
    /// the native specs use the same scheme vocabulary).
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.spec.d);
        for p in &self.spec.params {
            let n = p.size();
            match p.init.as_str() {
                "zeros" => out.extend(std::iter::repeat(0.0f32).take(n)),
                "ones" => out.extend(std::iter::repeat(1.0f32).take(n)),
                "uniform_fanin" => {
                    let bound = 1.0 / (p.fan_in.max(1) as f64).sqrt();
                    out.extend((0..n).map(|_| rng.uniform(-bound, bound) as f32));
                }
                init if init.starts_with("normal:") => {
                    let std: f64 = init[7..].parse().expect("bad normal std in manifest");
                    out.extend((0..n).map(|_| rng.normal_ms(0.0, std) as f32));
                }
                other => panic!("unknown init scheme {other:?} in manifest"),
            }
        }
        debug_assert_eq!(out.len(), self.spec.d);
        out
    }

    fn xy_literals(&self, batch: &Batch) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(batch.kind() == self.spec.kind, "batch kind mismatch");
        Ok(match batch {
            Batch::Image { x, y } => (
                lit_f32(x, &self.spec.x_shape)?,
                lit_i32(y, &self.spec.y_shape)?,
            ),
            Batch::Tokens { x, y } => (
                lit_i32(x, &self.spec.x_shape)?,
                lit_i32(y, &self.spec.y_shape)?,
            ),
        })
    }

    /// One local SGD step (paper eq. (2)); returns (new params, loss).
    /// `seed` drives dropout in the PJRT artifacts; the native models have
    /// no dropout and ignore it (their step is a pure function of inputs).
    pub fn train_step(
        &self,
        params: &[f32],
        batch: &Batch,
        seed: u32,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, f32)> {
        match &self.imp {
            Imp::Pjrt { train, .. } => {
                let (x, y) = self.xy_literals(batch)?;
                let p = lit_f32(params, &[self.spec.d])?;
                // models without dropout lower to 4 entry params (seed stripped)
                let arity = self.spec.arities.get("train").copied().unwrap_or(5);
                let out = if arity == 5 {
                    train.run(&[p, x, y, lit_u32_scalar(seed), lit_f32_scalar(lr)])?
                } else {
                    train.run(&[p, x, y, lit_f32_scalar(lr)])?
                };
                anyhow::ensure!(out.len() == 2, "train artifact returned {} outputs", out.len());
                Ok((to_vec_f32(&out[0])?, to_f32(&out[1])?))
            }
            Imp::Native(model) => model.train_step(params, batch, lr),
        }
    }

    /// Evaluate a batch; returns (mean loss, #correct).
    pub fn eval_step(&self, params: &[f32], batch: &Batch) -> anyhow::Result<(f32, f32)> {
        match &self.imp {
            Imp::Pjrt { eval, .. } => {
                let (x, y) = self.xy_literals(batch)?;
                let p = lit_f32(params, &[self.spec.d])?;
                let out = eval.run(&[p, x, y])?;
                anyhow::ensure!(out.len() == 2, "eval artifact returned {} outputs", out.len());
                Ok((to_f32(&out[0])?, to_f32(&out[1])?))
            }
            Imp::Native(model) => model.eval_step(params, batch),
        }
    }

    /// PS-side fused update `p − lr·g` — the L1 Pallas kernel under PJRT,
    /// a rust axpy natively (`lr = −1` turns it into the additive global
    /// update of eq. (10)).
    pub fn sgd_apply(&self, params: &[f32], grad: &[f32], lr: f32) -> anyhow::Result<Vec<f32>> {
        match &self.imp {
            Imp::Pjrt { sgd, .. } => {
                let p = lit_f32(params, &[self.spec.d])?;
                let g = lit_f32(grad, &[self.spec.d])?;
                let out = sgd.run(&[p, g, lit_f32_scalar(lr)])?;
                Ok(to_vec_f32(&out[0])?)
            }
            Imp::Native(_) => {
                anyhow::ensure!(params.len() == grad.len(), "params/grad length mismatch");
                Ok(native::sgd_apply(params, grad, lr))
            }
        }
    }

    /// Per-example predictions are not exposed; accuracy comes from
    /// `eval_step`'s correct count over the fixed eval batch shape.
    pub fn batch_size(&self) -> usize {
        self.spec.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_models_load_and_step() {
        let mut rng = Rng::new(1);
        for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
            let model = ModelRuntime::native(name).unwrap();
            assert_eq!(model.backend_name(), "native");
            let params = model.init_params(&mut rng);
            assert_eq!(params.len(), model.spec.d);
            let spec = &model.spec;
            let batch = crate::testing::fake_batch(spec, &mut rng);
            let (new_params, loss) = model.train_step(&params, &batch, 0, 0.01).unwrap();
            assert_eq!(new_params.len(), params.len());
            assert!(loss.is_finite() && loss > 0.0, "{name}: loss {loss}");
            assert_ne!(new_params, params, "{name}: params did not move");
            let (eloss, correct) = model.eval_step(&params, &batch).unwrap();
            assert!(eloss.is_finite());
            assert!(correct >= 0.0);
            let g: Vec<f32> = (0..spec.d).map(|_| rng.normal() as f32).collect();
            let upd = model.sgd_apply(&params, &g, 0.5).unwrap();
            for i in (0..spec.d).step_by(997) {
                assert!((upd[i] - (params[i] - 0.5 * g[i])).abs() < 1e-6);
            }
        }
        assert!(ModelRuntime::native("nope").is_err());
    }

    #[test]
    fn init_params_follow_native_schemes() {
        let model = ModelRuntime::native("mnist_cnn").unwrap();
        let mut rng = Rng::new(5);
        let params = model.init_params(&mut rng);
        let mut off = 0;
        for p in &model.spec.params {
            let n = p.size();
            let slice = &params[off..off + n];
            if p.init == "uniform_fanin" {
                let bound = 1.0 / (p.fan_in as f32).sqrt();
                assert!(
                    slice.iter().all(|&x| x.abs() <= bound + 1e-6),
                    "{} exceeds fan-in bound",
                    p.name
                );
            }
            off += n;
        }
        assert_eq!(off, model.spec.d);
    }
}
