//! Native pure-rust model backend: hand-rolled f32 forward/backward for the
//! training figures, no PJRT artifacts required.
//!
//! The paper's §VII experiments probe *aggregation under unreliable links*,
//! not vision SOTA — what the training harnesses need is a differentiable
//! model whose accuracy degrades when aggregation is biased or missing. The
//! native backend provides exactly that with two tiny architectures:
//!
//! - **image path**: a one-hidden-layer ReLU MLP over flattened images with
//!   NLL loss (stand-in for the Table-II CNNs);
//! - **token path**: an embedding + linear next-token LM (stand-in for the
//!   decoder-only transformer).
//!
//! Parameters live in one flat `f32[D]` vector in spec order — the same
//! model-as-a-vector abstraction the AOT artifacts use — and the init
//! schemes are the manifest vocabulary of
//! `python/compile/models/common.py` (`uniform_fanin`, `normal:<std>`,
//! `zeros`, `ones`), so [`super::ModelRuntime::init_params`] works
//! unchanged. Model *names* are kept identical to the artifact manifest
//! (`mnist_cnn` / `cifar_cnn` / `transformer`) so every figure harness,
//! CLI invocation, and `TrainConfig` default runs on either backend.
//!
//! Everything here is plain sequential f32 arithmetic over owned buffers:
//! bit-deterministic for a fixed parameter/batch stream, `Send + Sync`, and
//! therefore safe to fan out across the training-figure worker pool
//! (`parallel::parallel_map`).

use super::manifest::{InputKind, Manifest, ModelSpec, ParamSpec};
use super::model::Batch;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Clients the native backend simulates (matches the AOT artifact build).
pub const NATIVE_M: usize = 10;
/// Max stacked GC⁺ attempts t_r (matches the AOT artifact build).
pub const NATIVE_TR: usize = 2;

/// Architecture of a native model. Dimensions mirror the param layout of
/// the generated [`ModelSpec`] exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NativeArch {
    /// `x[B, n_in] → relu(x·W1 + b1) → ·W2 + b2 → NLL` classifier.
    Mlp { n_in: usize, hidden: usize, classes: usize },
    /// `E[x] · W + b → NLL` next-token LM over flattened `B·T` positions.
    EmbedLm { vocab: usize, dim: usize },
}

/// A native model: the architecture plus the fwd/bwd passes.
#[derive(Clone, Copy, Debug)]
pub struct NativeModel {
    pub arch: NativeArch,
}

impl NativeModel {
    /// Flat parameter count D.
    pub fn d(&self) -> usize {
        match self.arch {
            NativeArch::Mlp { n_in, hidden, classes } => {
                n_in * hidden + hidden + hidden * classes + classes
            }
            NativeArch::EmbedLm { vocab, dim } => vocab * dim + dim * vocab + vocab,
        }
    }

    /// One SGD step `p ← p − lr·∇L(p)`; returns (new params, batch loss).
    /// Native models have no dropout, so there is no step seed: the result
    /// is a pure function of `(params, batch, lr)`.
    pub fn train_step(
        &self,
        params: &[f32],
        batch: &Batch,
        lr: f32,
    ) -> anyhow::Result<(Vec<f32>, f32)> {
        let (loss, _, grad) = self.pass(params, batch, true)?;
        let grad = grad.expect("pass(want_grad=true) returns a gradient");
        Ok((sgd_apply(params, &grad, lr), loss))
    }

    /// Evaluate a batch; returns (mean loss, #correct predictions).
    pub fn eval_step(&self, params: &[f32], batch: &Batch) -> anyhow::Result<(f32, f32)> {
        let (loss, correct, _) = self.pass(params, batch, false)?;
        Ok((loss, correct as f32))
    }

    /// Shared forward(+backward) pass: (mean NLL, #correct, gradient).
    fn pass(
        &self,
        params: &[f32],
        batch: &Batch,
        want_grad: bool,
    ) -> anyhow::Result<(f32, usize, Option<Vec<f32>>)> {
        anyhow::ensure!(params.len() == self.d(), "params/arch size mismatch");
        match (self.arch, batch) {
            (NativeArch::Mlp { n_in, hidden, classes }, Batch::Image { x, y }) => {
                let rows = y.len();
                anyhow::ensure!(x.len() == rows * n_in, "image batch shape mismatch");
                anyhow::ensure!(
                    y.iter().all(|&l| (0..classes as i32).contains(&l)),
                    "image label out of range [0, {classes})"
                );
                let (w1, rest) = params.split_at(n_in * hidden);
                let (b1, rest) = rest.split_at(hidden);
                let (w2, b2) = rest.split_at(hidden * classes);

                let z1 = affine(x, rows, n_in, w1, b1, hidden);
                let a1: Vec<f32> = z1.iter().map(|&v| v.max(0.0)).collect();
                let z2 = affine(&a1, rows, hidden, w2, b2, classes);
                let (loss, dz2, correct) = softmax_xent(&z2, y, classes);
                if !want_grad {
                    return Ok((loss, correct, None));
                }

                let mut grad = vec![0.0f32; params.len()];
                let (gw1, grest) = grad.split_at_mut(n_in * hidden);
                let (gb1, grest) = grest.split_at_mut(hidden);
                let (gw2, gb2) = grest.split_at_mut(hidden * classes);
                accum_matgrad(&a1, rows, hidden, &dz2, classes, gw2, gb2);
                let mut dz1 = matmul_bt(&dz2, rows, classes, w2, hidden);
                for (v, &z) in dz1.iter_mut().zip(&z1) {
                    if z <= 0.0 {
                        *v = 0.0;
                    }
                }
                accum_matgrad(x, rows, n_in, &dz1, hidden, gw1, gb1);
                Ok((loss, correct, Some(grad)))
            }
            (NativeArch::EmbedLm { vocab, dim }, Batch::Tokens { x, y }) => {
                let rows = x.len();
                anyhow::ensure!(y.len() == rows, "token batch shape mismatch");
                anyhow::ensure!(
                    y.iter().all(|&t| (0..vocab as i32).contains(&t)),
                    "target token out of vocab [0, {vocab})"
                );
                let (emb, rest) = params.split_at(vocab * dim);
                let (w, b) = rest.split_at(dim * vocab);

                // gather: e[r, :] = E[x_r, :]
                let mut e = vec![0.0f32; rows * dim];
                for (r, &t) in x.iter().enumerate() {
                    let t = t as usize;
                    anyhow::ensure!(t < vocab, "token id {t} out of vocab {vocab}");
                    e[r * dim..(r + 1) * dim].copy_from_slice(&emb[t * dim..(t + 1) * dim]);
                }
                let z = affine(&e, rows, dim, w, b, vocab);
                let (loss, dz, correct) = softmax_xent(&z, y, vocab);
                if !want_grad {
                    return Ok((loss, correct, None));
                }

                let mut grad = vec![0.0f32; params.len()];
                let (gemb, grest) = grad.split_at_mut(vocab * dim);
                let (gw, gb) = grest.split_at_mut(dim * vocab);
                accum_matgrad(&e, rows, dim, &dz, vocab, gw, gb);
                // scatter-add: dE[x_r, :] += de[r, :]
                let de = matmul_bt(&dz, rows, vocab, w, dim);
                for (r, &t) in x.iter().enumerate() {
                    let t = t as usize;
                    let row = &de[r * dim..(r + 1) * dim];
                    let out = &mut gemb[t * dim..(t + 1) * dim];
                    for (o, v) in out.iter_mut().zip(row) {
                        *o += v;
                    }
                }
                Ok((loss, correct, Some(grad)))
            }
            _ => anyhow::bail!("batch kind does not match native architecture"),
        }
    }
}

/// Fused elementwise update `p − lr·g` — the native counterpart of the
/// Pallas `sgd_apply` artifact (`lr = −1` is the additive global update of
/// paper eq. (10)).
pub fn sgd_apply(params: &[f32], grad: &[f32], lr: f32) -> Vec<f32> {
    debug_assert_eq!(params.len(), grad.len());
    params.iter().zip(grad).map(|(p, g)| p - lr * g).collect()
}

// -- dense f32 kernels ---------------------------------------------------------

pub use kernels::{accum_matgrad, affine, matmul_bt};

/// Dense f32 kernels of the native backend — the per-step compute surface
/// of every training figure.
///
/// Each kernel ships in two forms:
///
/// - the production form (`affine`, `accum_matgrad`, `matmul_bt`):
///   **4-wide unrolled over `n_in`** so each pass touches four weight rows
///   per sweep of the output/delta row (4× less output-row traffic, four
///   independent accumulator streams the autovectorizer turns into SIMD),
///   **cache-blocked over `n_out`** so one output tile plus its four
///   weight-row tiles stay L1-resident at LM-vocab widths, and retaining
///   the `x == 0` skip (ReLU activations are ~half zeros) at
///   4-wide granularity;
/// - the scalar reference form (`*_ref`) — the original row-by-row loops,
///   kept as the correctness oracle (unit tests assert agreement) and as
///   the baseline of the `blocked vs naive` rows in `benches/hotpath.rs`.
///
/// The unrolled forms reassociate f32 additions, so results can differ
/// from the references by normal rounding (≤ a few ULP per dot product);
/// both are bit-deterministic run-to-run for a fixed input.
pub mod kernels {
    /// Output-column tile width: `JB` f32 outputs (one tile) + 4 weight-row
    /// tiles = 5·4·JB bytes ≈ 10 KiB, comfortably inside a 32 KiB L1.
    const JB: usize = 512;

    /// `out[r, :] = bias + x[r, :] · w` with `w` row-major `[n_in, n_out]`.
    pub fn affine(
        x: &[f32],
        rows: usize,
        n_in: usize,
        w: &[f32],
        bias: &[f32],
        n_out: usize,
    ) -> Vec<f32> {
        debug_assert_eq!(x.len(), rows * n_in);
        debug_assert_eq!(w.len(), n_in * n_out);
        debug_assert_eq!(bias.len(), n_out);
        let mut out = vec![0.0f32; rows * n_out];
        for (xrow, orow) in x.chunks_exact(n_in).zip(out.chunks_exact_mut(n_out)) {
            orow.copy_from_slice(bias);
            for j0 in (0..n_out).step_by(JB) {
                let j1 = (j0 + JB).min(n_out);
                let ob = &mut orow[j0..j1];
                let mut k = 0;
                while k + 4 <= n_in {
                    let (a0, a1, a2, a3) = (xrow[k], xrow[k + 1], xrow[k + 2], xrow[k + 3]);
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        let w0 = &w[k * n_out + j0..k * n_out + j1];
                        let w1 = &w[(k + 1) * n_out + j0..(k + 1) * n_out + j1];
                        let w2 = &w[(k + 2) * n_out + j0..(k + 2) * n_out + j1];
                        let w3 = &w[(k + 3) * n_out + j0..(k + 3) * n_out + j1];
                        for ((((o, p0), p1), p2), p3) in
                            ob.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3)
                        {
                            *o += a0 * p0 + a1 * p1 + a2 * p2 + a3 * p3;
                        }
                    }
                    k += 4;
                }
                while k < n_in {
                    let a = xrow[k];
                    if a != 0.0 {
                        let wr = &w[k * n_out + j0..k * n_out + j1];
                        for (o, &wv) in ob.iter_mut().zip(wr) {
                            *o += a * wv;
                        }
                    }
                    k += 1;
                }
            }
        }
        out
    }

    /// Scalar reference of [`affine`].
    pub fn affine_ref(
        x: &[f32],
        rows: usize,
        n_in: usize,
        w: &[f32],
        bias: &[f32],
        n_out: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * n_out];
        for r in 0..rows {
            let orow = &mut out[r * n_out..(r + 1) * n_out];
            orow.copy_from_slice(bias);
            let xrow = &x[r * n_in..(r + 1) * n_in];
            for (k, &a) in xrow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let wrow = &w[k * n_out..(k + 1) * n_out];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
        }
        out
    }

    /// Weight/bias gradients of an affine layer:
    /// `gw[k, j] += Σ_r x[r, k]·dy[r, j]`, `gb[j] += Σ_r dy[r, j]`.
    pub fn accum_matgrad(
        x: &[f32],
        rows: usize,
        n_in: usize,
        dy: &[f32],
        n_out: usize,
        gw: &mut [f32],
        gb: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), rows * n_in);
        debug_assert_eq!(dy.len(), rows * n_out);
        debug_assert_eq!(gw.len(), n_in * n_out);
        debug_assert_eq!(gb.len(), n_out);
        for (xrow, drow) in x.chunks_exact(n_in).zip(dy.chunks_exact(n_out)) {
            for (o, &d) in gb.iter_mut().zip(drow) {
                *o += d;
            }
            // 4 consecutive gw rows per pass over the delta row: one load
            // of each delta feeds four accumulation streams
            for (a4, g4) in xrow.chunks_exact(4).zip(gw.chunks_exact_mut(4 * n_out)) {
                let (a0, a1, a2, a3) = (a4[0], a4[1], a4[2], a4[3]);
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    continue; // ReLU sparsity: whole group dead
                }
                let (g0, rest) = g4.split_at_mut(n_out);
                let (g1, rest) = rest.split_at_mut(n_out);
                let (g2, g3) = rest.split_at_mut(n_out);
                for j0 in (0..n_out).step_by(JB) {
                    let j1 = (j0 + JB).min(n_out);
                    for ((((o0, o1), o2), o3), &d) in g0[j0..j1]
                        .iter_mut()
                        .zip(g1[j0..j1].iter_mut())
                        .zip(g2[j0..j1].iter_mut())
                        .zip(g3[j0..j1].iter_mut())
                        .zip(&drow[j0..j1])
                    {
                        *o0 += a0 * d;
                        *o1 += a1 * d;
                        *o2 += a2 * d;
                        *o3 += a3 * d;
                    }
                }
            }
            let k0 = (n_in / 4) * 4;
            for k in k0..n_in {
                let a = xrow[k];
                if a == 0.0 {
                    continue;
                }
                let grow = &mut gw[k * n_out..(k + 1) * n_out];
                for (o, &d) in grow.iter_mut().zip(drow) {
                    *o += a * d;
                }
            }
        }
    }

    /// Scalar reference of [`accum_matgrad`].
    pub fn accum_matgrad_ref(
        x: &[f32],
        rows: usize,
        n_in: usize,
        dy: &[f32],
        n_out: usize,
        gw: &mut [f32],
        gb: &mut [f32],
    ) {
        for r in 0..rows {
            let xrow = &x[r * n_in..(r + 1) * n_in];
            let drow = &dy[r * n_out..(r + 1) * n_out];
            for (o, &d) in gb.iter_mut().zip(drow) {
                *o += d;
            }
            for (k, &a) in xrow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let grow = &mut gw[k * n_out..(k + 1) * n_out];
                for (o, &d) in grow.iter_mut().zip(drow) {
                    *o += a * d;
                }
            }
        }
    }

    /// Input gradient of an affine layer: `dx[r, k] = Σ_j dy[r, j]·w[k, j]`.
    pub fn matmul_bt(dy: &[f32], rows: usize, n_out: usize, w: &[f32], n_in: usize) -> Vec<f32> {
        debug_assert_eq!(dy.len(), rows * n_out);
        debug_assert_eq!(w.len(), n_in * n_out);
        let mut dx = vec![0.0f32; rows * n_in];
        for (drow, xrow) in dy.chunks_exact(n_out).zip(dx.chunks_exact_mut(n_in)) {
            // 4 dot products per pass over drow: one load of each delta
            // feeds four independent accumulator streams
            for (x4, w4) in xrow.chunks_exact_mut(4).zip(w.chunks_exact(4 * n_out)) {
                let (w0, rest) = w4.split_at(n_out);
                let (w1, rest) = rest.split_at(n_out);
                let (w2, w3) = rest.split_at(n_out);
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for ((((&d, &p0), &p1), &p2), &p3) in
                    drow.iter().zip(w0).zip(w1).zip(w2).zip(w3)
                {
                    a0 += d * p0;
                    a1 += d * p1;
                    a2 += d * p2;
                    a3 += d * p3;
                }
                x4[0] = a0;
                x4[1] = a1;
                x4[2] = a2;
                x4[3] = a3;
            }
            let k0 = (n_in / 4) * 4;
            for k in k0..n_in {
                let wr = &w[k * n_out..(k + 1) * n_out];
                let mut acc = 0.0f32;
                for (&d, &wv) in drow.iter().zip(wr) {
                    acc += d * wv;
                }
                xrow[k] = acc;
            }
        }
        dx
    }

    /// Scalar reference of [`matmul_bt`].
    pub fn matmul_bt_ref(
        dy: &[f32],
        rows: usize,
        n_out: usize,
        w: &[f32],
        n_in: usize,
    ) -> Vec<f32> {
        let mut dx = vec![0.0f32; rows * n_in];
        for r in 0..rows {
            let drow = &dy[r * n_out..(r + 1) * n_out];
            let xrow = &mut dx[r * n_in..(r + 1) * n_in];
            for (k, o) in xrow.iter_mut().enumerate() {
                let wrow = &w[k * n_out..(k + 1) * n_out];
                let mut acc = 0.0f32;
                for (&d, &wv) in drow.iter().zip(wrow) {
                    acc += d * wv;
                }
                *o = acc;
            }
        }
        dx
    }
}

/// Row-wise log-softmax NLL over logits `[n, c]`: returns
/// (mean loss, `∂L/∂logits` already scaled by `1/n`, #correct argmax).
fn softmax_xent(logits: &[f32], labels: &[i32], c: usize) -> (f32, Vec<f32>, usize) {
    let n = labels.len();
    debug_assert_eq!(logits.len(), n * c);
    let mut d = vec![0.0f32; n * c];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    let inv = 1.0f32 / n as f32;
    for r in 0..n {
        let row = &logits[r * c..(r + 1) * c];
        let y = labels[r] as usize;
        debug_assert!(y < c, "label out of range");
        let mut maxv = row[0];
        let mut arg = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > maxv {
                maxv = v;
                arg = j;
            }
        }
        if arg == y {
            correct += 1;
        }
        let drow = &mut d[r * c..(r + 1) * c];
        let mut sum = 0.0f32;
        for (o, &v) in drow.iter_mut().zip(row) {
            let ez = (v - maxv).exp();
            *o = ez;
            sum += ez;
        }
        loss += (maxv + sum.ln() - row[y]) as f64;
        let scale = inv / sum;
        for o in drow.iter_mut() {
            *o *= scale;
        }
        drow[y] -= inv;
    }
    ((loss / n as f64) as f32, d, correct)
}

// -- model definitions ---------------------------------------------------------

fn linear_specs(name: &str, nin: usize, nout: usize) -> Vec<ParamSpec> {
    vec![
        ParamSpec {
            name: format!("{name}.w"),
            shape: vec![nin, nout],
            init: "uniform_fanin".to_string(),
            fan_in: nin,
        },
        ParamSpec {
            name: format!("{name}.b"),
            shape: vec![nout],
            init: "uniform_fanin".to_string(),
            fan_in: nin,
        },
    ]
}

fn mlp_model(
    name: &str,
    x_shape: [usize; 4],
    hidden: usize,
    classes: usize,
) -> (ModelSpec, NativeModel) {
    let batch = x_shape[0];
    let n_in: usize = x_shape[1..].iter().product();
    let mut params = linear_specs("fc1", n_in, hidden);
    params.extend(linear_specs("fc2", hidden, classes));
    let d = params.iter().map(|p| p.size()).sum();
    let spec = ModelSpec {
        name: name.to_string(),
        d,
        batch,
        x_shape: x_shape.to_vec(),
        y_shape: vec![batch],
        kind: InputKind::Image,
        num_classes: classes,
        params,
        artifacts: BTreeMap::new(),
        arities: BTreeMap::new(),
    };
    (spec, NativeModel { arch: NativeArch::Mlp { n_in, hidden, classes } })
}

fn lm_model(
    name: &str,
    batch: usize,
    seq: usize,
    vocab: usize,
    dim: usize,
) -> (ModelSpec, NativeModel) {
    // unit-normal embeddings give the bigram head a usable signal at the
    // repo's learning rates (validated against a numpy mirror of this file)
    let mut params = vec![ParamSpec {
        name: "embed.w".to_string(),
        shape: vec![vocab, dim],
        init: "normal:1.0".to_string(),
        fan_in: 0,
    }];
    params.extend(linear_specs("head", dim, vocab));
    let d = params.iter().map(|p| p.size()).sum();
    let spec = ModelSpec {
        name: name.to_string(),
        d,
        batch,
        x_shape: vec![batch, seq],
        y_shape: vec![batch, seq],
        kind: InputKind::Tokens,
        num_classes: vocab,
        params,
        artifacts: BTreeMap::new(),
        arities: BTreeMap::new(),
    };
    (spec, NativeModel { arch: NativeArch::EmbedLm { vocab, dim } })
}

/// Look up a native model by manifest name. The names shadow the AOT
/// artifact manifest so both backends accept the same `--model` values;
/// the native architectures are compact stand-ins, not the paper CNNs.
pub fn native_model(name: &str) -> Option<(ModelSpec, NativeModel)> {
    match name {
        "mnist_cnn" => Some(mlp_model(name, [32, 1, 14, 14], 64, 10)),
        "cifar_cnn" => Some(mlp_model(name, [32, 3, 10, 10], 96, 10)),
        "transformer" => Some(lm_model(name, 8, 32, 64, 32)),
        _ => None,
    }
}

/// Synthesized manifest for the native backend (no `artifacts/` needed):
/// same M / t_r / model names as the AOT build, native model shapes.
pub fn native_manifest() -> Manifest {
    let mut models = BTreeMap::new();
    for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
        let (spec, _) = native_model(name).expect("built-in native model");
        models.insert(name.to_string(), spec);
    }
    Manifest {
        dir: PathBuf::from("(native)"),
        m: NATIVE_M,
        tr: NATIVE_TR,
        mt: NATIVE_M * NATIVE_TR,
        models,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_params(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| (0.3 * rng.normal()) as f32).collect()
    }

    fn image_batch(rows: usize, n_in: usize, classes: usize, rng: &mut Rng) -> Batch {
        Batch::Image {
            x: (0..rows * n_in).map(|_| rng.normal() as f32).collect(),
            y: (0..rows).map(|_| rng.below(classes) as i32).collect(),
        }
    }

    fn token_batch(rows: usize, vocab: usize, rng: &mut Rng) -> Batch {
        Batch::Tokens {
            x: (0..rows).map(|_| rng.below(vocab) as i32).collect(),
            y: (0..rows).map(|_| rng.below(vocab) as i32).collect(),
        }
    }

    /// Central-difference gradient check: the backward pass must match
    /// numerical derivatives of the forward loss on every sampled coord.
    fn grad_check(model: &NativeModel, batch: &Batch, rng: &mut Rng) {
        let mut params = rand_params(model.d(), rng);
        let (_, _, grad) = model.pass(&params, batch, true).unwrap();
        let grad = grad.unwrap();
        // small step: keeps the ReLU kink window negligible while staying
        // well above the f32 loss quantization noise floor
        let eps = 1e-3f32;
        let stride = (params.len() / 23).max(1);
        for i in (0..params.len()).step_by(stride) {
            let old = params[i];
            params[i] = old + eps;
            let (lp, _, _) = model.pass(&params, batch, false).unwrap();
            params[i] = old - eps;
            let (lm, _, _) = model.pass(&params, batch, false).unwrap();
            params[i] = old;
            let num = (lp - lm) / (2.0 * eps);
            let err = (num - grad[i]).abs();
            assert!(err < 5e-3, "coord {i}: numerical {num} vs analytic {}", grad[i]);
        }
    }

    fn assert_close(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + x.abs().max(y.abs())),
                "{what}[{i}]: blocked {x} vs reference {y}"
            );
        }
    }

    /// The unrolled/blocked kernels must agree with their scalar references
    /// (up to f32 reassociation) on every shape class: unroll remainders,
    /// single-column outputs, tile-crossing widths, ReLU-style sparsity.
    #[test]
    fn blocked_kernels_match_scalar_references() {
        let mut rng = Rng::new(21);
        for &(rows, n_in, n_out) in
            &[(5usize, 7usize, 3usize), (32, 196, 64), (32, 64, 10), (3, 2, 600), (4, 9, 1)]
        {
            let x: Vec<f32> = (0..rows * n_in)
                .map(|_| if rng.bernoulli(0.4) { 0.0 } else { rng.normal() as f32 })
                .collect();
            let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n_out).map(|_| rng.normal() as f32).collect();
            let dy: Vec<f32> = (0..rows * n_out).map(|_| rng.normal() as f32).collect();
            let what = format!("{rows}x{n_in}->{n_out}");

            let got = kernels::affine(&x, rows, n_in, &w, &b, n_out);
            let want = kernels::affine_ref(&x, rows, n_in, &w, &b, n_out);
            assert_close(&got, &want, &format!("affine {what}"));

            let got = kernels::matmul_bt(&dy, rows, n_out, &w, n_in);
            let want = kernels::matmul_bt_ref(&dy, rows, n_out, &w, n_in);
            assert_close(&got, &want, &format!("matmul_bt {what}"));

            let mut gw = vec![0.1f32; n_in * n_out];
            let mut gb = vec![-0.2f32; n_out];
            kernels::accum_matgrad(&x, rows, n_in, &dy, n_out, &mut gw, &mut gb);
            let mut gw_ref = vec![0.1f32; n_in * n_out];
            let mut gb_ref = vec![-0.2f32; n_out];
            kernels::accum_matgrad_ref(&x, rows, n_in, &dy, n_out, &mut gw_ref, &mut gb_ref);
            assert_close(&gw, &gw_ref, &format!("accum_matgrad gw {what}"));
            assert_close(&gb, &gb_ref, &format!("accum_matgrad gb {what}"));
        }
    }

    #[test]
    fn mlp_gradient_matches_finite_differences() {
        let mut rng = Rng::new(1);
        let model = NativeModel { arch: NativeArch::Mlp { n_in: 7, hidden: 5, classes: 4 } };
        let batch = image_batch(6, 7, 4, &mut rng);
        grad_check(&model, &batch, &mut rng);
    }

    #[test]
    fn lm_gradient_matches_finite_differences() {
        let mut rng = Rng::new(2);
        let model = NativeModel { arch: NativeArch::EmbedLm { vocab: 11, dim: 6 } };
        let batch = token_batch(9, 11, &mut rng);
        grad_check(&model, &batch, &mut rng);
    }

    #[test]
    fn steps_are_deterministic() {
        let mut rng = Rng::new(3);
        let model = NativeModel { arch: NativeArch::Mlp { n_in: 8, hidden: 6, classes: 3 } };
        let params = rand_params(model.d(), &mut rng);
        let batch = image_batch(5, 8, 3, &mut rng);
        let (p1, l1) = model.train_step(&params, &batch, 0.05).unwrap();
        let (p2, l2) = model.train_step(&params, &batch, 0.05).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_ne!(p1, params, "params did not move");
    }

    #[test]
    fn repeated_steps_reduce_loss_on_separable_batch() {
        let mut rng = Rng::new(4);
        let (spec, model) = native_model("mnist_cnn").unwrap();
        let n_in = spec.x_elems() / spec.batch;
        // distinct random pattern per class, low noise
        let means: Vec<Vec<f32>> = (0..10)
            .map(|_| (0..n_in).map(|_| rng.normal() as f32).collect())
            .collect();
        let y: Vec<i32> = (0..spec.batch).map(|i| (i % 10) as i32).collect();
        let x: Vec<f32> = y
            .iter()
            .flat_map(|&c| {
                means[c as usize]
                    .iter()
                    .map(|&mu| 2.0 * mu + 0.3 * rng.normal() as f32)
                    .collect::<Vec<_>>()
            })
            .collect();
        let batch = Batch::Image { x, y };
        let mut params = rand_params(spec.d, &mut rng);
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..60 {
            let (p, loss) = model.train_step(&params, &batch, 0.02).unwrap();
            params = p;
            first.get_or_insert(loss);
            last = loss;
        }
        let first = first.unwrap();
        assert!(last < 0.65 * first, "loss {first} -> {last}");
    }

    #[test]
    fn lm_steps_reduce_loss() {
        let mut rng = Rng::new(5);
        let (spec, model) = native_model("transformer").unwrap();
        // deterministic next-token structure: y = x + 1 mod vocab
        let n = spec.batch * spec.x_shape[1];
        let x: Vec<i32> = (0..n).map(|_| rng.below(spec.num_classes) as i32).collect();
        let y: Vec<i32> = x.iter().map(|&t| (t + 1) % spec.num_classes as i32).collect();
        let batch = Batch::Tokens { x, y };
        let runtime = crate::runtime::ModelRuntime::native("transformer").unwrap();
        let mut params = runtime.init_params(&mut rng);
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..80 {
            let (p, loss) = model.train_step(&params, &batch, 0.5).unwrap();
            params = p;
            first.get_or_insert(loss);
            last = loss;
        }
        let first = first.unwrap();
        assert!(last < 0.5 * first, "loss {first} -> {last}");
    }

    #[test]
    fn specs_are_consistent() {
        for name in ["mnist_cnn", "cifar_cnn", "transformer"] {
            let (spec, model) = native_model(name).unwrap();
            assert_eq!(spec.d, model.d(), "{name}: spec/arch D mismatch");
            assert_eq!(
                spec.params.iter().map(|p| p.size()).sum::<usize>(),
                spec.d,
                "{name}: param sizes do not sum to D"
            );
        }
        assert!(native_model("nope").is_none());
        let man = native_manifest();
        assert_eq!(man.m, NATIVE_M);
        assert_eq!(man.mt, man.m * man.tr);
        assert_eq!(man.models.len(), 3);
    }

    #[test]
    fn sgd_apply_is_axpy() {
        let p = vec![1.0f32, 2.0, -3.0];
        let g = vec![0.5f32, -1.0, 2.0];
        assert_eq!(sgd_apply(&p, &g, 0.0), p);
        assert_eq!(sgd_apply(&p, &g, 1.0), vec![0.5, 3.0, -5.0]);
        assert_eq!(sgd_apply(&p, &g, -1.0), vec![1.5, 1.0, -1.0]);
    }

    #[test]
    fn batch_kind_mismatch_is_an_error() {
        let mut rng = Rng::new(6);
        let model = NativeModel { arch: NativeArch::Mlp { n_in: 4, hidden: 3, classes: 2 } };
        let params = rand_params(model.d(), &mut rng);
        let bad = token_batch(4, 2, &mut rng);
        assert!(model.eval_step(&params, &bad).is_err());
    }

    #[test]
    fn out_of_range_labels_are_an_error_not_a_panic() {
        let mut rng = Rng::new(7);
        let mlp = NativeModel { arch: NativeArch::Mlp { n_in: 4, hidden: 3, classes: 2 } };
        let params = rand_params(mlp.d(), &mut rng);
        let bad = Batch::Image { x: vec![0.0; 8], y: vec![0, 2] }; // label 2 >= classes
        assert!(mlp.eval_step(&params, &bad).is_err());

        let lm = NativeModel { arch: NativeArch::EmbedLm { vocab: 4, dim: 3 } };
        let params = rand_params(lm.d(), &mut rng);
        let bad_x = Batch::Tokens { x: vec![4], y: vec![0] }; // token 4 >= vocab
        assert!(lm.eval_step(&params, &bad_x).is_err());
        let bad_y = Batch::Tokens { x: vec![0], y: vec![4] }; // target 4 >= vocab
        assert!(lm.eval_step(&params, &bad_y).is_err());
    }
}
