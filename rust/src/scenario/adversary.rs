//! Byzantine adversary models: malicious clients that corrupt the coded
//! messages they emit, sampled per trial alongside the channel state.
//!
//! The channel engine (PR 4) models links that fail *honestly*; this module
//! models clients that lie. An [`AdversarySpec`] declares who is malicious
//! (a per-trial fraction or a fixed set), what they send
//! ([`Attack`]: sign-flip, additive noise, arbitrary replacement, or a
//! colluding-consistent shared vector), and where the corruption enters
//! ([`Surface`]): on the **uplink** (the client tampers with the coded
//! partial sum it reports to the PS) or on the **c2c** sharing phase (the
//! client consistently uses a fake local gradient in everything it emits —
//! the data-poisoning case).
//!
//! Determinism contract: all adversarial randomness (who is malicious,
//! noise/replacement draws) lives on the private [`ADVERSARY_STREAM`]
//! substream, never on the trial's emission stream — so a configured
//! adversary with an empty malicious set consumes **zero** emission draws
//! and every outcome is byte-identical to the non-adversarial path
//! (asserted in `tests/adversary.rs`).
//!
//! Detection guarantees (see the audit layer in [`crate::gc::byzantine`]):
//! uplink tampering violates the linear relations among redundant coded
//! rows and is caught by parity checks whenever the redundancy covers the
//! corrupted row; c2c-consistent corruption produces a stack that is fully
//! consistent with the *substituted* gradients and is information-
//! theoretically invisible to coding checks — the documented blind spot.

use crate::gc::FrCode;
use crate::network::SparseRealization;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Substream tag for adversarial state (who is malicious + corruption
/// draws), disjoint from the trial emission stream and from
/// [`crate::scenario::CHANNEL_STREAM`].
pub const ADVERSARY_STREAM: u64 = 0xADE5_A21E;

/// What a malicious client sends instead of its honest coded message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Attack {
    /// Negate the honest message (the classic model-poisoning flip).
    SignFlip,
    /// Honest message plus `sigma`-scaled Gaussian noise.
    Noise { sigma: f64 },
    /// Replace with an arbitrary `scale`-Gaussian vector (fresh per trial).
    Replace { scale: f64 },
    /// All malicious clients send one shared `scale`-Gaussian vector
    /// (colluding-consistent: copies agree with each other, defeating
    /// naive majority votes among the colluders).
    Collude { scale: f64 },
}

impl Attack {
    /// Stable CLI/JSON identifier.
    pub fn name(&self) -> &'static str {
        match self {
            Attack::SignFlip => "sign_flip",
            Attack::Noise { .. } => "noise",
            Attack::Replace { .. } => "replace",
            Attack::Collude { .. } => "collude",
        }
    }
}

/// Where the corruption enters the round.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Surface {
    /// The client tampers with the coded partial sum it uplinks; the
    /// shares it sent to neighbors were honest. Detectable via redundancy.
    #[default]
    Uplink,
    /// The client uses a fake local gradient consistently in everything it
    /// emits (c2c shares and its own sum) — data poisoning. Invisible to
    /// parity checks; recovered values for that client are silently wrong.
    C2c,
}

impl Surface {
    pub fn name(&self) -> &'static str {
        match self {
            Surface::Uplink => "uplink",
            Surface::C2c => "c2c",
        }
    }
}

/// Who is malicious.
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    /// Each client is independently malicious w.p. `fraction` per trial
    /// (drawn on the adversary substream).
    Fraction(f64),
    /// A fixed set of client indices (deterministic, no draws).
    Fixed(Vec<usize>),
}

/// Declarative adversary configuration, JSON-round-trippable like
/// [`crate::scenario::ChannelSpec`].
#[derive(Clone, Debug, PartialEq)]
pub struct AdversarySpec {
    pub attack: Attack,
    pub selection: Selection,
    pub surface: Surface,
    /// Run the detection/excision audit in the decode path.
    pub detect: bool,
}

impl AdversarySpec {
    /// Convenience constructor: fraction-sampled uplink attack with
    /// detection on.
    pub fn fraction(attack: Attack, fraction: f64) -> AdversarySpec {
        AdversarySpec {
            attack,
            selection: Selection::Fraction(fraction),
            surface: Surface::Uplink,
            detect: true,
        }
    }

    /// One-line human summary for table comments.
    pub fn summary(&self) -> String {
        let who = match &self.selection {
            Selection::Fraction(f) => format!("frac={f}"),
            Selection::Fixed(set) => format!("fixed={set:?}"),
        };
        format!(
            "{}({who}, {}{})",
            self.attack.name(),
            self.surface.name(),
            if self.detect { ", detect" } else { "" }
        )
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        match self.attack {
            Attack::Noise { sigma } => {
                anyhow::ensure!(
                    sigma.is_finite() && sigma > 0.0,
                    "noise sigma must be > 0, got {sigma}"
                )
            }
            Attack::Replace { scale } | Attack::Collude { scale } => {
                anyhow::ensure!(
                    scale.is_finite() && scale > 0.0,
                    "attack scale must be > 0, got {scale}"
                )
            }
            Attack::SignFlip => {}
        }
        match &self.selection {
            Selection::Fraction(f) => {
                anyhow::ensure!(
                    (0.0..=1.0).contains(f),
                    "adversary fraction must be in [0, 1], got {f}"
                )
            }
            Selection::Fixed(_) => {} // indices checked against M at reset
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("attack", json::s(self.attack.name()))];
        match self.attack {
            Attack::Noise { sigma } => fields.push(("sigma", json::num(sigma))),
            Attack::Replace { scale } | Attack::Collude { scale } => {
                fields.push(("scale", json::num(scale)))
            }
            Attack::SignFlip => {}
        }
        match &self.selection {
            Selection::Fraction(f) => fields.push(("fraction", json::num(*f))),
            Selection::Fixed(set) => fields.push((
                "clients",
                Json::Arr(set.iter().map(|&i| json::num(i as f64)).collect()),
            )),
        }
        // defaults are omitted so minimal specs stay minimal
        if self.surface != Surface::Uplink {
            fields.push(("surface", json::s(self.surface.name())));
        }
        if !self.detect {
            fields.push(("detect", Json::Bool(false)));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<AdversarySpec> {
        let kind = v
            .req("attack")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("adversary attack must be a string"))?;
        let num = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("adversary field {key:?} must be a number"))
        };
        let attack = match kind {
            "sign_flip" => Attack::SignFlip,
            "noise" => Attack::Noise { sigma: num("sigma")? },
            "replace" => Attack::Replace { scale: num("scale")? },
            "collude" => Attack::Collude { scale: num("scale")? },
            other => anyhow::bail!(
                "unknown attack {other:?} (sign_flip|noise|replace|collude)"
            ),
        };
        let selection = match (v.get("fraction"), v.get("clients")) {
            (Some(f), None) => Selection::Fraction(
                f.as_f64().ok_or_else(|| anyhow::anyhow!("adversary fraction must be a number"))?,
            ),
            (None, Some(arr)) => {
                let arr = arr
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("adversary clients must be an array"))?;
                let mut set = Vec::with_capacity(arr.len());
                for x in arr {
                    set.push(x.as_usize().ok_or_else(|| {
                        anyhow::anyhow!("adversary client indices must be integers")
                    })?);
                }
                Selection::Fixed(set)
            }
            _ => anyhow::bail!("adversary needs exactly one of \"fraction\" or \"clients\""),
        };
        let surface = match v.get("surface") {
            None => Surface::Uplink,
            Some(s) => match s.as_str() {
                Some("uplink") => Surface::Uplink,
                Some("c2c") => Surface::C2c,
                _ => anyhow::bail!("adversary surface must be \"uplink\" or \"c2c\""),
            },
        };
        let detect = match v.get("detect") {
            None => true,
            Some(b) => b
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("adversary detect must be a bool"))?,
        };
        let spec = AdversarySpec { attack, selection, surface, detect };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the compact CLI form
    /// `<attack>:<fraction>[:<param>][:c2c][:nodetect]`, e.g.
    /// `sign_flip:0.2`, `noise:0.1:5.0`, `collude:0.3:1.0:c2c:nodetect`.
    pub fn parse_cli(text: &str) -> anyhow::Result<AdversarySpec> {
        let mut it = text.split(':');
        let kind = it.next().unwrap_or("");
        let frac: f64 = it
            .next()
            .ok_or_else(|| {
                anyhow::anyhow!("adversary spec needs <attack>:<fraction>, got {text:?}")
            })?
            .parse()
            .map_err(|_| anyhow::anyhow!("bad adversary fraction in {text:?}"))?;
        let mut param: Option<f64> = None;
        let mut surface = Surface::Uplink;
        let mut detect = true;
        for tok in it {
            match tok {
                "c2c" => surface = Surface::C2c,
                "uplink" => surface = Surface::Uplink,
                "nodetect" => detect = false,
                _ => match tok.parse::<f64>() {
                    Ok(x) => param = Some(x),
                    Err(_) => anyhow::bail!("bad adversary spec token {tok:?} in {text:?}"),
                },
            }
        }
        let attack = match kind {
            "sign_flip" => Attack::SignFlip,
            "noise" => Attack::Noise { sigma: param.unwrap_or(1.0) },
            "replace" => Attack::Replace { scale: param.unwrap_or(1.0) },
            "collude" => Attack::Collude { scale: param.unwrap_or(1.0) },
            other => anyhow::bail!(
                "unknown attack {other:?} (sign_flip|noise|replace|collude)"
            ),
        };
        let spec = AdversarySpec { attack, selection: Selection::Fraction(frac), surface, detect };
        spec.validate()?;
        Ok(spec)
    }
}

/// Stateful per-trial adversary: holds the sampled malicious set and the
/// private corruption RNG. Reset once per trial (episode) with the trial's
/// [`ADVERSARY_STREAM`] substream seed; the malicious set then persists
/// across the trial's rounds/attempts (a compromised client stays
/// compromised, like a channel state).
pub struct AdversaryModel {
    pub spec: AdversarySpec,
    rng: Rng,
    malicious: Vec<bool>,
    count: usize,
    /// Shared collusion vector of this trial, materialized lazily per
    /// payload width.
    collude: Vec<f64>,
}

impl AdversaryModel {
    pub fn new(spec: AdversarySpec) -> AdversaryModel {
        AdversaryModel {
            spec,
            rng: Rng::new(0),
            malicious: Vec::new(),
            count: 0,
            collude: Vec::new(),
        }
    }

    /// Re-sample the malicious set for a fresh trial over `m` clients.
    /// Fraction selections draw one Bernoulli per client from the private
    /// substream; fixed sets draw nothing.
    pub fn reset(&mut self, m: usize, seed: u64) {
        self.rng = Rng::new(seed);
        self.malicious.clear();
        self.malicious.resize(m, false);
        self.count = 0;
        self.collude.clear();
        match &self.spec.selection {
            Selection::Fraction(f) => {
                let f = *f;
                for flag in self.malicious.iter_mut() {
                    if f > 0.0 && self.rng.bernoulli(f) {
                        *flag = true;
                        self.count += 1;
                    }
                }
            }
            Selection::Fixed(set) => {
                for &i in set {
                    if i < m && !self.malicious[i] {
                        self.malicious[i] = true;
                        self.count += 1;
                    }
                }
            }
        }
    }

    #[inline]
    pub fn is_malicious(&self, client: usize) -> bool {
        self.malicious.get(client).copied().unwrap_or(false)
    }

    /// Whether this trial has any malicious client at all. `false` means
    /// the trial must be byte-identical to the non-adversarial path.
    #[inline]
    pub fn any(&self) -> bool {
        self.count > 0
    }

    pub fn malicious_count(&self) -> usize {
        self.count
    }

    fn collude_row(&mut self, d: usize, scale: f64) -> &[f64] {
        if self.collude.len() != d {
            self.collude.clear();
            for _ in 0..d {
                self.collude.push(scale * self.rng.normal());
            }
        }
        &self.collude
    }

    /// Corrupt one payload-space row in place (the message a malicious
    /// client emits instead of the honest `row`). Draws come from the
    /// private substream only.
    pub fn corrupt_row(&mut self, row: &mut [f64]) {
        match self.spec.attack {
            Attack::SignFlip => {
                for x in row.iter_mut() {
                    *x = -*x;
                }
            }
            Attack::Noise { sigma } => {
                for x in row.iter_mut() {
                    *x += sigma * self.rng.normal();
                }
            }
            Attack::Replace { scale } => {
                for x in row.iter_mut() {
                    *x = scale * self.rng.normal();
                }
            }
            Attack::Collude { scale } => {
                let d = row.len();
                let v = self.collude_row(d, scale);
                row.copy_from_slice(v);
            }
        }
    }

    /// f32 variant for the trainer's payload rows.
    pub fn corrupt_row_f32(&mut self, row: &mut [f32]) {
        match self.spec.attack {
            Attack::SignFlip => {
                for x in row.iter_mut() {
                    *x = -*x;
                }
            }
            Attack::Noise { sigma } => {
                for x in row.iter_mut() {
                    *x += (sigma * self.rng.normal()) as f32;
                }
            }
            Attack::Replace { scale } => {
                for x in row.iter_mut() {
                    *x = (scale * self.rng.normal()) as f32;
                }
            }
            Attack::Collude { scale } => {
                let d = row.len();
                let v = self.collude_row(d, scale);
                for (x, &c) in row.iter_mut().zip(v) {
                    *x = c as f32;
                }
            }
        }
    }

    /// Whether two malicious clients' corrupted messages agree with each
    /// other (value-equality class structure of the FR plurality vote).
    fn consistent_class(&self, client: usize) -> FrClass {
        match self.spec.attack {
            // all sign-flippers of one group negate the same group sum
            Attack::SignFlip => FrClass::SignFlip,
            // colluders share one global vector
            Attack::Collude { .. } => FrClass::Collude,
            // noise / replacement draws are a.s. pairwise distinct
            Attack::Noise { .. } | Attack::Replace { .. } => FrClass::Unique(client),
        }
    }
}

/// Value-equality class of one uplinked FR group sum.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FrClass {
    Honest,
    SignFlip,
    Collude,
    Unique(usize),
}

/// Integrity verdict of one FR group after the audit. Ordered worst → best
/// so a union across GC⁺ repeats can simply take the max (with detection,
/// a cleanly validated copy from any attempt wins).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum GroupVerdict {
    /// No member delivered a complete sum.
    #[default]
    Uncovered,
    /// The plurality vote tied — the PS excises the whole group.
    Excised,
    /// The accepted value is corrupted (decoded-but-poisoned).
    Poisoned,
    /// The accepted value is the honest group sum.
    Clean,
}

impl GroupVerdict {
    /// Whether the group contributes a decoded value (clean or not).
    pub fn covered(&self) -> bool {
        matches!(self, GroupVerdict::Poisoned | GroupVerdict::Clean)
    }
}

/// Tallies of one FR attempt's audit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrAttemptAudit {
    /// Groups where corrupted data reached the PS this attempt.
    pub active: bool,
    /// Groups whose vote raised an alarm (≥ 2 value classes, or a tie).
    pub alarms: usize,
    /// Member copies excised by the vote (losing classes + ties).
    pub excised: usize,
    /// Honest member copies among the excised (the false-alarm cost).
    pub false_excised: usize,
}

impl AdversaryModel {
    /// Per-group integrity audit of one FR attempt (payload-free — the
    /// class structure is fully determined by who is malicious and the
    /// attack's consistency pattern).
    ///
    /// Uplink surface: the delivered-complete members of a group each
    /// uplink a copy of the group sum; malicious members tamper with
    /// theirs. With `detect`, the PS runs a plurality vote over the value-
    /// equality classes — the strict winner is accepted (honest sums from
    /// distinct members agree; sign-flipped copies agree with each other;
    /// noise/replacement copies are singletons; colluders share one
    /// vector), a tie excises the group. Without `detect`, the PS takes
    /// the first delivered copy.
    ///
    /// C2c surface: a malicious member's fake gradient enters *every*
    /// complete member's sum identically, so all copies agree — a single
    /// (corrupted) class the vote cannot flag. The group decodes poisoned:
    /// the documented blind spot of redundancy-based detection.
    pub fn fr_attempt_verdicts(
        &self,
        code: &FrCode,
        real: &SparseRealization,
        verdicts: &mut Vec<GroupVerdict>,
    ) -> FrAttemptAudit {
        verdicts.clear();
        let mut audit = FrAttemptAudit::default();
        for g in 0..code.groups() {
            let members = code.members(g);
            let group_has_malicious = members.clone().any(|r| self.is_malicious(r));
            let mut delivered: usize = 0;
            let mut first: Option<usize> = None;
            // class census of the delivered copies
            let mut honest = 0usize;
            let mut flip = 0usize;
            let mut collude = 0usize;
            let mut unique = 0usize;
            for r in members {
                if !real.row_delivered_complete(r) {
                    continue;
                }
                delivered += 1;
                if first.is_none() {
                    first = Some(r);
                }
                match self.surface_class(r) {
                    FrClass::Honest => honest += 1,
                    FrClass::SignFlip => flip += 1,
                    FrClass::Collude => collude += 1,
                    FrClass::Unique(_) => unique += 1,
                }
            }
            if delivered == 0 {
                verdicts.push(GroupVerdict::Uncovered);
                continue;
            }
            if self.spec.surface == Surface::C2c {
                // consistent substitution: every copy equals the same
                // (possibly corrupted) sum — a single class, no alarm
                let v = if group_has_malicious {
                    GroupVerdict::Poisoned
                } else {
                    GroupVerdict::Clean
                };
                audit.active |= group_has_malicious;
                verdicts.push(v);
                continue;
            }
            let corrupted_copies = delivered - honest;
            audit.active |= corrupted_copies > 0;
            if !self.spec.detect {
                let v = if self.is_malicious(first.expect("delivered > 0")) {
                    GroupVerdict::Poisoned
                } else {
                    GroupVerdict::Clean
                };
                verdicts.push(v);
                continue;
            }
            // plurality vote over the value classes: honest (one class),
            // sign-flip (one class), collude (one class), uniques (1 each)
            let classes =
                (honest > 0) as usize + (flip > 0) as usize + (collude > 0) as usize + unique;
            if classes <= 1 {
                // unanimous — no alarm; poisoned iff the one class is bad
                let v = if honest > 0 { GroupVerdict::Clean } else { GroupVerdict::Poisoned };
                verdicts.push(v);
                continue;
            }
            audit.alarms += 1;
            let unique_best = if unique > 0 { 1 } else { 0 };
            let best = honest.max(flip).max(collude).max(unique_best);
            let winners = (honest == best) as usize
                + (flip == best) as usize
                + (collude == best) as usize
                + if unique_best == best { unique } else { 0 };
            if winners != 1 {
                // tie: drop the whole group
                audit.excised += delivered;
                audit.false_excised += honest;
                verdicts.push(GroupVerdict::Excised);
                continue;
            }
            let honest_wins = honest == best;
            audit.excised += delivered - best;
            if !honest_wins {
                audit.false_excised += honest;
            }
            verdicts.push(if honest_wins { GroupVerdict::Clean } else { GroupVerdict::Poisoned });
        }
        audit
    }

    fn surface_class(&self, client: usize) -> FrClass {
        if self.is_malicious(client) {
            self.consistent_class(client)
        } else {
            FrClass::Honest
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::SparseSupport;

    fn spec(attack: Attack) -> AdversarySpec {
        AdversarySpec::fraction(attack, 0.5)
    }

    #[test]
    fn json_roundtrip_all_attacks() {
        for s in [
            spec(Attack::SignFlip),
            spec(Attack::Noise { sigma: 2.5 }),
            spec(Attack::Replace { scale: 3.0 }),
            AdversarySpec {
                attack: Attack::Collude { scale: 1.5 },
                selection: Selection::Fixed(vec![0, 3, 7]),
                surface: Surface::C2c,
                detect: false,
            },
        ] {
            let text = s.to_json().serialize();
            let back = AdversarySpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, s, "roundtrip failed for {text}");
        }
    }

    #[test]
    fn json_defaults_are_omitted() {
        let text = spec(Attack::SignFlip).to_json().serialize();
        assert!(!text.contains("surface"), "{text}");
        assert!(!text.contains("detect"), "{text}");
    }

    #[test]
    fn json_rejects_bad_specs() {
        for bad in [
            r#"{"attack": "sign_flip"}"#,                      // no selection
            r#"{"attack": "sign_flip", "fraction": 1.5}"#,     // fraction > 1
            r#"{"attack": "noise", "fraction": 0.1}"#,         // missing sigma
            r#"{"attack": "nuke", "fraction": 0.1}"#,          // unknown attack
            r#"{"attack": "sign_flip", "fraction": 0.1, "surface": "psychic"}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(AdversarySpec::from_json(&v).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn cli_parse_forms() {
        let s = AdversarySpec::parse_cli("sign_flip:0.2").unwrap();
        assert_eq!(s.attack, Attack::SignFlip);
        assert_eq!(s.selection, Selection::Fraction(0.2));
        assert_eq!(s.surface, Surface::Uplink);
        assert!(s.detect);
        let s = AdversarySpec::parse_cli("noise:0.1:5.0").unwrap();
        assert_eq!(s.attack, Attack::Noise { sigma: 5.0 });
        let s = AdversarySpec::parse_cli("collude:0.3:2.0:c2c:nodetect").unwrap();
        assert_eq!(s.attack, Attack::Collude { scale: 2.0 });
        assert_eq!(s.surface, Surface::C2c);
        assert!(!s.detect);
        assert!(AdversarySpec::parse_cli("sign_flip").is_err());
        assert!(AdversarySpec::parse_cli("sign_flip:2.0").is_err());
        assert!(AdversarySpec::parse_cli("sign_flip:0.1:what").is_err());
    }

    #[test]
    fn fraction_zero_samples_nobody_and_fixed_sets_are_exact() {
        let mut adv = AdversaryModel::new(spec(Attack::SignFlip));
        adv.spec.selection = Selection::Fraction(0.0);
        for seed in 0..50u64 {
            adv.reset(10, seed);
            assert!(!adv.any());
        }
        adv.spec.selection = Selection::Fixed(vec![1, 4, 4, 99]);
        adv.reset(10, 7);
        assert_eq!(adv.malicious_count(), 2); // dup + out-of-range ignored
        assert!(adv.is_malicious(1) && adv.is_malicious(4));
        assert!(!adv.is_malicious(0) && !adv.is_malicious(99));
    }

    #[test]
    fn fraction_sampling_is_seed_deterministic_and_plausible() {
        let mut adv = AdversaryModel::new(spec(Attack::SignFlip));
        let mut total = 0usize;
        for seed in 0..200u64 {
            adv.reset(10, seed);
            total += adv.malicious_count();
        }
        let mean = total as f64 / 200.0;
        assert!((mean - 5.0).abs() < 0.8, "mean malicious {mean} (expect ~5)");
        // identical seed → identical set
        adv.reset(10, 3);
        let a: Vec<bool> = (0..10).map(|i| adv.is_malicious(i)).collect();
        adv.reset(10, 3);
        let b: Vec<bool> = (0..10).map(|i| adv.is_malicious(i)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn corruption_ops_do_what_they_say() {
        let mut adv = AdversaryModel::new(spec(Attack::SignFlip));
        adv.reset(4, 1);
        let mut row = vec![1.0, -2.0, 3.0];
        adv.corrupt_row(&mut row);
        assert_eq!(row, vec![-1.0, 2.0, -3.0]);

        let mut adv = AdversaryModel::new(spec(Attack::Replace { scale: 2.0 }));
        adv.reset(4, 1);
        let mut row = vec![0.0; 16];
        adv.corrupt_row(&mut row);
        assert!(row.iter().any(|&x| x != 0.0));

        // colluders share the trial vector; a fresh trial redraws it
        let mut adv = AdversaryModel::new(spec(Attack::Collude { scale: 1.0 }));
        adv.reset(4, 1);
        let mut a = vec![1.0; 8];
        let mut b = vec![-5.0; 8];
        adv.corrupt_row(&mut a);
        adv.corrupt_row(&mut b);
        assert_eq!(a, b);
        adv.reset(4, 2);
        let mut c = vec![0.0; 8];
        adv.corrupt_row(&mut c);
        assert_ne!(a, c);
    }

    /// Hand-built FR plurality cases over one group of 3 (M=6, s=2).
    #[test]
    fn fr_plurality_votes() {
        let code = FrCode::new(6, 2).unwrap();
        let sup = code.sparse_support();
        let all_up = SparseRealization::perfect(&sup);
        let run = |set: Vec<usize>, attack: Attack, detect: bool| {
            let mut adv = AdversaryModel::new(AdversarySpec {
                attack,
                selection: Selection::Fixed(set),
                surface: Surface::Uplink,
                detect,
            });
            adv.reset(6, 0);
            let mut v = Vec::new();
            let audit = adv.fr_attempt_verdicts(&code, &all_up, &mut v);
            (v, audit)
        };
        // one flipper in group 0: honest wins 2–1, flipper excised
        let (v, audit) = run(vec![0], Attack::SignFlip, true);
        assert_eq!(v, vec![GroupVerdict::Clean, GroupVerdict::Clean]);
        assert_eq!(audit.alarms, 1);
        assert_eq!(audit.excised, 1);
        assert_eq!(audit.false_excised, 0);
        // two flippers outvote the honest member: detected but poisoned
        let (v, audit) = run(vec![0, 1], Attack::SignFlip, true);
        assert_eq!(v[0], GroupVerdict::Poisoned);
        assert_eq!(audit.alarms, 1);
        assert_eq!(audit.false_excised, 1);
        // two *noise* attackers are singletons: honest wins 1 vs 1+1
        // ... a three-way tie (1,1,1) excises the group
        let (v, audit) = run(vec![0, 1], Attack::Noise { sigma: 1.0 }, true);
        assert_eq!(v[0], GroupVerdict::Excised);
        assert!(audit.alarms >= 1);
        // whole group malicious and consistent: unanimous, silently poisoned
        let (v, audit) = run(vec![0, 1, 2], Attack::SignFlip, true);
        assert_eq!(v[0], GroupVerdict::Poisoned);
        assert_eq!(audit.alarms, 0);
        // without detection the first copy is taken at face value
        let (v, _) = run(vec![0], Attack::SignFlip, false);
        assert_eq!(v[0], GroupVerdict::Poisoned);
        let (v, _) = run(vec![1], Attack::SignFlip, false);
        assert_eq!(v[0], GroupVerdict::Clean);
    }

    #[test]
    fn fr_c2c_surface_is_the_documented_blind_spot() {
        let code = FrCode::new(6, 2).unwrap();
        let sup = code.sparse_support();
        let all_up = SparseRealization::perfect(&sup);
        let mut adv = AdversaryModel::new(AdversarySpec {
            attack: Attack::SignFlip,
            selection: Selection::Fixed(vec![0]),
            surface: Surface::C2c,
            detect: true,
        });
        adv.reset(6, 0);
        let mut v = Vec::new();
        let audit = adv.fr_attempt_verdicts(&code, &all_up, &mut v);
        // every copy of group 0's sum embeds the fake gradient identically:
        // covered, poisoned, zero alarms
        assert_eq!(v, vec![GroupVerdict::Poisoned, GroupVerdict::Clean]);
        assert_eq!(audit.alarms, 0);
        assert!(audit.active);
    }

    #[test]
    fn verdict_union_prefers_clean() {
        assert!(GroupVerdict::Clean > GroupVerdict::Poisoned);
        assert!(GroupVerdict::Poisoned > GroupVerdict::Excised);
        assert!(GroupVerdict::Excised > GroupVerdict::Uncovered);
        assert!(GroupVerdict::Clean.covered() && GroupVerdict::Poisoned.covered());
        assert!(!GroupVerdict::Excised.covered() && !GroupVerdict::Uncovered.covered());
    }
}
