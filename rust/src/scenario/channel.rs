//! Stateful channel models: link dynamics beyond memoryless i.i.d. erasure.
//!
//! The paper analyzes CoGC/GC⁺ under independent Bernoulli erasures, but its
//! central warning — all-or-nothing decoding is brittle exactly when
//! client-to-client channels degrade — is about *time-varying* loss. This
//! module supplies the link dynamics to probe those regimes: a
//! [`ChannelModel`] evolves per-trial state across communication attempts
//! and emits the same [`Realization`] the rest of the stack already
//! consumes.
//!
//! # Determinism / degenerate-equivalence contract
//!
//! Every model separates its randomness into two streams:
//!
//! - the **emission stream** — the `rng` passed to
//!   [`ChannelModel::sample_into`] (or its allocating wrapper
//!   [`ChannelModel::sample`]). Each sample consumes exactly one Bernoulli
//!   draw per off-diagonal c2c
//!   link (row-major) and one per uplink, in the order fixed by
//!   [`Realization::sample_with`] — the same draws, in the same order, as
//!   the memoryless [`Iid`] model;
//! - the **state stream** — a private RNG seeded by
//!   [`ChannelModel::reset`] (derive the seed with
//!   [`crate::parallel::trial_substream`]), which drives burst transitions,
//!   fade events, and latency draws and never touches the emission stream.
//!
//! A degenerately-configured stateful model (equal good/bad outage
//! probabilities, zero fade coupling, infinite deadline) therefore consumes
//! emission draws **byte-identically** to [`Iid`], so whole figure CSVs
//! collapse to the i.i.d. baseline — asserted in
//! `tests/scenario_models.rs`.
//!
//! All three non-trivial models *modulate* the [`Network`]'s per-link base
//! probabilities rather than replacing them, so they compose with every
//! paper topology (homogeneous, heterogeneous, conn tiers).
//!
//! # Sparse path (structured code families, M = 10⁵–10⁶)
//!
//! Every model also implements [`ChannelModel::reset_sparse`] /
//! [`ChannelModel::sample_sparse_into`], which restrict state and emission
//! to a [`SparseSupport`]'s M·s supported links plus the M uplinks — the
//! structured path never allocates O(M²). The sparse emission contract
//! mirrors the dense one: exactly one Bernoulli per supported link in
//! row-major `(row, idx)` order, then one per uplink; private state (burst
//! chains, latency draws) follows the same order on the state stream. The
//! sparse and dense streams are *different* sequences — the FR path has no
//! byte-level compatibility obligation to the dense oracle, only
//! distributional equivalence (pinned by `tests/code_families.rs`).

use crate::network::{Network, Realization, SparseRealization, SparseSupport};
use crate::parallel::Accumulate;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Tag of the per-trial channel-state substream (the `tag` argument to
/// [`crate::parallel::trial_substream`]) used by every sweep in the crate.
pub const CHANNEL_STREAM: u64 = 0xC11A_57A7;

/// Multiply a base outage probability by a state-dependent scale, clamped
/// to a probability. `scale = 1.0` returns `p` bit-exactly (the degenerate
/// case relies on this).
fn scaled(p: f64, scale: f64) -> f64 {
    (p * scale).clamp(0.0, 1.0)
}

/// Channel diagnostics accumulated across samples (all integer tallies, so
/// per-worker instances merge exactly under the parallel engine).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChannelStats {
    /// Communication attempts sampled.
    pub samples: usize,
    /// Link-attempts spent in the degraded condition (bad burst state,
    /// faded round, straggling source client).
    pub degraded: usize,
    /// Denominator of `degraded` (link-attempts tracked).
    pub degraded_denom: usize,
    /// Latency draws that beat the deadline (deadline models only).
    pub deadline_hits: usize,
    /// Total latency draws (0 for models without deadlines).
    pub deadline_total: usize,
    /// Degraded→healthy state-chain transitions (a burst, fade, or
    /// straggle spell ended). With `degraded`, this yields the mean
    /// degraded dwell time: `degraded / burst_ends` attempts per spell.
    pub burst_ends: usize,
}

impl ChannelStats {
    /// Fraction of link-attempts in the degraded condition (0 when the
    /// model tracks no degradation).
    pub fn degraded_frac(&self) -> f64 {
        if self.degraded_denom == 0 {
            0.0
        } else {
            self.degraded as f64 / self.degraded_denom as f64
        }
    }

    /// Fraction of latency draws beating the deadline (1 when the model has
    /// no deadline — nothing ever misses).
    pub fn deadline_hit_rate(&self) -> f64 {
        if self.deadline_total == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / self.deadline_total as f64
        }
    }

    /// Mean degraded dwell in chain steps per completed spell
    /// (0 when no spell has ended — nothing dwelt).
    pub fn mean_burst_dwell(&self) -> f64 {
        if self.burst_ends == 0 {
            0.0
        } else {
            self.degraded as f64 / self.burst_ends as f64
        }
    }
}

impl Accumulate for ChannelStats {
    fn merge(&mut self, other: Self) {
        self.samples += other.samples;
        self.degraded += other.degraded;
        self.degraded_denom += other.degraded_denom;
        self.deadline_hits += other.deadline_hits;
        self.deadline_total += other.deadline_total;
        self.burst_ends += other.burst_ends;
    }
}

/// A stateful link model: evolves per-trial state across communication
/// attempts and emits [`Realization`]s. See the module docs for the
/// two-stream determinism contract.
pub trait ChannelModel: Send + Sync {
    /// Short stable identifier (`iid`, `gilbert_elliott`, …).
    fn name(&self) -> &'static str;

    /// Re-initialize per-trial state for `net` (initial states are drawn
    /// from the model's stationary distribution). `state_seed` seeds the
    /// private state stream; derive it per trial with
    /// [`crate::parallel::trial_substream`] so sweeps stay bit-identical at
    /// any thread count.
    fn reset(&mut self, net: &Network, state_seed: u64);

    /// Draw the next attempt's realization into `out`, evolving internal
    /// state on the private stream. Emission draws follow the
    /// [`Realization::sample_with`] order/count contract exactly. `out` is
    /// resized on first use and refilled in place afterwards — the
    /// Monte-Carlo hot loops pool one buffer per worker.
    fn sample_into(&mut self, net: &Network, rng: &mut Rng, out: &mut Realization);

    /// Allocating convenience form of
    /// [`sample_into`](ChannelModel::sample_into) (draw-for-draw
    /// identical).
    fn sample(&mut self, net: &Network, rng: &mut Rng) -> Realization {
        let mut out = Realization::perfect(net.m);
        self.sample_into(net, rng, &mut out);
        out
    }

    /// Sparse analogue of [`reset`](ChannelModel::reset): re-initialize
    /// per-trial state restricted to `sup`'s links. State storage must be
    /// O(M·(s+1)) — this is what keeps the structured path dense-free.
    fn reset_sparse(&mut self, sup: &SparseSupport, net: &Network, state_seed: u64);

    /// Sparse analogue of [`sample_into`](ChannelModel::sample_into): draw
    /// the next attempt's realization on `sup`'s links only, evolving the
    /// per-link state on the private stream in the same `(row, idx)` /
    /// uplink order as the emission draws.
    fn sample_sparse_into(
        &mut self,
        sup: &SparseSupport,
        net: &Network,
        rng: &mut Rng,
        out: &mut SparseRealization,
    );

    /// Drain the diagnostics accumulated since the last call.
    fn take_stats(&mut self) -> ChannelStats {
        ChannelStats::default()
    }

    /// Nominal wall-clock duration of one communication attempt (the
    /// deadline window for latency models, 1 otherwise).
    fn round_duration(&self) -> f64 {
        1.0
    }

    fn clone_box(&self) -> Box<dyn ChannelModel>;
}

impl Clone for Box<dyn ChannelModel> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

// ── Iid ─────────────────────────────────────────────────────────────────

/// Memoryless i.i.d. Bernoulli erasures — the paper's §II-B model and the
/// degenerate baseline every other model collapses to.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Iid;

impl ChannelModel for Iid {
    fn name(&self) -> &'static str {
        "iid"
    }

    fn reset(&mut self, _net: &Network, _state_seed: u64) {}

    fn sample_into(&mut self, net: &Network, rng: &mut Rng, out: &mut Realization) {
        Realization::sample_with_into(net.m, rng, |i, j| net.p_c2c(i, j), |i| net.p_c2s[i], out);
    }

    fn reset_sparse(&mut self, _sup: &SparseSupport, _net: &Network, _state_seed: u64) {}

    fn sample_sparse_into(
        &mut self,
        sup: &SparseSupport,
        net: &Network,
        rng: &mut Rng,
        out: &mut SparseRealization,
    ) {
        SparseRealization::sample_with_into(
            sup,
            rng,
            |row, _idx, j| net.p_c2c(row, j),
            |i| net.p_c2s[i],
            out,
        );
    }

    fn clone_box(&self) -> Box<dyn ChannelModel> {
        Box::new(Iid)
    }
}

// ── Gilbert–Elliott ─────────────────────────────────────────────────────

/// Per-link two-state (good/bad) Markov bursts: every c2c link and uplink
/// carries its own chain with transition probabilities `p_gb` (good→bad)
/// and `p_bg` (bad→good); in state *x* the link's base outage probability
/// is multiplied by the corresponding scale (clamped to \[0, 1\]).
///
/// Closed forms used by the validation tests: the stationary bad
/// probability is `p_gb / (p_gb + p_bg)` ([`GilbertElliott::stationary_bad`]),
/// the stationary outage probability mixes the two states
/// ([`GilbertElliott::stationary_outage_c2c`]), and bad-state dwell times
/// are Geometric(`p_bg`) with mean `1/p_bg`.
#[derive(Clone, Debug)]
pub struct GilbertElliott {
    /// P(good → bad) per attempt.
    pub p_gb: f64,
    /// P(bad → good) per attempt.
    pub p_bg: f64,
    /// Outage-probability scale of a c2c link in the (good, bad) state.
    pub c2c_scale: (f64, f64),
    /// Outage-probability scale of an uplink in the (good, bad) state.
    pub c2s_scale: (f64, f64),
    m: usize,
    /// `bad_t[m][k]`: the k→m link is in the bad state (diagonal unused).
    bad_t: Vec<Vec<bool>>,
    /// Sparse-path chain states, `bad_ts[row * k + idx]` for the idx-th
    /// supported incoming link of `row` (empty in dense mode). The sparse
    /// and dense state sets are mutually exclusive per reset.
    bad_ts: Vec<bool>,
    bad_tau: Vec<bool>,
    state_rng: Rng,
    stats: ChannelStats,
}

impl GilbertElliott {
    pub fn new(p_gb: f64, p_bg: f64, c2c_scale: (f64, f64), c2s_scale: (f64, f64)) -> Self {
        assert!(
            (0.0..=1.0).contains(&p_gb) && (0.0..=1.0).contains(&p_bg),
            "transition probabilities must be in [0, 1]"
        );
        GilbertElliott {
            p_gb,
            p_bg,
            c2c_scale,
            c2s_scale,
            m: 0,
            bad_t: Vec::new(),
            bad_ts: Vec::new(),
            bad_tau: Vec::new(),
            state_rng: Rng::new(0),
            stats: ChannelStats::default(),
        }
    }

    /// Stationary probability of the bad state, `p_gb / (p_gb + p_bg)`.
    pub fn stationary_bad(&self) -> f64 {
        if self.p_gb + self.p_bg == 0.0 {
            0.0
        } else {
            self.p_gb / (self.p_gb + self.p_bg)
        }
    }

    /// Closed-form stationary outage probability of a c2c link whose base
    /// (i.i.d.) outage probability is `p`.
    pub fn stationary_outage_c2c(&self, p: f64) -> f64 {
        let pb = self.stationary_bad();
        (1.0 - pb) * scaled(p, self.c2c_scale.0) + pb * scaled(p, self.c2c_scale.1)
    }

    /// Closed-form stationary outage probability of an uplink with base `p`.
    pub fn stationary_outage_c2s(&self, p: f64) -> f64 {
        let pb = self.stationary_bad();
        (1.0 - pb) * scaled(p, self.c2s_scale.0) + pb * scaled(p, self.c2s_scale.1)
    }

    /// Whether the k→m c2c link is currently in the bad state (validation
    /// hook for the burst-statistics tests).
    pub fn c2c_bad(&self, m: usize, k: usize) -> bool {
        self.bad_t[m][k]
    }

    /// Advance one chain; returns whether a burst just ended (bad→good),
    /// the event the dwell diagnostics count.
    fn step(bad: &mut bool, p_gb: f64, p_bg: f64, rng: &mut Rng) -> bool {
        let was = *bad;
        *bad = if *bad { !rng.bernoulli(p_bg) } else { rng.bernoulli(p_gb) };
        was && !*bad
    }
}

impl ChannelModel for GilbertElliott {
    fn name(&self) -> &'static str {
        "gilbert_elliott"
    }

    fn reset(&mut self, net: &Network, state_seed: u64) {
        let mut srng = Rng::new(state_seed);
        let pb = self.stationary_bad();
        if self.m != net.m || self.bad_t.len() != net.m {
            // size once; repeated resets of one instance reuse the buffers
            // (fresh clones of an unsized prototype allocate here instead
            // of in clone_box — one allocation per trial either way)
            self.bad_t = vec![vec![false; net.m]; net.m];
            self.bad_ts = Vec::new();
            self.bad_tau = vec![false; net.m];
            self.m = net.m;
        }
        // draw order (row-major c2c, then uplinks) is part of the state
        // stream contract — the mirror tests replay it
        for row in &mut self.bad_t {
            for b in row.iter_mut() {
                *b = srng.bernoulli(pb);
            }
        }
        for b in &mut self.bad_tau {
            *b = srng.bernoulli(pb);
        }
        self.state_rng = srng;
        self.stats = ChannelStats::default();
    }

    fn sample_into(&mut self, net: &Network, rng: &mut Rng, out: &mut Realization) {
        assert_eq!(self.m, net.m, "GilbertElliott: reset() with this network before sampling");
        let m = self.m;
        let mut bad = 0usize;
        for i in 0..m {
            for j in 0..m {
                if i != j && self.bad_t[i][j] {
                    bad += 1;
                }
            }
        }
        bad += self.bad_tau.iter().filter(|&&b| b).count();
        self.stats.samples += 1;
        self.stats.degraded += bad;
        self.stats.degraded_denom += m * m; // (m² − m) c2c links + m uplinks

        // emit from the current states (one draw per link, Iid order)
        let (bad_t, bad_tau) = (&self.bad_t, &self.bad_tau);
        let (cg, cb) = self.c2c_scale;
        let (sg, sb) = self.c2s_scale;
        Realization::sample_with_into(
            m,
            rng,
            |i, j| scaled(net.p_c2c(i, j), if bad_t[i][j] { cb } else { cg }),
            |i| scaled(net.p_c2s[i], if bad_tau[i] { sb } else { sg }),
            out,
        );

        // evolve every chain on the private stream
        let mut ends = 0usize;
        for i in 0..m {
            for j in 0..m {
                if i != j
                    && Self::step(&mut self.bad_t[i][j], self.p_gb, self.p_bg, &mut self.state_rng)
                {
                    ends += 1;
                }
            }
        }
        for i in 0..m {
            if Self::step(&mut self.bad_tau[i], self.p_gb, self.p_bg, &mut self.state_rng) {
                ends += 1;
            }
        }
        self.stats.burst_ends += ends;
    }

    fn reset_sparse(&mut self, sup: &SparseSupport, net: &Network, state_seed: u64) {
        let mut srng = Rng::new(state_seed);
        let pb = self.stationary_bad();
        let (m, k) = (sup.m(), sup.k());
        assert_eq!(net.m, m, "support / network size mismatch");
        if self.m != m || self.bad_ts.len() != m * k {
            self.bad_ts = vec![false; m * k];
            self.bad_t = Vec::new(); // never hold dense state on the sparse path
            self.bad_tau = vec![false; m];
            self.m = m;
        }
        // state-stream order: supported links row-major, then uplinks
        for b in &mut self.bad_ts {
            *b = srng.bernoulli(pb);
        }
        for b in &mut self.bad_tau {
            *b = srng.bernoulli(pb);
        }
        self.state_rng = srng;
        self.stats = ChannelStats::default();
    }

    fn sample_sparse_into(
        &mut self,
        sup: &SparseSupport,
        net: &Network,
        rng: &mut Rng,
        out: &mut SparseRealization,
    ) {
        let (m, k) = (sup.m(), sup.k());
        assert_eq!(
            self.bad_ts.len(),
            m * k,
            "GilbertElliott: reset_sparse() with this support before sampling"
        );
        let bad = self.bad_ts.iter().filter(|&&b| b).count()
            + self.bad_tau.iter().filter(|&&b| b).count();
        self.stats.samples += 1;
        self.stats.degraded += bad;
        self.stats.degraded_denom += m * (k + 1); // M·s c2c links + M uplinks

        let (bad_ts, bad_tau) = (&self.bad_ts, &self.bad_tau);
        let (cg, cb) = self.c2c_scale;
        let (sg, sb) = self.c2s_scale;
        SparseRealization::sample_with_into(
            sup,
            rng,
            |row, idx, j| {
                scaled(net.p_c2c(row, j), if bad_ts[row * k + idx] { cb } else { cg })
            },
            |i| scaled(net.p_c2s[i], if bad_tau[i] { sb } else { sg }),
            out,
        );

        // evolve every chain on the private stream, same order as emission
        let mut ends = 0usize;
        for b in &mut self.bad_ts {
            if Self::step(b, self.p_gb, self.p_bg, &mut self.state_rng) {
                ends += 1;
            }
        }
        for b in &mut self.bad_tau {
            if Self::step(b, self.p_gb, self.p_bg, &mut self.state_rng) {
                ends += 1;
            }
        }
        self.stats.burst_ends += ends;
    }

    fn take_stats(&mut self) -> ChannelStats {
        std::mem::take(&mut self.stats)
    }

    fn clone_box(&self) -> Box<dyn ChannelModel> {
        Box::new(self.clone())
    }
}

// ── Correlated fading ───────────────────────────────────────────────────

/// A shared fade state inducing common-cause outages: while the channel is
/// *faded*, every link's base outage probability is multiplied by
/// `fade_scale` (clamped). The fade is a two-state Markov chain with
/// stationary fade probability `rho` and second eigenvalue `persistence`:
/// `persistence = 0` redraws the fade independently every attempt
/// (memoryless common-cause), larger values make one fade span consecutive
/// attempts — so a deep fade can kill a whole round of GC⁺ repeats. Mean
/// fade dwell is `1 / ((1−persistence)(1−rho))` attempts.
///
/// Outages stay conditionally independent given the fade, so the
/// same-attempt pairwise link correlation has the closed form of
/// [`CorrelatedFading::pairwise_correlation`] for every `persistence`.
#[derive(Clone, Debug)]
pub struct CorrelatedFading {
    /// Stationary probability an attempt is faded (the coupling strength).
    pub rho: f64,
    /// Outage-probability scale during a fade.
    pub fade_scale: f64,
    /// Fade-state persistence λ ∈ \[0, 1\] across attempts.
    pub persistence: f64,
    faded: bool,
    state_rng: Rng,
    stats: ChannelStats,
}

impl CorrelatedFading {
    pub fn new(rho: f64, fade_scale: f64, persistence: f64) -> Self {
        assert!((0.0..=1.0).contains(&rho), "rho must be in [0, 1]");
        assert!((0.0..=1.0).contains(&persistence), "persistence must be in [0, 1]");
        CorrelatedFading {
            rho,
            fade_scale,
            persistence,
            faded: false,
            state_rng: Rng::new(0),
            stats: ChannelStats::default(),
        }
    }

    /// Mean fade dwell time in attempts, `1 / ((1−λ)(1−ρ))`.
    pub fn mean_fade_dwell(&self) -> f64 {
        1.0 / ((1.0 - self.persistence) * (1.0 - self.rho))
    }

    /// Marginal outage probability of a link with base probability `p`.
    pub fn mean_outage(&self, p: f64) -> f64 {
        (1.0 - self.rho) * p + self.rho * scaled(p, self.fade_scale)
    }

    /// Closed-form correlation between the outage indicators of two links
    /// with base probabilities `p1`, `p2`:
    /// `Cov = ρ(1−ρ)(q1−p1)(q2−p2)` with `q = min(1, p·fade_scale)`.
    pub fn pairwise_correlation(&self, p1: f64, p2: f64) -> f64 {
        let (q1, q2) = (scaled(p1, self.fade_scale), scaled(p2, self.fade_scale));
        let cov = self.rho * (1.0 - self.rho) * (q1 - p1) * (q2 - p2);
        let (m1, m2) = (self.mean_outage(p1), self.mean_outage(p2));
        let var = m1 * (1.0 - m1) * m2 * (1.0 - m2);
        if var <= 0.0 {
            0.0
        } else {
            cov / var.sqrt()
        }
    }

    /// Advance the fade chain on the private stream; transition probs are
    /// chosen so the stationary fade probability stays ρ at every λ.
    fn evolve_fade(&mut self) {
        let (rho, lam) = (self.rho, self.persistence);
        let was = self.faded;
        self.faded = if self.faded {
            self.state_rng.bernoulli(lam + (1.0 - lam) * rho)
        } else {
            self.state_rng.bernoulli((1.0 - lam) * rho)
        };
        if was && !self.faded {
            self.stats.burst_ends += 1;
        }
    }
}

impl ChannelModel for CorrelatedFading {
    fn name(&self) -> &'static str {
        "correlated_fading"
    }

    fn reset(&mut self, _net: &Network, state_seed: u64) {
        self.state_rng = Rng::new(state_seed);
        // initial fade state from the stationary distribution
        self.faded = self.state_rng.bernoulli(self.rho);
        self.stats = ChannelStats::default();
    }

    fn sample_into(&mut self, net: &Network, rng: &mut Rng, out: &mut Realization) {
        let m = net.m;
        let faded = self.faded;
        self.stats.samples += 1;
        self.stats.degraded += if faded { m * m } else { 0 };
        self.stats.degraded_denom += m * m;
        let scale = if faded { self.fade_scale } else { 1.0 };
        Realization::sample_with_into(
            m,
            rng,
            |i, j| scaled(net.p_c2c(i, j), scale),
            |i| scaled(net.p_c2s[i], scale),
            out,
        );
        self.evolve_fade();
    }

    fn reset_sparse(&mut self, _sup: &SparseSupport, net: &Network, state_seed: u64) {
        // the fade state is O(1) — the sparse reset is the dense reset
        self.reset(net, state_seed);
    }

    fn sample_sparse_into(
        &mut self,
        sup: &SparseSupport,
        net: &Network,
        rng: &mut Rng,
        out: &mut SparseRealization,
    ) {
        let (m, k) = (sup.m(), sup.k());
        let faded = self.faded;
        self.stats.samples += 1;
        self.stats.degraded += if faded { m * (k + 1) } else { 0 };
        self.stats.degraded_denom += m * (k + 1);
        let scale = if faded { self.fade_scale } else { 1.0 };
        SparseRealization::sample_with_into(
            sup,
            rng,
            |row, _idx, j| scaled(net.p_c2c(row, j), scale),
            |i| scaled(net.p_c2s[i], scale),
            out,
        );
        self.evolve_fade();
    }

    fn take_stats(&mut self) -> ChannelStats {
        std::mem::take(&mut self.stats)
    }

    fn clone_box(&self) -> Box<dyn ChannelModel> {
        Box::new(self.clone())
    }
}

// ── Deadline stragglers ─────────────────────────────────────────────────

/// Shifted-exponential per-link latency with persistent straggler clients:
/// a transmission from source `k` takes `(shift + Exp(rate)) · f_k` where
/// `f_k = slow_factor` while `k` straggles (a per-client Markov state with
/// transitions `p_slow` / `p_recover`) and 1 otherwise. A link is up iff it
/// survives the base Bernoulli erasure **and** its latency beats
/// `deadline`; `deadline = ∞` disables the gate, collapsing to [`Iid`].
///
/// Deadline hits/misses are tallied into [`ChannelStats`] and
/// [`ChannelModel::round_duration`] reports the deadline window, making
/// transmissions-per-round and wall-clock first-class sweep metrics.
#[derive(Clone, Debug)]
pub struct DeadlineStraggler {
    /// Round deadline (`f64::INFINITY` = no deadline).
    pub deadline: f64,
    /// Deterministic latency floor.
    pub shift: f64,
    /// Rate of the exponential latency tail.
    pub rate: f64,
    /// P(normal → straggling) per attempt.
    pub p_slow: f64,
    /// P(straggling → normal) per attempt.
    pub p_recover: f64,
    /// Latency multiplier while straggling.
    pub slow_factor: f64,
    m: usize,
    slow: Vec<bool>,
    /// Scratch deadline-gate buffers, sized once in `reset` and overwritten
    /// every sample — repeated samples within a trial/episode allocate
    /// nothing (per-trial clone+reset still costs one buffer set).
    ok_t: Vec<Vec<bool>>,
    /// Sparse-path deadline gates, `ok_ts[row * k + idx]` (empty in dense
    /// mode); mutually exclusive with `ok_t` per reset.
    ok_ts: Vec<bool>,
    ok_tau: Vec<bool>,
    state_rng: Rng,
    stats: ChannelStats,
}

impl DeadlineStraggler {
    pub fn new(
        deadline: f64,
        shift: f64,
        rate: f64,
        p_slow: f64,
        p_recover: f64,
        slow_factor: f64,
    ) -> Self {
        assert!(deadline > 0.0 && shift >= 0.0 && rate > 0.0 && slow_factor >= 1.0);
        assert!((0.0..=1.0).contains(&p_slow) && (0.0..=1.0).contains(&p_recover));
        DeadlineStraggler {
            deadline,
            shift,
            rate,
            p_slow,
            p_recover,
            slow_factor,
            m: 0,
            slow: Vec::new(),
            ok_t: Vec::new(),
            ok_ts: Vec::new(),
            ok_tau: Vec::new(),
            state_rng: Rng::new(0),
            stats: ChannelStats::default(),
        }
    }

    /// Stationary probability a client is straggling.
    pub fn stationary_slow(&self) -> f64 {
        if self.p_slow + self.p_recover == 0.0 {
            0.0
        } else {
            self.p_slow / (self.p_slow + self.p_recover)
        }
    }

    /// P(latency beats the deadline) for a source with slowdown `factor`.
    pub fn hit_prob(&self, factor: f64) -> f64 {
        if self.deadline.is_infinite() {
            return 1.0;
        }
        let margin = self.deadline / factor - self.shift;
        if margin <= 0.0 {
            0.0
        } else {
            1.0 - (-self.rate * margin).exp()
        }
    }

    /// Closed-form stationary up-probability of a link with base erasure
    /// probability `p` (erasure survival × deadline hit, mixed over the
    /// stationary straggler state).
    pub fn stationary_up(&self, p: f64) -> f64 {
        let ps = self.stationary_slow();
        (1.0 - p) * ((1.0 - ps) * self.hit_prob(1.0) + ps * self.hit_prob(self.slow_factor))
    }

    fn latency(&mut self, src: usize) -> f64 {
        let f = if self.slow[src] { self.slow_factor } else { 1.0 };
        (self.shift + self.state_rng.exponential(self.rate)) * f
    }

    /// Advance every client's straggler chain on the private stream.
    fn evolve_slow(&mut self) {
        let mut ends = 0usize;
        for k in 0..self.slow.len() {
            let cur = self.slow[k];
            self.slow[k] = if cur {
                !self.state_rng.bernoulli(self.p_recover)
            } else {
                self.state_rng.bernoulli(self.p_slow)
            };
            if cur && !self.slow[k] {
                ends += 1;
            }
        }
        self.stats.burst_ends += ends;
    }
}

impl ChannelModel for DeadlineStraggler {
    fn name(&self) -> &'static str {
        "deadline_straggler"
    }

    fn reset(&mut self, net: &Network, state_seed: u64) {
        let mut srng = Rng::new(state_seed);
        let ps = self.stationary_slow();
        if self.m != net.m || self.ok_t.len() != net.m {
            self.slow = vec![false; net.m];
            self.ok_t = vec![vec![true; net.m]; net.m];
            self.ok_ts = Vec::new();
            self.ok_tau = vec![true; net.m];
            self.m = net.m;
        }
        for b in &mut self.slow {
            *b = srng.bernoulli(ps);
        }
        self.state_rng = srng;
        self.stats = ChannelStats::default();
    }

    fn sample_into(&mut self, net: &Network, rng: &mut Rng, out: &mut Realization) {
        assert_eq!(self.m, net.m, "DeadlineStraggler: reset() with this network before sampling");
        let m = self.m;
        self.stats.samples += 1;
        self.stats.degraded += self.slow.iter().filter(|&&s| s).count();
        self.stats.degraded_denom += m;

        // latency gates on the private stream, fixed order: c2c links
        // row-major (source = column), then uplinks (source = client)
        for i in 0..m {
            for j in 0..m {
                if i != j {
                    let hit = self.latency(j) <= self.deadline;
                    self.stats.deadline_hits += hit as usize;
                    self.stats.deadline_total += 1;
                    self.ok_t[i][j] = hit;
                }
            }
        }
        for i in 0..m {
            let hit = self.latency(i) <= self.deadline;
            self.stats.deadline_hits += hit as usize;
            self.stats.deadline_total += 1;
            self.ok_tau[i] = hit;
        }

        // a missed deadline forces the outage (probability 1 still consumes
        // the link's emission draw, preserving the Iid stream alignment)
        let (ok_t, ok_tau) = (&self.ok_t, &self.ok_tau);
        Realization::sample_with_into(
            m,
            rng,
            |i, j| if ok_t[i][j] { net.p_c2c(i, j) } else { 1.0 },
            |i| if ok_tau[i] { net.p_c2s[i] } else { 1.0 },
            out,
        );

        self.evolve_slow();
    }

    fn reset_sparse(&mut self, sup: &SparseSupport, net: &Network, state_seed: u64) {
        let mut srng = Rng::new(state_seed);
        let ps = self.stationary_slow();
        let (m, k) = (sup.m(), sup.k());
        assert_eq!(net.m, m, "support / network size mismatch");
        if self.m != m || self.ok_ts.len() != m * k {
            self.slow = vec![false; m];
            self.ok_ts = vec![true; m * k];
            self.ok_t = Vec::new(); // never hold dense state on the sparse path
            self.ok_tau = vec![true; m];
            self.m = m;
        }
        for b in &mut self.slow {
            *b = srng.bernoulli(ps);
        }
        self.state_rng = srng;
        self.stats = ChannelStats::default();
    }

    fn sample_sparse_into(
        &mut self,
        sup: &SparseSupport,
        net: &Network,
        rng: &mut Rng,
        out: &mut SparseRealization,
    ) {
        let (m, k) = (sup.m(), sup.k());
        assert_eq!(
            self.ok_ts.len(),
            m * k,
            "DeadlineStraggler: reset_sparse() with this support before sampling"
        );
        self.stats.samples += 1;
        self.stats.degraded += self.slow.iter().filter(|&&s| s).count();
        self.stats.degraded_denom += m;

        // latency gates on the private stream, fixed order: supported links
        // row-major (source = neighbour), then uplinks (source = client)
        for row in 0..m {
            for idx in 0..k {
                let src = sup.neighbor(row, idx);
                let hit = self.latency(src) <= self.deadline;
                self.stats.deadline_hits += hit as usize;
                self.stats.deadline_total += 1;
                self.ok_ts[row * k + idx] = hit;
            }
        }
        for i in 0..m {
            let hit = self.latency(i) <= self.deadline;
            self.stats.deadline_hits += hit as usize;
            self.stats.deadline_total += 1;
            self.ok_tau[i] = hit;
        }

        let (ok_ts, ok_tau) = (&self.ok_ts, &self.ok_tau);
        SparseRealization::sample_with_into(
            sup,
            rng,
            |row, idx, j| if ok_ts[row * k + idx] { net.p_c2c(row, j) } else { 1.0 },
            |i| if ok_tau[i] { net.p_c2s[i] } else { 1.0 },
            out,
        );

        self.evolve_slow();
    }

    fn take_stats(&mut self) -> ChannelStats {
        std::mem::take(&mut self.stats)
    }

    fn round_duration(&self) -> f64 {
        if self.deadline.is_finite() {
            self.deadline
        } else {
            1.0
        }
    }

    fn clone_box(&self) -> Box<dyn ChannelModel> {
        Box::new(self.clone())
    }
}

// ── Declarative spec ────────────────────────────────────────────────────

/// Declarative, JSON-round-trippable channel-model spec: the form scenarios
/// are written in ([`crate::scenario::Scenario`]); [`ChannelSpec::build`]
/// instantiates the stateful model.
#[derive(Clone, Debug, PartialEq)]
pub enum ChannelSpec {
    Iid,
    GilbertElliott { p_gb: f64, p_bg: f64, c2c_scale: (f64, f64), c2s_scale: (f64, f64) },
    CorrelatedFading { rho: f64, fade_scale: f64, persistence: f64 },
    DeadlineStraggler {
        deadline: f64,
        shift: f64,
        rate: f64,
        p_slow: f64,
        p_recover: f64,
        slow_factor: f64,
    },
}

impl ChannelSpec {
    pub fn name(&self) -> &'static str {
        match self {
            ChannelSpec::Iid => "iid",
            ChannelSpec::GilbertElliott { .. } => "gilbert_elliott",
            ChannelSpec::CorrelatedFading { .. } => "correlated_fading",
            ChannelSpec::DeadlineStraggler { .. } => "deadline_straggler",
        }
    }

    /// Parameter-range check, mirroring the constructor asserts — lets
    /// user-supplied JSON fail with an error instead of a panic.
    pub fn validate(&self) -> anyhow::Result<()> {
        let prob = |name: &str, p: f64| -> anyhow::Result<()> {
            anyhow::ensure!((0.0..=1.0).contains(&p), "channel {name} must be in [0, 1], got {p}");
            Ok(())
        };
        match *self {
            ChannelSpec::Iid => {}
            ChannelSpec::GilbertElliott { p_gb, p_bg, c2c_scale, c2s_scale } => {
                prob("p_gb", p_gb)?;
                prob("p_bg", p_bg)?;
                for (name, s) in [
                    ("c2c_good", c2c_scale.0),
                    ("c2c_bad", c2c_scale.1),
                    ("c2s_good", c2s_scale.0),
                    ("c2s_bad", c2s_scale.1),
                ] {
                    anyhow::ensure!(s >= 0.0, "channel scale {name} must be ≥ 0, got {s}");
                }
            }
            ChannelSpec::CorrelatedFading { rho, fade_scale, persistence } => {
                prob("rho", rho)?;
                prob("persistence", persistence)?;
                anyhow::ensure!(fade_scale >= 0.0, "fade_scale must be ≥ 0, got {fade_scale}");
            }
            ChannelSpec::DeadlineStraggler {
                deadline,
                shift,
                rate,
                p_slow,
                p_recover,
                slow_factor,
            } => {
                anyhow::ensure!(deadline > 0.0, "deadline must be > 0 (null = none)");
                anyhow::ensure!(shift >= 0.0, "shift must be ≥ 0, got {shift}");
                anyhow::ensure!(rate > 0.0, "rate must be > 0, got {rate}");
                anyhow::ensure!(slow_factor >= 1.0, "slow_factor must be ≥ 1, got {slow_factor}");
                prob("p_slow", p_slow)?;
                prob("p_recover", p_recover)?;
            }
        }
        Ok(())
    }

    /// Instantiate the stateful model (call [`ChannelModel::reset`] before
    /// sampling).
    pub fn build(&self) -> Box<dyn ChannelModel> {
        match *self {
            ChannelSpec::Iid => Box::new(Iid),
            ChannelSpec::GilbertElliott { p_gb, p_bg, c2c_scale, c2s_scale } => {
                Box::new(GilbertElliott::new(p_gb, p_bg, c2c_scale, c2s_scale))
            }
            ChannelSpec::CorrelatedFading { rho, fade_scale, persistence } => {
                Box::new(CorrelatedFading::new(rho, fade_scale, persistence))
            }
            ChannelSpec::DeadlineStraggler {
                deadline,
                shift,
                rate,
                p_slow,
                p_recover,
                slow_factor,
            } => Box::new(DeadlineStraggler::new(
                deadline, shift, rate, p_slow, p_recover, slow_factor,
            )),
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            ChannelSpec::Iid => json::obj(vec![("kind", json::s("iid"))]),
            ChannelSpec::GilbertElliott { p_gb, p_bg, c2c_scale, c2s_scale } => json::obj(vec![
                ("kind", json::s("gilbert_elliott")),
                ("p_gb", json::num(p_gb)),
                ("p_bg", json::num(p_bg)),
                ("c2c_good", json::num(c2c_scale.0)),
                ("c2c_bad", json::num(c2c_scale.1)),
                ("c2s_good", json::num(c2s_scale.0)),
                ("c2s_bad", json::num(c2s_scale.1)),
            ]),
            ChannelSpec::CorrelatedFading { rho, fade_scale, persistence } => json::obj(vec![
                ("kind", json::s("correlated_fading")),
                ("rho", json::num(rho)),
                ("fade_scale", json::num(fade_scale)),
                ("persistence", json::num(persistence)),
            ]),
            ChannelSpec::DeadlineStraggler {
                deadline,
                shift,
                rate,
                p_slow,
                p_recover,
                slow_factor,
            } => json::obj(vec![
                ("kind", json::s("deadline_straggler")),
                // infinity is not representable in JSON: null = no deadline
                ("deadline", if deadline.is_finite() { json::num(deadline) } else { Json::Null }),
                ("shift", json::num(shift)),
                ("rate", json::num(rate)),
                ("p_slow", json::num(p_slow)),
                ("p_recover", json::num(p_recover)),
                ("slow_factor", json::num(slow_factor)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ChannelSpec> {
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("channel kind must be a string"))?;
        let f = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("channel field {key:?} must be a number"))
        };
        Ok(match kind {
            "iid" => ChannelSpec::Iid,
            "gilbert_elliott" => ChannelSpec::GilbertElliott {
                p_gb: f("p_gb")?,
                p_bg: f("p_bg")?,
                c2c_scale: (f("c2c_good")?, f("c2c_bad")?),
                c2s_scale: (f("c2s_good")?, f("c2s_bad")?),
            },
            "correlated_fading" => ChannelSpec::CorrelatedFading {
                rho: f("rho")?,
                fade_scale: f("fade_scale")?,
                // optional for spec ergonomics: omitted = memoryless fades
                persistence: match v.get("persistence") {
                    None | Some(Json::Null) => 0.0,
                    Some(p) => p
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("persistence must be a number"))?,
                },
            },
            "deadline_straggler" => ChannelSpec::DeadlineStraggler {
                deadline: match v.get("deadline") {
                    None | Some(Json::Null) => f64::INFINITY,
                    Some(d) => d
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("deadline must be a number or null"))?,
                },
                shift: f("shift")?,
                rate: f("rate")?,
                p_slow: f("p_slow")?,
                p_recover: f("p_recover")?,
                slow_factor: f("slow_factor")?,
            },
            other => anyhow::bail!("unknown channel kind {other:?}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn homog(m: usize, p: f64) -> Network {
        Network::homogeneous(m, p, p)
    }

    #[test]
    fn iid_model_matches_raw_sampling() {
        let net = Network::homogeneous(8, 0.3, 0.2);
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let mut ch = Iid;
        ch.reset(&net, 123);
        for _ in 0..25 {
            assert_eq!(ch.sample(&net, &mut a), Realization::sample(&net, &mut b));
        }
        assert_eq!(a.next_u64(), b.next_u64(), "streams must stay aligned");
    }

    #[test]
    fn degenerate_gilbert_elliott_is_byte_identical_to_iid() {
        // equal good/bad outage probabilities (scale 1 in both states):
        // the emission stream must match Iid draw for draw, regardless of
        // the burst chain churning on the private stream
        let net = Network::homogeneous(9, 0.35, 0.15);
        let mut ge = GilbertElliott::new(0.3, 0.2, (1.0, 1.0), (1.0, 1.0));
        ge.reset(&net, 77);
        let mut a = Rng::new(4);
        let mut b = Rng::new(4);
        for _ in 0..40 {
            assert_eq!(ge.sample(&net, &mut a), Realization::sample(&net, &mut b));
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn infinite_deadline_straggler_is_byte_identical_to_iid() {
        let net = Network::homogeneous(7, 0.4, 0.25);
        let mut ds = DeadlineStraggler::new(f64::INFINITY, 0.5, 1.0, 0.2, 0.2, 3.0);
        ds.reset(&net, 5);
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        for _ in 0..40 {
            assert_eq!(ds.sample(&net, &mut a), Realization::sample(&net, &mut b));
        }
        assert_eq!(a.next_u64(), b.next_u64());
        // every latency draw beats an infinite deadline
        let st = ds.take_stats();
        assert_eq!(st.deadline_hits, st.deadline_total);
        assert_eq!(st.deadline_total, 40 * (7 * 7 - 7 + 7));
    }

    #[test]
    fn zero_coupling_fading_is_byte_identical_to_iid() {
        let net = Network::homogeneous(6, 0.5, 0.3);
        let mut cf = CorrelatedFading::new(0.0, 10.0, 0.8);
        cf.reset(&net, 3);
        let mut a = Rng::new(2);
        let mut b = Rng::new(2);
        for _ in 0..30 {
            assert_eq!(cf.sample(&net, &mut a), Realization::sample(&net, &mut b));
        }
    }

    #[test]
    fn gilbert_elliott_stationary_outage_matches_closed_form() {
        // fresh stationary state per trial → outage indicators are i.i.d.
        // across trials, so the plain binomial ±2σ band applies
        let net = homog(4, 0.3);
        let mut ge = GilbertElliott::new(0.1, 0.2, (0.5, 2.0), (0.5, 2.0));
        let want = ge.stationary_outage_c2c(0.3);
        let trials = 25_000;
        let mut outages = 0usize;
        for t in 0..trials {
            ge.reset(&net, 1_000 + t as u64);
            let mut rng = Rng::new(50_000 + t as u64);
            let real = ge.sample(&net, &mut rng);
            outages += !real.t[0][1] as usize;
        }
        let est = outages as f64 / trials as f64;
        let sigma = (want * (1.0 - want) / trials as f64).sqrt();
        assert!(
            (est - want).abs() < 2.0 * sigma + 2e-3,
            "stationary outage: closed form {want:.4} vs empirical {est:.4} (2σ = {:.4})",
            2.0 * sigma
        );
    }

    #[test]
    fn gilbert_elliott_long_run_outage_matches_closed_form() {
        // a single long trajectory (state carried across 30k rounds): the
        // Markov correlation inflates the variance, so use a wider band
        let net = homog(3, 0.2);
        let mut ge = GilbertElliott::new(0.15, 0.25, (0.25, 4.0), (0.25, 4.0));
        ge.reset(&net, 99);
        let mut rng = Rng::new(7);
        let rounds = 30_000;
        let mut outages = 0usize;
        for _ in 0..rounds {
            let real = ge.sample(&net, &mut rng);
            outages += !real.t[1][0] as usize;
        }
        let est = outages as f64 / rounds as f64;
        let want = ge.stationary_outage_c2c(0.2);
        let sigma = (want * (1.0 - want) / rounds as f64).sqrt();
        assert!(
            (est - want).abs() < 6.0 * sigma + 5e-3,
            "long-run outage: closed form {want:.4} vs empirical {est:.4}"
        );
    }

    #[test]
    fn gilbert_elliott_burst_lengths_are_geometric() {
        // dwell time in the bad state ~ Geometric(p_bg): mean 1/p_bg and
        // survival P(L > k) = (1 − p_bg)^k
        let p_bg = 0.3;
        let net = homog(2, 0.2);
        let mut ge = GilbertElliott::new(0.2, p_bg, (1.0, 1.0), (1.0, 1.0));
        ge.reset(&net, 17);
        let mut rng = Rng::new(23);
        let mut runs: Vec<usize> = Vec::new();
        let mut cur = 0usize;
        for _ in 0..60_000 {
            let bad = ge.c2c_bad(0, 1);
            ge.sample(&net, &mut rng);
            if bad {
                cur += 1;
            } else if cur > 0 {
                runs.push(cur);
                cur = 0;
            }
        }
        assert!(runs.len() > 3_000, "too few bursts observed: {}", runs.len());
        let mean = runs.iter().sum::<usize>() as f64 / runs.len() as f64;
        let want_mean = 1.0 / p_bg;
        assert!(
            (mean - want_mean).abs() < 0.15,
            "burst mean {mean:.3} vs geometric mean {want_mean:.3}"
        );
        for k in 1..=3usize {
            let surv = runs.iter().filter(|&&l| l > k).count() as f64 / runs.len() as f64;
            let want = (1.0 - p_bg).powi(k as i32);
            assert!(
                (surv - want).abs() < 0.03,
                "P(burst > {k}) = {surv:.3}, geometric predicts {want:.3}"
            );
        }
        // the dwell diagnostics agree: degraded / burst_ends estimates the
        // same geometric mean across all chains (they share p_bg)
        let st = ge.take_stats();
        assert!(st.burst_ends > 3_000, "too few burst ends tallied: {}", st.burst_ends);
        let dwell = st.mean_burst_dwell();
        assert!(
            (dwell - want_mean).abs() < 0.2,
            "stats dwell {dwell:.3} vs geometric mean {want_mean:.3}"
        );
    }

    #[test]
    fn correlated_fading_matches_configured_coupling() {
        // fade draws are i.i.d. per attempt, so attempts are i.i.d. and the
        // empirical pairwise correlation estimates the closed form
        let p = 0.2;
        let net = homog(4, p);
        // persistence 0 keeps attempts i.i.d., so the plain correlation
        // estimator over one trajectory applies
        let mut cf = CorrelatedFading::new(0.3, 4.0, 0.0);
        cf.reset(&net, 31);
        let want = cf.pairwise_correlation(p, p);
        assert!(want > 0.25, "configured coupling should induce strong correlation: {want}");
        let mut rng = Rng::new(13);
        let rounds = 50_000;
        let (mut x, mut y, mut xy) = (0usize, 0usize, 0usize);
        for _ in 0..rounds {
            let real = cf.sample(&net, &mut rng);
            let (a, b) = (!real.t[0][1] as usize, !real.t[2][3] as usize);
            x += a;
            y += b;
            xy += a * b;
        }
        let n = rounds as f64;
        let (mx, my) = (x as f64 / n, y as f64 / n);
        let cov = xy as f64 / n - mx * my;
        let corr = cov / (mx * (1.0 - mx) * my * (1.0 - my)).sqrt();
        assert!(corr > 0.0, "pairwise link correlation must be positive, got {corr}");
        assert!(
            (corr - want).abs() < 0.03,
            "pairwise correlation {corr:.4} vs closed form {want:.4}"
        );
        // marginal sanity
        let want_m = cf.mean_outage(p);
        assert!((mx - want_m).abs() < 0.01, "marginal {mx:.4} vs {want_m:.4}");
    }

    #[test]
    fn fade_dwell_times_are_geometric_with_the_configured_persistence() {
        // fade dwell ~ Geometric((1−λ)(1−ρ)): mean 1/((1−λ)(1−ρ))
        let (rho, lam) = (0.4, 0.5);
        let net = homog(2, 0.2);
        let mut cf = CorrelatedFading::new(rho, 3.0, lam);
        cf.reset(&net, 19);
        let want = cf.mean_fade_dwell();
        assert!((want - 1.0 / (0.5 * 0.6)).abs() < 1e-12);
        let mut rng = Rng::new(29);
        let mut runs: Vec<usize> = Vec::new();
        let mut cur = 0usize;
        let mut faded_rounds = 0usize;
        let rounds = 40_000;
        for _ in 0..rounds {
            let st_before = cf.faded;
            cf.sample(&net, &mut rng);
            faded_rounds += st_before as usize;
            if st_before {
                cur += 1;
            } else if cur > 0 {
                runs.push(cur);
                cur = 0;
            }
        }
        // stationary fade probability stays ρ at every persistence
        let frac = faded_rounds as f64 / rounds as f64;
        assert!((frac - rho).abs() < 0.02, "fade fraction {frac:.3} vs ρ = {rho}");
        assert!(runs.len() > 2_000, "too few fades: {}", runs.len());
        let mean = runs.iter().sum::<usize>() as f64 / runs.len() as f64;
        assert!((mean - want).abs() < 0.2, "fade dwell {mean:.3} vs geometric {want:.3}");
    }

    #[test]
    fn straggler_deadline_hit_rate_matches_closed_form() {
        // all clients normal (p_slow = 0): hit rate = 1 − exp(−rate·(d − shift))
        let net = homog(5, 0.0);
        let mut ds = DeadlineStraggler::new(1.0, 0.2, 1.0, 0.0, 1.0, 2.0);
        ds.reset(&net, 41);
        let want = ds.hit_prob(1.0);
        let mut rng = Rng::new(3);
        let rounds = 2_000;
        for _ in 0..rounds {
            ds.sample(&net, &mut rng);
        }
        let st = ds.take_stats();
        let est = st.deadline_hit_rate();
        let n = st.deadline_total as f64;
        let sigma = (want * (1.0 - want) / n).sqrt();
        assert!(
            (est - want).abs() < 4.0 * sigma + 2e-3,
            "hit rate {est:.4} vs closed form {want:.4}"
        );
        // on a perfect-erasure network the up-rate equals the hit rate
        assert!((ds.stationary_up(0.0) - want).abs() < 1e-12);
    }

    #[test]
    fn straggler_links_fail_when_too_slow_to_ever_hit() {
        // slow_factor large enough that a straggling source can never beat
        // the deadline: hit_prob(slow_factor) = 0
        let ds = DeadlineStraggler::new(1.5, 0.5, 1.0, 0.15, 0.15, 4.0);
        assert_eq!(ds.hit_prob(4.0), 0.0);
        assert!(ds.hit_prob(1.0) > 0.6);
        let up = ds.stationary_up(0.1);
        // half the clients straggle in stationarity → up-rate ≈ 0.9·0.5·hit
        assert!((up - 0.9 * 0.5 * ds.hit_prob(1.0)).abs() < 1e-12);
    }

    #[test]
    fn channel_stats_merge_and_rates() {
        let mut a = ChannelStats {
            samples: 2,
            degraded: 3,
            degraded_denom: 10,
            deadline_hits: 4,
            deadline_total: 5,
            burst_ends: 1,
        };
        a.merge(ChannelStats {
            samples: 1,
            degraded: 1,
            degraded_denom: 10,
            deadline_hits: 1,
            deadline_total: 5,
            burst_ends: 1,
        });
        assert_eq!(a.samples, 3);
        assert!((a.degraded_frac() - 0.2).abs() < 1e-12);
        assert!((a.deadline_hit_rate() - 0.5).abs() < 1e-12);
        assert!((a.mean_burst_dwell() - 2.0).abs() < 1e-12);
        let empty = ChannelStats::default();
        assert_eq!(empty.degraded_frac(), 0.0);
        assert_eq!(empty.deadline_hit_rate(), 1.0);
        assert_eq!(empty.mean_burst_dwell(), 0.0);
    }

    #[test]
    fn spec_json_roundtrip_all_kinds() {
        let specs = [
            ChannelSpec::Iid,
            ChannelSpec::GilbertElliott {
                p_gb: 0.05,
                p_bg: 0.25,
                c2c_scale: (0.5, 8.0),
                c2s_scale: (1.0, 1.0),
            },
            ChannelSpec::CorrelatedFading { rho: 0.2, fade_scale: 5.0, persistence: 0.6 },
            ChannelSpec::DeadlineStraggler {
                deadline: 1.5,
                shift: 0.5,
                rate: 1.0,
                p_slow: 0.15,
                p_recover: 0.15,
                slow_factor: 4.0,
            },
            ChannelSpec::DeadlineStraggler {
                deadline: f64::INFINITY,
                shift: 0.1,
                rate: 2.0,
                p_slow: 0.0,
                p_recover: 1.0,
                slow_factor: 1.0,
            },
        ];
        for spec in &specs {
            let text = spec.to_json().serialize();
            let back = ChannelSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(&back, spec, "roundtrip failed for {text}");
            // the spec builds a model that reports the same name
            assert_eq!(spec.build().name(), spec.name());
        }
        assert!(ChannelSpec::from_json(&Json::parse(r#"{"kind":"warp"}"#).unwrap()).is_err());
    }
}
