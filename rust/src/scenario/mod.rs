//! Stateful channel-scenario engine: bursty / correlated / straggler link
//! models behind a declarative scenario registry.
//!
//! The paper's experiments draw every link as a memoryless i.i.d. Bernoulli
//! erasure, which can only reproduce static operating points. This
//! subsystem opens the regimes the abstract actually warns about — bursty
//! channels, common-cause fades, deadline-bound stragglers — while keeping
//! the determinism contract of the parallel engine intact:
//!
//! - [`channel`] — the stateful [`ChannelModel`] trait and its four
//!   implementations ([`Iid`], [`GilbertElliott`], [`CorrelatedFading`],
//!   [`DeadlineStraggler`]), each with closed-form stationary statistics
//!   for validation and a degenerate configuration that collapses
//!   byte-identically to i.i.d.;
//! - [`adversary`] — the Byzantine dimension: [`AdversarySpec`] /
//!   [`AdversaryModel`] (malicious-client selection × attack strategy ×
//!   corruption surface), sampled per trial on its own substream so a
//!   fraction-0 adversary is byte-identical to no adversary at all;
//! - [`policy`] — degraded-mode recovery: [`RecoveryPolicy`] (bounded
//!   retransmission with backoff and a round deadline budget, the
//!   exact→approximate decode fallback threshold, and deterministic
//!   link-fault injection) applied by the [`PolicyChannel`] wrapper on a
//!   private substream, so a passive policy is byte-identical to none;
//! - [`registry`] — the declarative, JSON-round-trippable [`Scenario`]
//!   spec (network × channel × decoder × schedule) and the built-in
//!   catalog (`cogc scenario list`);
//! - [`sweep`] — [`run_scenario`]: many independent episodes of
//!   `rounds` consecutive rounds each, fanned over the Monte-Carlo engine
//!   into a per-round [`RoundSeries`] that is bit-identical at any
//!   `--threads` value.
//!
//! Entry points: `cogc scenario list | run <name>` on the CLI, or
//! [`crate::figures::scenario_sweep`] for the CSV time series.

pub mod adversary;
pub mod channel;
pub mod policy;
pub mod registry;
pub mod sweep;

pub use adversary::{
    AdversaryModel, AdversarySpec, Attack, FrAttemptAudit, GroupVerdict, Selection, Surface,
    ADVERSARY_STREAM,
};
pub use channel::{
    ChannelModel, ChannelSpec, ChannelStats, CorrelatedFading, DeadlineStraggler, GilbertElliott,
    Iid, CHANNEL_STREAM,
};
pub use policy::{Crash, PolicyChannel, PolicyStats, RecoveryPolicy, POLICY_STREAM};
pub use registry::{builtin, find, NetworkSpec, Scenario};
pub use sweep::{run_scenario, run_scenario_fr, RoundSeries, RoundTally};
