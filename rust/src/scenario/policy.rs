//! Declarative recovery policies: bounded per-link retransmission with
//! exponential backoff, per-round deadline budgets, an exact→approximate
//! decode fallback threshold, and deterministic link-fault injection
//! (forced uplink/c2c kill lists, mid-round crash-and-rejoin).
//!
//! # Determinism contract
//!
//! Retransmission success draws come from a **private policy stream**
//! (seeded per trial from the [`POLICY_STREAM`] substream), never from the
//! emission stream. The wrapped inner channel consumes its emission and
//! state draws exactly as it would unwrapped, so a passive policy
//! ([`RecoveryPolicy::is_passive`]) reproduces every existing scenario
//! tally byte-for-byte — the sweep layer dispatches passive configs to the
//! unwrapped code paths, and `tests/` assert the equivalence.
//!
//! Fault injection (kills, crash windows) is applied *after* the inner
//! sample and consumes no draws at all; retransmission then runs over the
//! post-fault realization, skipping the forced-down links.

use super::channel::{ChannelModel, ChannelStats};
use crate::network::{Network, Realization, SparseRealization, SparseSupport};
use crate::parallel::Accumulate;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Tag of the per-trial policy substream (retransmission success draws).
/// Distinct from `CHANNEL_STREAM` and `ADVERSARY_STREAM` so enabling a
/// policy never perturbs channel or adversary randomness.
pub const POLICY_STREAM: u64 = 0x9E7C_11CE;

/// A mid-episode crash-and-rejoin fault: `client` drops off the network
/// (uplink and every c2c link touching it) for rounds
/// `[at_round, at_round + down_rounds)`, then rejoins.
#[derive(Clone, Debug, PartialEq)]
pub struct Crash {
    pub client: usize,
    pub at_round: usize,
    pub down_rounds: usize,
}

/// Declarative degraded-mode recovery policy. The default value is
/// *passive*: no retries, no fallback, no faults — and the sweep layer
/// guarantees a passive policy is byte-identical to no policy at all.
#[derive(Clone, Debug, PartialEq)]
pub struct RecoveryPolicy {
    /// Max retransmit attempts per failed link per communication attempt.
    pub retries: usize,
    /// Exponential backoff base: the k-th retry of a link costs
    /// `backoff^(k-1)` channel time-steps against the round's budget.
    pub backoff: f64,
    /// Per-round retransmission time budget in channel time-steps;
    /// `0` means unlimited.
    pub deadline: f64,
    /// Switch exact→approximate decoding when GC⁺ reports the sum row
    /// unreachable (runs the round under [`crate::sim::Decoder::Approx`]).
    pub fallback: bool,
    /// Accept an approximate round only when its relative residual
    /// (`‖𝟙 − w·A‖/√M`) is at most this; rejected rounds tally as outages.
    pub fallback_residual: f64,
    /// Uplinks forced down every attempt (fault injection).
    pub kill_uplinks: Vec<usize>,
    /// c2c links `(dst, src)` forced down every attempt (fault injection).
    pub kill_c2c: Vec<(usize, usize)>,
    /// Optional mid-episode crash-and-rejoin fault.
    pub crash: Option<Crash>,
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy {
            retries: 0,
            backoff: 2.0,
            deadline: 0.0,
            fallback: false,
            fallback_residual: 1.0,
            kill_uplinks: Vec::new(),
            kill_c2c: Vec::new(),
            crash: None,
        }
    }
}

impl RecoveryPolicy {
    /// True when the policy changes nothing: no retries, no fallback, no
    /// injected faults. Passive configs must (and do) reproduce the
    /// policy-free code paths bit-for-bit.
    pub fn is_passive(&self) -> bool {
        self.retries == 0
            && !self.fallback
            && self.kill_uplinks.is_empty()
            && self.kill_c2c.is_empty()
            && self.crash.is_none()
    }

    /// One-line human summary for table comments.
    pub fn summary(&self) -> String {
        let mut parts = vec![format!("retry={}", self.retries)];
        if self.retries > 0 {
            parts.push(format!("backoff={}", self.backoff));
            if self.deadline > 0.0 {
                parts.push(format!("deadline={}", self.deadline));
            }
        }
        if self.fallback {
            parts.push(format!("approx<={}", self.fallback_residual));
        }
        if !self.kill_uplinks.is_empty() {
            parts.push(format!("kill_up={:?}", self.kill_uplinks));
        }
        if !self.kill_c2c.is_empty() {
            parts.push(format!("kill_c2c={:?}", self.kill_c2c));
        }
        if let Some(c) = &self.crash {
            parts.push(format!("crash={}@{}+{}", c.client, c.at_round, c.down_rounds));
        }
        format!("policy({})", parts.join(", "))
    }

    /// Validate against a network size `m` (0 skips the index checks —
    /// used before the topology is known).
    pub fn validate(&self, m: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.backoff.is_finite() && self.backoff >= 1.0,
            "policy backoff must be >= 1, got {} (each retry must cost at least one time-step)",
            self.backoff
        );
        anyhow::ensure!(
            self.deadline.is_finite() && self.deadline >= 0.0,
            "policy deadline must be >= 0 (0 = unlimited), got {}",
            self.deadline
        );
        anyhow::ensure!(
            self.fallback_residual.is_finite() && (0.0..=1.0).contains(&self.fallback_residual),
            "policy fallback threshold must be in [0, 1], got {} \
             (it bounds the relative residual |1 - w*A|/sqrt(M))",
            self.fallback_residual
        );
        if m > 0 {
            for &i in &self.kill_uplinks {
                anyhow::ensure!(i < m, "policy kill_uplinks index {i} out of range for M={m}");
            }
            for &(i, j) in &self.kill_c2c {
                anyhow::ensure!(
                    i < m && j < m && i != j,
                    "policy kill_c2c link ({i}, {j}) invalid for M={m} \
                     (need dst != src, both < M)"
                );
            }
            if let Some(c) = &self.crash {
                anyhow::ensure!(
                    c.client < m,
                    "policy crash client {} out of range for M={m}",
                    c.client
                );
            }
        }
        if let Some(c) = &self.crash {
            anyhow::ensure!(
                c.down_rounds > 0,
                "policy crash down_rounds must be > 0 (a 0-round crash is no crash)"
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("retries", json::num(self.retries as f64))];
        // defaults are omitted so minimal specs stay minimal
        if self.backoff != 2.0 {
            fields.push(("backoff", json::num(self.backoff)));
        }
        if self.deadline != 0.0 {
            fields.push(("deadline", json::num(self.deadline)));
        }
        if self.fallback {
            fields.push(("fallback", Json::Bool(true)));
        }
        if self.fallback_residual != 1.0 {
            fields.push(("fallback_residual", json::num(self.fallback_residual)));
        }
        if !self.kill_uplinks.is_empty() {
            fields.push((
                "kill_uplinks",
                Json::Arr(self.kill_uplinks.iter().map(|&i| json::num(i as f64)).collect()),
            ));
        }
        if !self.kill_c2c.is_empty() {
            fields.push((
                "kill_c2c",
                Json::Arr(
                    self.kill_c2c
                        .iter()
                        .map(|&(i, j)| Json::Arr(vec![json::num(i as f64), json::num(j as f64)]))
                        .collect(),
                ),
            ));
        }
        if let Some(c) = &self.crash {
            fields.push((
                "crash",
                json::obj(vec![
                    ("client", json::num(c.client as f64)),
                    ("at_round", json::num(c.at_round as f64)),
                    ("down_rounds", json::num(c.down_rounds as f64)),
                ]),
            ));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<RecoveryPolicy> {
        let usize_field = |v: &Json, key: &str| -> anyhow::Result<usize> {
            v.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("policy field {key:?} must be a non-negative integer"))
        };
        let mut p = RecoveryPolicy { retries: usize_field(v, "retries")?, ..Default::default() };
        if let Some(x) = v.get("backoff") {
            p.backoff = x
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("policy backoff must be a number"))?;
        }
        if let Some(x) = v.get("deadline") {
            p.deadline = x
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("policy deadline must be a number"))?;
        }
        if let Some(x) = v.get("fallback") {
            p.fallback = x
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("policy fallback must be a bool"))?;
        }
        if let Some(x) = v.get("fallback_residual") {
            p.fallback_residual = x
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("policy fallback_residual must be a number"))?;
        }
        if let Some(arr) = v.get("kill_uplinks") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("policy kill_uplinks must be an array"))?;
            for x in arr {
                p.kill_uplinks.push(x.as_usize().ok_or_else(|| {
                    anyhow::anyhow!("policy kill_uplinks entries must be integers")
                })?);
            }
        }
        if let Some(arr) = v.get("kill_c2c") {
            let arr = arr
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("policy kill_c2c must be an array"))?;
            for pair in arr {
                let pair = pair.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                    anyhow::anyhow!("policy kill_c2c entries must be [dst, src] pairs")
                })?;
                let i = pair[0]
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("policy kill_c2c indices must be integers"))?;
                let j = pair[1]
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("policy kill_c2c indices must be integers"))?;
                p.kill_c2c.push((i, j));
            }
        }
        if let Some(c) = v.get("crash") {
            p.crash = Some(Crash {
                client: usize_field(c, "client")?,
                at_round: usize_field(c, "at_round")?,
                down_rounds: usize_field(c, "down_rounds")?,
            });
        }
        p.validate(0)?;
        Ok(p)
    }

    /// Parse the compact CLI form
    /// `retry:<n>[:backoff=<b>][:deadline=<d>][:approx[=<thr>]]`
    /// `[:kill_up=<i,...>][:kill_c2c=<i-j,...>][:crash=<c>@<r>+<n>]`,
    /// e.g. `retry:2`, `retry:3:deadline=8:approx=0.5`,
    /// `retry:0:kill_up=0,3:crash=1@5+10`.
    pub fn parse_cli(text: &str) -> anyhow::Result<RecoveryPolicy> {
        let mut it = text.split(':');
        let head = it.next().unwrap_or("");
        anyhow::ensure!(
            head == "retry",
            "policy spec must start with retry:<n>, got {text:?}"
        );
        let retries: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("policy spec needs retry:<n>, got {text:?}"))?
            .parse()
            .map_err(|_| anyhow::anyhow!("bad policy retry count in {text:?}"))?;
        let mut p = RecoveryPolicy { retries, ..Default::default() };
        for tok in it {
            let (key, val) = match tok.split_once('=') {
                Some((k, v)) => (k, Some(v)),
                None => (tok, None),
            };
            match (key, val) {
                ("approx", None) => p.fallback = true,
                ("approx", Some(v)) => {
                    p.fallback = true;
                    p.fallback_residual = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad approx threshold in {text:?}"))?;
                }
                ("backoff", Some(v)) => {
                    p.backoff =
                        v.parse().map_err(|_| anyhow::anyhow!("bad backoff in {text:?}"))?;
                }
                ("deadline", Some(v)) => {
                    p.deadline =
                        v.parse().map_err(|_| anyhow::anyhow!("bad deadline in {text:?}"))?;
                }
                ("kill_up", Some(v)) => {
                    for part in v.split(',') {
                        p.kill_uplinks.push(part.parse().map_err(|_| {
                            anyhow::anyhow!("bad kill_up index {part:?} in {text:?}")
                        })?);
                    }
                }
                ("kill_c2c", Some(v)) => {
                    for part in v.split(',') {
                        let (i, j) = part.split_once('-').ok_or_else(|| {
                            anyhow::anyhow!("kill_c2c wants <dst>-<src> pairs, got {part:?}")
                        })?;
                        let i = i.parse().map_err(|_| {
                            anyhow::anyhow!("bad kill_c2c index {i:?} in {text:?}")
                        })?;
                        let j = j.parse().map_err(|_| {
                            anyhow::anyhow!("bad kill_c2c index {j:?} in {text:?}")
                        })?;
                        p.kill_c2c.push((i, j));
                    }
                }
                ("crash", Some(v)) => {
                    let (client, rest) = v.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("crash wants <client>@<round>+<down>, got {v:?}")
                    })?;
                    let (at, down) = rest.split_once('+').ok_or_else(|| {
                        anyhow::anyhow!("crash wants <client>@<round>+<down>, got {v:?}")
                    })?;
                    let parse = |s: &str, what: &str| -> anyhow::Result<usize> {
                        s.parse().map_err(|_| anyhow::anyhow!("bad crash {what} in {text:?}"))
                    };
                    p.crash = Some(Crash {
                        client: parse(client, "client")?,
                        at_round: parse(at, "round")?,
                        down_rounds: parse(down, "down count")?,
                    });
                }
                _ => anyhow::bail!("bad policy spec token {tok:?} in {text:?}"),
            }
        }
        p.validate(0)?;
        Ok(p)
    }
}

/// Per-round retransmission diagnostics (all integer tallies — merges
/// exactly under the parallel engine).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PolicyStats {
    /// Retransmission attempts drawn.
    pub retries: usize,
    /// Links brought up by a retransmission.
    pub recovered: usize,
    /// Link-retry sequences cut short by the round deadline budget.
    pub budget_exhausted: usize,
    /// Link-attempts forced down by kills or an active crash window.
    pub killed: usize,
}

impl Accumulate for PolicyStats {
    fn merge(&mut self, other: Self) {
        self.retries += other.retries;
        self.recovered += other.recovered;
        self.budget_exhausted += other.budget_exhausted;
        self.killed += other.killed;
    }
}

/// [`ChannelModel`] wrapper that applies a [`RecoveryPolicy`] on top of an
/// inner model: the inner sample happens first and consumes its emission
/// and state draws unchanged; faults and retransmissions post-process the
/// realization using only the private policy stream.
///
/// Drive it per trial with [`reset`](ChannelModel::reset) (inner state,
/// `CHANNEL_STREAM` seed) **and** [`PolicyChannel::reset_policy`]
/// (`POLICY_STREAM` seed), then [`PolicyChannel::set_round`] before each
/// round to roll the crash window and refill the deadline budget.
pub struct PolicyChannel {
    policy: RecoveryPolicy,
    inner: Box<dyn ChannelModel>,
    rng: Rng,
    /// Remaining retransmission time budget for the current round.
    budget_left: f64,
    /// Current round's crash victim, if the crash window is active.
    crashed: Option<usize>,
    stats: PolicyStats,
}

impl PolicyChannel {
    pub fn new(policy: RecoveryPolicy, inner: Box<dyn ChannelModel>) -> PolicyChannel {
        PolicyChannel {
            policy,
            inner,
            rng: Rng::new(0),
            budget_left: 0.0,
            crashed: None,
            stats: PolicyStats::default(),
        }
    }

    /// Seed the private retransmission stream for a new trial. Derive
    /// `seed` from the [`POLICY_STREAM`] substream.
    pub fn reset_policy(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
        self.budget_left = 0.0;
        self.crashed = None;
        self.stats = PolicyStats::default();
    }

    /// Enter round `r` of the episode: refill the retransmission budget
    /// and roll the crash window.
    pub fn set_round(&mut self, r: usize) {
        self.budget_left = if self.policy.deadline > 0.0 { self.policy.deadline } else { f64::INFINITY };
        self.crashed = self.policy.crash.as_ref().and_then(|c| {
            (r >= c.at_round && r < c.at_round + c.down_rounds).then_some(c.client)
        });
    }

    /// Drain the retransmission diagnostics accumulated since last call.
    pub fn take_policy_stats(&mut self) -> PolicyStats {
        std::mem::take(&mut self.stats)
    }

    /// Retry a single failed link with success probability `1 - p_out`.
    /// Returns true when a retransmission got through.
    fn retry_link(&mut self, p_out: f64) -> bool {
        for k in 0..self.policy.retries {
            let cost = self.policy.backoff.powi(k as i32);
            if cost > self.budget_left {
                self.stats.budget_exhausted += 1;
                return false;
            }
            self.budget_left -= cost;
            self.stats.retries += 1;
            if !self.rng.bernoulli(p_out) {
                self.stats.recovered += 1;
                return true;
            }
        }
        false
    }

    fn apply(&mut self, net: &Network, out: &mut Realization) {
        let m = net.m;
        // 1) fault injection: forced kills and the crash window
        for &i in &self.policy.kill_uplinks {
            if out.tau[i] {
                self.stats.killed += 1;
            }
            out.tau[i] = false;
        }
        for &(i, j) in &self.policy.kill_c2c {
            if out.t[i][j] {
                self.stats.killed += 1;
            }
            out.t[i][j] = false;
        }
        if let Some(c) = self.crashed {
            if out.tau[c] {
                self.stats.killed += 1;
            }
            out.tau[c] = false;
            for i in 0..m {
                if i == c {
                    continue;
                }
                // the crashed client neither sends nor receives
                self.stats.killed += (out.t[i][c] as usize) + (out.t[c][i] as usize);
                out.t[i][c] = false;
                out.t[c][i] = false;
            }
        }
        if self.policy.retries == 0 {
            return;
        }
        // 2) retransmission: fixed scan order (uplinks, then c2c row-major)
        //    so the policy stream is consumed identically at any thread
        //    count; killed/crashed links are not retried.
        for i in 0..m {
            if out.tau[i]
                || self.crashed == Some(i)
                || self.policy.kill_uplinks.contains(&i)
            {
                continue;
            }
            if self.retry_link(net.p_c2s[i]) {
                out.tau[i] = true;
            }
        }
        for i in 0..m {
            for j in 0..m {
                if i == j
                    || out.t[i][j]
                    || self.crashed == Some(i)
                    || self.crashed == Some(j)
                    || self.policy.kill_c2c.contains(&(i, j))
                {
                    continue;
                }
                if self.retry_link(net.p_c2c(i, j)) {
                    out.t[i][j] = true;
                }
            }
        }
    }
}

impl Clone for PolicyChannel {
    fn clone(&self) -> PolicyChannel {
        PolicyChannel {
            policy: self.policy.clone(),
            inner: self.inner.clone(),
            rng: self.rng.clone(),
            budget_left: self.budget_left,
            crashed: self.crashed,
            stats: self.stats.clone(),
        }
    }
}

impl ChannelModel for PolicyChannel {
    fn name(&self) -> &'static str {
        "policy"
    }

    fn reset(&mut self, net: &Network, state_seed: u64) {
        self.inner.reset(net, state_seed);
    }

    fn sample_into(&mut self, net: &Network, rng: &mut Rng, out: &mut Realization) {
        self.inner.sample_into(net, rng, out);
        self.apply(net, out);
    }

    fn reset_sparse(&mut self, sup: &SparseSupport, net: &Network, state_seed: u64) {
        // the sparse (FR) path never carries a policy — Scenario::validate
        // rejects the combination — so this is pure delegation
        self.inner.reset_sparse(sup, net, state_seed);
    }

    fn sample_sparse_into(
        &mut self,
        sup: &SparseSupport,
        net: &Network,
        rng: &mut Rng,
        out: &mut SparseRealization,
    ) {
        self.inner.sample_sparse_into(sup, net, rng, out);
    }

    fn take_stats(&mut self) -> ChannelStats {
        self.inner.take_stats()
    }

    fn round_duration(&self) -> f64 {
        self.inner.round_duration()
    }

    fn clone_box(&self) -> Box<dyn ChannelModel> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Iid;

    fn sample(ch: &mut PolicyChannel, net: &Network, seed: u64) -> Realization {
        let mut rng = Rng::new(seed);
        let mut out = Realization::perfect(net.m);
        ch.set_round(0);
        ch.sample_into(net, &mut rng, &mut out);
        out
    }

    #[test]
    fn passive_policy_is_draw_identical_to_the_inner_model() {
        let net = Network::homogeneous(6, 0.4, 0.4);
        let mut plain = Iid;
        let mut wrapped = PolicyChannel::new(RecoveryPolicy::default(), Box::new(Iid));
        wrapped.reset_policy(99);
        assert!(wrapped.policy.is_passive());
        for seed in 0..20u64 {
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            let mut a = Realization::perfect(net.m);
            let mut b = Realization::perfect(net.m);
            plain.sample_into(&net, &mut ra, &mut a);
            wrapped.set_round(0);
            wrapped.sample_into(&net, &mut rb, &mut b);
            assert_eq!(a.t, b.t, "seed {seed}");
            assert_eq!(a.tau, b.tau, "seed {seed}");
            // the emission stream advanced identically
            assert_eq!(ra.next_u64(), rb.next_u64(), "seed {seed}");
        }
    }

    #[test]
    fn kill_lists_force_links_down() {
        let net = Network::perfect(5);
        let policy = RecoveryPolicy {
            kill_uplinks: vec![1, 3],
            kill_c2c: vec![(0, 2), (4, 0)],
            ..Default::default()
        };
        policy.validate(5).unwrap();
        let mut ch = PolicyChannel::new(policy, Box::new(Iid));
        ch.reset_policy(7);
        let out = sample(&mut ch, &net, 1);
        assert!(!out.tau[1] && !out.tau[3]);
        assert!(out.tau[0] && out.tau[2] && out.tau[4]);
        assert!(!out.t[0][2] && !out.t[4][0]);
        assert!(out.t[2][0], "only the listed direction dies");
        let st = ch.take_policy_stats();
        assert_eq!(st.killed, 4);
        assert_eq!(st.retries, 0);
    }

    #[test]
    fn crash_window_isolates_the_client_then_rejoins() {
        let net = Network::perfect(4);
        let policy = RecoveryPolicy {
            crash: Some(Crash { client: 2, at_round: 1, down_rounds: 2 }),
            ..Default::default()
        };
        let mut ch = PolicyChannel::new(policy, Box::new(Iid));
        ch.reset_policy(3);
        for round in 0..4 {
            let mut rng = Rng::new(round as u64);
            let mut out = Realization::perfect(4);
            ch.set_round(round);
            ch.sample_into(&net, &mut rng, &mut out);
            let down = round == 1 || round == 2;
            assert_eq!(out.tau[2], !down, "round {round}");
            assert_eq!(out.t[0][2], !down, "round {round}");
            assert_eq!(out.t[2][0], !down, "round {round}");
            assert!(out.t[2][2], "diagonal survives the crash");
            assert!(out.tau[0] && out.t[1][0], "others unaffected");
        }
    }

    #[test]
    fn retries_recover_links_and_respect_the_budget() {
        // deterministic inner: all links always down, policy always
        // succeeds on retry (p_out = 0 in the retry draw ⇒ bernoulli(0)
        // never fires) — every link comes back up until the budget runs
        // out.
        let net = Network::homogeneous(4, 0.0, 0.0); // p_out = 0 ⇒ retry always succeeds
        let all_down = Network::homogeneous(4, 1.0, 1.0);
        let policy = RecoveryPolicy { retries: 2, backoff: 2.0, ..Default::default() };
        let mut ch = PolicyChannel::new(policy, Box::new(Iid));
        ch.reset_policy(11);
        let mut rng = Rng::new(5);
        let mut out = Realization::perfect(4);
        ch.set_round(0);
        // inner samples from the all-down network, retries draw against
        // the perfect network's p_out = 0
        ch.inner.sample_into(&all_down, &mut rng, &mut out);
        ch.apply(&net, &mut out);
        assert!(out.tau.iter().all(|&x| x), "unlimited budget recovers every uplink");
        assert!((0..4).all(|i| (0..4).all(|j| out.t[i][j])));
        let st = ch.take_policy_stats();
        assert_eq!(st.recovered, 4 + 12, "4 uplinks + 12 off-diagonal links");
        assert_eq!(st.retries, st.recovered, "first retry always succeeds here");

        // now a budget that only covers the first few links
        let policy = RecoveryPolicy { retries: 1, backoff: 1.0, deadline: 3.0, ..Default::default() };
        let mut ch = PolicyChannel::new(policy, Box::new(Iid));
        ch.reset_policy(11);
        let mut out = Realization::perfect(4);
        ch.inner.sample_into(&all_down, &mut Rng::new(5), &mut out);
        ch.set_round(0);
        ch.apply(&net, &mut out);
        let st = ch.take_policy_stats();
        assert_eq!(st.retries, 3, "budget of 3 unit-cost retries");
        assert_eq!(st.recovered, 3);
        assert!(st.budget_exhausted > 0);
        assert_eq!(out.tau.iter().filter(|&&x| x).count(), 3);
    }

    #[test]
    fn policy_stream_is_independent_of_the_emission_stream() {
        // identical emission seeds, different policy seeds ⇒ the inner
        // realization (pre-policy) is identical while recoveries differ;
        // identical policy seeds ⇒ everything is identical.
        let net = Network::homogeneous(6, 0.7, 0.7);
        let policy = RecoveryPolicy { retries: 1, ..Default::default() };
        let run = |pseed: u64| {
            let mut ch = PolicyChannel::new(policy.clone(), Box::new(Iid));
            ch.reset_policy(pseed);
            sample(&mut ch, &net, 42)
        };
        let a = run(1);
        let b = run(1);
        assert_eq!(a.t, b.t);
        assert_eq!(a.tau, b.tau);
        let c = run(2);
        assert!(
            a.t != c.t || a.tau != c.tau,
            "different policy seeds should recover different links at p=0.7"
        );
    }

    #[test]
    fn cli_roundtrips_through_json() {
        for text in [
            "retry:2",
            "retry:3:backoff=1.5:deadline=8:approx=0.5",
            "retry:0:kill_up=0,3:kill_c2c=1-2,4-0:crash=1@5+10",
            "retry:1:approx",
        ] {
            let p = RecoveryPolicy::parse_cli(text).unwrap();
            let back = RecoveryPolicy::from_json(&p.to_json()).unwrap();
            assert_eq!(p, back, "{text}");
        }
        assert!(RecoveryPolicy::parse_cli("retry:2").unwrap().is_passive() == false);
        assert!(RecoveryPolicy::parse_cli("retry:0").unwrap().is_passive());
    }

    #[test]
    fn bad_specs_are_rejected_with_messages() {
        for text in [
            "retries:2",          // wrong head
            "retry",              // missing count
            "retry:x",            // non-numeric count
            "retry:2:bogus=1",    // unknown key
            "retry:2:approx=2.0", // threshold out of range
            "retry:2:backoff=0.5",
            "retry:0:crash=1@5",  // malformed crash
            "retry:0:kill_c2c=12",
        ] {
            assert!(RecoveryPolicy::parse_cli(text).is_err(), "{text:?} should fail");
        }
        let err = RecoveryPolicy { backoff: 0.0, ..Default::default() }.validate(0).unwrap_err();
        assert!(err.to_string().contains("backoff"), "{err}");
        let err = RecoveryPolicy {
            kill_uplinks: vec![9],
            ..Default::default()
        }
        .validate(4)
        .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }
}
