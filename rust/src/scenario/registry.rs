//! Declarative scenario specs and the built-in registry.
//!
//! A [`Scenario`] names one complete experiment: a network topology, a
//! (possibly stateful) channel model, a decode policy, and a schedule
//! (rounds per episode). Scenarios round-trip through JSON
//! (`util::json`), so custom ones load from a file
//! (`cogc scenario run --file my.json`); the [`builtin`] registry ships
//! named scenarios spanning the good / bursty / correlated / straggler
//! regimes the paper's abstract warns about.

use super::adversary::{AdversarySpec, Attack, Selection, Surface};
use super::channel::ChannelSpec;
use super::policy::{Crash, RecoveryPolicy};
use crate::gc::CodeFamily;
use crate::network::Network;
use crate::sim::Decoder;
use crate::util::json::{self, Json};

/// Declarative network spec (the subset of constructors scenarios need;
/// every paper topology is expressible as one of these).
#[derive(Clone, Debug, PartialEq)]
pub enum NetworkSpec {
    /// Every uplink fails w.p. `p_ps`, every c2c link w.p. `p_cc`.
    Homogeneous { m: usize, p_ps: f64, p_cc: f64 },
    /// Perfect connectivity (the ideal-FL baseline).
    Perfect { m: usize },
}

impl NetworkSpec {
    pub fn m(&self) -> usize {
        match *self {
            NetworkSpec::Homogeneous { m, .. } | NetworkSpec::Perfect { m } => m,
        }
    }

    pub fn build(&self) -> Network {
        match *self {
            NetworkSpec::Homogeneous { m, p_ps, p_cc } => Network::homogeneous(m, p_ps, p_cc),
            NetworkSpec::Perfect { m } => Network::perfect(m),
        }
    }

    /// Parameter-range check, mirroring the `Network` constructor asserts —
    /// lets user-supplied JSON fail with an error instead of a panic.
    pub fn validate(&self) -> anyhow::Result<()> {
        if let NetworkSpec::Homogeneous { p_ps, p_cc, .. } = *self {
            anyhow::ensure!((0.0..=1.0).contains(&p_ps), "p_ps must be in [0, 1], got {p_ps}");
            anyhow::ensure!((0.0..=1.0).contains(&p_cc), "p_cc must be in [0, 1], got {p_cc}");
        }
        Ok(())
    }

    /// One-line human summary for tables/CSV comments.
    pub fn summary(&self) -> String {
        match *self {
            NetworkSpec::Homogeneous { m, p_ps, p_cc } => {
                format!("homogeneous(m={m}, p_ps={p_ps}, p_cc={p_cc})")
            }
            NetworkSpec::Perfect { m } => format!("perfect(m={m})"),
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            NetworkSpec::Homogeneous { m, p_ps, p_cc } => json::obj(vec![
                ("kind", json::s("homogeneous")),
                ("m", json::num(m as f64)),
                ("p_ps", json::num(p_ps)),
                ("p_cc", json::num(p_cc)),
            ]),
            NetworkSpec::Perfect { m } => {
                json::obj(vec![("kind", json::s("perfect")), ("m", json::num(m as f64))])
            }
        }
    }

    pub fn from_json(v: &Json) -> anyhow::Result<NetworkSpec> {
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("network kind must be a string"))?;
        let m = v
            .req("m")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("network m must be an integer"))?;
        Ok(match kind {
            "homogeneous" => NetworkSpec::Homogeneous {
                m,
                p_ps: v
                    .req("p_ps")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("p_ps must be a number"))?,
                p_cc: v
                    .req("p_cc")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("p_cc must be a number"))?,
            },
            "perfect" => NetworkSpec::Perfect { m },
            other => anyhow::bail!("unknown network kind {other:?}"),
        })
    }
}

fn decoder_to_json(d: Decoder) -> Json {
    match d {
        Decoder::Standard { attempts } => json::obj(vec![
            ("kind", json::s("standard")),
            ("attempts", json::num(attempts as f64)),
        ]),
        Decoder::GcPlus { tr } => {
            json::obj(vec![("kind", json::s("gcplus")), ("tr", json::num(tr as f64))])
        }
        Decoder::Approx { tr } => {
            json::obj(vec![("kind", json::s("approx")), ("tr", json::num(tr as f64))])
        }
    }
}

fn decoder_from_json(v: &Json) -> anyhow::Result<Decoder> {
    let kind = v
        .req("kind")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("decoder kind must be a string"))?;
    let n = |key: &str| -> anyhow::Result<usize> {
        v.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("decoder field {key:?} must be an integer"))
    };
    Ok(match kind {
        "standard" => Decoder::Standard { attempts: n("attempts")? },
        "gcplus" => Decoder::GcPlus { tr: n("tr")? },
        "approx" => Decoder::Approx { tr: n("tr")? },
        other => anyhow::bail!("unknown decoder kind {other:?} (standard|gcplus|approx)"),
    })
}

/// One named, fully-declarative experiment: network × channel × decoder ×
/// schedule. Run it with [`crate::scenario::run_scenario`] or
/// `cogc scenario run <name>`.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// What paper regime this probes (one line, shown by `scenario list`).
    pub description: String,
    pub net: NetworkSpec,
    pub channel: ChannelSpec,
    pub decoder: Decoder,
    /// Code family driving per-round decoding (dense cyclic, or the
    /// sparse fractional-repetition path that scales to M = 10⁵–10⁶).
    pub code: CodeFamily,
    /// Straggler tolerance of the code.
    pub s: usize,
    /// Synthetic payload dimension of the sim layer.
    pub payload_dim: usize,
    /// Rounds per episode (channel state persists across them).
    pub rounds: usize,
    /// Byzantine adversary, sampled per trial alongside the channel.
    /// `None` keeps the run byte-identical to the pre-adversary engine.
    pub adversary: Option<AdversarySpec>,
    /// Degraded-mode recovery policy (retransmission, decode fallback,
    /// fault injection). `None` — or a passive policy — keeps the run
    /// byte-identical to the policy-free engine.
    pub policy: Option<RecoveryPolicy>,
}

impl Scenario {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", json::s(&self.name)),
            ("description", json::s(&self.description)),
            ("network", self.net.to_json()),
            ("channel", self.channel.to_json()),
            ("decoder", decoder_to_json(self.decoder)),
        ];
        // "code" is omitted for the cyclic default so pre-existing cyclic
        // scenario JSON stays byte-identical
        if self.code != CodeFamily::Cyclic {
            fields.push(("code", json::s(self.code.name())));
        }
        fields.extend([
            ("s", json::num(self.s as f64)),
            ("payload_dim", json::num(self.payload_dim as f64)),
            ("rounds", json::num(self.rounds as f64)),
        ]);
        // "adversary" is omitted when absent so pre-existing scenario JSON
        // stays byte-identical
        if let Some(adv) = &self.adversary {
            fields.push(("adversary", adv.to_json()));
        }
        // likewise "policy": omitted when absent
        if let Some(policy) = &self.policy {
            fields.push(("policy", policy.to_json()));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Scenario> {
        let str_field = |key: &str| -> anyhow::Result<String> {
            Ok(v.req(key)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("scenario field {key:?} must be a string"))?
                .to_string())
        };
        let n = |key: &str| -> anyhow::Result<usize> {
            v.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("scenario field {key:?} must be an integer"))
        };
        let code = match v.get("code") {
            None => CodeFamily::Cyclic,
            Some(c) => {
                let name = c
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("scenario field \"code\" must be a string"))?;
                CodeFamily::parse(name).ok_or_else(|| {
                    anyhow::anyhow!("unknown code family {name:?} (cyclic|fr|binary)")
                })?
            }
        };
        let sc = Scenario {
            name: str_field("name")?,
            description: str_field("description")?,
            net: NetworkSpec::from_json(v.req("network")?)?,
            channel: ChannelSpec::from_json(v.req("channel")?)?,
            decoder: decoder_from_json(v.req("decoder")?)?,
            code,
            s: n("s")?,
            payload_dim: n("payload_dim")?,
            rounds: n("rounds")?,
            adversary: match v.get("adversary") {
                None => None,
                Some(a) => Some(AdversarySpec::from_json(a)?),
            },
            policy: match v.get("policy") {
                None => None,
                Some(p) => Some(RecoveryPolicy::from_json(p)?),
            },
        };
        sc.validate()?;
        Ok(sc)
    }

    pub fn from_json_str(text: &str) -> anyhow::Result<Scenario> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("scenario json: {e}"))?;
        Scenario::from_json(&v)
    }

    /// Load a scenario from a JSON file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Scenario::from_json_str(&text)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let m = self.net.m();
        anyhow::ensure!(m >= 2, "scenario {:?}: need at least 2 clients", self.name);
        anyhow::ensure!(
            self.s >= 1 && self.s < m,
            "scenario {:?}: s must be in [1, M−1], got s={} M={m}",
            self.name,
            self.s
        );
        self.code
            .validate(m, self.s)
            .map_err(|e| anyhow::anyhow!("scenario {:?}: {e}", self.name))?;
        anyhow::ensure!(self.rounds >= 1, "scenario {:?}: rounds must be ≥ 1", self.name);
        anyhow::ensure!(self.payload_dim >= 1, "scenario {:?}: payload_dim ≥ 1", self.name);
        match self.decoder {
            Decoder::Standard { attempts } => {
                anyhow::ensure!(attempts >= 1, "scenario {:?}: attempts must be ≥ 1", self.name)
            }
            Decoder::GcPlus { tr } | Decoder::Approx { tr } => {
                anyhow::ensure!(tr >= 1, "scenario {:?}: tr must be ≥ 1", self.name)
            }
        }
        if matches!(self.decoder, Decoder::Approx { .. }) {
            // FR coverage is all-or-nothing per group: there is no partial
            // row to project onto, so the least-squares fallback cannot
            // apply — ask for gcplus instead
            anyhow::ensure!(
                self.code != CodeFamily::FractionalRepetition,
                "scenario {:?}: the fr family has no approx fallback (use decoder \"gcplus\")",
                self.name
            );
        }
        self.channel
            .validate()
            .map_err(|e| anyhow::anyhow!("scenario {:?}: {e}", self.name))?;
        if let Some(adv) = &self.adversary {
            adv.validate().map_err(|e| anyhow::anyhow!("scenario {:?}: {e}", self.name))?;
        }
        if let Some(policy) = &self.policy {
            policy.validate(m).map_err(|e| anyhow::anyhow!("scenario {:?}: {e}", self.name))?;
            if !policy.is_passive() {
                // active policies post-process dense realizations; the
                // sparse FR path never materializes one
                anyhow::ensure!(
                    self.code != CodeFamily::FractionalRepetition,
                    "scenario {:?}: recovery policies need a dense family \
                     (cyclic or binary), not fr",
                    self.name
                );
                anyhow::ensure!(
                    self.adversary.is_none(),
                    "scenario {:?}: recovery policies cannot be combined with an \
                     adversary yet (drop \"policy\" or \"adversary\")",
                    self.name
                );
                if policy.fallback {
                    anyhow::ensure!(
                        !matches!(self.decoder, Decoder::Standard { .. }),
                        "scenario {:?}: the approx fallback needs the gcplus or approx \
                         decoder, not standard",
                        self.name
                    );
                }
            }
        }
        self.net.validate().map_err(|e| anyhow::anyhow!("scenario {:?}: {e}", self.name))?;
        self.net.build().validate()
    }
}

fn scenario(
    name: &str,
    description: &str,
    net: NetworkSpec,
    channel: ChannelSpec,
    decoder: Decoder,
) -> Scenario {
    Scenario {
        name: name.to_string(),
        description: description.to_string(),
        net,
        channel,
        decoder,
        code: CodeFamily::Cyclic,
        s: 7,
        payload_dim: 8,
        rounds: 60,
        adversary: None,
        policy: None,
    }
}

/// The built-in scenario catalog (names are stable CLI identifiers).
pub fn builtin() -> Vec<Scenario> {
    let m10 = |p_ps, p_cc| NetworkSpec::Homogeneous { m: 10, p_ps, p_cc };
    let mut v = vec![
        scenario(
            "iid-good",
            "memoryless benign links (paper Fig. 4 mild operating point)",
            m10(0.1, 0.1),
            ChannelSpec::Iid,
            Decoder::Standard { attempts: 1 },
        ),
        scenario(
            "iid-moderate",
            "memoryless moderate erasures (paper Fig. 6 setting 2)",
            m10(0.4, 0.5),
            ChannelSpec::Iid,
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "bursty-uplink",
            "Gilbert–Elliott uplink bursts over benign c2c links (straggly PS path)",
            m10(0.1, 0.1),
            ChannelSpec::GilbertElliott {
                p_gb: 0.05,
                p_bg: 0.25,
                c2c_scale: (1.0, 1.0),
                c2s_scale: (0.5, 8.0),
            },
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "bursty-c2c",
            "Gilbert–Elliott c2c bursts: the regime where all-or-nothing decoding is brittle",
            m10(0.4, 0.1),
            ChannelSpec::GilbertElliott {
                p_gb: 0.05,
                p_bg: 0.25,
                c2c_scale: (0.5, 8.0),
                c2s_scale: (1.0, 1.0),
            },
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "bursty-deep",
            "long deep bursts on every link (mean burst 10 attempts)",
            m10(0.3, 0.1),
            ChannelSpec::GilbertElliott {
                p_gb: 0.02,
                p_bg: 0.1,
                c2c_scale: (0.5, 9.0),
                c2s_scale: (0.5, 3.0),
            },
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "correlated-fade",
            "common-cause fades couple all links, persisting across attempts (ρ=0.2, λ=0.6)",
            m10(0.3, 0.15),
            ChannelSpec::CorrelatedFading { rho: 0.2, fade_scale: 5.0, persistence: 0.6 },
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "flash-crowd",
            "rare catastrophic multi-attempt fades (ρ = 0.05, near-total loss) on benign links",
            m10(0.2, 0.08),
            ChannelSpec::CorrelatedFading { rho: 0.05, fade_scale: 10.0, persistence: 0.5 },
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "straggler-mild",
            "shifted-exponential latency, generous deadline, occasional slow clients",
            m10(0.1, 0.1),
            ChannelSpec::DeadlineStraggler {
                deadline: 3.0,
                shift: 0.5,
                rate: 1.0,
                p_slow: 0.05,
                p_recover: 0.3,
                slow_factor: 3.0,
            },
            Decoder::GcPlus { tr: 2 },
        ),
        scenario(
            "straggler-harsh",
            "tight deadline: straggling sources can never beat it (persistent stragglers)",
            m10(0.1, 0.1),
            ChannelSpec::DeadlineStraggler {
                deadline: 1.5,
                shift: 0.5,
                rate: 1.0,
                p_slow: 0.15,
                p_recover: 0.15,
                slow_factor: 4.0,
            },
            Decoder::GcPlus { tr: 2 },
        ),
    ];
    // small fast scenario exercising the full stateful path (CI smoke)
    let mut smoke = scenario(
        "smoke",
        "tiny bursty scenario for CI smoke runs (M=6, 5 rounds)",
        NetworkSpec::Homogeneous { m: 6, p_ps: 0.3, p_cc: 0.2 },
        ChannelSpec::GilbertElliott {
            p_gb: 0.2,
            p_bg: 0.4,
            c2c_scale: (0.5, 3.0),
            c2s_scale: (0.5, 3.0),
        },
        Decoder::GcPlus { tr: 2 },
    );
    smoke.s = 3;
    smoke.rounds = 5;
    v.push(smoke.clone());

    // ── Byzantine grid: adversary fraction × channel regime ─────────────
    // Each entry reuses a catalog base so the channel side stays pinned to
    // a regime already characterized above; only the adversary differs.
    let byz = |base: &str, name: &str, description: &str, adv: AdversarySpec| {
        let mut sc = v
            .iter()
            .find(|s| s.name == base)
            .expect("byzantine grid bases are defined above")
            .clone();
        sc.name = name.to_string();
        sc.description = description.to_string();
        sc.adversary = Some(adv);
        sc
    };
    let byz_grid = vec![
        byz(
            "iid-moderate",
            "byz-flip-iid",
            "20% sign-flipping clients over memoryless links, audit on",
            AdversarySpec::fraction(Attack::SignFlip, 0.2),
        ),
        byz(
            "iid-moderate",
            "byz-flip-heavy",
            "40% sign-flipping clients: past the redundancy's correction budget",
            AdversarySpec::fraction(Attack::SignFlip, 0.4),
        ),
        byz(
            "bursty-c2c",
            "byz-flip-bursty",
            "20% sign-flippers under c2c bursts: erasures and lies compound",
            AdversarySpec::fraction(Attack::SignFlip, 0.2),
        ),
        byz(
            "iid-moderate",
            "byz-replace",
            "20% clients uplinking arbitrary garbage (scale-5 replacement)",
            AdversarySpec::fraction(Attack::Replace { scale: 5.0 }, 0.2),
        ),
        byz(
            "correlated-fade",
            "byz-collude-fade",
            "30% colluders sharing one forged vector during common-cause fades",
            AdversarySpec::fraction(Attack::Collude { scale: 1.0 }, 0.3),
        ),
        byz(
            "iid-moderate",
            "byz-c2c-poison",
            "consistent gradient substitution (c2c surface): the audit's blind spot",
            AdversarySpec {
                attack: Attack::Replace { scale: 5.0 },
                selection: Selection::Fraction(0.2),
                surface: Surface::C2c,
                detect: true,
            },
        ),
        byz(
            "iid-moderate",
            "byz-nodetect",
            "20% sign-flippers with the audit disabled (poisoning baseline)",
            AdversarySpec {
                attack: Attack::SignFlip,
                selection: Selection::Fraction(0.2),
                surface: Surface::Uplink,
                detect: false,
            },
        ),
        byz(
            "smoke",
            "byz-smoke",
            "tiny adversarial scenario for CI smoke runs (M=6, 30% flippers)",
            AdversarySpec::fraction(Attack::SignFlip, 0.3),
        ),
    ];
    v.extend(byz_grid);

    // ── Degraded-mode grid: approx fallback × recovery policy ───────────
    // Bases are reused the same way as the byzantine grid so the
    // error-vs-budget figure compares like channel regimes.
    let derive = |base: &str| {
        v.iter().find(|s| s.name == base).expect("degraded grid bases are defined above").clone()
    };
    let mut approx_mod = derive("iid-moderate");
    approx_mod.name = "approx-moderate".to_string();
    approx_mod.description =
        "iid-moderate with the least-squares fallback: outages become approx updates".to_string();
    approx_mod.decoder = Decoder::Approx { tr: 2 };
    v.push(approx_mod);

    let mut approx_bursty = derive("bursty-c2c");
    approx_bursty.name = "approx-bursty".to_string();
    approx_bursty.description =
        "c2c bursts with the least-squares fallback (degraded-mode headline case)".to_string();
    approx_bursty.decoder = Decoder::Approx { tr: 2 };
    v.push(approx_bursty);

    let mut pol_retry = derive("bursty-c2c");
    pol_retry.name = "policy-retry-bursty".to_string();
    pol_retry.description =
        "c2c bursts with 2 retransmits per link (backoff 2, deadline 6) and approx fallback"
            .to_string();
    pol_retry.policy = Some(RecoveryPolicy {
        retries: 2,
        backoff: 2.0,
        deadline: 6.0,
        fallback: true,
        fallback_residual: 0.5,
        ..Default::default()
    });
    v.push(pol_retry);

    let mut pol_faults = derive("smoke");
    pol_faults.name = "policy-faults-smoke".to_string();
    pol_faults.description =
        "CI fault injection: one dead uplink, one dead c2c link, a mid-episode crash".to_string();
    pol_faults.policy = Some(RecoveryPolicy {
        retries: 1,
        fallback: true,
        kill_uplinks: vec![0],
        kill_c2c: vec![(1, 2)],
        crash: Some(Crash { client: 3, at_round: 2, down_rounds: 2 }),
        ..Default::default()
    });
    v.push(pol_faults);

    // binary family under an adversary: the exact-i128 parity audit
    let mut byz_binary = derive("byz-smoke");
    byz_binary.name = "byz-binary".to_string();
    byz_binary.description =
        "binary ±1 family vs 30% sign-flippers: parity audit in exact i128 arithmetic"
            .to_string();
    byz_binary.code = CodeFamily::Binary;
    byz_binary.s = 2; // binary needs even s
    v.push(byz_binary);
    v
}

/// Look up a built-in scenario by name.
pub fn find(name: &str) -> anyhow::Result<Scenario> {
    let all = builtin();
    all.iter().find(|sc| sc.name == name).cloned().ok_or_else(|| {
        let names: Vec<&str> = all.iter().map(|sc| sc.name.as_str()).collect();
        anyhow::anyhow!("unknown scenario {name:?}; built-ins: {}", names.join(", "))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_eight_unique_valid_scenarios() {
        let all = builtin();
        assert!(all.len() >= 8, "only {} scenarios", all.len());
        let mut names: Vec<&str> = all.iter().map(|s| s.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate scenario names");
        for sc in &all {
            sc.validate().unwrap();
        }
        // the catalog spans all four channel model kinds
        for kind in ["iid", "gilbert_elliott", "correlated_fading", "deadline_straggler"] {
            assert!(
                all.iter().any(|s| s.channel.name() == kind),
                "no builtin scenario uses channel kind {kind}"
            );
        }
    }

    #[test]
    fn scenario_json_roundtrip() {
        for sc in builtin() {
            let text = sc.to_json().serialize();
            let back = Scenario::from_json_str(&text).unwrap();
            assert_eq!(back, sc, "roundtrip failed for {}", sc.name);
        }
    }

    #[test]
    fn code_family_roundtrip_and_default() {
        // cyclic scenarios omit the "code" key entirely (JSON unchanged
        // from before the family abstraction existed)
        let sc = find("smoke").unwrap();
        assert_eq!(sc.code, CodeFamily::Cyclic);
        let text = sc.to_json().serialize();
        assert!(!text.contains("\"code\""), "cyclic JSON should omit code: {text}");
        // an fr scenario round-trips through the explicit key
        let mut fr = find("smoke").unwrap();
        fr.code = CodeFamily::FractionalRepetition;
        fr.s = 2; // M=6 divisible by s+1=3
        let text = fr.to_json().serialize();
        assert!(text.contains("\"code\""));
        let back = Scenario::from_json_str(&text).unwrap();
        assert_eq!(back, fr);
        // fr with M not divisible by s+1 is rejected with a clear error
        let mut bad = find("smoke").unwrap();
        bad.code = CodeFamily::FractionalRepetition;
        bad.s = 3; // M=6, s+1=4 does not divide
        let err = Scenario::from_json_str(&bad.to_json().serialize()).unwrap_err().to_string();
        assert!(err.contains("divisible"), "{err}");
        // unknown family name is rejected
        let garbled = text.replace("\"fr\"", "\"lt\"");
        assert!(Scenario::from_json_str(&garbled).is_err());
    }

    #[test]
    fn byzantine_grid_present_and_clean_json_unchanged() {
        let all = builtin();
        let byz: Vec<_> = all.iter().filter(|s| s.adversary.is_some()).collect();
        assert!(byz.len() >= 6, "only {} byzantine scenarios", byz.len());
        assert!(byz.iter().any(|s| s.name == "byz-smoke"), "CI smoke entry missing");
        // the grid spans ≥ 2 channel regimes and ≥ 3 attack kinds
        let mut kinds: Vec<&str> = byz.iter().map(|s| s.channel.name()).collect();
        kinds.sort();
        kinds.dedup();
        assert!(kinds.len() >= 2, "byzantine grid covers only {kinds:?}");
        let mut attacks: Vec<&str> =
            byz.iter().map(|s| s.adversary.as_ref().unwrap().attack.name()).collect();
        attacks.sort();
        attacks.dedup();
        assert!(attacks.len() >= 3, "byzantine grid covers only {attacks:?}");
        // non-adversarial scenarios still serialize without the key
        let text = find("smoke").unwrap().to_json().serialize();
        assert!(!text.contains("adversary"), "{text}");
        let text = find("byz-collude-fade").unwrap().to_json().serialize();
        assert!(text.contains("\"adversary\""), "{text}");
    }

    #[test]
    fn find_known_and_unknown() {
        assert_eq!(find("smoke").unwrap().name, "smoke");
        let err = find("nope").unwrap_err().to_string();
        assert!(err.contains("smoke"), "error should list built-ins: {err}");
    }

    #[test]
    fn from_json_rejects_bad_specs() {
        // s out of range
        let mut sc = find("smoke").unwrap();
        sc.s = 6; // == m
        assert!(Scenario::from_json_str(&sc.to_json().serialize()).is_err());
        // garbage decoder
        assert!(Scenario::from_json_str(r#"{"name":"x"}"#).is_err());
        // out-of-range channel parameters must error, not panic in build()
        let mut sc = find("bursty-c2c").unwrap();
        sc.channel = ChannelSpec::GilbertElliott {
            p_gb: 1.5,
            p_bg: 0.2,
            c2c_scale: (1.0, 1.0),
            c2s_scale: (1.0, 1.0),
        };
        let err = Scenario::from_json_str(&sc.to_json().serialize()).unwrap_err().to_string();
        assert!(err.contains("p_gb"), "error should name the bad field: {err}");
        // out-of-range network probabilities likewise
        let mut sc = find("smoke").unwrap();
        sc.net = NetworkSpec::Homogeneous { m: 6, p_ps: 1.2, p_cc: 0.1 };
        assert!(Scenario::from_json_str(&sc.to_json().serialize()).is_err());
        // degenerate decoder parameters (tr = 0 would silently run 0
        // attempts per round)
        let mut sc = find("smoke").unwrap();
        sc.decoder = Decoder::GcPlus { tr: 0 };
        assert!(Scenario::from_json_str(&sc.to_json().serialize()).is_err());
    }

    #[test]
    fn approx_decoder_and_policy_roundtrip_and_omission() {
        // approx decoder round-trips through its own kind
        let sc = find("approx-moderate").unwrap();
        assert_eq!(sc.decoder, Decoder::Approx { tr: 2 });
        let text = sc.to_json().serialize();
        assert!(text.contains("\"approx\""), "{text}");
        assert_eq!(Scenario::from_json_str(&text).unwrap(), sc);
        // policy-free scenarios serialize without the key (byte-identity
        // of pre-existing JSON)
        let text = find("smoke").unwrap().to_json().serialize();
        assert!(!text.contains("\"policy\""), "{text}");
        // policy scenarios round-trip, kills and crash included
        for name in ["policy-retry-bursty", "policy-faults-smoke"] {
            let sc = find(name).unwrap();
            assert!(sc.policy.is_some());
            let back = Scenario::from_json_str(&sc.to_json().serialize()).unwrap();
            assert_eq!(back, sc, "{name}");
        }
    }

    #[test]
    fn from_json_rejects_bad_policy_and_decoder_specs() {
        let smoke = find("smoke").unwrap();
        // malformed policy: non-numeric retries
        let text = smoke
            .to_json()
            .serialize()
            .replace("\"rounds\":5", "\"rounds\":5,\"policy\":{\"retries\":\"two\"}");
        let err = Scenario::from_json_str(&text).unwrap_err().to_string();
        assert!(err.contains("retries"), "error should name the bad field: {err}");
        // policy with an out-of-range kill index errors (never panics)
        let mut sc = smoke.clone();
        sc.policy = Some(RecoveryPolicy { kill_uplinks: vec![99], ..Default::default() });
        let err = Scenario::from_json_str(&sc.to_json().serialize()).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // fallback threshold out of range
        let mut sc = smoke.clone();
        sc.policy = Some(RecoveryPolicy {
            fallback: true,
            fallback_residual: 3.0,
            ..Default::default()
        });
        let err = Scenario::from_json_str(&sc.to_json().serialize()).unwrap_err().to_string();
        assert!(err.contains("threshold"), "{err}");
        // active policy over the sparse fr family is rejected
        let mut sc = smoke.clone();
        sc.code = CodeFamily::FractionalRepetition;
        sc.s = 2;
        sc.policy = Some(RecoveryPolicy { retries: 1, ..Default::default() });
        let err = sc.validate().unwrap_err().to_string();
        assert!(err.contains("dense family"), "{err}");
        // approx decoder over fr likewise
        let mut sc = smoke.clone();
        sc.code = CodeFamily::FractionalRepetition;
        sc.s = 2;
        sc.decoder = Decoder::Approx { tr: 2 };
        let err = sc.validate().unwrap_err().to_string();
        assert!(err.contains("approx fallback"), "{err}");
        // policy + adversary is rejected with an actionable message
        let mut sc = find("byz-smoke").unwrap();
        sc.policy = Some(RecoveryPolicy { retries: 1, ..Default::default() });
        let err = sc.validate().unwrap_err().to_string();
        assert!(err.contains("adversary"), "{err}");
    }

    #[test]
    fn binary_adversarial_scenarios_now_validate() {
        // re-filed from the PR-8 satellite: the exact i128 audit port
        // lifted the binary+adversary rejection
        let sc = find("byz-binary").unwrap();
        assert_eq!(sc.code, CodeFamily::Binary);
        assert!(sc.adversary.is_some());
        sc.validate().unwrap();
        let back = Scenario::from_json_str(&sc.to_json().serialize()).unwrap();
        assert_eq!(back, sc);
    }
}
