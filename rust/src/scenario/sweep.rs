//! Scenario sweeps: per-round time series over many independent episodes.
//!
//! One *episode* = `rounds` consecutive CoGC rounds over a single
//! channel-state trajectory (bursts and straggler states persist across
//! rounds). [`run_scenario`] fans episodes over the deterministic
//! [`MonteCarlo`] engine: trial `t` draws its payloads/codes/erasures from
//! the canonical emission stream and its channel state from the
//! [`CHANNEL_STREAM`] substream, so the full [`RoundSeries`] — every
//! per-round tally — is bit-identical at any `--threads` value.

use super::adversary::{AdversaryModel, ADVERSARY_STREAM};
use super::channel::{ChannelModel, ChannelStats, CHANNEL_STREAM};
use super::policy::{PolicyChannel, PolicyStats, RecoveryPolicy, POLICY_STREAM};
use super::registry::Scenario;
use crate::gc::{BinaryCode, CodeFamily, FrCode, RESIDUAL_BUCKETS};
use crate::parallel::{parallel_map, Accumulate, MonteCarlo};
use crate::sim::{self, AdvReport, Outcome};
use crate::telemetry;

/// Tallies of one round index across all episodes (all integer fields, so
/// per-worker instances merge exactly).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundTally {
    /// Episodes that reached this round (= trials).
    pub trials: usize,
    /// Rounds decoded by the standard (binary) GC combinator.
    pub standard: usize,
    /// Rounds where GC⁺ recovered all M payloads.
    pub full: usize,
    /// Rounds where GC⁺ recovered a proper subset.
    pub partial: usize,
    /// Rounds with nothing decodable.
    pub none: usize,
    /// Rounds recovered *approximately*: the exact decoders failed and the
    /// accepted update is the least-squares combination of the delivered
    /// rows ([`Decoder::Approx`](sim::Decoder::Approx), or a policy's
    /// exact→approx fallback). Always 0 under the exact decoders.
    pub approx: usize,
    /// Accepted approximate rounds bucketed by relative residual
    /// (`residual/√M`, [`crate::gc::residual_bucket`] edges): bucket 0 is
    /// "exact to rounding", the top bucket "recovered almost nothing".
    pub residual_hist: [usize; RESIDUAL_BUCKETS],
    /// Transmissions consumed at this round across episodes (includes one
    /// per policy retransmission when a recovery policy is active).
    pub transmissions: usize,
    /// Channel diagnostics at this round across episodes.
    pub channel: ChannelStats,
    /// Rounds where corrupted data actually reached the PS (adversarial
    /// sweeps only; always 0 otherwise — as are the four tallies below).
    pub corrupted: usize,
    /// Rounds where the decode-path audit raised an alarm.
    pub detected: usize,
    /// Rounds whose decoded output contained corrupted data — the
    /// decoded-but-poisoned state of the 2×2 recovery × integrity split.
    pub poisoned: usize,
    /// Coded rows / group copies excised by the audit.
    pub excised: usize,
    /// Honest rows among the excised (false-alarm cost).
    pub false_excised: usize,
    /// GC⁺ rows recovered by the peeling fast path at this round across
    /// episodes (dense cyclic engines only; always 0 on the binary and
    /// sparse FR paths, whose decoders have no peeling stage).
    pub peeled: usize,
    /// GC⁺ rows forwarded to the dense RREF engine at this round.
    pub forwarded: usize,
    /// Link retransmissions attempted by the recovery policy (policy
    /// sweeps only; always 0 otherwise — as are the three tallies below).
    pub retries: usize,
    /// Retransmissions that brought a link back up.
    pub recovered: usize,
    /// Retry ladders cut short by the round's deadline budget.
    pub budget_exhausted: usize,
    /// Links forced down by the policy's fault injection (kill lists and
    /// crash windows).
    pub killed: usize,
}

impl RoundTally {
    /// Fraction of episodes that produced *some* global update this round
    /// (exact or accepted-approximate).
    pub fn p_update(&self) -> f64 {
        (self.standard + self.full + self.partial + self.approx) as f64
            / self.trials.max(1) as f64
    }

    /// Fraction of episodes whose update this round was approximate.
    pub fn p_approx(&self) -> f64 {
        self.approx as f64 / self.trials.max(1) as f64
    }

    /// Detection rate among rounds where corruption reached the PS.
    pub fn p_detected(&self) -> f64 {
        self.detected as f64 / self.corrupted.max(1) as f64
    }

    /// Fraction of all rounds whose accepted update was poisoned.
    pub fn p_poisoned(&self) -> f64 {
        self.poisoned as f64 / self.trials.max(1) as f64
    }

    fn absorb_adv(&mut self, rep: &AdvReport) {
        self.corrupted += rep.active as usize;
        self.detected += rep.detected as usize;
        self.poisoned += rep.poisoned as usize;
        self.excised += rep.excised;
        self.false_excised += rep.false_excised;
    }

    /// Classify one round outcome. `max_rel` is the acceptance threshold
    /// on the relative residual (`residual/√M`, see
    /// [`crate::gc::relative_residual`]): approximate rounds above it
    /// tally as outages. Non-policy paths pass `f64::INFINITY`, accepting
    /// every approximate round. Returns whether an approximate round was
    /// accepted (the caller bumps the fallback telemetry counter).
    fn absorb_outcome(&mut self, outcome: &Outcome, m: usize, max_rel: f64) -> bool {
        match outcome {
            Outcome::Standard { .. } => self.standard += 1,
            Outcome::Full => self.full += 1,
            Outcome::Partial { .. } => self.partial += 1,
            Outcome::Approx { residual } => {
                let rel = if m == 0 { 0.0 } else { residual / (m as f64).sqrt() };
                if rel <= max_rel {
                    self.approx += 1;
                    self.residual_hist[crate::gc::residual_bucket(rel)] += 1;
                    return true;
                }
                self.none += 1;
            }
            Outcome::None => self.none += 1,
        }
        false
    }

    /// Fold one round's policy stats in. Every retransmission is a real
    /// channel use, so retries also bill the transmission tally.
    fn absorb_policy(&mut self, ps: &PolicyStats) {
        self.retries += ps.retries;
        self.recovered += ps.recovered;
        self.budget_exhausted += ps.budget_exhausted;
        self.killed += ps.killed;
        self.transmissions += ps.retries;
    }
}

impl Accumulate for RoundTally {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.standard += other.standard;
        self.full += other.full;
        self.partial += other.partial;
        self.none += other.none;
        self.approx += other.approx;
        for (a, b) in self.residual_hist.iter_mut().zip(other.residual_hist) {
            *a += b;
        }
        self.transmissions += other.transmissions;
        self.channel.merge(other.channel);
        self.corrupted += other.corrupted;
        self.detected += other.detected;
        self.poisoned += other.poisoned;
        self.excised += other.excised;
        self.false_excised += other.false_excised;
        self.peeled += other.peeled;
        self.forwarded += other.forwarded;
        self.retries += other.retries;
        self.recovered += other.recovered;
        self.budget_exhausted += other.budget_exhausted;
        self.killed += other.killed;
    }
}

// Named shard projections of the pooled episode scratches — plain `fn`
// items (not closures) so [`MonteCarlo::run_scratch_tel`] can take them as
// ordinary function pointers.
fn cyclic_shard(s: &mut (Box<dyn ChannelModel>, sim::SimScratch)) -> Option<&mut telemetry::Shard> {
    Some(s.1.tel_mut())
}

fn binary_shard(
    s: &mut (Box<dyn ChannelModel>, sim::BinSimScratch),
) -> Option<&mut telemetry::Shard> {
    Some(s.1.tel_mut())
}

fn adv_shard(
    s: &mut (Box<dyn ChannelModel>, sim::AdvSimScratch, AdversaryModel),
) -> Option<&mut telemetry::Shard> {
    Some(s.1.tel_mut())
}

fn binary_adv_shard(
    s: &mut (Box<dyn ChannelModel>, sim::BinAdvScratch, AdversaryModel),
) -> Option<&mut telemetry::Shard> {
    Some(s.1.tel_mut())
}

fn policy_cyclic_shard(s: &mut (PolicyChannel, sim::SimScratch)) -> Option<&mut telemetry::Shard> {
    Some(s.1.tel_mut())
}

fn policy_binary_shard(
    s: &mut (PolicyChannel, sim::BinSimScratch),
) -> Option<&mut telemetry::Shard> {
    Some(s.1.tel_mut())
}

/// The per-round time series of a scenario sweep (index = round).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundSeries {
    pub rounds: Vec<RoundTally>,
}

impl RoundSeries {
    fn ensure_len(&mut self, n: usize) {
        if self.rounds.len() < n {
            self.rounds.resize(n, RoundTally::default());
        }
    }
}

impl Accumulate for RoundSeries {
    fn merge(&mut self, other: Self) {
        self.ensure_len(other.rounds.len());
        for (i, tally) in other.rounds.into_iter().enumerate() {
            self.rounds[i].merge(tally);
        }
    }
}

/// Run `trials` independent episodes of `sc` through the parallel engine
/// and tally outcomes per round. Bit-identical for any thread count.
///
/// Dispatches on the scenario's code family: dense cyclic episodes go
/// through the original pooled-scratch engine (byte-identical output to
/// before the family abstraction existed); fractional-repetition episodes
/// go through the sparse O(M·(s+1)) path ([`run_scenario_fr`]).
pub fn run_scenario(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    // a passive policy must be byte-identical to no policy at all, so it
    // dispatches to the unwrapped code paths verbatim
    let active_policy = sc.policy.as_ref().filter(|p| !p.is_passive()).is_some();
    match (active_policy, &sc.adversary, sc.code) {
        (true, None, CodeFamily::Cyclic) => run_scenario_cyclic_policy(sc, trials, mc),
        (true, None, CodeFamily::Binary) => run_scenario_binary_policy(sc, trials, mc),
        (true, _, _) => {
            unreachable!("Scenario::validate rejects this policy combination")
        }
        (false, None, CodeFamily::Cyclic) => run_scenario_cyclic(sc, trials, mc),
        (false, None, CodeFamily::FractionalRepetition) => run_scenario_fr(sc, trials, mc),
        (false, None, CodeFamily::Binary) => run_scenario_binary(sc, trials, mc),
        (false, Some(_), CodeFamily::Cyclic) => run_scenario_cyclic_adv(sc, trials, mc),
        (false, Some(_), CodeFamily::FractionalRepetition) => run_scenario_fr_adv(sc, trials, mc),
        (false, Some(_), CodeFamily::Binary) => run_scenario_binary_adv(sc, trials, mc),
    }
}

/// Binary {±1} episode engine: identical pooling and stream discipline to
/// [`run_scenario_cyclic`], with the round driven by the exact-arithmetic
/// [`sim::simulate_round_binary_scratch`]. The code is deterministic per
/// (M, s), so episodes consume emission draws only for payloads (the
/// cyclic engine additionally draws a fresh code per attempt).
fn run_scenario_binary(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let net = sc.net.build();
    let proto = sc.channel.build();
    let code = BinaryCode::new(net.m, sc.s).expect("scenario validated for the binary family");
    let mut series: RoundSeries = mc.run_scratch_tel(
        trials,
        || (proto.clone_box(), sim::BinSimScratch::new()),
        binary_shard,
        |t, rng, acc: &mut RoundSeries, (ch, scratch)| {
            ch.reset(&net, mc.substream_seed(CHANNEL_STREAM, t));
            acc.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                let round = sim::simulate_round_binary_scratch(
                    &net,
                    &mut **ch,
                    code,
                    sc.payload_dim,
                    sc.decoder,
                    rng,
                    scratch,
                );
                scratch.harvest();
                let tally = &mut acc.rounds[r];
                tally.trials += 1;
                if tally.absorb_outcome(&round.outcome, net.m, f64::INFINITY) {
                    scratch.tel_mut().inc(telemetry::metric::APPROX_FALLBACKS);
                }
                tally.transmissions += round.transmissions;
                let st = ch.take_stats();
                scratch.tel_mut().absorb_channel(&st);
                tally.channel.merge(st);
            }
        },
    );
    series.ensure_len(sc.rounds); // trials == 0 edge case
    series
}

/// Dense cyclic episode engine.
///
/// The channel box and the round buffers ([`sim::SimScratch`], including
/// the persistent incremental GC⁺ decoder) are pooled **per worker**: an
/// episode resets them per trial and every round within the episode reuses
/// them, so the steady-state episode loop allocates only its tallies.
fn run_scenario_cyclic(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let net = sc.net.build();
    let proto = sc.channel.build();
    let m = net.m;
    let mut series: RoundSeries = mc.run_scratch_tel(
        trials,
        || (proto.clone_box(), sim::SimScratch::new()),
        cyclic_shard,
        |t, rng, acc: &mut RoundSeries, (ch, scratch)| {
            ch.reset(&net, mc.substream_seed(CHANNEL_STREAM, t));
            acc.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                let round = sim::simulate_round_scratch(
                    &net,
                    &mut **ch,
                    m,
                    sc.s,
                    sc.payload_dim,
                    sc.decoder,
                    rng,
                    scratch,
                );
                scratch.harvest();
                let tally = &mut acc.rounds[r];
                tally.trials += 1;
                let (peeled, forwarded) = scratch.peel_split();
                tally.peeled += peeled;
                tally.forwarded += forwarded;
                if tally.absorb_outcome(&round.outcome, m, f64::INFINITY) {
                    scratch.tel_mut().inc(telemetry::metric::APPROX_FALLBACKS);
                }
                tally.transmissions += round.transmissions;
                let st = ch.take_stats();
                scratch.tel_mut().absorb_channel(&st);
                tally.channel.merge(st);
            }
        },
    );
    series.ensure_len(sc.rounds); // trials == 0 edge case
    series
}

/// Fractional-repetition episode engine: every structure is O(M·(s+1)) —
/// sparse realizations, group-coverage scans, no RREF and no dense M×M
/// anything — so episodes scale to M = 10⁵–10⁶ clients.
///
/// Episodes fan out one-per-job through [`parallel_map`] (at large M a
/// sweep runs few episodes, so chunking them 256-at-a-time would
/// serialize the whole run); per-round group scans inside an episode
/// dispatch through the same engine at the episode level's residual
/// parallelism. Episode `t` draws its erasures from [`MonteCarlo::trial_rng`]
/// and its channel state from the [`CHANNEL_STREAM`] substream — the same
/// two-stream scheme as the dense engine — and the per-episode series are
/// merged in episode order, so the output is bit-identical at any
/// `--threads` value.
pub fn run_scenario_fr(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let net = sc.net.build();
    let proto = sc.channel.build();
    let code = FrCode::new(net.m, sc.s).expect("scenario validated for the fr family");
    let sup = code.sparse_support();
    // leftover cores go to the in-episode group scans when episodes are few
    let decode_threads = (mc.threads / trials.max(1)).max(1);
    let episodes: Vec<u64> = (0..trials as u64).collect();
    // Episodes stream through bounded batches: each batch's per-episode
    // series merge (in episode order, so the fold stays bit-identical at
    // any thread count) before the next batch runs, keeping peak memory
    // O(threads · rounds) instead of O(trials · rounds).
    let batch = mc.threads.max(1) * 4;
    let mut total = RoundSeries::default();
    for chunk in episodes.chunks(batch) {
        let per_episode: Vec<RoundSeries> = parallel_map(chunk, mc.threads, |_, &t| {
            let mut ch = proto.clone_box();
            let mut scratch = sim::FrSimScratch::new();
            let mut rng = mc.trial_rng(t);
            ch.reset_sparse(&sup, &net, mc.substream_seed(CHANNEL_STREAM, t));
            let mut series = RoundSeries::default();
            series.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                let round = sim::simulate_round_fr(
                    &code,
                    &net,
                    &mut *ch,
                    sc.decoder,
                    decode_threads,
                    &mut rng,
                    &mut scratch,
                );
                let tally = &mut series.rounds[r];
                tally.trials += 1;
                match round.outcome {
                    sim::FrOutcome::Standard { .. } => tally.standard += 1,
                    sim::FrOutcome::Full => tally.full += 1,
                    sim::FrOutcome::Partial { .. } => tally.partial += 1,
                    sim::FrOutcome::None => tally.none += 1,
                }
                tally.transmissions += round.transmissions;
                tally.channel.merge(ch.take_stats());
            }
            series
        });
        for series in per_episode {
            total.merge(series);
        }
    }
    total.ensure_len(sc.rounds); // trials == 0 edge case
    total
}

/// Dense cyclic episode engine under a Byzantine adversary. The malicious
/// set is sampled per trial from the [`ADVERSARY_STREAM`] substream and
/// persists across the episode's rounds — a compromised client stays
/// compromised, exactly like a channel state. Trials where nobody turns
/// malicious take the plain round path and consume zero emission draws for
/// the adversary, so a fraction-0 spec reproduces the non-adversarial
/// series byte-for-byte (asserted in `tests/adversary.rs`).
fn run_scenario_cyclic_adv(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let spec = sc.adversary.clone().expect("dispatched on Some");
    let net = sc.net.build();
    let proto = sc.channel.build();
    let m = net.m;
    let detect = spec.detect;
    let mut series: RoundSeries = mc.run_scratch_tel(
        trials,
        || (proto.clone_box(), sim::AdvSimScratch::new(), AdversaryModel::new(spec.clone())),
        adv_shard,
        |t, rng, acc: &mut RoundSeries, (ch, scratch, adv)| {
            ch.reset(&net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(m, mc.substream_seed(ADVERSARY_STREAM, t));
            acc.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                let (round, rep) = sim::simulate_round_adv(
                    &net,
                    &mut **ch,
                    adv,
                    m,
                    sc.s,
                    sc.payload_dim,
                    sc.decoder,
                    rng,
                    scratch,
                );
                scratch.harvest();
                {
                    use telemetry::metric;
                    let tel = scratch.tel_mut();
                    if detect {
                        tel.inc(metric::AUDIT_CHECKS);
                    }
                    tel.add(metric::AUDIT_EXCISIONS, rep.excised as u64);
                }
                let tally = &mut acc.rounds[r];
                tally.trials += 1;
                let (peeled, forwarded) = scratch.peel_split();
                tally.peeled += peeled;
                tally.forwarded += forwarded;
                if tally.absorb_outcome(&round.outcome, m, f64::INFINITY) {
                    scratch.tel_mut().inc(telemetry::metric::APPROX_FALLBACKS);
                }
                tally.transmissions += round.transmissions;
                let st = ch.take_stats();
                scratch.tel_mut().absorb_channel(&st);
                tally.channel.merge(st);
                tally.absorb_adv(&rep);
            }
        },
    );
    series.ensure_len(sc.rounds);
    series
}

/// Fractional-repetition episode engine under a Byzantine adversary —
/// the sparse analogue of [`run_scenario_cyclic_adv`]: per-group plurality
/// votes instead of parity checks, still O(M·(s+1)) per round.
fn run_scenario_fr_adv(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let spec = sc.adversary.clone().expect("dispatched on Some");
    let net = sc.net.build();
    let proto = sc.channel.build();
    let code = FrCode::new(net.m, sc.s).expect("scenario validated for the fr family");
    let sup = code.sparse_support();
    let decode_threads = (mc.threads / trials.max(1)).max(1);
    let episodes: Vec<u64> = (0..trials as u64).collect();
    // bounded-batch streaming, same scheme as [`run_scenario_fr`]
    let batch = mc.threads.max(1) * 4;
    let mut total = RoundSeries::default();
    for chunk in episodes.chunks(batch) {
        let per_episode: Vec<RoundSeries> = parallel_map(chunk, mc.threads, |_, &t| {
            let mut ch = proto.clone_box();
            let mut scratch = sim::FrAdvScratch::new();
            let mut adv = AdversaryModel::new(spec.clone());
            let mut rng = mc.trial_rng(t);
            ch.reset_sparse(&sup, &net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(net.m, mc.substream_seed(ADVERSARY_STREAM, t));
            let mut series = RoundSeries::default();
            series.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                let (round, rep) = sim::simulate_round_fr_adv(
                    &code,
                    &net,
                    &mut *ch,
                    &mut adv,
                    sc.decoder,
                    decode_threads,
                    &mut rng,
                    &mut scratch,
                );
                let tally = &mut series.rounds[r];
                tally.trials += 1;
                match round.outcome {
                    sim::FrOutcome::Standard { .. } => tally.standard += 1,
                    sim::FrOutcome::Full => tally.full += 1,
                    sim::FrOutcome::Partial { .. } => tally.partial += 1,
                    sim::FrOutcome::None => tally.none += 1,
                }
                tally.transmissions += round.transmissions;
                tally.channel.merge(ch.take_stats());
                tally.absorb_adv(&rep);
            }
            series
        });
        for series in per_episode {
            total.merge(series);
        }
    }
    total.ensure_len(sc.rounds);
    total
}

/// Binary {±1} episode engine under a Byzantine adversary — the exact
/// integer analogue of [`run_scenario_cyclic_adv`]: the decode-path audit
/// runs in i128 rational arithmetic ([`crate::gc::audit_rows_int`]), so
/// parity violations are detected without a float tolerance band.
fn run_scenario_binary_adv(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let spec = sc.adversary.clone().expect("dispatched on Some");
    let net = sc.net.build();
    let proto = sc.channel.build();
    let code = BinaryCode::new(net.m, sc.s).expect("scenario validated for the binary family");
    let m = net.m;
    let detect = spec.detect;
    let mut series: RoundSeries = mc.run_scratch_tel(
        trials,
        || (proto.clone_box(), sim::BinAdvScratch::new(), AdversaryModel::new(spec.clone())),
        binary_adv_shard,
        |t, rng, acc: &mut RoundSeries, (ch, scratch, adv)| {
            ch.reset(&net, mc.substream_seed(CHANNEL_STREAM, t));
            adv.reset(m, mc.substream_seed(ADVERSARY_STREAM, t));
            acc.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                let (round, rep) = sim::simulate_round_binary_adv(
                    &net,
                    &mut **ch,
                    adv,
                    code,
                    sc.payload_dim,
                    sc.decoder,
                    rng,
                    scratch,
                );
                scratch.harvest();
                {
                    use telemetry::metric;
                    let tel = scratch.tel_mut();
                    if detect {
                        tel.inc(metric::AUDIT_CHECKS);
                    }
                    tel.add(metric::AUDIT_EXCISIONS, rep.excised as u64);
                }
                let tally = &mut acc.rounds[r];
                tally.trials += 1;
                if tally.absorb_outcome(&round.outcome, m, f64::INFINITY) {
                    scratch.tel_mut().inc(telemetry::metric::APPROX_FALLBACKS);
                }
                tally.transmissions += round.transmissions;
                let st = ch.take_stats();
                scratch.tel_mut().absorb_channel(&st);
                tally.channel.merge(st);
                tally.absorb_adv(&rep);
            }
        },
    );
    series.ensure_len(sc.rounds);
    series
}

/// The per-episode decoder and acceptance threshold of a recovery policy.
/// With the fallback enabled, exact GC⁺ episodes run under
/// [`Decoder::Approx`](sim::Decoder::Approx) (the exact path is tried
/// first and unchanged; only would-be outages fall through to least
/// squares), and approximate rounds above the residual threshold still
/// tally as outages.
fn policy_decode(sc: &Scenario, policy: &RecoveryPolicy) -> (sim::Decoder, f64) {
    if policy.fallback {
        let decoder = match sc.decoder {
            sim::Decoder::GcPlus { tr } => sim::Decoder::Approx { tr },
            other => other,
        };
        (decoder, policy.fallback_residual)
    } else {
        (sc.decoder, f64::INFINITY)
    }
}

/// Dense cyclic episode engine under a [`RecoveryPolicy`]: the channel is
/// wrapped in a [`PolicyChannel`] (faults, then bounded retransmission on
/// the private [`POLICY_STREAM`] substream), the round loop feeds the
/// per-round deadline budget and crash window via `set_round`, and the
/// policy's retry/recovery/budget tallies land in the round tally.
fn run_scenario_cyclic_policy(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let policy = sc.policy.clone().expect("dispatched on an active policy");
    let net = sc.net.build();
    let proto = sc.channel.build();
    let m = net.m;
    let (decoder, max_rel) = policy_decode(sc, &policy);
    let mut series: RoundSeries = mc.run_scratch_tel(
        trials,
        || (PolicyChannel::new(policy.clone(), proto.clone_box()), sim::SimScratch::new()),
        policy_cyclic_shard,
        |t, rng, acc: &mut RoundSeries, (ch, scratch)| {
            ch.reset(&net, mc.substream_seed(CHANNEL_STREAM, t));
            ch.reset_policy(mc.substream_seed(POLICY_STREAM, t));
            acc.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                ch.set_round(r);
                let round = sim::simulate_round_scratch(
                    &net,
                    &mut *ch,
                    m,
                    sc.s,
                    sc.payload_dim,
                    decoder,
                    rng,
                    scratch,
                );
                scratch.harvest();
                let tally = &mut acc.rounds[r];
                tally.trials += 1;
                let (peeled, forwarded) = scratch.peel_split();
                tally.peeled += peeled;
                tally.forwarded += forwarded;
                if tally.absorb_outcome(&round.outcome, m, max_rel) {
                    scratch.tel_mut().inc(telemetry::metric::APPROX_FALLBACKS);
                }
                tally.transmissions += round.transmissions;
                let ps = ch.take_policy_stats();
                scratch.tel_mut().add(telemetry::metric::POLICY_RETRIES, ps.retries as u64);
                tally.absorb_policy(&ps);
                let st = ch.take_stats();
                scratch.tel_mut().absorb_channel(&st);
                tally.channel.merge(st);
            }
        },
    );
    series.ensure_len(sc.rounds);
    series
}

/// Binary {±1} episode engine under a [`RecoveryPolicy`] — same wrapping
/// and stream discipline as [`run_scenario_cyclic_policy`] over the exact
/// integer decode path.
fn run_scenario_binary_policy(sc: &Scenario, trials: usize, mc: &MonteCarlo) -> RoundSeries {
    let policy = sc.policy.clone().expect("dispatched on an active policy");
    let net = sc.net.build();
    let proto = sc.channel.build();
    let code = BinaryCode::new(net.m, sc.s).expect("scenario validated for the binary family");
    let m = net.m;
    let (decoder, max_rel) = policy_decode(sc, &policy);
    let mut series: RoundSeries = mc.run_scratch_tel(
        trials,
        || (PolicyChannel::new(policy.clone(), proto.clone_box()), sim::BinSimScratch::new()),
        policy_binary_shard,
        |t, rng, acc: &mut RoundSeries, (ch, scratch)| {
            ch.reset(&net, mc.substream_seed(CHANNEL_STREAM, t));
            ch.reset_policy(mc.substream_seed(POLICY_STREAM, t));
            acc.ensure_len(sc.rounds);
            for r in 0..sc.rounds {
                ch.set_round(r);
                let round = sim::simulate_round_binary_scratch(
                    &net,
                    &mut *ch,
                    code,
                    sc.payload_dim,
                    decoder,
                    rng,
                    scratch,
                );
                scratch.harvest();
                let tally = &mut acc.rounds[r];
                tally.trials += 1;
                if tally.absorb_outcome(&round.outcome, m, max_rel) {
                    scratch.tel_mut().inc(telemetry::metric::APPROX_FALLBACKS);
                }
                tally.transmissions += round.transmissions;
                let ps = ch.take_policy_stats();
                scratch.tel_mut().add(telemetry::metric::POLICY_RETRIES, ps.retries as u64);
                tally.absorb_policy(&ps);
                let st = ch.take_stats();
                scratch.tel_mut().absorb_channel(&st);
                tally.channel.merge(st);
            }
        },
    );
    series.ensure_len(sc.rounds);
    series
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::registry;

    #[test]
    fn every_builtin_scenario_runs_and_tallies_partition() {
        for sc in registry::builtin() {
            let series = run_scenario(&sc, 4, &MonteCarlo::new(3));
            assert_eq!(series.rounds.len(), sc.rounds, "{}", sc.name);
            for (r, tally) in series.rounds.iter().enumerate() {
                assert_eq!(tally.trials, 4, "{} round {r}", sc.name);
                assert_eq!(
                    tally.standard + tally.full + tally.partial + tally.approx + tally.none,
                    tally.trials,
                    "{} round {r}: outcomes must partition",
                    sc.name
                );
                assert!(tally.transmissions > 0, "{} round {r}", sc.name);
            }
        }
    }

    #[test]
    fn stateful_scenarios_report_channel_diagnostics() {
        let sc = registry::find("bursty-c2c").unwrap();
        let series = run_scenario(&sc, 6, &MonteCarlo::new(11));
        let degraded: usize = series.rounds.iter().map(|t| t.channel.degraded).sum();
        let denom: usize = series.rounds.iter().map(|t| t.channel.degraded_denom).sum();
        assert!(denom > 0);
        assert!(degraded > 0, "a bursty scenario should spend time degraded");
        let sc = registry::find("straggler-harsh").unwrap();
        let series = run_scenario(&sc, 6, &MonteCarlo::new(11));
        let hits: usize = series.rounds.iter().map(|t| t.channel.deadline_hits).sum();
        let total: usize = series.rounds.iter().map(|t| t.channel.deadline_total).sum();
        assert!(total > 0 && hits < total, "harsh deadlines must miss sometimes");
    }

    /// The smoke scenario retargeted at the fr family (M=6, s=2 so
    /// M % (s+1) == 0 holds).
    fn fr_smoke() -> Scenario {
        let mut sc = registry::find("smoke").unwrap();
        sc.code = crate::gc::CodeFamily::FractionalRepetition;
        sc.s = 2;
        sc.validate().unwrap();
        sc
    }

    #[test]
    fn fr_scenario_runs_and_tallies_partition() {
        let sc = fr_smoke();
        let series = run_scenario(&sc, 8, &MonteCarlo::new(3));
        assert_eq!(series.rounds.len(), sc.rounds);
        for (r, tally) in series.rounds.iter().enumerate() {
            assert_eq!(tally.trials, 8, "round {r}");
            assert_eq!(
                tally.standard + tally.full + tally.partial + tally.approx + tally.none,
                tally.trials,
                "round {r}: outcomes must partition"
            );
            assert!(tally.transmissions > 0, "round {r}");
        }
        // the bursty channel's diagnostics flow through the sparse path too
        let degraded: usize = series.rounds.iter().map(|t| t.channel.degraded).sum();
        assert!(degraded > 0, "sparse GE path should report degraded link time");
    }

    #[test]
    fn fr_scenario_thread_invariant() {
        let sc = fr_smoke();
        let want = run_scenario(&sc, 6, &MonteCarlo::new(17).with_threads(1));
        for threads in [2usize, 8] {
            let got = run_scenario(&sc, 6, &MonteCarlo::new(17).with_threads(threads));
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn fr_zero_trials_yields_empty_tallies_of_full_length() {
        let sc = fr_smoke();
        let series = run_scenario(&sc, 0, &MonteCarlo::new(1));
        assert_eq!(series.rounds.len(), sc.rounds);
        assert!(series.rounds.iter().all(|t| t.trials == 0));
    }

    /// The smoke scenario retargeted at the binary family (s=2 is even).
    fn binary_smoke() -> Scenario {
        let mut sc = registry::find("smoke").unwrap();
        sc.code = crate::gc::CodeFamily::Binary;
        sc.s = 2;
        sc.validate().unwrap();
        sc
    }

    #[test]
    fn binary_scenario_runs_and_tallies_partition() {
        let sc = binary_smoke();
        let series = run_scenario(&sc, 8, &MonteCarlo::new(3));
        assert_eq!(series.rounds.len(), sc.rounds);
        for (r, tally) in series.rounds.iter().enumerate() {
            assert_eq!(tally.trials, 8, "round {r}");
            assert_eq!(
                tally.standard + tally.full + tally.partial + tally.approx + tally.none,
                tally.trials,
                "round {r}: outcomes must partition"
            );
            assert!(tally.transmissions > 0, "round {r}");
        }
        let decoded: usize =
            series.rounds.iter().map(|t| t.standard + t.full + t.partial).sum();
        assert!(decoded > 0, "the smoke channel should let some binary rounds decode");
    }

    #[test]
    fn binary_scenario_thread_invariant() {
        let sc = binary_smoke();
        let want = run_scenario(&sc, 6, &MonteCarlo::new(17).with_threads(1));
        for threads in [2usize, 8] {
            let got = run_scenario(&sc, 6, &MonteCarlo::new(17).with_threads(threads));
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn binary_zero_trials_yields_empty_tallies_of_full_length() {
        let sc = binary_smoke();
        let series = run_scenario(&sc, 0, &MonteCarlo::new(1));
        assert_eq!(series.rounds.len(), sc.rounds);
        assert!(series.rounds.iter().all(|t| t.trials == 0));
    }

    #[test]
    fn adversarial_sweeps_fill_the_integrity_tallies() {
        // audit on: attacks are mostly caught, poisoning is rare
        let sc = registry::find("byz-flip-iid").unwrap();
        let series = run_scenario(&sc, 10, &MonteCarlo::new(5));
        let sum = |f: fn(&RoundTally) -> usize| series.rounds.iter().map(f).sum::<usize>();
        let corrupted = sum(|t| t.corrupted);
        let detected = sum(|t| t.detected);
        assert!(corrupted > 0, "20% flippers over 10×60 rounds must corrupt something");
        assert!(detected > 0, "the audit should catch uplink sign flips");
        assert!(detected <= corrupted, "alarms only fire on active corruption");
        assert!(sum(|t| t.excised) >= detected, "detections excise rows");
        // outcome partition still holds under the adversary
        for (r, t) in series.rounds.iter().enumerate() {
            assert_eq!(t.standard + t.full + t.partial + t.approx + t.none, t.trials, "round {r}");
        }
        // audit off: same attack, now it lands — poisoned rounds appear
        // and nothing is ever detected
        let sc = registry::find("byz-nodetect").unwrap();
        let series = run_scenario(&sc, 10, &MonteCarlo::new(5));
        let sum = |f: fn(&RoundTally) -> usize| series.rounds.iter().map(f).sum::<usize>();
        assert_eq!(sum(|t| t.detected), 0);
        assert_eq!(sum(|t| t.excised), 0);
        assert!(sum(|t| t.poisoned) > 0, "undetected sign flips must poison decodes");
    }

    #[test]
    fn adversarial_fr_sweep_votes_and_stays_thread_invariant() {
        let mut sc = fr_smoke();
        sc.adversary =
            Some(crate::scenario::AdversarySpec::fraction(crate::scenario::Attack::SignFlip, 0.3));
        sc.validate().unwrap();
        let want = run_scenario(&sc, 8, &MonteCarlo::new(13).with_threads(1));
        for threads in [2usize, 8] {
            let got = run_scenario(&sc, 8, &MonteCarlo::new(13).with_threads(threads));
            assert_eq!(got, want, "threads={threads}");
        }
        let sum = |f: fn(&RoundTally) -> usize| want.rounds.iter().map(f).sum::<usize>();
        assert!(sum(|t| t.corrupted) > 0);
        assert!(sum(|t| t.detected) > 0, "the FR plurality vote should raise alarms");
    }

    #[test]
    fn cyclic_sweep_accumulates_peel_split_tallies() {
        // GC⁺ smoke rounds push rows, so the peel/forward split must fill
        let sc = registry::find("smoke").unwrap();
        let series = run_scenario(&sc, 6, &MonteCarlo::new(9));
        let pushed: usize = series.rounds.iter().map(|t| t.peeled + t.forwarded).sum();
        assert!(pushed > 0, "GC⁺ rounds must route rows through the decoder");
        // the binary engine has no peeling stage — its columns stay 0
        let sc = binary_smoke();
        let series = run_scenario(&sc, 6, &MonteCarlo::new(9));
        assert!(series.rounds.iter().all(|t| t.peeled == 0 && t.forwarded == 0));
    }

    #[test]
    fn zero_trials_yields_empty_tallies_of_full_length() {
        let sc = registry::find("smoke").unwrap();
        let series = run_scenario(&sc, 0, &MonteCarlo::new(1));
        assert_eq!(series.rounds.len(), sc.rounds);
        assert!(series.rounds.iter().all(|t| t.trials == 0));
    }

    #[test]
    fn passive_policy_is_byte_identical_to_no_policy() {
        // ISSUE acceptance: a policy-off / passive config reproduces every
        // existing tally bit-for-bit, at any thread count.
        for name in ["smoke", "bursty-c2c"] {
            let plain = registry::find(name).unwrap();
            let mut with = plain.clone();
            with.policy = Some(RecoveryPolicy::default());
            with.validate().unwrap();
            for threads in [1usize, 2, 8] {
                let want = run_scenario(&plain, 6, &MonteCarlo::new(21).with_threads(threads));
                let got = run_scenario(&with, 6, &MonteCarlo::new(21).with_threads(threads));
                assert_eq!(got, want, "{name} threads={threads}");
            }
        }
    }

    #[test]
    fn approx_scenarios_reclassify_outages_and_fill_the_histogram() {
        // Same emission stream: the approx decoder must reproduce every
        // exact tally and only reclassify would-be outages.
        let sc = registry::find("approx-moderate").unwrap();
        let mut exact = sc.clone();
        exact.decoder = sim::Decoder::GcPlus { tr: 2 };
        let a = run_scenario(&sc, 10, &MonteCarlo::new(3));
        let b = run_scenario(&exact, 10, &MonteCarlo::new(3));
        for (r, (ta, tb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
            assert_eq!(ta.standard, tb.standard, "round {r}");
            assert_eq!(ta.full, tb.full, "round {r}");
            assert_eq!(ta.partial, tb.partial, "round {r}");
            assert_eq!(ta.transmissions, tb.transmissions, "round {r}");
            assert_eq!(tb.none, ta.none + ta.approx, "round {r}");
        }
        let approx: usize = a.rounds.iter().map(|t| t.approx).sum();
        let hist: usize = a.rounds.iter().flat_map(|t| t.residual_hist.iter()).sum();
        assert!(approx > 0, "moderate erasures should trigger some fallbacks");
        assert_eq!(hist, approx, "each accepted approx round fills exactly one bucket");
    }

    #[test]
    fn policy_retries_lift_update_rate_and_stay_thread_invariant() {
        let sc = registry::find("policy-retry-bursty").unwrap();
        let want = run_scenario(&sc, 8, &MonteCarlo::new(19).with_threads(1));
        for threads in [2usize, 8] {
            let got = run_scenario(&sc, 8, &MonteCarlo::new(19).with_threads(threads));
            assert_eq!(got, want, "threads={threads}");
        }
        let sum = |f: fn(&RoundTally) -> usize| want.rounds.iter().map(f).sum::<usize>();
        assert!(sum(|t| t.retries) > 0, "the retry policy must attempt retransmissions");
        assert!(sum(|t| t.recovered) > 0, "some retransmissions should succeed");
        assert!(sum(|t| t.recovered) <= sum(|t| t.retries));
        for (r, t) in want.rounds.iter().enumerate() {
            assert_eq!(t.standard + t.full + t.partial + t.approx + t.none, t.trials, "round {r}");
        }
        // retransmission only flips failed links up and the fallback only
        // reclassifies outages, so the update count cannot drop vs the
        // policy-free run on the same emission stream
        let mut base = sc.clone();
        base.policy = None;
        let plain = run_scenario(&base, 8, &MonteCarlo::new(19).with_threads(1));
        let updates = |s: &RoundSeries| {
            s.rounds.iter().map(|t| t.standard + t.full + t.partial + t.approx).sum::<usize>()
        };
        assert!(
            updates(&want) >= updates(&plain),
            "policy lost updates: {} < {}",
            updates(&want),
            updates(&plain)
        );
    }

    #[test]
    fn policy_fault_injection_kills_links_and_partitions() {
        let sc = registry::find("policy-faults-smoke").unwrap();
        let series = run_scenario(&sc, 6, &MonteCarlo::new(7));
        let sum = |f: fn(&RoundTally) -> usize| series.rounds.iter().map(f).sum::<usize>();
        assert!(sum(|t| t.killed) > 0, "kill lists and the crash window must force links down");
        for (r, t) in series.rounds.iter().enumerate() {
            assert_eq!(t.trials, 6, "round {r}");
            assert_eq!(t.standard + t.full + t.partial + t.approx + t.none, t.trials, "round {r}");
        }
        // the crash window [2, 4) forces extra kills in those rounds
        assert!(
            series.rounds[2].killed > series.rounds[0].killed,
            "crash rounds must kill more links than pre-crash rounds"
        );
    }

    #[test]
    fn binary_adversarial_sweep_audits_exactly_and_stays_thread_invariant() {
        let sc = registry::find("byz-binary").unwrap();
        let want = run_scenario(&sc, 10, &MonteCarlo::new(5).with_threads(1));
        for threads in [2usize, 8] {
            let got = run_scenario(&sc, 10, &MonteCarlo::new(5).with_threads(threads));
            assert_eq!(got, want, "threads={threads}");
        }
        let sum = |f: fn(&RoundTally) -> usize| want.rounds.iter().map(f).sum::<usize>();
        assert!(sum(|t| t.corrupted) > 0, "30% flippers must corrupt something");
        assert!(sum(|t| t.detected) > 0, "the exact parity audit should fire");
        assert!(sum(|t| t.detected) <= sum(|t| t.corrupted));
        assert!(sum(|t| t.excised) >= sum(|t| t.detected));
        for (r, t) in want.rounds.iter().enumerate() {
            assert_eq!(t.standard + t.full + t.partial + t.approx + t.none, t.trials, "round {r}");
        }
    }

    #[test]
    fn round_series_merge_zero_extends() {
        let mut a = RoundSeries::default();
        a.ensure_len(1);
        a.rounds[0].trials = 2;
        let mut b = RoundSeries::default();
        b.ensure_len(3);
        b.rounds[2].full = 1;
        a.merge(b);
        assert_eq!(a.rounds.len(), 3);
        assert_eq!(a.rounds[0].trials, 2);
        assert_eq!(a.rounds[2].full, 1);
    }
}
