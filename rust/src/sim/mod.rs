//! Coding-layer simulation with synthetic payload vectors.
//!
//! Runs the full CoGC communication round — gradient sharing, partial sums,
//! uplink erasure, standard GC decode, GC⁺ decode — on synthetic gradient
//! vectors, *without* the model runtime. This validates the decode maths
//! end-to-end (recovered payloads vs ground truth) and produces the
//! statistics of Figs. 4/6 quickly; the `coordinator` module runs the same
//! round structure against real model payloads.
//!
//! Entry points: [`simulate_round`] for one fully-inspectable round
//! ([`SimRound`] carries the aggregate, the ground truth, and the decode
//! error) and [`sweep`] for [`MonteCarlo`]-parallel trial sweeps folding
//! into [`SweepStats`]. All randomness flows through explicit `Rng`
//! streams, so sweeps are bit-identical at every `--threads` value.
//!
//! Link erasures are drawn through a (possibly stateful)
//! [`ChannelModel`](crate::scenario::ChannelModel): repeated attempts
//! within a round see the channel state *evolve* (a burst can kill
//! consecutive repeats — exactly the regime where repetition stops
//! helping), and [`sweep`] resets a fresh per-trial state from the
//! [`CHANNEL_STREAM`](crate::scenario::CHANNEL_STREAM) substream so tallies
//! stay bit-identical at any thread count. Pass
//! [`Iid`](crate::scenario::Iid) for the paper's memoryless behavior.

use crate::gc::{self, FrCode, GcCode};
use crate::linalg::Matrix;
use crate::network::{Network, Realization, SparseRealization};
use crate::parallel::{Accumulate, MonteCarlo};
use crate::scenario::{ChannelModel, CHANNEL_STREAM};
use crate::util::rng::Rng;

/// Outcome of one simulated round.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Standard GC decoded the exact sum (attempt index that succeeded).
    Standard { attempt: usize },
    /// GC⁺ recovered all M local payloads.
    Full,
    /// GC⁺ recovered a proper subset.
    Partial { k4: Vec<usize> },
    /// Nothing decodable.
    None,
}

#[derive(Clone, Debug)]
pub struct SimRound {
    pub outcome: Outcome,
    /// The PS-side aggregate: exact mean (standard / full) or subset mean
    /// (partial); `None` when the round decoded nothing.
    pub aggregate: Option<Vec<f64>>,
    /// Ground-truth mean over all M payloads.
    pub true_mean: Vec<f64>,
    /// Max |aggregate − achievable target| (exact mean for Standard/Full,
    /// subset mean for Partial) — the numerical decode error.
    pub decode_err: f64,
    pub transmissions: usize,
}

/// Decode policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decoder {
    /// Standard GC over `attempts` repeats; all-or-nothing per attempt.
    Standard { attempts: usize },
    /// GC⁺ over `tr` stacked attempts (complete + incomplete sums uplinked).
    GcPlus { tr: usize },
}

/// Reusable per-worker buffers of [`simulate_round_scratch`]: the channel
/// realization, the observed attempts, the delivered partial sums (in
/// stack order), and the persistent incremental GC⁺ decoder. One instance
/// per worker serves every trial of a sweep — steady-state rounds allocate
/// only their returned [`SimRound`].
pub struct SimScratch {
    real: Realization,
    payload: Matrix,
    /// Observed attempts of the round (slots reused across trials).
    attempts: Vec<gc::Attempt>,
    /// Partial sums of the delivered rows, stacked across attempts in the
    /// exact order the decoder rows were pushed.
    sums: Matrix,
    /// Start row of each attempt's block inside `sums`.
    starts: Vec<usize>,
    dec: gc::GcPlusDecoder,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch {
            real: Realization::perfect(0),
            payload: Matrix::zeros(0, 0),
            attempts: Vec::new(),
            sums: Matrix::zeros(0, 0),
            starts: Vec::new(),
            dec: gc::GcPlusDecoder::new(0),
        }
    }
}

impl Default for SimScratch {
    fn default() -> Self {
        SimScratch::new()
    }
}

/// Simulate one CoGC round over synthetic payloads `G` (`M×D` normal).
///
/// `ch` supplies the link realizations and must have been `reset` for this
/// trial (stateless models like `Iid` need no reset); its state evolves
/// across the round's communication attempts. Allocating convenience form
/// of [`simulate_round_scratch`].
pub fn simulate_round(
    net: &Network,
    ch: &mut dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
) -> SimRound {
    let mut scratch = SimScratch::new();
    simulate_round_scratch(net, ch, m, s, d, decoder, rng, &mut scratch)
}

/// [`simulate_round`] with pooled buffers: the GC⁺ path feeds each
/// attempt's delivered coefficient rows into the persistent incremental
/// decoder (no re-stack, no per-block re-RREF) and computes partial sums
/// only for delivered rows. Identical outcomes and draw order to the
/// allocating form for every `(net, seed)`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_scratch(
    net: &Network,
    ch: &mut dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
    sc: &mut SimScratch,
) -> SimRound {
    // synthetic payloads, drawn in the canonical row-major order
    if sc.payload.rows != m || sc.payload.cols != d {
        sc.payload = Matrix::zeros(m, d);
    }
    for x in &mut sc.payload.data {
        *x = rng.normal();
    }
    let payload = &sc.payload;
    let true_mean: Vec<f64> = (0..d)
        .map(|j| (0..m).map(|i| payload[(i, j)]).sum::<f64>() / m as f64)
        .collect();

    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } => tr,
    };

    sc.dec.reset(m);
    if sc.sums.cols != d {
        sc.sums = Matrix::zeros(0, d);
    } else {
        sc.sums.clear_rows();
    }
    sc.starts.clear();
    let mut transmissions = 0usize;

    for a in 0..attempts_n {
        let code = GcCode::generate(m, s, rng);
        ch.sample_into(net, rng, &mut sc.real);
        if sc.attempts.len() <= a {
            sc.attempts.push(gc::Attempt::empty());
        }
        let att = &mut sc.attempts[a];
        gc::Attempt::observe_into(&code, &sc.real, att);
        // gradient-sharing phase: s transmissions per client
        transmissions += s * m;
        // uplink: standard GC sends only complete sums; GC+ sends all
        transmissions += match decoder {
            Decoder::Standard { .. } => att.complete.len(),
            Decoder::GcPlus { .. } => m, // every client attempts its uplink
        };
        // partial sums of the *delivered* rows only, pushed in stack order
        sc.starts.push(sc.sums.rows);
        for &r in &att.delivered {
            let start = sc.sums.data.len();
            sc.sums.data.resize(start + d, 0.0);
            sc.sums.rows += 1;
            let orow = &mut sc.sums.data[start..start + d];
            for k in 0..m {
                let c = att.perturbed[(r, k)];
                if c == 0.0 {
                    continue;
                }
                for (o, p) in orow.iter_mut().zip(payload.row(k)) {
                    *o += c * p;
                }
            }
            if matches!(decoder, Decoder::GcPlus { .. }) {
                sc.dec.push_row(att.perturbed.row(r));
            }
        }
    }

    // 1) standard decode on any single attempt with >= M - s complete sums
    for (i, att) in sc.attempts[..attempts_n].iter().enumerate() {
        if att.complete.len() < m - s {
            continue;
        }
        // complete rows of the perturbed matrix are exactly the original
        // code rows, so the combinator solve runs on them directly
        let Some(a) = gc::combinator::find_combinator_rows(&att.perturbed, s, &att.complete)
        else {
            continue;
        };
        // combine the delivered partial sums (combinator support is on
        // complete ⊆ delivered rows, in ascending order as before)
        let mut got = vec![0.0f64; d];
        for (off, &r) in att.delivered.iter().enumerate() {
            let coef = a[r];
            if coef == 0.0 {
                continue;
            }
            for (o, v) in got.iter_mut().zip(sc.sums.row(sc.starts[i] + off)) {
                *o += coef * v;
            }
        }
        let target: Vec<f64> = true_mean.iter().map(|x| x * m as f64).collect();
        let err = max_abs_diff(&got, &target);
        let aggregate: Vec<f64> = got.iter().map(|x| x / m as f64).collect();
        return SimRound {
            outcome: Outcome::Standard { attempt: i },
            aggregate: Some(aggregate),
            true_mean,
            decode_err: err,
            transmissions,
        };
    }

    if let Decoder::Standard { .. } = decoder {
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }

    // 2) GC+ complementary decode: the incremental engine already holds
    // the reduced form of every delivered coefficient row
    if sc.dec.decodable_count() == 0 {
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }
    let dec = sc.dec.decode();
    let decoded = dec.weights.matmul(&sc.sums);
    // decode error vs the true individual payloads
    let mut err = 0.0f64;
    for (i, &client) in dec.k4.iter().enumerate() {
        err = err.max(max_abs_diff(decoded.row(i), payload.row(client)));
    }
    // aggregate = mean over K4 (paper eq. (23))
    let aggregate: Vec<f64> = (0..d)
        .map(|j| (0..dec.k4.len()).map(|i| decoded[(i, j)]).sum::<f64>() / dec.k4.len() as f64)
        .collect();
    let outcome = if dec.k4.len() == m {
        Outcome::Full
    } else {
        Outcome::Partial { k4: dec.k4 }
    };
    SimRound { outcome, aggregate: Some(aggregate), true_mean, decode_err: err, transmissions }
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

// ── Fractional-repetition round engine (structured large-M path) ────────

/// Outcome of one fractional-repetition round. Mirrors [`Outcome`] but
/// carries only the covered-group count for partial recovery — never an
/// O(M) member list — so the structured path stays O(M·(s+1)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrOutcome {
    /// A single attempt covered every group (exact-sum standard decode;
    /// attempt index that succeeded).
    Standard { attempt: usize },
    /// The union over GC⁺ repeats covered every group.
    Full,
    /// A proper, non-empty subset of groups was covered.
    Partial { covered_groups: usize },
    /// Nothing decodable.
    None,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrRound {
    pub outcome: FrOutcome,
    pub transmissions: usize,
}

impl FrRound {
    /// |K₄| of the round: recovered clients (members of covered groups).
    pub fn k4_count(&self, code: &FrCode) -> usize {
        match self.outcome {
            FrOutcome::Standard { .. } | FrOutcome::Full => code.m,
            FrOutcome::Partial { covered_groups } => covered_groups * (code.s + 1),
            FrOutcome::None => 0,
        }
    }
}

/// Reusable per-worker buffers of [`simulate_round_fr`]: the sparse
/// realization and the union coverage accumulator — everything O(M·(s+1)).
#[derive(Default)]
pub struct FrSimScratch {
    real: SparseRealization,
    acc: Vec<bool>,
}

impl FrSimScratch {
    pub fn new() -> FrSimScratch {
        FrSimScratch::default()
    }
}

/// Simulate one CoGC round under a fractional-repetition code.
///
/// The structured analogue of [`simulate_round_scratch`]: erasures are
/// drawn only on the group support ([`ChannelModel::sample_sparse_into`])
/// and decoding is the per-group membership scan of
/// [`FrCode::covered`] — dispatched through
/// [`crate::parallel::parallel_map`] with `decode_threads` workers — in
/// place of the RREF engine. Nothing here allocates O(M²).
///
/// Outcome semantics mirror the dense engine: a single attempt covering
/// every group is a standard (exact-sum) decode; under [`Decoder::GcPlus`]
/// the coverage union over `tr` repeats yields full / partial / no
/// recovery. Transmission accounting matches the dense engine too
/// (`s·M` sharing per attempt; uplinks from complete rows under standard,
/// from every client under GC⁺). No payload vectors are drawn — the FR
/// decode is coefficient-free, so the outcome depends only on the channel.
pub fn simulate_round_fr(
    code: &FrCode,
    net: &Network,
    ch: &mut dyn ChannelModel,
    decoder: Decoder,
    decode_threads: usize,
    rng: &mut Rng,
    sc: &mut FrSimScratch,
) -> FrRound {
    let sup = code.sparse_support();
    let (m, s) = (code.m, code.s);
    debug_assert_eq!(net.m, m);
    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } => tr,
    };
    sc.acc.clear();
    sc.acc.resize(code.groups(), false);
    let mut transmissions = 0usize;
    let mut standard_at: Option<usize> = None;

    for a in 0..attempts_n {
        ch.sample_sparse_into(&sup, net, rng, &mut sc.real);
        // gradient-sharing phase: s transmissions per client
        transmissions += s * m;
        // uplink: standard GC sends only complete delivered sums; GC+ all
        transmissions += match decoder {
            Decoder::Standard { .. } => {
                (0..m).filter(|&r| sc.real.row_delivered_complete(r)).count()
            }
            Decoder::GcPlus { .. } => m,
        };
        let covered = code.covered(&sc.real, decode_threads);
        if standard_at.is_none() && FrCode::all_covered(&covered) {
            standard_at = Some(a);
        }
        FrCode::union_covered(&mut sc.acc, &covered);
    }

    // 1) standard decode: some single attempt covered every group
    if let Some(attempt) = standard_at {
        return FrRound { outcome: FrOutcome::Standard { attempt }, transmissions };
    }
    if let Decoder::Standard { .. } = decoder {
        return FrRound { outcome: FrOutcome::None, transmissions };
    }
    // 2) GC⁺ complementary decode: union coverage over the tr repeats
    let covered_groups = FrCode::covered_groups(&sc.acc);
    let outcome = if covered_groups == code.groups() {
        FrOutcome::Full
    } else if covered_groups > 0 {
        FrOutcome::Partial { covered_groups }
    } else {
        FrOutcome::None
    };
    FrRound { outcome, transmissions }
}

/// Aggregate tallies of a [`sweep`] over many simulated rounds.
///
/// Every field combines associatively (counts, integer sums, a maximum), so
/// per-worker instances merge exactly — the requirement of the parallel
/// engine's determinism guarantee. Note the decode error is tracked as a
/// *maximum* (order-independent), never an order-sensitive float sum.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepStats {
    pub trials: usize,
    /// Rounds decoded by the standard (binary) GC combinator.
    pub standard: usize,
    /// Rounds where GC⁺ recovered all M payloads.
    pub full: usize,
    /// Rounds where GC⁺ recovered a proper subset.
    pub partial: usize,
    /// Rounds with nothing decodable.
    pub none: usize,
    /// Total transmissions consumed across all rounds.
    pub transmissions: usize,
    /// Worst numerical decode error observed over all decoding rounds.
    pub max_decode_err: f64,
}

impl SweepStats {
    /// Fraction of rounds that produced *some* global update.
    pub fn p_update(&self) -> f64 {
        (self.standard + self.full + self.partial) as f64 / self.trials as f64
    }

    pub fn mean_transmissions(&self) -> f64 {
        self.transmissions as f64 / self.trials as f64
    }
}

impl Accumulate for SweepStats {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.standard += other.standard;
        self.full += other.full;
        self.partial += other.partial;
        self.none += other.none;
        self.transmissions += other.transmissions;
        self.max_decode_err = self.max_decode_err.max(other.max_decode_err);
    }
}

/// Run `trials` independent [`simulate_round`]s through the parallel engine
/// and tally the outcomes. Bit-identical for any thread count.
///
/// `ch` is a prototype: the engine clones it once per worker and resets the
/// clone from each trial's channel-state substream, so stateful models are
/// independent across trials and identical for every work-stealing
/// schedule. All round buffers (realization, attempts, partial sums, the
/// incremental decoder) are pooled per worker via [`SimScratch`] — the
/// steady-state trial body allocates only its round result.
pub fn sweep(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    trials: usize,
    mc: &MonteCarlo,
) -> SweepStats {
    mc.run_scratch(
        trials,
        || (ch.clone_box(), SimScratch::new()),
        |t, rng, acc: &mut SweepStats, (chb, sc)| {
            chb.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            let r = simulate_round_scratch(net, &mut **chb, m, s, d, decoder, rng, sc);
            acc.trials += 1;
            match r.outcome {
                Outcome::Standard { .. } => acc.standard += 1,
                Outcome::Full => acc.full += 1,
                Outcome::Partial { .. } => acc.partial += 1,
                Outcome::None => acc.none += 1,
            }
            acc.transmissions += r.transmissions;
            acc.max_decode_err = acc.max_decode_err.max(r.decode_err);
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Iid;
    use crate::testing::Prop;

    #[test]
    fn perfect_network_standard_decodes_exactly() {
        let net = Network::perfect(10);
        let mut rng = Rng::new(1);
        let r =
            simulate_round(&net, &mut Iid, 10, 7, 23, Decoder::Standard { attempts: 1 }, &mut rng);
        assert!(matches!(r.outcome, Outcome::Standard { attempt: 0 }));
        assert!(r.decode_err < 1e-6, "err = {}", r.decode_err);
        let agg = r.aggregate.unwrap();
        assert!(max_abs_diff(&agg, &r.true_mean) < 1e-9);
        // transmissions: sM + M complete uplinks = 7*10 + 10
        assert_eq!(r.transmissions, 80);
    }

    #[test]
    fn gcplus_full_recovery_matches_true_mean() {
        // moderate c2c erasures + good uplinks: standard GC often fails
        // (incomplete sums) but the perturbation-boosted rank lets GC+
        // achieve full recovery, matching the exact mean.
        let net = Network::homogeneous(10, 0.1, 0.5);
        let mut rng = Rng::new(2);
        let mut fulls = 0;
        for _ in 0..60 {
            let r =
                simulate_round(&net, &mut Iid, 10, 7, 11, Decoder::GcPlus { tr: 2 }, &mut rng);
            if r.outcome == Outcome::Full {
                fulls += 1;
                assert!(r.decode_err < 1e-6);
                assert!(max_abs_diff(&r.aggregate.unwrap(), &r.true_mean) < 1e-8);
            }
        }
        assert!(fulls > 10, "full recoveries: {fulls}");
    }

    #[test]
    fn prop_decode_error_always_small_when_decoding() {
        Prop::new(30).forall("sim decode error", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m);
            let p = rng.uniform(0.1, 0.8);
            let net = Network::homogeneous(m, p, p);
            let dec = if rng.bernoulli(0.5) {
                Decoder::Standard { attempts: 2 }
            } else {
                Decoder::GcPlus { tr: 2 }
            };
            let r = simulate_round(&net, &mut Iid, m, s, 9, dec, rng);
            assert!(
                r.decode_err < 1e-5,
                "decode error {} (outcome {:?})",
                r.decode_err,
                r.outcome
            );
        });
    }

    #[test]
    fn sweep_tallies_partition_and_decode_exactly() {
        let net = Network::homogeneous(8, 0.3, 0.3);
        let st = sweep(&net, &Iid, 8, 3, 5, Decoder::GcPlus { tr: 2 }, 300, &MonteCarlo::new(9));
        assert_eq!(st.trials, 300);
        assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
        assert!(st.p_update() > 0.0 && st.p_update() <= 1.0);
        assert!(st.mean_transmissions() > 0.0);
        assert!(st.max_decode_err < 1e-5, "decode err {}", st.max_decode_err);
    }

    #[test]
    fn sweep_is_thread_count_invariant() {
        let net = Network::homogeneous(8, 0.4, 0.4);
        let run = |threads: usize| {
            sweep(
                &net,
                &Iid,
                8,
                3,
                5,
                Decoder::GcPlus { tr: 2 },
                400,
                &MonteCarlo::new(17).with_threads(threads),
            )
        };
        let want = run(1);
        for threads in [2usize, 8] {
            assert_eq!(run(threads), want, "threads={threads}");
        }
    }

    #[test]
    fn standard_none_when_all_uplinks_dead() {
        let net = Network::homogeneous(6, 1.0, 0.0);
        let mut rng = Rng::new(3);
        let r =
            simulate_round(&net, &mut Iid, 6, 2, 5, Decoder::Standard { attempts: 3 }, &mut rng);
        assert_eq!(r.outcome, Outcome::None);
        assert!(r.aggregate.is_none());
    }

    #[test]
    fn fr_perfect_network_standard_decodes_first_attempt() {
        let code = FrCode::new(12, 3).unwrap();
        let net = Network::perfect(12);
        let mut rng = Rng::new(1);
        let mut sc = FrSimScratch::new();
        let r = simulate_round_fr(
            &code,
            &net,
            &mut Iid,
            Decoder::Standard { attempts: 1 },
            1,
            &mut rng,
            &mut sc,
        );
        assert_eq!(r.outcome, FrOutcome::Standard { attempt: 0 });
        // transmissions: sM sharing + M complete uplinks = 3*12 + 12
        assert_eq!(r.transmissions, 48);
        assert_eq!(r.k4_count(&code), 12);
    }

    #[test]
    fn fr_dead_uplinks_decode_nothing() {
        let code = FrCode::new(8, 1).unwrap();
        let net = Network::homogeneous(8, 1.0, 0.0);
        let mut rng = Rng::new(2);
        let mut sc = FrSimScratch::new();
        for dec in [Decoder::Standard { attempts: 2 }, Decoder::GcPlus { tr: 2 }] {
            let r = simulate_round_fr(&code, &net, &mut Iid, dec, 1, &mut rng, &mut sc);
            assert_eq!(r.outcome, FrOutcome::None);
            assert_eq!(r.k4_count(&code), 0);
        }
    }

    #[test]
    fn fr_outcomes_partition_and_partials_appear() {
        // lossy enough that coverage is usually partial over GC+ repeats
        let code = FrCode::new(12, 2).unwrap();
        let net = Network::homogeneous(12, 0.6, 0.5);
        let mut rng = Rng::new(5);
        let mut sc = FrSimScratch::new();
        let (mut partial, mut k4_tot) = (0usize, 0usize);
        for _ in 0..200 {
            let r = simulate_round_fr(
                &code,
                &net,
                &mut Iid,
                Decoder::GcPlus { tr: 2 },
                1,
                &mut rng,
                &mut sc,
            );
            if let FrOutcome::Partial { covered_groups } = r.outcome {
                partial += 1;
                assert!(covered_groups >= 1 && covered_groups < code.groups());
                assert_eq!(r.k4_count(&code), covered_groups * 3);
            }
            k4_tot += r.k4_count(&code);
        }
        assert!(partial > 20, "partials: {partial}");
        assert!(k4_tot > 0);
    }

    #[test]
    fn fr_decode_threads_do_not_change_outcomes() {
        let code = FrCode::new(24, 3).unwrap();
        let net = Network::homogeneous(24, 0.4, 0.3);
        let run = |threads: usize| {
            let mut rng = Rng::new(7);
            let mut sc = FrSimScratch::new();
            (0..50)
                .map(|_| {
                    simulate_round_fr(
                        &code,
                        &net,
                        &mut Iid,
                        Decoder::GcPlus { tr: 2 },
                        threads,
                        &mut rng,
                        &mut sc,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4));
    }
}
