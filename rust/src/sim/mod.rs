//! Coding-layer simulation with synthetic payload vectors.
//!
//! Runs the full CoGC communication round — gradient sharing, partial sums,
//! uplink erasure, standard GC decode, GC⁺ decode — on synthetic gradient
//! vectors, *without* the model runtime. This validates the decode maths
//! end-to-end (recovered payloads vs ground truth) and produces the
//! statistics of Figs. 4/6 quickly; the `coordinator` module runs the same
//! round structure against real model payloads.
//!
//! Entry points: [`simulate_round`] for one fully-inspectable round
//! ([`SimRound`] carries the aggregate, the ground truth, and the decode
//! error) and [`sweep`] for [`MonteCarlo`]-parallel trial sweeps folding
//! into [`SweepStats`]. All randomness flows through explicit `Rng`
//! streams, so sweeps are bit-identical at every `--threads` value.
//!
//! Link erasures are drawn through a (possibly stateful)
//! [`ChannelModel`](crate::scenario::ChannelModel): repeated attempts
//! within a round see the channel state *evolve* (a burst can kill
//! consecutive repeats — exactly the regime where repetition stops
//! helping), and [`sweep`] resets a fresh per-trial state from the
//! [`CHANNEL_STREAM`](crate::scenario::CHANNEL_STREAM) substream so tallies
//! stay bit-identical at any thread count. Pass
//! [`Iid`](crate::scenario::Iid) for the paper's memoryless behavior.

use crate::gc::{self, BinaryCode, FrCode, GcCode, IntRref};
use crate::linalg::Matrix;
use crate::network::{Network, Realization, SparseRealization};
use crate::parallel::{Accumulate, MonteCarlo};
use crate::scenario::{ChannelModel, CHANNEL_STREAM};
use crate::telemetry;
use crate::util::rng::Rng;

/// Outcome of one simulated round.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Standard GC decoded the exact sum (attempt index that succeeded).
    Standard { attempt: usize },
    /// GC⁺ recovered all M local payloads.
    Full,
    /// GC⁺ recovered a proper subset.
    Partial { k4: Vec<usize> },
    /// Degraded mode: nothing decoded exactly, but the least-squares
    /// fallback combined the delivered rows into an approximate sum.
    /// `residual` is the coefficient-space miss `‖𝟙 − w·A‖₂` (0 would mean
    /// the exact decoder had succeeded; `√M` means nothing was recovered).
    Approx { residual: f64 },
    /// Nothing decodable.
    None,
}

#[derive(Clone, Debug)]
pub struct SimRound {
    pub outcome: Outcome,
    /// The PS-side aggregate: exact mean (standard / full) or subset mean
    /// (partial); `None` when the round decoded nothing.
    pub aggregate: Option<Vec<f64>>,
    /// Ground-truth mean over all M payloads.
    pub true_mean: Vec<f64>,
    /// Max |aggregate − achievable target| (exact mean for Standard/Full,
    /// subset mean for Partial) — the numerical decode error. For
    /// [`Outcome::Approx`] rounds this is instead the *gradient* error
    /// |aggregate − true mean|: the approximation cost, not rounding.
    pub decode_err: f64,
    pub transmissions: usize,
}

/// Decode policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decoder {
    /// Standard GC over `attempts` repeats; all-or-nothing per attempt.
    Standard { attempts: usize },
    /// GC⁺ over `tr` stacked attempts (complete + incomplete sums uplinked).
    GcPlus { tr: usize },
    /// GC⁺ with the degraded-mode fallback: identical round structure and
    /// draws to [`Decoder::GcPlus`], but when nothing decodes exactly the
    /// round returns the optimal least-squares combine of the delivered
    /// rows ([`Outcome::Approx`]) instead of a hard outage.
    Approx { tr: usize },
}

/// Reusable per-worker buffers of [`simulate_round_scratch`]: the channel
/// realization, the observed attempts, the delivered partial sums (in
/// stack order), and the persistent incremental GC⁺ decoder. One instance
/// per worker serves every trial of a sweep — steady-state rounds allocate
/// only their returned [`SimRound`].
pub struct SimScratch {
    real: Realization,
    payload: Matrix,
    /// Observed attempts of the round (slots reused across trials).
    attempts: Vec<gc::Attempt>,
    /// Partial sums of the delivered rows, stacked across attempts in the
    /// exact order the decoder rows were pushed.
    sums: Matrix,
    /// Start row of each attempt's block inside `sums`.
    starts: Vec<usize>,
    dec: gc::GcPlusDecoder,
    /// Pooled telemetry shard (flat integer arrays — part of the
    /// zero-allocation scratch contract). The sweep engine merges worker
    /// shards into the global registry in index order.
    tel: telemetry::Shard,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch {
            real: Realization::perfect(0),
            payload: Matrix::zeros(0, 0),
            attempts: Vec::new(),
            sums: Matrix::zeros(0, 0),
            starts: Vec::new(),
            dec: gc::GcPlusDecoder::new(0),
            tel: telemetry::Shard::new(),
        }
    }

    /// Peeling fast-path vs dense-forwarded row split of the round just
    /// simulated (the decoder keeps its state until the next round resets
    /// it) — the armed-only per-round sweep CSV columns read this.
    pub fn peel_split(&self) -> (usize, usize) {
        self.dec.peel_split()
    }

    /// Record the round just simulated into the pooled telemetry shard.
    pub fn harvest(&mut self) {
        self.dec.harvest(&mut self.tel);
    }

    /// The pooled shard (engine projection + caller-side audit counters).
    pub fn tel_mut(&mut self) -> &mut telemetry::Shard {
        &mut self.tel
    }
}

impl Default for SimScratch {
    fn default() -> Self {
        SimScratch::new()
    }
}

/// Simulate one CoGC round over synthetic payloads `G` (`M×D` normal).
///
/// `ch` supplies the link realizations and must have been `reset` for this
/// trial (stateless models like `Iid` need no reset); its state evolves
/// across the round's communication attempts. Allocating convenience form
/// of [`simulate_round_scratch`].
pub fn simulate_round(
    net: &Network,
    ch: &mut dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
) -> SimRound {
    let mut scratch = SimScratch::new();
    simulate_round_scratch(net, ch, m, s, d, decoder, rng, &mut scratch)
}

/// [`simulate_round`] with pooled buffers: the GC⁺ path feeds each
/// attempt's delivered coefficient rows into the persistent incremental
/// decoder (no re-stack, no per-block re-RREF) and computes partial sums
/// only for delivered rows. Identical outcomes and draw order to the
/// allocating form for every `(net, seed)`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_scratch(
    net: &Network,
    ch: &mut dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
    sc: &mut SimScratch,
) -> SimRound {
    // synthetic payloads, drawn in the canonical row-major order
    if sc.payload.rows != m || sc.payload.cols != d {
        sc.payload = Matrix::zeros(m, d);
    }
    for x in &mut sc.payload.data {
        *x = rng.normal();
    }
    let payload = &sc.payload;
    let true_mean: Vec<f64> = (0..d)
        .map(|j| (0..m).map(|i| payload[(i, j)]).sum::<f64>() / m as f64)
        .collect();

    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
    };

    sc.dec.reset(m);
    if sc.sums.cols != d {
        sc.sums = Matrix::zeros(0, d);
    } else {
        sc.sums.clear_rows();
    }
    sc.starts.clear();
    let mut transmissions = 0usize;

    for a in 0..attempts_n {
        let code = GcCode::generate(m, s, rng);
        ch.sample_into(net, rng, &mut sc.real);
        if sc.attempts.len() <= a {
            sc.attempts.push(gc::Attempt::empty());
        }
        let att = &mut sc.attempts[a];
        gc::Attempt::observe_into(&code, &sc.real, att);
        // gradient-sharing phase: s transmissions per client
        transmissions += s * m;
        // uplink: standard GC sends only complete sums; GC+ sends all
        transmissions += match decoder {
            Decoder::Standard { .. } => att.complete.len(),
            Decoder::GcPlus { .. } | Decoder::Approx { .. } => m, // every client uplinks
        };
        // partial sums of the *delivered* rows only, pushed in stack order
        sc.starts.push(sc.sums.rows);
        for &r in &att.delivered {
            let start = sc.sums.data.len();
            sc.sums.data.resize(start + d, 0.0);
            sc.sums.rows += 1;
            let orow = &mut sc.sums.data[start..start + d];
            for k in 0..m {
                let c = att.perturbed[(r, k)];
                if c == 0.0 {
                    continue;
                }
                for (o, p) in orow.iter_mut().zip(payload.row(k)) {
                    *o += c * p;
                }
            }
            if matches!(decoder, Decoder::GcPlus { .. } | Decoder::Approx { .. }) {
                sc.dec.push_row(att.perturbed.row(r));
            }
        }
    }

    // 1) standard decode on any single attempt with >= M - s complete sums
    for (i, att) in sc.attempts[..attempts_n].iter().enumerate() {
        if att.complete.len() < m - s {
            continue;
        }
        // complete rows of the perturbed matrix are exactly the original
        // code rows, so the combinator solve runs on them directly
        let Some(a) = gc::combinator::find_combinator_rows(&att.perturbed, s, &att.complete)
        else {
            continue;
        };
        // combine the delivered partial sums (combinator support is on
        // complete ⊆ delivered rows, in ascending order as before)
        let mut got = vec![0.0f64; d];
        for (off, &r) in att.delivered.iter().enumerate() {
            let coef = a[r];
            if coef == 0.0 {
                continue;
            }
            for (o, v) in got.iter_mut().zip(sc.sums.row(sc.starts[i] + off)) {
                *o += coef * v;
            }
        }
        let target: Vec<f64> = true_mean.iter().map(|x| x * m as f64).collect();
        let err = max_abs_diff(&got, &target);
        let aggregate: Vec<f64> = got.iter().map(|x| x / m as f64).collect();
        return SimRound {
            outcome: Outcome::Standard { attempt: i },
            aggregate: Some(aggregate),
            true_mean,
            decode_err: err,
            transmissions,
        };
    }

    if let Decoder::Standard { .. } = decoder {
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }

    // 2) GC+ complementary decode: the incremental engine already holds
    // the reduced form of every delivered coefficient row
    if sc.dec.decodable_count() == 0 {
        // degraded mode: under the approx decoder, fall back to the
        // optimal least-squares combine of whatever rows did arrive
        if matches!(decoder, Decoder::Approx { .. }) && sc.dec.rank() > 0 {
            if let Some(sol) = gc::approx_sum(&sc.dec) {
                let mut agg = vec![0.0f64; d];
                for (i, &w) in sol.weights.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    for (o, v) in agg.iter_mut().zip(sc.sums.row(i)) {
                        *o += w * v;
                    }
                }
                for a in agg.iter_mut() {
                    *a /= m as f64;
                }
                let err = max_abs_diff(&agg, &true_mean);
                return SimRound {
                    outcome: Outcome::Approx { residual: sol.residual },
                    aggregate: Some(agg),
                    true_mean,
                    decode_err: err,
                    transmissions,
                };
            }
        }
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }
    let dec = sc.dec.decode();
    let decoded = dec.weights.matmul(&sc.sums);
    // decode error vs the true individual payloads
    let mut err = 0.0f64;
    for (i, &client) in dec.k4.iter().enumerate() {
        err = err.max(max_abs_diff(decoded.row(i), payload.row(client)));
    }
    // aggregate = mean over K4 (paper eq. (23))
    let aggregate: Vec<f64> = (0..d)
        .map(|j| (0..dec.k4.len()).map(|i| decoded[(i, j)]).sum::<f64>() / dec.k4.len() as f64)
        .collect();
    let outcome = if dec.k4.len() == m {
        Outcome::Full
    } else {
        Outcome::Partial { k4: dec.k4 }
    };
    SimRound { outcome, aggregate: Some(aggregate), true_mean, decode_err: err, transmissions }
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

// ── Binary {±1} round engine (exact integer decode path) ────────────────

/// Reusable per-worker buffers of [`simulate_round_binary_scratch`]:
/// mirrors [`SimScratch`] with the float GC⁺ decoder replaced by the exact
/// integer engine ([`IntRref`]) and a cached dense bridge of the
/// deterministic code (the code is fixed per (M, s), so the bridge is
/// built once per worker, not per attempt).
pub struct BinSimScratch {
    real: Realization,
    payload: Matrix,
    /// Dense float mirror of the binary code, for attempt observation
    /// (erasure masking + completeness); rebuilt only when (m, s) change.
    bridge: Option<(BinaryCode, gc::GcCode)>,
    attempts: Vec<gc::Attempt>,
    sums: Matrix,
    starts: Vec<usize>,
    ieng: IntRref,
    /// Float mirror of the stack, fed only under [`Decoder::Approx`]: the
    /// least-squares fallback runs on the float engine's reduced state
    /// (the exact engine stays the decode authority for unit rows).
    fdec: gc::GcPlusDecoder,
    /// Integer row buffer for pushes into the exact engine.
    ibuf: Vec<i64>,
    /// Extraction-weight buffer (one decodable row at a time).
    wbuf: Vec<f64>,
    /// Pooled telemetry shard (see [`SimScratch`]).
    tel: telemetry::Shard,
}

impl BinSimScratch {
    pub fn new() -> BinSimScratch {
        BinSimScratch {
            real: Realization::perfect(0),
            payload: Matrix::zeros(0, 0),
            bridge: None,
            attempts: Vec::new(),
            sums: Matrix::zeros(0, 0),
            starts: Vec::new(),
            ieng: IntRref::new(0),
            fdec: gc::GcPlusDecoder::new(0),
            ibuf: Vec::new(),
            wbuf: Vec::new(),
            tel: telemetry::Shard::new(),
        }
    }

    /// Record the round just simulated (exact integer decode path) into
    /// the pooled telemetry shard.
    pub fn harvest(&mut self) {
        self.tel.absorb_int_engine(self.ieng.rows() as u64, self.ieng.rank() as u64);
    }

    /// The pooled shard (engine projection + caller-side counters).
    pub fn tel_mut(&mut self) -> &mut telemetry::Shard {
        &mut self.tel
    }
}

impl Default for BinSimScratch {
    fn default() -> Self {
        BinSimScratch::new()
    }
}

/// Allocating convenience form of [`simulate_round_binary_scratch`].
pub fn simulate_round_binary(
    net: &Network,
    ch: &mut dyn ChannelModel,
    code: BinaryCode,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
) -> SimRound {
    let mut scratch = BinSimScratch::new();
    simulate_round_binary_scratch(net, ch, code, d, decoder, rng, &mut scratch)
}

/// One CoGC round over the deterministic {±1} binary code, decoded in
/// exact arithmetic.
///
/// Same round structure, transmission accounting, and outcome
/// classification as [`simulate_round_scratch`], with three differences:
/// the code is fixed across attempts (the family is deterministic, so no
/// per-attempt code draw — only channel state consumes randomness); the
/// standard decode solves the combinator over the rationals
/// ([`BinaryCode::combinator_weights`] — a pattern either decodes or it
/// does not, no tolerance band); and the GC⁺ path pushes the delivered
/// ±1 rows into the exact [`IntRref`], whose unit rows and extraction
/// weights are integer-exact. Floats enter only when the exact weights
/// combine the payload sums.
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_binary_scratch(
    net: &Network,
    ch: &mut dyn ChannelModel,
    code: BinaryCode,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
    sc: &mut BinSimScratch,
) -> SimRound {
    let (m, s) = (code.m, code.s);
    debug_assert_eq!(net.m, m);
    if sc.payload.rows != m || sc.payload.cols != d {
        sc.payload = Matrix::zeros(m, d);
    }
    for x in &mut sc.payload.data {
        *x = rng.normal();
    }
    let payload = &sc.payload;
    let true_mean: Vec<f64> = (0..d)
        .map(|j| (0..m).map(|i| payload[(i, j)]).sum::<f64>() / m as f64)
        .collect();

    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
    };

    if !matches!(&sc.bridge, Some((c, _)) if *c == code) {
        sc.bridge = Some((code, code.to_gc_code()));
    }
    let gc_code = &sc.bridge.as_ref().expect("bridge built above").1;

    sc.ieng.reset(m);
    if matches!(decoder, Decoder::Approx { .. }) {
        sc.fdec.reset(m);
    }
    if sc.sums.cols != d {
        sc.sums = Matrix::zeros(0, d);
    } else {
        sc.sums.clear_rows();
    }
    sc.starts.clear();
    let mut transmissions = 0usize;

    for a in 0..attempts_n {
        ch.sample_into(net, rng, &mut sc.real);
        if sc.attempts.len() <= a {
            sc.attempts.push(gc::Attempt::empty());
        }
        let att = &mut sc.attempts[a];
        gc::Attempt::observe_into(gc_code, &sc.real, att);
        transmissions += s * m;
        transmissions += match decoder {
            Decoder::Standard { .. } => att.complete.len(),
            Decoder::GcPlus { .. } | Decoder::Approx { .. } => m,
        };
        sc.starts.push(sc.sums.rows);
        for &r in &att.delivered {
            let start = sc.sums.data.len();
            sc.sums.data.resize(start + d, 0.0);
            sc.sums.rows += 1;
            let orow = &mut sc.sums.data[start..start + d];
            for k in 0..m {
                let c = att.perturbed[(r, k)];
                if c == 0.0 {
                    continue;
                }
                for (o, p) in orow.iter_mut().zip(payload.row(k)) {
                    *o += c * p;
                }
            }
            if matches!(decoder, Decoder::GcPlus { .. } | Decoder::Approx { .. }) {
                // the perturbed entries are exactly 0.0 / ±1.0
                sc.ibuf.clear();
                sc.ibuf.extend(att.perturbed.row(r).iter().map(|&v| {
                    debug_assert_eq!(v, v as i64 as f64);
                    v as i64
                }));
                sc.ieng.push_row(&sc.ibuf);
                if matches!(decoder, Decoder::Approx { .. }) {
                    sc.fdec.push_row(att.perturbed.row(r));
                }
            }
        }
    }

    // 1) standard decode: exact rational combinator over the complete rows
    // (complete perturbed rows equal the original deterministic rows)
    for (i, att) in sc.attempts[..attempts_n].iter().enumerate() {
        if att.complete.len() < m - s {
            continue;
        }
        let Some(a) = code.combinator_weights(&att.complete) else {
            continue;
        };
        let mut got = vec![0.0f64; d];
        let mut next = 0usize;
        for (off, &r) in att.delivered.iter().enumerate() {
            // complete ⊆ delivered, both ascending: advance in lockstep
            if next >= att.complete.len() || att.complete[next] != r {
                continue;
            }
            let coef = a[next];
            next += 1;
            if coef == 0.0 {
                continue;
            }
            for (o, v) in got.iter_mut().zip(sc.sums.row(sc.starts[i] + off)) {
                *o += coef * v;
            }
        }
        let target: Vec<f64> = true_mean.iter().map(|x| x * m as f64).collect();
        let err = max_abs_diff(&got, &target);
        let aggregate: Vec<f64> = got.iter().map(|x| x / m as f64).collect();
        return SimRound {
            outcome: Outcome::Standard { attempt: i },
            aggregate: Some(aggregate),
            true_mean,
            decode_err: err,
            transmissions,
        };
    }

    if let Decoder::Standard { .. } = decoder {
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }

    // 2) GC⁺ complementary decode on the exact engine
    let k4_n = sc.ieng.decodable_count();
    if k4_n == 0 {
        // degraded mode: least-squares fallback over the float mirror
        if matches!(decoder, Decoder::Approx { .. }) && sc.fdec.rank() > 0 {
            if let Some(sol) = gc::approx_sum(&sc.fdec) {
                let mut agg = vec![0.0f64; d];
                for (i, &w) in sol.weights.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    for (o, v) in agg.iter_mut().zip(sc.sums.row(i)) {
                        *o += w * v;
                    }
                }
                for a in agg.iter_mut() {
                    *a /= m as f64;
                }
                let err = max_abs_diff(&agg, &true_mean);
                return SimRound {
                    outcome: Outcome::Approx { residual: sol.residual },
                    aggregate: Some(agg),
                    true_mean,
                    decode_err: err,
                    transmissions,
                };
            }
        }
        return SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
    }
    let mut k4 = Vec::with_capacity(k4_n);
    let mut err = 0.0f64;
    let mut agg = vec![0.0f64; d];
    for (client, row) in sc.ieng.decodable() {
        k4.push(client);
        sc.ieng.t_row_f64(row, &mut sc.wbuf);
        let mut decoded = vec![0.0f64; d];
        for (k, &w) in sc.wbuf.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            for (o, v) in decoded.iter_mut().zip(sc.sums.row(k)) {
                *o += w * v;
            }
        }
        err = err.max(max_abs_diff(&decoded, payload.row(client)));
        for (a, v) in agg.iter_mut().zip(&decoded) {
            *a += v;
        }
    }
    let aggregate: Vec<f64> = agg.iter().map(|x| x / k4.len() as f64).collect();
    let outcome = if k4.len() == m { Outcome::Full } else { Outcome::Partial { k4 } };
    SimRound { outcome, aggregate: Some(aggregate), true_mean, decode_err: err, transmissions }
}

// ── Fractional-repetition round engine (structured large-M path) ────────

/// Outcome of one fractional-repetition round. Mirrors [`Outcome`] but
/// carries only the covered-group count for partial recovery — never an
/// O(M) member list — so the structured path stays O(M·(s+1)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrOutcome {
    /// A single attempt covered every group (exact-sum standard decode;
    /// attempt index that succeeded).
    Standard { attempt: usize },
    /// The union over GC⁺ repeats covered every group.
    Full,
    /// A proper, non-empty subset of groups was covered.
    Partial { covered_groups: usize },
    /// Nothing decodable.
    None,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrRound {
    pub outcome: FrOutcome,
    pub transmissions: usize,
}

impl FrRound {
    /// |K₄| of the round: recovered clients (members of covered groups).
    pub fn k4_count(&self, code: &FrCode) -> usize {
        match self.outcome {
            FrOutcome::Standard { .. } | FrOutcome::Full => code.m,
            FrOutcome::Partial { covered_groups } => covered_groups * (code.s + 1),
            FrOutcome::None => 0,
        }
    }
}

/// Reusable per-worker buffers of [`simulate_round_fr`]: the sparse
/// realization and the union coverage accumulator — everything O(M·(s+1)).
#[derive(Default)]
pub struct FrSimScratch {
    real: SparseRealization,
    acc: Vec<bool>,
}

impl FrSimScratch {
    pub fn new() -> FrSimScratch {
        FrSimScratch::default()
    }
}

/// Simulate one CoGC round under a fractional-repetition code.
///
/// The structured analogue of [`simulate_round_scratch`]: erasures are
/// drawn only on the group support ([`ChannelModel::sample_sparse_into`])
/// and decoding is the per-group membership scan of
/// [`FrCode::covered`] — dispatched through
/// [`crate::parallel::parallel_map`] with `decode_threads` workers — in
/// place of the RREF engine. Nothing here allocates O(M²).
///
/// Outcome semantics mirror the dense engine: a single attempt covering
/// every group is a standard (exact-sum) decode; under [`Decoder::GcPlus`]
/// the coverage union over `tr` repeats yields full / partial / no
/// recovery. Transmission accounting matches the dense engine too
/// (`s·M` sharing per attempt; uplinks from complete rows under standard,
/// from every client under GC⁺). No payload vectors are drawn — the FR
/// decode is coefficient-free, so the outcome depends only on the channel.
pub fn simulate_round_fr(
    code: &FrCode,
    net: &Network,
    ch: &mut dyn ChannelModel,
    decoder: Decoder,
    decode_threads: usize,
    rng: &mut Rng,
    sc: &mut FrSimScratch,
) -> FrRound {
    let sup = code.sparse_support();
    let (m, s) = (code.m, code.s);
    debug_assert_eq!(net.m, m);
    // FR has no least-squares fallback (coverage is all-or-nothing per
    // group), so Approx degrades to plain GC⁺ semantics here.
    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
    };
    sc.acc.clear();
    sc.acc.resize(code.groups(), false);
    let mut transmissions = 0usize;
    let mut standard_at: Option<usize> = None;

    for a in 0..attempts_n {
        ch.sample_sparse_into(&sup, net, rng, &mut sc.real);
        // gradient-sharing phase: s transmissions per client
        transmissions += s * m;
        // uplink: standard GC sends only complete delivered sums; GC+ all
        transmissions += match decoder {
            Decoder::Standard { .. } => {
                (0..m).filter(|&r| sc.real.row_delivered_complete(r)).count()
            }
            Decoder::GcPlus { .. } | Decoder::Approx { .. } => m,
        };
        let covered = code.covered(&sc.real, decode_threads);
        if standard_at.is_none() && FrCode::all_covered(&covered) {
            standard_at = Some(a);
        }
        FrCode::union_covered(&mut sc.acc, &covered);
    }

    // 1) standard decode: some single attempt covered every group
    if let Some(attempt) = standard_at {
        return FrRound { outcome: FrOutcome::Standard { attempt }, transmissions };
    }
    if let Decoder::Standard { .. } = decoder {
        return FrRound { outcome: FrOutcome::None, transmissions };
    }
    // 2) GC⁺ complementary decode: union coverage over the tr repeats
    let covered_groups = FrCode::covered_groups(&sc.acc);
    let outcome = if covered_groups == code.groups() {
        FrOutcome::Full
    } else if covered_groups > 0 {
        FrOutcome::Partial { covered_groups }
    } else {
        FrOutcome::None
    };
    FrRound { outcome, transmissions }
}

// ── Byzantine-adversarial round engine ──────────────────────────────────

/// Decode error above which a round counts as poisoned. Honest rounds sit
/// below 1e-5 (asserted across the test suite); surviving attacks show
/// O(1) relative error.
const POISON_TOL: f64 = 1e-4;

/// Integrity report of one adversarial round, alongside the usual
/// recovery outcome — the second axis of the 2×2 recovery × integrity
/// split.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdvReport {
    /// Corrupted data actually reached the PS this round (malicious
    /// clients whose tampered messages were all erased don't count).
    pub active: bool,
    /// The audit raised an alarm (some parity check / group vote failed).
    pub detected: bool,
    /// The round's decoded output contains corrupted data — the
    /// decoded-but-poisoned state.
    pub poisoned: bool,
    /// Rows (cyclic) or member copies (FR) excised by the audit.
    pub excised: usize,
    /// Honest rows among the excised (the false-alarm cost).
    pub false_excised: usize,
}

/// Per-worker buffers of [`simulate_round_adv`]: the plain scratch plus
/// the raw coefficient stack, per-row corruption flags, and the
/// kept-row staging used after excision.
#[derive(Default)]
pub struct AdvSimScratch {
    sim: SimScratch,
    /// Raw coded coefficient rows in exact stack order (audit input).
    coeffs: Matrix,
    /// Whether each stacked row carries corrupted data.
    corrupted: Vec<bool>,
    /// Stack indices the PS actually received (standard GC uplinks only
    /// complete sums; GC⁺ uplinks everything) — the audit's input rows.
    uplinked: Vec<usize>,
    /// Payload with malicious rows substituted (c2c surface only).
    adv_payload: Matrix,
}

impl AdvSimScratch {
    pub fn new() -> AdvSimScratch {
        AdvSimScratch::default()
    }

    /// Peel/forward split of the round just simulated (see
    /// [`SimScratch::peel_split`]).
    pub fn peel_split(&self) -> (usize, usize) {
        self.sim.peel_split()
    }

    /// Record the round just simulated into the pooled telemetry shard.
    pub fn harvest(&mut self) {
        self.sim.harvest();
    }

    /// The pooled shard (audit counters are bumped here by the sweep).
    pub fn tel_mut(&mut self) -> &mut telemetry::Shard {
        self.sim.tel_mut()
    }
}

/// [`simulate_round_scratch`] under a Byzantine adversary.
///
/// `adv` must have been `reset` for this trial (its malicious set is the
/// trial's state, like the channel's). When no client is malicious this
/// trial, the round is **byte-identical** to the plain path: same draws,
/// same outcome, zero audit work. Otherwise malicious clients corrupt
/// what they emit — on the [`Surface::Uplink`](crate::scenario::Surface)
/// the coded partial sums they uplink, on `Surface::C2c` the local
/// gradient embedded in everything they send — and, when
/// `adv.spec.detect` is set, the decode path audits the stack with
/// [`gc::byzantine::audit_rows`], excises suspect rows, and re-decodes on
/// the survivors (standard path: re-solve the combinator on the kept
/// complete rows; GC⁺: rebuild the RREF engine on the kept stack).
///
/// Ground truth is known here, so the report's `poisoned` flag is exact:
/// decode error vs the *honest* payloads above [`POISON_TOL`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_adv(
    net: &Network,
    ch: &mut dyn ChannelModel,
    adv: &mut crate::scenario::AdversaryModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
    sc: &mut AdvSimScratch,
) -> (SimRound, AdvReport) {
    if !adv.any() {
        let round = simulate_round_scratch(net, ch, m, s, d, decoder, rng, &mut sc.sim);
        return (round, AdvReport::default());
    }
    use crate::scenario::Surface;
    let surface = adv.spec.surface;
    let detect = adv.spec.detect;

    // emission phase: identical draw order to the plain path
    if sc.sim.payload.rows != m || sc.sim.payload.cols != d {
        sc.sim.payload = Matrix::zeros(m, d);
    }
    for x in &mut sc.sim.payload.data {
        *x = rng.normal();
    }
    let true_mean: Vec<f64> = (0..d)
        .map(|j| (0..m).map(|i| sc.sim.payload[(i, j)]).sum::<f64>() / m as f64)
        .collect();
    // c2c surface: malicious clients encode a substituted gradient
    // consistently everywhere (draws on the adversary substream only)
    if surface == Surface::C2c {
        sc.adv_payload = sc.sim.payload.clone();
        for k in 0..m {
            if adv.is_malicious(k) {
                adv.corrupt_row(sc.adv_payload.row_mut(k));
            }
        }
    }

    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
    };
    if sc.sim.sums.cols != d {
        sc.sim.sums = Matrix::zeros(0, d);
    } else {
        sc.sim.sums.clear_rows();
    }
    if sc.coeffs.cols != m {
        sc.coeffs = Matrix::zeros(0, m);
    } else {
        sc.coeffs.clear_rows();
    }
    sc.corrupted.clear();
    sc.uplinked.clear();
    sc.sim.starts.clear();
    let mut transmissions = 0usize;

    for a in 0..attempts_n {
        let code = GcCode::generate(m, s, rng);
        ch.sample_into(net, rng, &mut sc.sim.real);
        if sc.sim.attempts.len() <= a {
            sc.sim.attempts.push(gc::Attempt::empty());
        }
        let att = &mut sc.sim.attempts[a];
        gc::Attempt::observe_into(&code, &sc.sim.real, att);
        transmissions += s * m;
        transmissions += match decoder {
            Decoder::Standard { .. } => att.complete.len(),
            Decoder::GcPlus { .. } | Decoder::Approx { .. } => m,
        };
        sc.sim.starts.push(sc.sim.sums.rows);
        for &r in &att.delivered {
            let start = sc.sim.sums.data.len();
            sc.sim.sums.data.resize(start + d, 0.0);
            sc.sim.sums.rows += 1;
            let payload =
                if surface == Surface::C2c { &sc.adv_payload } else { &sc.sim.payload };
            let orow = &mut sc.sim.sums.data[start..start + d];
            let mut touches_malicious = false;
            for k in 0..m {
                let c = att.perturbed[(r, k)];
                if c == 0.0 {
                    continue;
                }
                touches_malicious |= adv.is_malicious(k);
                for (o, p) in orow.iter_mut().zip(payload.row(k)) {
                    *o += c * p;
                }
            }
            // an uplink-tampering client corrupts only sums it actually
            // uplinks: all delivered rows under GC⁺, complete rows under
            // standard GC (incomplete sums never reach the PS there)
            let uplinked = matches!(decoder, Decoder::GcPlus { .. } | Decoder::Approx { .. })
                || att.complete.binary_search(&r).is_ok();
            let row_corrupt = match surface {
                Surface::Uplink => {
                    if adv.is_malicious(r) && uplinked {
                        adv.corrupt_row(orow);
                        true
                    } else {
                        false
                    }
                }
                Surface::C2c => touches_malicious,
            };
            sc.coeffs.push_row(att.perturbed.row(r));
            sc.corrupted.push(row_corrupt);
            if uplinked {
                sc.uplinked.push(sc.coeffs.rows - 1);
            }
        }
    }
    let mut report = AdvReport {
        active: sc.uplinked.iter().any(|&i| sc.corrupted[i]),
        ..AdvReport::default()
    };

    // Decode-path audit, run ONCE over everything the PS received. The
    // cyclic B is full-rank, so the rows of a single attempt satisfy no
    // non-trivial linear relation — every parity check crosses attempt
    // boundaries, i.e. detection power is bought with repeat redundancy
    // (attempts/tr ≥ 2); a lone attempt is auditable but unfalsifiable.
    let mut kept_mask = vec![true; sc.coeffs.rows];
    if detect && !sc.uplinked.is_empty() {
        let audit_coeffs = sc.coeffs.select_rows(&sc.uplinked);
        let audit = gc::audit_rows(&audit_coeffs, |combo, kept| {
            // map local audit indices to stack rows
            let orig: Vec<usize> = kept.iter().map(|&j| sc.uplinked[j]).collect();
            gc::payload_check_fails(combo, &orig, &sc.sim.sums)
        });
        report.detected = audit.alarm;
        report.excised = audit.excised.len();
        for &j in &audit.excised {
            let stack_row = sc.uplinked[j];
            kept_mask[stack_row] = false;
            if !sc.corrupted[stack_row] {
                report.false_excised += 1;
            }
        }
    }

    // 1) standard decode on the surviving complete rows of any attempt
    for (i, att) in sc.sim.attempts[..attempts_n].iter().enumerate() {
        if att.complete.len() < m - s {
            continue;
        }
        // stack index of each delivered row is starts[i] + offset;
        // complete ⊆ delivered, both ascending
        let mut kept_clients: Vec<usize> = Vec::with_capacity(att.complete.len());
        {
            let mut ci = 0usize;
            for (off, &r) in att.delivered.iter().enumerate() {
                if ci < att.complete.len() && att.complete[ci] == r {
                    if kept_mask[sc.sim.starts[i] + off] {
                        kept_clients.push(r);
                    }
                    ci += 1;
                }
            }
        }
        if kept_clients.len() < m - s {
            continue; // excision cost this attempt its decodability
        }
        let Some(a) = gc::combinator::find_combinator_rows(&att.perturbed, s, &kept_clients)
        else {
            continue;
        };
        let mut got = vec![0.0f64; d];
        for (off, &r) in att.delivered.iter().enumerate() {
            let coef = a[r];
            if coef == 0.0 {
                continue;
            }
            for (o, v) in got.iter_mut().zip(sc.sim.sums.row(sc.sim.starts[i] + off)) {
                *o += coef * v;
            }
        }
        let target: Vec<f64> = true_mean.iter().map(|x| x * m as f64).collect();
        let err = max_abs_diff(&got, &target);
        report.poisoned = err > POISON_TOL;
        let aggregate: Vec<f64> = got.iter().map(|x| x / m as f64).collect();
        let round = SimRound {
            outcome: Outcome::Standard { attempt: i },
            aggregate: Some(aggregate),
            true_mean,
            decode_err: err,
            transmissions,
        };
        return (round, report);
    }

    if let Decoder::Standard { .. } = decoder {
        let round = SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
        return (round, report);
    }

    // 2) GC⁺: rebuild the incremental engine on the audit's survivors
    let kept: Vec<usize> = (0..sc.coeffs.rows).filter(|&r| kept_mask[r]).collect();
    sc.sim.dec.reset(m);
    for &r in &kept {
        sc.sim.dec.push_row(sc.coeffs.row(r));
    }
    if sc.sim.dec.decodable_count() == 0 {
        // Degraded mode: least-squares over the surviving rows. Poisoning
        // is classified symbolically (any corrupted row with nonzero
        // weight taints the combination) — the approx error itself cannot
        // be thresholded because it is nonzero even on clean rounds.
        if matches!(decoder, Decoder::Approx { .. }) && sc.sim.dec.rank() > 0 {
            if let Some(sol) = gc::approx_sum(&sc.sim.dec) {
                report.poisoned =
                    gc::byzantine::weights_touch_corrupted(&sol.weights, &kept, &sc.corrupted);
                let mut agg = vec![0.0f64; d];
                for (i, &w) in sol.weights.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    for (o, v) in agg.iter_mut().zip(sc.sim.sums.row(kept[i])) {
                        *o += w * v;
                    }
                }
                for a in agg.iter_mut() {
                    *a /= m as f64;
                }
                let err = max_abs_diff(&agg, &true_mean);
                let round = SimRound {
                    outcome: Outcome::Approx { residual: sol.residual },
                    aggregate: Some(agg),
                    true_mean,
                    decode_err: err,
                    transmissions,
                };
                return (round, report);
            }
        }
        let round = SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
        return (round, report);
    }
    let dec = sc.sim.dec.decode();
    let kept_sums = sc.sim.sums.select_rows(&kept);
    let decoded = dec.weights.matmul(&kept_sums);
    let mut err = 0.0f64;
    for (i, &client) in dec.k4.iter().enumerate() {
        err = err.max(max_abs_diff(decoded.row(i), sc.sim.payload.row(client)));
    }
    report.poisoned = err > POISON_TOL;
    let aggregate: Vec<f64> = (0..d)
        .map(|j| (0..dec.k4.len()).map(|i| decoded[(i, j)]).sum::<f64>() / dec.k4.len() as f64)
        .collect();
    let outcome =
        if dec.k4.len() == m { Outcome::Full } else { Outcome::Partial { k4: dec.k4 } };
    let round = SimRound {
        outcome,
        aggregate: Some(aggregate),
        true_mean,
        decode_err: err,
        transmissions,
    };
    (round, report)
}

/// Per-worker buffers of [`simulate_round_binary_adv`]: the binary scratch
/// plus the audit staging (coefficient stack, corruption flags, received
/// rows) mirroring [`AdvSimScratch`].
#[derive(Default)]
pub struct BinAdvScratch {
    bin: BinSimScratch,
    /// Received coded rows in exact stack order (masked ±1 entries).
    coeffs: Matrix,
    corrupted: Vec<bool>,
    uplinked: Vec<usize>,
    adv_payload: Matrix,
}

impl BinAdvScratch {
    pub fn new() -> BinAdvScratch {
        BinAdvScratch::default()
    }

    /// Record the round just simulated into the pooled telemetry shard.
    pub fn harvest(&mut self) {
        self.bin.harvest();
    }

    /// The pooled shard (audit counters are bumped here by the sweep).
    pub fn tel_mut(&mut self) -> &mut telemetry::Shard {
        self.bin.tel_mut()
    }
}

/// [`simulate_round_binary_scratch`] under a Byzantine adversary — the
/// exact-arithmetic analogue of [`simulate_round_adv`]. The audit runs in
/// i128 rational arithmetic ([`gc::audit_rows_int`]): binary rows are
/// integer vectors, so every parity combination is exact and the support
/// test has no float tolerance band. Standard decode re-solves the exact
/// combinator on the surviving complete rows; GC⁺ rebuilds the [`IntRref`]
/// on the surviving stack (plus the float mirror when the decoder is
/// [`Decoder::Approx`], for the least-squares fallback).
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_binary_adv(
    net: &Network,
    ch: &mut dyn ChannelModel,
    adv: &mut crate::scenario::AdversaryModel,
    code: BinaryCode,
    d: usize,
    decoder: Decoder,
    rng: &mut Rng,
    sc: &mut BinAdvScratch,
) -> (SimRound, AdvReport) {
    if !adv.any() {
        let round = simulate_round_binary_scratch(net, ch, code, d, decoder, rng, &mut sc.bin);
        return (round, AdvReport::default());
    }
    use crate::scenario::Surface;
    let (m, s) = (code.m, code.s);
    debug_assert_eq!(net.m, m);
    let surface = adv.spec.surface;
    let detect = adv.spec.detect;

    // emission phase: identical draw order to the plain binary path
    if sc.bin.payload.rows != m || sc.bin.payload.cols != d {
        sc.bin.payload = Matrix::zeros(m, d);
    }
    for x in &mut sc.bin.payload.data {
        *x = rng.normal();
    }
    let true_mean: Vec<f64> = (0..d)
        .map(|j| (0..m).map(|i| sc.bin.payload[(i, j)]).sum::<f64>() / m as f64)
        .collect();
    if surface == Surface::C2c {
        sc.adv_payload = sc.bin.payload.clone();
        for k in 0..m {
            if adv.is_malicious(k) {
                adv.corrupt_row(sc.adv_payload.row_mut(k));
            }
        }
    }

    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
    };
    if !matches!(&sc.bin.bridge, Some((c, _)) if *c == code) {
        sc.bin.bridge = Some((code, code.to_gc_code()));
    }
    if sc.bin.sums.cols != d {
        sc.bin.sums = Matrix::zeros(0, d);
    } else {
        sc.bin.sums.clear_rows();
    }
    if sc.coeffs.cols != m {
        sc.coeffs = Matrix::zeros(0, m);
    } else {
        sc.coeffs.clear_rows();
    }
    sc.corrupted.clear();
    sc.uplinked.clear();
    sc.bin.starts.clear();
    let mut transmissions = 0usize;

    for a in 0..attempts_n {
        ch.sample_into(net, rng, &mut sc.bin.real);
        if sc.bin.attempts.len() <= a {
            sc.bin.attempts.push(gc::Attempt::empty());
        }
        let gc_code = &sc.bin.bridge.as_ref().expect("bridge built above").1;
        let att = &mut sc.bin.attempts[a];
        gc::Attempt::observe_into(gc_code, &sc.bin.real, att);
        transmissions += s * m;
        transmissions += match decoder {
            Decoder::Standard { .. } => att.complete.len(),
            Decoder::GcPlus { .. } | Decoder::Approx { .. } => m,
        };
        sc.bin.starts.push(sc.bin.sums.rows);
        for &r in &att.delivered {
            let start = sc.bin.sums.data.len();
            sc.bin.sums.data.resize(start + d, 0.0);
            sc.bin.sums.rows += 1;
            let payload =
                if surface == Surface::C2c { &sc.adv_payload } else { &sc.bin.payload };
            let orow = &mut sc.bin.sums.data[start..start + d];
            let mut touches_malicious = false;
            for k in 0..m {
                let c = att.perturbed[(r, k)];
                if c == 0.0 {
                    continue;
                }
                touches_malicious |= adv.is_malicious(k);
                for (o, p) in orow.iter_mut().zip(payload.row(k)) {
                    *o += c * p;
                }
            }
            let uplinked = matches!(decoder, Decoder::GcPlus { .. } | Decoder::Approx { .. })
                || att.complete.binary_search(&r).is_ok();
            let row_corrupt = match surface {
                Surface::Uplink => {
                    if adv.is_malicious(r) && uplinked {
                        adv.corrupt_row(orow);
                        true
                    } else {
                        false
                    }
                }
                Surface::C2c => touches_malicious,
            };
            sc.coeffs.push_row(att.perturbed.row(r));
            sc.corrupted.push(row_corrupt);
            if uplinked {
                sc.uplinked.push(sc.coeffs.rows - 1);
            }
        }
    }
    let mut report = AdvReport {
        active: sc.uplinked.iter().any(|&i| sc.corrupted[i]),
        ..AdvReport::default()
    };

    // decode-path audit in exact arithmetic (see simulate_round_adv for
    // the repeat-redundancy argument — it holds verbatim here)
    let mut kept_mask = vec![true; sc.coeffs.rows];
    if detect && !sc.uplinked.is_empty() {
        let audit_coeffs = sc.coeffs.select_rows(&sc.uplinked);
        let audit = gc::audit_rows_int(&audit_coeffs, |combo, kept| {
            let orig: Vec<usize> = kept.iter().map(|&j| sc.uplinked[j]).collect();
            gc::payload_check_fails(combo, &orig, &sc.bin.sums)
        });
        report.detected = audit.alarm;
        report.excised = audit.excised.len();
        for &j in &audit.excised {
            let stack_row = sc.uplinked[j];
            kept_mask[stack_row] = false;
            if !sc.corrupted[stack_row] {
                report.false_excised += 1;
            }
        }
    }

    // 1) standard decode: exact combinator over the surviving complete rows
    for (i, att) in sc.bin.attempts[..attempts_n].iter().enumerate() {
        if att.complete.len() < m - s {
            continue;
        }
        let mut kept_clients: Vec<usize> = Vec::with_capacity(att.complete.len());
        {
            let mut ci = 0usize;
            for (off, &r) in att.delivered.iter().enumerate() {
                if ci < att.complete.len() && att.complete[ci] == r {
                    if kept_mask[sc.bin.starts[i] + off] {
                        kept_clients.push(r);
                    }
                    ci += 1;
                }
            }
        }
        let Some(a) = code.combinator_weights(&kept_clients) else {
            continue;
        };
        let mut got = vec![0.0f64; d];
        let mut next = 0usize;
        for (off, &r) in att.delivered.iter().enumerate() {
            // kept_clients ⊆ complete ⊆ delivered, all ascending
            if next >= kept_clients.len() || kept_clients[next] != r {
                continue;
            }
            let coef = a[next];
            next += 1;
            if coef == 0.0 {
                continue;
            }
            for (o, v) in got.iter_mut().zip(sc.bin.sums.row(sc.bin.starts[i] + off)) {
                *o += coef * v;
            }
        }
        let target: Vec<f64> = true_mean.iter().map(|x| x * m as f64).collect();
        let err = max_abs_diff(&got, &target);
        report.poisoned = err > POISON_TOL;
        let aggregate: Vec<f64> = got.iter().map(|x| x / m as f64).collect();
        let round = SimRound {
            outcome: Outcome::Standard { attempt: i },
            aggregate: Some(aggregate),
            true_mean,
            decode_err: err,
            transmissions,
        };
        return (round, report);
    }

    if let Decoder::Standard { .. } = decoder {
        let round = SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
        return (round, report);
    }

    // 2) GC⁺: rebuild the exact engine on the audit's survivors
    let kept: Vec<usize> = (0..sc.coeffs.rows).filter(|&r| kept_mask[r]).collect();
    sc.bin.ieng.reset(m);
    if matches!(decoder, Decoder::Approx { .. }) {
        sc.bin.fdec.reset(m);
    }
    for &r in &kept {
        sc.bin.ibuf.clear();
        sc.bin.ibuf.extend(sc.coeffs.row(r).iter().map(|&v| {
            debug_assert_eq!(v, v as i64 as f64);
            v as i64
        }));
        sc.bin.ieng.push_row(&sc.bin.ibuf);
        if matches!(decoder, Decoder::Approx { .. }) {
            sc.bin.fdec.push_row(sc.coeffs.row(r));
        }
    }
    let k4_n = sc.bin.ieng.decodable_count();
    if k4_n == 0 {
        // degraded mode over the float mirror; poisoning is symbolic (any
        // surviving corrupted row with nonzero weight taints the mean)
        if matches!(decoder, Decoder::Approx { .. }) && sc.bin.fdec.rank() > 0 {
            if let Some(sol) = gc::approx_sum(&sc.bin.fdec) {
                report.poisoned =
                    gc::byzantine::weights_touch_corrupted(&sol.weights, &kept, &sc.corrupted);
                let mut agg = vec![0.0f64; d];
                for (i, &w) in sol.weights.iter().enumerate() {
                    if w == 0.0 {
                        continue;
                    }
                    for (o, v) in agg.iter_mut().zip(sc.bin.sums.row(kept[i])) {
                        *o += w * v;
                    }
                }
                for x in agg.iter_mut() {
                    *x /= m as f64;
                }
                let err = max_abs_diff(&agg, &true_mean);
                let round = SimRound {
                    outcome: Outcome::Approx { residual: sol.residual },
                    aggregate: Some(agg),
                    true_mean,
                    decode_err: err,
                    transmissions,
                };
                return (round, report);
            }
        }
        let round = SimRound {
            outcome: Outcome::None,
            aggregate: None,
            true_mean,
            decode_err: 0.0,
            transmissions,
        };
        return (round, report);
    }
    let mut k4 = Vec::with_capacity(k4_n);
    let mut err = 0.0f64;
    let mut agg = vec![0.0f64; d];
    for (client, row) in sc.bin.ieng.decodable() {
        k4.push(client);
        sc.bin.ieng.t_row_f64(row, &mut sc.bin.wbuf);
        let mut decoded = vec![0.0f64; d];
        for (i, &w) in sc.bin.wbuf.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            for (o, v) in decoded.iter_mut().zip(sc.bin.sums.row(kept[i])) {
                *o += w * v;
            }
        }
        err = err.max(max_abs_diff(&decoded, sc.bin.payload.row(client)));
        for (x, v) in agg.iter_mut().zip(&decoded) {
            *x += v;
        }
    }
    report.poisoned = err > POISON_TOL;
    let aggregate: Vec<f64> = agg.iter().map(|x| x / k4.len() as f64).collect();
    let outcome = if k4.len() == m { Outcome::Full } else { Outcome::Partial { k4 } };
    let round = SimRound {
        outcome,
        aggregate: Some(aggregate),
        true_mean,
        decode_err: err,
        transmissions,
    };
    (round, report)
}

/// Per-worker buffers of [`simulate_round_fr_adv`].
#[derive(Default)]
pub struct FrAdvScratch {
    fr: FrSimScratch,
    verdicts: Vec<crate::scenario::GroupVerdict>,
    acc: Vec<crate::scenario::GroupVerdict>,
}

impl FrAdvScratch {
    pub fn new() -> FrAdvScratch {
        FrAdvScratch::default()
    }
}

/// [`simulate_round_fr`] under a Byzantine adversary — payload-free, so
/// the integrity audit is the structural plurality vote of
/// [`AdversaryModel::fr_attempt_verdicts`](crate::scenario::AdversaryModel::fr_attempt_verdicts)
/// over each group's delivered copies, still O(M·(s+1)) per attempt.
/// With detection, the union across GC⁺ repeats keeps the best verdict
/// per group (a cleanly validated copy from any attempt wins); without,
/// the first delivered copy sticks, exactly as a vote-less PS would
/// behave.
#[allow(clippy::too_many_arguments)]
pub fn simulate_round_fr_adv(
    code: &FrCode,
    net: &Network,
    ch: &mut dyn ChannelModel,
    adv: &mut crate::scenario::AdversaryModel,
    decoder: Decoder,
    decode_threads: usize,
    rng: &mut Rng,
    sc: &mut FrAdvScratch,
) -> (FrRound, AdvReport) {
    if !adv.any() {
        let round = simulate_round_fr(code, net, ch, decoder, decode_threads, rng, &mut sc.fr);
        return (round, AdvReport::default());
    }
    use crate::scenario::GroupVerdict;
    let sup = code.sparse_support();
    let (m, s) = (code.m, code.s);
    let detect = adv.spec.detect;
    let attempts_n = match decoder {
        Decoder::Standard { attempts } => attempts,
        // FR coverage is all-or-nothing per group — no least-squares
        // fallback exists, so Approx degrades to plain GC⁺ semantics.
        Decoder::GcPlus { tr } | Decoder::Approx { tr } => tr,
    };
    sc.acc.clear();
    sc.acc.resize(code.groups(), GroupVerdict::Uncovered);
    let mut transmissions = 0usize;
    let mut standard_at: Option<usize> = None;
    let mut report = AdvReport::default();

    for a in 0..attempts_n {
        ch.sample_sparse_into(&sup, net, rng, &mut sc.fr.real);
        transmissions += s * m;
        transmissions += match decoder {
            Decoder::Standard { .. } => {
                (0..m).filter(|&r| sc.fr.real.row_delivered_complete(r)).count()
            }
            Decoder::GcPlus { .. } | Decoder::Approx { .. } => m,
        };
        let audit = adv.fr_attempt_verdicts(code, &sc.fr.real, &mut sc.verdicts);
        report.active |= audit.active;
        report.detected |= audit.alarms > 0;
        report.excised += audit.excised;
        report.false_excised += audit.false_excised;
        if standard_at.is_none() && sc.verdicts.iter().all(|v| v.covered()) {
            standard_at = Some(a);
        }
        for (acc, &v) in sc.acc.iter_mut().zip(sc.verdicts.iter()) {
            if detect {
                // best verdict wins: Clean > Poisoned > Excised > Uncovered
                *acc = (*acc).max(v);
            } else if !acc.covered() && v != GroupVerdict::Uncovered {
                *acc = v; // the PS keeps the first value it accepted
            }
        }
    }
    report.poisoned = sc.acc.iter().any(|&v| v == GroupVerdict::Poisoned);

    if let Some(attempt) = standard_at {
        let round = FrRound { outcome: FrOutcome::Standard { attempt }, transmissions };
        return (round, report);
    }
    if let Decoder::Standard { .. } = decoder {
        return (FrRound { outcome: FrOutcome::None, transmissions }, report);
    }
    let covered_groups = sc.acc.iter().filter(|v| v.covered()).count();
    let outcome = if covered_groups == code.groups() {
        FrOutcome::Full
    } else if covered_groups > 0 {
        FrOutcome::Partial { covered_groups }
    } else {
        FrOutcome::None
    };
    (FrRound { outcome, transmissions }, report)
}

/// Aggregate tallies of a [`sweep`] over many simulated rounds.
///
/// Every field combines associatively (counts, integer sums, a maximum), so
/// per-worker instances merge exactly — the requirement of the parallel
/// engine's determinism guarantee. Note the decode error is tracked as a
/// *maximum* (order-independent), never an order-sensitive float sum.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepStats {
    pub trials: usize,
    /// Rounds decoded by the standard (binary) GC combinator.
    pub standard: usize,
    /// Rounds where GC⁺ recovered all M payloads.
    pub full: usize,
    /// Rounds where GC⁺ recovered a proper subset.
    pub partial: usize,
    /// Rounds with nothing decodable.
    pub none: usize,
    /// Rounds recovered by the degraded-mode least-squares fallback
    /// ([`Decoder::Approx`] only; always 0 for the other decoders).
    pub approx: usize,
    /// Total transmissions consumed across all rounds.
    pub transmissions: usize,
    /// Worst numerical decode error observed over all *exact* decoding
    /// rounds (standard / full / partial).
    pub max_decode_err: f64,
    /// Worst gradient error |approx aggregate − true mean| over the
    /// approx-recovered rounds. Tracked separately: it is a modelling
    /// error, not a numerical one, and would swamp `max_decode_err`.
    pub max_approx_err: f64,
}

impl SweepStats {
    /// Fraction of rounds that produced *some* global update (approx
    /// rounds count — the PS applies the degraded aggregate).
    pub fn p_update(&self) -> f64 {
        (self.standard + self.full + self.partial + self.approx) as f64 / self.trials as f64
    }

    pub fn mean_transmissions(&self) -> f64 {
        self.transmissions as f64 / self.trials as f64
    }
}

impl Accumulate for SweepStats {
    fn merge(&mut self, other: Self) {
        self.trials += other.trials;
        self.standard += other.standard;
        self.full += other.full;
        self.partial += other.partial;
        self.none += other.none;
        self.approx += other.approx;
        self.transmissions += other.transmissions;
        self.max_decode_err = self.max_decode_err.max(other.max_decode_err);
        self.max_approx_err = self.max_approx_err.max(other.max_approx_err);
    }
}

/// Run `trials` independent [`simulate_round`]s through the parallel engine
/// and tally the outcomes. Bit-identical for any thread count.
///
/// `ch` is a prototype: the engine clones it once per worker and resets the
/// clone from each trial's channel-state substream, so stateful models are
/// independent across trials and identical for every work-stealing
/// schedule. All round buffers (realization, attempts, partial sums, the
/// incremental decoder) are pooled per worker via [`SimScratch`] — the
/// steady-state trial body allocates only its round result.
pub fn sweep(
    net: &Network,
    ch: &dyn ChannelModel,
    m: usize,
    s: usize,
    d: usize,
    decoder: Decoder,
    trials: usize,
    mc: &MonteCarlo,
) -> SweepStats {
    mc.run_scratch(
        trials,
        || (ch.clone_box(), SimScratch::new()),
        |t, rng, acc: &mut SweepStats, (chb, sc)| {
            chb.reset(net, mc.substream_seed(CHANNEL_STREAM, t));
            let r = simulate_round_scratch(net, &mut **chb, m, s, d, decoder, rng, sc);
            acc.trials += 1;
            match r.outcome {
                Outcome::Standard { .. } => acc.standard += 1,
                Outcome::Full => acc.full += 1,
                Outcome::Partial { .. } => acc.partial += 1,
                Outcome::Approx { .. } => acc.approx += 1,
                Outcome::None => acc.none += 1,
            }
            acc.transmissions += r.transmissions;
            if matches!(r.outcome, Outcome::Approx { .. }) {
                acc.max_approx_err = acc.max_approx_err.max(r.decode_err);
            } else {
                acc.max_decode_err = acc.max_decode_err.max(r.decode_err);
            }
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Iid;
    use crate::testing::Prop;

    #[test]
    fn perfect_network_standard_decodes_exactly() {
        let net = Network::perfect(10);
        let mut rng = Rng::new(1);
        let r =
            simulate_round(&net, &mut Iid, 10, 7, 23, Decoder::Standard { attempts: 1 }, &mut rng);
        assert!(matches!(r.outcome, Outcome::Standard { attempt: 0 }));
        assert!(r.decode_err < 1e-6, "err = {}", r.decode_err);
        let agg = r.aggregate.unwrap();
        assert!(max_abs_diff(&agg, &r.true_mean) < 1e-9);
        // transmissions: sM + M complete uplinks = 7*10 + 10
        assert_eq!(r.transmissions, 80);
    }

    #[test]
    fn gcplus_full_recovery_matches_true_mean() {
        // moderate c2c erasures + good uplinks: standard GC often fails
        // (incomplete sums) but the perturbation-boosted rank lets GC+
        // achieve full recovery, matching the exact mean.
        let net = Network::homogeneous(10, 0.1, 0.5);
        let mut rng = Rng::new(2);
        let mut fulls = 0;
        for _ in 0..60 {
            let r =
                simulate_round(&net, &mut Iid, 10, 7, 11, Decoder::GcPlus { tr: 2 }, &mut rng);
            if r.outcome == Outcome::Full {
                fulls += 1;
                assert!(r.decode_err < 1e-6);
                assert!(max_abs_diff(&r.aggregate.unwrap(), &r.true_mean) < 1e-8);
            }
        }
        assert!(fulls > 10, "full recoveries: {fulls}");
    }

    #[test]
    fn prop_decode_error_always_small_when_decoding() {
        Prop::new(30).forall("sim decode error", |rng, _| {
            let m = rng.range(4, 11);
            let s = rng.range(1, m);
            let p = rng.uniform(0.1, 0.8);
            let net = Network::homogeneous(m, p, p);
            let dec = if rng.bernoulli(0.5) {
                Decoder::Standard { attempts: 2 }
            } else {
                Decoder::GcPlus { tr: 2 }
            };
            let r = simulate_round(&net, &mut Iid, m, s, 9, dec, rng);
            assert!(
                r.decode_err < 1e-5,
                "decode error {} (outcome {:?})",
                r.decode_err,
                r.outcome
            );
        });
    }

    #[test]
    fn sweep_tallies_partition_and_decode_exactly() {
        let net = Network::homogeneous(8, 0.3, 0.3);
        let st = sweep(&net, &Iid, 8, 3, 5, Decoder::GcPlus { tr: 2 }, 300, &MonteCarlo::new(9));
        assert_eq!(st.trials, 300);
        assert_eq!(st.standard + st.full + st.partial + st.none, st.trials);
        assert!(st.p_update() > 0.0 && st.p_update() <= 1.0);
        assert!(st.mean_transmissions() > 0.0);
        assert!(st.max_decode_err < 1e-5, "decode err {}", st.max_decode_err);
    }

    #[test]
    fn sweep_is_thread_count_invariant() {
        let net = Network::homogeneous(8, 0.4, 0.4);
        let run = |threads: usize| {
            sweep(
                &net,
                &Iid,
                8,
                3,
                5,
                Decoder::GcPlus { tr: 2 },
                400,
                &MonteCarlo::new(17).with_threads(threads),
            )
        };
        let want = run(1);
        for threads in [2usize, 8] {
            assert_eq!(run(threads), want, "threads={threads}");
        }
    }

    #[test]
    fn approx_only_reclassifies_gcplus_outage_rounds() {
        // Approx draws identically to GC⁺ (same code draws, same channel
        // realizations, same transmission accounting); the only divergence
        // is that some None rounds become Approx. Everything else must be
        // bit-identical.
        let net = Network::homogeneous(8, 0.6, 0.6);
        let mc = MonteCarlo::new(23);
        let exact = sweep(&net, &Iid, 8, 3, 5, Decoder::GcPlus { tr: 2 }, 500, &mc);
        let approx = sweep(&net, &Iid, 8, 3, 5, Decoder::Approx { tr: 2 }, 500, &mc);
        assert_eq!(exact.standard, approx.standard);
        assert_eq!(exact.full, approx.full);
        assert_eq!(exact.partial, approx.partial);
        assert_eq!(exact.transmissions, approx.transmissions);
        assert_eq!(exact.approx, 0);
        assert_eq!(exact.none, approx.none + approx.approx);
        assert_eq!(exact.max_decode_err.to_bits(), approx.max_decode_err.to_bits());
        assert!(approx.approx > 0, "lossy net never exercised the fallback");
        assert!(approx.max_approx_err > 0.0);
    }

    #[test]
    fn approx_sweep_is_thread_count_invariant() {
        let net = Network::homogeneous(8, 0.55, 0.55);
        let run = |threads: usize| {
            sweep(
                &net,
                &Iid,
                8,
                3,
                5,
                Decoder::Approx { tr: 2 },
                400,
                &MonteCarlo::new(31).with_threads(threads),
            )
        };
        let want = run(1);
        assert!(want.approx > 0, "fallback never fired");
        for threads in [2usize, 8] {
            assert_eq!(run(threads), want, "threads={threads}");
        }
    }

    #[test]
    fn binary_approx_round_recovers_or_matches_gcplus() {
        // Binary family under Approx: the float mirror decoder feeds the
        // least-squares fallback while the exact engine keeps decode
        // authority. On each round the outcome either matches the GC⁺ run
        // exactly or upgrades a None to an Approx with a finite residual.
        let code = crate::gc::BinaryCode::new(8, 2).unwrap();
        let net = Network::homogeneous(8, 0.6, 0.6);
        let (mut upgraded, mut matched) = (0usize, 0usize);
        for trial in 0..200u64 {
            let mut ra = Rng::new(7 ^ trial);
            let mut rb = Rng::new(7 ^ trial);
            let exact =
                simulate_round_binary(&net, &mut Iid, code, 5, Decoder::GcPlus { tr: 2 }, &mut ra);
            let approx =
                simulate_round_binary(&net, &mut Iid, code, 5, Decoder::Approx { tr: 2 }, &mut rb);
            assert_eq!(exact.transmissions, approx.transmissions);
            match (&exact.outcome, &approx.outcome) {
                (Outcome::None, Outcome::Approx { residual }) => {
                    assert!(residual.is_finite() && *residual >= 0.0);
                    assert!(approx.aggregate.is_some());
                    upgraded += 1;
                }
                (a, b) => {
                    assert_eq!(a, b, "trial {trial}");
                    matched += 1;
                }
            }
        }
        assert!(upgraded > 0, "fallback never fired ({matched} matched)");
    }

    fn byz_spec(detect: bool) -> crate::scenario::AdversarySpec {
        crate::scenario::AdversarySpec {
            attack: crate::scenario::Attack::SignFlip,
            selection: crate::scenario::Selection::Fraction(0.4),
            surface: crate::scenario::Surface::Uplink,
            detect,
        }
    }

    #[test]
    fn binary_adv_exact_audit_detects_and_report_is_consistent() {
        // Exact i128 audit over the deterministic ±1 code: with repeats
        // (tr = 2) the parity checks must fire on sign-flipped uplinks,
        // and the integrity report must stay internally consistent.
        let code = crate::gc::BinaryCode::new(8, 2).unwrap();
        let net = Network::homogeneous(8, 0.2, 0.2);
        let mut on = crate::scenario::AdversaryModel::new(byz_spec(true));
        let mut off = crate::scenario::AdversaryModel::new(byz_spec(false));
        let mut sc_on = BinAdvScratch::new();
        let mut sc_off = BinAdvScratch::new();
        let (mut active, mut detected, mut poisoned_on, mut poisoned_off) = (0, 0, 0, 0);
        for trial in 0..300u64 {
            on.reset(8, 0xAD ^ trial);
            off.reset(8, 0xAD ^ trial);
            let mut ra = Rng::new(11 ^ trial);
            let mut rb = Rng::new(11 ^ trial);
            let (r_on, rep_on) = simulate_round_binary_adv(
                &net,
                &mut Iid,
                &mut on,
                code,
                4,
                Decoder::GcPlus { tr: 2 },
                &mut ra,
                &mut sc_on,
            );
            let (r_off, rep_off) = simulate_round_binary_adv(
                &net,
                &mut Iid,
                &mut off,
                code,
                4,
                Decoder::GcPlus { tr: 2 },
                &mut rb,
                &mut sc_off,
            );
            // the attack and audit never change the communication bill
            assert_eq!(r_on.transmissions, r_off.transmissions, "trial {trial}");
            assert!(rep_on.false_excised <= rep_on.excised);
            if !rep_on.active {
                // no corrupted data reached the PS: honest rows satisfy
                // every exact parity check, so nothing fires
                assert!(!rep_on.detected && !rep_on.poisoned && rep_on.excised == 0);
            }
            assert!(!rep_off.detected && rep_off.excised == 0);
            active += rep_on.active as usize;
            detected += rep_on.detected as usize;
            poisoned_on += rep_on.poisoned as usize;
            poisoned_off += rep_off.poisoned as usize;
        }
        assert!(active > 0, "attack never reached the PS");
        assert!(detected > 0, "exact audit never fired");
        assert!(poisoned_off > 0, "undetected sign flips must poison decodes");
        assert!(
            poisoned_on < poisoned_off,
            "excision should cut poisoning ({poisoned_on} vs {poisoned_off})"
        );
    }

    #[test]
    fn binary_adv_without_malicious_clients_matches_plain_path() {
        // Fraction-0 adversary: every trial delegates to the plain binary
        // path on the same rng stream — rounds must be byte-identical.
        let code = crate::gc::BinaryCode::new(8, 2).unwrap();
        let net = Network::homogeneous(8, 0.5, 0.5);
        let mut adv = crate::scenario::AdversaryModel::new(crate::scenario::AdversarySpec {
            selection: crate::scenario::Selection::Fraction(0.0),
            ..byz_spec(true)
        });
        let mut sc = BinAdvScratch::new();
        for trial in 0..50u64 {
            adv.reset(8, 0xAD ^ trial);
            assert!(!adv.any());
            let mut ra = Rng::new(17 ^ trial);
            let mut rb = Rng::new(17 ^ trial);
            let (got, rep) = simulate_round_binary_adv(
                &net,
                &mut Iid,
                &mut adv,
                code,
                4,
                Decoder::Approx { tr: 2 },
                &mut ra,
                &mut sc,
            );
            let want =
                simulate_round_binary(&net, &mut Iid, code, 4, Decoder::Approx { tr: 2 }, &mut rb);
            assert_eq!(rep, AdvReport::default());
            assert_eq!(got.outcome, want.outcome, "trial {trial}");
            assert_eq!(got.transmissions, want.transmissions);
            assert_eq!(got.decode_err.to_bits(), want.decode_err.to_bits());
            assert_eq!(got.aggregate, want.aggregate);
            assert_eq!(ra.next_u64(), rb.next_u64(), "rng streams diverged");
        }
    }

    #[test]
    fn standard_none_when_all_uplinks_dead() {
        let net = Network::homogeneous(6, 1.0, 0.0);
        let mut rng = Rng::new(3);
        let r =
            simulate_round(&net, &mut Iid, 6, 2, 5, Decoder::Standard { attempts: 3 }, &mut rng);
        assert_eq!(r.outcome, Outcome::None);
        assert!(r.aggregate.is_none());
    }

    #[test]
    fn fr_perfect_network_standard_decodes_first_attempt() {
        let code = FrCode::new(12, 3).unwrap();
        let net = Network::perfect(12);
        let mut rng = Rng::new(1);
        let mut sc = FrSimScratch::new();
        let r = simulate_round_fr(
            &code,
            &net,
            &mut Iid,
            Decoder::Standard { attempts: 1 },
            1,
            &mut rng,
            &mut sc,
        );
        assert_eq!(r.outcome, FrOutcome::Standard { attempt: 0 });
        // transmissions: sM sharing + M complete uplinks = 3*12 + 12
        assert_eq!(r.transmissions, 48);
        assert_eq!(r.k4_count(&code), 12);
    }

    #[test]
    fn fr_dead_uplinks_decode_nothing() {
        let code = FrCode::new(8, 1).unwrap();
        let net = Network::homogeneous(8, 1.0, 0.0);
        let mut rng = Rng::new(2);
        let mut sc = FrSimScratch::new();
        for dec in [Decoder::Standard { attempts: 2 }, Decoder::GcPlus { tr: 2 }] {
            let r = simulate_round_fr(&code, &net, &mut Iid, dec, 1, &mut rng, &mut sc);
            assert_eq!(r.outcome, FrOutcome::None);
            assert_eq!(r.k4_count(&code), 0);
        }
    }

    #[test]
    fn fr_outcomes_partition_and_partials_appear() {
        // lossy enough that coverage is usually partial over GC+ repeats
        let code = FrCode::new(12, 2).unwrap();
        let net = Network::homogeneous(12, 0.6, 0.5);
        let mut rng = Rng::new(5);
        let mut sc = FrSimScratch::new();
        let (mut partial, mut k4_tot) = (0usize, 0usize);
        for _ in 0..200 {
            let r = simulate_round_fr(
                &code,
                &net,
                &mut Iid,
                Decoder::GcPlus { tr: 2 },
                1,
                &mut rng,
                &mut sc,
            );
            if let FrOutcome::Partial { covered_groups } = r.outcome {
                partial += 1;
                assert!(covered_groups >= 1 && covered_groups < code.groups());
                assert_eq!(r.k4_count(&code), covered_groups * 3);
            }
            k4_tot += r.k4_count(&code);
        }
        assert!(partial > 20, "partials: {partial}");
        assert!(k4_tot > 0);
    }

    #[test]
    fn fr_decode_threads_do_not_change_outcomes() {
        let code = FrCode::new(24, 3).unwrap();
        let net = Network::homogeneous(24, 0.4, 0.3);
        let run = |threads: usize| {
            let mut rng = Rng::new(7);
            let mut sc = FrSimScratch::new();
            (0..50)
                .map(|_| {
                    simulate_round_fr(
                        &code,
                        &net,
                        &mut Iid,
                        Decoder::GcPlus { tr: 2 },
                        threads,
                        &mut rng,
                        &mut sc,
                    )
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4));
    }
}
